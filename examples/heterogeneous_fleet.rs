//! Scenario: a strongly heterogeneous edge fleet (the paper's §I
//! motivation) — compare Heroes against FedAvg under the same devices,
//! links and data, and show where the speedup comes from: per-client
//! width + τ adaptation and factorized transfers.
//!
//! ```bash
//! make artifacts && cargo run --release --example heterogeneous_fleet
//! ```

// Test/bench/example code: panicking on setup failure is idiomatic
// (CONTRIBUTING.md — the error-handling contract binds library code).
#![allow(clippy::unwrap_used, clippy::expect_used, clippy::indexing_slicing)]


use heroes::baselines::make_strategy;
use heroes::baselines::Strategy;
use heroes::config::{ExperimentConfig, Scale};
use heroes::coordinator::env::FlEnv;
use heroes::runtime::{EnginePool, Manifest};
use heroes::simulation::DeviceClass;
use heroes::util::rng::Rng;

fn run(pool: &EnginePool, cfg: &ExperimentConfig, scheme: &str) -> anyhow::Result<()> {
    let mut env = FlEnv::build(pool, cfg.clone())?;

    // Show the fleet composition once.
    if scheme == "heroes" {
        let mut counts = [0usize; 4];
        for d in &env.fleet.devices {
            counts[match d.class {
                DeviceClass::Laptop => 0,
                DeviceClass::JetsonTx2 => 1,
                DeviceClass::XavierNx => 2,
                DeviceClass::AgxXavier => 3,
            }] += 1;
        }
        println!(
            "fleet: {} laptop, {} tx2, {} xavier-nx, {} agx-xavier",
            counts[0], counts[1], counts[2], counts[3]
        );
    }

    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut s = make_strategy(scheme, &env.info, cfg, &mut rng)?;
    let mut waits = Vec::new();
    for _ in 0..cfg.rounds {
        let r = s.run_round(&mut env)?;
        waits.push(r.avg_wait);
    }
    let (loss, acc) = s.evaluate(&env)?;
    println!(
        "{scheme:<9} sim {:>8.1}s  traffic {:>8.4} GB  mean wait {:>6.2}s  loss {loss:.3} acc {:>5.1}%",
        env.clock.now(),
        env.traffic.total_gb(),
        heroes::util::stats::mean(&waits),
        acc * 100.0
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    heroes::util::logging::init_from_env();
    let pool = EnginePool::single(Manifest::load(&Manifest::default_dir())?)?;
    let mut cfg = ExperimentConfig::preset("cnn", Scale::Smoke);
    cfg.rounds = 25;
    println!(
        "heterogeneous fleet: {} clients, {} per round, Γ=40 Non-IID\n",
        cfg.n_clients, cfg.k_per_round
    );
    for scheme in ["fedavg", "heterofl", "heroes"] {
        run(&pool, &cfg, scheme)?;
    }
    println!("\nsame rounds — Heroes spends far less simulated time and traffic.");
    Ok(())
}
