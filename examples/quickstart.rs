//! Quickstart: the smallest end-to-end Heroes run.
//!
//! Builds a 10-client federated world over the synthetic CIFAR twin,
//! runs 20 Heroes rounds through the AOT PJRT executables and prints the
//! accuracy trajectory plus the controller's decisions along the way.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

// Test/bench/example code: panicking on setup failure is idiomatic
// (CONTRIBUTING.md — the error-handling contract binds library code).
#![allow(clippy::unwrap_used, clippy::expect_used, clippy::indexing_slicing)]


use heroes::baselines::Strategy;
use heroes::config::{ExperimentConfig, Scale};
use heroes::coordinator::env::FlEnv;
use heroes::coordinator::server::HeroesServer;
use heroes::runtime::{EnginePool, Manifest};
use heroes::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    heroes::util::logging::init_from_env();

    // 1. Load the AOT artifacts (HLO text + manifest) and start PJRT.
    let pool = EnginePool::single(Manifest::load(&Manifest::default_dir())?)?;

    // 2. Configure a small federated world.
    let mut cfg = ExperimentConfig::preset("cnn", Scale::Smoke);
    cfg.n_clients = 10;
    cfg.k_per_round = 5;
    cfg.samples_per_client = 40;
    cfg.rounds = 20;
    let mut env = FlEnv::build(&pool, cfg.clone())?;

    // 3. The Heroes parameter server (paper Alg. 1).
    let mut rng = Rng::new(cfg.seed);
    let mut server = HeroesServer::new(&env.info, &cfg, &mut rng)?;

    let (loss0, acc0) = server.evaluate(&env)?;
    println!("round  0: loss {loss0:.4} acc {:.1}%  (untrained)", acc0 * 100.0);

    // 4. Federated rounds: width/τ/block assignment -> local SGD via the
    //    AOT train executables -> block-wise aggregation.
    for round in 1..=cfg.rounds {
        let r = server.run_round(&mut env)?;
        if round % 5 == 0 {
            let (loss, acc) = server.evaluate(&env)?;
            println!(
                "round {round:>2}: loss {loss:.4} acc {:>5.1}%  widths {:?} taus {:?}  T^h={:.1}s W^h={:.1}s",
                acc * 100.0,
                r.widths,
                r.taus,
                r.round_time,
                r.avg_wait
            );
        }
    }

    // 5. Final metrics: simulated time + transferred bytes.
    println!(
        "done: simulated {:.1}s, traffic {:.4} GB, block balance range {:?}",
        env.clock.now(),
        env.traffic.total_gb(),
        server.ledger.count_range(),
    );
    Ok(())
}
