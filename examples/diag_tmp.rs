use heroes::config::{ExperimentConfig, Scale};
use heroes::coordinator::env::FlEnv;
use heroes::coordinator::server::HeroesServer;
use heroes::runtime::{Engine, Manifest};
use heroes::util::rng::Rng;
use heroes::baselines::Strategy;

fn main() {
    let engine = Engine::new(Manifest::load(&Manifest::default_dir()).unwrap()).unwrap();
    let mut cfg = ExperimentConfig::preset("cnn", Scale::Smoke);
    cfg.n_clients = 8; cfg.k_per_round = 4; cfg.samples_per_client = 32;
    cfg.test_samples = 128; cfg.tau_default = 4; cfg.tau_max = 12; cfg.mu_max = 1.1; 
    let mut env = FlEnv::build(&engine, cfg.clone()).unwrap();
    let mut rng = Rng::new(cfg.seed);
    let mut server = HeroesServer::new(&env.info, &cfg, &mut rng).unwrap();
    let norm = |s: &HeroesServer| -> (f64, f64) {
        (s.global.bases.iter().map(|t| t.sq_norm()).sum::<f64>(),
         s.global.coeffs.iter().map(|t| t.sq_norm()).sum::<f64>())
    };
    let (b0, c0) = norm(&server);
    println!("init basis²={b0:.4} coeff²={c0:.4}");
    for i in 0..50 {
        let prev = server.global.clone();
        let r = server.run_round(&mut env).unwrap();
        let db: f64 = server.global.bases.iter().zip(&prev.bases).map(|(a,b)| a.sq_dist(b)).sum();
        let dc: f64 = server.global.coeffs.iter().zip(&prev.coeffs).map(|(a,b)| a.sq_dist(b)).sum();
        if i % 10 == 9 { let (l,a)=server.evaluate(&env).unwrap(); println!("round {i}: train={:.3} eval={l:.4} acc={a:.4} (db={db:.4} dc={dc:.4})", r.mean_loss); }
    }
    let (l, a) = server.evaluate(&env).unwrap();
    println!("eval {l:.4} acc {a:.4}");
}
