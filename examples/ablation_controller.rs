//! Ablation: which of Heroes' two mechanisms buys what?
//!
//! Runs four variants under identical worlds (DESIGN.md ablation index):
//!   1. full Heroes            (adaptive τ + enhanced NC rotation)
//!   2. Heroes w/o adaptive τ  (fixed identical τ, rotation kept)
//!   3. Flanc                  (original NC: no rotation, fixed τ)
//!   4. FedAvg                 (no NC at all)
//!
//! ```bash
//! make artifacts && cargo run --release --example ablation_controller
//! ```

// Test/bench/example code: panicking on setup failure is idiomatic
// (CONTRIBUTING.md — the error-handling contract binds library code).
#![allow(clippy::unwrap_used, clippy::expect_used, clippy::indexing_slicing)]


use heroes::baselines::make_strategy;
use heroes::baselines::Strategy;
use heroes::config::{ExperimentConfig, Scale};
use heroes::coordinator::env::FlEnv;
use heroes::runtime::{EnginePool, Manifest};
use heroes::util::rng::Rng;

fn run_variant(
    pool: &EnginePool,
    cfg: &ExperimentConfig,
    label: &str,
    scheme: &str,
) -> anyhow::Result<()> {
    let mut env = FlEnv::build(pool, cfg.clone())?;
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut s = make_strategy(scheme, &env.info, cfg, &mut rng)?;
    let mut waits = Vec::new();
    for _ in 0..cfg.rounds {
        waits.push(s.run_round(&mut env)?.avg_wait);
    }
    let (_, acc) = s.evaluate(&env)?;
    println!(
        "{label:<24} acc {:>5.1}%  sim {:>7.1}s  wait {:>5.2}s  traffic {:.4} GB",
        acc * 100.0,
        env.clock.now(),
        heroes::util::stats::mean(&waits),
        env.traffic.total_gb()
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    heroes::util::logging::init_from_env();
    let pool = EnginePool::single(Manifest::load(&Manifest::default_dir())?)?;
    let mut cfg = ExperimentConfig::preset("cnn", Scale::Smoke);
    cfg.rounds = 25;

    run_variant(&pool, &cfg, "heroes (full)", "heroes")?;

    // no adaptive τ: collapse the controller's freedom to a single value
    let mut fixed = cfg.clone();
    fixed.tau_min = fixed.tau_default;
    fixed.tau_max = fixed.tau_default;
    run_variant(&pool, &fixed, "heroes w/o adaptive τ", "heroes")?;

    run_variant(&pool, &cfg, "flanc (original NC)", "flanc")?;
    run_variant(&pool, &cfg, "fedavg", "fedavg")?;
    Ok(())
}
