//! Scenario: federated next-character prediction over naturally Non-IID
//! text shards (the paper's Shakespeare workload, §VI-D5).
//!
//! Each client's shard comes from its own style-perturbed Markov chain
//! (like per-role dialogue styles); the composed RNN shares a neural
//! basis across widths while Heroes rotates coefficient groups.
//!
//! ```bash
//! make artifacts && cargo run --release --example text_federated
//! ```

// Test/bench/example code: panicking on setup failure is idiomatic
// (CONTRIBUTING.md — the error-handling contract binds library code).
#![allow(clippy::unwrap_used, clippy::expect_used, clippy::indexing_slicing)]


use heroes::baselines::make_strategy;
use heroes::baselines::Strategy;
use heroes::config::{ExperimentConfig, Scale};
use heroes::coordinator::env::FlEnv;
use heroes::runtime::{EnginePool, Manifest};
use heroes::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    heroes::util::logging::init_from_env();
    let pool = EnginePool::single(Manifest::load(&Manifest::default_dir())?)?;

    let mut cfg = ExperimentConfig::preset("rnn", Scale::Smoke);
    cfg.n_clients = 12;
    cfg.k_per_round = 4;
    cfg.rounds = 30;

    println!(
        "federated text: {} clients (natural Non-IID shards), vocab 64, seq 20\n",
        cfg.n_clients
    );

    for scheme in ["fedavg", "flanc", "heroes"] {
        let mut env = FlEnv::build(&pool, cfg.clone())?;
        let mut rng = Rng::new(cfg.seed ^ 0x5EED);
        let mut s = make_strategy(scheme, &env.info, &cfg, &mut rng)?;
        let (_, acc0) = s.evaluate(&env)?;
        for _ in 0..cfg.rounds {
            s.run_round(&mut env)?;
        }
        let (loss, acc) = s.evaluate(&env)?;
        println!(
            "{scheme:<8} next-char acc {:.1}% -> {:.1}%  (sim {:.0}s, {:.4} GB, loss {loss:.3})",
            acc0 * 100.0,
            acc * 100.0,
            env.clock.now(),
            env.traffic.total_gb()
        );
    }
    println!("\nchance level is 1/64 ≈ 1.6%; the chain's bigram ceiling is ~35-45%.");
    Ok(())
}
