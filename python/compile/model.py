"""L2: Heroes model forward/train/eval graphs in JAX (build-time only).

Every exported function takes a *flat* argument list (the manifest records
the exact ordering) so the rust L3 coordinator can feed PJRT literals
positionally:

  composed params : [v_0, u_0, v_1, u_1, ..., bias]       (layer order)
  dense params    : [w_0, w_1, ..., bias]
  train   : (*params, x, y, lr)  -> (*params', loss[1], grad_sq_norm[1])
  eval    : (*params, x, y)      -> (loss_sum[1], correct[1])
  probe   : (*params, x, y)      -> (grad_flat[D],)        (Alg. 2 l.7-9)

The composed path calls the L1 Pallas kernels (compose / sgd / xent) so
they lower into the same HLO module; the dense path (baselines: FedAvg,
ADP, HeteroFL) shares xent/sgd. Width-p geometry follows paper Fig. 1:
û_p is the concatenation of b(p) blocks chosen by the rust block ledger —
the HLO is width-specific but block-choice agnostic.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import compose, sgd_update, xent
from .specs import LayerSpec, ModelSpec

# ---------------------------------------------------------------------------
# parameter bookkeeping


def composed_param_specs(spec: ModelSpec, p: int) -> List[Tuple[str, Tuple[int, ...], float]]:
    """(name, shape, init_std) for every composed-model input tensor."""
    out = []
    for l in spec.layers:
        k2, i, r = l.basis_shape()
        # Composed weight w = v·u has var(w) = R·var(v)·var(u). Target
        # He-init variance 2/fan_in at the FULL width P (the global
        # coefficient is initialized once, width-independently); narrower
        # compositions are then mildly conservative, never explosive.
        fan_in_full = k2 * l.p_in(spec.cap_p) * i
        out.append((f"v_{l.name}", (k2, i, r), (1.0 / (k2 * i)) ** 0.5))
        out.append((f"u_{l.name}", l.coeff_shape(p),
                    (2.0 * k2 * i / (r * fan_in_full)) ** 0.5))
    out.append(("bias", (spec.classes,), 0.0))
    return out


def dense_param_specs(spec: ModelSpec, p: int) -> List[Tuple[str, Tuple[int, ...], float]]:
    """(name, shape, init_std) for every dense-model input tensor."""
    out = []
    for l in spec.layers:
        shape = l.weight_shape(p)
        # He at FULL width, like the composed path: HeteroFL slices the
        # width-P global model, so sub-width weights inherit the full-width
        # variance and the forward pass applies the static scaler.
        fan_in_full = l.k * l.k * l.p_in(spec.cap_p) * l.i
        out.append((f"w_{l.name}", shape, (2.0 / fan_in_full) ** 0.5))
    out.append(("bias", (spec.classes,), 0.0))
    return out


def data_specs(spec: ModelSpec, batch: int):
    """(name, shape, dtype) of the (x, y) batch inputs."""
    if spec.family == "rnn":
        return [("x", (batch, spec.seq_len), "i32"), ("y", (batch, spec.seq_len), "i32")]
    hw = spec.input_hw
    return [("x", (batch, hw, hw, spec.in_channels), "f32"), ("y", (batch,), "i32")]


# ---------------------------------------------------------------------------
# weight materialization


def _weight(l: LayerSpec, p: int, v: jnp.ndarray, u: jnp.ndarray, cap_p: int) -> jnp.ndarray:
    """Compose + arrange one width-p weight (paper Fig. 1, via L1 kernel).

    Block slot `s = a·p_out + g` must cover the *contiguous* input-channel
    group `a` and output-channel group `g`, so that (i) consecutive
    composed layers agree on channel grouping and (ii) a width-p model is
    a channel-aligned sub-network of the width-P model. A plain row-major
    reshape of (k², I, b·O) would interleave the basis rows across groups
    (stride-P channels), destroying both properties — hence the explicit
    (k², a, i, g, o) transpose before flattening.
    """
    inter = compose(v, u)                     # (k², I, b·O)
    k2, i, _ = inter.shape
    p_in, p_out = l.p_in(p), l.p_out(p)
    inter = inter.reshape(k2, i, p_in, p_out, l.o)   # slots -> (a, g)
    inter = inter.transpose(0, 2, 1, 3, 4)           # (k², a, i, g, o)
    w = inter.reshape(l.weight_shape(p))
    # Static width scaler (HeteroFL-style): factors are initialized for
    # He variance at the FULL width P, so a width-p weight has fan-in
    # p_in·I but variance 2/(k²·P·I) — sqrt(P/p_in) restores unit-scale
    # activations at every width. Deterministic per width, identity at P.
    if l.s_in and p < cap_p:
        w = w * float((cap_p / p) ** 0.5)
    return w


def _conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int) -> jnp.ndarray:
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _weights_from_args(spec: ModelSpec, p: int, params: Sequence[jnp.ndarray], composed: bool):
    """Materialize {layer name: weight} plus the head bias from flat params."""
    ws = {}
    if composed:
        for idx, l in enumerate(spec.layers):
            v, u = params[2 * idx], params[2 * idx + 1]
            ws[l.name] = _weight(l, p, v, u, spec.cap_p)
        bias = params[2 * len(spec.layers)]
    else:
        for idx, l in enumerate(spec.layers):
            w = params[idx]
            if l.s_in and p < spec.cap_p:
                w = w * float((spec.cap_p / p) ** 0.5)  # static scaler
            ws[l.name] = w
        bias = params[len(spec.layers)]
    return ws, bias


# ---------------------------------------------------------------------------
# family forwards


def _cnn_forward(spec: ModelSpec, ws, bias, x):
    h = jax.nn.relu(_conv2d(x, ws["conv1"], 1))
    h = jax.nn.relu(_conv2d(h, ws["conv2"], 2))
    h = jax.nn.relu(_conv2d(h, ws["conv3"], 2))
    pooled = jnp.mean(h, axis=(1, 2))
    return pooled @ ws["head"] + bias[None, :]


def _resnet_forward(spec: ModelSpec, ws, bias, x):
    # residual sums are normalized by 1/sqrt(2) to keep activation
    # variance flat through the network (no BatchNorm in the composed
    # setting — width-dependent statistics would break block sharing)
    inv_sqrt2 = 0.7071067811865476
    h1 = jax.nn.relu(_conv2d(x, ws["conv1"], 1))
    b1 = _conv2d(jax.nn.relu(_conv2d(h1, ws["b1c1"], 1)), ws["b1c2"], 1)
    h2 = jax.nn.relu((h1 + b1) * inv_sqrt2)
    h3 = jax.nn.relu(
        (_conv2d(h2, ws["down"], 2) + _conv2d(h2, ws["skip"], 2)) * inv_sqrt2
    )
    b2 = _conv2d(jax.nn.relu(_conv2d(h3, ws["b2c1"], 1)), ws["b2c2"], 1)
    h4 = jax.nn.relu((h3 + b2) * inv_sqrt2)
    pooled = jnp.mean(h4, axis=(1, 2))
    return pooled @ ws["head"] + bias[None, :]


def _rnn_forward(spec: ModelSpec, ws, bias, x):
    """x: (B, T) int32 -> logits (B, T, vocab) via scan over time."""
    emb = jnp.take(ws["embed"], x, axis=0)            # (B, T, E)
    b, t, e = emb.shape
    hidden = ws["wh"].shape[0]

    def step(h, xt):
        h = jnp.tanh(xt @ ws["wx"] + h @ ws["wh"])
        return h, h

    h0 = jnp.zeros((b, hidden), dtype=jnp.float32)
    _, hs = lax.scan(step, h0, jnp.swapaxes(emb, 0, 1))  # (T, B, H)
    logits = jnp.einsum("tbh,hc->tbc", hs, ws["head"]) + bias[None, None, :]
    return jnp.swapaxes(logits, 0, 1)                    # (B, T, C)


_FORWARDS = {"cnn": _cnn_forward, "resnet": _resnet_forward, "rnn": _rnn_forward}


def forward(spec: ModelSpec, p: int, params: Sequence[jnp.ndarray], x: jnp.ndarray,
            composed: bool) -> jnp.ndarray:
    ws, bias = _weights_from_args(spec, p, params, composed)
    return _FORWARDS[spec.family](spec, ws, bias, x)


# ---------------------------------------------------------------------------
# loss / metrics


def _per_sample_loss(spec: ModelSpec, logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    if spec.family == "rnn":
        b, t, c = logits.shape
        return xent(logits.reshape(b * t, c), y.reshape(b * t))
    return xent(logits, y)


def _correct_count(spec: ModelSpec, logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    if spec.family == "rnn":
        pred = jnp.argmax(logits, axis=-1)
        return jnp.sum((pred == y).astype(jnp.float32))
    pred = jnp.argmax(logits, axis=-1)
    return jnp.sum((pred == y).astype(jnp.float32))


# ---------------------------------------------------------------------------
# exported graph builders (consumed by aot.py)


def make_train(spec: ModelSpec, p: int, composed: bool):
    """One local SGD iteration (paper Alg. 2 line 5) as a pure function."""
    n_params = 2 * len(spec.layers) + 1 if composed else len(spec.layers) + 1

    def train(*args):
        params, (x, y, lr) = list(args[:n_params]), args[n_params:]

        def loss_fn(ps):
            logits = forward(spec, p, ps, x, composed)
            return jnp.mean(_per_sample_loss(spec, logits, y))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new = [sgd_update(pa, g, lr) for pa, g in zip(params, grads)]
        gsq = sum(jnp.sum(g * g) for g in grads)
        return (*new, loss[None], gsq[None])

    return train


def make_eval(spec: ModelSpec, p: int, composed: bool):
    """Batch evaluation: (loss_sum, correct_count) over eval_batch samples."""
    n_params = 2 * len(spec.layers) + 1 if composed else len(spec.layers) + 1

    def evaluate(*args):
        params, (x, y) = list(args[:n_params]), args[n_params:]
        logits = forward(spec, p, params, x, composed)
        losses = _per_sample_loss(spec, logits, y)
        return (jnp.sum(losses)[None], _correct_count(spec, logits, y)[None])

    return evaluate


def make_probe(spec: ModelSpec, p: int, composed: bool = True):
    """Flat gradient probe: the PS estimates L, σ², G² (Alg. 2 lines 7-9)
    from probe outputs at two parameter points / two batches."""
    n_params = 2 * len(spec.layers) + 1 if composed else len(spec.layers) + 1

    def probe(*args):
        params, (x, y) = list(args[:n_params]), args[n_params:]

        def loss_fn(ps):
            logits = forward(spec, p, ps, x, composed)
            return jnp.mean(_per_sample_loss(spec, logits, y))

        grads = jax.grad(loss_fn)(params)
        return (jnp.concatenate([g.reshape(-1) for g in grads]),)

    return probe


def probe_dim(spec: ModelSpec, p: int, composed: bool = True) -> int:
    specs = composed_param_specs(spec, p) if composed else dense_param_specs(spec, p)
    return sum(int(jnp.prod(jnp.asarray(s))) for _, s, _ in specs)
