"""L1 performance model: VMEM footprint + MXU utilization estimates for
the Pallas composition kernels (EXPERIMENTS.md §Perf, DESIGN.md
§Hardware-Adaptation).

`interpret=True` gives CPU-numpy timings only — not a TPU proxy — so the
L1 target is *structural*: keep every grid step's working set far inside
VMEM (≈16 MiB/core) and report how much of the 128×128 MXU each
contraction shape can use. Run:

    cd python && python -m compile.perf
"""
from __future__ import annotations

from . import specs as S
from .kernels.compose import _tile

VMEM_BYTES = 16 * 1024 * 1024
MXU_EDGE = 128


def matmul_report(m: int, k: int, n: int) -> dict:
    """One tiled matmul's per-grid-step footprint and MXU geometry."""
    tm, tn = _tile(m), _tile(n)
    # A-tile + B-tile + O-tile resident per step, f32
    vmem = 4 * (tm * k + k * tn + tm * tn)
    # double-buffered streams (the implicit pallas pipeline)
    vmem_db = 2 * 4 * (tm * k + k * tn) + 4 * tm * tn
    # fraction of the systolic array covered by one (tm x k)·(k x tn) pass
    mxu_rows = min(tm, MXU_EDGE) / MXU_EDGE
    mxu_cols = min(tn, MXU_EDGE) / MXU_EDGE
    mxu_depth = min(k, MXU_EDGE) / MXU_EDGE
    return {
        "shape": f"({m}x{k})x({k}x{n})",
        "tile": f"{tm}x{k}x{tn}",
        "grid": (m // tm) * (n // tn),
        "vmem_bytes": vmem,
        "vmem_db_bytes": vmem_db,
        "vmem_frac": vmem_db / VMEM_BYTES,
        "mxu_util": mxu_rows * mxu_cols * mxu_depth,
        "flops": 2 * m * k * n,
    }


def compose_reports(spec: S.ModelSpec, p: int):
    """Forward + VJP matmuls of every layer's composition at width p."""
    out = []
    for l in spec.layers:
        k2, i, r = l.basis_shape()
        m = k2 * i
        n = l.blocks_at(p) * l.o
        out.append((f"{l.name}/fwd", matmul_report(m, r, n)))
        out.append((f"{l.name}/dv", matmul_report(m, n, r)))
        out.append((f"{l.name}/du", matmul_report(r, m, n)))
    return out


def main():
    print(f"VMEM budget/core: {VMEM_BYTES // (1024*1024)} MiB; MXU {MXU_EDGE}x{MXU_EDGE}")
    for fam, mk in S.FAMILIES.items():
        spec = mk()
        p = spec.cap_p
        print(f"\n[{fam}] composition kernels at full width P={p}")
        print(f"{'kernel':<14} {'shape':<18} {'tile':<12} {'grid':>4} "
              f"{'VMEM(dbuf)':>10} {'%VMEM':>7} {'MXU util':>9}")
        worst_vmem = 0.0
        vol_weighted_util = 0.0
        total_flops = 0
        for name, r in compose_reports(spec, p):
            print(f"{name:<14} {r['shape']:<18} {r['tile']:<12} {r['grid']:>4} "
                  f"{r['vmem_db_bytes']:>9}B {100*r['vmem_frac']:>6.3f}% {100*r['mxu_util']:>8.2f}%")
            worst_vmem = max(worst_vmem, r["vmem_frac"])
            vol_weighted_util += r["mxu_util"] * r["flops"]
            total_flops += r["flops"]
        print(f"  worst-case VMEM use {100*worst_vmem:.3f}%  |  "
              f"FLOP-weighted MXU coverage {100*vol_weighted_util/total_flops:.2f}%")
        print("  note: shapes are rank-bounded (K = R); on real TPU these small"
              " contractions would be fused into the conv epilogue or batched"
              " across layers — the schedule keeps them bandwidth-bound, not"
              " MXU-bound, which is the right roofline corner for factors this"
              " small (see EXPERIMENTS.md §Perf).")


if __name__ == "__main__":
    main()
