"""Model-family specifications shared by L2 (jax), aot.py and (via
manifest.json) the rust L3 coordinator.

A composed layer follows paper §II-B: basis v ∈ (k², I, R), complete
coefficient u ∈ (R, B·O) with B = P^(s_in + s_out) blocks of shape (R, O).
A width-p reduction uses b(p) = p^(s_in + s_out) blocks; composing and
reshaping yields the (k, k, p_in·I, p_out·O) weight, p_in = p if s_in else
1, p_out = p if s_out else 1 (paper Fig. 1).

Three families mirror the paper's evaluation (§VI-A):
  cnn    — 4-layer CNN            (CIFAR-10 twin;      synthetic 16×16×3, 10 classes)
  resnet — composed ResNet-8      (ImageNet-100 twin;  synthetic 16×16×3, 20 classes)
  rnn    — next-char vanilla RNN  (Shakespeare twin;   64-symbol alphabet)

The real datasets are not available offline; DESIGN.md §Substitutions
documents the synthetic twins. All geometry below is exercised at the
paper's P = 4 widths.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

BYTES_F32 = 4


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One composed layer (conv / dense / embedding lookup)."""

    name: str
    kind: str                 # 'conv' | 'dense' | 'embed'
    k: int                    # kernel edge (1 for dense/embed)
    stride: int               # conv stride (1 otherwise)
    i: int                    # base input channels (per width unit)
    o: int                    # base output channels (per width unit)
    r: int                    # rank R of the factorization
    s_in: bool                # input channel count scales with p
    s_out: bool               # output channel count scales with p
    # Channel-group classes: layers whose activations meet (sequentially
    # or through residual adds) must select the SAME channel groups, so
    # width-p sub-models stay channel-aligned sub-networks of the full
    # model. `in_class` names the group class feeding this layer;
    # `out_class` the class of its output channels. None on a fixed
    # (non-scaling) side. The rust block ledger rotates *groups* per
    # class (enhanced NC at group granularity — DESIGN.md §Deviations).
    in_class: Optional[str] = None
    out_class: Optional[str] = None

    def blocks_total(self, cap_p: int) -> int:
        """B = P^(s_in+s_out): number of blocks in the complete coefficient."""
        return cap_p ** (int(self.s_in) + int(self.s_out))

    def blocks_at(self, p: int) -> int:
        """b(p) = p^(s_in+s_out): blocks composing a width-p weight."""
        return p ** (int(self.s_in) + int(self.s_out))

    def p_in(self, p: int) -> int:
        return p if self.s_in else 1

    def p_out(self, p: int) -> int:
        return p if self.s_out else 1

    def basis_shape(self) -> Tuple[int, int, int]:
        return (self.k * self.k, self.i, self.r)

    def block_shape(self) -> Tuple[int, int]:
        return (self.r, self.o)

    def coeff_shape(self, p: int) -> Tuple[int, int]:
        """Reduced coefficient (R, b(p)·O)."""
        return (self.r, self.blocks_at(p) * self.o)

    def weight_shape(self, p: int):
        """Composed / dense weight at width p."""
        ci, co = self.p_in(p) * self.i, self.p_out(p) * self.o
        if self.kind == "conv":
            return (self.k, self.k, ci, co)
        return (ci, co)

    # --- cost model (used by aot.py to fill manifest; L3 simulator reads it) ---

    def fwd_flops(self, p: int, hw: int) -> int:
        """Forward FLOPs for one sample; hw = spatial positions seen by this
        layer (1 for dense, seq_len for recurrent dense)."""
        ci, co = self.p_in(p) * self.i, self.p_out(p) * self.o
        return 2 * self.k * self.k * ci * co * hw

    def compose_flops(self, p: int) -> int:
        """Composition matmul + its two VJP matmuls (per iteration, not per
        sample): 3 matmuls of (k²I × R) x (R × b·O)."""
        m = self.k * self.k * self.i
        n = self.blocks_at(p) * self.o
        return 3 * 2 * m * self.r * n

    def factor_bytes(self, p: int) -> int:
        """Bytes of (v, û_p) — what Heroes/Flanc transmit."""
        k2, i, r = self.basis_shape()
        return BYTES_F32 * (k2 * i * r + r * self.blocks_at(p) * self.o)

    def dense_bytes(self, p: int) -> int:
        """Bytes of the dense width-p weight — what MP schemes transmit."""
        ci, co = self.p_in(p) * self.i, self.p_out(p) * self.o
        return BYTES_F32 * self.k * self.k * ci * co


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    family: str
    layers: Tuple[LayerSpec, ...]
    cap_p: int                       # P, maximum width
    classes: int
    batch: int                       # training batch size (fixed for AOT)
    eval_batch: int
    input_hw: Optional[int] = None   # image edge (CV families)
    in_channels: int = 3
    vocab: int = 0                   # NLP family
    seq_len: int = 0

    def layer(self, name: str) -> LayerSpec:
        for l in self.layers:
            if l.name == name:
                return l
        raise KeyError(name)

    # --- spatial bookkeeping for the cost model ---

    def spatial(self) -> dict:
        """Map layer name -> number of spatial positions its conv touches."""
        out = {}
        if self.family == "rnn":
            for l in self.layers:
                out[l.name] = self.seq_len if l.name != "embed" else 1
            return out
        hw = self.input_hw
        for l in self.layers:
            if l.kind == "conv":
                hw_out = (hw + l.stride - 1) // l.stride
                out[l.name] = hw_out * hw_out
                hw = hw_out
            else:
                out[l.name] = 1
        return out

    def train_flops(self, p: int, composed: bool) -> int:
        """FLOPs for one local iteration (fwd + bwd ≈ 3×fwd per batch,
        plus composition overhead when running the factorized model)."""
        sp = self.spatial()
        per_sample = sum(l.fwd_flops(p, sp[l.name]) for l in self.layers)
        total = 3 * per_sample * self.batch
        if composed:
            total += sum(l.compose_flops(p) for l in self.layers)
        return total

    def upload_bytes(self, p: int, composed: bool) -> int:
        """Bytes a client uploads after local training (paper Eq. 18);
        the head bias (classes,) always rides along."""
        if composed:
            body = sum(l.factor_bytes(p) for l in self.layers)
        else:
            body = sum(l.dense_bytes(p) for l in self.layers)
        return body + BYTES_F32 * self.classes

    def download_bytes(self, p: int, composed: bool) -> int:
        """PS -> client payload; same tensors travel down."""
        return self.upload_bytes(p, composed)


def _conv(name, i, o, r, *, k=3, stride=1, s_in=True, s_out=True, ic=None, oc=None):
    return LayerSpec(name, "conv", k, stride, i, o, r, s_in, s_out, ic, oc)


def _dense(name, i, o, r, *, s_in=True, s_out=False, ic=None, oc=None):
    return LayerSpec(name, "dense", 1, 1, i, o, r, s_in, s_out, ic, oc)


def cnn_spec() -> ModelSpec:
    """4-layer CNN, the paper's CIFAR-10 model (§VI-A3): three 3×3 convs +
    linear head. Base widths ×{1..4}."""
    return ModelSpec(
        family="cnn",
        layers=(
            _conv("conv1", 3, 4, 6, s_in=False, oc="g1"),
            _conv("conv2", 4, 8, 8, stride=2, ic="g1", oc="g2"),
            _conv("conv3", 8, 8, 8, stride=2, ic="g2", oc="g3"),
            _dense("head", 8, 10, 8, ic="g3"),
        ),
        cap_p=4, classes=10, batch=16, eval_batch=64, input_hw=16,
    )


def resnet_spec() -> ModelSpec:
    """Composed ResNet-8, the ImageNet-100 twin (paper uses ResNet-18; the
    CPU-only box gets the same residual topology at reduced depth/width).
    Residual adds tie group classes: conv1/b1c2 share s1; down/skip/b2c2
    share s2."""
    return ModelSpec(
        family="resnet",
        layers=(
            _conv("conv1", 3, 4, 6, s_in=False, oc="s1"),
            _conv("b1c1", 4, 4, 8, ic="s1", oc="m1"),
            _conv("b1c2", 4, 4, 8, ic="m1", oc="s1"),
            _conv("down", 4, 8, 8, stride=2, ic="s1", oc="s2"),
            _conv("skip", 4, 8, 4, k=1, stride=2, ic="s1", oc="s2"),
            _conv("b2c1", 8, 8, 8, ic="s2", oc="m2"),
            _conv("b2c2", 8, 8, 8, ic="m2", oc="s2"),
            _dense("head", 8, 20, 8, ic="s2"),
        ),
        cap_p=4, classes=20, batch=16, eval_batch=64, input_hw=16,
    )


def rnn_spec() -> ModelSpec:
    """Vanilla tanh RNN for next-character prediction, the Shakespeare twin
    (paper: RNN with hidden = embed = 512; ours: 8·p at P = 4). The hidden
    state ties embed/wx/wh/head to one group class."""
    return ModelSpec(
        family="rnn",
        layers=(
            LayerSpec("embed", "embed", 1, 1, 64, 8, 8, False, True, None, "h"),
            _dense("wx", 8, 8, 8, s_in=True, s_out=True, ic="h", oc="h"),
            _dense("wh", 8, 8, 8, s_in=True, s_out=True, ic="h", oc="h"),
            _dense("head", 8, 64, 8, ic="h"),
        ),
        cap_p=4, classes=64, batch=8, eval_batch=32, vocab=64, seq_len=20,
    )


FAMILIES = {"cnn": cnn_spec, "resnet": resnet_spec, "rnn": rnn_spec}


def all_specs() -> List[ModelSpec]:
    return [f() for f in FAMILIES.values()]
