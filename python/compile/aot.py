"""AOT entry point: lower every Heroes executable to HLO *text* and emit
artifacts/manifest.json for the rust runtime.

Run once at build time (`make artifacts`); python never touches the
request path afterwards. Interchange is HLO text, NOT serialized
HloModuleProto — jax >= 0.5 emits protos with 64-bit instruction ids that
the crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Per model family (cnn / resnet / rnn) we export:
  {fam}_train_p{p}   composed train step,  p = 1..P     (Heroes, Flanc)
  {fam}_dtrain_p{p}  dense train step,     p = 1..P     (FedAvg, ADP, HeteroFL)
  {fam}_eval         composed eval at full width P
  {fam}_deval        dense eval at full width P
  {fam}_probe_p{p}   composed flat-gradient probe        (Alg. 2 l.7-9)

manifest.json records, for every executable, the exact positional input /
output tensor specs, and for every family the layer geometry, per-width
FLOPs and transfer-byte cost model the L3 simulator uses (paper Eq. 17-18).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import specs as S

_DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def _sds(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(tuple(shape), _DTYPES[dtype])


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _input_specs(spec: S.ModelSpec, p: int, composed: bool, kind: str):
    """Positional input tensor specs for an executable."""
    pspecs = (M.composed_param_specs(spec, p) if composed
              else M.dense_param_specs(spec, p))
    ins = [{"name": n, "shape": list(s), "dtype": "f32"} for n, s, _ in pspecs]
    batch = spec.eval_batch if kind == "eval" else spec.batch
    for n, s, d in M.data_specs(spec, batch):
        ins.append({"name": n, "shape": list(s), "dtype": d})
    if kind == "train":
        ins.append({"name": "lr", "shape": [1], "dtype": "f32"})
    return ins


def _output_specs(spec: S.ModelSpec, p: int, composed: bool, kind: str):
    if kind == "train":
        pspecs = (M.composed_param_specs(spec, p) if composed
                  else M.dense_param_specs(spec, p))
        outs = [{"name": n, "shape": list(s), "dtype": "f32"} for n, s, _ in pspecs]
        outs.append({"name": "loss", "shape": [1], "dtype": "f32"})
        outs.append({"name": "grad_sq_norm", "shape": [1], "dtype": "f32"})
        return outs
    if kind == "eval":
        return [{"name": "loss_sum", "shape": [1], "dtype": "f32"},
                {"name": "correct", "shape": [1], "dtype": "f32"}]
    d = M.probe_dim(spec, p, composed)
    return [{"name": "grad_flat", "shape": [d], "dtype": "f32"}]


def _builder(spec: S.ModelSpec, p: int, composed: bool, kind: str):
    if kind == "train":
        return M.make_train(spec, p, composed)
    if kind == "eval":
        return M.make_eval(spec, p, composed)
    return M.make_probe(spec, p, composed)


def _lower_one(spec: S.ModelSpec, p: int, composed: bool, kind: str, out_dir: str,
               name: str) -> dict:
    ins = _input_specs(spec, p, composed, kind)
    args = [_sds(i["shape"], i["dtype"]) for i in ins]
    fn = _builder(spec, p, composed, kind)
    t0 = time.time()
    text = to_hlo_text(jax.jit(fn).lower(*args))
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    print(f"  {name:24s} {len(text):>9d} chars  {time.time()-t0:5.1f}s", flush=True)
    return {
        "file": fname, "model": spec.family, "kind": kind, "p": p,
        "composed": composed,
        "inputs": ins,
        "outputs": _output_specs(spec, p, composed, kind),
    }


def _model_manifest(spec: S.ModelSpec) -> dict:
    layers = []
    for l in spec.layers:
        layers.append({
            "name": l.name, "kind": l.kind, "k": l.k, "stride": l.stride,
            "i": l.i, "o": l.o, "r": l.r, "s_in": l.s_in, "s_out": l.s_out,
            "in_class": l.in_class, "out_class": l.out_class,
            "basis_shape": list(l.basis_shape()),
            "block_shape": list(l.block_shape()),
            "blocks_total": l.blocks_total(spec.cap_p),
        })
    widths = list(range(1, spec.cap_p + 1))
    params = {
        "composed": {str(p): [{"name": n, "shape": list(s), "init_std": std}
                              for n, s, std in M.composed_param_specs(spec, p)]
                     for p in widths},
        "dense": {str(p): [{"name": n, "shape": list(s), "init_std": std}
                           for n, s, std in M.dense_param_specs(spec, p)]
                  for p in widths},
    }
    if spec.family == "rnn":
        inp = {"kind": "text", "vocab": spec.vocab, "seq_len": spec.seq_len}
    else:
        inp = {"kind": "image", "hw": spec.input_hw, "channels": spec.in_channels}
    return {
        "cap_p": spec.cap_p, "classes": spec.classes,
        "batch": spec.batch, "eval_batch": spec.eval_batch,
        "input": inp, "layers": layers, "params": params,
        "flops": {
            "composed": {str(p): spec.train_flops(p, True) for p in widths},
            "dense": {str(p): spec.train_flops(p, False) for p in widths},
        },
        "bytes": {
            "composed": {str(p): spec.upload_bytes(p, True) for p in widths},
            "dense": {str(p): spec.upload_bytes(p, False) for p in widths},
        },
        "probe_dim": {str(p): M.probe_dim(spec, p, True) for p in widths},
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--only", default=None, help="restrict to one family")
    args = ap.parse_args()
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"version": 1, "models": {}, "executables": {}}
    t0 = time.time()
    for fam, mk in S.FAMILIES.items():
        if args.only and fam != args.only:
            continue
        spec = mk()
        print(f"[{fam}] lowering (P={spec.cap_p})", flush=True)
        manifest["models"][fam] = _model_manifest(spec)
        for p in range(1, spec.cap_p + 1):
            manifest["executables"][f"{fam}_train_p{p}"] = _lower_one(
                spec, p, True, "train", out_dir, f"{fam}_train_p{p}")
            manifest["executables"][f"{fam}_dtrain_p{p}"] = _lower_one(
                spec, p, False, "train", out_dir, f"{fam}_dtrain_p{p}")
            manifest["executables"][f"{fam}_probe_p{p}"] = _lower_one(
                spec, p, True, "probe", out_dir, f"{fam}_probe_p{p}")
        manifest["executables"][f"{fam}_eval"] = _lower_one(
            spec, spec.cap_p, True, "eval", out_dir, f"{fam}_eval")
        manifest["executables"][f"{fam}_deval"] = _lower_one(
            spec, spec.cap_p, False, "eval", out_dir, f"{fam}_deval")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    n = len(manifest["executables"])
    print(f"wrote {n} executables + manifest.json in {time.time()-t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
