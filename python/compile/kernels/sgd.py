"""Fused elementwise SGD-update Pallas kernel.

Applied to every basis / coefficient / bias tensor once per local
iteration (paper Alg. 2 line 5). The tensor is flattened, padded to a
lane-friendly multiple, and walked by a 1-D grid; the learning rate
arrives as a (1,) operand so the same AOT executable serves any lr the
rust coordinator chooses at runtime.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 8 sublanes x 128 lanes — one f32 VREG tile on TPU.
_CHUNK = 1024


def _sgd_kernel(p_ref, g_ref, lr_ref, o_ref):
    o_ref[...] = p_ref[...] - lr_ref[0] * g_ref[...]


def sgd_update(param: jnp.ndarray, grad: jnp.ndarray, lr: jnp.ndarray) -> jnp.ndarray:
    """p - lr * g, elementwise, any shape; lr is a (1,) f32 array."""
    assert param.shape == grad.shape, (param.shape, grad.shape)
    shape = param.shape
    n = param.size
    pad = (-n) % _CHUNK
    p1 = jnp.pad(param.reshape(-1), (0, pad))
    g1 = jnp.pad(grad.reshape(-1), (0, pad))
    total = n + pad
    out = pl.pallas_call(
        _sgd_kernel,
        grid=(total // _CHUNK,),
        in_specs=[
            pl.BlockSpec((_CHUNK,), lambda i: (i,)),
            pl.BlockSpec((_CHUNK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((_CHUNK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((total,), jnp.float32),
        interpret=True,
    )(p1, g1, lr)
    return out[:n].reshape(shape)
