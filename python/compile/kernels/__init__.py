"""L1: Pallas kernels for Heroes' compute hot-spots.

- compose: neural composition matmul w = v . u (fwd + VJP)  [paper Eq. 4]
- sgd:     fused elementwise SGD update                      [Alg. 2 l.5]
- xent:    fused softmax cross-entropy (fwd + VJP)
- ref:     pure-jnp oracles for all of the above
"""
from .compose import compose, matmul  # noqa: F401
from .sgd import sgd_update  # noqa: F401
from .xent import xent  # noqa: F401
