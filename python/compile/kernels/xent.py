"""Fused softmax-cross-entropy Pallas kernel (forward + VJP).

One kernel pass computes the per-sample loss ``logsumexp(z) - z[y]``
without materializing the softmax in HBM; the VJP kernel emits
``(softmax(z) - onehot(y)) * dL`` in one pass. Batch rows are tiled on the
grid; the class axis stays resident per tile (C <= 64 for every Heroes
model, far inside VMEM).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_MAX_ROWS = 128


def _row_tile(b: int) -> int:
    if b <= _MAX_ROWS:
        return max(b, 1)
    for t in range(_MAX_ROWS, 0, -1):
        if b % t == 0:
            return t
    return 1


def _xent_fwd_kernel(z_ref, y_ref, o_ref):
    z = z_ref[...]                      # (TB, C)
    y = y_ref[...]                      # (TB,)
    m = jnp.max(z, axis=1, keepdims=True)
    lse = m[:, 0] + jnp.log(jnp.sum(jnp.exp(z - m), axis=1))
    onehot = (y[:, None] == jnp.arange(z.shape[1])[None, :]).astype(z.dtype)
    picked = jnp.sum(z * onehot, axis=1)
    o_ref[...] = lse - picked


def _xent_bwd_kernel(z_ref, y_ref, d_ref, o_ref):
    z = z_ref[...]
    y = y_ref[...]
    d = d_ref[...]
    m = jnp.max(z, axis=1, keepdims=True)
    e = jnp.exp(z - m)
    sm = e / jnp.sum(e, axis=1, keepdims=True)
    onehot = (y[:, None] == jnp.arange(z.shape[1])[None, :]).astype(z.dtype)
    o_ref[...] = (sm - onehot) * d[:, None]


@jax.custom_vjp
def xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Per-sample cross-entropy. logits (B, C) f32, labels (B,) int32 -> (B,)."""
    b, c = logits.shape
    tb = _row_tile(b)
    return pl.pallas_call(
        _xent_fwd_kernel,
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, c), lambda i: (i, 0)),
            pl.BlockSpec((tb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(logits, labels)


def _xent_fwd(logits, labels):
    return xent(logits, labels), (logits, labels)


def _xent_bwd(res, dloss):
    logits, labels = res
    b, c = logits.shape
    tb = _row_tile(b)
    dz = pl.pallas_call(
        _xent_bwd_kernel,
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, c), lambda i: (i, 0)),
            pl.BlockSpec((tb,), lambda i: (i,)),
            pl.BlockSpec((tb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tb, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=True,
    )(logits, labels, dloss)
    return dz, None


xent.defvjp(_xent_fwd, _xent_bwd)
