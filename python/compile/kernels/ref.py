"""Pure-jnp oracles for the Pallas kernels (L1 correctness reference).

Every Pallas kernel in this package has an exact jnp twin here; pytest
asserts allclose between the two across a hypothesis-driven sweep of
shapes/dtypes (python/tests/test_kernels.py). The oracles are also the
semantic definition used by the convergence-sensitive code paths: if a
kernel and its oracle disagree, the kernel is wrong.
"""
from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain 2-D matmul in f32 accumulation."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def compose_ref(v: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Neural composition (paper Eq. 4): w = v · u.

    v: (K2, I, R) neural basis, u: (R, BO) reduced coefficient.
    Returns the intermediate tensor (K2, I, BO); the caller reshapes to
    the (k, k, p_in*I, p_out*O) weight (paper Fig. 1).
    """
    k2, i, r = v.shape
    return matmul_ref(v.reshape(k2 * i, r), u).reshape(k2, i, u.shape[1])


def sgd_ref(param: jnp.ndarray, grad: jnp.ndarray, lr: jnp.ndarray) -> jnp.ndarray:
    """Elementwise SGD: p - lr * g, lr a (1,) array."""
    return param - lr[0] * grad


def xent_ref(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Per-sample softmax cross-entropy.

    logits: (B, C) f32, labels: (B,) int32. Returns (B,) f32 losses.
    """
    m = jnp.max(logits, axis=1, keepdims=True)
    lse = m[:, 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=1))
    picked = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=1)[:, 0]
    return lse - picked


def xent_grad_ref(logits: jnp.ndarray, labels: jnp.ndarray, dloss: jnp.ndarray) -> jnp.ndarray:
    """VJP of xent_ref w.r.t. logits: (softmax - onehot) * dloss."""
    m = jnp.max(logits, axis=1, keepdims=True)
    e = jnp.exp(logits - m)
    sm = e / jnp.sum(e, axis=1, keepdims=True)
    onehot = (labels[:, None] == jnp.arange(logits.shape[1])[None, :]).astype(logits.dtype)
    return (sm - onehot) * dloss[:, None]
