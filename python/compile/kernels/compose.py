"""Pallas kernels for neural composition (paper §II-B, Eq. 4 / Fig. 1).

The compute hot-spot of Heroes is the composition matmul
``w = reshape(v · û)`` plus its two VJP matmuls (``dv = dw · ûᵀ``,
``dû = vᵀ · dw``). All three run through one tiled Pallas matmul kernel.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid walks (M/TM, N/TN)
output tiles; each step keeps an (TM, K) A-tile and a (K, TN) B-tile
resident in VMEM and contracts them on the MXU with f32 accumulation
(``preferred_element_type``). K is the rank R (small), so a single K pass
per tile suffices — no K-loop accumulator is needed, which keeps the VMEM
footprint at ``TM*K + K*TN + TM*TN`` floats per step and lets the implicit
Pallas pipeline double-buffer the HBM→VMEM streams.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so interpret mode is the correctness path (validated against
kernels.ref by pytest); real-TPU performance is estimated analytically in
EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Largest tile edge we allow. 128 matches the MXU systolic-array edge;
# tiles are chosen as the largest divisor of the dim that is <= this.
_MAX_TILE = 128
# Single-pass contraction bound: all Heroes shapes have K = R (<= 32) in
# the forward pass and K = k^2*I (<= 576) in the VJPs.
_MAX_K = 4096


def _tile(dim: int, cap: int = _MAX_TILE) -> int:
    """Largest divisor of `dim` that is <= cap (>= 1)."""
    if dim <= cap:
        return max(dim, 1)
    for t in range(cap, 0, -1):
        if dim % t == 0:
            return t
    return 1


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Tiled Pallas matmul: (M, K) x (K, N) -> (M, N), f32 accumulate.

    Grid is (M/TM, N/TN); K is contracted in a single pass (see module
    docstring for why that is the right TPU schedule at Heroes' ranks).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch: {a.shape} x {b.shape}"
    assert k <= _MAX_K, f"K={k} exceeds single-pass bound {_MAX_K}"
    tm, tn = _tile(m), _tile(n)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // tm, n // tn),
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


@jax.custom_vjp
def compose(v: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Neural composition w = v · u (paper Eq. 4).

    v: (K2, I, R) neural basis; u: (R, BO) reduced coefficient built from
    b(p) least-trained blocks. Returns (K2, I, BO); the model layer
    reshapes this to the (k, k, p_in*I, p_out*O) weight (paper Fig. 1).

    Differentiable via custom VJP so gradients flow into both factors —
    this is the Flanc-style all-in-one training that replaces the lossy
    decompose step of Alg. 2 line 10 (see DESIGN.md "Decomposition note").
    """
    k2, i, r = v.shape
    return matmul(v.reshape(k2 * i, r), u).reshape(k2, i, u.shape[1])


def _compose_fwd(v, u):
    return compose(v, u), (v, u)


def _compose_bwd(res, dw):
    v, u = res
    k2, i, r = v.shape
    bo = u.shape[1]
    dw2 = dw.reshape(k2 * i, bo)
    dv = matmul(dw2, u.T).reshape(k2, i, r)
    du = matmul(v.reshape(k2 * i, r).T, dw2)
    return dv, du


compose.defvjp(_compose_fwd, _compose_bwd)
