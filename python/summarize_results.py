"""Render results/*_summary.json into the EXPERIMENTS.md tables.

Usage: python python/summarize_results.py [results_dir]
"""
import json
import os
import sys


def load(d, name):
    p = os.path.join(d, f"{name}_summary.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def pct(x):
    return "n/r" if x is None else f"{100*x:.1f}%"


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "results"

    t1 = load(d, "table1")
    if t1:
        print("## Table I (measured)")
        bg, bt = t1["budgets_gb"], t1["budgets_s"]
        print(f"| scheme | acc@{bg[0]:.3f}GB | acc@{bg[1]:.3f}GB | acc@{bt[0]:.0f}s | acc@{bt[1]:.0f}s |")
        print("|---|---|---|---|---|")
        label = {"heterofl": "MP", "flanc": "Original NC", "heroes": "Enhanced NC"}
        for s in ["heterofl", "flanc", "heroes"]:
            row = t1["accuracy"].get(s)
            if row:
                print(f"| {label[s]} | " + " | ".join(pct(x) for x in row) + " |")
        print()

    f2 = load(d, "fig2")
    if f2:
        print("## Fig 2 (measured)")
        fx, ad = f2["fixed_sorted_s"], f2["adaptive_sorted_s"]
        print(f"- fixed τ: max {fx[0]:.1f}s / min {fx[-1]:.1f}s, mean idle {100*f2['fixed_idle_frac']:.1f}%")
        print(f"- adaptive τ: max {ad[0]:.1f}s / min {ad[-1]:.1f}s, mean idle {100*f2['adaptive_idle_frac']:.1f}%")
        print()

    for name, title in [("fig4a", "Fig 4a (CNN)"), ("fig4b", "Fig 4b (ResNet)")]:
        f4 = load(d, name)
        if f4:
            print(f"## {title} — accuracy at the common time budget ({f4['time_budget_s']:.0f}s)")
            print("| scheme | final acc |")
            print("|---|---|")
            for s, acc in sorted(f4["final_accuracy"].items(), key=lambda kv: -kv[1]):
                print(f"| {s} | {pct(acc)} |")
            print()

    for name, title in [("fig5a", "Fig 5a (CNN)"), ("fig5b", "Fig 5b (ResNet)")]:
        f5 = load(d, name)
        if f5:
            print(f"## {title} — mean waiting time")
            print("| scheme | wait (s) |")
            print("|---|---|")
            for s, w in sorted(f5["mean_wait_s"].items(), key=lambda kv: kv[1]):
                print(f"| {s} | {w:.2f} |")
            print()

    for name, title in [("fig6", "Fig 6 (CNN)"), ("fig8", "Fig 8 (ResNet)")]:
        f = load(d, name)
        if f:
            print(f"## {title} — to {100*f['target_accuracy']:.0f}% accuracy")
            print("| scheme | traffic (GB) | time (s) | final acc |")
            print("|---|---|---|---|")
            for s, row in f["consumption"].items():
                gb = row["traffic_gb"]
                t = row["time_s"]
                print(f"| {s} | {gb if gb is None else f'{gb:.4f}'} | "
                      f"{t if t is None else f'{t:.0f}'} | {pct(row['final_acc'])} |")
            print()

    for name, title in [("fig7a", "Fig 7a (Γ sweep, CNN)"), ("fig7b", "Fig 7b (φ sweep, ResNet)")]:
        f = load(d, name)
        if f:
            print(f"## {title} — accuracy at common budget per level")
            print("| scheme | " + " | ".join(str(int(l)) for l in f["levels"]) + " |")
            print("|---|" + "---|" * len(f["levels"]))
            for s, accs in f["accuracy"].items():
                print(f"| {s} | " + " | ".join(pct(a) for a in accs) + " |")
            print()

    f9 = load(d, "fig9")
    if f9:
        print(f"## Fig 9 (RNN) — to {100*f9['target_accuracy']:.0f}% next-char accuracy")
        print("| scheme | time (s) | traffic (GB) | final acc |")
        print("|---|---|---|---|")
        for s, row in f9["results"].items():
            t, gb = row["time_s"], row["traffic_gb"]
            print(f"| {s} | {t if t is None else f'{t:.0f}'} | "
                  f"{gb if gb is None else f'{gb:.4f}'} | {pct(row['final_acc'])} |")
        print()

    e2 = load(d, "e2e")
    if e2:
        print(f"## e2e — Heroes final accuracy {pct(e2['final_accuracy'])} after {e2['rounds']} rounds")


if __name__ == "__main__":
    main()
