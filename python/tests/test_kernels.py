"""L1 correctness: every Pallas kernel against its pure-jnp oracle,
including the custom VJPs, with hypothesis sweeping shapes/dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import compose, matmul, sgd_update, xent
from compile.kernels import ref

RNG = np.random.default_rng(0)


def arr(*shape, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32) * scale)


# ----------------------------------------------------------------------
# matmul / compose


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 160),
    k=st.integers(1, 48),
    n=st.integers(1, 200),
)
def test_matmul_matches_ref_over_shapes(m, k, n):
    a = arr(m, k)
    b = arr(k, n)
    np.testing.assert_allclose(matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    k=st.sampled_from([1, 3]),
    i=st.integers(1, 12),
    r=st.integers(1, 16),
    blocks=st.integers(1, 16),
    o=st.integers(1, 12),
)
def test_compose_matches_ref_over_geometry(k, i, r, blocks, o):
    v = arr(k * k, i, r)
    u = arr(r, blocks * o)
    np.testing.assert_allclose(compose(v, u), ref.compose_ref(v, u), rtol=1e-4, atol=1e-5)


def test_compose_vjp_matches_autodiff_of_ref():
    v = arr(9, 4, 8)
    u = arr(8, 128)

    def f(v, u):
        return jnp.sum(jnp.tanh(compose(v, u)))

    def g(v, u):
        return jnp.sum(jnp.tanh(ref.compose_ref(v, u)))

    gv1, gu1 = jax.grad(f, argnums=(0, 1))(v, u)
    gv2, gu2 = jax.grad(g, argnums=(0, 1))(v, u)
    np.testing.assert_allclose(gv1, gv2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gu1, gu2, rtol=1e-4, atol=1e-5)


def test_compose_under_jit():
    v, u = arr(9, 3, 6), arr(6, 16)
    out = jax.jit(compose)(v, u)
    np.testing.assert_allclose(out, ref.compose_ref(v, u), rtol=1e-4, atol=1e-5)


def test_matmul_rejects_bad_contraction():
    with pytest.raises(AssertionError):
        matmul(arr(4, 5), arr(6, 7))


# ----------------------------------------------------------------------
# sgd


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 5000),
    lr=st.floats(1e-4, 1.0),
)
def test_sgd_matches_ref_any_length(n, lr):
    p = arr(n)
    g = arr(n)
    lr_a = jnp.asarray([lr], dtype=jnp.float32)
    np.testing.assert_allclose(
        sgd_update(p, g, lr_a), ref.sgd_ref(p, g, lr_a), rtol=1e-5, atol=1e-6
    )


def test_sgd_nd_shapes():
    for shape in [(3, 5, 7), (1,), (2, 2, 2, 2), (1024,), (1025,)]:
        p, g = arr(*shape), arr(*shape)
        lr = jnp.asarray([0.1], dtype=jnp.float32)
        out = sgd_update(p, g, lr)
        assert out.shape == p.shape
        np.testing.assert_allclose(out, p - 0.1 * g, rtol=1e-5, atol=1e-6)


def test_sgd_zero_lr_is_identity():
    p, g = arr(33), arr(33)
    out = sgd_update(p, g, jnp.asarray([0.0], dtype=jnp.float32))
    np.testing.assert_array_equal(out, p)


# ----------------------------------------------------------------------
# xent


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 130), c=st.integers(2, 64))
def test_xent_matches_ref_over_shapes(b, c):
    z = arr(b, c, scale=3.0)
    y = jnp.asarray(RNG.integers(0, c, size=(b,)).astype(np.int32))
    np.testing.assert_allclose(xent(z, y), ref.xent_ref(z, y), rtol=1e-4, atol=1e-5)


def test_xent_vjp_matches_autodiff_of_ref():
    z = arr(32, 10, scale=2.0)
    y = jnp.asarray(RNG.integers(0, 10, size=(32,)).astype(np.int32))

    g1 = jax.grad(lambda z: jnp.mean(xent(z, y)))(z)
    g2 = jax.grad(lambda z: jnp.mean(ref.xent_ref(z, y)))(z)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-6)


def test_xent_is_shift_invariant():
    z = arr(8, 12)
    y = jnp.asarray(RNG.integers(0, 12, size=(8,)).astype(np.int32))
    np.testing.assert_allclose(xent(z, y), xent(z + 100.0, y), rtol=1e-4, atol=1e-4)


def test_xent_correct_class_dominant_gives_low_loss():
    c = 10
    y = jnp.asarray(np.arange(8, dtype=np.int32) % c)
    z = jax.nn.one_hot(y, c) * 20.0
    losses = xent(z, y)
    assert float(jnp.max(losses)) < 1e-3


def test_xent_gradient_rows_sum_to_zero():
    # d/dz of per-sample xent sums to zero across classes
    z = arr(16, 7)
    y = jnp.asarray(RNG.integers(0, 7, size=(16,)).astype(np.int32))
    g = jax.grad(lambda z: jnp.sum(xent(z, y)))(z)
    np.testing.assert_allclose(jnp.sum(g, axis=1), jnp.zeros(16), atol=1e-5)
