"""L2 correctness: model geometry, training dynamics, the channel-aligned
composition property, the static width scaler, and probes — for all three
families at every width."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import specs as S

RNG = np.random.default_rng(7)


def init(pspecs):
    return [
        jnp.asarray(RNG.normal(size=s).astype(np.float32) * (std if std > 0 else 0.0))
        for _, s, std in pspecs
    ]


def batch_for(spec, batch=None):
    b = batch or spec.batch
    if spec.family == "rnn":
        x = jnp.asarray(RNG.integers(0, spec.vocab, size=(b, spec.seq_len)).astype(np.int32))
        y = jnp.asarray(RNG.integers(0, spec.vocab, size=(b, spec.seq_len)).astype(np.int32))
    else:
        x = jnp.asarray(RNG.normal(size=(b, spec.input_hw, spec.input_hw, 3)).astype(np.float32))
        y = jnp.asarray(RNG.integers(0, spec.classes, size=(b,)).astype(np.int32))
    return x, y


FAMS = list(S.FAMILIES)
WIDTHS = [1, 2, 3, 4]


@pytest.mark.parametrize("fam", FAMS)
@pytest.mark.parametrize("p", WIDTHS)
@pytest.mark.parametrize("composed", [True, False])
def test_forward_shapes(fam, p, composed):
    spec = S.FAMILIES[fam]()
    ps = init(M.composed_param_specs(spec, p) if composed else M.dense_param_specs(spec, p))
    x, _ = batch_for(spec)
    logits = M.forward(spec, p, ps, x, composed)
    if fam == "rnn":
        assert logits.shape == (spec.batch, spec.seq_len, spec.vocab)
    else:
        assert logits.shape == (spec.batch, spec.classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("fam", FAMS)
@pytest.mark.parametrize("p", [1, 4])
@pytest.mark.parametrize("composed", [True, False])
def test_train_step_reduces_loss(fam, p, composed):
    spec = S.FAMILIES[fam]()
    ps = init(M.composed_param_specs(spec, p) if composed else M.dense_param_specs(spec, p))
    x, y = batch_for(spec)
    tr = jax.jit(M.make_train(spec, p, composed))
    lr = jnp.asarray([0.05], dtype=jnp.float32)
    cur, losses = list(ps), []
    for _ in range(30):
        out = tr(*cur, x, y, lr)
        cur = list(out[:-2])
        losses.append(float(out[-2][0]))
        assert float(out[-1][0]) >= 0.0  # grad_sq_norm
    assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], f"{fam} p={p} composed={composed}: {losses[0]} -> {losses[-1]}"


@pytest.mark.parametrize("fam", FAMS)
def test_eval_counts_and_loss(fam):
    spec = S.FAMILIES[fam]()
    p = spec.cap_p
    ps = init(M.composed_param_specs(spec, p))
    x, y = batch_for(spec, spec.eval_batch)
    ev = M.make_eval(spec, p, True)
    loss_sum, correct = ev(*ps, x, y)
    n = spec.eval_batch * (spec.seq_len if fam == "rnn" else 1)
    assert 0.0 <= float(correct[0]) <= n
    assert float(loss_sum[0]) > 0.0


@pytest.mark.parametrize("fam", FAMS)
@pytest.mark.parametrize("p", [1, 3])
def test_probe_dim_matches_param_count(fam, p):
    spec = S.FAMILIES[fam]()
    ps = init(M.composed_param_specs(spec, p))
    x, y = batch_for(spec)
    g = M.make_probe(spec, p, True)(*ps, x, y)[0]
    expect = sum(int(np.prod(s)) for _, s, _ in M.composed_param_specs(spec, p))
    assert g.shape == (expect,)
    assert float(jnp.sum(g * g)) > 0.0


def test_channel_aligned_composition():
    """The width-p composed weight with group selections {A}×{G} must equal
    (up to the static scaler) the full-width weight restricted to those
    channel groups — the sub-network alignment property (DESIGN.md
    §Deviations 1-2)."""
    spec = S.FAMILIES["cnn"]()
    l = spec.layer("conv2")  # s_in & s_out, B = 16
    P = spec.cap_p
    v = jnp.asarray(RNG.normal(size=l.basis_shape()).astype(np.float32))
    u_full = jnp.asarray(RNG.normal(size=(l.r, l.blocks_total(P) * l.o)).astype(np.float32))
    w_full = M._weight(l, P, v, u_full, P)  # (3,3,16,32)

    sel_in, sel_out = [1, 3], [0, 2]  # arbitrary ascending groups
    block_ids = [a * P + g for a in sel_in for g in sel_out]
    u_hat = jnp.concatenate([u_full[:, b * l.o:(b + 1) * l.o] for b in block_ids], axis=1)
    p = 2
    w_sub = M._weight(l, p, v, u_hat, P)  # (3,3,8,16), scaled by sqrt(P/p)
    scale = float(np.sqrt(P / p))

    for ai, a in enumerate(sel_in):
        for gi, g in enumerate(sel_out):
            sub_tile = w_sub[:, :, ai * l.i:(ai + 1) * l.i, gi * l.o:(gi + 1) * l.o]
            full_tile = w_full[:, :, a * l.i:(a + 1) * l.i, g * l.o:(g + 1) * l.o]
            np.testing.assert_allclose(sub_tile / scale, full_tile, rtol=1e-5, atol=1e-6)


def test_static_scaler_identity_at_full_width():
    spec = S.FAMILIES["cnn"]()
    l = spec.layer("conv3")
    v = jnp.asarray(RNG.normal(size=l.basis_shape()).astype(np.float32))
    u = jnp.asarray(RNG.normal(size=(l.r, l.blocks_total(4) * l.o)).astype(np.float32))
    w4 = M._weight(l, 4, v, u, 4)
    # recompute without scaler by asking for cap_p == p
    inter = np.asarray(w4)
    assert np.isfinite(inter).all()
    # p=1 weight from block 0 should be exactly sqrt(4) x the full tile
    u1 = u[:, : l.o]
    w1 = M._weight(l, 1, v, u1, 4)
    np.testing.assert_allclose(
        np.asarray(w1) / 2.0, np.asarray(w4)[:, :, : l.i, : l.o], rtol=1e-5, atol=1e-6
    )


def test_logit_scale_healthy_across_widths():
    """The static scaler keeps logits within an order of magnitude across
    widths (the bug class that froze sub-width training)."""
    spec = S.FAMILIES["cnn"]()
    x, _ = batch_for(spec)
    stds = []
    for p in WIDTHS:
        ps = init(M.composed_param_specs(spec, p))
        stds.append(float(jnp.std(M.forward(spec, p, ps, x, True))))
    assert max(stds) / min(stds) < 8.0, f"logit stds diverge across widths: {stds}"


def test_param_specs_shapes_and_stds():
    for fam in FAMS:
        spec = S.FAMILIES[fam]()
        for p in WIDTHS:
            cspecs = M.composed_param_specs(spec, p)
            assert cspecs[-1][0] == "bias"
            for (name, shape, std), l in zip(cspecs[0::2], spec.layers):
                assert name == f"v_{l.name}"
                assert tuple(shape) == l.basis_shape()
                assert std > 0
            for (name, shape, _), l in zip(cspecs[1::2], spec.layers):
                assert name == f"u_{l.name}"
                assert tuple(shape) == l.coeff_shape(p)
            dspecs = M.dense_param_specs(spec, p)
            assert len(dspecs) == len(spec.layers) + 1


def test_cost_model_monotone_in_width():
    for fam in FAMS:
        spec = S.FAMILIES[fam]()
        for composed in [True, False]:
            flops = [spec.train_flops(p, composed) for p in WIDTHS]
            bytes_ = [spec.upload_bytes(p, composed) for p in WIDTHS]
            assert flops == sorted(flops) and flops[0] > 0
            assert bytes_ == sorted(bytes_) and bytes_[0] > 0
        # the factorized transfer must beat dense at full width
        assert spec.upload_bytes(4, True) < spec.upload_bytes(4, False)


def test_group_classes_are_consistent():
    """s_in/s_out must come with in_class/out_class, and residual-tied
    layers must agree on base channel counts."""
    for fam in FAMS:
        spec = S.FAMILIES[fam]()
        out_dims = {}
        for l in spec.layers:
            assert l.s_in == (l.in_class is not None), l.name
            assert l.s_out == (l.out_class is not None), l.name
            if l.out_class:
                out_dims.setdefault(l.out_class, l.o)
                assert out_dims[l.out_class] == l.o, f"{l.name}: class width mismatch"
        for l in spec.layers:
            if l.in_class:
                assert l.in_class in out_dims, f"{l.name}: dangling in_class"
                assert out_dims[l.in_class] == l.i, f"{l.name}: in/out width mismatch"
