"""AOT pipeline checks: spec builders agree with the exported manifest and
the HLO files exist and parse structurally (when artifacts are built)."""
import json
import os

import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile import specs as S

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
HAVE_ARTIFACTS = os.path.exists(os.path.join(ART, "manifest.json"))


def test_input_specs_ordering():
    spec = S.cnn_spec()
    ins = aot._input_specs(spec, 2, True, "train")
    names = [i["name"] for i in ins]
    assert names[0] == "v_conv1"
    assert names[1] == "u_conv1"
    assert names[-3:] == ["x", "y", "lr"]
    # eval uses eval_batch and no lr
    ev = aot._input_specs(spec, 4, True, "eval")
    assert ev[-1]["name"] == "y"
    assert ev[-2]["shape"][0] == spec.eval_batch


def test_output_specs_match_kind():
    spec = S.rnn_spec()
    outs = aot._output_specs(spec, 3, True, "train")
    assert outs[-2]["name"] == "loss"
    assert outs[-1]["name"] == "grad_sq_norm"
    assert len(outs) == 2 * len(spec.layers) + 1 + 2
    probe = aot._output_specs(spec, 3, True, "probe")
    assert probe[0]["shape"] == [M.probe_dim(spec, 3, True)]
    ev = aot._output_specs(spec, 4, True, "eval")
    assert [o["name"] for o in ev] == ["loss_sum", "correct"]


def test_model_manifest_contents():
    spec = S.resnet_spec()
    m = aot._model_manifest(spec)
    assert m["cap_p"] == 4
    assert len(m["layers"]) == len(spec.layers)
    for lm, l in zip(m["layers"], spec.layers):
        assert lm["blocks_total"] == l.blocks_total(4)
        assert lm["in_class"] == l.in_class
        assert lm["out_class"] == l.out_class
    for p in "1234":
        assert m["flops"]["composed"][p] > 0
        assert m["bytes"]["composed"][p] < m["bytes"]["dense"][p] or p == "1"


@pytest.mark.skipif(not HAVE_ARTIFACTS, reason="run `make artifacts` first")
def test_manifest_file_matches_specs():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert set(man["models"]) == set(S.FAMILIES)
    for fam, mk in S.FAMILIES.items():
        spec = mk()
        got = man["models"][fam]
        expect = aot._model_manifest(spec)
        assert got["cap_p"] == expect["cap_p"]
        assert got["params"] == expect["params"]
        assert got["flops"] == {k: {p: float(v) for p, v in d.items()}
                                for k, d in expect["flops"].items()}
        for p in range(1, spec.cap_p + 1):
            for kind in ["train", "dtrain", "probe"]:
                name = f"{fam}_{kind}_p{p}"
                assert name in man["executables"], name
                assert os.path.exists(os.path.join(ART, man["executables"][name]["file"]))
        for kind in ["eval", "deval"]:
            assert f"{fam}_{kind}" in man["executables"]


@pytest.mark.skipif(not HAVE_ARTIFACTS, reason="run `make artifacts` first")
def test_hlo_files_look_like_hlo():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    # spot-check one executable per family
    for fam in S.FAMILIES:
        path = os.path.join(ART, man["executables"][f"{fam}_train_p1"]["file"])
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head, f"{path} does not look like HLO text"
        assert "ENTRY" in open(path).read()


def test_lowering_one_executable_roundtrip(tmp_path):
    """Actually lower a tiny executable and check the emitted spec."""
    spec = S.cnn_spec()
    entry = aot._lower_one(spec, 1, True, "eval", str(tmp_path), "tmp_eval")
    assert (tmp_path / "tmp_eval.hlo.txt").exists()
    text = (tmp_path / "tmp_eval.hlo.txt").read_text()
    assert "HloModule" in text
    assert entry["kind"] == "eval"
    assert entry["inputs"][-1]["dtype"] == "i32"
    # input count: 2 per layer + bias + x + y
    assert len(entry["inputs"]) == 2 * len(spec.layers) + 1 + 2
