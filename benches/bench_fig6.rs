//! End-to-end bench regenerating Fig. 6 (resource consumption, CNN) at a miniature
//! scale via the shared `util::bench::experiment_miniature` runner
//! (harness = false; bench-lite). Skips gracefully without artifacts.

// Test/bench/example code: panicking on setup failure is idiomatic
// (CONTRIBUTING.md — the error-handling contract binds library code).
#![allow(clippy::unwrap_used, clippy::expect_used, clippy::indexing_slicing)]


fn main() {
    heroes::util::bench::experiment_miniature("fig6");
}
