//! Micro-benchmarks of the coordinator hot paths (bench-lite harness;
//! no criterion in the offline vendor set — see util::bench).
//!
//! These are the quantities the §Perf pass tracks: PJRT dispatch latency,
//! block gather/scatter, aggregation, round planning, data synthesis,
//! and the lazy population model's O(cohort) round cost across
//! population scales.

// Test/bench/example code: panicking on setup failure is idiomatic
// (CONTRIBUTING.md — the error-handling contract binds library code).
#![allow(clippy::unwrap_used, clippy::expect_used, clippy::indexing_slicing)]


use heroes::baselines::{DenseServer, Strategy};
use heroes::codec::json::Json;
use heroes::codec::{self, CodecCfg, Encoding, FrameMeta};
use heroes::config::{ExperimentConfig, QuorumKnob, Scale};
use heroes::coordinator::aggregate::ComposedAccumulator;
use heroes::coordinator::assignment::{plan_round, ClientStatus, ControllerCfg};
use heroes::coordinator::env::FlEnv;
use heroes::coordinator::frequency::Estimates;
use heroes::coordinator::ledger::BlockLedger;
use heroes::coordinator::quorum_ctl::QuorumPolicy;
use heroes::coordinator::round::RoundDriver;
use heroes::coordinator::RoundReport;
use heroes::data::synth_image::ImageGen;
use heroes::model::ComposedGlobal;
use heroes::runtime::{EnginePool, EngineStats, Manifest, Value};
use heroes::experiments::{run_scheme, StopCondition};
use heroes::simulation::{
    ClientDevice, DeviceClass, LazyCache, LinkSample, NetworkModel, Population, PopulationSpec,
    Scenario,
};
use heroes::tensor::blocks::{gather_blocks, scatter_blocks_add};
use heroes::tensor::Tensor;
use heroes::util::bench::Bench;
use heroes::util::rng::Rng;
use heroes::util::stats;

fn main() {
    let b = Bench::default();

    // `HEROES_BENCH_ONLY=<section>` restricts the run to one section
    // (micro | population | codec | faults | driver) so CI can run each
    // acceptance bench as its own named step; unset runs everything.
    let only = std::env::var("HEROES_BENCH_ONLY").ok();
    let run_section = |name: &str| only.as_deref().map_or(true, |o| o == name);

    // ---- pure-rust substrate paths (always available) ----
    if run_section("micro") {
        let mut rng = Rng::new(1);
        let u = Tensor::randn(&[8, 128], 0.1, &mut rng);
        b.run("blocks/gather 4-of-16 (R=8,O=8)", |_| gather_blocks(&u, &[1, 5, 9, 13], 8));

        let reduced = gather_blocks(&u, &[1, 5, 9, 13], 8);
        b.run("blocks/scatter+count", |_| {
            let mut sums = Tensor::zeros(&[8, 128]);
            let mut counts = vec![0u32; 16];
            scatter_blocks_add(&mut sums, &mut counts, &reduced, &[1, 5, 9, 13], 8);
            sums
        });

        // HeteroFL prefix extraction/aggregation (row-copy fast path)
        let w = Tensor::randn(&[3, 3, 64, 128], 0.1, &mut rng);
        b.run("tensor/slice_prefix (3,3,64,128)->(3,3,32,64)", |_| {
            w.slice_prefix(&[3, 3, 32, 64])
        });
        let half = w.slice_prefix(&[3, 3, 32, 64]);
        b.run("tensor/scatter_prefix_add (3,3,32,64)", |_| {
            let mut full = Tensor::zeros(&[3, 3, 64, 128]);
            let mut counts = vec![0u32; full.len()];
            full.scatter_prefix_add(&half, &mut counts);
            full
        });

        let gen = ImageGen::cifar_twin();
        b.run("data/synthesize 64 images", |i| gen.generate(64, i, &mut Rng::new(i)));
    }

    // ---- codec: wire-format encode/decode throughput + ratio ----
    if run_section("codec") {
        codec_bench(&b);
    }

    // ---- population scale: O(cohort) round cost from 1e3 to 1e6 ----
    if run_section("population") {
        population_bench();
    }

    // ---- fault pressure: recovery overhead vs rate, retry vs replan ----
    if run_section("faults") {
        faults_bench(&b);
    }

    // manifest-dependent paths
    if !run_section("driver") {
        return;
    }
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("(artifacts missing — run `make artifacts` for the PJRT benches)");
        return;
    }
    let pool = EnginePool::single(Manifest::load(&dir).unwrap()).unwrap();
    let engine = pool.primary();
    let info = engine.manifest().model("cnn").unwrap().clone();
    let cfg = ExperimentConfig::preset("cnn", Scale::Smoke);

    // round planning
    let ctrl = ControllerCfg {
        mu_max: cfg.mu_max, rho: cfg.rho, eta: 0.1, epsilon: cfg.epsilon,
        tau_min: 1, tau_max: 60, tau_floor: 10, h_max: 1_000_000, beta_sq: 1e-3,
        codec: CodecCfg::Analytic,
    };
    let est = Estimates { l: 2.0, sigma_sq: 0.5, g_sq: 1.0, loss: 2.0 };
    let statuses: Vec<ClientStatus> = (0..10)
        .map(|i| ClientStatus {
            client: i,
            q_flops: 2e7 + i as f64 * 7e6,
            link: LinkSample { up_bps: 8_000.0 + i as f64 * 1000.0, down_bps: 50_000.0 },
        })
        .collect();
    b.run("coordinator/plan_round K=10", |_| {
        let mut ledger = BlockLedger::new(&info).unwrap();
        plan_round(&info, &ctrl, &est, &statuses, &mut ledger).unwrap()
    });

    // aggregation of K=10 full-width updates
    let mut rng = Rng::new(2);
    let global = ComposedGlobal::init(&info, &mut rng).unwrap();
    let mut ledger = BlockLedger::new(&info).unwrap();
    let full = ledger.full_selection(&info).unwrap();
    let payload = global.reduced_inputs(&info, info.cap_p, &full.blocks).unwrap();
    b.run("coordinator/aggregate K=10 full-width", |_| {
        let mut acc = ComposedAccumulator::new(&info, &global);
        for _ in 0..10 {
            acc.push(&full.blocks, &payload).unwrap();
        }
        acc.finalize().unwrap()
    });

    // staleness-weighted aggregation (quorum late merges): the in-place
    // fused axpy push vs the clone→scale→push a naive weighted merge
    // would do — the reference materializes a scaled payload per client
    b.run("coordinator/aggregate K=10 weighted in-place", |_| {
        let mut acc = ComposedAccumulator::new(&info, &global);
        for _ in 0..10 {
            acc.push_weighted(&full.blocks, &payload, 0.5).unwrap();
        }
        acc.finalize().unwrap()
    });
    b.run("coordinator/aggregate K=10 weighted clone+scale ref", |_| {
        let mut acc = ComposedAccumulator::new(&info, &global);
        for _ in 0..10 {
            let scaled: Vec<Tensor> = payload
                .iter()
                .map(|t| {
                    let mut c = t.clone();
                    c.scale(0.5);
                    c
                })
                .collect();
            acc.push_weighted(&full.blocks, &scaled, 1.0).unwrap();
        }
        acc.finalize().unwrap()
    });

    // PJRT single train-step dispatch (p=1 and p=4)
    let ds = ImageGen::cifar_twin().generate(info.batch, 7, &mut rng);
    let mut x = vec![0.0f32; info.batch * ds.sample_size()];
    let mut y = vec![0i32; info.batch];
    for i in 0..info.batch {
        x[i * ds.sample_size()..(i + 1) * ds.sample_size()].copy_from_slice(ds.sample(i));
        y[i] = ds.labels[i];
    }
    let xt = Tensor::from_vec(&[info.batch, 16, 16, 3], x);
    let yt = heroes::tensor::IntTensor::from_vec(&[info.batch], y);
    let lr = Tensor::from_vec(&[1], vec![0.05]);
    for p in [1, info.cap_p] {
        let sel = ledger.select_for_width(&info, p).unwrap();
        let params = global.reduced_inputs(&info, p, &sel.blocks).unwrap();
        let name = Manifest::train_name("cnn", p, true);
        engine.prepare(&name).unwrap();
        b.run(&format!("pjrt/train_step cnn p={p}"), |_| {
            let mut inputs: Vec<Value> = params.iter().map(Value::F32).collect();
            inputs.push(Value::F32(&xt));
            inputs.push(Value::I32(&yt));
            inputs.push(Value::F32(&lr));
            engine.execute(&name, &inputs).unwrap()
        });
    }
    // ---- parallel round driver: 16-client fleet ----
    // workers=1 vs 4 on one shared engine, then workers=4 over a
    // per-worker engine pool: pooled must be no slower than shared (the
    // pool removes intra-op contention on one PJRT client). The simulated
    // *virtual* time is byte-identical across all three — see
    // coordinator::round docs.
    let mut cfg16 = ExperimentConfig::preset("cnn", Scale::Smoke);
    cfg16.n_clients = 16;
    cfg16.k_per_round = 16;
    cfg16.samples_per_client = 32;
    cfg16.test_samples = 64;
    cfg16.tau_default = 2;
    let bq = Bench::quick();
    let warm = Manifest::train_name("cnn", info.cap_p, false);
    let mut driver_stats = Vec::new();
    for (workers, engines) in [(1usize, 1usize), (4, 1), (4, 4)] {
        cfg16.workers = workers;
        let bench_pool = EnginePool::new(Manifest::load(&dir).unwrap(), engines).unwrap();
        bench_pool.prepare_all(&[warm.as_str()]).unwrap();
        let mut env = FlEnv::build(&bench_pool, cfg16.clone()).unwrap();
        let mut srng = Rng::new(cfg16.seed ^ 0x5EED);
        let mut server = DenseServer::fedavg(&info, &cfg16, &mut srng).unwrap();
        bq.run(
            &format!("driver/round K=16 fedavg workers={workers} engines={engines}"),
            |_| server.run_round(&mut env).unwrap(),
        );
        driver_stats.push(bench_pool.stats());
    }

    // ---- straggler tail: full barrier vs --overlap vs --quorum K ----
    // 16-client cohort, client 0 on a ~4.5x slower device than the rest
    // (Laptop vs AGX Xavier — the widest spread the fleet model offers):
    // a synchronous round's completion time T^h (Eq. 19) is pinned to the
    // straggler, a K=12 quorum round closes at the 12th-fastest
    // projection. Per-round wall-clock here is the *simulated* round time
    // — the metric every figure reports; the real seconds per round are
    // recorded alongside for the pipeline-overlap effect.
    let mut cfg_tail = ExperimentConfig::preset("cnn", Scale::Smoke);
    cfg_tail.n_clients = 16;
    cfg_tail.k_per_round = 16;
    cfg_tail.samples_per_client = 32;
    cfg_tail.test_samples = 64;
    cfg_tail.tau_default = 2;
    cfg_tail.workers = 4;
    let rounds = 4usize;
    let skew_fleet = |env: &mut FlEnv| {
        for (i, d) in env.fleet.devices.iter_mut().enumerate() {
            let class = if i == 0 { DeviceClass::Laptop } else { DeviceClass::AgxXavier };
            *d = ClientDevice::new(class, Rng::new(100 + i as u64));
        }
    };
    let mean_round_time = |reports: &[RoundReport]| {
        stats::mean(&reports.iter().map(|r| r.round_time).collect::<Vec<_>>())
    };

    /// Dispatch mode of one straggler-tail config.
    #[derive(Clone, Copy)]
    enum TailMode {
        Sync,
        Overlap,
        Quorum(usize),
        /// `--quorum auto`: per-round (K, α) from the adaptive controller
        Adaptive,
    }

    let tail_pool = EnginePool::new(Manifest::load(&dir).unwrap(), 4).unwrap();
    tail_pool.prepare_all(&[warm.as_str()]).unwrap();
    let mut snapshot: Vec<(&str, Json)> = Vec::new();
    let configs = [
        ("full-barrier", TailMode::Sync),
        ("overlap", TailMode::Overlap),
        ("quorum-12", TailMode::Quorum(12)),
        ("quorum-14", TailMode::Quorum(14)),
        ("adaptive", TailMode::Adaptive),
    ];
    let mut virtuals: Vec<(&str, f64)> = Vec::new();
    for (label, mode) in configs {
        let mut cfg_run = cfg_tail.clone();
        cfg_run.quorum = match mode {
            TailMode::Quorum(k) => QuorumKnob::Fixed(k),
            TailMode::Adaptive => QuorumKnob::Auto,
            _ => QuorumKnob::Off,
        };
        let mut env = FlEnv::build(&tail_pool, cfg_run.clone()).unwrap();
        skew_fleet(&mut env);
        let mut srng = Rng::new(cfg_run.seed ^ 0x5EED);
        let mut server = DenseServer::fedavg(&info, &cfg_run, &mut srng).unwrap();
        let driver = RoundDriver::new(cfg_run.workers);
        // exactly the policy a real `--quorum K`/`--quorum auto` run
        // would build from this config — no hand-rolled duplicate of
        // the from_config recipe to drift out of sync
        let mut policy = QuorumPolicy::from_config(&cfg_run)
            .unwrap_or_else(|| QuorumPolicy::fixed(0, cfg_run.staleness_alpha));
        let t0 = std::time::Instant::now();
        let reports = match mode {
            TailMode::Quorum(_) | TailMode::Adaptive => driver
                .run_quorum(&tail_pool, &mut env, &mut server, rounds, &mut policy, None)
                .unwrap(),
            TailMode::Overlap => {
                driver.run_overlapped(&tail_pool, &mut env, &mut server, rounds).unwrap()
            }
            TailMode::Sync => (0..rounds).map(|_| server.run_round(&mut env).unwrap()).collect(),
        };
        let real = t0.elapsed().as_secs_f64();
        let virt = mean_round_time(&reports);
        let mean_k = stats::mean(
            &reports.iter().map(|r| r.completion_times.len() as f64).collect::<Vec<_>>(),
        );
        println!(
            "driver/straggler-tail K=16 {label:<13} virtual {virt:8.1} s/round, \
             real {:.3} s/round, mean K {mean_k:4.1}",
            real / rounds as f64
        );
        virtuals.push((label, virt));
        let mut entry = vec![
            ("rounds", Json::Num(rounds as f64)),
            ("round_time_virtual_mean", Json::Num(virt)),
            ("real_secs_per_round", Json::Num(real / rounds as f64)),
            ("mean_quorum_k", Json::Num(mean_k)),
        ];
        if let QuorumPolicy::Auto(ctl) = &policy {
            entry.push(("final_alpha", Json::Num(ctl.alpha())));
        }
        snapshot.push((label, Json::obj(entry)));
    }

    // adaptive vs the best static K (round-time comparison the ROADMAP's
    // adaptive-quorum item asks for)
    let virt_of = |name: &str| virtuals.iter().find(|(l, _)| *l == name).map(|(_, v)| *v);
    let adaptive = virt_of("adaptive").unwrap_or(f64::NAN);
    let statics = [
        ("quorum-12", virt_of("quorum-12")),
        ("quorum-14", virt_of("quorum-14")),
        ("full-barrier", virt_of("full-barrier")),
    ];
    let (best_static, best_virt) = statics
        .into_iter()
        .filter_map(|(l, v)| v.map(|v| (l, v)))
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .unwrap_or(("none", f64::NAN));
    println!(
        "driver/straggler-tail adaptive {adaptive:.1} s/round vs best static \
         ({best_static}) {best_virt:.1} s/round{}",
        if adaptive <= best_virt { " — adaptive wins/ties" } else { "" }
    );

    let pick = |names: &[&str]| {
        let entries: Vec<(&str, Json)> = snapshot
            .iter()
            .filter(|(l, _)| names.contains(l))
            .map(|(l, j)| (*l, j.clone()))
            .collect();
        Json::obj(entries)
    };
    // PR 3's static comparison (same three configs; entries now also
    // carry mean_quorum_k)
    write_snap(
        "BENCH_quorum.json",
        &Json::obj(vec![
            ("bench", Json::Str("straggler_tail_quorum".into())),
            ("clients", Json::Num(cfg_tail.n_clients as f64)),
            ("quorum", Json::Num(12.0)),
            ("configs", pick(&["full-barrier", "overlap", "quorum-12"])),
        ]),
    );
    // the adaptive entry vs every static K
    write_snap(
        "BENCH_adaptive_quorum.json",
        &Json::obj(vec![
            ("bench", Json::Str("straggler_tail_adaptive_quorum".into())),
            ("clients", Json::Num(cfg_tail.n_clients as f64)),
            ("best_static", Json::Str(best_static.into())),
            ("best_static_virtual", Json::Num(best_virt)),
            ("adaptive_virtual", Json::Num(adaptive)),
            ("configs", pick(&["full-barrier", "quorum-12", "quorum-14", "adaptive"])),
        ]),
    );

    // ---- churn: Heroes vs dense vs Flanc under flash-crowd churn ----
    // time- and traffic-to-accuracy with a third of the fleet windowed,
    // the WAN congested in-window and 2–8% of dispatched tasks vanishing
    // mid-round (`--scenario flash-crowd-churn --quorum auto`): the
    // scenario engine's headline comparison, emitted as BENCH_churn.json
    let mut cfg_churn = ExperimentConfig::preset("cnn", Scale::Smoke);
    cfg_churn.n_clients = 16;
    cfg_churn.k_per_round = 8;
    cfg_churn.samples_per_client = 32;
    cfg_churn.test_samples = 64;
    cfg_churn.tau_default = 2;
    cfg_churn.workers = 4;
    cfg_churn.rounds = 6;
    cfg_churn.eval_every = 2;
    cfg_churn.scenario = Scenario::parse("flash-crowd-churn").unwrap();
    cfg_churn.quorum = QuorumKnob::Auto;
    let churn_pool = EnginePool::new(Manifest::load(&dir).unwrap(), 4).unwrap();
    churn_pool.prepare_all(&[warm.as_str()]).unwrap();
    let mut churn_runs = Vec::new();
    let mut weakest_final = f64::INFINITY;
    for scheme in ["heroes", "fedavg", "flanc"] {
        let t0 = std::time::Instant::now();
        let rec = run_scheme(&churn_pool, &cfg_churn, scheme, StopCondition::default()).unwrap();
        let real = t0.elapsed().as_secs_f64();
        weakest_final = weakest_final.min(rec.final_accuracy());
        churn_runs.push((scheme, rec, real));
    }
    // shared target just under the weakest scheme's final accuracy, so
    // every scheme has a defined time/traffic-to-accuracy entry
    let target = (weakest_final * 0.95).max(0.0);
    let opt_num = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
    let mut churn_entries: Vec<(&str, Json)> = Vec::new();
    for (scheme, rec, real) in &churn_runs {
        let last = rec.samples.last().unwrap();
        println!(
            "driver/churn K=8-of-16 {scheme:<8} acc {:.3}, sim {:7.1} s, \
             traffic {:.4} GB, t2a@{target:.2} {:?} s, gb2a {:?} GB, real {real:.2} s",
            last.test_acc,
            last.sim_time,
            last.traffic_gb,
            rec.time_to_accuracy(target),
            rec.traffic_to_accuracy(target),
        );
        churn_entries.push((
            scheme,
            Json::obj(vec![
                ("final_acc", Json::Num(last.test_acc)),
                ("sim_time", Json::Num(last.sim_time)),
                ("traffic_gb", Json::Num(last.traffic_gb)),
                ("time_to_target", opt_num(rec.time_to_accuracy(target))),
                ("traffic_to_target", opt_num(rec.traffic_to_accuracy(target))),
                ("real_secs", Json::Num(*real)),
            ]),
        ));
    }
    write_snap(
        "BENCH_churn.json",
        &Json::obj(vec![
            ("bench", Json::Str("flash_crowd_churn_time_traffic_to_accuracy".into())),
            ("scenario", Json::Str(cfg_churn.scenario.name().into())),
            ("clients", Json::Num(cfg_churn.n_clients as f64)),
            ("rounds", Json::Num(cfg_churn.rounds as f64)),
            ("target_acc", Json::Num(target)),
            ("schemes", Json::obj(churn_entries)),
        ]),
    );

    // totals over everything this bench executed: the shared micro-bench
    // pool plus each driver config's own pool
    let st = EngineStats::merged(std::iter::once(pool.stats()).chain(driver_stats));
    println!(
        "engine totals: {} compiles ({:.2}s), {} executions ({:.3}ms mean)",
        st.compiles,
        st.compile_secs,
        st.executions,
        1e3 * st.execute_secs / st.executions.max(1) as f64
    );
}

/// The lazy population model's acceptance bench: per-round planning
/// work (cohort sampling + per-member device/link/shard derivations
/// through a bounded cache) must stay flat as the population grows
/// 1000x — nothing on this path may enumerate clients. Emitted as
/// BENCH_population.json; a super-linear blow-up (worst scale > 8x
/// the smallest) fails the bench, which CI runs as a named step.
fn population_bench() {
    let net = NetworkModel::default();
    let pop_rounds = 50usize;
    let pop_k = 16usize;
    let mut pop_entries: Vec<(&str, Json)> = Vec::new();
    let mut per_round: Vec<f64> = Vec::new();
    for (label, n) in
        [("1e3", 1_000usize), ("1e4", 10_000), ("1e5", 100_000), ("1e6", 1_000_000)]
    {
        let pop = Population::new(PopulationSpec::default_mix(n, 42)).unwrap();
        let mut cache: LazyCache<u64> = LazyCache::new(4 * pop_k).unwrap();
        let mut sink = 0u64;
        let round_work = |round: usize, cache: &mut LazyCache<u64>, sink: &mut u64| {
            let cohort = pop.sample_cohort(round, pop_k, |_| true);
            assert_eq!(cohort.len(), pop_k, "population {n}: short cohort");
            for &c in &cohort {
                let q = pop.flops(c, round);
                let link = net.sample(&mut pop.link_rng(c, round));
                let spec = pop.shard_spec(c, 60);
                *sink ^= cache.get_or_insert_with(c, || spec.seed ^ spec.quota as u64);
                *sink ^= q.to_bits() ^ link.up_bps.to_bits();
            }
        };
        // one untimed warmup round per scale (allocator + map warm-up)
        round_work(pop_rounds, &mut cache, &mut sink);
        let t0 = std::time::Instant::now();
        for round in 0..pop_rounds {
            round_work(round, &mut cache, &mut sink);
        }
        let secs = t0.elapsed().as_secs_f64() / pop_rounds as f64;
        std::hint::black_box(sink);
        let st = cache.stats().clone();
        println!(
            "population/round K={pop_k} n={label:<4} {:9.2} µs/round, \
             {} materializations, peak resident {}",
            1e6 * secs,
            st.materializations,
            st.peak_resident
        );
        per_round.push(secs);
        pop_entries.push((
            label,
            Json::obj(vec![
                ("clients", Json::Num(n as f64)),
                ("round_secs", Json::Num(secs)),
                ("materializations", Json::Num(st.materializations as f64)),
                ("peak_resident", Json::Num(st.peak_resident as f64)),
                ("evictions", Json::Num(st.evictions as f64)),
            ]),
        ));
    }
    let floor = per_round.iter().copied().fold(f64::INFINITY, f64::min);
    let worst = per_round.iter().copied().fold(0.0f64, f64::max);
    let ratio = worst / floor.max(1e-9);
    write_snap(
        "BENCH_population.json",
        &Json::obj(vec![
            ("bench", Json::Str("population_scale_round_cost".into())),
            ("k_per_round", Json::Num(pop_k as f64)),
            ("rounds", Json::Num(pop_rounds as f64)),
            ("worst_over_best", Json::Num(ratio)),
            ("scales", Json::obj(pop_entries)),
        ]),
    );
    if ratio > 8.0 {
        eprintln!(
            "population/round cost is not flat: worst scale is {ratio:.1}x the best \
             (bound 8x) — an O(population) step leaked onto the round path"
        );
        std::process::exit(1);
    }
}

/// Fault-pressure acceptance bench, pure rust (no artifacts needed):
/// a synthetic 64-client cohort's completion plan is stamped under
/// rising fault rates with the `retry` and `replan` policies, measuring
/// what recovery actually costs — the mean round-closing completion
/// inflation (retry pays backoff delays, replan pays lost members) and
/// the fraction of the cohort each policy abandons. Also times the
/// stamp hot path itself (one draw + resolution per dispatched task —
/// it rides every round dispatch, so it must stay microseconds-cheap).
/// Emitted as BENCH_faults.json, which CI runs as a named step.
fn faults_bench(b: &Bench) {
    use heroes::coordinator::resilience::{FaultPolicyCfg, FaultsCtl};
    use heroes::simulation::FaultsCfg;

    let cohort = 64usize;
    let rounds = 40usize;
    // a heterogeneous completion plan: client i finishes in 30..90 s
    let completions: Vec<f64> = {
        let mut rng = Rng::new(0xFA_0175);
        (0..cohort).map(|_| rng.uniform_in(30.0, 90.0)).collect()
    };
    let baseline_close: f64 =
        completions.iter().copied().fold(0.0, f64::max);

    // stamp hot-path cost at a representative mixed rate
    let hot = FaultsCfg::parse("exec=0.1,corrupt=0.05,partition=0.1").unwrap();
    b.run("faults/stamp 64-task round (mixed 25%)", |i| {
        let mut ctl = FaultsCtl::new(hot, FaultPolicyCfg::default(), 7);
        ctl.note_dispatched(cohort);
        for (client, c) in completions.iter().enumerate() {
            ctl.stamp_one(i as usize, client, *c, false).unwrap();
        }
        *ctl.ledger()
    });

    let policies: [(&str, FaultPolicyCfg); 2] = [
        ("retry", FaultPolicyCfg::default()),
        ("replan", FaultPolicyCfg::parse("replan").unwrap()),
    ];
    let mut entries: Vec<(String, Json)> = Vec::new();
    for rate in [0.05f64, 0.1, 0.2, 0.4] {
        let cfg = FaultsCfg { exec: rate, corrupt: rate, partition: rate };
        for (policy_name, policy) in policies {
            // per round: the closing time is the max surviving
            // completion after stamping; abandoned members are lost
            let mut overhead = 0.0f64;
            let mut lost = 0u64;
            let mut ctl = FaultsCtl::new(cfg, policy, 11);
            for round in 0..rounds {
                ctl.note_dispatched(cohort);
                let mut close = 0.0f64;
                for (client, c) in completions.iter().enumerate() {
                    let stamped = ctl.stamp_one(round, client, *c, false).unwrap();
                    match stamped {
                        Some((stamp, _)) if !stamp.recovered => lost += 1,
                        Some((_, new_completion)) => close = close.max(new_completion),
                        None => close = close.max(*c),
                    }
                }
                overhead += close / baseline_close - 1.0;
            }
            let ledger = *ctl.ledger();
            let mean_overhead = overhead / rounds as f64;
            let lost_frac = lost as f64 / (cohort * rounds) as f64;
            println!(
                "faults/pressure rate={rate:<4} {policy_name:<6} \
                 recovery overhead {:6.2}% of round time, {:5.2}% of cohort lost, \
                 observed rate {:.3}",
                100.0 * mean_overhead,
                100.0 * lost_frac,
                ledger.observed_rate()
            );
            entries.push((
                format!("rate{rate}/{policy_name}"),
                Json::obj(vec![
                    ("injection_rate_per_class", Json::Num(rate)),
                    ("mean_recovery_overhead", Json::Num(mean_overhead)),
                    ("cohort_lost_frac", Json::Num(lost_frac)),
                    ("observed_fault_rate", Json::Num(ledger.observed_rate())),
                    (
                        "retried",
                        Json::Num(
                            (ledger.exec.retried
                                + ledger.corrupt.retried
                                + ledger.partition.retried) as f64,
                        ),
                    ),
                ]),
            ));
        }
    }
    let entries: Vec<(&str, Json)> =
        entries.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    write_snap(
        "BENCH_faults.json",
        &Json::obj(vec![
            ("bench", Json::Str("fault_pressure_recovery_overhead".into())),
            ("cohort", Json::Num(cohort as f64)),
            ("rounds", Json::Num(rounds as f64)),
            ("configs", Json::obj(entries)),
        ]),
    );
}

/// HWU1 codec throughput + compression ratio, pure rust (no artifacts
/// needed): a synthetic composed-payload update at widths P ∈ {1, 4} is
/// framed and read back under each `--codec wire*` mode. Reports encode
/// and decode MB/s (of raw f32 payload) and the encoded-to-raw byte
/// ratio; emitted as BENCH_codec.json, which CI runs as a named step.
fn codec_bench(b: &Bench) {
    let modes: [(&str, Encoding); 3] = [
        ("raw", Encoding { q8: false, topk: None }),
        ("q8", Encoding { q8: true, topk: None }),
        ("q8+topk0.25", Encoding { q8: true, topk: Some(0.25) }),
    ];
    let mut entries: Vec<(String, Json)> = Vec::new();
    for p in [1usize, 4] {
        // the composed-update silhouette of a small conv family at
        // width p: per-layer [v_l, û_l] pairs plus a bias vector
        let shapes: Vec<Vec<usize>> = vec![
            vec![9, 16, 8 * p],
            vec![8 * p, 16 * p],
            vec![9, 16 * p, 8 * p],
            vec![8 * p, 32 * p],
            vec![64 * p, 10],
            vec![10],
        ];
        let mut rng = Rng::new(0xC0DEC ^ p as u64);
        let tensors: Vec<Tensor> =
            shapes.iter().map(|s| Tensor::randn(s, 0.1, &mut rng)).collect();
        let raw_bytes: usize = tensors.iter().map(|t| 4 * t.len()).sum();
        let meta = FrameMeta { scheme: codec::scheme_id::HEROES, round: 0, client: 7 };
        for (mode, enc) in modes {
            let frame_bytes =
                codec::frame_len_for_shapes(shapes.iter().map(Vec::as_slice), enc);
            let mut buf = Vec::with_capacity(frame_bytes);
            codec::encode_update(&mut buf, &meta, enc, &tensors).unwrap();
            assert_eq!(buf.len(), frame_bytes, "planned frame length drifted");

            let e = b.run(&format!("codec/encode p={p} {mode}"), |_| {
                let mut out = Vec::with_capacity(frame_bytes);
                codec::encode_update(&mut out, &meta, enc, &tensors).unwrap();
                out
            });
            let d = b.run(&format!("codec/decode p={p} {mode}"), |_| {
                codec::decode_update(&buf).unwrap()
            });
            let enc_mbs = raw_bytes as f64 / e.median() / 1e6;
            let dec_mbs = raw_bytes as f64 / d.median() / 1e6;
            let ratio = frame_bytes as f64 / raw_bytes as f64;
            println!(
                "codec/p={p} {mode:<12} {enc_mbs:8.1} MB/s enc, {dec_mbs:8.1} MB/s dec, \
                 {frame_bytes} B frame ({:.1}% of raw)",
                100.0 * ratio
            );
            entries.push((
                format!("p{p}/{mode}"),
                Json::obj(vec![
                    ("raw_bytes", Json::from(raw_bytes)),
                    ("frame_bytes", Json::from(frame_bytes)),
                    ("ratio_vs_raw", Json::Num(ratio)),
                    ("encode_mb_per_s", Json::Num(enc_mbs)),
                    ("decode_mb_per_s", Json::Num(dec_mbs)),
                ]),
            ));
        }
    }
    let entries: Vec<(&str, Json)> =
        entries.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    write_snap(
        "BENCH_codec.json",
        &Json::obj(vec![
            ("bench", Json::Str("codec_wire_throughput_and_ratio".into())),
            ("configs", Json::obj(entries)),
        ]),
    );
}

/// Snapshots land next to the experiment outputs (`heroes exp` writes
/// results/ too); a read-only tree degrades to a warning, not an abort.
fn write_snap(file: &str, out: &Json) {
    let snap_path = std::path::Path::new("results").join(file);
    match std::fs::create_dir_all("results")
        .and_then(|()| std::fs::write(&snap_path, out.to_string_pretty()))
    {
        Ok(()) => println!("  -> {}", snap_path.display()),
        Err(e) => eprintln!("  (could not write {}: {e})", snap_path.display()),
    }
}
