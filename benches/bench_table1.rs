//! End-to-end bench regenerating Table I (enhanced NC vs original NC vs MP) at a miniature
//! scale via the shared `util::bench::experiment_miniature` runner
//! (harness = false; bench-lite). Skips gracefully without artifacts.

fn main() {
    heroes::util::bench::experiment_miniature("table1");
}
