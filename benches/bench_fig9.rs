//! End-to-end bench regenerating Fig. 9 (RNN over text) at a miniature
//! scale via the shared `util::bench::experiment_miniature` runner
//! (harness = false; bench-lite). Skips gracefully without artifacts.

fn main() {
    heroes::util::bench::experiment_miniature("fig9");
}
