//! End-to-end bench regenerating Fig. 8 (resource consumption, ResNet) at a miniature scale
//! (harness = false; bench-lite). Skips gracefully without artifacts.

use heroes::experiments::{run_experiment, ExpCtx};
use heroes::runtime::{EnginePool, Manifest};
use heroes::util::bench::Bench;
use heroes::util::cli::Args;

fn main() {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("(artifacts missing — run `make artifacts`)");
        return;
    }
    let pool = EnginePool::single(Manifest::load(&dir).unwrap()).unwrap();
    // miniature world: a few clients, a few rounds — the bench measures
    // the harness end-to-end, the real figures come from `heroes exp`.
    let args = Args::parse_from(
        ["--clients", "6", "--k", "3", "--rounds", "6", "--eval-every", "3",
         "--samples-per-client", "24", "--test-samples", "64"]
            .iter().map(|s| s.to_string()),
    );
    let ctx = ExpCtx {
        pool: &pool,
        scale: heroes::config::Scale::Smoke,
        args,
        out_dir: std::env::temp_dir().join("heroes_bench_results"),
    };
    Bench::quick().run_once("fig8 (miniature)", || {
        run_experiment("fig8", &ctx).unwrap();
    });
}
