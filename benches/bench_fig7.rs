//! End-to-end bench regenerating Fig. 7a (Non-IID sweep, CNN) at a miniature
//! scale via the shared `util::bench::experiment_miniature` runner
//! (harness = false; bench-lite). Skips gracefully without artifacts.

fn main() {
    heroes::util::bench::experiment_miniature("fig7a");
}
