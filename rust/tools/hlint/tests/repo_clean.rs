//! The `--deny` CI gate as a plain cargo test: the checked-in
//! `rust/src/**` tree must carry zero unsuppressed hlint findings.
//! Every `hlint::allow` in the tree must be well-formed (reason
//! required) — a malformed one surfaces here as `bad_suppression`.

// test-only assertions; failure output beats typed errors here
#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::path::{Path, PathBuf};

use hlint::{lint_source, Finding, RULE_NAMES};

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap_or_else(|e| panic!("read_dir {}: {e}", dir.display()))
        .map(|e| e.expect("dir entry").path())
        .collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            collect_rs(&p, out);
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
}

#[test]
fn tree_has_zero_unsuppressed_findings() {
    let src_root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../src");
    let src_root = src_root.canonicalize().expect("rust/src exists");
    let mut files = Vec::new();
    collect_rs(&src_root, &mut files);
    assert!(!files.is_empty(), "no sources under {}", src_root.display());

    let mut active: Vec<Finding> = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(&src_root)
            .expect("walked from src_root")
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        let src = std::fs::read_to_string(path).expect("readable source");
        active.extend(lint_source(&rel, &src, &RULE_NAMES).active);
    }
    assert!(
        active.is_empty(),
        "unsuppressed hlint findings:\n{}",
        active
            .iter()
            .map(|f| format!("  rust/src/{}:{}: [{}] {}", f.file, f.line, f.rule, f.message))
            .collect::<Vec<_>>()
            .join("\n")
    );
}
