// fixture: D2 bad — shared-cursor Rng field and parameter
use crate::util::rng::Rng;

pub struct Sampler {
    rng: Rng,
}

impl Sampler {
    pub fn draw(&mut self, rng: &mut Rng) -> f64 {
        rng.uniform()
    }
}
