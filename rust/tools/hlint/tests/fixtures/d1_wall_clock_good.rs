// fixture: D1 good — schedule facts come from the virtual clock
pub fn stamp(sim_time: f64, dt: f64) -> f64 {
    sim_time + dt
}
