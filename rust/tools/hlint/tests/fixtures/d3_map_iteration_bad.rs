// fixture: D3 bad — iterating a HashMap on a deterministic path
use std::collections::HashMap;

pub fn sum_all(m: &HashMap<usize, u64>) -> u64 {
    let mut total = 0;
    for (_k, v) in m.iter() {
        total += *v;
    }
    total
}
