// fixture: reason-less suppression — the allow must be rejected (the
// finding stays active AND a bad_suppression finding is raised)
pub fn first(v: &[f64]) -> f64 {
    v[0] // hlint::allow(panic_path)
}
