// fixture: well-formed suppressions — a trailing line allow, an
// own-line allow, and an item-scoped allow; all carry reasons
pub fn first(v: &[f64]) -> f64 {
    v[0] // hlint::allow(panic_path): fixture pin — caller guarantees non-empty
}

pub fn second(v: &[f64]) -> f64 {
    // hlint::allow(panic_path): fixture pin — caller guarantees len >= 2
    v[1]
}

// hlint::allow(panic_path, item): dense kernel, loop-bounded indices
pub fn sum(v: &[f64]) -> f64 {
    let mut t = 0.0;
    for i in 0..v.len() {
        t += v[i];
    }
    t
}
