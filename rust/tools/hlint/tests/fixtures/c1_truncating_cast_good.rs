// fixture: C1 good — widening is legal, the audited exits are
// util::cast::{bytes_to_f64, bytes_to_usize}, and u64-wide counter
// declarations are exactly the contract
use crate::util::cast::{bytes_to_f64, bytes_to_usize};

pub struct Meta {
    pub up_bytes: u64,
    pub wan_up_bytes: Option<u64>,
    /// not a byte counter — free to stay usize
    pub widths: Vec<usize>,
}

pub fn gb(frame_len: usize, total_bytes: u64) -> (u64, f64, usize) {
    (frame_len as u64, bytes_to_f64(total_bytes) / 1e9, bytes_to_usize(total_bytes))
}
