// fixture: C1 good — widening is legal, and the audited f64 exit is
// util::cast::bytes_to_f64
use crate::util::cast::bytes_to_f64;

pub fn gb(frame_len: usize, total_bytes: u64) -> (u64, f64) {
    (frame_len as u64, bytes_to_f64(total_bytes) / 1e9)
}
