// fixture: D3 good — BTreeMap iteration is ordered; HashMap get/insert
// stays legal
use std::collections::{BTreeMap, HashMap};

pub fn sum_all(m: &BTreeMap<usize, u64>) -> u64 {
    m.values().sum()
}

pub fn bump(m: &mut HashMap<usize, u64>, k: usize) -> u64 {
    let v = m.get(&k).copied().unwrap_or(0) + 1;
    m.insert(k, v);
    v
}
