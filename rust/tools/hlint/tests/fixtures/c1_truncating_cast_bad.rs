// fixture: C1 bad — byte counters narrowed through lossy casts
pub fn gb(total_bytes: u64, traffic_up: u64) -> (f64, usize) {
    (total_bytes as f64 / 1e9, traffic_up as usize)
}
