// fixture: C1 bad — byte counters narrowed through lossy casts
pub fn gb(total_bytes: u64, traffic_up: u64) -> (f64, usize) {
    (total_bytes as f64 / 1e9, traffic_up as usize)
}

// ... and byte counters *declared* narrow: the counter truncates on a
// 32-bit target before any cast is visible (struct fields, params and
// container generics alike)
pub struct Meta {
    pub up_bytes: usize,
    pub wan_up_bytes: Option<u32>,
    pub bytes: Vec<usize>,
}
