// fixture: P1 bad — unwrap, panic macro and slice-index in non-test
// code; the #[cfg(test)] module at the bottom must NOT be flagged
pub fn first(v: &[f64]) -> f64 {
    v[0]
}

pub fn must(o: Option<u32>) -> u32 {
    assert!(o.is_some());
    o.unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_here() {
        let v = vec![1.0f64];
        assert_eq!(v[0], Some(1.0f64).unwrap());
    }
}
