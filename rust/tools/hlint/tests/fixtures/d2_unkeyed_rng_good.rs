// fixture: D2 good — per-event keyed RNG, derived and dropped in place
use crate::util::rng::Rng;

pub fn draw(seed: u64, round: u64, client: u64) -> f64 {
    let mut rng = Rng::new(seed ^ (round << 20) ^ client);
    rng.uniform()
}
