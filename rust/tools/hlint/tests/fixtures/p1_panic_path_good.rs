// fixture: P1 good — typed errors instead of panics
use anyhow::{anyhow, Result};

pub fn first(v: &[f64]) -> Result<f64> {
    v.first().copied().ok_or_else(|| anyhow!("empty slice"))
}

pub fn must(o: Option<u32>) -> Result<u32> {
    o.ok_or_else(|| anyhow!("missing value"))
}
