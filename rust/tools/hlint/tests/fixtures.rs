//! hlint self-test over the fixture corpus: every bad fixture triggers
//! exactly its rule, every good fixture is clean, the `hlint::allow`
//! grammar round-trips (line, next-line and item scopes), and a
//! reason-less allow is rejected.
//!
//! Fixtures are linted under *virtual* paths (e.g.
//! `coordinator/fixture.rs`) so the directory-scoped rules fire without
//! the snippets living in the real tree; the files under
//! `tests/fixtures/` are data, not compile targets.

// test-only assertions; failure output beats typed errors here
#![allow(clippy::unwrap_used, clippy::expect_used)]

use hlint::{lint_source, Finding, LintOutcome, BAD_SUPPRESSION, RULE_NAMES};

fn read_fixture(name: &str) -> String {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
}

fn lint(name: &str, vpath: &str) -> LintOutcome {
    lint_source(vpath, &read_fixture(name), &RULE_NAMES)
}

fn rules_of(fs: &[Finding]) -> Vec<&'static str> {
    fs.iter().map(|f| f.rule).collect()
}

#[test]
fn bad_fixtures_trigger_exactly_their_rule() {
    let cases = [
        ("d1_wall_clock_bad.rs", "metrics/fixture.rs", "wall_clock", 2),
        ("d2_unkeyed_rng_bad.rs", "simulation/fixture.rs", "unkeyed_rng", 2),
        ("d3_map_iteration_bad.rs", "coordinator/fixture.rs", "map_iteration", 1),
        ("p1_panic_path_bad.rs", "coordinator/fixture.rs", "panic_path", 3),
        ("c1_truncating_cast_bad.rs", "metrics/fixture.rs", "truncating_cast", 5),
    ];
    for (file, vpath, rule, count) in cases {
        let out = lint(file, vpath);
        assert!(out.suppressed.is_empty(), "{file}: unexpected suppressions");
        assert_eq!(out.active.len(), count, "{file}: {:?}", rules_of(&out.active));
        for f in &out.active {
            assert_eq!(f.rule, rule, "{file}: stray finding {f:?}");
            assert_eq!(f.file, vpath, "{file}: finding must carry its span");
            assert!(f.line > 0, "{file}: finding must carry its span");
        }
    }
}

#[test]
fn good_fixtures_are_clean() {
    let cases = [
        ("d1_wall_clock_good.rs", "metrics/fixture.rs"),
        ("d2_unkeyed_rng_good.rs", "simulation/fixture.rs"),
        ("d3_map_iteration_good.rs", "coordinator/fixture.rs"),
        ("p1_panic_path_good.rs", "coordinator/fixture.rs"),
        ("c1_truncating_cast_good.rs", "metrics/fixture.rs"),
    ];
    for (file, vpath) in cases {
        let out = lint(file, vpath);
        assert!(out.active.is_empty(), "{file}: {:?}", out.active);
        assert!(out.suppressed.is_empty(), "{file}: {:?}", out.suppressed);
    }
}

#[test]
fn rule_selection_gates_the_pass() {
    // the D1 bad fixture is clean when only C1 runs
    let src = read_fixture("d1_wall_clock_bad.rs");
    let out = lint_source("metrics/fixture.rs", &src, &["truncating_cast"]);
    assert!(out.active.is_empty(), "{:?}", out.active);
}

#[test]
fn directory_scoping_gates_the_pass() {
    // the same panic-path source is legal outside the enforced dirs
    let src = read_fixture("p1_panic_path_bad.rs");
    let out = lint_source("util/fixture.rs", &src, &RULE_NAMES);
    assert!(out.active.is_empty(), "{:?}", out.active);
}

#[test]
fn wall_clock_allow_zone_covers_the_tcp_transport() {
    // transport/tcp.rs may read the wall clock (socket timeouts are real
    // time by definition); the same source stays flagged elsewhere
    let src = read_fixture("d1_wall_clock_bad.rs");
    let out = lint_source("transport/tcp.rs", &src, &RULE_NAMES);
    assert!(out.active.is_empty(), "{:?}", out.active);
    let out = lint_source("transport/sim.rs", &src, &RULE_NAMES);
    assert_eq!(out.active.len(), 2, "{:?}", out.active);
}

#[test]
fn suppression_round_trip() {
    // trailing-line, next-line and item scopes each silence their finding
    let out = lint("suppress_ok.rs", "coordinator/fixture.rs");
    assert!(out.active.is_empty(), "{:?}", out.active);
    assert_eq!(out.suppressed.len(), 3, "{:?}", rules_of(&out.suppressed));
    assert!(out.suppressed.iter().all(|f| f.rule == "panic_path"));
}

#[test]
fn missing_reason_suppression_rejected() {
    let out = lint("suppress_missing_reason.rs", "coordinator/fixture.rs");
    assert!(out.suppressed.is_empty(), "a reason-less allow must not suppress");
    let rules = rules_of(&out.active);
    assert!(rules.contains(&"panic_path"), "{rules:?}");
    assert!(rules.contains(&BAD_SUPPRESSION), "{rules:?}");
}

#[test]
fn unknown_rule_and_bad_scope_rejected() {
    let src = "pub fn f(v: &[f64]) -> f64 {\n    v[0] // hlint::allow(no_such_rule): reason\n}\n";
    let out = lint_source("coordinator/fixture.rs", src, &RULE_NAMES);
    assert!(rules_of(&out.active).contains(&BAD_SUPPRESSION), "{:?}", out.active);

    let src = "pub fn f(v: &[f64]) -> f64 {\n    v[0] // hlint::allow(panic_path, fn): reason\n}\n";
    let out = lint_source("coordinator/fixture.rs", src, &RULE_NAMES);
    assert!(rules_of(&out.active).contains(&BAD_SUPPRESSION), "{:?}", out.active);
    // the rejected allow must not silence the real finding either
    assert!(rules_of(&out.active).contains(&"panic_path"), "{:?}", out.active);
}

#[test]
fn allow_only_covers_its_rule() {
    // a panic_path allow does not silence a truncating_cast on the line
    let src = "pub fn f(total_bytes: u64) -> f64 {\n    total_bytes as f64 // hlint::allow(panic_path): wrong rule\n}\n";
    let out = lint_source("coordinator/fixture.rs", src, &RULE_NAMES);
    assert!(rules_of(&out.active).contains(&"truncating_cast"), "{:?}", out.active);
    assert!(out.suppressed.is_empty());
}
