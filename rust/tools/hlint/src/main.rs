//! hlint CLI: walk `rust/src/**`, apply the rule set, report findings.
//!
//! ```text
//! cargo run -p hlint -- [--deny] [--json] [--rule NAME]... [--root DIR]
//! ```
//!
//! `--deny` exits 1 when any unsuppressed finding remains (the CI
//! gate); `--json` emits a machine-readable findings object on stdout;
//! `--rule` restricts the pass to the named rule(s) (repeatable;
//! default: all). `--root` points at the repo root (default: walk up
//! from the current directory until `rust/src` is found).

use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use hlint::{canonical_rule, lint_source, Finding, RULE_NAMES};

struct Opts {
    deny: bool,
    json: bool,
    rules: Vec<&'static str>,
    root: Option<PathBuf>,
}

fn usage() -> &'static str {
    "usage: hlint [--deny] [--json] [--rule NAME]... [--root DIR]\n\
     rules: wall_clock unkeyed_rng map_iteration panic_path truncating_cast"
}

fn parse_opts() -> Result<Opts, String> {
    let mut opts = Opts { deny: false, json: false, rules: Vec::new(), root: None };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--deny" => opts.deny = true,
            "--json" => opts.json = true,
            "--rule" => {
                let name = args.next().ok_or("--rule needs a rule name")?;
                let rule = canonical_rule(&name)
                    .ok_or_else(|| format!("unknown rule `{name}`\n{}", usage()))?;
                if !opts.rules.contains(&rule) {
                    opts.rules.push(rule);
                }
            }
            "--root" => {
                let dir = args.next().ok_or("--root needs a directory")?;
                opts.root = Some(PathBuf::from(dir));
            }
            "-h" | "--help" => return Err(usage().to_string()),
            other => return Err(format!("unknown argument `{other}`\n{}", usage())),
        }
    }
    if opts.rules.is_empty() {
        opts.rules = RULE_NAMES.to_vec();
    }
    Ok(opts)
}

/// Locate `<repo>/rust/src`: `--root` wins, otherwise walk up from cwd.
fn find_src_root(opts: &Opts) -> Result<PathBuf, String> {
    if let Some(root) = &opts.root {
        let candidate = root.join("rust").join("src");
        if candidate.is_dir() {
            return Ok(candidate);
        }
        if root.is_dir() {
            return Ok(root.clone());
        }
        return Err(format!("--root `{}` is not a directory", root.display()));
    }
    let mut dir = std::env::current_dir().map_err(|e| format!("cwd: {e}"))?;
    loop {
        let candidate = dir.join("rust").join("src");
        if candidate.is_dir() {
            return Ok(candidate);
        }
        if !dir.pop() {
            return Err("no rust/src found walking up from the current directory; pass --root".to_string());
        }
    }
}

/// Deterministic (sorted) recursive walk collecting `.rs` files.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries = std::fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    let mut paths: Vec<PathBuf> = Vec::new();
    for entry in entries {
        let entry = entry.map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        paths.push(entry.path());
    }
    paths.sort();
    for p in paths {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn emit_json(root: &Path, rules: &[&str], active: &[Finding], suppressed: usize) {
    let mut s = String::new();
    s.push_str("{\n");
    let _ = writeln!(s, "  \"root\": \"{}\",", json_escape(&root.display().to_string()));
    let rule_list: Vec<String> = rules.iter().map(|r| format!("\"{r}\"")).collect();
    let _ = writeln!(s, "  \"rules\": [{}],", rule_list.join(", "));
    let _ = writeln!(s, "  \"suppressed\": {suppressed},");
    s.push_str("  \"findings\": [\n");
    for (i, f) in active.iter().enumerate() {
        let sep = if i + 1 == active.len() { "" } else { "," };
        let _ = writeln!(
            s,
            "    {{\"file\": \"rust/src/{}\", \"line\": {}, \"rule\": \"{}\", \"message\": \"{}\"}}{}",
            json_escape(&f.file),
            f.line,
            f.rule,
            json_escape(&f.message),
            sep
        );
    }
    s.push_str("  ]\n}");
    println!("{s}");
}

fn run() -> Result<ExitCode, String> {
    let opts = parse_opts()?;
    let src_root = find_src_root(&opts)?;

    let mut files = Vec::new();
    collect_rs(&src_root, &mut files)?;

    let mut active: Vec<Finding> = Vec::new();
    let mut suppressed = 0usize;
    for path in &files {
        let rel = path
            .strip_prefix(&src_root)
            .map_err(|e| format!("strip_prefix: {e}"))?
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let outcome = lint_source(&rel, &src, &opts.rules);
        suppressed += outcome.suppressed.len();
        active.extend(outcome.active);
    }
    active.sort_by(|a, b| (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule)));

    if opts.json {
        emit_json(&src_root, &opts.rules, &active, suppressed);
    } else {
        for f in &active {
            println!("rust/src/{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
        }
    }
    eprintln!(
        "hlint: {} finding(s) ({} suppressed) across {} file(s)",
        active.len(),
        suppressed,
        files.len()
    );

    if opts.deny && !active.is_empty() {
        return Ok(ExitCode::from(1));
    }
    Ok(ExitCode::SUCCESS)
}

fn main() -> ExitCode {
    match run() {
        Ok(code) => code,
        Err(msg) => {
            eprintln!("{msg}");
            ExitCode::from(2)
        }
    }
}
