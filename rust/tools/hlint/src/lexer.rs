//! A minimal Rust lexer — just enough structure for token-level lint
//! rules.
//!
//! This is deliberately *not* a full Rust lexer (and deliberately not
//! `syn`: the linter must build with zero dependencies in offline /
//! vendored environments). It classifies identifiers, single-character
//! punctuation, literals and lifetimes, tracks line numbers, and pulls
//! comments out of band so the suppression engine can see
//! `// hlint::allow(...)` markers. The only hard requirements are that
//! quotes inside strings / chars / raw strings never open a literal,
//! that nested block comments terminate, and that line numbers are
//! right — everything else (float vs. int, keyword vs. ident) is left
//! to the rules, which work on token *shape*, not semantics.

/// Token classification. Multi-character operators (`::`, `->`, `=>`)
/// are emitted as consecutive single-character [`TokKind::Punct`]
/// tokens; rules that care look at neighbors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Punct,
    Lit,
    Lifetime,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

#[derive(Debug, Clone)]
pub struct Comment {
    pub line: u32,
    /// Comment body without the `//` / `/* */` delimiters.
    pub text: String,
    /// True when nothing but whitespace precedes the comment on its
    /// line: an own-line `hlint::allow` scopes to the *next* code line
    /// (or item), a trailing one to its own line.
    pub own_line: bool,
}

/// Lex `src` into code tokens plus an out-of-band comment list.
pub fn lex(src: &str) -> (Vec<Tok>, Vec<Comment>) {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut line_has_code = false;

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            line_has_code = false;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        // line comment (incl. `///` and `//!` doc comments)
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && b[j] != '\n' {
                j += 1;
            }
            comments.push(Comment {
                line,
                text: b[start..j].iter().collect(),
                own_line: !line_has_code,
            });
            i = j;
            continue;
        }
        // block comment, nesting honored
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let start_line = line;
            let text_start = i + 2;
            let mut depth = 1u32;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if b[j] == '/' && j + 1 < n && b[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == '*' && j + 1 < n && b[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            let text_end = if depth == 0 { j.saturating_sub(2) } else { j };
            comments.push(Comment {
                line: start_line,
                text: b[text_start..text_end.max(text_start)].iter().collect(),
                own_line: !line_has_code,
            });
            i = j;
            continue;
        }
        line_has_code = true;
        // raw string: r"..." / r#"..."# / r##"..."## ...
        if c == 'r' && i + 1 < n && (b[i + 1] == '"' || b[i + 1] == '#') {
            let mut j = i + 1;
            let mut hashes = 0usize;
            while j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == '"' {
                let mut k = j + 1;
                while k < n {
                    if b[k] == '\n' {
                        line += 1;
                        k += 1;
                        continue;
                    }
                    if b[k] == '"' {
                        let mut h = 0usize;
                        while h < hashes && k + 1 + h < n && b[k + 1 + h] == '#' {
                            h += 1;
                        }
                        if h == hashes {
                            k += 1 + hashes;
                            break;
                        }
                    }
                    k += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Lit,
                    text: String::from("r\"..\""),
                    line,
                });
                i = k;
                continue;
            }
            // `r` not followed by a raw string: fall through as an ident
        }
        if c == '"' {
            let mut j = i + 1;
            while j < n {
                if b[j] == '\\' {
                    j += 2;
                    continue;
                }
                if b[j] == '"' {
                    break;
                }
                if b[j] == '\n' {
                    line += 1;
                }
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Lit,
                text: String::from("\"..\""),
                line,
            });
            i = if j < n { j + 1 } else { n };
            continue;
        }
        if c == '\'' {
            // `'a'` is a char literal; `'a` / `'static` is a lifetime.
            if i + 1 < n && (b[i + 1].is_alphabetic() || b[i + 1] == '_') {
                let mut j = i + 1;
                while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                    j += 1;
                }
                if j < n && b[j] == '\'' && j == i + 2 {
                    toks.push(Tok {
                        kind: TokKind::Lit,
                        text: b[i..=j].iter().collect(),
                        line,
                    });
                    i = j + 1;
                    continue;
                }
                toks.push(Tok {
                    kind: TokKind::Lifetime,
                    text: b[i..j].iter().collect(),
                    line,
                });
                i = j;
                continue;
            }
            // escaped or symbolic char literal: scan to the closing '
            let mut j = i + 1;
            while j < n {
                if b[j] == '\\' {
                    j += 2;
                    continue;
                }
                if b[j] == '\'' {
                    break;
                }
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Lit,
                text: String::from("'..'"),
                line,
            });
            i = if j < n { j + 1 } else { n };
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let mut j = i;
            while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Ident,
                text: b[i..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n && (b[j].is_alphanumeric() || b[j] == '.' || b[j] == '_') {
                // `1.0` continues the literal; `1.max(..)` / `0..n` do not
                if b[j] == '.' && (j + 1 >= n || !b[j + 1].is_ascii_digit()) {
                    break;
                }
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Lit,
                text: b[i..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        toks.push(Tok {
            kind: TokKind::Punct,
            text: c.to_string(),
            line,
        });
        i += 1;
    }

    (toks, comments)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(toks: &[Tok]) -> Vec<&str> {
        toks.iter().map(|t| t.text.as_str()).collect()
    }

    #[test]
    fn idents_puncts_and_lines() {
        let (toks, _) = lex("fn f() {\n    x.y\n}\n");
        assert_eq!(texts(&toks), ["fn", "f", "(", ")", "{", "x", ".", "y", "}"]);
        assert_eq!(toks[5].line, 2); // `x`
        assert_eq!(toks[8].line, 3); // `}`
    }

    #[test]
    fn strings_do_not_leak_tokens() {
        let (toks, _) = lex(r#"let s = "a.unwrap() [0]"; s"#);
        // no `unwrap` ident token may come out of the string literal
        assert!(toks.iter().all(|t| t.text != "unwrap"));
        assert_eq!(toks.last().map(|t| t.text.as_str()), Some("s"));
    }

    #[test]
    fn raw_string_with_hashes() {
        let (toks, _) = lex(r###"let s = r#"quote " inside"#; done"###);
        assert_eq!(toks.last().map(|t| t.text.as_str()), Some("done"));
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let (toks, _) = lex("let c = 'x'; fn f<'a>(v: &'a str) {}");
        assert!(toks.iter().any(|t| t.kind == TokKind::Lit && t.text == "'x'"));
        assert!(toks.iter().any(|t| t.kind == TokKind::Lifetime && t.text == "'a"));
    }

    #[test]
    fn comments_out_of_band() {
        let (toks, comments) = lex("x; // trailing note\n// own line\ny;\n");
        assert_eq!(texts(&toks), ["x", ";", "y", ";"]);
        assert_eq!(comments.len(), 2);
        assert!(!comments[0].own_line);
        assert_eq!(comments[0].text.trim(), "trailing note");
        assert!(comments[1].own_line);
        assert_eq!(comments[1].line, 2);
    }

    #[test]
    fn nested_block_comment_terminates() {
        let (toks, comments) = lex("/* a /* b */ c */ z");
        assert_eq!(texts(&toks), ["z"]);
        assert_eq!(comments.len(), 1);
    }

    #[test]
    fn numeric_literal_method_call() {
        let (toks, _) = lex("let x = 1.max(2) + 3.5;");
        assert!(toks.iter().any(|t| t.text == "max"));
        assert!(toks.iter().any(|t| t.kind == TokKind::Lit && t.text == "3.5"));
    }
}
