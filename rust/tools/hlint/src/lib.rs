//! hlint — the Heroes repo's determinism & error-handling analyzer.
//!
//! Mechanizes the invariants every PR since PR 1 has enforced by
//! review: runs are pure functions of `(seed, cfg)` — no wall-clock
//! reads, no shared-cursor RNGs, no hash-order iteration on
//! deterministic paths — and failures surface as typed `Err`s, never
//! panics; byte counters never narrow through lossy casts. See
//! CONTRIBUTING.md for the rule table and the `hlint::allow`
//! suppression grammar, and `src/rules.rs` for the rule semantics.
//!
//! The library entry point is [`lint_source`], which takes a *virtual*
//! path (relative to `rust/src/`) so the fixture suite can exercise
//! rule scoping without touching the real tree. The binary
//! (`cargo run -p hlint -- --deny`) walks `rust/src/**` and applies it
//! per file.

pub mod lexer;
pub mod rules;

pub use rules::{canonical_rule, lint_source, Finding, LintOutcome, BAD_SUPPRESSION, RULE_NAMES};
