//! The five repo invariants as token-level rules, plus the
//! `hlint::allow` suppression engine.
//!
//! Paths are *virtual*: rules scope on the path **relative to
//! `rust/src/`** (e.g. `coordinator/round.rs`), so the fixture suite
//! can lint snippets under any directory it wants to exercise. Rules
//! are heuristic by design — they work on token shape, not on resolved
//! types — and the contract (see CONTRIBUTING.md) is: a false positive
//! gets a reasoned `hlint::allow`, a false negative gets a sharper
//! rule, and the tree stays at zero unsuppressed findings.

use crate::lexer::{lex, Tok, TokKind};

/// The user-selectable rules, in reporting order.
pub const RULE_NAMES: [&str; 5] = [
    "wall_clock",      // D1
    "unkeyed_rng",     // D2
    "map_iteration",   // D3
    "panic_path",      // P1
    "truncating_cast", // C1
];

/// Internal rule for malformed / reason-less `hlint::allow` markers.
/// Always on, never suppressible.
pub const BAD_SUPPRESSION: &str = "bad_suppression";

/// D1: files (relative to `rust/src/`) that may read the wall clock.
/// `transport/tcp.rs` is in the zone because socket timeouts are real
/// time by definition — the determinism contract survives because wall
/// time there only decides *whether* a fate arrives (Dropped/Faulted on
/// timeout), never any value the virtual clock or the planner consumes.
const WALL_CLOCK_ALLOWLIST: [&str; 4] =
    ["runtime/engine.rs", "util/bench.rs", "util/logging.rs", "transport/tcp.rs"];

const D2_DIRS: [&str; 2] = ["simulation", "coordinator"];
const D3_DIRS: [&str; 5] = ["coordinator", "simulation", "codec", "metrics", "transport"];
const P1_DIRS: [&str; 5] = ["coordinator", "codec", "simulation", "runtime", "transport"];

const PANIC_MACROS: [&str; 7] = [
    "panic",
    "unreachable",
    "todo",
    "unimplemented",
    "assert",
    "assert_eq",
    "assert_ne",
];
const ITER_METHODS: [&str; 9] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "into_iter",
    "drain",
    "into_keys",
    "into_values",
];
const CAST_TARGETS: [&str; 3] = ["usize", "u32", "f64"];
/// Keywords that can directly precede `[` without forming an index
/// expression (`as [T; 2]` cannot, but being conservative here only
/// costs false negatives on exotic code, never false positives).
const NON_INDEX_PRECEDERS: [&str; 13] = [
    "mut", "in", "as", "return", "else", "match", "if", "box", "dyn", "impl", "where", "for",
    "let",
];

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Virtual path the source was linted under (relative to `rust/src/`).
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
}

#[derive(Debug, Clone, Default)]
pub struct LintOutcome {
    /// Findings that survived suppression — these fail `--deny`.
    pub active: Vec<Finding>,
    /// Findings silenced by a well-formed `hlint::allow`.
    pub suppressed: Vec<Finding>,
}

/// Map a user-supplied rule name onto its canonical `&'static str`.
pub fn canonical_rule(name: &str) -> Option<&'static str> {
    RULE_NAMES.iter().copied().find(|r| *r == name)
}

fn enabled(rules: &[&'static str], name: &str) -> bool {
    rules.iter().any(|r| *r == name)
}

fn in_dirs(rel: &str, dirs: &[&str]) -> bool {
    dirs.iter().any(|d| rel.starts_with(&format!("{d}/")))
}

/// Inclusive line ranges covered by `#[test]` / `#[cfg(test)]` items.
fn test_ranges(toks: &[Tok]) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        let is_attr_open = toks[i].text == "#"
            && toks.get(i + 1).map(|t| t.text.as_str()) == Some("[");
        if !is_attr_open {
            i += 1;
            continue;
        }
        // collect every ident inside the (possibly nested) attribute
        let mut j = i + 2;
        let mut depth = 1u32;
        let mut words: Vec<&str> = Vec::new();
        while j < toks.len() && depth > 0 {
            match toks[j].text.as_str() {
                "[" => depth += 1,
                "]" => depth -= 1,
                _ => {
                    if toks[j].kind == TokKind::Ident {
                        words.push(toks[j].text.as_str());
                    }
                }
            }
            j += 1;
        }
        let is_test = words.iter().any(|w| *w == "test") && !words.iter().any(|w| *w == "not");
        if !is_test {
            i = j;
            continue;
        }
        // skip any further attributes / signature up to the item body,
        // then cover the brace-matched block (or a `;`-terminated item)
        let mut m = j;
        while m < toks.len() && toks[m].text != "{" && toks[m].text != ";" {
            m += 1;
        }
        if m < toks.len() && toks[m].text == "{" {
            let mut d = 1u32;
            let mut p = m + 1;
            while p < toks.len() && d > 0 {
                match toks[p].text.as_str() {
                    "{" => d += 1,
                    "}" => d -= 1,
                    _ => {}
                }
                p += 1;
            }
            let end_line = toks
                .get(p.saturating_sub(1))
                .map(|t| t.line)
                .unwrap_or(toks[i].line);
            out.push((toks[i].line, end_line));
            i = p;
        } else {
            i = m.saturating_add(1);
        }
    }
    out
}

fn in_test(ranges: &[(u32, u32)], line: u32) -> bool {
    ranges.iter().any(|&(a, b)| a <= line && line <= b)
}

// ---------------------------------------------------------------- rules

/// D1 — wall-clock reads (`Instant`, `SystemTime`) outside the
/// allowlisted timing/logging modules.
fn rule_wall_clock(rel: &str, toks: &[Tok], tests: &[(u32, u32)], out: &mut Vec<Finding>) {
    if WALL_CLOCK_ALLOWLIST.contains(&rel) {
        return;
    }
    for t in toks {
        if t.kind == TokKind::Ident
            && (t.text == "Instant" || t.text == "SystemTime")
            && !in_test(tests, t.line)
        {
            out.push(Finding {
                file: rel.to_string(),
                line: t.line,
                rule: "wall_clock",
                message: format!(
                    "`{}` outside the wall-clock allowlist — schedule facts must come from the virtual clock",
                    t.text
                ),
            });
        }
    }
}

/// D2 — shared-cursor `Rng` bindings (fields / params typed `Rng`) in
/// `simulation/` and `coordinator/`. A `: Rng` type ascription is the
/// smell; `Rng::new(key)` path expressions (per-event keyed
/// construction) are exactly the sanctioned alternative and are not
/// flagged.
fn rule_unkeyed_rng(rel: &str, toks: &[Tok], tests: &[(u32, u32)], out: &mut Vec<Finding>) {
    if !in_dirs(rel, &D2_DIRS) {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident || t.text != "Rng" || in_test(tests, t.line) {
            continue;
        }
        // `Rng::...` is a path expression, not a type ascription
        if toks.get(i + 1).map(|t| t.text.as_str()) == Some(":")
            && toks.get(i + 2).map(|t| t.text.as_str()) == Some(":")
        {
            continue;
        }
        // walk back over `&`, `mut` and lifetimes to the ascription colon
        let mut j = i;
        while j > 0 {
            let prev = &toks[j - 1];
            if prev.text == "&" || prev.text == "mut" || prev.kind == TokKind::Lifetime {
                j -= 1;
            } else {
                break;
            }
        }
        if j == 0 {
            continue;
        }
        let colon = toks[j - 1].text == ":";
        let path_sep = j >= 2 && toks[j - 2].text == ":";
        if colon && !path_sep {
            out.push(Finding {
                file: rel.to_string(),
                line: t.line,
                rule: "unkeyed_rng",
                message: "shared-cursor `Rng` binding (field/param) — derive a per-event keyed RNG instead".to_string(),
            });
        }
    }
}

/// D3 — iteration over `HashMap` / `HashSet` bindings in deterministic
/// modules. Tracks idents ascribed or assigned a hash collection, then
/// flags order-dependent method calls (`iter`, `keys`, `drain`, ...)
/// and `for .. in` loops over them. `get` / `insert` / `contains_key`
/// stay legal. Receiver matching covers `x.iter()` and `self.x.iter()`;
/// a field of some *other* struct (`plan.x.iter()`) is out of scope —
/// that binding is tracked where it is declared.
fn rule_map_iteration(rel: &str, toks: &[Tok], tests: &[(u32, u32)], out: &mut Vec<Finding>) {
    if !in_dirs(rel, &D3_DIRS) {
        return;
    }
    let mut tracked: Vec<&str> = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident || (t.text != "HashMap" && t.text != "HashSet") {
            continue;
        }
        let mut j = i;
        while j > 0 && (toks[j - 1].text == "&" || toks[j - 1].text == "mut") {
            j -= 1;
        }
        if j == 0 {
            continue;
        }
        let prev = &toks[j - 1];
        // `name: HashMap<..>` ascription (field, param, or let)
        if prev.text == ":" && !(j >= 2 && toks[j - 2].text == ":") {
            if j >= 2 && toks[j - 2].kind == TokKind::Ident {
                tracked.push(toks[j - 2].text.as_str());
            }
            continue;
        }
        // `let name = HashMap::new()` / `with_capacity(..)`
        if prev.text == "=" && j >= 2 && toks[j - 2].kind == TokKind::Ident {
            tracked.push(toks[j - 2].text.as_str());
        }
    }
    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident || in_test(tests, t.line) {
            continue;
        }
        // receiver.method( where method is order-dependent
        if ITER_METHODS.contains(&t.text.as_str())
            && i >= 2
            && toks[i - 1].text == "."
            && toks.get(i + 1).map(|t| t.text.as_str()) == Some("(")
        {
            let recv = &toks[i - 2];
            if recv.kind == TokKind::Ident && tracked.contains(&recv.text.as_str()) {
                // `self.recv.method()` is ours; `other.recv.method()` is
                // a different binding that happens to share the name
                let through_field = i >= 4 && toks[i - 3].text == ".";
                let not_ours = through_field
                    && toks.get(i - 4).map(|t| t.text.as_str()) != Some("self");
                if !not_ours {
                    out.push(Finding {
                        file: rel.to_string(),
                        line: t.line,
                        rule: "map_iteration",
                        message: format!(
                            "`{}.{}()` iterates a Hash{{Map,Set}} — order is unstable; use BTreeMap or a sorted collect",
                            recv.text, t.text
                        ),
                    });
                }
            }
        }
        // `for .. in [&][mut] tracked {`
        if t.text == "in" {
            let mut j = i + 1;
            while j < toks.len() && (toks[j].text == "&" || toks[j].text == "mut") {
                j += 1;
            }
            let direct_loop = toks.get(j).map(|t| t.kind) == Some(TokKind::Ident)
                && tracked.contains(&toks[j].text.as_str())
                && toks.get(j + 1).map(|t| t.text.as_str()) == Some("{");
            if direct_loop {
                out.push(Finding {
                    file: rel.to_string(),
                    line: t.line,
                    rule: "map_iteration",
                    message: format!(
                        "for-loop over Hash{{Map,Set}} `{}` — order is unstable; use BTreeMap or a sorted collect",
                        toks[j].text
                    ),
                });
            }
        }
    }
}

/// P1 — panic paths in non-test code: `.unwrap()` / `.expect()`, panic
/// macros (`panic!`, `assert!`, `unreachable!`, ... — `debug_assert*`
/// is deliberately legal), and slice-index expressions (`x[i]` after an
/// ident, `)` or `]`; type positions like `&[f64]` don't match).
fn rule_panic_path(rel: &str, toks: &[Tok], tests: &[(u32, u32)], out: &mut Vec<Finding>) {
    if !in_dirs(rel, &P1_DIRS) {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if in_test(tests, t.line) {
            continue;
        }
        if t.kind == TokKind::Ident
            && (t.text == "unwrap" || t.text == "expect")
            && i >= 1
            && toks[i - 1].text == "."
            && toks.get(i + 1).map(|t| t.text.as_str()) == Some("(")
        {
            out.push(Finding {
                file: rel.to_string(),
                line: t.line,
                rule: "panic_path",
                message: format!("`.{}()` in non-test code — return a typed `Err` instead", t.text),
            });
        }
        if t.kind == TokKind::Ident
            && PANIC_MACROS.contains(&t.text.as_str())
            && toks.get(i + 1).map(|t| t.text.as_str()) == Some("!")
        {
            out.push(Finding {
                file: rel.to_string(),
                line: t.line,
                rule: "panic_path",
                message: format!("`{}!` in non-test code — return a typed `Err` instead", t.text),
            });
        }
        if t.text == "[" && i >= 1 {
            let prev = &toks[i - 1];
            let after_ident =
                prev.kind == TokKind::Ident && !NON_INDEX_PRECEDERS.contains(&prev.text.as_str());
            let after_close = prev.text == "]" || prev.text == ")";
            if after_ident || after_close {
                out.push(Finding {
                    file: rel.to_string(),
                    line: t.line,
                    rule: "panic_path",
                    message: "slice-index expression can panic — use `.get()` and surface a typed `Err`"
                        .to_string(),
                });
            }
        }
    }
}

/// C1 — numeric casts on byte counters (the PR 7 recorder bug class):
/// `x as usize` / `as u32` / `as f64` where the nearest preceding ident
/// (skipping one call-paren group) is `bytes`, `*_bytes` or `*traffic*`.
/// Widening to `u64` / `u128` stays legal; `util::cast::bytes_to_f64`
/// and `bytes_to_usize` are the audited exits.
///
/// Also flags *declarations* that type a byte-counter ident narrow —
/// `up_bytes: usize` struct fields, params and lets (optionally behind
/// `&`/`Vec<`/`Option<`): a counter born narrow truncates before any
/// cast is visible, which is how the PR 7 bug entered.
fn is_bytes_ident(name: &str) -> bool {
    name == "bytes" || name.ends_with("_bytes") || name.to_lowercase().contains("traffic")
}

fn rule_truncating_cast(rel: &str, toks: &[Tok], tests: &[(u32, u32)], out: &mut Vec<Finding>) {
    // narrow declarations: `bytes-ish : [&|mut|Vec|Option|<]* (usize|u32)`
    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident || !is_bytes_ident(t.text.as_str()) || in_test(tests, t.line) {
            continue;
        }
        // a single ascription colon (`name::` paths have two)
        if toks.get(i + 1).map(|t| t.text.as_str()) != Some(":")
            || toks.get(i + 2).map(|t| t.text.as_str()) == Some(":")
        {
            continue;
        }
        // hop over references and one level of container generics
        let mut j = i + 2;
        let mut hops = 0u32;
        while hops < 4 {
            match toks.get(j).map(|t| t.text.as_str()) {
                Some("&" | "mut" | "<" | "Vec" | "Option") => {
                    j += 1;
                    hops += 1;
                }
                _ => break,
            }
        }
        let Some(ty) = toks.get(j) else { continue };
        if ty.kind == TokKind::Ident && (ty.text == "usize" || ty.text == "u32") {
            out.push(Finding {
                file: rel.to_string(),
                line: t.line,
                rule: "truncating_cast",
                message: format!(
                    "byte counter `{}` declared as `{}` — counters stay u64 end to end (util::cast holds the audited exits)",
                    t.text, ty.text
                ),
            });
        }
    }
    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident || t.text != "as" || in_test(tests, t.line) {
            continue;
        }
        let Some(target) = toks.get(i + 1) else { continue };
        if target.kind != TokKind::Ident || !CAST_TARGETS.contains(&target.text.as_str()) {
            continue;
        }
        // nearest preceding ident, skipping one `( .. )` group so that
        // `total_bytes() as f64` resolves to `total_bytes`
        let mut j = i;
        if j >= 1 && toks[j - 1].text == ")" {
            let mut depth = 1u32;
            j -= 1;
            while j > 0 && depth > 0 {
                j -= 1;
                match toks[j].text.as_str() {
                    ")" => depth += 1,
                    "(" => depth -= 1,
                    _ => {}
                }
            }
        }
        if j == 0 {
            continue;
        }
        let src = &toks[j - 1];
        if src.kind != TokKind::Ident {
            continue;
        }
        let name = src.text.as_str();
        if is_bytes_ident(name) {
            out.push(Finding {
                file: rel.to_string(),
                line: t.line,
                rule: "truncating_cast",
                message: format!(
                    "`{} as {}` narrows/reshapes a byte counter — widen to u64 or go through util::cast",
                    name, target.text
                ),
            });
        }
    }
}

// ---------------------------------------------------- suppression engine

#[derive(Debug)]
struct Allow {
    rule: &'static str,
    start: u32,
    end: u32,
}

/// End line of the item whose first token is `toks[k]`: the matching
/// `}` of the first `{` (or a `;` met at depth 0 for block-less items).
fn item_end_line(toks: &[Tok], k: usize) -> u32 {
    let mut depth = 0u32;
    for t in toks.iter().skip(k) {
        match t.text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth = depth.saturating_sub(1);
                if depth == 0 {
                    return t.line;
                }
            }
            ";" if depth == 0 => return t.line,
            _ => {}
        }
    }
    toks.last().map(|t| t.line).unwrap_or(0)
}

/// Parse every `hlint::allow` marker in `comments`; return the resolved
/// allow ranges plus `bad_suppression` findings for malformed ones.
fn collect_allows(
    rel: &str,
    toks: &[Tok],
    comments: &[crate::lexer::Comment],
) -> (Vec<Allow>, Vec<Finding>) {
    let mut allows = Vec::new();
    let mut bad = Vec::new();
    let mut push_bad = |line: u32, msg: String| {
        bad.push(Finding {
            file: rel.to_string(),
            line,
            rule: BAD_SUPPRESSION,
            message: msg,
        });
    };
    for c in comments {
        let Some(pos) = c.text.find("hlint::allow") else {
            continue;
        };
        let rest = c.text[pos + "hlint::allow".len()..].trim_start();
        let Some(stripped) = rest.strip_prefix('(') else {
            push_bad(c.line, "malformed `hlint::allow` — expected `(rule[, item]): reason`".to_string());
            continue;
        };
        let Some(close) = stripped.find(')') else {
            push_bad(c.line, "malformed `hlint::allow` — unclosed `(`".to_string());
            continue;
        };
        let inside = &stripped[..close];
        let after = stripped[close + 1..].trim_start();
        let mut parts = inside.split(',').map(str::trim);
        let rule_name = parts.next().unwrap_or_default();
        let Some(rule) = canonical_rule(rule_name) else {
            push_bad(c.line, format!("`hlint::allow` names unknown rule `{rule_name}`"));
            continue;
        };
        let scope = parts.next();
        let item_scope = match scope {
            None => false,
            Some("item") => true,
            Some(other) => {
                push_bad(c.line, format!("`hlint::allow` scope must be `item`, got `{other}`"));
                continue;
            }
        };
        if parts.next().is_some() {
            push_bad(c.line, "`hlint::allow` takes at most `(rule, item)`".to_string());
            continue;
        }
        let Some(reason) = after.strip_prefix(':') else {
            push_bad(
                c.line,
                format!("`hlint::allow({rule_name})` without a reason — write `: <why this is sound>`"),
            );
            continue;
        };
        if reason.trim().is_empty() {
            push_bad(
                c.line,
                format!("`hlint::allow({rule_name})` with an empty reason — write `: <why this is sound>`"),
            );
            continue;
        }
        if !c.own_line {
            // trailing comment: suppress its own line
            allows.push(Allow { rule, start: c.line, end: c.line });
            continue;
        }
        // own-line comment: suppress the next code line (or whole item)
        let Some(k) = toks.iter().position(|t| t.line > c.line) else {
            push_bad(c.line, "`hlint::allow` with no following code".to_string());
            continue;
        };
        let start = toks[k].line;
        let end = if item_scope { item_end_line(toks, k).max(start) } else { start };
        allows.push(Allow { rule, start, end });
    }
    (allows, bad)
}

// ----------------------------------------------------------- entry point

/// Lint one source file under a virtual path (relative to `rust/src/`).
///
/// `rules` holds canonical rule names (see [`canonical_rule`]); pass
/// `&RULE_NAMES` for everything. `bad_suppression` findings are always
/// produced and never suppressible.
pub fn lint_source(virtual_path: &str, src: &str, rules: &[&'static str]) -> LintOutcome {
    let rel = virtual_path.replace('\\', "/");
    let (toks, comments) = lex(src);
    let tests = test_ranges(&toks);

    let mut raw: Vec<Finding> = Vec::new();
    if enabled(rules, "wall_clock") {
        rule_wall_clock(&rel, &toks, &tests, &mut raw);
    }
    if enabled(rules, "unkeyed_rng") {
        rule_unkeyed_rng(&rel, &toks, &tests, &mut raw);
    }
    if enabled(rules, "map_iteration") {
        rule_map_iteration(&rel, &toks, &tests, &mut raw);
    }
    if enabled(rules, "panic_path") {
        rule_panic_path(&rel, &toks, &tests, &mut raw);
    }
    if enabled(rules, "truncating_cast") {
        rule_truncating_cast(&rel, &toks, &tests, &mut raw);
    }

    let (allows, bad) = collect_allows(&rel, &toks, &comments);
    let mut out = LintOutcome::default();
    for f in raw {
        let hit = allows
            .iter()
            .any(|a| a.rule == f.rule && a.start <= f.line && f.line <= a.end);
        if hit {
            out.suppressed.push(f);
        } else {
            out.active.push(f);
        }
    }
    out.active.extend(bad);
    out.active.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out.suppressed.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}
