//! PJRT execution engine: loads `artifacts/*.hlo.txt`, compiles them on
//! the CPU PJRT client (lazily, cached per process) and executes them with
//! host tensors from `crate::tensor`.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`. The AOT
//! side lowers with `return_tuple=True`, so every result is one tuple
//! literal that we decompose against the manifest's output specs.
//!
//! The engine is **thread-safe** (`Sync`): the executable cache sits
//! behind an `RwLock` (executions only take the read lock), a compile of
//! one executable is serialized by a per-name lock without blocking
//! executions or compiles of *other* executables, and statistics are
//! plain atomics. `coordinator::round::RoundDriver` relies on this to run
//! simulated clients on several worker threads against one engine.
//!
//! One engine still means one PJRT client, whose intra-op parallelism can
//! serialize concurrent executions under load; [`super::pool::EnginePool`]
//! stacks several engines over one shared `Arc<Manifest>` so each round
//! worker gets a private client and executable cache.

use super::manifest::{DType, ExecSpec, Manifest, TensorSpec};
use crate::tensor::{IntTensor, Tensor};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// Borrowed input value for an execution.
#[derive(Debug, Clone, Copy)]
pub enum Value<'a> {
    F32(&'a Tensor),
    I32(&'a IntTensor),
}

impl<'a> Value<'a> {
    fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => t.shape(),
            Value::I32(t) => t.shape(),
        }
    }

    fn dtype(&self) -> DType {
        match self {
            Value::F32(_) => DType::F32,
            Value::I32(_) => DType::I32,
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Value::F32(t) => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.data().len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, t.shape(), bytes)?
            }
            Value::I32(t) => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.data().len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, t.shape(), bytes)?
            }
        };
        Ok(lit)
    }
}

/// Cumulative engine statistics snapshot (perf pass reads these).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    pub compiles: u64,
    pub executions: u64,
    pub compile_secs: f64,
    pub execute_secs: f64,
}

impl EngineStats {
    /// Merge several snapshots into one (an [`super::pool::EnginePool`]
    /// reports the sum over its engines).
    pub fn merged<I: IntoIterator<Item = EngineStats>>(stats: I) -> EngineStats {
        stats.into_iter().fold(EngineStats::default(), |mut acc, s| {
            acc.compiles += s.compiles;
            acc.executions += s.executions;
            acc.compile_secs += s.compile_secs;
            acc.execute_secs += s.execute_secs;
            acc
        })
    }
}

/// Lock-free counters behind `EngineStats`; durations accumulate in
/// nanoseconds so they stay monotone under concurrent `fetch_add`.
#[derive(Debug, Default)]
struct StatCells {
    compiles: AtomicU64,
    executions: AtomicU64,
    compile_nanos: AtomicU64,
    execute_nanos: AtomicU64,
}

/// One PJRT client + executable cache. Shareable by every worker thread
/// (all mutable state is internally synchronized); several engines can
/// share one parsed manifest through [`Engine::with_shared`].
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Arc<Manifest>,
    cache: RwLock<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    /// per-executable compile gates: the first thread to miss the cache
    /// compiles while later threads for the *same* name wait on its gate
    /// (and then hit the cache) instead of compiling twice
    compiling: Mutex<HashMap<String, Arc<Mutex<()>>>>,
    stats: StatCells,
}

impl Engine {
    /// Create a CPU engine over a parsed manifest.
    pub fn new(manifest: Manifest) -> Result<Engine> {
        Engine::with_shared(Arc::new(manifest))
    }

    /// Create a CPU engine over an already-shared manifest (the
    /// `EnginePool` path: N clients, one parsed manifest).
    pub fn with_shared(manifest: Arc<Manifest>) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        log::debug!(
            "PJRT platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Engine {
            client,
            manifest,
            cache: RwLock::new(HashMap::new()),
            compiling: Mutex::new(HashMap::new()),
            stats: StatCells::default(),
        })
    }

    /// Engine over the default artifacts directory.
    pub fn load_default() -> Result<Engine> {
        Engine::new(Manifest::load_default()?)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> EngineStats {
        EngineStats {
            compiles: self.stats.compiles.load(Ordering::Relaxed),
            executions: self.stats.executions.load(Ordering::Relaxed),
            compile_secs: self.stats.compile_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
            execute_secs: self.stats.execute_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
        }
    }

    /// Ensure an executable is compiled (warms the cache).
    pub fn prepare(&self, name: &str) -> Result<()> {
        self.compiled(name).map(|_| ())
    }

    /// Fetch (compiling at most once per name) the executable.
    fn compiled(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        // poisoned locks are recovered, not propagated: the guarded state
        // (compile cache, gate map) stays valid across a panicking reader
        use std::sync::PoisonError;
        if let Some(exe) = self.cache.read().unwrap_or_else(PoisonError::into_inner).get(name) {
            return Ok(exe.clone());
        }
        // Miss: serialize per name so concurrent callers compile once.
        let gate = {
            let mut compiling = self.compiling.lock().unwrap_or_else(PoisonError::into_inner);
            compiling.entry(name.to_string()).or_default().clone()
        };
        let _gate = gate.lock().unwrap_or_else(PoisonError::into_inner);
        // double-check under the gate: another thread may have won the race
        if let Some(exe) = self.cache.read().unwrap_or_else(PoisonError::into_inner).get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.exec(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .map_err(|e| anyhow!("loading {}: {e}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e}"))?,
        );
        self.stats.compiles.fetch_add(1, Ordering::Relaxed);
        self.stats
            .compile_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        log::debug!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        self.cache
            .write()
            .unwrap_or_else(PoisonError::into_inner)
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    fn check_inputs(spec: &ExecSpec, inputs: &[Value]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            ));
        }
        for (v, is) in inputs.iter().zip(&spec.inputs) {
            if v.shape() != is.shape.as_slice() || v.dtype() != is.dtype {
                return Err(anyhow!(
                    "{}: input `{}` expects {:?} {:?}, got {:?} {:?}",
                    spec.name,
                    is.name,
                    is.dtype,
                    is.shape,
                    v.dtype(),
                    v.shape()
                ));
            }
        }
        Ok(())
    }

    /// Execute `name` with positional inputs, returning positional f32
    /// outputs as host tensors (all Heroes outputs are f32). Safe to call
    /// from any number of threads concurrently.
    pub fn execute(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
        // borrow, don't clone: ExecSpec holds nested Vecs and this is the
        // hot path (§Perf iteration 1)
        let spec = self.manifest.exec(name)?;
        Self::check_inputs(spec, inputs)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<_>>()?;
        let exe = self.compiled(name)?;
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e}"))?;
        let out_lit = result
            .first()
            .and_then(|device| device.first())
            .ok_or_else(|| anyhow!("{name}: runtime returned no output buffer"))?
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e}"))?;
        self.stats.executions.fetch_add(1, Ordering::Relaxed);
        self.stats
            .execute_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let parts = out_lit
            .to_tuple()
            .map_err(|e| anyhow!("decomposing result tuple of {name}: {e}"))?;
        if parts.len() != spec.outputs.len() {
            return Err(anyhow!(
                "{name}: expected {} outputs, got {}",
                spec.outputs.len(),
                parts.len()
            ));
        }
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, os)| literal_to_tensor(lit, os).context(os.name.clone()))
            .collect()
    }
}

fn literal_to_tensor(lit: xla::Literal, spec: &TensorSpec) -> Result<Tensor> {
    let v: Vec<f32> = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow!("output is not f32: {e}"))?;
    if v.len() != spec.elements() {
        return Err(anyhow!(
            "output `{}` has {} elements, expected {:?}",
            spec.name,
            v.len(),
            spec.shape
        ));
    }
    Ok(Tensor::from_vec(&spec.shape, v))
}

#[cfg(test)]
mod tests {
    // Engine tests that require compiled artifacts live in
    // rust/tests/integration_runtime.rs and integration_parallel.rs; the
    // Value plumbing is testable standalone.
    use super::*;

    #[test]
    fn engine_is_send_and_sync() {
        // the whole parallel round driver rests on this bound
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Engine>();
    }

    #[test]
    fn stats_merge_sums_all_fields() {
        let a = EngineStats { compiles: 2, executions: 10, compile_secs: 1.5, execute_secs: 0.25 };
        let b = EngineStats { compiles: 1, executions: 4, compile_secs: 0.5, execute_secs: 0.75 };
        let m = EngineStats::merged([a, b]);
        assert_eq!(m.compiles, 3);
        assert_eq!(m.executions, 14);
        assert!((m.compile_secs - 2.0).abs() < 1e-12);
        assert!((m.execute_secs - 1.0).abs() < 1e-12);
        let empty = EngineStats::merged([]);
        assert_eq!((empty.compiles, empty.executions), (0, 0));
    }

    #[test]
    fn value_shape_dtype() {
        let t = Tensor::zeros(&[2, 3]);
        let v = Value::F32(&t);
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(v.dtype(), DType::F32);
        let it = IntTensor::zeros(&[4]);
        let vi = Value::I32(&it);
        assert_eq!(vi.dtype(), DType::I32);
    }

    #[test]
    fn value_to_literal_roundtrip() {
        let t = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = Value::F32(&t).to_literal().unwrap();
        assert_eq!(lit.element_count(), 4);
        let back: Vec<f32> = lit.to_vec().unwrap();
        assert_eq!(back, vec![1.0, 2.0, 3.0, 4.0]);
        let it = IntTensor::from_vec(&[3], vec![7, 8, 9]);
        let lit = Value::I32(&it).to_literal().unwrap();
        let back: Vec<i32> = lit.to_vec().unwrap();
        assert_eq!(back, vec![7, 8, 9]);
    }
}
