//! Per-worker PJRT engine pool.
//!
//! One [`Engine`] is thread-safe, but it wraps a single PJRT CPU client:
//! concurrent executions funnel into that client's intra-op thread pool
//! and serialize under load, which capped the speedup of the parallel
//! round driver (`coordinator::round`). An [`EnginePool`] holds **N
//! independent clients over one shared parsed [`Manifest`]** so that
//! round worker *i* executes on engine *i* and never contends with the
//! other workers:
//!
//! * **sharded executable caches** — each engine compiles and caches its
//!   own `PjRtLoadedExecutable`s; a compile on one engine never blocks an
//!   execution on another. [`EnginePool::prepare_all`] warms every shard
//!   up front (in parallel) so steady-state rounds never compile.
//! * **merged statistics** — [`EnginePool::stats`] sums the per-engine
//!   [`EngineStats`], keeping the perf pass's counters meaningful.
//! * **determinism** — PJRT CPU executions are deterministic functions of
//!   their inputs and every engine compiles the same HLO with the same
//!   pipeline, so *which* engine runs a task cannot change its result;
//!   the round driver's byte-identical-reports contract is preserved for
//!   any pool size.
//!
//! A pool of one engine is exactly the old shared-engine behaviour; every
//! consumer that only needs "an engine" (evaluation, benches) uses
//! [`EnginePool::primary`].

use super::engine::{Engine, EngineStats};
use super::manifest::Manifest;
use anyhow::Result;
use std::sync::Arc;

/// A worker thread's panic converted into a typed error. The round
/// driver catches unwinds (a panicking task must still produce a
/// completion — see `coordinator::round::worker_loop`) and
/// [`EnginePool::prepare_all`] joins its per-engine compile threads;
/// both paths surface this instead of a stringly error or a process
/// abort, so callers can downcast and tests can pin the contract.
#[derive(Debug, thiserror::Error)]
#[error("engine {engine}: worker panicked: {msg}")]
pub struct EnginePanic {
    /// pool index of the engine the panicking thread was pinned to
    pub engine: usize,
    /// the panic payload, stringified when possible
    pub msg: String,
}

impl EnginePanic {
    /// Convert a `catch_unwind`/`join` payload into the typed error.
    pub fn from_payload(engine: usize, payload: Box<dyn std::any::Any + Send>) -> EnginePanic {
        let msg = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "non-string panic payload".into());
        EnginePanic { engine, msg }
    }
}

/// N PJRT CPU clients over one shared manifest (see module docs).
pub struct EnginePool {
    engines: Vec<Engine>,
}

impl EnginePool {
    /// Pool of `n` engines (`n == 0` is treated as 1) over one parsed
    /// manifest.
    pub fn new(manifest: Manifest, n: usize) -> Result<EnginePool> {
        let shared = Arc::new(manifest);
        let engines = (0..n.max(1))
            .map(|_| Engine::with_shared(shared.clone()))
            .collect::<Result<Vec<_>>>()?;
        Ok(EnginePool { engines })
    }

    /// Single-engine pool — the old shared-engine behaviour.
    pub fn single(manifest: Manifest) -> Result<EnginePool> {
        EnginePool::new(manifest, 1)
    }

    /// Number of engines (≥ 1 by construction).
    pub fn len(&self) -> usize {
        self.engines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.engines.is_empty()
    }

    /// The engine pinned to round worker `worker` (wraps when the pool is
    /// smaller than the worker count).
    #[allow(clippy::indexing_slicing)]
    pub fn engine(&self, worker: usize) -> &Engine {
        // hlint::allow(panic_path): index is `% len` and construction guarantees ≥ 1 engine
        &self.engines[worker % self.engines.len()]
    }

    /// The coordinator's engine (evaluation, serial dispatch, benches).
    #[allow(clippy::indexing_slicing)]
    pub fn primary(&self) -> &Engine {
        // hlint::allow(panic_path): construction guarantees ≥ 1 engine
        &self.engines[0]
    }

    /// The shared manifest.
    #[allow(clippy::indexing_slicing)]
    pub fn manifest(&self) -> &Manifest {
        // hlint::allow(panic_path): construction guarantees ≥ 1 engine
        self.engines[0].manifest()
    }

    /// Merged statistics over all engines.
    pub fn stats(&self) -> EngineStats {
        EngineStats::merged(self.engines.iter().map(|e| e.stats()))
    }

    /// Warm every engine's executable cache for the given names — one
    /// thread per engine, since the per-engine compiles are independent.
    /// Steady-state rounds then never hit a compile.
    pub fn prepare_all(&self, names: &[&str]) -> Result<()> {
        std::thread::scope(|s| {
            let handles: Vec<_> = self
                .engines
                .iter()
                .map(|e| {
                    s.spawn(move || -> Result<()> {
                        for &name in names {
                            e.prepare(name)?;
                        }
                        Ok(())
                    })
                })
                .collect();
            for (engine, h) in handles.into_iter().enumerate() {
                h.join().map_err(|p| EnginePanic::from_payload(engine, p))??;
            }
            Ok(())
        })
    }
}

#[cfg(test)]
mod tests {
    // Pool construction requires a live PJRT client, so behavioural tests
    // (cache isolation, merged stats over real compiles, determinism
    // across pool sizes) live in rust/tests/integration_parallel.rs and
    // skip without artifacts. The pure pieces are pinned here.
    use super::*;

    #[test]
    fn panic_payloads_stringify() {
        let e = EnginePanic::from_payload(2, Box::new("boom"));
        assert_eq!((e.engine, e.msg.as_str()), (2, "boom"));
        assert!(e.to_string().contains("engine 2"));
        let e = EnginePanic::from_payload(0, Box::new(String::from("heap boom")));
        assert_eq!(e.msg, "heap boom");
        let e = EnginePanic::from_payload(1, Box::new(42u32));
        assert_eq!(e.msg, "non-string panic payload");
    }

    #[test]
    fn pool_is_send_and_sync() {
        // round workers borrow &EnginePool across threads
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<EnginePool>();
    }
}
