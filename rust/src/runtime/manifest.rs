//! `artifacts/manifest.json` loader.
//!
//! The manifest is produced by `python/compile/aot.py` alongside the HLO
//! text files and is the single source of truth for: executable I/O
//! orderings and shapes, per-family layer geometry (basis/block shapes,
//! block counts), per-width parameter specs with init stds, and the
//! FLOPs / transfer-bytes cost model the simulator plugs into the paper's
//! Eq. 17-18.

use crate::codec::json::{self, Json};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Tensor dtype in the AOT interface (everything is f32 or i32).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(anyhow!("unknown dtype `{other}`")),
        }
    }
}

/// One positional input/output of an executable.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.req_str("name")?.to_string(),
            shape: j.req("shape")?.usize_vec()?,
            dtype: DType::parse(j.req_str("dtype")?)?,
        })
    }
}

/// Executable kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecKind {
    Train,
    Eval,
    Probe,
}

/// One AOT-compiled HLO module.
#[derive(Debug, Clone)]
pub struct ExecSpec {
    pub name: String,
    pub file: PathBuf,
    pub model: String,
    pub kind: ExecKind,
    pub p: usize,
    pub composed: bool,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One composed layer's geometry (mirrors python specs.LayerSpec).
#[derive(Debug, Clone)]
pub struct LayerInfo {
    pub name: String,
    pub kind: String,
    pub k: usize,
    pub stride: usize,
    pub i: usize,
    pub o: usize,
    pub r: usize,
    pub s_in: bool,
    pub s_out: bool,
    /// channel-group class feeding this layer (None = fixed input side)
    pub in_class: Option<String>,
    /// channel-group class of the output channels (None = fixed output)
    pub out_class: Option<String>,
    pub basis_shape: Vec<usize>,
    pub block_shape: Vec<usize>,
    pub blocks_total: usize,
}

impl LayerInfo {
    /// b(p) = p^(s_in+s_out): blocks a width-p model trains (paper §II-B).
    pub fn blocks_at(&self, p: usize) -> usize {
        p.pow(u32::from(self.s_in) + u32::from(self.s_out))
    }

    /// Shape of the complete coefficient (R, B·O).
    pub fn full_coeff_shape(&self) -> [usize; 2] {
        [self.r, self.blocks_total * self.o]
    }
}

/// A parameter tensor spec with its init std.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init_std: f64,
}

/// Input data description for a family.
#[derive(Debug, Clone)]
pub enum InputInfo {
    Image { hw: usize, channels: usize },
    Text { vocab: usize, seq_len: usize },
}

/// One model family's geometry + cost model.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub family: String,
    pub cap_p: usize,
    pub classes: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub input: InputInfo,
    pub layers: Vec<LayerInfo>,
    /// composed-model params per width ("1".."P")
    pub composed_params: BTreeMap<usize, Vec<ParamSpec>>,
    /// dense-model params per width
    pub dense_params: BTreeMap<usize, Vec<ParamSpec>>,
    /// FLOPs per local iteration, per width
    pub flops_composed: BTreeMap<usize, f64>,
    pub flops_dense: BTreeMap<usize, f64>,
    /// upload bytes per width (Eq. 18 numerator)
    pub bytes_composed: BTreeMap<usize, usize>,
    pub bytes_dense: BTreeMap<usize, usize>,
    pub probe_dim: BTreeMap<usize, usize>,
}

impl ModelInfo {
    pub fn layer(&self, name: &str) -> Result<&LayerInfo> {
        self.layers
            .iter()
            .find(|l| l.name == name)
            .ok_or_else(|| anyhow!("no layer `{name}` in {}", self.family))
    }

    /// Composed-model param specs at width `p` — the fallible accessor
    /// the planners use instead of indexing [`ModelInfo::composed_params`]
    /// (a width outside `1..=cap_p` is a planner bug surfaced as a typed
    /// error, not a panic).
    pub fn composed_params_of(&self, p: usize) -> Result<&[ParamSpec]> {
        self.composed_params
            .get(&p)
            .map(Vec::as_slice)
            .ok_or_else(|| anyhow!("no composed params for width {p} in {}", self.family))
    }

    /// Composed upload bytes at width `p` (see [`ModelInfo::composed_params_of`]).
    pub fn bytes_composed_of(&self, p: usize) -> Result<usize> {
        self.bytes_composed
            .get(&p)
            .copied()
            .ok_or_else(|| anyhow!("no composed byte size for width {p} in {}", self.family))
    }
}

/// Parsed manifest: all families + all executables.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelInfo>,
    pub executables: BTreeMap<String, ExecSpec>,
}

fn parse_per_width_map<T, F: Fn(&Json) -> Option<T>>(j: &Json, f: F) -> Result<BTreeMap<usize, T>> {
    let obj = j.as_obj().ok_or_else(|| anyhow!("expected object keyed by width"))?;
    let mut out = BTreeMap::new();
    for (k, v) in obj {
        let p: usize = k.parse().map_err(|_| anyhow!("bad width key `{k}`"))?;
        out.insert(p, f(v).ok_or_else(|| anyhow!("bad value for width {k}"))?);
    }
    Ok(out)
}

fn parse_params(j: &Json) -> Result<Vec<ParamSpec>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("params must be an array"))?
        .iter()
        .map(|p| {
            Ok(ParamSpec {
                name: p.req_str("name")?.to_string(),
                shape: p.req("shape")?.usize_vec()?,
                init_std: p.req_f64("init_std")?,
            })
        })
        .collect()
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let root = json::parse_file(&dir.join("manifest.json"))
            .context("loading manifest (run `make artifacts` first)")?;
        let mut models = BTreeMap::new();
        for (fam, m) in root.req("models")?.as_obj().ok_or_else(|| anyhow!("models not an object"))? {
            let input_j = m.req("input")?;
            let input = match input_j.req_str("kind")? {
                "image" => InputInfo::Image {
                    hw: input_j.req_usize("hw")?,
                    channels: input_j.req_usize("channels")?,
                },
                "text" => InputInfo::Text {
                    vocab: input_j.req_usize("vocab")?,
                    seq_len: input_j.req_usize("seq_len")?,
                },
                other => return Err(anyhow!("unknown input kind `{other}`")),
            };
            let layers = m
                .req_arr("layers")?
                .iter()
                .map(|l| {
                    Ok(LayerInfo {
                        name: l.req_str("name")?.to_string(),
                        kind: l.req_str("kind")?.to_string(),
                        k: l.req_usize("k")?,
                        stride: l.req_usize("stride")?,
                        i: l.req_usize("i")?,
                        o: l.req_usize("o")?,
                        r: l.req_usize("r")?,
                        s_in: l.req_bool("s_in")?,
                        s_out: l.req_bool("s_out")?,
                        in_class: l.get("in_class").and_then(Json::as_str).map(str::to_string),
                        out_class: l.get("out_class").and_then(Json::as_str).map(str::to_string),
                        basis_shape: l.req("basis_shape")?.usize_vec()?,
                        block_shape: l.req("block_shape")?.usize_vec()?,
                        blocks_total: l.req_usize("blocks_total")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let params = m.req("params")?;
            let flops = m.req("flops")?;
            let bytes = m.req("bytes")?;
            models.insert(
                fam.clone(),
                ModelInfo {
                    family: fam.clone(),
                    cap_p: m.req_usize("cap_p")?,
                    classes: m.req_usize("classes")?,
                    batch: m.req_usize("batch")?,
                    eval_batch: m.req_usize("eval_batch")?,
                    input,
                    layers,
                    composed_params: parse_per_width_map(params.req("composed")?, |v| parse_params(v).ok())?,
                    dense_params: parse_per_width_map(params.req("dense")?, |v| parse_params(v).ok())?,
                    flops_composed: parse_per_width_map(flops.req("composed")?, Json::as_f64)?,
                    flops_dense: parse_per_width_map(flops.req("dense")?, Json::as_f64)?,
                    bytes_composed: parse_per_width_map(bytes.req("composed")?, Json::as_usize)?,
                    bytes_dense: parse_per_width_map(bytes.req("dense")?, Json::as_usize)?,
                    probe_dim: parse_per_width_map(m.req("probe_dim")?, Json::as_usize)?,
                },
            );
        }

        let mut executables = BTreeMap::new();
        for (name, e) in root
            .req("executables")?
            .as_obj()
            .ok_or_else(|| anyhow!("executables not an object"))?
        {
            let kind = match e.req_str("kind")? {
                "train" => ExecKind::Train,
                "eval" => ExecKind::Eval,
                "probe" => ExecKind::Probe,
                other => return Err(anyhow!("unknown exec kind `{other}`")),
            };
            executables.insert(
                name.clone(),
                ExecSpec {
                    name: name.clone(),
                    file: dir.join(e.req_str("file")?),
                    model: e.req_str("model")?.to_string(),
                    kind,
                    p: e.req_usize("p")?,
                    composed: e.req_bool("composed")?,
                    inputs: e.req_arr("inputs")?.iter().map(TensorSpec::parse).collect::<Result<Vec<_>>>()?,
                    outputs: e.req_arr("outputs")?.iter().map(TensorSpec::parse).collect::<Result<Vec<_>>>()?,
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), models, executables })
    }

    /// Default artifacts dir: `$HEROES_ARTIFACTS` or `<crate root>/artifacts`.
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("HEROES_ARTIFACTS") {
            return PathBuf::from(p);
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Load from the default location.
    pub fn load_default() -> Result<Manifest> {
        Self::load(&Self::default_dir())
    }

    pub fn model(&self, family: &str) -> Result<&ModelInfo> {
        self.models
            .get(family)
            .ok_or_else(|| anyhow!("family `{family}` not in manifest"))
    }

    pub fn exec(&self, name: &str) -> Result<&ExecSpec> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow!("executable `{name}` not in manifest"))
    }

    /// Conventional executable names.
    pub fn train_name(family: &str, p: usize, composed: bool) -> String {
        if composed {
            format!("{family}_train_p{p}")
        } else {
            format!("{family}_dtrain_p{p}")
        }
    }

    pub fn eval_name(family: &str, composed: bool) -> String {
        if composed {
            format!("{family}_eval")
        } else {
            format!("{family}_deval")
        }
    }

    pub fn probe_name(family: &str, p: usize) -> String {
        format!("{family}_probe_p{p}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full-manifest integration tests live in rust/tests/ (they need
    // `make artifacts`); here we check parsing against a miniature doc.
    fn mini() -> Manifest {
        let doc = r#"{
          "models": {"toy": {
            "cap_p": 2, "classes": 3, "batch": 4, "eval_batch": 8,
            "input": {"kind": "image", "hw": 8, "channels": 1},
            "layers": [{"name":"l0","kind":"conv","k":3,"stride":1,"i":2,"o":5,"r":4,
                        "s_in":false,"s_out":true,"basis_shape":[9,2,4],
                        "block_shape":[4,5],"blocks_total":2}],
            "params": {"composed": {"1": [{"name":"v_l0","shape":[9,2,4],"init_std":0.1}]},
                        "dense": {"1": [{"name":"w_l0","shape":[3,3,2,5],"init_std":0.2}]}},
            "flops": {"composed": {"1": 100}, "dense": {"1": 90}},
            "bytes": {"composed": {"1": 1000}, "dense": {"1": 2000}},
            "probe_dim": {"1": 42}
          }},
          "executables": {"toy_train_p1": {
            "file": "toy_train_p1.hlo.txt", "model": "toy", "kind": "train",
            "p": 1, "composed": true,
            "inputs": [{"name":"v_l0","shape":[9,2,4],"dtype":"f32"},
                       {"name":"y","shape":[4],"dtype":"i32"}],
            "outputs": [{"name":"loss","shape":[1],"dtype":"f32"}]
          }}
        }"#;
        let dir = std::env::temp_dir().join("heroes_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), doc).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn parses_models_and_executables() {
        let m = mini();
        let toy = m.model("toy").unwrap();
        assert_eq!(toy.cap_p, 2);
        assert_eq!(toy.layers[0].blocks_at(2), 2); // s_out only
        assert_eq!(toy.layers[0].full_coeff_shape(), [4, 10]);
        assert_eq!(toy.flops_composed[&1], 100.0);
        let e = m.exec("toy_train_p1").unwrap();
        assert_eq!(e.kind, ExecKind::Train);
        assert_eq!(e.inputs[1].dtype, DType::I32);
        assert_eq!(e.inputs[0].elements(), 72);
    }

    #[test]
    fn missing_family_errors() {
        let m = mini();
        assert!(m.model("nope").is_err());
        assert!(m.exec("nope").is_err());
    }

    #[test]
    fn exec_name_conventions() {
        assert_eq!(Manifest::train_name("cnn", 3, true), "cnn_train_p3");
        assert_eq!(Manifest::train_name("cnn", 3, false), "cnn_dtrain_p3");
        assert_eq!(Manifest::eval_name("rnn", true), "rnn_eval");
        assert_eq!(Manifest::eval_name("rnn", false), "rnn_deval");
        assert_eq!(Manifest::probe_name("resnet", 2), "resnet_probe_p2");
    }
}
