//! Runtime layer: manifest-driven loading + PJRT execution of the AOT
//! artifacts (`artifacts/*.hlo.txt`). See DESIGN.md — rust owns the entire
//! request path; python only ever ran at `make artifacts` time.

// The determinism layers promise typed errors, never panics: promote
// slice-index panics to clippy warnings here (CI denies warnings);
// hlint rule P1 enforces the same contract with per-line reasons.
#![warn(clippy::indexing_slicing)]


pub mod engine;
pub mod manifest;
pub mod pool;

pub use engine::{Engine, EngineStats, Value};
pub use manifest::{DType, ExecKind, ExecSpec, InputInfo, LayerInfo, Manifest, ModelInfo, ParamSpec, TensorSpec};
pub use pool::{EnginePanic, EnginePool};
