//! Runtime layer: manifest-driven loading + PJRT execution of the AOT
//! artifacts (`artifacts/*.hlo.txt`). See DESIGN.md — rust owns the entire
//! request path; python only ever ran at `make artifacts` time.

pub mod engine;
pub mod manifest;
pub mod pool;

pub use engine::{Engine, EngineStats, Value};
pub use manifest::{DType, ExecKind, ExecSpec, InputInfo, LayerInfo, Manifest, ModelInfo, ParamSpec, TensorSpec};
pub use pool::{EnginePanic, EnginePool};
