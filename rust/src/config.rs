//! Experiment configuration.
//!
//! One `ExperimentConfig` fully determines a federated run: model family,
//! fleet size and sampling, data synthesis + partition scheme, the
//! controller's budgets (paper §V: μ^max, ρ, δ, T^max), learning rate and
//! seed. Configs parse from JSON files (`configs/*.json`) and accept CLI
//! overrides; `Scale` presets keep smoke runs in minutes while `--scale
//! paper` reproduces the full 100-client protocol.

use crate::codec::json::Json;
use crate::codec::CodecCfg;
use crate::coordinator::resilience::FaultPolicyCfg;
use crate::simulation::{FaultsCfg, Scenario};
use crate::transport::TransportCfg;
use crate::util::cli::Args;
use anyhow::{anyhow, Result};

/// Non-IID partition scheme (paper §VI-A2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Partition {
    /// CIFAR scheme: Γ% of each client's samples from one dominant class.
    Gamma(f64),
    /// ImageNet scheme: each client lacks `missing_frac` of the classes.
    Phi(f64),
    /// Text: natural per-shard Non-IID (per-role style chains).
    Natural,
}

impl Partition {
    pub fn name(&self) -> String {
        match self {
            Partition::Gamma(g) => format!("gamma{g:.0}"),
            Partition::Phi(f) => format!("phi{:.0}", f * 100.0),
            Partition::Natural => "natural".into(),
        }
    }
}

/// Preset sizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// CI-friendly: tens of clients, tens of rounds.
    Smoke,
    /// Paper protocol: 100 clients, 10 per round, hundreds of rounds.
    Paper,
}

impl Scale {
    pub fn parse(s: &str) -> Result<Scale> {
        match s {
            "smoke" => Ok(Scale::Smoke),
            "paper" => Ok(Scale::Paper),
            other => Err(anyhow!("unknown scale `{other}` (smoke|paper)")),
        }
    }
}

/// The `--quorum` knob: full barrier, a static K, or the adaptive
/// controller (`coordinator::quorum_ctl`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuorumKnob {
    /// synchronous rounds (the default)
    Off,
    /// PR 3's static semi-async K-of-N (`--quorum K`); K ≥ the cohort
    /// size reproduces the synchronous loop byte-identically
    Fixed(usize),
    /// per-round adaptive (K, α) (`--quorum auto`): smallest K whose
    /// projected staleness penalty fits the Eq. 23 ε-margin slice
    /// (`--quorum-margin`), floored at `--quorum-floor`
    Auto,
}

impl QuorumKnob {
    /// Parse the CLI/JSON value: `auto`, or an integer (0 = off).
    pub fn parse(s: &str) -> Result<QuorumKnob> {
        if s == "auto" {
            return Ok(QuorumKnob::Auto);
        }
        let k: usize = s
            .parse()
            .map_err(|_| anyhow!("--quorum expects an integer or `auto`, got `{s}`"))?;
        Ok(if k == 0 { QuorumKnob::Off } else { QuorumKnob::Fixed(k) })
    }

    /// True when rounds run through `RoundDriver::run_quorum`.
    pub fn is_active(&self) -> bool {
        !matches!(self, QuorumKnob::Off)
    }
}

/// The full-barrier paths' reaction to a scenario mid-round dropout
/// (`--dropout-policy`; the quorum path always treats dropped clients as
/// never-arriving stragglers instead).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DropoutPolicy {
    /// re-plan phase C over the survivors (the dropped client's broadcast
    /// is billed, its update is lost); an all-dropped round is still a
    /// typed error (`ScenarioError::EmptySurvivors`)
    Survivors,
    /// any mid-round dropout fails the run
    /// (`ScenarioError::MidRoundDropout`)
    Error,
}

impl DropoutPolicy {
    pub fn parse(s: &str) -> Result<DropoutPolicy> {
        match s {
            "survivors" => Ok(DropoutPolicy::Survivors),
            "error" => Ok(DropoutPolicy::Error),
            other => Err(anyhow!("unknown dropout policy `{other}` (survivors|error)")),
        }
    }
}

/// The `--population` knob: how `FlEnv` holds the client world.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PopulationMode {
    /// enumerate every client at build time (fleet + full dataset +
    /// partition) — the historical default, byte-identical to itself
    Eager,
    /// the parametric `simulation::population` world: clients are priors,
    /// per-client state is derived from `(seed, client)` on first touch
    /// and memoized in a bounded cache — O(cohort) round cost and
    /// resident memory at any population size
    Lazy,
}

impl PopulationMode {
    pub fn parse(s: &str) -> Result<PopulationMode> {
        match s {
            "eager" => Ok(PopulationMode::Eager),
            "lazy" => Ok(PopulationMode::Lazy),
            other => Err(anyhow!("unknown population mode `{other}` (eager|lazy)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PopulationMode::Eager => "eager",
            PopulationMode::Lazy => "lazy",
        }
    }
}

/// Full experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// model family: cnn | resnet | rnn
    pub family: String,
    pub n_clients: usize,
    /// clients sampled per round (K)
    pub k_per_round: usize,
    /// total rounds to run (the experiment driver may stop earlier on a
    /// time/traffic/accuracy budget)
    pub rounds: usize,
    /// samples per client (image families)
    pub samples_per_client: usize,
    pub test_samples: usize,
    /// tokens per shard (text family)
    pub shard_tokens: usize,
    pub partition: Partition,
    pub lr: f32,
    /// effective lr at round h is lr / (1 + h / lr_decay_rounds) — a
    /// standard 1/t schedule applied identically to every scheme (the
    /// AOT executables take lr as a runtime input, so no recompilation)
    pub lr_decay_rounds: usize,
    pub seed: u64,
    /// evaluate the global model every this many rounds
    pub eval_every: usize,
    // ---- controller budgets (paper §V) ----
    /// per-iteration time budget for width assignment (seconds)
    pub mu_max: f64,
    /// waiting-time bound ρ (seconds)
    pub rho: f64,
    /// fallback local update frequency (round 0 / baselines)
    pub tau_default: usize,
    /// hard τ range for the controller
    pub tau_min: usize,
    pub tau_max: usize,
    /// convergence threshold ε used when solving for H (Eq. 26); this is a
    /// mean-square-gradient target, so it sets the controller's τ scale
    /// (τ_l ≈ 1.5·ε/(η·L·Φ) at the Eq. 26 optimum)
    pub epsilon: f64,
    /// WAN bandwidth band (Mb/s). Defaults are the paper's 1-5 / 10-20
    /// bands scaled by ~1/30 — the same factor by which our CPU-sized
    /// models shrink the paper's transfer sizes — preserving the paper's
    /// communication-dominated time regime (DESIGN.md §Substitutions).
    pub up_mbps: (f64, f64),
    pub down_mbps: (f64, f64),
    /// Worker threads for the round driver (`coordinator::round`).
    /// 1 = the serial coordinator loop; any N yields byte-identical
    /// results (see the driver's determinism contract), so this knob only
    /// trades wall-clock for cores.
    pub workers: usize,
    /// PJRT engines in the pool (`runtime::pool`): one per worker avoids
    /// intra-op contention on a single client. 0 (default) = match
    /// `workers`; 1 = the old shared-engine behaviour. Byte-identical for
    /// any value — engines only execute.
    pub pool_engines: usize,
    /// Overlap round *h+1*'s planning with round *h*'s stragglers
    /// (`RoundDriver::run_overlapped`). Byte-identical to the
    /// non-overlapped loop; purely a wall-clock knob.
    pub overlap: bool,
    /// Semi-async K-of-N quorum (`RoundDriver::run_quorum`): aggregate a
    /// round once its K virtually-fastest cohort members land and fold
    /// stragglers into later rounds staleness-weighted. `Off` (default)
    /// disables; `Fixed(K ≥ cohort)` reproduces the synchronous loop
    /// byte-identically; `Auto` hands K (and α) to the per-round
    /// controller. Takes precedence over `overlap` (it subsumes it).
    /// Seed-deterministic for any worker/pool count in every mode.
    pub quorum: QuorumKnob,
    /// α in the staleness weight `1/(1+s)^α` applied to late merges
    /// (quorum mode only). 0 disables discounting. Under `--quorum auto`
    /// this is the annealing ceiling `alpha_max` (and the starting α).
    pub staleness_alpha: f64,
    /// `--quorum-margin`: fraction of the Eq. 23 margin `ε − 6L²β²` the
    /// adaptive controller's projected staleness penalty may consume.
    pub quorum_margin: f64,
    /// `--quorum-floor`: hard K floor for the adaptive controller
    /// (clamped to the per-round cohort size).
    pub quorum_floor: usize,
    /// `--scenario`: the churn schedule driving bandwidth drift,
    /// availability windows and mid-round dropouts
    /// (`simulation::scenario`; `Stable` = the historical default path,
    /// byte for byte).
    pub scenario: Scenario,
    /// `--dropout-policy`: how the full-barrier paths react to a
    /// mid-round dropout (the quorum path always treats dropped clients
    /// as never-arriving stragglers).
    pub dropout_policy: DropoutPolicy,
    /// `--population`: eager (default; historical byte-identical world)
    /// or lazy (parametric population, O(cohort) rounds at millions of
    /// clients — see `simulation::population`).
    pub population: PopulationMode,
    /// `--hierarchy E`: number of edge aggregators between the cohort and
    /// the parameter server (quorum mode only). 0 or 1 = flat (the
    /// historical single-level path, byte for byte); E > 1 splits each
    /// round's cohort round-robin over E edges, each edge closes its own
    /// sub-quorum and forwards **one** composed update over the backhaul,
    /// and the root quorums over edge arrivals (`coordinator::hierarchy`).
    pub hierarchy: usize,
    /// `--codec`: how update uploads are represented and billed
    /// (`codec::CodecCfg`). `Analytic` (default) bills tensor-shape
    /// byte counts — byte-identical to the pre-codec repo; `wire` modes
    /// encode real `HWU1` frames (optionally q8-quantized / top-k
    /// sparsified) and bill the meter, ν and the hierarchy backhaul
    /// from measured frame lengths.
    pub codec: CodecCfg,
    /// `--faults`: per-class engine-level fault rates
    /// (`simulation::faults::FaultsCfg`; `off` = the default, which
    /// stamps nothing, consumes no RNG and is byte-identical to the
    /// pre-fault repo).
    pub faults: FaultsCfg,
    /// `--fault-policy`: what the coordinator does about each drawn
    /// fault class — retry (bounded, exponential virtual-clock backoff),
    /// re-plan (abandon + survivors re-plan) or fail typed
    /// (`coordinator::resilience::FaultPolicyCfg`).
    pub fault_policy: FaultPolicyCfg,
    /// `--transport`: which backend executes dispatched tasks
    /// (`transport::TransportCfg`). `sim` (default) is the in-process
    /// worker pool, byte-identical to the pre-transport repo;
    /// `tcp:<addr>` binds a localhost server and dispatches over real
    /// sockets (requires the `net` cargo feature). Decisions are
    /// transport-independent (see `transport` module docs), so both
    /// backends must produce identical results.
    pub transport: TransportCfg,
}

/// The pool-sizing rule, shared by `ExperimentConfig::pool_size` and
/// callers that size a pool straight from CLI flags (before any config
/// exists): 0 requested engines means one per worker.
pub fn resolve_pool_size(workers: usize, pool_engines: usize) -> usize {
    if pool_engines == 0 {
        workers
    } else {
        pool_engines
    }
}

impl ExperimentConfig {
    /// Defaults for a family at a scale.
    pub fn preset(family: &str, scale: Scale) -> ExperimentConfig {
        let (n_clients, k, rounds, spc, test, shard) = match scale {
            Scale::Smoke => (20, 5, 60, 40, 400, 2_000),
            Scale::Paper => (100, 10, 400, 50, 1_000, 4_000),
        };
        // the composed ResNet needs a longer horizon (group-rotation
        // equilibration through 5 tied classes) and a slightly hotter,
        // decayed lr — see EXPERIMENTS.md
        let rounds = if family == "resnet" { rounds * 5 / 2 } else { rounds };
        ExperimentConfig {
            family: family.to_string(),
            n_clients,
            k_per_round: k,
            rounds,
            samples_per_client: spc,
            test_samples: test,
            shard_tokens: shard,
            partition: match family {
                "resnet" => Partition::Phi(0.4),
                "rnn" => Partition::Natural,
                _ => Partition::Gamma(40.0),
            },
            lr: match family {
                "rnn" => 0.3,
                "resnet" => 0.15,
                _ => 0.1,
            },
            lr_decay_rounds: 60,
            seed: 42,
            eval_every: if scale == Scale::Smoke { 5 } else { 10 },
            // μ^max maps the four device classes onto the four widths
            // (laptop→1, TX2→2, NX→3, AGX→4) given each family's FLOPs —
            // mirrors the paper's "increase width as much as possible
            // within the resource budget" with a fleet that spans widths.
            mu_max: match family {
                "resnet" => 2.2,
                "rnn" => 0.058,
                _ => 0.65,
            },
            rho: 0.5,
            tau_default: if family == "resnet" { 15 } else { 10 },
            tau_min: 1,
            tau_max: 60,
            epsilon: 0.8,
            up_mbps: (1.0 / 30.0, 5.0 / 30.0),
            down_mbps: (10.0 / 30.0, 20.0 / 30.0),
            workers: 1,
            pool_engines: 0,
            overlap: false,
            quorum: QuorumKnob::Off,
            staleness_alpha: 1.0,
            quorum_margin: 0.5,
            quorum_floor: 1,
            scenario: Scenario::Stable,
            dropout_policy: DropoutPolicy::Survivors,
            population: PopulationMode::Eager,
            hierarchy: 0,
            codec: CodecCfg::Analytic,
            faults: FaultsCfg::default(),
            fault_policy: FaultPolicyCfg::default(),
            transport: TransportCfg::Sim,
        }
    }

    /// Engines the runtime pool should hold for this config
    /// (`pool_engines`, defaulting to one per worker).
    pub fn pool_size(&self) -> usize {
        resolve_pool_size(self.workers, self.pool_engines)
    }

    /// Apply CLI overrides (`--clients`, `--k`, `--rounds`, `--lr`,
    /// `--seed`, `--gamma`, `--phi`, `--mu-max`, `--rho`, ...).
    pub fn apply_args(mut self, args: &Args) -> Result<ExperimentConfig> {
        self.n_clients = args.get_usize("clients", self.n_clients)?;
        self.k_per_round = args.get_usize("k", self.k_per_round)?;
        self.rounds = args.get_usize("rounds", self.rounds)?;
        self.samples_per_client = args.get_usize("samples-per-client", self.samples_per_client)?;
        self.test_samples = args.get_usize("test-samples", self.test_samples)?;
        self.lr = args.get_f64("lr", self.lr as f64)? as f32;
        self.lr_decay_rounds = args.get_usize("lr-decay", self.lr_decay_rounds)?;
        self.seed = args.get_u64("seed", self.seed)?;
        self.eval_every = args.get_usize("eval-every", self.eval_every)?;
        self.mu_max = args.get_f64("mu-max", self.mu_max)?;
        self.rho = args.get_f64("rho", self.rho)?;
        self.tau_default = args.get_usize("tau", self.tau_default)?;
        self.tau_max = args.get_usize("tau-max", self.tau_max)?;
        self.epsilon = args.get_f64("epsilon", self.epsilon)?;
        self.up_mbps = (
            args.get_f64("up-lo", self.up_mbps.0)?,
            args.get_f64("up-hi", self.up_mbps.1)?,
        );
        self.down_mbps = (
            args.get_f64("down-lo", self.down_mbps.0)?,
            args.get_f64("down-hi", self.down_mbps.1)?,
        );
        self.workers = args.get_usize("workers", self.workers)?;
        self.pool_engines = args.get_usize("pool", self.pool_engines)?;
        if args.flag("overlap") {
            self.overlap = true;
        }
        if let Some(q) = args.get("quorum") {
            self.quorum = QuorumKnob::parse(q)?;
        }
        self.staleness_alpha = args.get_f64("staleness-alpha", self.staleness_alpha)?;
        self.quorum_margin = args.get_f64("quorum-margin", self.quorum_margin)?;
        self.quorum_floor = args.get_usize("quorum-floor", self.quorum_floor)?;
        if let Some(s) = args.get("scenario") {
            self.scenario = Scenario::parse(s)?;
        }
        if let Some(p) = args.get("dropout-policy") {
            self.dropout_policy = DropoutPolicy::parse(p)?;
        }
        if let Some(p) = args.get("population") {
            self.population = PopulationMode::parse(p)?;
        }
        self.hierarchy = args.get_usize("hierarchy", self.hierarchy)?;
        if let Some(c) = args.get("codec") {
            self.codec = CodecCfg::parse(c)?;
        }
        if let Some(f) = args.get("faults") {
            self.faults = FaultsCfg::parse(f)?;
        }
        if let Some(p) = args.get("fault-policy") {
            self.fault_policy = FaultPolicyCfg::parse(p)?;
        }
        if let Some(t) = args.get("transport") {
            self.transport = TransportCfg::parse(t)?;
        }
        if let Some(g) = args.get("gamma") {
            self.partition = Partition::Gamma(g.parse().map_err(|_| anyhow!("bad --gamma"))?);
        }
        if let Some(f) = args.get("phi") {
            let v: f64 = f.parse().map_err(|_| anyhow!("bad --phi"))?;
            self.partition = Partition::Phi(if v > 1.0 { v / 100.0 } else { v });
        }
        self.validate()?;
        Ok(self)
    }

    /// Parse a config JSON object (same keys as the CLI overrides).
    pub fn from_json(family: &str, scale: Scale, j: &Json) -> Result<ExperimentConfig> {
        let mut c = Self::preset(family, scale);
        let grab_usize = |key: &str, cur: usize| j.get(key).and_then(Json::as_usize).unwrap_or(cur);
        let grab_f64 = |key: &str, cur: f64| j.get(key).and_then(Json::as_f64).unwrap_or(cur);
        c.n_clients = grab_usize("clients", c.n_clients);
        c.k_per_round = grab_usize("k", c.k_per_round);
        c.rounds = grab_usize("rounds", c.rounds);
        c.samples_per_client = grab_usize("samples_per_client", c.samples_per_client);
        c.test_samples = grab_usize("test_samples", c.test_samples);
        c.lr = grab_f64("lr", c.lr as f64) as f32;
        c.seed = j.get("seed").and_then(Json::as_f64).map(|x| x as u64).unwrap_or(c.seed);
        c.mu_max = grab_f64("mu_max", c.mu_max);
        c.rho = grab_f64("rho", c.rho);
        c.tau_default = grab_usize("tau", c.tau_default);
        c.workers = grab_usize("workers", c.workers);
        c.pool_engines = grab_usize("pool", c.pool_engines);
        if let Some(o) = j.get("overlap").and_then(Json::as_bool) {
            c.overlap = o;
        }
        // JSON parity with the CLI: `"quorum"` is either a non-negative
        // integer (0 = off) or the string "auto"; anything else is an
        // error, never a silent fall-back to the synchronous default
        match j.get("quorum") {
            Some(Json::Str(s)) => c.quorum = QuorumKnob::parse(s)?,
            Some(v) => {
                let k = v
                    .as_usize()
                    .ok_or_else(|| anyhow!("`quorum` expects an integer or \"auto\", got {v}"))?;
                c.quorum = if k == 0 { QuorumKnob::Off } else { QuorumKnob::Fixed(k) };
            }
            None => {}
        }
        c.staleness_alpha = grab_f64("staleness_alpha", c.staleness_alpha);
        c.quorum_margin = grab_f64("quorum_margin", c.quorum_margin);
        c.quorum_floor = grab_usize("quorum_floor", c.quorum_floor);
        // JSON parity with the CLI: catalog-name strings; anything else
        // (wrong type, unknown name) is an error, never a silent
        // fall-back to the stable default
        if let Some(v) = j.get("scenario") {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow!("`scenario` expects a catalog-name string, got {v}"))?;
            c.scenario = Scenario::parse(s)?;
        }
        if let Some(v) = j.get("dropout_policy") {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow!("`dropout_policy` expects a string, got {v}"))?;
            c.dropout_policy = DropoutPolicy::parse(s)?;
        }
        // JSON parity with the CLI: `"population"` is "eager"|"lazy";
        // anything else is an error, never a silent fall-back
        if let Some(v) = j.get("population") {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow!("`population` expects \"eager\" or \"lazy\", got {v}"))?;
            c.population = PopulationMode::parse(s)?;
        }
        c.hierarchy = grab_usize("hierarchy", c.hierarchy);
        // JSON parity with the CLI: `"codec"` is a knob string
        // (`analytic` | `wire` | `wire:q8` | `wire:q8,topk=R`); anything
        // else is an error, never a silent fall-back to analytic
        if let Some(v) = j.get("codec") {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow!("`codec` expects a codec-knob string, got {v}"))?;
            c.codec = CodecCfg::parse(s)?;
        }
        // JSON parity with the CLI: `"faults"` and `"fault_policy"` are
        // knob strings (`off` | `exec=R,corrupt=R,partition=R`;
        // `retry` | `exec=retry,...,budget=N,backoff=S`); anything else
        // is an error, never a silent fall-back to fault-free
        if let Some(v) = j.get("faults") {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow!("`faults` expects a fault-knob string, got {v}"))?;
            c.faults = FaultsCfg::parse(s)?;
        }
        if let Some(v) = j.get("fault_policy") {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow!("`fault_policy` expects a policy-knob string, got {v}"))?;
            c.fault_policy = FaultPolicyCfg::parse(s)?;
        }
        // JSON parity with the CLI: `"transport"` is a knob string
        // (`sim` | `tcp:<addr>`); anything else is an error, never a
        // silent fall-back to the in-process pool
        if let Some(v) = j.get("transport") {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow!("`transport` expects \"sim\" or \"tcp:<addr>\", got {v}"))?;
            c.transport = TransportCfg::parse(s)?;
        }
        if let Some(g) = j.get("gamma").and_then(Json::as_f64) {
            c.partition = Partition::Gamma(g);
        }
        if let Some(f) = j.get("phi").and_then(Json::as_f64) {
            c.partition = Partition::Phi(if f > 1.0 { f / 100.0 } else { f });
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        if self.k_per_round == 0 || self.k_per_round > self.n_clients {
            return Err(anyhow!(
                "k_per_round {} must be in 1..={}",
                self.k_per_round,
                self.n_clients
            ));
        }
        if !(self.lr > 0.0) {
            return Err(anyhow!("lr must be positive"));
        }
        if self.tau_min == 0 || self.tau_min > self.tau_max {
            return Err(anyhow!("bad tau range [{}, {}]", self.tau_min, self.tau_max));
        }
        if self.rho < 0.0 || self.mu_max <= 0.0 {
            return Err(anyhow!("budgets must be positive"));
        }
        if self.workers == 0 {
            return Err(anyhow!("workers must be at least 1"));
        }
        if self.staleness_alpha.is_nan() || self.staleness_alpha < 0.0 {
            return Err(anyhow!("staleness_alpha must be non-negative"));
        }
        if !(self.quorum_margin > 0.0 && self.quorum_margin <= 1.0) {
            return Err(anyhow!(
                "quorum_margin must be in (0, 1], got {}",
                self.quorum_margin
            ));
        }
        if self.quorum_floor == 0 {
            return Err(anyhow!("quorum_floor must be at least 1"));
        }
        if self.hierarchy > 1 && !self.quorum.is_active() {
            return Err(anyhow!(
                "hierarchy {} needs quorum aggregation (--quorum K|auto): edge \
                 aggregators reuse the quorum/staleness machinery per level",
                self.hierarchy
            ));
        }
        if self.hierarchy > self.k_per_round {
            return Err(anyhow!(
                "hierarchy {} exceeds the cohort size {} — every edge needs at \
                 least one member",
                self.hierarchy,
                self.k_per_round
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_valid() {
        for fam in ["cnn", "resnet", "rnn"] {
            for scale in [Scale::Smoke, Scale::Paper] {
                ExperimentConfig::preset(fam, scale).validate().unwrap();
            }
        }
    }

    #[test]
    fn family_defaults() {
        assert_eq!(ExperimentConfig::preset("cnn", Scale::Smoke).partition, Partition::Gamma(40.0));
        assert_eq!(ExperimentConfig::preset("resnet", Scale::Smoke).partition, Partition::Phi(0.4));
        assert_eq!(ExperimentConfig::preset("rnn", Scale::Smoke).partition, Partition::Natural);
    }

    #[test]
    fn cli_overrides() {
        let args = Args::parse_from(
            ["--clients", "50", "--k", "7", "--gamma", "80", "--lr", "0.2"]
                .iter()
                .map(|s| s.to_string()),
        );
        let c = ExperimentConfig::preset("cnn", Scale::Smoke).apply_args(&args).unwrap();
        assert_eq!(c.n_clients, 50);
        assert_eq!(c.k_per_round, 7);
        assert_eq!(c.partition, Partition::Gamma(80.0));
        assert!((c.lr - 0.2).abs() < 1e-6);
    }

    #[test]
    fn workers_knob_parses_and_validates() {
        assert_eq!(ExperimentConfig::preset("cnn", Scale::Smoke).workers, 1);
        let args = Args::parse_from(["--workers", "4"].iter().map(|s| s.to_string()));
        let c = ExperimentConfig::preset("cnn", Scale::Smoke).apply_args(&args).unwrap();
        assert_eq!(c.workers, 4);
        let mut bad = ExperimentConfig::preset("cnn", Scale::Smoke);
        bad.workers = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn pool_and_overlap_knobs() {
        let base = ExperimentConfig::preset("cnn", Scale::Smoke);
        assert_eq!(base.pool_engines, 0);
        assert!(!base.overlap);
        assert_eq!(base.pool_size(), base.workers, "pool defaults to one engine per worker");

        let args = Args::parse_from(
            ["--workers", "4", "--pool", "2", "--overlap"].iter().map(|s| s.to_string()),
        );
        let c = ExperimentConfig::preset("cnn", Scale::Smoke).apply_args(&args).unwrap();
        assert_eq!(c.workers, 4);
        assert_eq!(c.pool_engines, 2);
        assert_eq!(c.pool_size(), 2);
        assert!(c.overlap);

        let j = crate::codec::json::parse(r#"{"workers": 3, "pool": 3, "overlap": true}"#).unwrap();
        let c = ExperimentConfig::from_json("cnn", Scale::Smoke, &j).unwrap();
        assert_eq!((c.workers, c.pool_size()), (3, 3));
        assert!(c.overlap);
    }

    #[test]
    fn quorum_knobs_parse_and_validate() {
        let base = ExperimentConfig::preset("cnn", Scale::Smoke);
        assert_eq!(base.quorum, QuorumKnob::Off, "quorum defaults to off (full barrier)");
        assert!(!base.quorum.is_active());
        assert_eq!(base.staleness_alpha, 1.0);
        assert_eq!(base.quorum_margin, 0.5);
        assert_eq!(base.quorum_floor, 1);

        let args = Args::parse_from(
            ["--quorum", "3", "--staleness-alpha", "2.5"].iter().map(|s| s.to_string()),
        );
        let c = ExperimentConfig::preset("cnn", Scale::Smoke).apply_args(&args).unwrap();
        assert_eq!(c.quorum, QuorumKnob::Fixed(3));
        assert!(c.quorum.is_active());
        assert!((c.staleness_alpha - 2.5).abs() < 1e-12);

        let j = crate::codec::json::parse(r#"{"quorum": 4, "staleness_alpha": 0.5}"#).unwrap();
        let c = ExperimentConfig::from_json("cnn", Scale::Smoke, &j).unwrap();
        assert_eq!(c.quorum, QuorumKnob::Fixed(4));
        assert!((c.staleness_alpha - 0.5).abs() < 1e-12);

        let mut bad = ExperimentConfig::preset("cnn", Scale::Smoke);
        bad.staleness_alpha = -1.0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn quorum_auto_parses_from_cli_and_json() {
        assert_eq!(QuorumKnob::parse("auto").unwrap(), QuorumKnob::Auto);
        assert_eq!(QuorumKnob::parse("0").unwrap(), QuorumKnob::Off);
        assert_eq!(QuorumKnob::parse("7").unwrap(), QuorumKnob::Fixed(7));
        assert!(QuorumKnob::parse("maybe").is_err());

        let args = Args::parse_from(
            ["--quorum", "auto", "--quorum-margin", "0.3", "--quorum-floor", "2"]
                .iter()
                .map(|s| s.to_string()),
        );
        let c = ExperimentConfig::preset("cnn", Scale::Smoke).apply_args(&args).unwrap();
        assert_eq!(c.quorum, QuorumKnob::Auto);
        assert!(c.quorum.is_active());
        assert!((c.quorum_margin - 0.3).abs() < 1e-12);
        assert_eq!(c.quorum_floor, 2);

        // JSON parity: string "auto" and the two controller knobs
        let j = crate::codec::json::parse(
            r#"{"quorum": "auto", "quorum_margin": 0.25, "quorum_floor": 3}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json("cnn", Scale::Smoke, &j).unwrap();
        assert_eq!(c.quorum, QuorumKnob::Auto);
        assert!((c.quorum_margin - 0.25).abs() < 1e-12);
        assert_eq!(c.quorum_floor, 3);

        // malformed JSON `quorum` values are errors, never a silent
        // fall-back to the synchronous default
        for bad_doc in [r#"{"quorum": true}"#, r#"{"quorum": -1}"#, r#"{"quorum": "fast"}"#] {
            let j = crate::codec::json::parse(bad_doc).unwrap();
            assert!(
                ExperimentConfig::from_json("cnn", Scale::Smoke, &j).is_err(),
                "{bad_doc} must be rejected"
            );
        }

        // controller knobs validate
        let mut bad = ExperimentConfig::preset("cnn", Scale::Smoke);
        bad.quorum_margin = 0.0;
        assert!(bad.validate().is_err());
        bad.quorum_margin = 1.5;
        assert!(bad.validate().is_err());
        let mut bad = ExperimentConfig::preset("cnn", Scale::Smoke);
        bad.quorum_floor = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn scenario_and_dropout_policy_knobs() {
        let base = ExperimentConfig::preset("cnn", Scale::Smoke);
        assert_eq!(base.scenario, Scenario::Stable, "scenario defaults to stable (no churn)");
        assert_eq!(base.dropout_policy, DropoutPolicy::Survivors);

        let args = Args::parse_from(
            ["--scenario", "correlated-dropout", "--dropout-policy", "error"]
                .iter()
                .map(|s| s.to_string()),
        );
        let c = ExperimentConfig::preset("cnn", Scale::Smoke).apply_args(&args).unwrap();
        assert_eq!(c.scenario.name(), "correlated-dropout");
        assert_eq!(c.dropout_policy, DropoutPolicy::Error);

        // JSON parity: catalog-name strings
        let j = crate::codec::json::parse(
            r#"{"scenario": "flash-crowd-churn", "dropout_policy": "survivors"}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json("cnn", Scale::Smoke, &j).unwrap();
        assert_eq!(c.scenario.name(), "flash-crowd-churn");
        assert_eq!(c.dropout_policy, DropoutPolicy::Survivors);

        // every catalog name parses through both surfaces
        for name in crate::simulation::SCENARIO_CATALOG {
            let args =
                Args::parse_from(["--scenario", name].iter().map(|s| s.to_string()));
            ExperimentConfig::preset("cnn", Scale::Smoke).apply_args(&args).unwrap();
            let doc = crate::codec::json::parse(&format!(r#"{{"scenario": "{name}"}}"#)).unwrap();
            ExperimentConfig::from_json("cnn", Scale::Smoke, &doc).unwrap();
        }

        // malformed values are errors, never a silent fall-back
        let bad_cli = Args::parse_from(["--scenario", "mayhem"].iter().map(|s| s.to_string()));
        assert!(ExperimentConfig::preset("cnn", Scale::Smoke).apply_args(&bad_cli).is_err());
        let bad_pol =
            Args::parse_from(["--dropout-policy", "retry"].iter().map(|s| s.to_string()));
        assert!(ExperimentConfig::preset("cnn", Scale::Smoke).apply_args(&bad_pol).is_err());
        for bad_doc in
            [r#"{"scenario": 3}"#, r#"{"scenario": "mayhem"}"#, r#"{"dropout_policy": true}"#]
        {
            let j = crate::codec::json::parse(bad_doc).unwrap();
            assert!(
                ExperimentConfig::from_json("cnn", Scale::Smoke, &j).is_err(),
                "{bad_doc} must be rejected"
            );
        }
    }

    #[test]
    fn population_and_hierarchy_knobs() {
        let base = ExperimentConfig::preset("cnn", Scale::Smoke);
        assert_eq!(base.population, PopulationMode::Eager, "population defaults to eager");
        assert_eq!(base.hierarchy, 0, "hierarchy defaults to flat");

        assert_eq!(PopulationMode::parse("eager").unwrap(), PopulationMode::Eager);
        assert_eq!(PopulationMode::parse("lazy").unwrap(), PopulationMode::Lazy);
        assert_eq!(PopulationMode::Lazy.name(), "lazy");
        assert!(PopulationMode::parse("huge").is_err());

        let args = Args::parse_from(
            ["--population", "lazy", "--hierarchy", "4", "--quorum", "auto", "--clients", "100000"]
                .iter()
                .map(|s| s.to_string()),
        );
        let c = ExperimentConfig::preset("cnn", Scale::Smoke).apply_args(&args).unwrap();
        assert_eq!(c.population, PopulationMode::Lazy);
        assert_eq!(c.hierarchy, 4);
        assert_eq!(c.n_clients, 100_000);

        // JSON parity
        let j = crate::codec::json::parse(
            r#"{"population": "lazy", "hierarchy": 2, "quorum": "auto"}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json("cnn", Scale::Smoke, &j).unwrap();
        assert_eq!(c.population, PopulationMode::Lazy);
        assert_eq!(c.hierarchy, 2);

        // malformed values are errors, never a silent fall-back
        let bad_cli = Args::parse_from(["--population", "huge"].iter().map(|s| s.to_string()));
        assert!(ExperimentConfig::preset("cnn", Scale::Smoke).apply_args(&bad_cli).is_err());
        for bad_doc in [r#"{"population": 3}"#, r#"{"population": "huge"}"#] {
            let j = crate::codec::json::parse(bad_doc).unwrap();
            assert!(
                ExperimentConfig::from_json("cnn", Scale::Smoke, &j).is_err(),
                "{bad_doc} must be rejected"
            );
        }

        // hierarchy without quorum is rejected (edges reuse the quorum
        // machinery), as is an edge tree wider than the cohort
        let mut bad = ExperimentConfig::preset("cnn", Scale::Smoke);
        bad.hierarchy = 2;
        assert!(bad.validate().is_err());
        bad.quorum = QuorumKnob::Auto;
        bad.validate().unwrap();
        bad.hierarchy = bad.k_per_round + 1;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn codec_knob_parses_from_cli_and_json() {
        use crate::codec::{CodecCfg, Encoding};
        let base = ExperimentConfig::preset("cnn", Scale::Smoke);
        assert_eq!(base.codec, CodecCfg::Analytic, "codec defaults to analytic billing");

        let args = Args::parse_from(["--codec", "wire:q8,topk=0.25"].iter().map(|s| s.to_string()));
        let c = ExperimentConfig::preset("cnn", Scale::Smoke).apply_args(&args).unwrap();
        assert_eq!(c.codec, CodecCfg::Wire(Encoding { q8: true, topk: Some(0.25) }));

        // JSON parity: the same knob grammar as the CLI
        let j = crate::codec::json::parse(r#"{"codec": "wire:q8"}"#).unwrap();
        let c = ExperimentConfig::from_json("cnn", Scale::Smoke, &j).unwrap();
        assert_eq!(c.codec, CodecCfg::Wire(Encoding { q8: true, topk: None }));

        // malformed values are errors, never a silent fall-back
        let bad_cli = Args::parse_from(["--codec", "zip"].iter().map(|s| s.to_string()));
        assert!(ExperimentConfig::preset("cnn", Scale::Smoke).apply_args(&bad_cli).is_err());
        for bad_doc in [r#"{"codec": 3}"#, r#"{"codec": "wire:topk=2"}"#] {
            let j = crate::codec::json::parse(bad_doc).unwrap();
            assert!(
                ExperimentConfig::from_json("cnn", Scale::Smoke, &j).is_err(),
                "{bad_doc} must be rejected"
            );
        }
    }

    #[test]
    fn fault_knobs_parse_from_cli_and_json() {
        use crate::coordinator::resilience::FaultAction;
        let base = ExperimentConfig::preset("cnn", Scale::Smoke);
        assert!(base.faults.is_off(), "faults default to off (byte-identical runs)");
        assert_eq!(base.fault_policy, FaultPolicyCfg::default());

        let args = Args::parse_from(
            ["--faults", "exec=0.1,corrupt=0.05", "--fault-policy", "exec=retry,corrupt=replan,budget=3"]
                .iter()
                .map(|s| s.to_string()),
        );
        let c = ExperimentConfig::preset("cnn", Scale::Smoke).apply_args(&args).unwrap();
        assert!((c.faults.rate(crate::simulation::FaultClass::Exec) - 0.1).abs() < 1e-12);
        assert!((c.faults.rate(crate::simulation::FaultClass::Corrupt) - 0.05).abs() < 1e-12);
        assert_eq!(c.fault_policy.corrupt, FaultAction::Replan);
        assert_eq!(c.fault_policy.budget, 3);

        // JSON parity: the same knob grammar as the CLI
        let j = crate::codec::json::parse(
            r#"{"faults": "partition=0.2", "fault_policy": "fail"}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json("cnn", Scale::Smoke, &j).unwrap();
        assert!((c.faults.rate(crate::simulation::FaultClass::Partition) - 0.2).abs() < 1e-12);
        assert_eq!(c.fault_policy.exec, FaultAction::Fail);

        // malformed values are errors, never a silent fall-back to off
        let bad_cli = Args::parse_from(["--faults", "gamma=0.1"].iter().map(|s| s.to_string()));
        assert!(ExperimentConfig::preset("cnn", Scale::Smoke).apply_args(&bad_cli).is_err());
        let bad_pol =
            Args::parse_from(["--fault-policy", "panic"].iter().map(|s| s.to_string()));
        assert!(ExperimentConfig::preset("cnn", Scale::Smoke).apply_args(&bad_pol).is_err());
        for bad_doc in
            [r#"{"faults": 3}"#, r#"{"faults": "exec=2.0"}"#, r#"{"fault_policy": true}"#]
        {
            let j = crate::codec::json::parse(bad_doc).unwrap();
            assert!(
                ExperimentConfig::from_json("cnn", Scale::Smoke, &j).is_err(),
                "{bad_doc} must be rejected"
            );
        }
    }

    #[test]
    fn transport_knob_parses_from_cli_and_json() {
        let base = ExperimentConfig::preset("cnn", Scale::Smoke);
        assert!(base.transport.is_sim(), "transport defaults to the in-process pool");

        let args =
            Args::parse_from(["--transport", "tcp:127.0.0.1:0"].iter().map(|s| s.to_string()));
        let c = ExperimentConfig::preset("cnn", Scale::Smoke).apply_args(&args).unwrap();
        assert_eq!(c.transport, TransportCfg::Tcp("127.0.0.1:0".into()));

        // JSON parity: the same knob grammar as the CLI
        let j = crate::codec::json::parse(r#"{"transport": "tcp:127.0.0.1:4477"}"#).unwrap();
        let c = ExperimentConfig::from_json("cnn", Scale::Smoke, &j).unwrap();
        assert_eq!(c.transport, TransportCfg::Tcp("127.0.0.1:4477".into()));
        let j = crate::codec::json::parse(r#"{"transport": "sim"}"#).unwrap();
        assert!(ExperimentConfig::from_json("cnn", Scale::Smoke, &j).unwrap().transport.is_sim());

        // malformed values are errors, never a silent fall-back to sim
        let bad_cli = Args::parse_from(["--transport", "udp:x"].iter().map(|s| s.to_string()));
        assert!(ExperimentConfig::preset("cnn", Scale::Smoke).apply_args(&bad_cli).is_err());
        for bad_doc in [r#"{"transport": 3}"#, r#"{"transport": "tcp:"}"#] {
            let j = crate::codec::json::parse(bad_doc).unwrap();
            assert!(
                ExperimentConfig::from_json("cnn", Scale::Smoke, &j).is_err(),
                "{bad_doc} must be rejected"
            );
        }
    }

    #[test]
    fn validation_rejects_bad_k() {
        let mut c = ExperimentConfig::preset("cnn", Scale::Smoke);
        c.k_per_round = c.n_clients + 1;
        assert!(c.validate().is_err());
    }

    #[test]
    fn json_config() {
        let j = crate::codec::json::parse(r#"{"clients": 12, "k": 3, "phi": 60}"#).unwrap();
        let c = ExperimentConfig::from_json("resnet", Scale::Smoke, &j).unwrap();
        assert_eq!(c.n_clients, 12);
        assert_eq!(c.partition, Partition::Phi(0.6));
    }

    #[test]
    fn partition_names() {
        assert_eq!(Partition::Gamma(40.0).name(), "gamma40");
        assert_eq!(Partition::Phi(0.4).name(), "phi40");
        assert_eq!(Partition::Natural.name(), "natural");
    }
}
