//! `heroes` — the experiment launcher.
//!
//! ```text
//! heroes exp <id> [--scale smoke|paper] [--out results/] [overrides...]
//! heroes exp all            # every table/figure at the chosen scale
//! heroes train [--family cnn] [--scheme heroes] [--rounds N] [...]
//! heroes inspect-artifacts  # list compiled executables + cost model
//! heroes list               # available experiments / schemes
//! heroes client --connect <addr>   # executor for --transport tcp
//! ```
//!
//! Overrides: --clients --k --rounds --lr --seed --gamma --phi --tau
//! --tau-max --mu-max --rho --epsilon --eval-every --samples-per-client
//! --test-samples --up-lo/--up-hi/--down-lo/--down-hi --target
//! --workers (round-driver threads; N and 1 are byte-identical)
//! --pool (PJRT engines, default one per worker) --overlap (pipeline
//! round h+1's planning under round h's stragglers; byte-identical)
//! --quorum K|auto (semi-async K-of-N aggregation: a round closes once
//! its K virtually-fastest members land, stragglers merge into later
//! rounds staleness-weighted; K ≥ cohort ≡ the synchronous loop
//! byte-for-byte, K < cohort is seed-deterministic for any worker
//! count. `auto` hands K and α to the per-round adaptive controller:
//! smallest K whose projected staleness penalty fits the Eq. 23
//! ε-margin slice, α annealed against the observed losses — still
//! seed-deterministic, and byte-identical to the full barrier on
//! cohorts with no straggler tail)
//! --quorum-margin (fraction of the ε margin the adaptive controller
//! may spend on staleness, default 0.5)
//! --quorum-floor (adaptive K floor, default 1)
//! --staleness-alpha (α in the late-merge weight 1/(1+s)^α, default 1;
//! the annealing ceiling under --quorum auto)
//! --scenario stable|diurnal-bandwidth|flash-crowd-churn|
//! correlated-dropout (seed-deterministic churn schedule: trace-driven
//! WAN drift, availability windows, mid-round dropouts —
//! `simulation::scenario`; `stable` is byte-identical to the default
//! path; the quorum paths treat a dropped client as a never-arriving
//! straggler and surface infeasible static quorums as typed errors)
//! --dropout-policy survivors|error (full-barrier reaction to a
//! mid-round dropout: re-plan phase C over the survivors — default —
//! or fail the run; default survivors)
//! --population eager|lazy (client-state model: `eager` — default,
//! byte-identical to every prior release — materializes all N clients'
//! data/devices/links up front; `lazy` derives per-client state on
//! demand from `(seed, client_id)` via `simulation::population`, so a
//! round costs O(cohort) in time and memory and `--clients 1000000`
//! is practical; lazy is its own deterministic world, not bit-equal
//! to eager)
//! --hierarchy E (quorum mode only, default 1 = flat: split each
//! round's cohort across E edge aggregators, each running the quorum
//! policy over its sub-cohort and forwarding one composed update over
//! a backhaul link; the root quorums over the E arrivals —
//! `coordinator::hierarchy`. Requires --quorum and E ≤ --k).
//! --codec analytic|wire|wire:q8|wire:q8,topk=R (update-upload codec,
//! `codec` module: `analytic` — default, byte-identical to every prior
//! release — bills the float-count estimate and never frames a payload;
//! the `wire` modes encode each trained update into the `HWU1` frame
//! format and bill ν / TrafficMeter / WAN bytes from the *measured*
//! frame length — `q8` adds per-tensor uint8 affine quantization,
//! `topk=R` magnitude sparsification keeping a fraction R ∈ (0, 1] of
//! each tensor, and the decoded — dequantized, densified — update is
//! what aggregates, so compression error honestly reaches the global
//! model. Encoded bytes are a pure function of (plan, update, cfg):
//! wire runs stay seed-deterministic for any --workers/--pool).
//! --faults off|exec=R,corrupt=R,partition=R (seeded engine-level
//! fault injection, `simulation::faults`: per-(round, client) draws of
//! typed faults — `exec` engine/worker failures, `corrupt` HWU1 frame
//! corruption caught as typed codec errors, `partition` transient
//! network loss. Faults are schedule facts, pure in
//! `(seed, round, client)`: a faulted run is bit-identical for any
//! --workers/--pool/--overlap, and `off` — the default — is
//! byte-identical to every prior release)
//! --fault-policy retry|replan|fail or per-class
//! exec=A,corrupt=A,partition=A[,budget=N][,backoff=S] (how the
//! coordinator answers each injected fault: `retry` re-runs the task
//! up to `budget` times at `backoff` simulated seconds per attempt —
//! the default, budget 2, backoff 5 — `replan` abandons the client
//! for the round and lets phase C re-plan over the survivors, `fail`
//! aborts the run with a typed error; per-run accounting lands in the
//! recorder output as the `resilience` ledger, and the adaptive
//! quorum controller reads the observed fault rate as churn)
//! --transport sim|tcp:<addr> (which backend executes dispatched
//! tasks, `transport` module: `sim` — default, byte-identical to every
//! prior release — runs the in-process worker pool; `tcp:<addr>` binds
//! a localhost server — `tcp:127.0.0.1:0` picks a free port — and
//! dispatches length-prefixed `HWU1`-framed tasks to connected
//! executors: in-process loopback threads, or `heroes client
//! --connect <addr>` processes. All decisions are virtual-clock plan
//! facts carried in the messages, so a tcp run must reproduce the sim
//! byte for byte — same plans, chosen K, aggregated model and billed
//! bytes; only wall clocks differ. Wall time only decides whether a
//! fate arrives: a timed-out or vanished executor completes its tasks
//! as `Dropped`, a protocol violation as `Faulted`. Needs the `net`
//! cargo feature — built without it, `--transport tcp:` is a typed
//! error)

// Outside the determinism layers (CONTRIBUTING.md): CLI surface,
// report generation and dev tooling may panic on programmer error.
#![allow(clippy::unwrap_used, clippy::expect_used)]


use anyhow::{anyhow, Result};
use heroes::baselines::ALL_SCHEMES;
use heroes::config::{ExperimentConfig, Scale};
use heroes::experiments::{run_experiment, run_scheme, ExpCtx, StopCondition, ALL_EXPERIMENTS};
use heroes::runtime::{EnginePool, Manifest};
use heroes::util::cli::Args;
use std::path::PathBuf;

fn main() {
    heroes::util::logging::init_from_env();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::parse_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "exp" => cmd_exp(&args),
        "train" => cmd_train(&args),
        "client" => cmd_client(&args),
        "inspect-artifacts" => cmd_inspect(),
        "list" => {
            println!("experiments: {}", ALL_EXPERIMENTS.join(" "));
            println!("schemes:     {}", ALL_SCHEMES.join(" "));
            Ok(())
        }
        _ => {
            println!("usage: heroes <exp|train|client|inspect-artifacts|list> [...]");
            println!("       see rust/src/main.rs docs for flags");
            Ok(())
        }
    }
}

/// Load the AOT manifest, with a friendly error when artifacts are
/// missing (the only guard — both commands go through here).
fn load_manifest() -> Result<Manifest> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        return Err(anyhow!(
            "artifacts not found in {} — run `make artifacts` first",
            dir.display()
        ));
    }
    Manifest::load(&dir)
}

/// Engine pool sized from the CLI: `--pool N` engines, defaulting to one
/// per `--workers` thread (so parallel dispatch never contends on one
/// PJRT client).
fn make_pool(args: &Args) -> Result<EnginePool> {
    let workers = args.get_usize("workers", 1)?;
    let engines = args.get_usize("pool", 0)?;
    EnginePool::new(load_manifest()?, heroes::config::resolve_pool_size(workers, engines))
}

fn cmd_exp(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: heroes exp <id|all> [flags]"))?
        .clone();
    let scale = Scale::parse(args.get_or("scale", "smoke"))?;
    let pool = make_pool(args)?;
    let ctx = ExpCtx {
        pool: &pool,
        scale,
        args: args.clone(),
        out_dir: PathBuf::from(args.get_or("out", "results")),
    };
    if id == "all" {
        for name in ALL_EXPERIMENTS {
            run_experiment(name, &ctx)?;
            println!();
        }
        Ok(())
    } else {
        run_experiment(&id, &ctx)
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let family = args.get_or("family", "cnn").to_string();
    let scheme = args.get_or("scheme", "heroes").to_string();
    let scale = Scale::parse(args.get_or("scale", "smoke"))?;
    let cfg = if let Some(path) = args.get("config") {
        let doc = heroes::codec::json::parse_file(std::path::Path::new(path))?;
        ExperimentConfig::from_json(&family, scale, &doc)?.apply_args(args)?
    } else {
        ExperimentConfig::preset(&family, scale).apply_args(args)?
    };
    let pool = EnginePool::new(load_manifest()?, cfg.pool_size())?;
    let stop = StopCondition {
        sim_time: args.get("time-budget").map(|v| v.parse()).transpose().map_err(|_| anyhow!("bad --time-budget"))?,
        traffic_gb: args.get("traffic-budget").map(|v| v.parse()).transpose().map_err(|_| anyhow!("bad --traffic-budget"))?,
        accuracy: args.get("target").map(|v| v.parse()).transpose().map_err(|_| anyhow!("bad --target"))?,
    };
    let rec = run_scheme(&pool, &cfg, &scheme, stop)?;
    let out = PathBuf::from(args.get_or("out", "results"));
    rec.write_files(&out, &format!("train_{family}"))?;
    let last = rec.samples.last().unwrap();
    println!(
        "{scheme}/{family}: {} rounds, sim {:.0}s, traffic {:.4}GB, acc {:.2}%",
        last.round,
        last.sim_time,
        last.traffic_gb,
        last.test_acc * 100.0
    );
    Ok(())
}

/// Executor process for `--transport tcp:<addr>`: connect to the
/// coordinator, greet, and serve task messages until it hangs up. Needs
/// the same `make artifacts` output as the coordinator — both sides run
/// the identical AOT executables, which is what keeps tcp runs
/// byte-identical to the simulation.
fn cmd_client(args: &Args) -> Result<()> {
    let addr = args
        .get("connect")
        .ok_or_else(|| anyhow!("usage: heroes client --connect <host:port>"))?;
    let pool = EnginePool::new(load_manifest()?, 1)?;
    let stream = std::net::TcpStream::connect(addr)
        .map_err(|e| anyhow!("connecting to coordinator {addr}: {e}"))?;
    heroes::transport::client::client_loop(stream, pool.primary())?;
    println!("coordinator closed the session; client exiting");
    Ok(())
}

fn cmd_inspect() -> Result<()> {
    let m = Manifest::load(&Manifest::default_dir())?;
    println!("{} model families, {} executables", m.models.len(), m.executables.len());
    for (fam, info) in &m.models {
        println!(
            "[{fam}] P={} classes={} batch={} layers={}",
            info.cap_p,
            info.classes,
            info.batch,
            info.layers.len()
        );
        for p in 1..=info.cap_p {
            println!(
                "  p={p}: flops/iter composed {:>12.0} dense {:>12.0} | upload bytes composed {:>8} dense {:>8}",
                info.flops_composed[&p], info.flops_dense[&p],
                info.bytes_composed[&p], info.bytes_dense[&p]
            );
        }
    }
    Ok(())
}
