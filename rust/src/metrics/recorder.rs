//! Time-series recorder for one scheme's run.
//!
//! One `Sample` per evaluation point carries the simulated clock, the
//! cumulative traffic (total and split by direction — the CSV and JSON
//! emitters share one schema, pinned by a round-trip test) and the test
//! metrics; the figure/table harnesses query derived quantities
//! (time-to-accuracy, traffic-to-accuracy, accuracy-at-budget) from the
//! recorded series, and experiments persist them as JSON + CSV under
//! `results/`.

use crate::codec::json::Json;
use crate::coordinator::resilience::ResilienceLedger;
use crate::coordinator::RoundReport;
use crate::simulation::TrafficMeter;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One evaluation point of a run.
#[derive(Debug, Clone)]
pub struct Sample {
    pub round: usize,
    /// simulated seconds since start
    pub sim_time: f64,
    /// cumulative PS↔client traffic (GB)
    pub traffic_gb: f64,
    /// cumulative PS→client broadcast bytes
    pub down_bytes: u64,
    /// cumulative client→PS upload bytes
    pub up_bytes: u64,
    pub test_loss: f64,
    pub test_acc: f64,
    /// W^h averaged since the previous sample
    pub avg_wait: f64,
    pub mean_train_loss: f64,
    pub block_variance: f64,
}

/// A scheme's recorded run.
#[derive(Debug, Clone)]
pub struct Recorder {
    pub scheme: String,
    pub samples: Vec<Sample>,
    /// fault-injection ledger (`--faults`): per-class
    /// injected/observed/retried/recovered/abandoned counts attached by
    /// the runner at the end of a faulted run. `None` (fault-free runs)
    /// leaves the JSON output byte-identical to the pre-fault schema.
    resilience: Option<ResilienceLedger>,
    // accumulators between eval points
    waits: Vec<f64>,
    reports: usize,
}

impl Recorder {
    pub fn new(scheme: &str) -> Recorder {
        Recorder {
            scheme: scheme.to_string(),
            samples: Vec::new(),
            resilience: None,
            waits: Vec::new(),
            reports: 0,
        }
    }

    /// Attach the run's resilience ledger (fault-injection runs only —
    /// see the field docs).
    pub fn set_resilience(&mut self, ledger: ResilienceLedger) {
        self.resilience = Some(ledger);
    }

    /// Fold in a round report (between evaluation points).
    pub fn push_round(&mut self, r: &RoundReport) {
        self.waits.push(r.avg_wait);
        self.reports += 1;
    }

    /// Record an evaluation point (test metrics + current clock +
    /// traffic meter — totals and both per-direction counters come from
    /// the same meter so the emitters can never disagree).
    pub fn push_eval(
        &mut self,
        round: usize,
        sim_time: f64,
        traffic: &TrafficMeter,
        test_loss: f64,
        test_acc: f64,
        mean_train_loss: f64,
        block_variance: f64,
    ) {
        let avg_wait = crate::util::stats::mean(&self.waits);
        self.waits.clear();
        self.samples.push(Sample {
            round,
            sim_time,
            traffic_gb: traffic.total_gb(),
            down_bytes: traffic.down_bytes,
            up_bytes: traffic.up_bytes,
            test_loss,
            test_acc,
            avg_wait,
            mean_train_loss,
            block_variance,
        });
    }

    // ------------- derived metrics (paper §VI-B2) -------------

    /// Completion time (metric ③): first simulated time reaching `target`
    /// accuracy.
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        self.samples.iter().find(|s| s.test_acc >= target).map(|s| s.sim_time)
    }

    /// Network traffic (metric ④) consumed by the time `target` accuracy
    /// is first reached.
    pub fn traffic_to_accuracy(&self, target: f64) -> Option<f64> {
        self.samples.iter().find(|s| s.test_acc >= target).map(|s| s.traffic_gb)
    }

    /// Best accuracy achieved within a simulated-time budget.
    pub fn accuracy_at_time(&self, budget: f64) -> f64 {
        self.samples
            .iter()
            .filter(|s| s.sim_time <= budget)
            .map(|s| s.test_acc)
            .fold(0.0, f64::max)
    }

    /// Best accuracy achieved within a traffic budget (GB).
    pub fn accuracy_at_traffic(&self, budget_gb: f64) -> f64 {
        self.samples
            .iter()
            .filter(|s| s.traffic_gb <= budget_gb)
            .map(|s| s.test_acc)
            .fold(0.0, f64::max)
    }

    /// Mean of the recorded per-sample average waits (metric ②).
    pub fn mean_wait(&self) -> f64 {
        crate::util::stats::mean(&self.samples.iter().map(|s| s.avg_wait).collect::<Vec<_>>())
    }

    pub fn final_accuracy(&self) -> f64 {
        self.samples.last().map(|s| s.test_acc).unwrap_or(0.0)
    }

    // ------------- persistence -------------

    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .samples
            .iter()
            .map(|s| {
                Json::Obj(BTreeMap::from([
                    ("round".into(), Json::from(s.round)),
                    ("sim_time".into(), Json::from(s.sim_time)),
                    ("traffic_gb".into(), Json::from(s.traffic_gb)),
                    // u64 counters take the lossless Json::Uint path —
                    // the old `as usize` + f64 route truncated > 2^53
                    ("down_bytes".into(), Json::from(s.down_bytes)),
                    ("up_bytes".into(), Json::from(s.up_bytes)),
                    ("test_loss".into(), Json::from(s.test_loss)),
                    ("test_acc".into(), Json::from(s.test_acc)),
                    ("avg_wait".into(), Json::from(s.avg_wait)),
                    ("mean_train_loss".into(), Json::from(s.mean_train_loss)),
                    ("block_variance".into(), Json::from(s.block_variance)),
                ]))
            })
            .collect();
        let mut fields = vec![
            ("scheme", Json::from(self.scheme.clone())),
            ("samples", Json::Arr(rows)),
        ];
        if let Some(ledger) = &self.resilience {
            // run-level key, not a per-sample column: the ledger is a
            // whole-run sum, and the CSV/JSON sample schemas stay in
            // agreement (the schema test inspects sample rows only)
            fields.push(("resilience", ledger.to_json()));
        }
        Json::obj(fields)
    }

    /// CSV columns; one name per [`Sample`] field, same set the JSON
    /// emitter writes (the schema-agreement test pins this).
    pub const CSV_HEADER: &str = "round,sim_time,traffic_gb,down_bytes,up_bytes,\
                                          test_loss,test_acc,avg_wait,mean_train_loss,\
                                          block_variance";

    pub fn to_csv(&self) -> String {
        let mut out = String::from(Self::CSV_HEADER);
        out.push('\n');
        for s in &self.samples {
            out.push_str(&format!(
                "{},{:.3},{:.6},{},{},{:.5},{:.5},{:.4},{:.5},{:.4}\n",
                s.round, s.sim_time, s.traffic_gb, s.down_bytes, s.up_bytes, s.test_loss,
                s.test_acc, s.avg_wait, s.mean_train_loss, s.block_variance
            ));
        }
        out
    }

    /// Write `<dir>/<prefix>_<scheme>.{json,csv}`.
    pub fn write_files(&self, dir: &Path, prefix: &str) -> Result<()> {
        std::fs::create_dir_all(dir).context("creating results dir")?;
        let base = format!("{prefix}_{}", self.scheme);
        std::fs::write(dir.join(format!("{base}.json")), self.to_json().to_string_pretty())?;
        std::fs::write(dir.join(format!("{base}.csv")), self.to_csv())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A meter holding the given per-direction byte totals.
    fn meter(down: u64, up: u64) -> TrafficMeter {
        let mut t = TrafficMeter::new();
        t.record_down(down);
        t.record_up(up);
        t
    }

    fn rec() -> Recorder {
        let mut r = Recorder::new("test");
        // three eval points with rising accuracy and traffic
        r.push_eval(0, 10.0, &meter(60_000_000, 40_000_000), 2.0, 0.30, 2.0, 0.0);
        r.push_eval(5, 50.0, &meter(300_000_000, 200_000_000), 1.5, 0.55, 1.5, 1.0);
        r.push_eval(10, 100.0, &meter(600_000_000, 400_000_000), 1.0, 0.70, 1.0, 2.0);
        r
    }

    #[test]
    fn derived_metrics() {
        let r = rec();
        assert_eq!(r.time_to_accuracy(0.5), Some(50.0));
        assert_eq!(r.time_to_accuracy(0.9), None);
        assert_eq!(r.traffic_to_accuracy(0.6), Some(1.0));
        assert!((r.accuracy_at_time(60.0) - 0.55).abs() < 1e-12);
        assert!((r.accuracy_at_traffic(0.2) - 0.30).abs() < 1e-12);
        assert!((r.final_accuracy() - 0.70).abs() < 1e-12);
    }

    #[test]
    fn wait_accumulation_resets_per_eval() {
        let mut r = Recorder::new("w");
        let mk = |wait: f64| crate::coordinator::RoundReport {
            round: 0,
            round_time: 1.0,
            avg_wait: wait,
            mean_loss: 1.0,
            taus: vec![],
            widths: vec![],
            down_bytes: 0,
            up_bytes: 0,
            completion_times: vec![],
            block_variance: 0.0,
        };
        r.push_round(&mk(2.0));
        r.push_round(&mk(4.0));
        r.push_eval(1, 1.0, &TrafficMeter::new(), 1.0, 0.1, 1.0, 0.0);
        assert!((r.samples[0].avg_wait - 3.0).abs() < 1e-12);
        r.push_round(&mk(10.0));
        r.push_eval(2, 2.0, &TrafficMeter::new(), 1.0, 0.2, 1.0, 0.0);
        assert!((r.samples[1].avg_wait - 10.0).abs() < 1e-12);
    }

    #[test]
    fn csv_and_json_shapes() {
        let r = rec();
        let csv = r.to_csv();
        assert_eq!(csv.lines().count(), 4); // header + 3 rows
        let j = r.to_json();
        assert_eq!(j.get("scheme").unwrap().as_str(), Some("test"));
        assert_eq!(j.get("samples").unwrap().as_arr().unwrap().len(), 3);
        // round-trips through our parser
        let parsed = crate::codec::json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("samples").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn csv_and_json_emitters_share_one_schema() {
        // regression: the emitters disagreed on the per-direction byte
        // counters (up_bytes/down_bytes existed in one surface but not
        // the CSV header) — the column set is now pinned to be identical
        let r = rec();
        let header: std::collections::BTreeSet<&str> =
            Recorder::CSV_HEADER.split(',').collect();
        let rows = r.to_json();
        let row = rows.get("samples").unwrap().as_arr().unwrap()[0].as_obj().unwrap();
        let json_keys: std::collections::BTreeSet<&str> =
            row.keys().map(String::as_str).collect();
        assert_eq!(header, json_keys, "CSV header and JSON row keys must agree");
        // and the CSV body has exactly one value per header column
        let csv = r.to_csv();
        for line in csv.lines().skip(1) {
            assert_eq!(line.split(',').count(), header.len(), "ragged CSV row: {line}");
        }
    }

    #[test]
    fn per_direction_bytes_round_trip_through_both_emitters() {
        let r = rec();
        assert_eq!(r.samples[0].down_bytes, 60_000_000);
        assert_eq!(r.samples[0].up_bytes, 40_000_000);
        assert!((r.samples[0].traffic_gb - 0.1).abs() < 1e-12, "gb derives from the meter");

        // JSON: parse back and compare the counters exactly
        let parsed = crate::codec::json::parse(&r.to_json().to_string_pretty()).unwrap();
        let row = &parsed.get("samples").unwrap().as_arr().unwrap()[1];
        assert_eq!(row.get("down_bytes").unwrap().as_usize(), Some(300_000_000));
        assert_eq!(row.get("up_bytes").unwrap().as_usize(), Some(200_000_000));

        // CSV: the byte columns are exact integers in header position
        let csv = r.to_csv();
        let cols: Vec<&str> = Recorder::CSV_HEADER.split(',').collect();
        let di = cols.iter().position(|&c| c == "down_bytes").unwrap();
        let ui = cols.iter().position(|&c| c == "up_bytes").unwrap();
        let row2: Vec<&str> = csv.lines().nth(2).unwrap().split(',').collect();
        assert_eq!(row2[di].parse::<u64>().unwrap(), 300_000_000);
        assert_eq!(row2[ui].parse::<u64>().unwrap(), 200_000_000);
    }

    #[test]
    fn resilience_ledger_is_a_run_level_json_key() {
        // fault-free runs keep the pre-fault schema byte for byte;
        // faulted runs gain one run-level key (never a sample column, so
        // the CSV/JSON schema-agreement test is untouched)
        let mut r = rec();
        assert!(r.to_json().get("resilience").is_none());

        let mut ledger = ResilienceLedger::default();
        ledger.dispatched = 10;
        ledger.exec.injected = 3;
        ledger.exec.observed = 2;
        ledger.exec.retried = 4;
        ledger.exec.recovered = 1;
        ledger.exec.abandoned = 1;
        r.set_resilience(ledger);
        let parsed = crate::codec::json::parse(&r.to_json().to_string_pretty()).unwrap();
        let res = parsed.get("resilience").expect("faulted runs carry the ledger");
        assert_eq!(res.get("dispatched").unwrap().as_u64(), Some(10));
        let exec = res.get("exec").unwrap();
        assert_eq!(exec.get("injected").unwrap().as_u64(), Some(3));
        assert_eq!(exec.get("observed").unwrap().as_u64(), Some(2));
        assert_eq!(exec.get("retried").unwrap().as_u64(), Some(4));
        assert!((res.get("observed_fault_rate").unwrap().as_f64().unwrap() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn counters_above_4gib_survive_the_json_round_trip() {
        // regression: `Json::from(s.down_bytes as usize)` routed the
        // counters through f64 — exact here, but the same From<usize>
        // truncated anything above 2^53, and a long simulated campaign's
        // cumulative traffic gets there. The counters now ride
        // Json::Uint; pin a > 4 GiB (and a > 2^53) value end to end.
        let mut r = Recorder::new("big");
        let big_down = 9_007_199_254_740_995u64; // 2^53 + 3: not f64-representable
        let big_up = 5_000_000_000u64; // > 4 GiB
        r.push_eval(
            1,
            1.0,
            &meter(big_down as usize, big_up as usize),
            1.0,
            0.5,
            1.0,
            0.0,
        );
        let parsed = crate::codec::json::parse(&r.to_json().to_string_pretty()).unwrap();
        let row = &parsed.get("samples").unwrap().as_arr().unwrap()[0];
        assert_eq!(row.get("down_bytes").unwrap().as_u64(), Some(big_down));
        assert_eq!(row.get("up_bytes").unwrap().as_u64(), Some(big_up));
    }
}
