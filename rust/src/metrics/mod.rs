//! Metrics recording + the paper's four evaluation metrics (§VI-B2):
//! test accuracy, average waiting time, completion time (to target
//! accuracy) and network traffic.

pub mod recorder;

pub use recorder::{Recorder, Sample};
