//! Metrics recording + the paper's four evaluation metrics (§VI-B2):
//! test accuracy, average waiting time, completion time (to target
//! accuracy) and network traffic.

// The determinism layers promise typed errors, never panics: promote
// slice-index panics to clippy warnings here (CI denies warnings);
// hlint rule P1 enforces the same contract with per-line reasons.
#![warn(clippy::indexing_slicing)]


pub mod recorder;

pub use recorder::{Recorder, Sample};
