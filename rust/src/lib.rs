//! # Heroes — lightweight federated learning with enhanced neural
//! composition and adaptive local update
//!
//! Rust reproduction of *Heroes* (Yan et al., 2023): an FL framework for
//! heterogeneous edge networks combining
//!
//! 1. **enhanced neural composition** — layer weights factored into a
//!    shared neural basis and a blocked coefficient; width-`p` sub-models
//!    compose the `p²` least-trained blocks, and blocks of all shapes
//!    aggregate into one global coefficient (paper §II-B, Eq. 5), and
//! 2. **adaptive local update** — per-client local iteration counts
//!    chosen by a greedy controller driven by the convergence bound
//!    (paper §V, Alg. 1/2).
//!
//! Architecture (DESIGN.md): this crate is Layer 3 — the coordinator.
//! Model compute (Layer 2 JAX graphs calling Layer 1 Pallas kernels) is
//! AOT-compiled to HLO text by `make artifacts` and executed through the
//! PJRT CPU client (`runtime`); python never runs inside the round loop.

pub mod baselines;
pub mod codec;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod simulation;
pub mod tensor;
pub mod transport;
pub mod util;
