//! Substrate utilities built in-house for the offline environment (see
//! DESIGN.md "Substrate inventory"): JSON, RNG, statistics, CLI parsing,
//! bench-lite and prop-lite.

pub mod bench;
pub mod cast;
pub mod cli;
/// crate-private: the public JSON surface is the `crate::codec::json`
/// facade (re-exported value type + parser, streaming writers)
pub(crate) mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
