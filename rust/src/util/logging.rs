//! Minimal `log` backend writing to stderr with wall-clock offsets.
//!
//! The `log` facade is in the vendor set; this is the only implementation
//! (substrate — no env_logger offline). Verbosity comes from the launcher
//! (`--verbose` / `-q`) or `HEROES_LOG=debug|info|warn|error`.

use log::{Level, LevelFilter, Metadata, Record};
use std::time::Instant;

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, _metadata: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let t = self.start.elapsed().as_secs_f64();
            let lvl = match record.level() {
                Level::Error => "ERROR",
                Level::Warn => "WARN ",
                Level::Info => "INFO ",
                Level::Debug => "DEBUG",
                Level::Trace => "TRACE",
            };
            eprintln!("[{t:9.3}s {lvl}] {}", record.args());
        }
    }

    fn flush(&self) {}
}

/// Install the logger once; later calls adjust the level only.
pub fn init(level: LevelFilter) {
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| {
        let logger = Box::leak(Box::new(StderrLogger { start: Instant::now() }));
        let _ = log::set_logger(logger);
    });
    log::set_max_level(level);
}

/// Level from the HEROES_LOG env var, defaulting to `info`.
pub fn init_from_env() {
    let lvl = match std::env::var("HEROES_LOG").as_deref() {
        Ok("trace") => LevelFilter::Trace,
        Ok("debug") => LevelFilter::Debug,
        Ok("warn") => LevelFilter::Warn,
        Ok("error") => LevelFilter::Error,
        Ok("off") => LevelFilter::Off,
        _ => LevelFilter::Info,
    };
    init(lvl);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init(LevelFilter::Info);
        init(LevelFilter::Debug);
        log::info!("logging test line");
        assert_eq!(log::max_level(), LevelFilter::Debug);
    }
}
