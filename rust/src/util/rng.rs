//! Deterministic RNG substrate (no `rand` in the offline vendor set).
//!
//! xoshiro256** seeded via SplitMix64. Every stochastic component of the
//! system (client sampling, data synthesis, device/network fluctuation,
//! parameter init) takes an explicit `Rng` so experiments replay
//! bit-identically from a seed — results in EXPERIMENTS.md cite the seed.

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 works (SplitMix64 whitens it).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent child stream (for per-client / per-module RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f64 in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform usize in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free enough for simulation use; the modulo
        // bias at n << 2^64 is negligible, but use widening multiply anyway.
        let x = self.next_u64();
        (((x as u128) * (n as u128)) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(f64::MIN_POSITIVE);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean / std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Sample k distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Vector of standard normals (used for parameter init).
    pub fn normal_vec(&mut self, n: usize, std: f64) -> Vec<f32> {
        (0..n).map(|_| (self.normal() * std) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(6);
        let s = r.sample_distinct(100, 10);
        assert_eq!(s.len(), 10);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
        assert!(sorted.iter().all(|&x| x < 100));
        // full sample is a permutation
        let mut all = r.sample_distinct(50, 50);
        all.sort_unstable();
        assert_eq!(all, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(8);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
