//! Small statistics toolkit used by the simulator, the controller and the
//! metrics recorder (substrate — keeps the hot paths allocation-free).

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance (paper Eq. 21 uses population form).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Linear-interpolated quantile, q in [0,1]. Sorts a copy.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Streaming mean/variance (Welford). Used by the metrics recorder so the
/// round loop never buffers per-iteration samples.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
}

/// Exponential moving average — the PS smooths client capability estimates
/// with this (dynamic edge conditions, paper §V-C).
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ema { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((variance(&xs) - 4.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_slices_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[]), 0.0);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((quantile(&xs, 0.25) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.5, -3.0, 7.5, 0.25, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert_eq!(w.count(), xs.len() as u64);
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.variance() - variance(&xs)).abs() < 1e-12);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.get(), None);
        e.push(10.0);
        assert_eq!(e.get(), Some(10.0));
        for _ in 0..50 {
            e.push(2.0);
        }
        assert!((e.get().unwrap() - 2.0).abs() < 1e-6);
    }
}
