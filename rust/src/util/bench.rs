//! bench-lite: a minimal benchmarking harness (substrate — no criterion in
//! the offline vendor set).
//!
//! Every `benches/*.rs` target (`harness = false`) uses this: warmup,
//! fixed-duration sampling, and a median / mean / p95 report in a
//! criterion-like one-line format. Also used by the EXPERIMENTS.md §Perf
//! iteration loop to keep before/after numbers comparable.

// Outside the determinism layers (CONTRIBUTING.md): CLI surface,
// report generation and dev tooling may panic on programmer error.
#![allow(clippy::unwrap_used, clippy::expect_used)]


use std::time::{Duration, Instant};

/// One benchmark's collected samples (seconds per iteration).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn median(&self) -> f64 {
        crate::util::stats::median(&self.samples)
    }

    pub fn mean(&self) -> f64 {
        crate::util::stats::mean(&self.samples)
    }

    pub fn p95(&self) -> f64 {
        crate::util::stats::quantile(&self.samples, 0.95)
    }

    /// criterion-like single line, time auto-scaled.
    pub fn report(&self) -> String {
        format!(
            "{:<40} time: [{} {} {}]  ({} samples x {} iters)",
            self.name,
            fmt_time(self.median()),
            fmt_time(self.mean()),
            fmt_time(self.p95()),
            self.samples.len(),
            self.iters_per_sample,
        )
    }
}

/// Human-readable seconds.
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.2} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: Duration::from_millis(300), measure: Duration::from_secs(2), max_samples: 50 }
    }
}

impl Bench {
    /// Quick profile for heavier end-to-end benches.
    pub fn quick() -> Self {
        Bench { warmup: Duration::from_millis(50), measure: Duration::from_millis(800), max_samples: 12 }
    }

    /// Run `f` repeatedly, printing and returning the result.
    /// `f` receives the iteration index; return value is black-boxed.
    pub fn run<F, R>(&self, name: &str, mut f: F) -> BenchResult
    where
        F: FnMut(u64) -> R,
    {
        // Warmup + calibration: find iters per sample so one sample is ~2ms+.
        let wstart = Instant::now();
        let mut calib_iters = 0u64;
        while wstart.elapsed() < self.warmup {
            std::hint::black_box(f(calib_iters));
            calib_iters += 1;
        }
        let per_iter = if calib_iters > 0 {
            wstart.elapsed().as_secs_f64() / calib_iters as f64
        } else {
            self.warmup.as_secs_f64()
        };
        let iters_per_sample = ((2e-3 / per_iter).ceil() as u64).max(1);

        let mut samples = Vec::new();
        let mstart = Instant::now();
        let mut idx = 0u64;
        while mstart.elapsed() < self.measure && samples.len() < self.max_samples {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                std::hint::black_box(f(idx));
                idx += 1;
            }
            samples.push(t0.elapsed().as_secs_f64() / iters_per_sample as f64);
        }
        if samples.is_empty() {
            // single mandatory sample for very slow bodies
            let t0 = Instant::now();
            std::hint::black_box(f(idx));
            samples.push(t0.elapsed().as_secs_f64());
        }
        let res = BenchResult { name: name.to_string(), samples, iters_per_sample };
        println!("{}", res.report());
        res
    }

    /// Time a single execution (for end-to-end experiment benches where
    /// one run IS the measurement).
    pub fn run_once<F, R>(&self, name: &str, f: F) -> BenchResult
    where
        F: FnOnce() -> R,
    {
        let t0 = Instant::now();
        std::hint::black_box(f());
        let dt = t0.elapsed().as_secs_f64();
        let res = BenchResult { name: name.to_string(), samples: vec![dt], iters_per_sample: 1 };
        println!("{}", res.report());
        res
    }
}

/// Shared body of the `benches/bench_fig*.rs` / `bench_table1.rs`
/// harnesses (formerly nine copy-pasted mains): run one paper experiment
/// end-to-end in a miniature world — a few clients, a few rounds — and
/// time it with [`Bench::quick`]. The bench measures the harness, the
/// real figures come from `heroes exp`. Skips gracefully without AOT
/// artifacts, like every PJRT-dependent target.
pub fn experiment_miniature(id: &str) {
    use crate::experiments::{run_experiment, ExpCtx};
    use crate::runtime::{EnginePool, Manifest};

    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("(artifacts missing — run `make artifacts`)");
        return;
    }
    let pool = EnginePool::single(Manifest::load(&dir).unwrap()).unwrap();
    let args = crate::util::cli::Args::parse_from(
        ["--clients", "6", "--k", "3", "--rounds", "6", "--eval-every", "3",
         "--samples-per-client", "24", "--test-samples", "64"]
            .iter()
            .map(|s| s.to_string()),
    );
    let ctx = ExpCtx {
        pool: &pool,
        scale: crate::config::Scale::Smoke,
        args,
        out_dir: std::env::temp_dir().join("heroes_bench_results"),
    };
    Bench::quick().run_once(&format!("{id} (miniature)"), || {
        run_experiment(id, &ctx).unwrap();
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_time_scales() {
        assert!(fmt_time(5e-9).contains("ns"));
        assert!(fmt_time(5e-6).contains("µs"));
        assert!(fmt_time(5e-3).contains("ms"));
        assert!(fmt_time(5.0).contains(" s"));
    }

    #[test]
    fn runs_and_reports() {
        let b = Bench { warmup: Duration::from_millis(5), measure: Duration::from_millis(30), max_samples: 10 };
        let r = b.run("noop", |i| i.wrapping_mul(3));
        assert!(!r.samples.is_empty());
        assert!(r.median() >= 0.0);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn run_once_single_sample() {
        let b = Bench::default();
        let r = b.run_once("one", || 42);
        assert_eq!(r.samples.len(), 1);
    }
}
