//! Audited numeric casts for byte counters.
//!
//! Traffic accounting keeps byte counts in `u64` end to end (the PR 7
//! recorder bug was exactly a counter narrowing through an unchecked
//! `as` cast — hlint rule C1 now flags that class). The one legitimate
//! exit from the u64 domain is a *value-preserving* conversion to `f64`
//! for rate math and reporting, and it lives here so the cast sites are
//! auditable in one place.

/// Exact `f64` view of a byte counter.
///
/// `f64` holds every integer up to 2^53 exactly — about 9 petabytes,
/// far above any traffic total this simulator can book (a debug build
/// checks the bound). Use this instead of `as f64` on `*_bytes` /
/// traffic counters; widening casts (`usize as u64`) stay legal.
pub fn bytes_to_f64(bytes: u64) -> f64 {
    debug_assert!(bytes <= (1u64 << 53), "byte counter exceeds exact f64 range");
    // hlint::allow(truncating_cast): this is the audited conversion point — value-preserving below 2^53, checked above
    bytes as f64
}

/// Saturating `usize` view of a byte counter, for in-memory allocation
/// sizes (`Vec::with_capacity` and friends).
///
/// On 64-bit targets this is value-preserving; on a hypothetical 32-bit
/// target a counter past `usize::MAX` clamps instead of truncating. Like
/// [`bytes_to_f64`] this is an audited exit from the u64 byte domain —
/// use it instead of `as usize` on `*_bytes` / traffic counters.
pub fn bytes_to_usize(bytes: u64) -> usize {
    usize::try_from(bytes).unwrap_or(usize::MAX)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_at_boundaries() {
        assert_eq!(bytes_to_f64(0), 0.0);
        assert_eq!(bytes_to_f64(1), 1.0);
        assert_eq!(bytes_to_f64((1 << 53) - 1), 9_007_199_254_740_991.0);
        assert_eq!(bytes_to_f64(123_456_789_012), 123_456_789_012.0);
    }

    #[test]
    fn usize_view_saturates() {
        assert_eq!(bytes_to_usize(0), 0);
        assert_eq!(bytes_to_usize(4096), 4096);
        // saturation (a no-op on 64-bit, the clamp on 32-bit)
        assert_eq!(bytes_to_usize(u64::MAX), usize::try_from(u64::MAX).unwrap_or(usize::MAX));
    }
}
