//! Tiny CLI flag parser (substrate — no clap in the offline vendor set).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and free
//! positional arguments. The launcher (`rust/src/main.rs`), every example
//! and every bench use this.

// Outside the determinism layers (CONTRIBUTING.md): CLI surface,
// report generation and dev tooling may panic on programmer error.
#![allow(clippy::unwrap_used, clippy::expect_used)]


use std::collections::BTreeMap;

/// Parsed command line: positionals + `--key value` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    /// flags seen without a value, e.g. `--verbose`
    switches: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — `argv[0]` must already be
    /// stripped.
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn parse_env() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got `{v}`")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse_from(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn positionals_and_options() {
        let a = args(&["exp", "fig4a", "--scale", "smoke", "--rounds=20", "--verbose"]);
        assert_eq!(a.positional, vec!["exp", "fig4a"]);
        assert_eq!(a.get("scale"), Some("smoke"));
        assert_eq!(a.get_usize("rounds", 0).unwrap(), 20);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_and_errors() {
        let a = args(&["--n", "abc"]);
        assert_eq!(a.get_usize("m", 7).unwrap(), 7);
        assert!(a.get_usize("n", 0).is_err());
        assert_eq!(a.get_or("missing", "dflt"), "dflt");
    }

    #[test]
    fn switch_before_positional() {
        // `--dry` followed by another flag stays a switch
        let a = args(&["--dry", "--k", "3"]);
        assert!(a.flag("dry"));
        assert_eq!(a.get("k"), Some("3"));
    }

    #[test]
    fn eq_form_and_floats() {
        let a = args(&["--lr=0.05", "--rho", "1.5"]);
        assert!((a.get_f64("lr", 0.0).unwrap() - 0.05).abs() < 1e-12);
        assert!((a.get_f64("rho", 0.0).unwrap() - 1.5).abs() < 1e-12);
    }
}
