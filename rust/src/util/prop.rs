//! prop-lite: property-based testing helper (substrate — no proptest in
//! the offline vendor set).
//!
//! `check(cases, gen, prop)` draws `cases` random inputs from `gen`, runs
//! `prop`, and on failure performs greedy shrinking via the input's
//! `Shrink` implementation before panicking with the minimal
//! counterexample. Coordinator invariants (block-ledger balance, width
//! assignment monotonicity, aggregation conservation, ...) are verified
//! with this in rust/tests/prop_coordinator.rs and module unit tests.

use crate::util::rng::Rng;
use std::fmt::Debug;

/// Types that can propose smaller variants of themselves.
pub trait Shrink: Sized + Clone {
    /// Candidate strictly-"smaller" values, most aggressive first.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<usize> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<u64> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<f64> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
            if self.fract() != 0.0 {
                out.push(self.trunc());
            }
        }
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // halves
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        // drop one element
        if self.len() > 1 {
            let mut v = self.clone();
            v.pop();
            out.push(v);
        }
        // shrink one element
        for (i, x) in self.iter().enumerate().take(8) {
            for s in x.shrink().into_iter().take(2) {
                let mut v = self.clone();
                v[i] = s;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<(A, B)> {
        let mut out: Vec<(A, B)> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<(A, B, C)> {
        let mut out: Vec<(A, B, C)> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b, self.2.clone())));
        out.extend(self.2.shrink().into_iter().map(|c| (self.0.clone(), self.1.clone(), c)));
        out
    }
}

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` random inputs; shrink + panic on failure.
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: Shrink + Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> PropResult,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg) = shrink_loop(input, msg, &mut prop);
            panic!(
                "property failed (case {case}/{cases}, seed {seed}):\n  input: {min_input:?}\n  error: {min_msg}"
            );
        }
    }
}

fn shrink_loop<T, P>(mut input: T, mut msg: String, prop: &mut P) -> (T, String)
where
    T: Shrink + Debug,
    P: FnMut(&T) -> PropResult,
{
    // Greedy descent, bounded so pathological shrinkers terminate.
    for _ in 0..200 {
        let mut advanced = false;
        for cand in input.shrink() {
            if let Err(m) = prop(&cand) {
                input = cand;
                msg = m;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    (input, msg)
}

/// Convenience assert for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_silent() {
        check(1, 200, |r| r.below(100), |&x| {
            if x < 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(2, 100, |r| r.below(100), |&x| {
            if x < 90 {
                Ok(())
            } else {
                Err(format!("{x} >= 90"))
            }
        });
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // Property: all values < 10. Failing inputs shrink toward 10.
        let mut observed = None;
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check(3, 200, |r| r.below(1000), |&x| {
                if x < 10 {
                    Ok(())
                } else {
                    Err(format!("x={x}"))
                }
            });
        }));
        assert!(result.is_err());
        // rerun shrink loop manually to inspect the minimum
        let mut prop = |x: &usize| {
            if *x < 10 {
                Ok(())
            } else {
                Err(format!("x={x}"))
            }
        };
        let (min, _) = shrink_loop(977usize, "x=977".into(), &mut prop);
        observed = Some(min);
        assert!(observed.unwrap() < 30, "shrunk to {:?}", observed);
    }

    #[test]
    fn vec_shrink_reduces_len() {
        let v = vec![5usize, 6, 7, 8];
        let shrunk = v.shrink();
        assert!(shrunk.iter().any(|s| s.len() < v.len()));
    }
}
