//! Minimal JSON parser / serializer backend (substrate — no serde in the
//! offline vendor set; see DESIGN.md).
//!
//! This module is `pub(crate)`: the public entry point is the
//! [`crate::codec::json`] facade, which re-exports the value type and the
//! parser and adds the streaming `io::Write` serializers. Call sites
//! outside the crate (benches, integration tests, the binary) go through
//! the facade; nothing outside `codec/` should walk these internals.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers incl. exponents, bools, null). Object key order is preserved so
//! serialized configs/results diff cleanly.
//!
//! Number fidelity contract (pinned by tests here and in `codec::json`):
//!
//! * every `f64` the serializer emits reparses to the **identical bits**
//!   (Rust's `{}` formatting is shortest-round-trip; the integer fast
//!   path is exact below 1e15 and excludes `-0.0`, which serializes as
//!   `-0` through the float path);
//! * non-negative integer literals parse as [`Json::Uint`], a lossless
//!   `u64` path for cumulative counters that overflow `f64`'s 2^53
//!   integer range (>4 GiB traffic meters at population scale);
//! * `Num` and `Uint` compare equal when they denote the same integer,
//!   so `parse("42") == Json::Num(42.0)` and round-trips through the
//!   serializer (which emits the same text for both) stay `==`.

// Outside the determinism layers (CONTRIBUTING.md): CLI surface,
// report generation and dev tooling may panic on programmer error.
#![allow(clippy::unwrap_used, clippy::expect_used)]


use std::collections::BTreeMap;
use std::fmt;
use std::io::{self, Write};

/// A parsed JSON value.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    /// Lossless non-negative integer. The parser produces this for every
    /// plain integer literal that fits; `From<u64>`/`From<usize>` land
    /// here so byte counters never round through `f64`.
    Uint(u64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps deterministic ordering for serialization.
    Obj(BTreeMap<String, Json>),
}

/// `Num(f)` and `Uint(u)` denote the same JSON number iff `f` is a
/// non-negative integer exactly representable as that `u64` — and the
/// conversion is exact in both directions (above 2^53 a `u64` has no
/// exact `f64` twin, so `Uint(2^53+1) != Num((2^53+1) as f64)`).
fn uint_eq_f64(u: u64, f: f64) -> bool {
    f >= 0.0
        && f < 18_446_744_073_709_551_616.0 // 2^64: `f as u64` would saturate
        && f.fract() == 0.0
        && f as u64 == u
        && u as f64 == f
}

impl PartialEq for Json {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (Json::Null, Json::Null) => true,
            (Json::Bool(a), Json::Bool(b)) => a == b,
            (Json::Num(a), Json::Num(b)) => a == b,
            (Json::Uint(a), Json::Uint(b)) => a == b,
            (Json::Num(f), Json::Uint(u)) | (Json::Uint(u), Json::Num(f)) => uint_eq_f64(*u, *f),
            (Json::Str(a), Json::Str(b)) => a == b,
            (Json::Arr(a), Json::Arr(b)) => a == b,
            (Json::Obj(a), Json::Obj(b)) => a == b,
            _ => false,
        }
    }
}

/// Error with byte offset into the source text.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl Json {
    // ---------------- accessors ----------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            Json::Uint(u) => Some(*u as f64),
            _ => None,
        }
    }

    /// Lossless `u64` view: `Uint` directly, `Num` only when it denotes
    /// an exactly-representable non-negative integer.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Uint(u) => Some(*u),
            Json::Num(n) if uint_eq_f64(*n as u64, *n) => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Uint(u) => i64::try_from(*u).ok(),
            _ => self.as_f64().map(|n| n as i64),
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Uint(u) => usize::try_from(*u).ok(),
            _ => self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None }),
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` on a non-object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `get` that fails loudly with a path-ish message — manifest reading
    /// wants hard errors, not silent defaults.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("json field `{key}` is not a string"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("json field `{key}` is not a number"))
    }

    pub fn req_u64(&self, key: &str) -> anyhow::Result<u64> {
        self.req(key)?
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("json field `{key}` is not a u64-exact integer"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("json field `{key}` is not a non-negative number"))
    }

    pub fn req_bool(&self, key: &str) -> anyhow::Result<bool> {
        self.req(key)?
            .as_bool()
            .ok_or_else(|| anyhow::anyhow!("json field `{key}` is not a bool"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("json field `{key}` is not an array"))
    }

    /// `[1,2,3]` -> `vec![1,2,3]` (usize).
    pub fn usize_vec(&self) -> anyhow::Result<Vec<usize>> {
        let arr = self
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array of numbers"))?;
        arr.iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("expected non-negative number"))
            })
            .collect()
    }

    // ---------------- constructors ----------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64_slice(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Uint(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Uint(v as u64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

// ------------------------------------------------------------------------
// parsing

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

/// Parse a JSON document (must consume the entire input modulo whitespace).
pub fn parse(src: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: src.as_bytes(), pos: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a json value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.ws();
            arr.push(self.value()?);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pairs
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let v = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(v).ok_or_else(|| self.err("invalid codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                        };
                        out.push(c);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let plain_int_end = self.pos; // no '.', no exponent yet
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        // Non-negative plain-integer literals take the lossless u64 path
        // (counters above 2^53 round-trip exactly); everything else —
        // negatives, fractions, exponents, > u64::MAX — is f64, which
        // Rust parses correctly rounded.
        if self.pos == plain_int_end && self.b[start] != b'-' {
            if let Ok(u) = s.parse::<u64>() {
                return Ok(Json::Uint(u));
            }
        }
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

// ------------------------------------------------------------------------
// serialization — streams into any `io::Write` sink (lil-json idiom);
// the `to_string_*` conveniences wrap an in-memory Vec.

fn esc<W: Write>(s: &str, out: &mut W) -> io::Result<()> {
    out.write_all(b"\"")?;
    for c in s.chars() {
        match c {
            '"' => out.write_all(b"\\\"")?,
            '\\' => out.write_all(b"\\\\")?,
            '\n' => out.write_all(b"\\n")?,
            '\r' => out.write_all(b"\\r")?,
            '\t' => out.write_all(b"\\t")?,
            c if (c as u32) < 0x20 => write!(out, "\\u{:04x}", c as u32)?,
            c => write!(out, "{c}")?,
        }
    }
    out.write_all(b"\"")
}

fn fmt_num<W: Write>(n: f64, out: &mut W) -> io::Result<()> {
    let neg_zero = n == 0.0 && n.is_sign_negative();
    if n.is_finite() && n == n.trunc() && n.abs() < 1e15 && !neg_zero {
        // exact integer fast path; -0.0 must not take it (the sign bit
        // would be lost on reparse)
        write!(out, "{}", n as i64)
    } else if n.is_finite() {
        // Rust's `{}` for f64 is shortest-round-trip and never uses
        // exponent notation, so the text is valid JSON and reparses to
        // identical bits (incl. "-0" -> -0.0)
        write!(out, "{n}")
    } else {
        out.write_all(b"null") // JSON has no NaN/Inf
    }
}

impl Json {
    pub(crate) fn write_to<W: Write>(&self, out: &mut W, indent: usize, cur: usize) -> io::Result<()> {
        let (nl, pad, pad2): (String, String, String) = if indent > 0 {
            (
                "\n".into(),
                " ".repeat(cur + indent),
                " ".repeat(cur),
            )
        } else {
            (String::new(), String::new(), String::new())
        };
        match self {
            Json::Null => out.write_all(b"null")?,
            Json::Bool(b) => out.write_all(if *b { b"true" } else { b"false" })?,
            Json::Num(n) => fmt_num(*n, out)?,
            Json::Uint(u) => write!(out, "{u}")?,
            Json::Str(s) => esc(s, out)?,
            Json::Arr(a) => {
                if a.is_empty() {
                    return out.write_all(b"[]");
                }
                out.write_all(b"[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.write_all(b",")?;
                    }
                    out.write_all(nl.as_bytes())?;
                    out.write_all(pad.as_bytes())?;
                    v.write_to(out, indent, cur + indent)?;
                }
                out.write_all(nl.as_bytes())?;
                out.write_all(pad2.as_bytes())?;
                out.write_all(b"]")?;
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    return out.write_all(b"{}");
                }
                out.write_all(b"{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.write_all(b",")?;
                    }
                    out.write_all(nl.as_bytes())?;
                    out.write_all(pad.as_bytes())?;
                    esc(k, out)?;
                    out.write_all(b":")?;
                    if indent > 0 {
                        out.write_all(b" ")?;
                    }
                    v.write_to(out, indent, cur + indent)?;
                }
                out.write_all(nl.as_bytes())?;
                out.write_all(pad2.as_bytes())?;
                out.write_all(b"}")?;
            }
        }
        Ok(())
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut buf = Vec::new();
        self.write_to(&mut buf, 0, 0).expect("Vec<u8> write is infallible");
        String::from_utf8(buf).expect("serializer emits UTF-8")
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut buf = Vec::new();
        self.write_to(&mut buf, 2, 0).expect("Vec<u8> write is infallible");
        String::from_utf8(buf).expect("serializer emits UTF-8")
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

/// Parse a JSON file from disk.
pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let src = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&src).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" é 😀");
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("\"\\x\"").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"n":-7,"o":{"k":[[]]}}"#;
        let v = parse(src).unwrap();
        let c = v.to_string_compact();
        assert_eq!(parse(&c).unwrap(), v);
        let p = v.to_string_pretty();
        assert_eq!(parse(&p).unwrap(), v);
    }

    #[test]
    fn integer_formatting_is_exact() {
        let v = Json::Num(1234567890.0);
        assert_eq!(v.to_string_compact(), "1234567890");
    }

    #[test]
    fn uint_is_lossless_above_f64_integer_range() {
        // 2^53 + 1 has no exact f64 twin: the old `usize as f64` path
        // silently rounded it to 2^53. The Uint path round-trips it.
        let big = (1u64 << 53) + 1;
        let v = Json::from(big);
        assert_eq!(v.to_string_compact(), "9007199254740993");
        assert_eq!(parse("9007199254740993").unwrap().as_u64(), Some(big));
        assert_ne!(parse("9007199254740993").unwrap(), Json::Num(big as f64));
        // u64::MAX round-trips; u64::MAX as f64 rounds to 2^64, which is
        // NOT equal to Uint(u64::MAX)
        let v = Json::from(u64::MAX);
        assert_eq!(parse(&v.to_string_compact()).unwrap().as_u64(), Some(u64::MAX));
        assert_ne!(Json::Uint(u64::MAX), Json::Num(u64::MAX as f64));
    }

    #[test]
    fn num_uint_cross_equality() {
        // the serializer emits identical text for Num(4.0) and Uint(4),
        // so equality must identify them
        assert_eq!(Json::Num(4.0), Json::Uint(4));
        assert_eq!(Json::Uint(0), Json::Num(0.0));
        // IEEE equality: -0.0 == 0.0, so cross-equality identifies them
        // too (keeps PartialEq transitive with Num(0.0) == Num(-0.0));
        // bit-level pinning goes through the goldens' hex bit patterns
        assert_eq!(Json::Uint(0), Json::Num(-0.0));
        assert_ne!(Json::Num(4.5), Json::Uint(4));
        assert_ne!(Json::Num(-4.0), Json::Uint(4));
        // exact at the 2^53 boundary, distinct just above it
        assert_eq!(Json::Num(9007199254740992.0), Json::Uint(1 << 53));
        assert_ne!(Json::Num((1u64 << 53) as f64), Json::Uint((1 << 53) + 1));
    }

    #[test]
    fn every_emitted_f64_reparses_to_identical_bits() {
        // the golden traces pin f64s as bit patterns; the emitter must
        // never lose bits. Covers the integer fast path, shortest-
        // round-trip decimals, subnormals, extremes, and -0.0 (which
        // used to serialize as "0", dropping the sign bit).
        let cases = [
            0.0,
            -0.0,
            1.0,
            -1.0,
            0.1,
            1.0 / 3.0,
            -2.5e-7,
            1e15,          // just past the integer fast path
            999999999999999.0, // the last integer inside it
            1e300,
            1e-300,
            f64::MAX,
            f64::MIN_POSITIVE,
            5e-324, // smallest subnormal
            std::f64::consts::PI,
            (1u64 << 53) as f64,
        ];
        for v in cases {
            let text = Json::Num(v).to_string_compact();
            let back = match parse(&text).unwrap() {
                Json::Num(n) => n,
                Json::Uint(u) => u as f64, // integer text may parse as Uint
                other => panic!("{text} parsed as {other:?}"),
            };
            assert_eq!(
                back.to_bits(),
                v.to_bits(),
                "f64 {v:?} serialized as {text} reparsed to different bits"
            );
        }
    }

    #[test]
    fn accessors_and_req() {
        let v = parse(r#"{"s":"x","n":3,"b":true,"a":[4,5]}"#).unwrap();
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.req_usize("n").unwrap(), 3);
        assert_eq!(v.req_u64("n").unwrap(), 3);
        assert!(v.req_bool("b").unwrap());
        assert_eq!(v.req_arr("a").unwrap().len(), 2);
        assert_eq!(v.get("a").unwrap().usize_vec().unwrap(), vec![4, 5]);
        assert!(v.req("missing").is_err());
        assert!(v.req_str("n").is_err());
    }
}
