//! Minimal JSON parser / serializer (substrate — no serde in the offline
//! vendor set; see DESIGN.md).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers incl. exponents, bools, null). Object key order is preserved so
//! serialized configs/results diff cleanly. Used for `artifacts/manifest.json`,
//! experiment configs and results emission.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps deterministic ordering for serialization.
    Obj(BTreeMap<String, Json>),
}

/// Error with byte offset into the source text.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl Json {
    // ---------------- accessors ----------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` on a non-object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `get` that fails loudly with a path-ish message — manifest reading
    /// wants hard errors, not silent defaults.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("json field `{key}` is not a string"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("json field `{key}` is not a number"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("json field `{key}` is not a non-negative number"))
    }

    pub fn req_bool(&self, key: &str) -> anyhow::Result<bool> {
        self.req(key)?
            .as_bool()
            .ok_or_else(|| anyhow::anyhow!("json field `{key}` is not a bool"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("json field `{key}` is not an array"))
    }

    /// `[1,2,3]` -> `vec![1,2,3]` (usize).
    pub fn usize_vec(&self) -> anyhow::Result<Vec<usize>> {
        let arr = self
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array of numbers"))?;
        arr.iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("expected non-negative number"))
            })
            .collect()
    }

    // ---------------- constructors ----------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64_slice(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

// ------------------------------------------------------------------------
// parsing

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

/// Parse a JSON document (must consume the entire input modulo whitespace).
pub fn parse(src: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: src.as_bytes(), pos: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a json value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.ws();
            arr.push(self.value()?);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pairs
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let v = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(v).ok_or_else(|| self.err("invalid codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                        };
                        out.push(c);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

// ------------------------------------------------------------------------
// serialization

fn esc(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn fmt_num(n: f64, out: &mut String) {
    if n.is_finite() && n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

impl Json {
    fn write(&self, out: &mut String, indent: usize, cur: usize) {
        let (nl, pad, pad2): (String, String, String) = if indent > 0 {
            (
                "\n".into(),
                " ".repeat(cur + indent),
                " ".repeat(cur),
            )
        } else {
            (String::new(), String::new(), String::new())
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => fmt_num(*n, out),
            Json::Str(s) => esc(s, out),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(&nl);
                    out.push_str(&pad);
                    v.write(out, indent, cur + indent);
                }
                out.push_str(&nl);
                out.push_str(&pad2);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(&nl);
                    out.push_str(&pad);
                    esc(k, out);
                    out.push(':');
                    if indent > 0 {
                        out.push(' ');
                    }
                    v.write(out, indent, cur + indent);
                }
                out.push_str(&nl);
                out.push_str(&pad2);
                out.push('}');
            }
        }
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 2, 0);
        s
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

/// Parse a JSON file from disk.
pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let src = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&src).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" é 😀");
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("\"\\x\"").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"n":-7,"o":{"k":[[]]}}"#;
        let v = parse(src).unwrap();
        let c = v.to_string_compact();
        assert_eq!(parse(&c).unwrap(), v);
        let p = v.to_string_pretty();
        assert_eq!(parse(&p).unwrap(), v);
    }

    #[test]
    fn integer_formatting_is_exact() {
        let v = Json::Num(1234567890.0);
        assert_eq!(v.to_string_compact(), "1234567890");
    }

    #[test]
    fn accessors_and_req() {
        let v = parse(r#"{"s":"x","n":3,"b":true,"a":[4,5]}"#).unwrap();
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.req_usize("n").unwrap(), 3);
        assert!(v.req_bool("b").unwrap());
        assert_eq!(v.req_arr("a").unwrap().len(), 2);
        assert_eq!(v.get("a").unwrap().usize_vec().unwrap(), vec![4, 5]);
        assert!(v.req("missing").is_err());
        assert!(v.req_str("n").is_err());
    }
}
