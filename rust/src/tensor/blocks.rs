//! Coefficient-block gather/scatter (paper §II-B, Fig. 1).
//!
//! The complete coefficient of a layer is `u ∈ (R, B·O)` — `B` blocks of
//! shape `(R, O)` laid out contiguously along the column axis. A width-p
//! client receives the `b(p)` least-trained blocks *in ascending block-id
//! order* concatenated into the reduced coefficient `û ∈ (R, b·O)`; after
//! local training the PS scatters the updated blocks back and averages
//! block-wise over the clients that trained them (paper Eq. 5).
//!
//! Keeping ids sorted makes the (gather ∘ scatter) pair an exact bijection
//! per block and the block-wise aggregation well-defined across clients
//! with different selections.

use super::Tensor;

/// Extract blocks `ids` (each of `o` columns) from the complete
/// coefficient `u: (R, B·O)` into a reduced coefficient `(R, ids.len()·O)`.
/// `ids` must be strictly ascending.
pub fn gather_blocks(u: &Tensor, ids: &[usize], o: usize) -> Tensor {
    let (r, total_cols) = dims2(u);
    assert!(total_cols % o == 0, "coefficient width {total_cols} not a multiple of block width {o}");
    let b_total = total_cols / o;
    assert!(ids.windows(2).all(|w| w[0] < w[1]), "block ids must be strictly ascending: {ids:?}");
    assert!(ids.iter().all(|&i| i < b_total), "block id out of range: {ids:?} (B={b_total})");

    let bsel = ids.len();
    let mut out = Tensor::zeros(&[r, bsel * o]);
    let src = u.data();
    let dst = out.data_mut();
    for row in 0..r {
        let src_row = row * total_cols;
        let dst_row = row * bsel * o;
        for (slot, &id) in ids.iter().enumerate() {
            let s = src_row + id * o;
            let d = dst_row + slot * o;
            dst[d..d + o].copy_from_slice(&src[s..s + o]);
        }
    }
    out
}

/// Accumulate a reduced coefficient back into block-granular sums.
/// `sums: (R, B·O)` accumulates values; `counts[b]` counts contributions
/// per block. Division happens in `finalize_block_average`.
pub fn scatter_blocks_add(sums: &mut Tensor, counts: &mut [u32], reduced: &Tensor, ids: &[usize], o: usize) {
    let (r, total_cols) = dims2(sums);
    let (rr, red_cols) = dims2(reduced);
    assert_eq!(r, rr, "rank-dim mismatch");
    assert_eq!(red_cols, ids.len() * o, "reduced width {red_cols} != {}*{o}", ids.len());
    assert!(total_cols % o == 0);
    assert_eq!(counts.len(), total_cols / o, "counts must have one slot per block");

    let src = reduced.data();
    let dst = sums.data_mut();
    for row in 0..r {
        let dst_row = row * total_cols;
        let src_row = row * red_cols;
        for (slot, &id) in ids.iter().enumerate() {
            let d = dst_row + id * o;
            let s = src_row + slot * o;
            for c in 0..o {
                dst[d + c] += src[s + c];
            }
        }
    }
    for &id in ids {
        counts[id] += 1;
    }
}

/// Weighted block accumulation: `sums += w · reduced` block-wise, with
/// `weights[b]` accumulating `w` per touched block. The fused in-place
/// form of (clone → scale(w) → scatter_blocks_add): the semi-async merge
/// path folds staleness-weighted late updates without materializing a
/// scaled temporary. `w = 1.0` reproduces `scatter_blocks_add`
/// bit-for-bit (multiplication by 1.0 is exact), which keeps the full-
/// quorum path byte-identical to the synchronous aggregation.
pub fn scatter_blocks_axpy(
    sums: &mut Tensor,
    weights: &mut [f32],
    reduced: &Tensor,
    ids: &[usize],
    o: usize,
    w: f32,
) {
    let (r, total_cols) = dims2(sums);
    let (rr, red_cols) = dims2(reduced);
    assert_eq!(r, rr, "rank-dim mismatch");
    assert_eq!(red_cols, ids.len() * o, "reduced width {red_cols} != {}*{o}", ids.len());
    assert!(total_cols % o == 0);
    assert_eq!(weights.len(), total_cols / o, "weights must have one slot per block");

    let src = reduced.data();
    let dst = sums.data_mut();
    for row in 0..r {
        let dst_row = row * total_cols;
        let src_row = row * red_cols;
        for (slot, &id) in ids.iter().enumerate() {
            let d = dst_row + id * o;
            let s = src_row + slot * o;
            for c in 0..o {
                dst[d + c] += w * src[s + c];
            }
        }
    }
    for &id in ids {
        weights[id] += w;
    }
}

/// Weighted Eq. 5 finalize: blocks with accumulated weight > 0 become
/// `sum / weight` (an affine combination — the effective per-client
/// coefficients of every block sum to 1); weight-0 blocks carry
/// `fallback` (the previous global coefficient). With unit weights the
/// division is bit-identical to `finalize_block_average` (a small f32
/// integer equals the u32 count exactly).
pub fn finalize_block_weighted(sums: &mut Tensor, weights: &[f32], fallback: &Tensor, o: usize) {
    let (r, total_cols) = dims2(sums);
    assert_eq!(fallback.shape(), sums.shape(), "fallback shape mismatch");
    assert_eq!(weights.len(), total_cols / o);
    let prev = fallback.data();
    let data = sums.data_mut();
    for row in 0..r {
        let base = row * total_cols;
        for (b, &wsum) in weights.iter().enumerate() {
            let off = base + b * o;
            if wsum == 0.0 {
                data[off..off + o].copy_from_slice(&prev[off..off + o]);
            } else {
                let inv = 1.0 / wsum;
                for c in 0..o {
                    data[off + c] *= inv;
                }
            }
        }
    }
}

/// Finish paper Eq. 5: blocks with `counts > 0` become `sum / count`;
/// untouched blocks keep `fallback`'s value (the previous global
/// coefficient — a block nobody trained this round is carried forward).
pub fn finalize_block_average(sums: &mut Tensor, counts: &[u32], fallback: &Tensor, o: usize) {
    let (r, total_cols) = dims2(sums);
    assert_eq!(fallback.shape(), sums.shape(), "fallback shape mismatch");
    assert_eq!(counts.len(), total_cols / o);
    let prev = fallback.data();
    let data = sums.data_mut();
    for row in 0..r {
        let base = row * total_cols;
        for (b, &cnt) in counts.iter().enumerate() {
            let off = base + b * o;
            if cnt == 0 {
                data[off..off + o].copy_from_slice(&prev[off..off + o]);
            } else {
                let inv = 1.0 / cnt as f32;
                for c in 0..o {
                    data[off + c] *= inv;
                }
            }
        }
    }
}

fn dims2(t: &Tensor) -> (usize, usize) {
    let s = t.shape();
    assert_eq!(s.len(), 2, "coefficient must be rank-2, got {s:?}");
    (s[0], s[1])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coeff(r: usize, b: usize, o: usize) -> Tensor {
        // element value encodes (row, block, col) for easy checking
        let mut data = Vec::with_capacity(r * b * o);
        for row in 0..r {
            for blk in 0..b {
                for c in 0..o {
                    data.push((row * 100 + blk * 10 + c) as f32);
                }
            }
        }
        Tensor::from_vec(&[r, b * o], data)
    }

    #[test]
    fn gather_picks_correct_columns() {
        let u = coeff(2, 4, 3);
        let g = gather_blocks(&u, &[1, 3], 3);
        assert_eq!(g.shape(), &[2, 6]);
        // row 0: block1 cols then block3 cols
        assert_eq!(&g.data()[..6], &[10.0, 11.0, 12.0, 30.0, 31.0, 32.0]);
        // row 1
        assert_eq!(&g.data()[6..], &[110.0, 111.0, 112.0, 130.0, 131.0, 132.0]);
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn gather_requires_sorted_ids() {
        let u = coeff(1, 4, 2);
        gather_blocks(&u, &[2, 1], 2);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn gather_checks_range() {
        let u = coeff(1, 4, 2);
        gather_blocks(&u, &[4], 2);
    }

    #[test]
    fn scatter_then_average_roundtrip() {
        let u = coeff(2, 4, 3);
        let g = gather_blocks(&u, &[0, 2], 3);
        let mut sums = Tensor::zeros(&[2, 12]);
        let mut counts = vec![0u32; 4];
        scatter_blocks_add(&mut sums, &mut counts, &g, &[0, 2], 3);
        assert_eq!(counts, vec![1, 0, 1, 0]);
        finalize_block_average(&mut sums, &counts, &u, 3);
        // trained blocks equal original (single contribution), untouched fall back
        assert_eq!(sums.data(), u.data());
    }

    #[test]
    fn blockwise_average_of_two_clients() {
        // paper Fig. 3: leftmost block trained by two clients with values 4 and 2 -> 3
        let mut sums = Tensor::zeros(&[1, 2]);
        let mut counts = vec![0u32; 2];
        let c1 = Tensor::from_vec(&[1, 1], vec![4.0]);
        let c2 = Tensor::from_vec(&[1, 1], vec![2.0]);
        scatter_blocks_add(&mut sums, &mut counts, &c1, &[0], 1);
        scatter_blocks_add(&mut sums, &mut counts, &c2, &[0], 1);
        let fallback = Tensor::from_vec(&[1, 2], vec![9.0, 7.0]);
        finalize_block_average(&mut sums, &counts, &fallback, 1);
        assert_eq!(sums.data(), &[3.0, 7.0]); // averaged block + carried-forward block
    }

    #[test]
    fn weighted_scatter_matches_unweighted_at_unit_weight() {
        let u = coeff(2, 4, 3);
        let g = gather_blocks(&u, &[0, 2], 3);
        let mut a = Tensor::zeros(&[2, 12]);
        let mut aw = vec![0.0f32; 4];
        scatter_blocks_axpy(&mut a, &mut aw, &g, &[0, 2], 3, 1.0);
        let mut b = Tensor::zeros(&[2, 12]);
        let mut bc = vec![0u32; 4];
        scatter_blocks_add(&mut b, &mut bc, &g, &[0, 2], 3);
        assert_eq!(a.data(), b.data());
        assert_eq!(aw, vec![1.0, 0.0, 1.0, 0.0]);

        finalize_block_weighted(&mut a, &aw, &u, 3);
        finalize_block_average(&mut b, &bc, &u, 3);
        assert_eq!(a.data(), b.data());
        assert_eq!(a.data(), u.data(), "single unit-weight contribution is the identity");
    }

    #[test]
    fn weighted_blockwise_average_is_affine() {
        // one block, clients with values 4 and 2 at weights 1 and 1/2:
        // (1·4 + 0.5·2)/1.5 = 10/3 — an affine combination, not a sum
        let mut sums = Tensor::zeros(&[1, 2]);
        let mut weights = vec![0.0f32; 2];
        let c1 = Tensor::from_vec(&[1, 1], vec![4.0]);
        let c2 = Tensor::from_vec(&[1, 1], vec![2.0]);
        scatter_blocks_axpy(&mut sums, &mut weights, &c1, &[0], 1, 1.0);
        scatter_blocks_axpy(&mut sums, &mut weights, &c2, &[0], 1, 0.5);
        let fallback = Tensor::from_vec(&[1, 2], vec![9.0, 7.0]);
        finalize_block_weighted(&mut sums, &weights, &fallback, 1);
        assert!((sums.data()[0] - 10.0 / 3.0).abs() < 1e-6);
        assert_eq!(sums.data()[1], 7.0); // untouched block carries fallback
    }

    #[test]
    fn disjoint_selections_fill_disjoint_blocks() {
        let u = coeff(1, 4, 2);
        let ga = gather_blocks(&u, &[0, 1], 2);
        let gb = gather_blocks(&u, &[2, 3], 2);
        let mut sums = Tensor::zeros(&[1, 8]);
        let mut counts = vec![0u32; 4];
        scatter_blocks_add(&mut sums, &mut counts, &ga, &[0, 1], 2);
        scatter_blocks_add(&mut sums, &mut counts, &gb, &[2, 3], 2);
        assert_eq!(counts, vec![1; 4]);
        finalize_block_average(&mut sums, &counts, &u, 2);
        assert_eq!(sums.data(), u.data());
    }
}
