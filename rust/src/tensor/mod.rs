//! Host-side tensors (substrate — no ndarray offline).
//!
//! Row-major f32 (`Tensor`) and i32 (`IntTensor`) buffers with the exact
//! operations the coordinator hot path needs: init, axpy-style
//! accumulation for aggregation, norms for the L/σ²/G² estimators,
//! N-d prefix slicing for HeteroFL sub-model extraction, and the
//! coefficient block gather/scatter (see `blocks`).

pub mod blocks;

use crate::util::rng::Rng;

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} incompatible with {} elements",
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    /// Gaussian init with the manifest-provided std (0 ⇒ zeros).
    pub fn randn(shape: &[usize], std: f64, rng: &mut Rng) -> Tensor {
        let n: usize = shape.iter().product();
        if std == 0.0 {
            return Tensor::zeros(shape);
        }
        Tensor { shape: shape.to_vec(), data: rng.normal_vec(n, std) }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Bytes when serialized as f32 (traffic accounting).
    pub fn byte_size(&self) -> usize {
        self.data.len() * 4
    }

    // ---------------- arithmetic (aggregation hot path) ----------------

    /// self += other
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "add_assign shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += *b;
        }
    }

    /// self += alpha * other
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "axpy shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * *b;
        }
    }

    /// self *= alpha
    pub fn scale(&mut self, alpha: f32) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    /// ||self - other||²  (model-error α and L estimation)
    pub fn sq_dist(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape, "sq_dist shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum()
    }

    /// ||self||²
    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|a| (*a as f64) * (*a as f64)).sum()
    }

    // ---------------- N-d prefix slicing (HeteroFL) ----------------

    fn strides(shape: &[usize]) -> Vec<usize> {
        let mut s = vec![1usize; shape.len()];
        for i in (0..shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * shape[i + 1];
        }
        s
    }

    /// Walk the flat offsets (into a tensor of shape `full`) of every
    /// contiguous innermost row of the `sub` prefix region, in row-major
    /// order of `sub`. The innermost axis of a row-major prefix region is
    /// contiguous, so callers move whole rows at a time instead of
    /// decomposing a multi-index per element (§Perf: this is the HeteroFL
    /// payload-extraction/aggregation hot path).
    fn for_each_prefix_row(full: &[usize], sub: &[usize], mut f: impl FnMut(usize)) {
        let rank = sub.len();
        let row = if rank == 0 { 1 } else { sub[rank - 1] };
        if row == 0 || sub.iter().product::<usize>() == 0 {
            return;
        }
        let outer: usize = sub[..rank.saturating_sub(1)].iter().product();
        let strides = Self::strides(full);
        let mut idx = vec![0usize; rank.saturating_sub(1)];
        for _ in 0..outer {
            let off: usize = idx.iter().zip(&strides).map(|(i, s)| i * s).sum();
            f(off);
            // odometer increment over the outer axes of `sub`
            for d in (0..idx.len()).rev() {
                idx[d] += 1;
                if idx[d] < sub[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
    }

    /// Copy the leading `sub` region (per-axis prefix) out of self.
    /// HeteroFL extracts width-p sub-weights this way: `w[..ci, ..co]`.
    pub fn slice_prefix(&self, sub: &[usize]) -> Tensor {
        assert_eq!(sub.len(), self.shape.len(), "rank mismatch");
        for (s, full) in sub.iter().zip(&self.shape) {
            assert!(s <= full, "prefix {sub:?} exceeds {:?}", self.shape);
        }
        let mut out = Tensor::zeros(sub);
        let rank = sub.len();
        let row = if rank == 0 { 1 } else { sub[rank - 1] };
        let mut dst = 0usize;
        Self::for_each_prefix_row(&self.shape, sub, |src| {
            out.data[dst..dst + row].copy_from_slice(&self.data[src..src + row]);
            dst += row;
        });
        out
    }

    /// Weighted prefix accumulation: `self[..sub] += w · sub`, with
    /// `weights` accumulating `w` per touched element. The fused in-place
    /// form of (clone → scale(w) → scatter_prefix_add) — the quorum
    /// merge path never materializes a scaled temporary. `w = 1.0`
    /// reproduces `scatter_prefix_add` bit-for-bit (multiplication by 1.0
    /// is exact), which is what keeps `--quorum N` byte-identical to the
    /// serial loop.
    pub fn scatter_prefix_axpy(&mut self, sub: &Tensor, weights: &mut [f32], w: f32) {
        assert_eq!(sub.shape.len(), self.shape.len(), "rank mismatch");
        assert_eq!(weights.len(), self.data.len(), "weights length mismatch");
        for (s, full) in sub.shape.iter().zip(&self.shape) {
            assert!(s <= full, "prefix {:?} exceeds {:?}", sub.shape, self.shape);
        }
        let rank = sub.shape.len();
        let row = if rank == 0 { 1 } else { sub.shape[rank - 1] };
        let mut src = 0usize;
        let data = &mut self.data;
        Self::for_each_prefix_row(&self.shape, &sub.shape, |dst| {
            for ((d, c), s) in data[dst..dst + row]
                .iter_mut()
                .zip(&mut weights[dst..dst + row])
                .zip(&sub.data[src..src + row])
            {
                *d += w * *s;
                *c += w;
            }
            src += row;
        });
    }

    /// Accumulate `sub` into the leading region of self; `counts` tracks
    /// how many contributions each element has received (HeteroFL's
    /// overlap-aware averaging divides by it afterwards).
    pub fn scatter_prefix_add(&mut self, sub: &Tensor, counts: &mut [u32]) {
        assert_eq!(sub.shape.len(), self.shape.len(), "rank mismatch");
        assert_eq!(counts.len(), self.data.len(), "counts length mismatch");
        for (s, full) in sub.shape.iter().zip(&self.shape) {
            assert!(s <= full, "prefix {:?} exceeds {:?}", sub.shape, self.shape);
        }
        let rank = sub.shape.len();
        let row = if rank == 0 { 1 } else { sub.shape[rank - 1] };
        let mut src = 0usize;
        let data = &mut self.data;
        Self::for_each_prefix_row(&self.shape, &sub.shape, |dst| {
            for ((d, c), s) in data[dst..dst + row]
                .iter_mut()
                .zip(&mut counts[dst..dst + row])
                .zip(&sub.data[src..src + row])
            {
                *d += *s;
                *c += 1;
            }
            src += row;
        });
    }
}

/// Dense row-major i32 tensor (token / label batches).
#[derive(Debug, Clone, PartialEq)]
pub struct IntTensor {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl IntTensor {
    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> IntTensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} incompatible with {} elements",
            data.len()
        );
        IntTensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> IntTensor {
        let n: usize = shape.iter().product();
        IntTensor { shape: shape.to_vec(), data: vec![0; n] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[i32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [i32] {
        &mut self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_from_vec() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.shape(), &[2, 3]);
        let u = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(u.data()[3], 4.0);
    }

    #[test]
    #[should_panic(expected = "incompatible")]
    fn from_vec_checks_shape() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn randn_respects_std() {
        let mut rng = Rng::new(1);
        let t = Tensor::randn(&[10_000], 0.5, &mut rng);
        let var = t.data().iter().map(|x| (*x as f64).powi(2)).sum::<f64>() / 10_000.0;
        assert!((var - 0.25).abs() < 0.02, "var {var}");
        let z = Tensor::randn(&[4], 0.0, &mut rng);
        assert!(z.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn arithmetic() {
        let mut a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[3], vec![10.0, 20.0, 30.0]);
        a.add_assign(&b);
        assert_eq!(a.data(), &[11.0, 22.0, 33.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.data(), &[16.0, 32.0, 48.0]);
        a.scale(0.25);
        assert_eq!(a.data(), &[4.0, 8.0, 12.0]);
        assert!((a.sq_norm() - (16.0 + 64.0 + 144.0)).abs() < 1e-9);
        assert!((a.sq_dist(&b) - (36.0 + 144.0 + 324.0)).abs() < 1e-9);
    }

    #[test]
    fn prefix_slice_2d() {
        // 3x4 matrix, take 2x2 prefix
        let t = Tensor::from_vec(&[3, 4], (0..12).map(|x| x as f32).collect());
        let s = t.slice_prefix(&[2, 2]);
        assert_eq!(s.data(), &[0.0, 1.0, 4.0, 5.0]);
    }

    #[test]
    fn prefix_slice_4d_conv() {
        // (k,k,ci,co) = (1,1,2,3) out of (1,1,4,6)
        let t = Tensor::from_vec(&[1, 1, 4, 6], (0..24).map(|x| x as f32).collect());
        let s = t.slice_prefix(&[1, 1, 2, 3]);
        assert_eq!(s.data(), &[0.0, 1.0, 2.0, 6.0, 7.0, 8.0]);
    }

    #[test]
    fn scatter_prefix_roundtrip() {
        let mut full = Tensor::zeros(&[3, 4]);
        let mut counts = vec![0u32; 12];
        let sub = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        full.scatter_prefix_add(&sub, &mut counts);
        full.scatter_prefix_add(&sub, &mut counts);
        assert_eq!(full.data()[0], 2.0);
        assert_eq!(full.data()[1], 4.0);
        assert_eq!(full.data()[4], 6.0);
        assert_eq!(full.data()[5], 8.0);
        assert_eq!(counts[0], 2);
        assert_eq!(counts[2], 0);
        // slice back out equals 2x the sub
        let back = full.slice_prefix(&[2, 2]);
        assert_eq!(back.data(), &[2.0, 4.0, 6.0, 8.0]);
    }

    /// Naive per-element reference for the fast row-copy implementations.
    fn slice_prefix_ref(t: &Tensor, sub: &[usize]) -> Tensor {
        let mut out = Tensor::zeros(sub);
        let src_strides = Tensor::strides(t.shape());
        let dst_strides = Tensor::strides(sub);
        let rank = sub.len();
        for flat in 0..out.len() {
            let mut rem = flat;
            let mut src = 0;
            for d in 0..rank {
                src += (rem / dst_strides[d]) * src_strides[d];
                rem %= dst_strides[d];
            }
            out.data[flat] = t.data[src];
        }
        out
    }

    #[test]
    fn prefix_slice_matches_reference_on_awkward_shapes() {
        let mut rng = Rng::new(11);
        for (shape, sub) in [
            (vec![7], vec![3]),
            (vec![7], vec![7]),
            (vec![5, 6], vec![1, 6]),
            (vec![5, 6], vec![5, 1]),
            (vec![3, 3, 4, 6], vec![3, 3, 2, 3]),
            (vec![2, 1, 3], vec![2, 1, 2]),
            (vec![4, 4], vec![0, 4]),
            (vec![4, 4], vec![4, 0]),
        ] {
            let t = Tensor::randn(&shape, 1.0, &mut rng);
            let fast = t.slice_prefix(&sub);
            let slow = slice_prefix_ref(&t, &sub);
            assert_eq!(fast.shape(), slow.shape(), "{shape:?} -> {sub:?}");
            assert_eq!(fast.data(), slow.data(), "{shape:?} -> {sub:?}");
        }
    }

    #[test]
    fn scatter_prefix_matches_slice_roundtrip_on_awkward_shapes() {
        let mut rng = Rng::new(12);
        for (shape, sub) in [
            (vec![7], vec![3]),
            (vec![5, 6], vec![2, 3]),
            (vec![3, 3, 4, 6], vec![3, 3, 2, 3]),
        ] {
            let src = Tensor::randn(&sub, 1.0, &mut rng);
            let mut full = Tensor::zeros(&shape);
            let mut counts = vec![0u32; full.len()];
            full.scatter_prefix_add(&src, &mut counts);
            // scattering then slicing back must be the identity
            assert_eq!(full.slice_prefix(&sub).data(), src.data(), "{shape:?} <- {sub:?}");
            // counts: exactly the prefix region is 1, the rest 0
            let ones: u32 = counts.iter().sum();
            assert_eq!(ones as usize, src.len());
            // untouched elements stay zero
            let total: f64 = full.data().iter().map(|x| *x as f64).sum();
            let expect: f64 = src.data().iter().map(|x| *x as f64).sum();
            assert!((total - expect).abs() < 1e-4);
        }
    }

    #[test]
    fn scatter_prefix_axpy_matches_clone_scale_add() {
        // the fused weighted scatter must equal the naive clone→scale→add
        // reference bitwise (same multiply-then-add rounding order)
        let mut rng = Rng::new(13);
        for (shape, sub, w) in [
            (vec![7], vec![3], 0.37f32),
            (vec![5, 6], vec![2, 3], 0.62),
            (vec![3, 3, 4, 6], vec![3, 3, 2, 3], 1.0),
        ] {
            let src = Tensor::randn(&sub, 1.0, &mut rng);
            let mut fused = Tensor::randn(&shape, 1.0, &mut rng);
            let mut naive = fused.clone();
            let mut fw = vec![0.0f32; fused.len()];
            fused.scatter_prefix_axpy(&src, &mut fw, w);

            let mut scaled = src.clone();
            scaled.scale(w);
            let mut counts = vec![0u32; naive.len()];
            naive.scatter_prefix_add(&scaled, &mut counts);
            assert_eq!(fused.data(), naive.data(), "{shape:?} <- {sub:?} @ {w}");
            // weights accumulate w exactly where counts accumulated 1
            for (fwv, &c) in fw.iter().zip(&counts) {
                assert_eq!(*fwv, c as f32 * w);
            }
        }
        // w = 1.0 must reproduce the unweighted path exactly
        let src = Tensor::randn(&[2, 3], 1.0, &mut rng);
        let mut a = Tensor::zeros(&[4, 5]);
        let mut b = Tensor::zeros(&[4, 5]);
        let mut fw = vec![0.0f32; 20];
        let mut counts = vec![0u32; 20];
        a.scatter_prefix_axpy(&src, &mut fw, 1.0);
        b.scatter_prefix_add(&src, &mut counts);
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn int_tensor_basics() {
        let t = IntTensor::from_vec(&[2, 2], vec![1, 2, 3, 4]);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.data()[2], 3);
        let z = IntTensor::zeros(&[3]);
        assert_eq!(z.data(), &[0, 0, 0]);
    }
}
