//! Transport abstraction for the coordinator's dispatch/collect loop.
//!
//! The round driver's coordinator bodies (`drive_rounds`,
//! `drive_quorum`, `collect_completions`) are written against the
//! [`Transport`] trait: dispatch a round's [`LocalTask`]s, receive
//! [`Completion`]s in whatever order the executors produce them. Two
//! backends implement it:
//!
//! - [`SimTransport`] (crate-internal): the in-process worker pool —
//!   a shared task queue plus an mpsc completion channel. This is the
//!   historical path, byte-identical to the pre-transport repo for
//!   every `--workers`/`--pool`/`--overlap`/`--quorum` configuration.
//! - `TcpTransport` (`transport::tcp`, behind the `net` cargo
//!   feature): a localhost TCP server speaking the framing below, with
//!   clients running as in-process threads or separate `heroes client`
//!   processes.
//!
//! # Framing on the wire
//!
//! Every message is `[u32 kind (LE)][u64 body_len (LE)][body]` — see
//! [`proto`] for the three kinds (hello/task/result) and their body
//! layouts. Tensor groups travel as raw `HWU1` frames (the codec's
//! wire format, bit-exact by construction), scalars as IEEE-754 bit
//! patterns, so no value is ever reformatted in transit. Incremental
//! reads tolerate arbitrary chunking; a declared length above the
//! receiver's cap is a typed error before any allocation.
//!
//! # Clock ownership
//!
//! The virtual clock owns every *decision*: completion times, quorum
//! membership, staleness weights, billed traffic are all plan facts
//! computed coordinator-side and carried in the messages. The wall
//! clock (legal only inside `transport/tcp.rs` — hlint rule D1) decides
//! only whether a fate arrives at all: a connect/read/write timeout
//! maps the task to [`TaskFate::Dropped`], a protocol violation to
//! [`TaskFate::Faulted`], and no wall-clock quantity ever enters a
//! virtual-time field (synthesized fates carry `0.0` timestamps).
//!
//! # The simulation is the oracle
//!
//! Because decisions are transport-independent, a run over any faithful
//! backend must reproduce the simulation byte for byte — same plans,
//! same chosen K, same aggregated model, same billed bytes; only wall
//! clocks differ. `rust/tests/integration_transport.rs` pins sim-vs-net
//! parity on exactly this contract.
//!
//! [`TaskFate::Dropped`]: crate::coordinator::round::TaskFate::Dropped
//! [`TaskFate::Faulted`]: crate::coordinator::round::TaskFate::Faulted

pub mod client;
pub mod proto;
mod sim;
#[cfg(feature = "net")]
pub mod tcp;

pub(crate) use sim::SimTransport;

use crate::coordinator::round::LocalTask;
use anyhow::Result;

pub use crate::coordinator::round::Completion;

/// Every executor endpoint is gone — the transport can never deliver
/// another completion. The drive loops map this onto their historical
/// "worker pool died" errors.
#[derive(Debug, thiserror::Error)]
#[error("transport closed: every executor endpoint is gone")]
pub struct TransportClosed;

/// A backend that executes dispatched tasks and returns their fates.
///
/// Contract: every task handed to [`Transport::dispatch`] produces
/// exactly one [`Completion`] echoing its `(seq, index)` — including
/// tasks whose executor vanishes (the backend synthesizes a `Dropped`
/// or `Faulted` fate). Completions may arrive in any order; the drive
/// loops do the routing.
pub trait Transport {
    /// Hand one round's tasks (assignment order) to the executors under
    /// sequence number `seq`.
    fn dispatch(&mut self, seq: usize, tasks: Vec<LocalTask>) -> Result<()>;

    /// Block until the next completion (any round, any order).
    fn recv(&mut self) -> Result<Completion, TransportClosed>;
}

/// The `--transport` knob: which backend runs the cohort's tasks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TransportCfg {
    /// in-process worker pool (the default; byte-identical to the
    /// pre-transport repo)
    Sim,
    /// localhost TCP server bound to the given address (`tcp:<addr>`;
    /// `tcp:127.0.0.1:0` picks a free port). Requires the `net` cargo
    /// feature at run time.
    Tcp(String),
}

impl TransportCfg {
    pub fn parse(s: &str) -> Result<TransportCfg> {
        if s == "sim" {
            return Ok(TransportCfg::Sim);
        }
        if let Some(addr) = s.strip_prefix("tcp:") {
            if addr.is_empty() {
                return Err(anyhow::anyhow!(
                    "`--transport tcp:` needs a bind address (e.g. tcp:127.0.0.1:0)"
                ));
            }
            return Ok(TransportCfg::Tcp(addr.to_string()));
        }
        Err(anyhow::anyhow!("unknown transport `{s}` (sim | tcp:<addr>)"))
    }

    pub fn name(&self) -> String {
        match self {
            TransportCfg::Sim => "sim".into(),
            TransportCfg::Tcp(addr) => format!("tcp:{addr}"),
        }
    }

    pub fn is_sim(&self) -> bool {
        matches!(self, TransportCfg::Sim)
    }
}

#[cfg(test)]
mod tests {
    use super::TransportCfg;

    #[test]
    fn transport_knob_parses_and_round_trips() {
        assert_eq!(TransportCfg::parse("sim").unwrap(), TransportCfg::Sim);
        assert_eq!(
            TransportCfg::parse("tcp:127.0.0.1:0").unwrap(),
            TransportCfg::Tcp("127.0.0.1:0".into())
        );
        for cfg in [TransportCfg::Sim, TransportCfg::Tcp("127.0.0.1:4477".into())] {
            assert_eq!(TransportCfg::parse(&cfg.name()).unwrap(), cfg);
        }
    }

    #[test]
    fn transport_knob_rejects_malformed_values() {
        for bad in ["", "tcp", "tcp:", "udp:1.2.3.4:5", "simulated"] {
            assert!(TransportCfg::parse(bad).is_err(), "`{bad}` must be rejected");
        }
    }
}
