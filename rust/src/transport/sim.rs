//! The in-process backend: a thin adapter over the round driver's
//! historical task queue + completion channel. Dispatch pushes onto the
//! shared [`TaskQueue`] the worker threads pop from; receive blocks on
//! the mpsc channel those workers report into. Zero behavioural
//! distance from the pre-transport repo — the adapter exists so the
//! drive loops can be written once against `dyn Transport`.

use crate::coordinator::round::{Completion, LocalTask, TaskQueue};
use crate::transport::{Transport, TransportClosed};
use anyhow::Result;
use std::sync::mpsc::Receiver;

pub(crate) struct SimTransport<'q> {
    queue: &'q TaskQueue,
    rx: Receiver<Completion>,
}

impl<'q> SimTransport<'q> {
    pub(crate) fn new(queue: &'q TaskQueue, rx: Receiver<Completion>) -> SimTransport<'q> {
        SimTransport { queue, rx }
    }
}

impl Transport for SimTransport<'_> {
    fn dispatch(&mut self, seq: usize, tasks: Vec<LocalTask>) -> Result<()> {
        self.queue.push_round(seq, tasks);
        Ok(())
    }

    fn recv(&mut self) -> Result<Completion, TransportClosed> {
        // a closed channel means every worker hung up — the drive loops
        // translate this into their historical "worker pool died" errors
        self.rx.recv().map_err(|_| TransportClosed)
    }
}
