//! The networked backend: a tokio localhost server that dispatches
//! tasks to TCP clients speaking the [`proto`] framing, behind the
//! `net` cargo feature.
//!
//! Topology: [`TcpTransport::bind`] spawns one named OS thread running
//! a current-thread tokio runtime. The coordinator (which stays fully
//! synchronous) talks to it over two channels — an unbounded command
//! channel in, a std completion channel out — so the drive loops see
//! exactly the [`Transport`] contract the in-process pool satisfies.
//! Clients connect as separate processes (`heroes client --connect`)
//! or as in-process threads ([`with_loopback`]).
//!
//! Determinism: all *decisions* are plan facts carried in the messages
//! (module docs, `transport`); this file owns the only legal wall-clock
//! zone (hlint rule D1), and wall time decides nothing but whether a
//! fate arrives — a connect/read/write timeout completes the task as
//! [`TaskFate::Dropped`], a protocol violation as
//! [`TaskFate::Faulted`], both with `0.0` virtual timestamps so no
//! wall-clock quantity can leak into a virtual-time field.
//!
//! Backpressure: per-connection task buffers are bounded (`depth`), the
//! per-connection in-flight window is bounded (`depth`), and the reader
//! rejects any frame above `frame_cap` before allocating — a peer can
//! never size our buffers.
//!
//! Stamped fates ([`stamped_fate`]) are resolved locally at dispatch
//! and never ship; only a recovered `corrupt` stamp's bit draw travels
//! (the executor's poison-and-reject check needs it).

use crate::coordinator::round::{stamped_fate, DroppedTask, FaultedTask, LocalTask, TaskFate};
use crate::runtime::EnginePool;
use crate::simulation::FaultClass;
use crate::transport::client::client_loop;
use crate::transport::proto::{self, KIND_HELLO, KIND_RESULT, KIND_TASK};
use crate::transport::{Completion, Transport, TransportClosed};
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, VecDeque};
use std::net::SocketAddr;
use std::sync::mpsc as std_mpsc;
use std::time::Duration;
use tokio::io::{AsyncReadExt, AsyncWriteExt};
use tokio::net::tcp::OwnedReadHalf;
use tokio::net::{TcpListener, TcpStream};
use tokio::sync::mpsc;
use tokio::task::JoinSet;
use tokio::time::{sleep, timeout};

/// Knobs for the TCP backend. Timeouts are wall-clock by nature and
/// only ever decide whether a fate arrives, never what it contains.
#[derive(Debug, Clone)]
pub struct TcpCfg {
    /// bind address (`127.0.0.1:0` picks a free port)
    pub addr: String,
    /// how long a dispatched task waits for a first connection before
    /// it completes as `Dropped`
    pub accept_timeout: Duration,
    /// per-connection read/write/handshake timeout
    pub io_timeout: Duration,
    /// largest accepted message body (bytes)
    pub frame_cap: u64,
    /// per-connection task buffer and in-flight window
    pub depth: usize,
}

impl TcpCfg {
    pub fn new(addr: impl Into<String>) -> TcpCfg {
        TcpCfg {
            addr: addr.into(),
            accept_timeout: Duration::from_secs(30),
            io_timeout: Duration::from_secs(120),
            frame_cap: proto::FRAME_CAP,
            depth: 2,
        }
    }
}

/// One dispatched task, ready for the wire: the pre-encoded frame plus
/// the synthesis facts the server needs if the executor vanishes.
struct Assign {
    seq: usize,
    index: usize,
    client: usize,
    bytes: u64,
    frame: Vec<u8>,
}

/// What the server still owes for a written task.
struct Pending {
    client: usize,
    bytes: u64,
}

/// Why a connection's serve loop ended.
enum ConnExit {
    /// coordinator shutdown with nothing owed
    Clean,
    /// the peer vanished or stalled — owed tasks complete as `Dropped`
    Gone,
    /// the peer spoke nonsense — owed tasks complete as `Faulted`
    Protocol,
}

/// What the connection's reader forwards to its serve loop.
enum RdMsg {
    Frame(u32, Vec<u8>),
    /// the peer declared a body above `frame_cap`
    Oversize,
}

pub struct TcpTransport {
    cmd_tx: Option<mpsc::UnboundedSender<Assign>>,
    done_rx: std_mpsc::Receiver<Completion>,
    /// stamped fates synthesized at dispatch, drained before the socket
    local: VecDeque<Completion>,
    addr: SocketAddr,
    server: Option<std::thread::JoinHandle<()>>,
}

impl TcpTransport {
    /// Bind the listener and start the server thread; returns once the
    /// socket is live (so `addr` is concrete even for port 0).
    pub fn bind(cfg: TcpCfg) -> Result<TcpTransport> {
        let (cmd_tx, cmd_rx) = mpsc::unbounded_channel::<Assign>();
        let (done_tx, done_rx) = std_mpsc::channel::<Completion>();
        let (addr_tx, addr_rx) = std_mpsc::channel::<Result<SocketAddr>>();
        let bind_addr = cfg.addr.clone();
        let server = std::thread::Builder::new()
            .name("heroes-tcp-coordinator".into())
            .spawn(move || {
                let rt = match tokio::runtime::Builder::new_current_thread()
                    .enable_io()
                    .enable_time()
                    .build()
                {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = addr_tx.send(Err(anyhow!("building the tokio runtime: {e}")));
                        return;
                    }
                };
                rt.block_on(async move {
                    let listener = match TcpListener::bind(&bind_addr).await {
                        Ok(l) => l,
                        Err(e) => {
                            let _ = addr_tx.send(Err(anyhow!("binding {bind_addr}: {e}")));
                            return;
                        }
                    };
                    let addr = match listener.local_addr() {
                        Ok(a) => a,
                        Err(e) => {
                            let _ = addr_tx.send(Err(anyhow!("reading the bound address: {e}")));
                            return;
                        }
                    };
                    if addr_tx.send(Ok(addr)).is_err() {
                        return;
                    }
                    server_main(listener, cmd_rx, done_tx, &cfg).await;
                });
            })?;
        let addr = addr_rx
            .recv()
            .map_err(|_| anyhow!("tcp server thread died before reporting its address"))??;
        Ok(TcpTransport {
            cmd_tx: Some(cmd_tx),
            done_rx,
            local: VecDeque::new(),
            addr,
            server: Some(server),
        })
    }

    /// The concrete bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting work and join the server thread. Connections are
    /// closed server-side first, which is what releases `heroes client`
    /// processes (they exit on the clean end-of-stream).
    pub fn close(&mut self) {
        drop(self.cmd_tx.take());
        if let Some(h) = self.server.take() {
            let _ = h.join();
        }
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.close();
    }
}

impl Transport for TcpTransport {
    fn dispatch(&mut self, seq: usize, tasks: Vec<LocalTask>) -> Result<()> {
        for (index, mut task) in tasks.into_iter().enumerate() {
            // stamped fates are decided; resolve them here so dropout
            // and unrecovered-fault stamps never travel the wire
            if let Some(fate) = stamped_fate(&task) {
                self.local.push_back(Completion { seq, index, outcome: Ok(fate) });
                continue;
            }
            // pre-draw the worst-case batch schedule from the task's
            // own stream; the stream is per-task, so over-drawing is
            // parity-neutral (nothing else ever reads it)
            let n = proto::batches_needed(task.tau, task.probe_exec.is_some()).max(1);
            let batches: Vec<_> = (0..n).map(|_| task.stream.next_batch()).collect();
            let body = proto::encode_task_msg(seq as u64, index as u64, &task, &batches)?;
            let assign = Assign {
                seq,
                index,
                client: task.client,
                bytes: task.bytes,
                frame: proto::frame(KIND_TASK, &body),
            };
            self.cmd_tx
                .as_ref()
                .ok_or_else(|| anyhow!("tcp transport is closed"))?
                .send(assign)
                .map_err(|_| anyhow!("tcp server loop is gone"))?;
        }
        Ok(())
    }

    fn recv(&mut self) -> Result<Completion, TransportClosed> {
        if let Some(c) = self.local.pop_front() {
            return Ok(c);
        }
        self.done_rx.recv().map_err(|_| TransportClosed)
    }
}

fn dropped(client: usize, bytes: u64) -> TaskFate {
    TaskFate::Dropped(DroppedTask { client, bytes, drop_time: 0.0 })
}

fn faulted(client: usize, bytes: u64) -> TaskFate {
    TaskFate::Faulted(FaultedTask {
        client,
        bytes,
        class: FaultClass::Corrupt,
        retries: 0,
        fault_time: 0.0,
    })
}

/// The server loop: accept connections, round-robin assignments over
/// them, survive connection loss by re-routing the bounced assignment.
async fn server_main(
    listener: TcpListener,
    mut cmd_rx: mpsc::UnboundedReceiver<Assign>,
    done: std_mpsc::Sender<Completion>,
    cfg: &TcpCfg,
) {
    let depth = cfg.depth.max(1);
    let mut conns: Vec<mpsc::Sender<Assign>> = Vec::new();
    let mut set: JoinSet<()> = JoinSet::new();
    let mut rr: usize = 0;
    loop {
        tokio::select! {
            accepted = listener.accept() => {
                if let Ok((stream, _peer)) = accepted {
                    conns.push(admit(&mut set, stream, depth, &done, cfg));
                }
            }
            cmd = cmd_rx.recv() => {
                let Some(mut assign) = cmd else { break };
                loop {
                    if conns.is_empty() {
                        // no executor yet: give one accept_timeout to
                        // show up, else the task completes as Dropped
                        match timeout(cfg.accept_timeout, listener.accept()).await {
                            Ok(Ok((stream, _peer))) => {
                                conns.push(admit(&mut set, stream, depth, &done, cfg));
                            }
                            Ok(Err(_)) | Err(_) => {
                                let c = Completion {
                                    seq: assign.seq,
                                    index: assign.index,
                                    outcome: Ok(dropped(assign.client, assign.bytes)),
                                };
                                let _ = done.send(c);
                                break;
                            }
                        }
                    }
                    let i = rr % conns.len().max(1);
                    rr = rr.wrapping_add(1);
                    let Some(tx) = conns.get(i).cloned() else { continue };
                    match tx.send(assign).await {
                        Ok(()) => break,
                        // the connection died; its serve loop settles
                        // whatever it already owned — this assignment
                        // bounced back, try the next connection
                        Err(bounced) => {
                            conns.swap_remove(i);
                            assign = bounced.0;
                        }
                    }
                }
            }
        }
    }
    // shutdown: closing the task channels ends every serve loop, which
    // drops the write halves and releases the clients on clean EOF
    drop(conns);
    while set.join_next().await.is_some() {}
}

fn admit(
    set: &mut JoinSet<()>,
    stream: TcpStream,
    depth: usize,
    done: &std_mpsc::Sender<Completion>,
    cfg: &TcpCfg,
) -> mpsc::Sender<Assign> {
    let (tx, rx) = mpsc::channel::<Assign>(depth);
    set.spawn(serve_conn(stream, rx, done.clone(), depth, cfg.io_timeout, cfg.frame_cap));
    tx
}

/// Read frames off one connection, tolerating arbitrary chunking
/// (`read_exact` accumulates). Exits on end-of-stream, any read error,
/// or an oversized declaration — the serve loop interprets the channel
/// closing as the peer being gone.
async fn read_loop(mut rd: OwnedReadHalf, out: mpsc::Sender<RdMsg>, cap: u64) {
    loop {
        let mut head = [0u8; proto::ENVELOPE_LEN];
        if rd.read_exact(&mut head).await.is_err() {
            return;
        }
        let (kind, n) = proto::split_envelope(&head);
        if n > cap {
            let _ = out.send(RdMsg::Oversize).await;
            return;
        }
        let Ok(n) = usize::try_from(n) else {
            let _ = out.send(RdMsg::Oversize).await;
            return;
        };
        let mut body = vec![0u8; n];
        if rd.read_exact(&mut body).await.is_err() {
            return;
        }
        if out.send(RdMsg::Frame(kind, body)).await.is_err() {
            return;
        }
    }
}

/// Serve one connection: handshake, then a select loop writing
/// assignments (bounded in-flight window) and settling results. On any
/// exit, everything this connection still owes is completed — `Gone`
/// as `Dropped`, `Protocol` as `Faulted` — so the drive loops always
/// see exactly one completion per task.
async fn serve_conn(
    stream: TcpStream,
    mut tasks: mpsc::Receiver<Assign>,
    done: std_mpsc::Sender<Completion>,
    depth: usize,
    io_timeout: Duration,
    frame_cap: u64,
) {
    let _ = stream.set_nodelay(true);
    let (rd, mut wr) = stream.into_split();
    // a dedicated reader task owns the read half: its channel recv is
    // cancellation-safe in the select below, a raw read_exact is not
    let (msg_tx, mut msgs) = mpsc::channel::<RdMsg>(4);
    let reader = tokio::spawn(read_loop(rd, msg_tx, frame_cap));
    let mut in_flight: BTreeMap<(usize, usize), Pending> = BTreeMap::new();

    let greeted = matches!(
        timeout(io_timeout, msgs.recv()).await,
        Ok(Some(RdMsg::Frame(KIND_HELLO, body))) if proto::hello_ok(&body)
    );
    let exit = if !greeted {
        ConnExit::Protocol
    } else {
        serve_greeted(&mut tasks, &mut msgs, &mut wr, &done, &mut in_flight, depth, io_timeout)
            .await
    };

    // refuse new work, absorb what was already buffered, then settle
    // every owed task under the exit's fate
    tasks.close();
    while let Some(a) = tasks.recv().await {
        in_flight.insert((a.seq, a.index), Pending { client: a.client, bytes: a.bytes });
    }
    for ((seq, index), p) in in_flight {
        let fate = match exit {
            ConnExit::Protocol => faulted(p.client, p.bytes),
            ConnExit::Clean | ConnExit::Gone => dropped(p.client, p.bytes),
        };
        let _ = done.send(Completion { seq, index, outcome: Ok(fate) });
    }
    reader.abort();
}

async fn serve_greeted(
    tasks: &mut mpsc::Receiver<Assign>,
    msgs: &mut mpsc::Receiver<RdMsg>,
    wr: &mut tokio::net::tcp::OwnedWriteHalf,
    done: &std_mpsc::Sender<Completion>,
    in_flight: &mut BTreeMap<(usize, usize), Pending>,
    depth: usize,
    io_timeout: Duration,
) -> ConnExit {
    loop {
        tokio::select! {
            task = tasks.recv(), if in_flight.len() < depth => {
                let Some(a) = task else {
                    // coordinator shutdown; anything still owed is the
                    // caller's to settle
                    return if in_flight.is_empty() { ConnExit::Clean } else { ConnExit::Gone };
                };
                in_flight.insert((a.seq, a.index), Pending { client: a.client, bytes: a.bytes });
                match timeout(io_timeout, wr.write_all(&a.frame)).await {
                    Ok(Ok(())) => {}
                    // write timeout or error: the frame may be half
                    // out, the connection is unusable
                    _ => return ConnExit::Gone,
                }
            }
            msg = msgs.recv() => {
                let Some(msg) = msg else { return ConnExit::Gone };
                let RdMsg::Frame(kind, body) = msg else { return ConnExit::Protocol };
                if kind != KIND_RESULT {
                    return ConnExit::Protocol;
                }
                let Ok((seq, index, res)) = proto::decode_result_msg(&body) else {
                    return ConnExit::Protocol;
                };
                let Ok(key) = usize::try_from(seq).and_then(|s| Ok((s, usize::try_from(index)?)))
                else {
                    return ConnExit::Protocol;
                };
                // a result for a task this connection doesn't own is a
                // protocol violation, not a routing puzzle
                if in_flight.remove(&key).is_none() {
                    return ConnExit::Protocol;
                }
                let outcome = match res {
                    Ok(o) => Ok(TaskFate::Done(o)),
                    Err(m) => Err(anyhow!("remote task failed: {m}")),
                };
                if done.send(Completion { seq: key.0, index: key.1, outcome }).is_err() {
                    return ConnExit::Gone;
                }
            }
            // the sleep restarts on every loop turn, so this arm fires
            // only after a full quiet io_timeout with work outstanding
            _ = sleep(io_timeout), if !in_flight.is_empty() => return ConnExit::Gone,
        }
    }
}

/// Run `f` against a bound [`TcpTransport`] with `clients` in-process
/// executor threads connected over real localhost sockets — the
/// loopback topology the integration tests and `--transport tcp` with
/// in-process clients use. The transport is closed (releasing the
/// clients on clean EOF) before the client threads are joined; client
/// errors are reported but do not mask `f`'s result.
pub fn with_loopback<R>(
    pool: &EnginePool,
    clients: usize,
    cfg: TcpCfg,
    f: impl FnOnce(&mut TcpTransport) -> Result<R>,
) -> Result<R> {
    let mut tp = TcpTransport::bind(cfg)?;
    let addr = tp.addr();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients.max(1))
            .map(|i| {
                let engine = pool.engine(i);
                s.spawn(move || -> Result<()> {
                    let stream = std::net::TcpStream::connect(addr)?;
                    client_loop(stream, engine)
                })
            })
            .collect();
        let out = f(&mut tp);
        tp.close();
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => eprintln!("loopback client exited with an error: {e:#}"),
                Err(_) => eprintln!("loopback client thread panicked"),
            }
        }
        out
    })
}
