//! Message bodies for the networked coordinator: pure, synchronous
//! encode/decode shared by the async server (`transport::tcp`) and the
//! sync client (`transport::client`), so the two sides can never drift.
//!
//! Three message kinds travel the `[u32 kind][u64 body_len][body]`
//! envelope (little-endian throughout):
//!
//! - **hello** — a magic u64; the server admits no task to an ungreeted
//!   connection.
//! - **task** — a [`LocalTask`] plus its pre-drawn batch schedule. The
//!   coordinator draws the task's worst-case batch consumption
//!   ([`batches_needed`]) from the live stream at dispatch and ships
//!   it; the client replays it through [`BatchStream::Fixed`], which
//!   makes client-side training bit-identical to the simulation in
//!   every path, including the divergence retry. Dropout stamps and
//!   unrecovered fault stamps never ship — the coordinator resolves
//!   those fates locally (`stamped_fate`); only a recovered `corrupt`
//!   stamp's bit draw travels, because the executor needs it to poison
//!   and re-decode the frame.
//! - **result** — the [`TaskOutcome`] of a completed task, or the
//!   task's error message (which fails the run through the
//!   earliest-failed-task path, exactly as in-process errors do).
//!
//! Floats travel as IEEE-754 bit patterns and tensor groups as raw
//! `HWU1` frames, so every numeric value round-trips bit-exactly —
//! the foundation of the sim-vs-net parity contract (module docs,
//! `transport`).

use crate::codec::{self, scheme_id, Encoding, FrameMeta};
use crate::coordinator::client::LocalResult;
use crate::coordinator::env::{BatchStream, FixedBatches};
use crate::coordinator::estimator::ClientEstimates;
use crate::coordinator::resilience::{FaultAction, FaultStamp};
use crate::coordinator::round::{LocalTask, TaskOutcome, WireTask};
use crate::coordinator::XData;
use crate::simulation::{FaultClass, FaultEvent};
use crate::tensor::{IntTensor, Tensor};
use anyhow::{anyhow, Result};
use std::io::{Read, Write};

pub const KIND_HELLO: u32 = 1;
pub const KIND_TASK: u32 = 2;
pub const KIND_RESULT: u32 = 3;

/// Envelope prefix length: `[u32 kind][u64 body_len]`.
pub const ENVELOPE_LEN: usize = 12;

/// Default per-message body cap (bytes): bounds every buffer a peer can
/// make the receiver allocate.
pub const FRAME_CAP: u64 = 1 << 31;

/// Handshake magic ("HEROES1\0" as a little-endian u64).
pub const HELLO_MAGIC: u64 = u64::from_le_bytes(*b"HEROES1\0");

/// Worst-case batch consumption of `run_local` for a task: two probe
/// batches (estimation rounds only) plus up to two attempts of τ
/// batches each (the divergence-retry path). Pre-drawing exactly this
/// many makes the shipped schedule cover every execution path.
pub fn batches_needed(tau: usize, has_probe: bool) -> usize {
    2 * tau + if has_probe { 2 } else { 0 }
}

// ---------------------------------------------------------------- body I/O

/// Bounded cursor over a received body; every under-run is a typed
/// error, never a panic (hlint rule P1).
struct Rd<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| anyhow!("transport message length overflows"))?;
        let s = self
            .b
            .get(self.pos..end)
            .ok_or_else(|| anyhow!("transport message truncated"))?;
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        let s = self.take(1)?;
        s.first().copied().ok_or_else(|| anyhow!("transport message truncated"))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into()?))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into()?))
    }

    fn len(&mut self) -> Result<usize> {
        let n = self.u64()?;
        usize::try_from(n).map_err(|_| anyhow!("transport length {n} exceeds the address space"))
    }

    fn f32_bits(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn f64_bits(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn string(&mut self) -> Result<String> {
        let n = self.u32()?;
        let s = self.take(usize::try_from(n)?)?;
        String::from_utf8(s.to_vec()).map_err(|_| anyhow!("transport string is not utf-8"))
    }

    fn done(&self) -> Result<()> {
        if self.pos == self.b.len() {
            Ok(())
        } else {
            Err(anyhow!(
                "transport message carries {} trailing bytes",
                self.b.len() - self.pos
            ))
        }
    }
}

fn put_u8(b: &mut Vec<u8>, v: u8) {
    b.push(v);
}

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f32_bits(b: &mut Vec<u8>, v: f32) {
    put_u32(b, v.to_bits());
}

fn put_f64_bits(b: &mut Vec<u8>, v: f64) {
    put_u64(b, v.to_bits());
}

fn put_string(b: &mut Vec<u8>, s: &str) -> Result<()> {
    let n = u32::try_from(s.len()).map_err(|_| anyhow!("transport string too long"))?;
    put_u32(b, n);
    b.extend_from_slice(s.as_bytes());
    Ok(())
}

/// A tensor group as one raw `HWU1` frame (bit-exact round-trip), or a
/// zero length for the empty group (an `HWU1` frame is never empty).
fn put_tensors(b: &mut Vec<u8>, client: u64, tensors: &[Tensor]) -> Result<()> {
    if tensors.is_empty() {
        put_u64(b, 0);
        return Ok(());
    }
    let mut frame = Vec::new();
    let meta = FrameMeta { scheme: scheme_id::HEROES, round: 0, client };
    codec::encode_update(&mut frame, &meta, Encoding::default(), tensors)?;
    put_u64(b, frame.len() as u64);
    b.extend_from_slice(&frame);
    Ok(())
}

fn take_tensors(r: &mut Rd) -> Result<Vec<Tensor>> {
    let n = r.len()?;
    if n == 0 {
        return Ok(Vec::new());
    }
    let frame = r.take(n)?;
    Ok(codec::decode_update(frame)?.tensors)
}

fn put_int_tensor(b: &mut Vec<u8>, t: &IntTensor) -> Result<()> {
    let rank = u32::try_from(t.shape().len()).map_err(|_| anyhow!("int tensor rank too large"))?;
    put_u32(b, rank);
    for &d in t.shape() {
        put_u64(b, d as u64);
    }
    put_u64(b, t.data().len() as u64);
    for &v in t.data() {
        b.extend_from_slice(&v.to_le_bytes());
    }
    Ok(())
}

fn take_int_tensor(r: &mut Rd) -> Result<IntTensor> {
    let rank = r.u32()?;
    if rank > 8 {
        return Err(anyhow!("int tensor rank {rank} exceeds the sanity cap"));
    }
    let mut shape = Vec::with_capacity(rank as usize);
    for _ in 0..rank {
        shape.push(r.len()?);
    }
    let n = r.len()?;
    if shape.iter().product::<usize>() != n {
        return Err(anyhow!("int tensor shape {shape:?} incompatible with {n} elements"));
    }
    let raw = r.take(n.checked_mul(4).ok_or_else(|| anyhow!("int tensor length overflows"))?)?;
    let data = raw
        .chunks_exact(4)
        .map(|c| c.try_into().map(i32::from_le_bytes))
        .collect::<Result<Vec<i32>, _>>()?;
    Ok(IntTensor::from_vec(&shape, data))
}

fn put_batch(b: &mut Vec<u8>, client: u64, x: &XData, y: &IntTensor) -> Result<()> {
    match x {
        XData::Image(t) => {
            put_u8(b, 0);
            put_tensors(b, client, std::slice::from_ref(t))?;
        }
        XData::Tokens(t) => {
            put_u8(b, 1);
            put_int_tensor(b, t)?;
        }
    }
    put_int_tensor(b, y)
}

fn take_batch(r: &mut Rd) -> Result<(XData, IntTensor)> {
    let x = match r.u8()? {
        0 => {
            let mut ts = take_tensors(r)?;
            if ts.len() != 1 {
                return Err(anyhow!("image batch frame must carry exactly one tensor"));
            }
            let t = ts.pop().ok_or_else(|| anyhow!("image batch frame is empty"))?;
            XData::Image(t)
        }
        1 => XData::Tokens(take_int_tensor(r)?),
        k => return Err(anyhow!("unknown batch payload tag {k}")),
    };
    let y = take_int_tensor(r)?;
    Ok((x, y))
}

// ---------------------------------------------------------------- messages

/// Hello body: the magic alone.
pub fn hello_body() -> Vec<u8> {
    HELLO_MAGIC.to_le_bytes().to_vec()
}

pub fn hello_ok(body: &[u8]) -> bool {
    let mut r = Rd { b: body, pos: 0 };
    matches!(r.u64(), Ok(m) if m == HELLO_MAGIC) && r.done().is_ok()
}

const FLAG_PROBE: u8 = 1;
const FLAG_WIRE: u8 = 1 << 1;
const FLAG_WIRE_Q8: u8 = 1 << 2;
const FLAG_WIRE_TOPK: u8 = 1 << 3;
const FLAG_CORRUPT: u8 = 1 << 4;

/// Task body: plan facts + executables + payload + the pre-drawn batch
/// schedule. `batches` must be nonempty ([`batches_needed`] is ≥ 2 for
/// any dispatchable τ ≥ 1).
pub fn encode_task_msg(
    seq: u64,
    index: u64,
    task: &LocalTask,
    batches: &[(XData, IntTensor)],
) -> Result<Vec<u8>> {
    let mut b = Vec::new();
    put_u64(&mut b, seq);
    put_u64(&mut b, index);
    put_u64(&mut b, task.client as u64);
    put_u64(&mut b, task.p as u64);
    put_u64(&mut b, task.tau as u64);
    put_f32_bits(&mut b, task.lr);
    put_f64_bits(&mut b, task.completion);
    put_u64(&mut b, task.bytes);
    put_u64(&mut b, task.up_bytes);
    put_u64(&mut b, task.rebill_bytes);
    // only a *recovered corrupt* stamp has an executor-side effect (the
    // poison-and-reject check needs the bit draw); every other stamp is
    // resolved coordinator-side and must not ship
    let corrupt_bit = match task.fault {
        Some(s) if s.recovered && s.event.class == FaultClass::Corrupt => Some(s.event.bit),
        _ => None,
    };
    let mut flags = 0u8;
    if task.probe_exec.is_some() {
        flags |= FLAG_PROBE;
    }
    if let Some(w) = task.wire {
        flags |= FLAG_WIRE;
        if w.enc.q8 {
            flags |= FLAG_WIRE_Q8;
        }
        if w.enc.topk.is_some() {
            flags |= FLAG_WIRE_TOPK;
        }
    }
    if corrupt_bit.is_some() {
        flags |= FLAG_CORRUPT;
    }
    put_u8(&mut b, flags);
    let w = task.wire.unwrap_or(WireTask { scheme: 0, round: 0, enc: Encoding::default() });
    put_u8(&mut b, w.scheme);
    put_u32(&mut b, w.round);
    put_f64_bits(&mut b, w.enc.topk.unwrap_or(0.0));
    put_u64(&mut b, corrupt_bit.unwrap_or(0));
    put_string(&mut b, &task.train_exec)?;
    if let Some(p) = &task.probe_exec {
        put_string(&mut b, p)?;
    }
    put_tensors(&mut b, task.client as u64, &task.payload)?;
    let n = u32::try_from(batches.len()).map_err(|_| anyhow!("batch schedule too long"))?;
    put_u32(&mut b, n);
    for (x, y) in batches {
        put_batch(&mut b, task.client as u64, x, y)?;
    }
    Ok(b)
}

/// Inverse of [`encode_task_msg`]: `(seq, index, task)` with the batch
/// schedule rehydrated as [`BatchStream::Fixed`].
pub fn decode_task_msg(body: &[u8]) -> Result<(u64, u64, LocalTask)> {
    let mut r = Rd { b: body, pos: 0 };
    let seq = r.u64()?;
    let index = r.u64()?;
    let client = usize::try_from(r.u64()?)?;
    let p = usize::try_from(r.u64()?)?;
    let tau = usize::try_from(r.u64()?)?;
    let lr = r.f32_bits()?;
    let completion = r.f64_bits()?;
    let bytes = r.u64()?;
    let up_bytes = r.u64()?;
    let rebill_bytes = r.u64()?;
    let flags = r.u8()?;
    let wire_scheme = r.u8()?;
    let wire_round = r.u32()?;
    let topk = r.f64_bits()?;
    let corrupt_bit = r.u64()?;
    let train_exec = r.string()?;
    let probe_exec = if flags & FLAG_PROBE != 0 { Some(r.string()?) } else { None };
    let payload = take_tensors(&mut r)?;
    let n_batches = r.u32()?;
    let mut batches = Vec::with_capacity(n_batches as usize);
    for _ in 0..n_batches {
        batches.push(take_batch(&mut r)?);
    }
    r.done()?;
    let wire = (flags & FLAG_WIRE != 0).then_some(WireTask {
        scheme: wire_scheme,
        round: wire_round,
        enc: Encoding {
            q8: flags & FLAG_WIRE_Q8 != 0,
            topk: (flags & FLAG_WIRE_TOPK != 0).then_some(topk),
        },
    });
    // synthesize the minimal recovered-corrupt stamp the executor's
    // poison-and-reject check reads; the other fields are inert on the
    // recovered path (completion/rebill adjustments already happened
    // coordinator-side and travel in their own fields)
    let fault = (flags & FLAG_CORRUPT != 0).then_some(FaultStamp {
        event: FaultEvent {
            class: FaultClass::Corrupt,
            severity: 1,
            frac: 0.0,
            stall: 0.0,
            bit: corrupt_bit,
        },
        action: FaultAction::Retry,
        retries: 0,
        recovered: true,
        fault_time: 0.0,
    });
    let stream = BatchStream::Fixed(
        FixedBatches::new(batches)
            .ok_or_else(|| anyhow!("task message carries an empty batch schedule"))?,
    );
    Ok((
        seq,
        index,
        LocalTask {
            client,
            p,
            tau,
            lr,
            train_exec,
            probe_exec,
            payload,
            stream,
            bytes,
            up_bytes,
            rebill_bytes,
            wire,
            completion,
            drop_at: None,
            fault,
        },
    ))
}

/// A completed task's result body.
pub fn encode_done_msg(seq: u64, index: u64, o: &TaskOutcome) -> Result<Vec<u8>> {
    let mut b = Vec::new();
    put_u64(&mut b, seq);
    put_u64(&mut b, index);
    put_u8(&mut b, 0);
    put_u64(&mut b, o.client as u64);
    put_u64(&mut b, o.p as u64);
    put_u64(&mut b, o.tau as u64);
    put_u64(&mut b, o.bytes);
    put_u64(&mut b, o.up_bytes);
    put_f64_bits(&mut b, o.completion);
    put_f64_bits(&mut b, o.result.mean_loss);
    put_f64_bits(&mut b, o.result.final_loss);
    put_f64_bits(&mut b, o.result.mean_grad_sq);
    match o.result.estimates {
        Some(e) => {
            put_u8(&mut b, 1);
            put_f64_bits(&mut b, e.l);
            put_f64_bits(&mut b, e.sigma_sq);
            put_f64_bits(&mut b, e.g_sq);
        }
        None => put_u8(&mut b, 0),
    }
    put_tensors(&mut b, o.client as u64, &o.result.params)?;
    Ok(b)
}

/// A failed task's result body: the error travels as a message and
/// fails the run through the earliest-failed-task path, exactly as an
/// in-process task error would.
pub fn encode_err_msg(seq: u64, index: u64, msg: &str) -> Vec<u8> {
    let mut b = Vec::new();
    put_u64(&mut b, seq);
    put_u64(&mut b, index);
    put_u8(&mut b, 1);
    // a lossy length clamp keeps the body bounded; errors are prose
    let msg: String = msg.chars().take(4096).collect();
    put_u32(&mut b, msg.len() as u32);
    b.extend_from_slice(msg.as_bytes());
    b
}

/// Inverse of [`encode_done_msg`]/[`encode_err_msg`].
pub fn decode_result_msg(body: &[u8]) -> Result<(u64, u64, Result<TaskOutcome, String>)> {
    let mut r = Rd { b: body, pos: 0 };
    let seq = r.u64()?;
    let index = r.u64()?;
    match r.u8()? {
        1 => {
            let msg = r.string()?;
            r.done()?;
            Ok((seq, index, Err(msg)))
        }
        0 => {
            let client = usize::try_from(r.u64()?)?;
            let p = usize::try_from(r.u64()?)?;
            let tau = usize::try_from(r.u64()?)?;
            let bytes = r.u64()?;
            let up_bytes = r.u64()?;
            let completion = r.f64_bits()?;
            let mean_loss = r.f64_bits()?;
            let final_loss = r.f64_bits()?;
            let mean_grad_sq = r.f64_bits()?;
            let estimates = match r.u8()? {
                0 => None,
                1 => Some(ClientEstimates {
                    l: r.f64_bits()?,
                    sigma_sq: r.f64_bits()?,
                    g_sq: r.f64_bits()?,
                }),
                k => return Err(anyhow!("unknown estimates tag {k}")),
            };
            let params = take_tensors(&mut r)?;
            r.done()?;
            Ok((
                seq,
                index,
                Ok(TaskOutcome {
                    client,
                    p,
                    tau,
                    bytes,
                    up_bytes,
                    completion,
                    result: LocalResult {
                        params,
                        mean_loss,
                        final_loss,
                        mean_grad_sq,
                        estimates,
                    },
                }),
            ))
        }
        k => Err(anyhow!("unknown result status {k}")),
    }
}

// ---------------------------------------------------------------- envelope

/// Split a received envelope into `(kind, body_len)`.
pub fn split_envelope(head: &[u8; ENVELOPE_LEN]) -> (u32, u64) {
    let mut r = Rd { b: head, pos: 0 };
    match (r.u32(), r.u64()) {
        (Ok(kind), Ok(n)) => (kind, n),
        // unreachable: the array is exactly ENVELOPE_LEN bytes
        _ => (0, 0),
    }
}

/// Assemble one on-the-wire message: envelope + body.
pub fn frame(kind: u32, body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(ENVELOPE_LEN + body.len());
    put_u32(&mut out, kind);
    put_u64(&mut out, body.len() as u64);
    out.extend_from_slice(body);
    out
}

/// Write one message to a (blocking) stream.
pub fn write_msg<W: Write>(w: &mut W, kind: u32, body: &[u8]) -> Result<()> {
    w.write_all(&frame(kind, body))?;
    w.flush()?;
    Ok(())
}

/// Fill `buf` from `r`, tolerating arbitrary chunking; returns the
/// bytes actually read (short only at end-of-stream).
fn read_full<R: Read>(r: &mut R, buf: &mut [u8]) -> std::io::Result<usize> {
    let mut filled = 0usize;
    while filled < buf.len() {
        let Some(dst) = buf.get_mut(filled..) else { break };
        match r.read(dst) {
            Ok(0) => break,
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(filled)
}

/// Read one message off a (blocking) stream: `Ok(None)` on a clean
/// end-of-stream at a message boundary, a typed error on a truncated
/// envelope/body or a declared length above `cap` (checked before any
/// allocation — the peer cannot size our buffers).
pub fn read_msg<R: Read>(r: &mut R, cap: u64) -> Result<Option<(u32, Vec<u8>)>> {
    let mut head = [0u8; ENVELOPE_LEN];
    let got = read_full(r, &mut head)?;
    if got == 0 {
        return Ok(None);
    }
    if got < ENVELOPE_LEN {
        return Err(anyhow!("transport stream ended mid-envelope ({got} of {ENVELOPE_LEN} bytes)"));
    }
    let mut hr = Rd { b: &head, pos: 0 };
    let kind = hr.u32()?;
    let n = hr.u64()?;
    if n > cap {
        return Err(anyhow!("transport message of {n} bytes exceeds the {cap}-byte cap"));
    }
    let n = usize::try_from(n).map_err(|_| anyhow!("transport length {n} exceeds the address space"))?;
    let mut body = vec![0u8; n];
    let got = read_full(r, &mut body)?;
    if got < n {
        return Err(anyhow!("transport stream ended mid-body ({got} of {n} bytes)"));
    }
    Ok(Some((kind, body)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::round::TaskFate;

    fn image_batch(seed: f32) -> (XData, IntTensor) {
        let x = Tensor::from_vec(&[2, 3], vec![seed, 1.5, -2.25, 0.0, f32::MIN_POSITIVE, 7.0]);
        let y = IntTensor::from_vec(&[2], vec![1, 0]);
        (XData::Image(x), y)
    }

    fn token_batch() -> (XData, IntTensor) {
        let x = IntTensor::from_vec(&[2, 4], vec![5, 6, 7, 8, 9, 10, 11, 12]);
        let y = IntTensor::from_vec(&[2, 4], vec![6, 7, 8, 9, 10, 11, 12, 13]);
        (XData::Tokens(x), y)
    }

    fn task(batches: Vec<(XData, IntTensor)>) -> LocalTask {
        LocalTask {
            client: 11,
            p: 3,
            tau: 2,
            lr: 0.125,
            train_exec: "train_p3".into(),
            probe_exec: Some("probe_p3".into()),
            payload: vec![Tensor::from_vec(&[2, 2], vec![1.0, -1.0, 0.5, 0.25])],
            stream: BatchStream::Fixed(FixedBatches::new(vec![image_batch(0.5)]).unwrap()),
            bytes: 1 << 33,
            up_bytes: (1 << 33) + 17,
            rebill_bytes: 9,
            wire: Some(WireTask {
                scheme: scheme_id::HEROES,
                round: 4,
                enc: Encoding { q8: true, topk: Some(0.25) },
            }),
            completion: 12.75,
            drop_at: None,
            fault: Some(FaultStamp {
                event: FaultEvent {
                    class: FaultClass::Corrupt,
                    severity: 2,
                    frac: 0.4,
                    stall: 0.0,
                    bit: 37,
                },
                action: FaultAction::Retry,
                retries: 1,
                recovered: true,
                fault_time: 0.0,
            }),
        }
    }

    #[test]
    fn task_messages_round_trip_bit_exactly() {
        for batches in [vec![image_batch(0.5), image_batch(-3.0)], vec![token_batch()]] {
            let t = task(batches.clone());
            let body = encode_task_msg(7, 2, &t, &batches).unwrap();
            let (seq, index, mut back) = decode_task_msg(&body).unwrap();
            assert_eq!((seq, index), (7, 2));
            assert_eq!(back.client, t.client);
            assert_eq!(back.p, t.p);
            assert_eq!(back.tau, t.tau);
            assert_eq!(back.lr.to_bits(), t.lr.to_bits());
            assert_eq!(back.train_exec, t.train_exec);
            assert_eq!(back.probe_exec, t.probe_exec);
            assert_eq!(back.bytes, t.bytes);
            assert_eq!(back.up_bytes, t.up_bytes);
            assert_eq!(back.rebill_bytes, t.rebill_bytes);
            assert_eq!(back.completion.to_bits(), t.completion.to_bits());
            assert!(back.drop_at.is_none());
            let w = back.wire.unwrap();
            assert_eq!(w.scheme, scheme_id::HEROES);
            assert_eq!(w.round, 4);
            assert!(w.enc.q8);
            assert_eq!(w.enc.topk, Some(0.25));
            let f = back.fault.unwrap();
            assert!(f.recovered);
            assert_eq!(f.event.class, FaultClass::Corrupt);
            assert_eq!(f.event.bit, 37);
            assert_eq!(back.payload.len(), 1);
            assert_eq!(back.payload[0].data(), t.payload[0].data());
            // the shipped schedule replays in order
            for (x, y) in &batches {
                let (bx, by) = back.stream.next_batch();
                match (x, &bx) {
                    (XData::Image(a), XData::Image(b)) => assert_eq!(a.data(), b.data()),
                    (XData::Tokens(a), XData::Tokens(b)) => assert_eq!(a.data(), b.data()),
                    _ => panic!("batch payload kind flipped in transit"),
                }
                assert_eq!(y.data(), by.data());
            }
        }
    }

    #[test]
    fn unstamped_tasks_ship_no_fault() {
        let batches = vec![image_batch(1.0)];
        let mut t = task(batches.clone());
        t.fault = None;
        t.wire = None;
        t.probe_exec = None;
        let body = encode_task_msg(0, 0, &t, &batches).unwrap();
        let (_, _, back) = decode_task_msg(&body).unwrap();
        assert!(back.fault.is_none());
        assert!(back.wire.is_none());
        assert!(back.probe_exec.is_none());
    }

    #[test]
    fn result_messages_round_trip_bit_exactly() {
        let o = TaskOutcome {
            client: 5,
            p: 2,
            tau: 3,
            bytes: 1 << 34,
            up_bytes: (1 << 34) + 3,
            completion: 9.5,
            result: LocalResult {
                params: vec![Tensor::from_vec(&[3], vec![0.1, -0.2, 0.3])],
                mean_loss: 1.25,
                final_loss: 1.0,
                mean_grad_sq: 0.0625,
                estimates: Some(ClientEstimates { l: 2.0, sigma_sq: 0.5, g_sq: 4.0 }),
            },
        };
        let body = encode_done_msg(3, 1, &o).unwrap();
        let (seq, index, res) = decode_result_msg(&body).unwrap();
        assert_eq!((seq, index), (3, 1));
        let back = res.unwrap();
        assert_eq!(back.client, 5);
        assert_eq!(back.up_bytes, o.up_bytes);
        assert_eq!(back.completion.to_bits(), o.completion.to_bits());
        assert_eq!(back.result.mean_loss.to_bits(), o.result.mean_loss.to_bits());
        assert_eq!(back.result.params[0].data(), o.result.params[0].data());
        let e = back.result.estimates.unwrap();
        assert_eq!(e.sigma_sq.to_bits(), 0.5f64.to_bits());

        let body = encode_err_msg(4, 0, "engine exploded");
        let (seq, index, res) = decode_result_msg(&body).unwrap();
        assert_eq!((seq, index), (4, 0));
        assert_eq!(res.unwrap_err(), "engine exploded");
    }

    #[test]
    fn stamped_fates_never_ship() {
        // a decoded task must never early-return a stamped fate on the
        // client: drop_at is stripped and only recovered-corrupt ships
        let batches = vec![image_batch(2.0)];
        let mut t = task(batches.clone());
        t.drop_at = Some(3.5);
        let body = encode_task_msg(0, 0, &t, &batches).unwrap();
        let (_, _, back) = decode_task_msg(&body).unwrap();
        assert!(crate::coordinator::round::stamped_fate(&back).is_none());
        assert!(matches!(
            crate::coordinator::round::stamped_fate(&t),
            Some(TaskFate::Dropped(_))
        ));
    }

    #[test]
    fn truncated_and_oversized_messages_are_typed_errors() {
        let batches = vec![image_batch(0.0)];
        let t = task(batches.clone());
        let body = encode_task_msg(1, 0, &t, &batches).unwrap();
        for cut in [0, 1, 8, 40, body.len() - 1] {
            assert!(decode_task_msg(&body[..cut]).is_err(), "cut {cut} must error");
        }
        // trailing garbage is rejected too
        let mut long = body.clone();
        long.push(0);
        assert!(decode_task_msg(&long).is_err());

        // envelope: chunked reads, clean EOF, truncation, cap
        let msg = frame(KIND_TASK, &body);
        struct Chunky<'a>(&'a [u8], usize);
        impl std::io::Read for Chunky<'_> {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                let n = 3.min(buf.len()).min(self.0.len() - self.1);
                buf[..n].copy_from_slice(&self.0[self.1..self.1 + n]);
                self.1 += n;
                Ok(n)
            }
        }
        let (kind, got) = read_msg(&mut Chunky(&msg, 0), FRAME_CAP).unwrap().unwrap();
        assert_eq!(kind, KIND_TASK);
        assert_eq!(got, body);
        assert!(read_msg(&mut Chunky(&[], 0), FRAME_CAP).unwrap().is_none());
        assert!(read_msg(&mut Chunky(&msg[..5], 0), FRAME_CAP).is_err());
        assert!(read_msg(&mut Chunky(&msg[..20], 0), FRAME_CAP).is_err());
        let err = read_msg(&mut Chunky(&msg, 0), 4).unwrap_err();
        assert!(err.to_string().contains("exceeds the 4-byte cap"), "{err}");
    }

    #[test]
    fn hello_round_trips_and_rejects_noise() {
        assert!(hello_ok(&hello_body()));
        assert!(!hello_ok(b"HEROES1"));
        assert!(!hello_ok(b"HEROES2\0"));
        assert!(!hello_ok(&[]));
    }

    #[test]
    fn envelope_splits_round_trip() {
        let msg = frame(KIND_RESULT, &[1, 2, 3]);
        let head: [u8; ENVELOPE_LEN] = msg[..ENVELOPE_LEN].try_into().unwrap();
        assert_eq!(split_envelope(&head), (KIND_RESULT, 3));
    }

    #[test]
    fn batches_needed_covers_the_retry_path() {
        assert_eq!(batches_needed(1, false), 2);
        assert_eq!(batches_needed(4, false), 8);
        assert_eq!(batches_needed(4, true), 10);
    }
}
