//! The executor side of the networked transport: a synchronous loop
//! that greets the coordinator, then serves task messages until the
//! coordinator hangs up.
//!
//! Runs identically as an in-process thread (the loopback tests and
//! `transport::tcp::with_loopback`) or as a separate OS process
//! (`heroes client --connect <addr>`): both paths are a plain
//! `std::net::TcpStream` plus an [`Engine`] — no async runtime on the
//! client, so the `net` cargo feature is not needed here.
//!
//! Execution reuses the exact worker body of the simulation
//! ([`exec_task`]): same PJRT executables, same wire-frame
//! encode/verify/decode, same divergence retry — the only difference
//! is that batches replay from the task's shipped schedule instead of
//! a live loader, which `BatchStream::Fixed` makes bit-identical.

use crate::coordinator::round::{exec_task, TaskFate};
use crate::runtime::Engine;
use crate::transport::proto::{self, KIND_RESULT, KIND_TASK};
use anyhow::{anyhow, Result};
use std::net::TcpStream;

/// Serve one coordinator connection until it closes the stream.
///
/// A clean end-of-stream at a message boundary is a normal shutdown
/// (`Ok(())`); a mid-message cut or a malformed message is an error.
/// Task failures do *not* tear the loop down — they travel back as
/// error results and fail the run coordinator-side, exactly like an
/// in-process task error.
pub fn client_loop(mut stream: TcpStream, engine: &Engine) -> Result<()> {
    // results are small; don't batch them behind Nagle
    stream.set_nodelay(true)?;
    proto::write_msg(&mut stream, proto::KIND_HELLO, &proto::hello_body())?;
    loop {
        let Some((kind, body)) = proto::read_msg(&mut stream, proto::FRAME_CAP)? else {
            return Ok(());
        };
        if kind != KIND_TASK {
            return Err(anyhow!("client expected a task message, got kind {kind}"));
        }
        let (seq, index, task) = proto::decode_task_msg(&body)?;
        let reply = match exec_task(engine, task) {
            Ok(TaskFate::Done(outcome)) => proto::encode_done_msg(seq, index, &outcome)?,
            // decode_task_msg strips drop/unrecovered-fault stamps (the
            // coordinator resolves those fates locally), so a stamped
            // fate surfacing here means the two sides disagree about
            // the protocol — report it instead of guessing
            Ok(_) => proto::encode_err_msg(
                seq,
                index,
                "stamped fate executed client-side: dropout/fault stamps must never ship",
            ),
            Err(e) => proto::encode_err_msg(seq, index, &format!("{e:#}")),
        };
        proto::write_msg(&mut stream, KIND_RESULT, &reply)?;
    }
}
