//! Local-update-frequency mathematics (paper §V-B, Eq. 23-27).
//!
//! The approximated convergence bound (Eq. 23)
//!
//!   G(H, τ) = 4F(x⁰)/(Hητ) + LητΦ/3 + 6L²β²,   Φ = G² + 18σ²
//!
//! is convex in τ; its minimizer at fixed H is
//!
//!   τ*(H) = sqrt(12 F / (η² H L Φ)).                       (Eq. 26)
//!
//! Substituting τ* gives G*(H) = 4·sqrt(F·L·Φ/(3H)) + 6L²β², decreasing
//! in H, so the *smallest* round count meeting the convergence target ε is
//!
//!   H* = ceil( 16·F·L·Φ / (3·(ε − 6L²β²)²) ).
//!
//! Alg. 1 line 13 solves Eq. 27 by exactly this: each candidate client is
//! assumed fastest, H* is computed, and its projected total time
//! T_n = H*·(τ*(H*)·μ_n + ν_n) ranks the clients. Eq. 24 then brackets the
//! other clients' τ so nobody waits more than ρ.

/// Variable estimates aggregated from client probes (Alg. 2 l.7-9 → Alg. 1 l.25).
#[derive(Debug, Clone, Copy)]
pub struct Estimates {
    /// smoothness L
    pub l: f64,
    /// gradient-variance bound σ²
    pub sigma_sq: f64,
    /// gradient-norm bound G²
    pub g_sq: f64,
    /// current global loss F(x^h)
    pub loss: f64,
}

impl Estimates {
    /// Sensible bootstrap before any probe data exists (round 0 uses the
    /// predefined τ anyway; these values only avoid division by zero).
    pub fn bootstrap(loss: f64) -> Estimates {
        Estimates { l: 1.0, sigma_sq: 1.0, g_sq: 1.0, loss: loss.max(1e-3) }
    }

    /// Φ = G² + 18σ² (appears throughout §V).
    pub fn phi(&self) -> f64 {
        self.g_sq + 18.0 * self.sigma_sq
    }

    /// Guard against degenerate probes: clamp everything positive.
    pub fn sanitized(&self) -> Estimates {
        Estimates {
            l: self.l.clamp(1e-3, 1e3),
            sigma_sq: self.sigma_sq.clamp(1e-8, 1e6),
            g_sq: self.g_sq.clamp(1e-8, 1e6),
            loss: self.loss.clamp(1e-3, 1e6),
        }
    }
}

/// τ*(H) = sqrt(12 F / (η² H L Φ)) (Eq. 26), as a float ≥ 1.
pub fn tau_opt(est: &Estimates, eta: f64, h: usize) -> f64 {
    let e = est.sanitized();
    let denom = eta * eta * h as f64 * e.l * e.phi();
    (12.0 * e.loss / denom).sqrt().max(1.0)
}

/// Cap an *observed* β² proxy so the Eq. 23 floor 6L²β² never swallows
/// ε: the proxy (block-training imbalance) is an error-bound estimate,
/// not a certainty, and an uncapped early-training spike (CV² ≈ 1 after
/// one skewed round) would pin H* at h_max and collapse every τ to the
/// floor — a degenerate regime as bad as the hardcoded β² = 0 it
/// replaces. Capping at ε/(12L²) keeps the margin ≥ ε/2, so H* grows at
/// most 4× over the β² = 0 horizon while staying monotone in the
/// observed imbalance.
pub fn capped_beta_sq(observed: f64, epsilon: f64, l: f64) -> f64 {
    let l = l.clamp(1e-3, 1e3);
    observed.max(0.0).min(epsilon / (12.0 * l * l))
}

/// H* = smallest round count whose optimal-τ bound reaches `epsilon`
/// (β² — the coefficient-reduction error bound — shifts the floor).
/// Clamped to [1, h_max]: when ε is unreachable (ε ≤ 6L²β²) the best the
/// controller can do is run the maximum horizon.
pub fn solve_rounds(est: &Estimates, epsilon: f64, beta_sq: f64, h_max: usize) -> usize {
    let e = est.sanitized();
    let floor = 6.0 * e.l * e.l * beta_sq;
    let margin = epsilon - floor;
    if margin <= 0.0 {
        return h_max;
    }
    let h = (16.0 * e.loss * e.l * e.phi() / (3.0 * margin * margin)).ceil();
    (h as usize).clamp(1, h_max)
}

/// Projected fraction of a cohort's training lost to staleness discounts
/// if the round closes at the `k`-th of its **ascending-sorted**
/// projected completion times: each straggler `i > k` merges roughly
/// `⌈(t_i − t_k)/t_k⌉` rounds late (subsequent quorum rounds advance the
/// clock by ~t_k each) at weight `1/(1+s)^α`, so `(1 − w)` of its
/// contribution is discounted away. This is the adaptive quorum
/// controller's per-candidate-K penalty projection — the same
/// lost-iteration units `BlockLedger::staleness_index` reports after the
/// fact. Non-increasing in `k` (fewer, closer stragglers) and
/// non-decreasing in `α`; 0 at `k ≥ n` (full barrier projects no
/// staleness).
#[allow(clippy::indexing_slicing)]
// hlint::allow(panic_path, item): the `k == 0 || k >= n` guard pins `k` to `1..n`, so both `[k - 1]` and `[k..]` are in bounds
pub fn projected_staleness_loss(sorted_completions: &[f64], k: usize, alpha: f64) -> f64 {
    let n = sorted_completions.len();
    if k == 0 || k >= n {
        return 0.0;
    }
    let t_k = sorted_completions[k - 1].max(1e-12);
    sorted_completions[k..]
        .iter()
        .map(|&t| {
            let s = ((t - t_k) / t_k).ceil().max(1.0);
            1.0 - (1.0 / (1.0 + s)).powf(alpha)
        })
        .sum::<f64>()
        / n as f64
}

/// The staleness budget the adaptive quorum controller may spend per
/// round: `margin_frac` of the Eq. 23 margin `ε − 6L²β²`, expressed in
/// the same lost-training-fraction units as `projected_staleness_loss`
/// (an extra β² increment of that size raises the 6L²β² floor by at most
/// the granted margin slice). β² goes through [`capped_beta_sq`] first so
/// an early imbalance spike cannot zero the budget and pin K at N
/// forever; the cap keeps the margin ≥ ε/2, so the budget stays positive
/// while still shrinking monotonically as the observed imbalance grows.
pub fn staleness_budget(epsilon: f64, l: f64, beta_sq: f64, margin_frac: f64) -> f64 {
    let l = l.clamp(1e-3, 1e3);
    let b = capped_beta_sq(beta_sq, epsilon, l);
    let margin = (epsilon - 6.0 * l * l * b).max(0.0);
    margin_frac.clamp(0.0, 1.0) * margin / (6.0 * l * l)
}

/// Projected total completion time if client (μ, ν) is the fastest
/// (Eq. 27): T(H) = H · (τ*(H)·μ + ν).
pub fn projected_total_time(est: &Estimates, eta: f64, h: usize, mu: f64, nu: f64) -> f64 {
    h as f64 * (tau_opt(est, eta, h) * mu + nu)
}

/// Eq. 24 bracket: τ for client (μ, ν) such that
/// 0 ≤ T_l − (τ·μ + ν) ≤ ρ, intersected with [τ_min, τ_max].
/// Returns an inclusive integer interval, or the closest feasible point
/// when the exact bracket is empty (a very slow client simply gets τ_min —
/// it is the straggler the width assignment should have prevented).
pub fn tau_bounds(t_l: f64, mu: f64, nu: f64, rho: f64, tau_min: usize, tau_max: usize) -> (usize, usize) {
    debug_assert!(mu > 0.0);
    let hi = ((t_l - nu) / mu).floor();
    let lo = ((t_l - rho - nu) / mu).ceil();
    let lo = (lo.max(tau_min as f64)) as usize;
    let hi = if hi < tau_min as f64 { tau_min } else { (hi as usize).min(tau_max) };
    if lo > hi {
        // infeasible bracket: collapse onto the nearest feasible τ
        let pin = hi.clamp(tau_min, tau_max);
        (pin, pin)
    } else {
        (lo.clamp(tau_min, tau_max), hi)
    }
}

/// Completion time of one client for a round (Eq. 19 summand).
pub fn completion_time(tau: usize, mu: f64, nu: f64) -> f64 {
    tau as f64 * mu + nu
}

#[cfg(test)]
mod tests {
    use super::*;

    fn est() -> Estimates {
        Estimates { l: 2.0, sigma_sq: 0.5, g_sq: 4.0, loss: 2.3 }
    }

    #[test]
    fn phi_combines_bounds() {
        assert!((est().phi() - 13.0).abs() < 1e-12);
    }

    #[test]
    fn tau_opt_decreases_with_h() {
        let e = est();
        let t10 = tau_opt(&e, 0.05, 10);
        let t100 = tau_opt(&e, 0.05, 100);
        assert!(t10 > t100, "{t10} !> {t100}");
        // exact: sqrt(12*2.3/(0.05^2*10*2*13))
        let expect = (12.0 * 2.3 / (0.05f64.powi(2) * 10.0 * 2.0 * 13.0)).sqrt();
        assert!((t10 - expect).abs() < 1e-9);
    }

    #[test]
    fn tau_opt_floors_at_one() {
        let e = Estimates { l: 100.0, sigma_sq: 100.0, g_sq: 100.0, loss: 1e-3 };
        assert_eq!(tau_opt(&e, 0.5, 10_000), 1.0);
    }

    #[test]
    fn solve_rounds_monotone_in_epsilon() {
        let e = est();
        let h_loose = solve_rounds(&e, 1.0, 0.0, 100_000);
        let h_tight = solve_rounds(&e, 0.1, 0.0, 100_000);
        assert!(h_tight > h_loose, "{h_tight} !> {h_loose}");
    }

    #[test]
    fn solve_rounds_strictly_increases_with_beta_sq() {
        // the 6L²β² floor of Eq. 23 shrinks the margin ε − floor, so at a
        // fixed ε the required horizon must strictly grow with β² (until
        // the h_max clamp)
        let e = est();
        let mut prev = 0;
        for beta_sq in [0.0, 1e-3, 2e-3, 4e-3] {
            let h = solve_rounds(&e, 0.5, beta_sq, 10_000_000);
            assert!(h > prev, "H* not strictly increasing: {h} !> {prev} at β²={beta_sq}");
            prev = h;
        }
    }

    #[test]
    fn solve_rounds_caps_when_unreachable() {
        let e = est();
        // floor = 6 L² β² = 24 β²; with β²=1, floor=24 > ε
        assert_eq!(solve_rounds(&e, 0.5, 1.0, 500), 500);
    }

    #[test]
    fn capped_beta_keeps_solver_out_of_the_degenerate_regime() {
        // An early-training imbalance spike (CV² ≈ 1) fed raw would pin
        // H* at h_max; through the cap the margin stays ≥ ε/2, so H* is
        // finite (≤ 4× the β²=0 horizon) yet still grows with imbalance.
        let e = est(); // L = 2 after sanitize
        let (eps, h_max) = (0.5, 10_000_000);
        let h0 = solve_rounds(&e, eps, 0.0, h_max);
        assert_eq!(solve_rounds(&e, eps, 1.0, h_max), h_max, "raw spike saturates");
        let capped = capped_beta_sq(1.0, eps, e.l);
        let h_capped = solve_rounds(&e, eps, capped, h_max);
        assert!(h_capped < h_max, "capped β² must not saturate the solver");
        assert!(h_capped > h0, "capped β² must still lengthen the horizon");
        assert!(h_capped <= 4 * h0 + 4, "margin ≥ ε/2 bounds the blow-up at 4×");
        // small observations pass through untouched; negatives clamp to 0
        assert_eq!(capped_beta_sq(1e-4, eps, e.l), 1e-4);
        assert_eq!(capped_beta_sq(-1.0, eps, e.l), 0.0);
    }

    #[test]
    fn projected_staleness_loss_shape() {
        let sorted = [1.0, 1.1, 1.2, 4.5];
        // full barrier (k = n) projects no staleness; so does k = 0
        assert_eq!(projected_staleness_loss(&sorted, 4, 1.0), 0.0);
        assert_eq!(projected_staleness_loss(&sorted, 0, 1.0), 0.0);
        // k = 3: one straggler 4.5 vs t_k = 1.2 → s = ⌈2.75⌉ = 3,
        // lost = (1 − 1/4)/4
        let l3 = projected_staleness_loss(&sorted, 3, 1.0);
        assert!((l3 - 0.75 / 4.0).abs() < 1e-12, "got {l3}");
        // non-increasing in k, non-decreasing in α
        let l1 = projected_staleness_loss(&sorted, 1, 1.0);
        let l2 = projected_staleness_loss(&sorted, 2, 1.0);
        assert!(l1 >= l2 && l2 >= l3, "{l1} {l2} {l3}");
        assert!(projected_staleness_loss(&sorted, 2, 2.0) >= l2);
        // α = 0 never discounts, so nothing is projected lost
        assert_eq!(projected_staleness_loss(&sorted, 1, 0.0), 0.0);
    }

    #[test]
    fn staleness_budget_shrinks_with_imbalance_but_stays_positive() {
        let (eps, l) = (0.8, 2.0);
        let b0 = staleness_budget(eps, l, 0.0, 0.5);
        assert!((b0 - 0.5 * eps / (6.0 * l * l)).abs() < 1e-12);
        let b_mid = staleness_budget(eps, l, 1e-3, 0.5);
        assert!(b_mid < b0, "budget must shrink with observed β²");
        // a CV² ≈ 1 spike goes through the cap: margin ≥ ε/2, budget > 0
        let b_spike = staleness_budget(eps, l, 1.0, 0.5);
        assert!(b_spike > 0.0, "capped β² must leave a positive budget");
        assert!(b_spike >= 0.5 * (eps / 2.0) / (6.0 * l * l) - 1e-15);
        // margin_frac scales linearly and clamps to [0, 1]
        assert!((staleness_budget(eps, l, 0.0, 1.0) - 2.0 * b0).abs() < 1e-12);
        assert_eq!(staleness_budget(eps, l, 0.0, -1.0), 0.0);
    }

    #[test]
    fn projected_time_increasing_in_mu_nu() {
        let e = est();
        let base = projected_total_time(&e, 0.05, 50, 0.1, 1.0);
        assert!(projected_total_time(&e, 0.05, 50, 0.2, 1.0) > base);
        assert!(projected_total_time(&e, 0.05, 50, 0.1, 2.0) > base);
    }

    #[test]
    fn tau_bounds_bracket_matches_eq24() {
        // T_l = 10, μ = 0.5, ν = 1, ρ = 2 → τ ∈ [(10-2-1)/0.5, (10-1)/0.5] = [14, 18]
        let (lo, hi) = tau_bounds(10.0, 0.5, 1.0, 2.0, 1, 100);
        assert_eq!((lo, hi), (14, 18));
        // every τ in the bracket satisfies 0 ≤ T_l - (τμ+ν) ≤ ρ
        for tau in lo..=hi {
            let slack = 10.0 - completion_time(tau, 0.5, 1.0);
            assert!((0.0..=2.0).contains(&slack), "τ={tau} slack={slack}");
        }
    }

    #[test]
    fn tau_bounds_clamp_to_range() {
        let (lo, hi) = tau_bounds(1000.0, 0.1, 0.0, 1.0, 1, 30);
        assert_eq!((lo, hi), (30, 30)); // wants huge τ, capped at τ_max... bracket collapses
        let (lo, hi) = tau_bounds(0.1, 1.0, 5.0, 1.0, 1, 30);
        assert_eq!((lo, hi), (1, 1)); // slow client pinned at τ_min
    }
}
