//! Global aggregation (paper §III-3).
//!
//! * neural basis: plain average over the K participants,
//!   `v^{h+1} = (1/K) Σ v̄_n` — every client trains the full basis.
//! * coefficient: **block-wise** average (Eq. 5) — each block averages
//!   over exactly the clients that trained it; untouched blocks carry the
//!   previous global value forward.
//! * head bias: plain average (it rides along with every payload).
//!
//! `DenseAccumulator` implements the baselines' aggregation: FedAvg's
//! plain average is the width-P special case of HeteroFL's overlap-aware
//! element-count averaging.
//!
//! Both accumulators carry **f32 weight sums** instead of integer counts:
//! the semi-async quorum path (`coordinator::round`, "Semi-async quorum
//! rounds") folds late arrivals with staleness weight `1/(1+s)^α`, so a
//! block's average becomes `Σ wᵢxᵢ / Σ wᵢ` — an affine combination whose
//! effective coefficients sum to 1 for every block. The weighted pushes
//! accumulate **in place** via fused axpy loops (`scatter_blocks_axpy`,
//! `scatter_prefix_axpy`, `Tensor::axpy`) — no per-push clone or scaled
//! temporary is ever materialized (pinned by the clone+scale reference-
//! equivalence tests below and benched in `bench_hotpaths`). Unit-weight
//! pushes are bit-identical to the old integer-count arithmetic (×1.0 is
//! exact; an f32 sum of 1.0s equals the u32 count exactly up to 2²⁴
//! clients), which is what keeps `--quorum N` byte-identical to the
//! serial loop.

use crate::model::{ComposedGlobal, DenseGlobal};
use crate::runtime::ModelInfo;
use crate::tensor::blocks::{finalize_block_weighted, scatter_blocks_axpy};
use crate::tensor::Tensor;
use anyhow::{anyhow, Result};

/// Accumulates composed-model client updates for one round.
pub struct ComposedAccumulator<'a> {
    info: &'a ModelInfo,
    prev: &'a ComposedGlobal,
    basis_sums: Vec<Tensor>,
    coeff_sums: Vec<Tensor>,
    coeff_weights: Vec<Vec<f32>>,
    bias_sum: Tensor,
    weight_sum: f32,
    clients: u32,
}

impl<'a> ComposedAccumulator<'a> {
    pub fn new(info: &'a ModelInfo, prev: &'a ComposedGlobal) -> ComposedAccumulator<'a> {
        ComposedAccumulator {
            info,
            prev,
            basis_sums: info.layers.iter().map(|l| Tensor::zeros(&l.basis_shape)).collect(),
            coeff_sums: info
                .layers
                .iter()
                .map(|l| Tensor::zeros(&l.full_coeff_shape()))
                .collect(),
            coeff_weights: info.layers.iter().map(|l| vec![0.0f32; l.blocks_total]).collect(),
            bias_sum: Tensor::zeros(prev.bias.shape()),
            weight_sum: 0.0,
            clients: 0,
        }
    }

    /// Fold in one client's updated parameter list
    /// `[v̄_0, ū̂_0, v̄_1, ū̂_1, ..., bias]` with its block selections.
    pub fn push(&mut self, selections: &[Vec<usize>], updated: &[Tensor]) -> Result<()> {
        self.push_weighted(selections, updated, 1.0)
    }

    /// `push` with contribution weight `w` (quorum members 1.0, late
    /// arrivals their staleness weight). Accumulates in place — no scaled
    /// temporary.
    #[allow(clippy::indexing_slicing)]
    // hlint::allow(panic_path, item): every index is bounded by the arity checks at fn entry (`updated.len() == 2l+1`, `selections.len() == l`) and the accumulator vectors were sized from the same `info.layers` in `new`
    pub fn push_weighted(
        &mut self,
        selections: &[Vec<usize>],
        updated: &[Tensor],
        w: f32,
    ) -> Result<()> {
        if w.is_nan() || w <= 0.0 {
            return Err(anyhow!("contribution weight must be positive, got {w}"));
        }
        let l = self.info.layers.len();
        if updated.len() != 2 * l + 1 {
            return Err(anyhow!("expected {} tensors, got {}", 2 * l + 1, updated.len()));
        }
        if selections.len() != l {
            return Err(anyhow!("expected {} selections", l));
        }
        for (idx, layer) in self.info.layers.iter().enumerate() {
            let v = &updated[2 * idx];
            let u_hat = &updated[2 * idx + 1];
            if v.shape() != layer.basis_shape.as_slice() {
                return Err(anyhow!("basis shape mismatch on {}", layer.name));
            }
            self.basis_sums[idx].axpy(w, v);
            scatter_blocks_axpy(
                &mut self.coeff_sums[idx],
                &mut self.coeff_weights[idx],
                u_hat,
                &selections[idx],
                layer.o,
                w,
            );
        }
        self.bias_sum.axpy(w, &updated[2 * l]);
        self.weight_sum += w;
        self.clients += 1;
        Ok(())
    }

    /// Number of clients folded in so far.
    pub fn count(&self) -> u32 {
        self.clients
    }

    /// Produce the next global model (paper Alg. 1 line 26).
    #[allow(clippy::indexing_slicing)]
    // hlint::allow(panic_path, item): `coeff_sums`/`coeff_weights` were sized from `info.layers` in `new`, and `prev` is the previous round's global built from the same manifest
    pub fn finalize(mut self) -> Result<ComposedGlobal> {
        if self.clients == 0 {
            return Err(anyhow!("no client updates to aggregate"));
        }
        let inv = 1.0 / self.weight_sum;
        for b in self.basis_sums.iter_mut() {
            b.scale(inv);
        }
        for (idx, layer) in self.info.layers.iter().enumerate() {
            finalize_block_weighted(
                &mut self.coeff_sums[idx],
                &self.coeff_weights[idx],
                &self.prev.coeffs[idx],
                layer.o,
            );
        }
        self.bias_sum.scale(inv);
        Ok(ComposedGlobal { bases: self.basis_sums, coeffs: self.coeff_sums, bias: self.bias_sum })
    }
}

/// Accumulates dense-model client updates (FedAvg / ADP / HeteroFL).
pub struct DenseAccumulator<'a> {
    info: &'a ModelInfo,
    prev: &'a DenseGlobal,
    weight_sums: Vec<Tensor>,
    elem_weights: Vec<Vec<f32>>,
    bias_sum: Tensor,
    weight_sum: f32,
    clients: u32,
}

impl<'a> DenseAccumulator<'a> {
    pub fn new(info: &'a ModelInfo, prev: &'a DenseGlobal) -> DenseAccumulator<'a> {
        DenseAccumulator {
            info,
            prev,
            weight_sums: prev.weights.iter().map(|w| Tensor::zeros(w.shape())).collect(),
            elem_weights: prev.weights.iter().map(|w| vec![0.0f32; w.len()]).collect(),
            bias_sum: Tensor::zeros(prev.bias.shape()),
            weight_sum: 0.0,
            clients: 0,
        }
    }

    /// Fold in one client's updated dense sub-model at width `p`
    /// (`[w̄_0, ..., w̄_{L-1}, bias]` with width-p shapes).
    pub fn push(&mut self, p: usize, updated: &[Tensor]) -> Result<()> {
        self.push_weighted(p, updated, 1.0)
    }

    /// `push` with contribution weight `w`, accumulated in place.
    #[allow(clippy::indexing_slicing)]
    // hlint::allow(panic_path, item): every index is bounded by the arity checks at fn entry (`updated.len() == l+1`, `specs.len() == l`) and the accumulator vectors were sized from `prev.weights` in `new`
    pub fn push_weighted(&mut self, p: usize, updated: &[Tensor], w: f32) -> Result<()> {
        if w.is_nan() || w <= 0.0 {
            return Err(anyhow!("contribution weight must be positive, got {w}"));
        }
        let l = self.info.layers.len();
        if updated.len() != l + 1 {
            return Err(anyhow!("expected {} tensors, got {}", l + 1, updated.len()));
        }
        let specs = self
            .info
            .dense_params
            .get(&p)
            .ok_or_else(|| anyhow!("no dense params at p={p}"))?;
        if specs.len() != l {
            // manifest input: a spec list that disagrees with the layer
            // count is a typed error, not an index panic below
            return Err(anyhow!("dense params at p={p} list {} specs for {l} layers", specs.len()));
        }
        for idx in 0..l {
            if updated[idx].shape() != specs[idx].shape.as_slice() {
                return Err(anyhow!(
                    "weight {idx} shape {:?} != spec {:?}",
                    updated[idx].shape(),
                    specs[idx].shape
                ));
            }
            self.weight_sums[idx]
                .scatter_prefix_axpy(&updated[idx], &mut self.elem_weights[idx], w);
        }
        self.bias_sum.axpy(w, &updated[l]);
        self.weight_sum += w;
        self.clients += 1;
        Ok(())
    }

    pub fn count(&self) -> u32 {
        self.clients
    }

    /// Element-wise overlap-aware weighted average; untouched elements
    /// carry the previous global value (HeteroFL).
    #[allow(clippy::indexing_slicing)]
    // hlint::allow(panic_path, item): `weight_sums`/`elem_weights` were sized element-for-element from `prev.weights` in `new`, so the zipped per-element walk stays in bounds
    pub fn finalize(mut self) -> Result<DenseGlobal> {
        if self.clients == 0 {
            return Err(anyhow!("no client updates to aggregate"));
        }
        for (idx, sums) in self.weight_sums.iter_mut().enumerate() {
            let weights = &self.elem_weights[idx];
            let prev = self.prev.weights[idx].data();
            let data = sums.data_mut();
            for (e, (&wsum, &pv)) in weights.iter().zip(prev).enumerate() {
                if wsum == 0.0 {
                    data[e] = pv;
                } else {
                    data[e] /= wsum;
                }
            }
        }
        self.bias_sum.scale(1.0 / self.weight_sum);
        Ok(DenseGlobal { weights: self.weight_sums, bias: self.bias_sum })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests_support::toy_info;
    use crate::util::rng::Rng;

    #[test]
    fn composed_roundtrip_identity() {
        // One client training everything at full width with no change
        // must reproduce the previous global exactly.
        let info = toy_info();
        let prev = ComposedGlobal::init(&info, &mut Rng::new(1)).unwrap();
        let sels = crate::model::full_selections(&info);
        let payload = prev.reduced_inputs(&info, info.cap_p, &sels).unwrap();
        let mut acc = ComposedAccumulator::new(&info, &prev);
        acc.push(&sels, &payload).unwrap();
        let next = acc.finalize().unwrap();
        assert_eq!(next.bases[0].data(), prev.bases[0].data());
        assert_eq!(next.coeffs[0].data(), prev.coeffs[0].data());
        assert_eq!(next.bias.data(), prev.bias.data());
    }

    #[test]
    fn composed_blockwise_average_eq5() {
        // Two clients train disjoint blocks of layer 0; each block must
        // take exactly its trainer's value; basis averages.
        let info = toy_info();
        let prev = ComposedGlobal::init(&info, &mut Rng::new(2)).unwrap();
        let mut acc = ComposedAccumulator::new(&info, &prev);

        let mk = |c: f32| -> Vec<Tensor> {
            vec![
                Tensor::from_vec(&[9, 2, 3], vec![c; 54]),
                Tensor::from_vec(&[3, 4], vec![c; 12]), // 1 block of layer 0
                Tensor::from_vec(&[1, 4, 3], vec![c; 12]),
                Tensor::from_vec(&[3, 5], vec![c; 15]), // 1 block of layer 1
                Tensor::from_vec(&[5], vec![c; 5]),
            ]
        };
        acc.push(&[vec![0], vec![0]], &mk(2.0)).unwrap();
        acc.push(&[vec![1], vec![1]], &mk(4.0)).unwrap();
        let next = acc.finalize().unwrap();
        // basis = mean(2, 4) = 3 everywhere
        assert!(next.bases[0].data().iter().all(|&x| (x - 3.0).abs() < 1e-6));
        // layer-0 coefficient: block 0 = 2.0, block 1 = 4.0
        let u = next.coeffs[0].data();
        for row in 0..3 {
            for c in 0..4 {
                assert_eq!(u[row * 8 + c], 2.0);
                assert_eq!(u[row * 8 + 4 + c], 4.0);
            }
        }
        assert!(next.bias.data().iter().all(|&x| (x - 3.0).abs() < 1e-6));
    }

    #[test]
    fn composed_shared_block_averages_paper_fig3() {
        // paper Fig. 3: a block trained by two clients with values 4 and 2
        // aggregates to 3.
        let info = toy_info();
        let prev = ComposedGlobal::init(&info, &mut Rng::new(3)).unwrap();
        let mut acc = ComposedAccumulator::new(&info, &prev);
        let mk = |c: f32| -> Vec<Tensor> {
            vec![
                Tensor::from_vec(&[9, 2, 3], vec![0.0; 54]),
                Tensor::from_vec(&[3, 4], vec![c; 12]),
                Tensor::from_vec(&[1, 4, 3], vec![0.0; 12]),
                Tensor::from_vec(&[3, 5], vec![0.0; 15]),
                Tensor::from_vec(&[5], vec![0.0; 5]),
            ]
        };
        acc.push(&[vec![0], vec![0]], &mk(4.0)).unwrap();
        acc.push(&[vec![0], vec![1]], &mk(2.0)).unwrap();
        let next = acc.finalize().unwrap();
        let u = next.coeffs[0].data();
        assert_eq!(u[0], 3.0); // (4+2)/2
        // block 1 untouched -> carried from prev
        assert_eq!(u[4], prev.coeffs[0].data()[4]);
    }

    #[test]
    fn composed_rejects_bad_shapes() {
        let info = toy_info();
        let prev = ComposedGlobal::init(&info, &mut Rng::new(4)).unwrap();
        let mut acc = ComposedAccumulator::new(&info, &prev);
        assert!(acc.push(&[vec![0], vec![0]], &[Tensor::zeros(&[1])]).is_err());
        assert!(ComposedAccumulator::new(&info, &prev).finalize().is_err());
    }

    #[test]
    fn dense_fedavg_is_plain_average_at_full_width() {
        let info = toy_info();
        let prev = DenseGlobal::init(&info, &mut Rng::new(5)).unwrap();
        let mut acc = DenseAccumulator::new(&info, &prev);
        let mk = |c: f32| -> Vec<Tensor> {
            vec![
                Tensor::from_vec(&[3, 3, 2, 8], vec![c; 144]),
                Tensor::from_vec(&[8, 5], vec![c; 40]),
                Tensor::from_vec(&[5], vec![c; 5]),
            ]
        };
        acc.push(2, &mk(1.0)).unwrap();
        acc.push(2, &mk(3.0)).unwrap();
        let next = acc.finalize().unwrap();
        assert!(next.weights[0].data().iter().all(|&x| (x - 2.0).abs() < 1e-6));
        assert!(next.bias.data().iter().all(|&x| (x - 2.0).abs() < 1e-6));
    }

    #[test]
    fn dense_heterofl_overlap_average() {
        // client A at width 1 (prefix region), client B at width 2 (full):
        // overlap averages, B-only region takes B's value, untouched = prev.
        let info = toy_info();
        let prev = DenseGlobal::init(&info, &mut Rng::new(6)).unwrap();
        let mut acc = DenseAccumulator::new(&info, &prev);
        let a = vec![
            Tensor::from_vec(&[3, 3, 2, 4], vec![1.0; 72]),
            Tensor::from_vec(&[4, 5], vec![1.0; 20]),
            Tensor::from_vec(&[5], vec![1.0; 5]),
        ];
        let b = vec![
            Tensor::from_vec(&[3, 3, 2, 8], vec![3.0; 144]),
            Tensor::from_vec(&[8, 5], vec![3.0; 40]),
            Tensor::from_vec(&[5], vec![3.0; 5]),
        ];
        acc.push(1, &a).unwrap();
        acc.push(2, &b).unwrap();
        let next = acc.finalize().unwrap();
        let w = next.weights[0].data();
        // element (0,0,0,0): trained by both -> 2.0
        assert_eq!(w[0], 2.0);
        // element (0,0,0,7): only B -> 3.0
        assert_eq!(w[7], 3.0);
        // head weight rows beyond width-1 prefix: only B
        assert_eq!(next.weights[1].data()[39], 3.0);
    }

    #[test]
    fn dense_rejects_unknown_width() {
        let info = toy_info();
        let prev = DenseGlobal::init(&info, &mut Rng::new(7)).unwrap();
        let mut acc = DenseAccumulator::new(&info, &prev);
        assert!(acc.push(9, &[Tensor::zeros(&[1])]).is_err());
    }

    #[test]
    fn weighted_push_rejects_nonpositive_weights() {
        let info = toy_info();
        let prev = ComposedGlobal::init(&info, &mut Rng::new(8)).unwrap();
        let sels = crate::model::full_selections(&info);
        let payload = prev.reduced_inputs(&info, info.cap_p, &sels).unwrap();
        for w in [0.0f32, -1.0, f32::NAN] {
            let mut acc = ComposedAccumulator::new(&info, &prev);
            assert!(acc.push_weighted(&sels, &payload, w).is_err(), "w={w} must be rejected");
        }
    }

    #[test]
    fn composed_weighted_matches_clone_scale_reference() {
        // In-place weighted accumulation must equal the naive
        // clone→scale→add reference bitwise: same multiply-then-add
        // rounding order, no scaled temporary needed.
        let info = toy_info();
        let prev = ComposedGlobal::init(&info, &mut Rng::new(9)).unwrap();
        let sels = crate::model::full_selections(&info);
        let payload = prev.reduced_inputs(&info, info.cap_p, &sels).unwrap();
        let w = 0.375f32;

        let mut fused = ComposedAccumulator::new(&info, &prev);
        fused.push_weighted(&sels, &payload, 1.0).unwrap();
        fused.push_weighted(&sels, &payload, w).unwrap();
        let fused = fused.finalize().unwrap();

        // reference: scale a cloned payload, push at weight 1... but fix
        // the normalization by replaying the same weight sums by hand
        let scaled: Vec<Tensor> = payload
            .iter()
            .map(|t| {
                let mut c = t.clone();
                c.scale(w);
                c
            })
            .collect();
        let l = info.layers.len();
        // numerator check: sums(1·x + w·x) == x + scaled elementwise
        for i in 0..l {
            let mut sum = payload[2 * i].clone();
            sum.add_assign(&scaled[2 * i]);
            let mut expect = sum;
            expect.scale(1.0 / (1.0 + w));
            assert_eq!(fused.bases[i].data(), expect.data(), "basis {i}");
        }
        let mut bias = payload[2 * l].clone();
        bias.add_assign(&scaled[2 * l]);
        bias.scale(1.0 / (1.0 + w));
        assert_eq!(fused.bias.data(), bias.data());
    }

    #[test]
    fn composed_weighted_identical_uploads_are_idempotent() {
        // Σ wᵢx / Σ wᵢ == x for any positive weights: the quorum round's
        // effective weights normalize to 1 for every block.
        let info = toy_info();
        let prev = ComposedGlobal::init(&info, &mut Rng::new(10)).unwrap();
        let mut acc = ComposedAccumulator::new(&info, &prev);
        let mut ledger = crate::coordinator::ledger::BlockLedger::new(&info).unwrap();
        for (i, w) in [1.0f32, 0.5, 0.25, 0.125].into_iter().enumerate() {
            let p = 1 + (i % info.cap_p);
            let sel = ledger.select_for_width(&info, p).unwrap();
            ledger.record(&sel, 1).unwrap();
            let payload = prev.reduced_inputs(&info, p, &sel.blocks).unwrap();
            acc.push_weighted(&sel.blocks, &payload, w).unwrap();
        }
        let next = acc.finalize().unwrap();
        for (a, b) in next.coeffs.iter().zip(&prev.coeffs) {
            assert!(a.sq_dist(b) < 1e-8, "coefficient drifted under identical weighted uploads");
        }
        for (a, b) in next.bases.iter().zip(&prev.bases) {
            assert!(a.sq_dist(b) < 1e-8, "basis drifted under identical weighted uploads");
        }
        assert!(next.bias.sq_dist(&prev.bias) < 1e-8);
    }

    #[test]
    fn dense_weighted_average_matches_f64_reference() {
        // two full-width clients at weights 1 and 0.5: every trained
        // element must equal (1·a + 0.5·b) / 1.5
        let info = toy_info();
        let prev = DenseGlobal::init(&info, &mut Rng::new(11)).unwrap();
        let mut acc = DenseAccumulator::new(&info, &prev);
        let mk = |c: f32| -> Vec<Tensor> {
            vec![
                Tensor::from_vec(&[3, 3, 2, 8], vec![c; 144]),
                Tensor::from_vec(&[8, 5], vec![c; 40]),
                Tensor::from_vec(&[5], vec![c; 5]),
            ]
        };
        acc.push_weighted(2, &mk(1.0), 1.0).unwrap();
        acc.push_weighted(2, &mk(4.0), 0.5).unwrap();
        let next = acc.finalize().unwrap();
        let expect = (1.0 + 0.5 * 4.0) / 1.5;
        assert!(next.weights[0].data().iter().all(|&x| (x - expect).abs() < 1e-6));
        assert!(next.bias.data().iter().all(|&x| (x - expect).abs() < 1e-6));
    }
}
