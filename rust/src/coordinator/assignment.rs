//! Round planning — the greedy controller of paper Alg. 1 (lines 4-23).
//!
//! Pure logic over client statuses, the cost model from the manifest and
//! the block ledger; no PJRT involvement, so the whole planner is unit-
//! and property-testable. Steps per round:
//!
//! 1. **Width assignment** (l.6-11): grow every client's width while the
//!    projected per-iteration time stays under μ^max.
//! 2. **Fastest-client selection** (l.12-14): solve Eq. 27 for H* once —
//!    it depends on the estimates, ε and the observed β² (Eq. 23's 6L²β²
//!    floor), not on any client's (μ, ν) — then rank clients by the
//!    projected total time to carry that horizon.
//! 3. **Frequency + block assignment** (l.15-22): the fastest client gets
//!    the bound-optimal τ*; everyone else gets the τ inside the Eq. 24
//!    bracket that minimizes the block-count variance V^h; block
//!    selections are the least-trained ones at assignment time, and the
//!    ledger is updated client-by-client exactly as in the paper.

use crate::codec::CodecCfg;
use crate::coordinator::frequency::{
    completion_time, projected_total_time, solve_rounds, tau_bounds, tau_opt, Estimates,
};
use crate::coordinator::ledger::{BlockLedger, Selection};
use crate::runtime::ModelInfo;
use crate::simulation::LinkSample;
use anyhow::{anyhow, Result};

/// Controller knobs (paper §V inputs), extracted from ExperimentConfig.
#[derive(Debug, Clone, Copy)]
pub struct ControllerCfg {
    pub mu_max: f64,
    pub rho: f64,
    pub eta: f64,
    pub epsilon: f64,
    pub tau_min: usize,
    pub tau_max: usize,
    /// Floor for the *fastest* client's τ. The scheme needs T_l to be the
    /// round's reference maximum (paper §V-B: "the completion time of
    /// client l is the largest"); at our reduced scale the honest bound
    /// constants can push τ* below the predefined τ, which would collapse
    /// the Eq. 24 brackets — so τ_l = max(τ*, τ_floor). DESIGN.md
    /// documents this deviation.
    pub tau_floor: usize,
    /// cap for the H* search
    pub h_max: usize,
    /// β² — the coefficient-reduction error bound of Eq. 23, whose 6L²β²
    /// term floors the reachable convergence target. The Heroes server
    /// feeds this from the *observed* block-training imbalance
    /// (`BlockLedger::relative_variance`) each round; 0 recovers the
    /// idealized no-reduction-error bound.
    pub beta_sq: f64,
    /// Upload-payload codec. ν (Eq. 18) is priced from the bytes the
    /// client will *actually* send: the analytic float count by default,
    /// or the measured wire-frame length in `wire` modes — so a
    /// quantized/sparsified upload shortens the planned tail exactly as
    /// it shortens the simulated one.
    pub codec: CodecCfg,
}

/// A client's observed status for the round (Alg. 1 line 4).
#[derive(Debug, Clone, Copy)]
pub struct ClientStatus {
    pub client: usize,
    /// sustained FLOP/s this round
    pub q_flops: f64,
    pub link: LinkSample,
}

/// The planned work for one participating client.
#[derive(Debug, Clone)]
pub struct Assignment {
    pub client: usize,
    pub p: usize,
    /// per-iteration compute time μ_n^h (Eq. 17)
    pub mu: f64,
    /// upload time ν_n^h (Eq. 18)
    pub nu: f64,
    pub tau: usize,
    /// group + block selection for this client
    pub selection: Selection,
    /// projected completion time τ·μ + ν
    pub projected_t: f64,
}

/// A planned round.
#[derive(Debug, Clone)]
pub struct RoundPlan {
    pub assignments: Vec<Assignment>,
    /// index into `assignments` of the fastest client l
    pub fastest: usize,
    /// T_l^h — the round's reference completion time
    pub t_l: f64,
    /// H* solved for the fastest client
    pub h_star: usize,
}

/// Collect round statuses for exactly the sampled cohort (Alg. 1 line 4).
///
/// This is the planner's only status entry point, and it is O(cohort):
/// one `FlEnv::status` draw per sampled client, nothing per population
/// member. With `--population lazy` each draw is a keyed RNG derivation,
/// so planning a K-client round costs the same at 100 clients as at a
/// million.
pub fn cohort_statuses(
    env: &mut crate::coordinator::env::FlEnv,
    clients: &[usize],
) -> Vec<ClientStatus> {
    clients.iter().map(|&c| env.status(c)).collect()
}

/// Width assignment (Alg. 1 lines 6-11): largest p with μ(p) ≤ μ^max.
///
/// Total over malformed manifests: a missing width in the cost map stops
/// the growth (same choice the in-bounds loop makes), and a manifest
/// without even width 1 yields `μ = ∞` — which the dispatch validation
/// rejects as a non-finite projected completion, instead of a panic here.
pub fn assign_width(info: &ModelInfo, q_flops: f64, mu_max: f64) -> (usize, f64) {
    let mut p = 1;
    let mut mu = info.flops_composed.get(&1).map_or(f64::INFINITY, |&f| f / q_flops);
    while p < info.cap_p {
        match info.flops_composed.get(&(p + 1)) {
            Some(&f) if f / q_flops <= mu_max => {
                p += 1;
                mu = f / q_flops;
            }
            _ => break,
        }
    }
    (p, mu)
}

/// Plan a full round (mutates the ledger exactly as Alg. 1 does).
/// Errs on an empty cohort — index 0 into an empty plan would panic in
/// every downstream consumer.
#[allow(clippy::indexing_slicing)]
pub fn plan_round(
    info: &ModelInfo,
    cfg: &ControllerCfg,
    est: &Estimates,
    statuses: &[ClientStatus],
    ledger: &mut BlockLedger,
) -> Result<RoundPlan> {
    if statuses.is_empty() {
        return Err(anyhow!("cannot plan a round with an empty cohort"));
    }

    // 1. widths + per-round cost components
    let mut partial: Vec<(ClientStatus, usize, f64, f64)> = statuses
        .iter()
        .map(|s| {
            let (p, mu) = assign_width(info, s.q_flops, cfg.mu_max);
            let up = crate::codec::upload_bytes(
                info.composed_params_of(p)?,
                info.bytes_composed_of(p)?,
                cfg.codec,
            );
            let nu = s.link.upload_time(up);
            Ok((*s, p, mu, nu))
        })
        .collect::<Result<_>>()?;

    // 2. fastest-client selection via Eq. 27. H* depends only on the
    // estimates / ε / β² — not on the candidate's (μ, ν) — so it is
    // solved once, not K times; clients are then ranked by the projected
    // total time they would need to carry that horizon.
    let h_star = solve_rounds(est, cfg.epsilon, cfg.beta_sq, cfg.h_max);
    let mut fastest = 0;
    let mut best_total = f64::INFINITY;
    for (i, (_, _, mu, nu)) in partial.iter().enumerate() {
        let t_n = projected_total_time(est, cfg.eta, h_star, *mu, *nu);
        if t_n < best_total {
            best_total = t_n;
            fastest = i;
        }
    }

    // 3a. fastest client: bound-optimal τ (floored, see ControllerCfg),
    // blocks, ledger update
    let tau_l = (tau_opt(est, cfg.eta, h_star).round() as usize)
        .clamp(cfg.tau_floor.max(cfg.tau_min), cfg.tau_max);
    // hlint::allow(panic_path): `fastest` came from enumerating `partial`, which is non-empty (checked at entry)
    let (s_l, p_l, mu_l, nu_l) = partial[fastest];
    let sel_l = ledger.select_for_width(info, p_l)?;
    ledger.record(&sel_l, tau_l as u64)?;
    let t_l = completion_time(tau_l, mu_l, nu_l);

    let mut assignments = vec![Assignment {
        client: s_l.client,
        p: p_l,
        mu: mu_l,
        nu: nu_l,
        tau: tau_l,
        selection: sel_l,
        projected_t: t_l,
    }];

    // 3b. everyone else: Eq. 24 bracket + V^h-minimizing τ
    // Keep original order except the fastest moved to front of processing.
    let rest: Vec<usize> = (0..partial.len()).filter(|&i| i != fastest).collect();
    for i in rest {
        // hlint::allow(panic_path): `rest` enumerates `0..partial.len()`
        let (s, p, mu, nu) = partial[i];
        let sel = ledger.select_for_width(info, p)?;
        let (lo, hi) = tau_bounds(t_l, mu, nu, cfg.rho, cfg.tau_min, cfg.tau_max);
        let mut best_tau = lo;
        let mut best_var = f64::INFINITY;
        for tau in lo..=hi {
            let v = ledger.variance_if(&sel, tau as u64);
            // `<=` so ties resolve to the LARGEST τ in the bracket: idle
            // headroom becomes extra local iterations (paper §II-C).
            if v <= best_var {
                best_var = v;
                best_tau = tau;
            }
        }
        ledger.record(&sel, best_tau as u64)?;
        assignments.push(Assignment {
            client: s.client,
            p,
            mu,
            nu,
            tau: best_tau,
            selection: sel,
            projected_t: completion_time(best_tau, mu, nu),
        });
    }
    // restore stable client order for downstream consumers
    partial.clear();
    assignments.sort_by_key(|a| a.client);
    let fastest_idx = assignments
        .iter()
        .position(|a| a.client == s_l.client)
        .ok_or_else(|| anyhow!("fastest client {} vanished from its own plan", s_l.client))?;

    Ok(RoundPlan { assignments, fastest: fastest_idx, t_l, h_star })
}

/// Reference-client selection over already-costed assignments: the index
/// and projected completion time of the **fastest** client — the same
/// "client l" semantics `plan_round` uses (paper §V-B ranks clients by
/// projected total time and takes the quickest as the round's reference).
/// The bootstrap round of `HeroesServer::plan` (no estimates yet) uses
/// this; it previously selected the *slowest* client via `max_by`.
///
/// `None` on an empty cohort — the old `(0, 0.0)` sentinel let callers
/// index assignment 0 of an empty plan and panic downstream; every
/// caller must now surface a proper error instead.
pub fn fastest_reference(assignments: &[Assignment]) -> Option<(usize, f64)> {
    assignments
        .iter()
        .enumerate()
        .map(|(i, a)| (i, a.projected_t))
        .min_by(|a, b| a.1.total_cmp(&b.1))
}

/// Average waiting time of a plan (paper Eq. 20) given the realized
/// completion times.
pub fn average_wait(completion_times: &[f64]) -> f64 {
    if completion_times.is_empty() {
        return 0.0;
    }
    let t_max = completion_times.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    completion_times.iter().map(|t| t_max - t).sum::<f64>() / completion_times.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests_support::toy_info;
    use crate::util::rng::Rng;

    fn cfg() -> ControllerCfg {
        ControllerCfg {
            mu_max: 0.5,
            rho: 1.0,
            eta: 0.05,
            epsilon: 0.05,
            tau_min: 1,
            tau_max: 50,
            tau_floor: 1,
            h_max: 100_000,
            beta_sq: 0.0,
            codec: CodecCfg::Analytic,
        }
    }

    fn est() -> Estimates {
        Estimates { l: 1.0, sigma_sq: 0.2, g_sq: 2.0, loss: 2.0 }
    }

    fn status(client: usize, q: f64, up_mbps: f64) -> ClientStatus {
        ClientStatus {
            client,
            q_flops: q,
            link: LinkSample { up_bps: up_mbps * 125_000.0, down_bps: 15.0 * 125_000.0 },
        }
    }

    #[test]
    fn width_grows_with_compute() {
        let info = toy_info(); // flops: p1=1e6, p2=2e6
        // q so that p2 iteration costs 0.4s (< mu_max) -> width 2
        let (p, mu) = assign_width(&info, 5e6, 0.5);
        assert_eq!(p, 2);
        assert!((mu - 0.4).abs() < 1e-9);
        // q so that p2 costs 2s -> stuck at width 1
        let (p, mu) = assign_width(&info, 1e6, 0.5);
        assert_eq!(p, 1);
        assert!((mu - 1.0).abs() < 1e-9);
    }

    #[test]
    fn plan_prefers_fast_client_as_reference() {
        let info = toy_info();
        let mut ledger = BlockLedger::new(&info).unwrap();
        let statuses = vec![
            status(0, 1e6, 1.0),  // slow compute, slow link
            status(1, 2e7, 5.0),  // fast everything
            status(2, 5e6, 2.0),
        ];
        let plan = plan_round(&info, &cfg(), &est(), &statuses, &mut ledger).unwrap();
        assert_eq!(plan.assignments.len(), 3);
        let fast = &plan.assignments[plan.fastest];
        assert_eq!(fast.client, 1);
        assert!(plan.t_l > 0.0);
        assert!(plan.h_star >= 1);
    }

    #[test]
    fn plan_balances_completion_times() {
        let info = toy_info();
        let mut ledger = BlockLedger::new(&info).unwrap();
        let statuses: Vec<ClientStatus> = (0..6)
            .map(|i| status(i, 2e6 + i as f64 * 4e6, 1.0 + i as f64 * 0.7))
            .collect();
        let plan = plan_round(&info, &cfg(), &est(), &statuses, &mut ledger).unwrap();
        // all completion times within ρ of the reference OR pinned at τ_min
        for a in &plan.assignments {
            let slack = plan.t_l - a.projected_t;
            assert!(
                slack >= -1e-9 || a.tau == 1,
                "client {} exceeds reference: slack {slack}",
                a.client
            );
            if a.tau > 1 && a.tau < 50 {
                assert!(slack <= cfg().rho + a.mu + 1e-9, "client {} waits too long: {slack}", a.client);
            }
        }
    }

    #[test]
    fn plan_updates_ledger_with_taus() {
        let info = toy_info();
        let mut ledger = BlockLedger::new(&info).unwrap();
        let statuses = vec![status(0, 1e7, 3.0), status(1, 1e7, 3.0)];
        let plan = plan_round(&info, &cfg(), &est(), &statuses, &mut ledger).unwrap();
        let total: u64 = plan
            .assignments
            .iter()
            .map(|a| a.tau as u64 * a.selection.groups[0].len() as u64)
            .sum();
        let class0: u64 = ledger.class_counts(0).iter().sum();
        assert_eq!(class0, total);
    }

    #[test]
    fn block_selection_rotates_across_rounds() {
        let info = toy_info();
        let mut ledger = BlockLedger::new(&info).unwrap();
        let statuses = vec![status(0, 1e6, 1.0)]; // width 1 -> 1 block per layer
        let p1 = plan_round(&info, &cfg(), &est(), &statuses, &mut ledger).unwrap();
        let p2 = plan_round(&info, &cfg(), &est(), &statuses, &mut ledger).unwrap();
        // second round must pick the other (less-trained) group
        assert_ne!(p1.assignments[0].selection.groups[0], p2.assignments[0].selection.groups[0]);
    }

    #[test]
    fn fastest_reference_picks_minimum_projected_time() {
        // regression: the bootstrap plan used `max_by`, i.e. the slowest
        let info = toy_info();
        let ledger = BlockLedger::new(&info).unwrap();
        let mk = |client: usize, projected_t: f64| Assignment {
            client,
            p: 1,
            mu: 0.1,
            nu: 0.1,
            tau: 5,
            selection: ledger.select_for_width(&info, 1).unwrap(),
            projected_t,
        };
        let assignments = vec![mk(0, 9.0), mk(1, 2.0), mk(2, 5.0)];
        let (idx, t_l) = fastest_reference(&assignments).unwrap();
        assert_eq!(idx, 1, "must select the fastest client, not the slowest");
        assert!((t_l - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_cohort_is_an_error_not_a_sentinel() {
        // regression: fastest_reference(&[]) returned (0, 0.0), and the
        // first consumer to index assignment 0 panicked
        assert!(fastest_reference(&[]).is_none());
        let info = toy_info();
        let mut ledger = BlockLedger::new(&info).unwrap();
        let err = plan_round(&info, &cfg(), &est(), &[], &mut ledger).unwrap_err();
        assert!(err.to_string().contains("empty cohort"), "unexpected error: {err}");
    }

    #[test]
    fn h_star_grows_with_observed_beta_sq() {
        // regression: plan_round used to pass a literal β² = 0, erasing
        // the 6L²β² floor of Eq. 23 — the solved horizon must now grow
        // with the observed coefficient-reduction error
        let info = toy_info();
        let statuses = vec![status(0, 1e7, 3.0), status(1, 5e6, 1.5)];
        let mut h_prev = 0;
        // β² values small enough that ε − 6L²β² stays positive and H*
        // stays under h_max (the clamp would flatten the comparison)
        for beta_sq in [0.0, 0.001, 0.002] {
            let mut c = cfg();
            c.beta_sq = beta_sq;
            let mut ledger = BlockLedger::new(&info).unwrap();
            let plan = plan_round(&info, &c, &est(), &statuses, &mut ledger).unwrap();
            assert!(
                plan.h_star > h_prev,
                "H* must grow with β²: {} !> {h_prev} at β²={beta_sq}",
                plan.h_star
            );
            h_prev = plan.h_star;
        }
    }

    #[test]
    fn average_wait_matches_eq20() {
        let w = average_wait(&[1.0, 3.0, 5.0]);
        // T = 5; waits = 4, 2, 0 -> mean 2
        assert!((w - 2.0).abs() < 1e-12);
        assert_eq!(average_wait(&[]), 0.0);
    }

    #[test]
    fn deterministic_given_same_inputs() {
        let info = toy_info();
        let statuses: Vec<ClientStatus> = {
            let mut rng = Rng::new(5);
            (0..5).map(|i| status(i, rng.uniform_in(1e6, 2e7), rng.uniform_in(1.0, 5.0))).collect()
        };
        let mut l1 = BlockLedger::new(&info).unwrap();
        let mut l2 = BlockLedger::new(&info).unwrap();
        let a = plan_round(&info, &cfg(), &est(), &statuses, &mut l1).unwrap();
        let b = plan_round(&info, &cfg(), &est(), &statuses, &mut l2).unwrap();
        for (x, y) in a.assignments.iter().zip(&b.assignments) {
            assert_eq!(x.client, y.client);
            assert_eq!(x.tau, y.tau);
            assert_eq!(x.selection, y.selection);
        }
    }
}
