//! Shared parallel round pipeline — every scheme (Heroes, the dense
//! baselines, Flanc) plans a round into [`LocalTask`]s and hands them to
//! the [`RoundDriver`], which executes the simulated clients (possibly on
//! several worker threads over a per-worker [`EnginePool`]) and performs
//! the round bookkeeping the schemes used to reimplement one by one.
//!
//! # Pipeline
//!
//! A scheme's round is decomposed into the three [`Strategy`] hook phases
//! (see `baselines::Strategy`):
//!
//! * **A · plan-ahead** (`plan_ahead`) — sample participants, collect
//!   statuses and run any outcome-independent width/τ planning. Phase A
//!   is the only phase that consumes the environment's RNG, and it must
//!   not read state that phase C mutates — that contract is what lets the
//!   coordinator run it for round *h+1* while round *h* is still
//!   executing.
//! * **B · materialize** (`take_tasks`) — turn the pending plan into
//!   ordered, fully self-contained [`LocalTask`]s against the scheme's
//!   *current* global model (payloads, batch streams, executables).
//! * **C · finish** (`finish_round`) — fold the assignment-ordered
//!   [`TaskOutcome`]s into the global model and the environment's traffic
//!   meter / virtual clock (Eq. 19), emitting the [`RoundReport`].
//!
//! Between B and C the driver **dispatches**: a task queue feeds worker
//! threads, worker *i* pinned to engine *i* of the pool so executions
//! never contend on one PJRT client's intra-op lock, and a completion
//! channel carries `(task index, outcome)` pairs back to the coordinator,
//! which files them in assignment order.
//!
//! # Overlapped execution
//!
//! [`RoundDriver::run`] drives one round (B-phase output in, ordered
//! outcomes out). [`RoundDriver::run_overlapped`] drives a *sequence* of
//! rounds over one persistent worker pool: while round *h*'s stragglers
//! drain, the coordinator already runs phase A of round *h+1* (sampling,
//! statuses, outcome-independent width/τ planning), and round *h+1*'s
//! tasks hit the still-warm workers the moment phase C of round *h*
//! lands — no per-round fork/join barrier, no thread respawn. Payload
//! materialization (phase B) stays sequenced after phase C of the
//! previous round because a synchronous-FL payload is a function of the
//! aggregated global; overlapping *that* means semi-async aggregation,
//! which ROADMAP.md tracks as its own item.
//!
//! # Determinism contract
//!
//! A dispatched task touches no shared mutable state: its batch stream is
//! owned and seeded by `(seed, client, round)` ([`FlEnv::batch_stream`]),
//! its payload is owned, and PJRT CPU executions are deterministic
//! functions of their inputs — on *every* engine of the pool, since all
//! engines compile the same HLO through the same pipeline. Combined with
//! assignment-order collection and the phase contract above (A commutes
//! with C, B and C are sequenced), a seeded run produces **byte-identical
//! `RoundReport` sequences for any `--workers N`, any pool size, and for
//! overlapped vs. non-overlapped dispatch**
//! (`rust/tests/integration_parallel.rs` pins all three axes).

use crate::baselines::Strategy;
use crate::coordinator::assignment::average_wait;
use crate::coordinator::client::{run_local, LocalResult};
use crate::coordinator::env::{BatchStream, FlEnv};
use crate::coordinator::RoundReport;
use crate::runtime::{Engine, EnginePool};
use crate::tensor::Tensor;
use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Condvar, Mutex};

/// One client's planned local round, fully self-contained: a worker
/// thread needs nothing beyond the task and a `&Engine` to execute it.
///
/// Self-containment means the plan phase materializes all K payloads
/// before dispatch (peak memory K reduced payloads instead of the old
/// serial loop's one). Payloads are factorized sub-models and K is tens
/// of clients, so this is cheap; revisit (build payloads on-worker from
/// the read-only global) if cohorts grow orders of magnitude.
pub struct LocalTask {
    pub client: usize,
    /// assigned width
    pub p: usize,
    /// local update frequency τ
    pub tau: usize,
    /// effective learning rate for this round
    pub lr: f32,
    pub train_exec: String,
    /// estimation-probe executable (Heroes probing rounds only)
    pub probe_exec: Option<String>,
    /// parameter payload `[...]` in the executable's input layout
    pub payload: Vec<Tensor>,
    /// owned batch source (seeded by `(seed, client, round)`)
    pub stream: BatchStream,
    /// payload transfer size, counted once per direction (broadcast down,
    /// upload up)
    pub bytes: usize,
    /// projected completion time τ·μ + ν (Eq. 17-18)
    pub completion: f64,
}

/// A completed task: the plan metadata plus the local-training result.
pub struct TaskOutcome {
    pub client: usize,
    pub p: usize,
    pub tau: usize,
    pub bytes: usize,
    pub completion: f64,
    pub result: LocalResult,
}

fn exec_task(engine: &Engine, task: LocalTask) -> Result<TaskOutcome> {
    let LocalTask {
        client, p, tau, lr, train_exec, probe_exec, payload, mut stream, bytes, completion,
    } = task;
    let result = run_local(
        engine,
        &train_exec,
        probe_exec.as_deref(),
        payload,
        tau,
        lr,
        || stream.next_batch(),
    )?;
    Ok(TaskOutcome { client, p, tau, bytes, completion, result })
}

/// A task tagged with its round sequence number and assignment index.
struct Dispatch {
    seq: usize,
    index: usize,
    task: LocalTask,
}

/// A finished task travelling back over the completion channel.
struct Completion {
    seq: usize,
    index: usize,
    outcome: Result<TaskOutcome>,
}

/// The shared work queue: coordinator pushes, workers pop (blocking until
/// work arrives or the queue is closed).
struct TaskQueue {
    state: Mutex<QueueState>,
    ready: Condvar,
}

struct QueueState {
    tasks: VecDeque<Dispatch>,
    closed: bool,
}

impl TaskQueue {
    fn new() -> TaskQueue {
        TaskQueue {
            state: Mutex::new(QueueState { tasks: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
        }
    }

    /// Enqueue one round's tasks (assignment order) under sequence `seq`.
    fn push_round(&self, seq: usize, tasks: Vec<LocalTask>) {
        let mut st = self.state.lock().expect("task queue poisoned");
        for (index, task) in tasks.into_iter().enumerate() {
            st.tasks.push_back(Dispatch { seq, index, task });
        }
        drop(st);
        self.ready.notify_all();
    }

    /// No more work will ever arrive; blocked workers drain and exit.
    fn close(&self) {
        self.state.lock().expect("task queue poisoned").closed = true;
        self.ready.notify_all();
    }

    /// Next task, blocking while the queue is open but empty; `None` once
    /// it is closed and drained.
    fn pop(&self) -> Option<Dispatch> {
        let mut st = self.state.lock().expect("task queue poisoned");
        loop {
            if let Some(d) = st.tasks.pop_front() {
                return Some(d);
            }
            if st.closed {
                return None;
            }
            st = self.ready.wait(st).expect("task queue poisoned");
        }
    }
}

/// Worker body: pull tasks, execute on the pinned engine, report on the
/// completion channel. Exits when the queue closes or the coordinator
/// hangs up the channel.
///
/// A panicking task must still produce a completion: the coordinator
/// blocks on exactly one completion per dispatched task, and sibling
/// workers keep their channel ends alive while parked in `pop()`, so an
/// unwound worker would deadlock the whole scope (the overlapped queue
/// stays open between rounds). The panic is converted into the task's
/// error and surfaced through the ordinary earliest-failed-task path.
fn worker_loop(engine: &Engine, queue: &TaskQueue, tx: Sender<Completion>) {
    while let Some(Dispatch { seq, index, task }) = queue.pop() {
        let outcome =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| exec_task(engine, task)))
                .unwrap_or_else(|payload| {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "non-string panic payload".into());
                    Err(anyhow!("worker task panicked: {msg}"))
                });
        if tx.send(Completion { seq, index, outcome }).is_err() {
            break;
        }
    }
}

/// Closes the queue when dropped — **including on unwind**. Workers park
/// in `TaskQueue::pop` while the queue is open; if the coordinator side
/// panics without closing, `std::thread::scope` would wait forever to
/// join them, turning a crash into a silent hang. Every dispatch path
/// holds one of these for the lifetime of its worker scope.
struct CloseOnDrop<'q>(&'q TaskQueue);

impl Drop for CloseOnDrop<'_> {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// Ordered collect: slot completions by assignment index, then surface
/// the earliest failed task's error (independent of scheduling) or the
/// outcomes in assignment order.
fn into_ordered(slots: Vec<Option<Result<TaskOutcome>>>) -> Result<Vec<TaskOutcome>> {
    let mut out = Vec::with_capacity(slots.len());
    for slot in slots {
        out.push(slot.expect("completion missing for a dispatched task")?);
    }
    Ok(out)
}

/// Collect exactly `expected` completions of round `seq`, filing each by
/// its assignment index (shared by the single-round and overlapped
/// dispatch paths — their collection protocol must never diverge).
fn collect_completions(
    rx: &std::sync::mpsc::Receiver<Completion>,
    expected: usize,
    seq: usize,
) -> Result<Vec<TaskOutcome>> {
    let mut slots: Vec<Option<Result<TaskOutcome>>> = (0..expected).map(|_| None).collect();
    for _ in 0..expected {
        let c = rx.recv().map_err(|_| anyhow!("worker pool died mid-round"))?;
        assert_eq!(c.seq, seq, "completion from a round not in flight");
        slots[c.index] = Some(c.outcome);
    }
    into_ordered(slots)
}

/// Coordinator body of [`RoundDriver::run_overlapped`]: plan, dispatch
/// and collect `rounds` rounds against an already-running worker pool.
fn drive_rounds(
    queue: &TaskQueue,
    rx: &std::sync::mpsc::Receiver<Completion>,
    env: &mut FlEnv,
    strategy: &mut dyn Strategy,
    rounds: usize,
    reports: &mut Vec<RoundReport>,
) -> Result<()> {
    // phases A + B for round 0, then dispatch immediately
    strategy.plan_ahead(env)?;
    let tasks = strategy.take_tasks(env)?;
    let mut expected = tasks.len();
    if expected == 0 {
        return Err(anyhow!("cannot dispatch an empty cohort"));
    }
    queue.push_round(0, tasks);

    for h in 0..rounds {
        if h + 1 < rounds {
            // overlap: round h+1's phase A runs while round h's
            // stragglers are still on the workers
            strategy.plan_ahead(env)?;
        }
        let outcomes = collect_completions(rx, expected, h)?;
        reports.push(strategy.finish_round(env, outcomes)?);
        if h + 1 < rounds {
            // phase B for h+1 (payloads need the freshly aggregated
            // global); workers pick tasks up as they free — no join
            // barrier in between
            let tasks = strategy.take_tasks(env)?;
            expected = tasks.len();
            if expected == 0 {
                return Err(anyhow!("cannot dispatch an empty cohort"));
            }
            queue.push_round(h + 1, tasks);
        }
    }
    Ok(())
}

/// Dispatches rounds' tasks over up to `workers` threads, worker *i*
/// pinned to engine *i* of the pool.
#[derive(Debug, Clone, Copy)]
pub struct RoundDriver {
    workers: usize,
}

impl RoundDriver {
    /// `workers == 0` is treated as 1 (the serial coordinator loop).
    pub fn new(workers: usize) -> RoundDriver {
        RoundDriver { workers: workers.max(1) }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Execute one round's tasks, returning outcomes in assignment order.
    ///
    /// Errs on an empty cohort (an empty round has no reference client
    /// and would poison every downstream average). Never spawns more
    /// threads than tasks; with one worker (or one task) everything runs
    /// inline on the caller's thread against the pool's primary engine.
    pub fn run(&self, pool: &EnginePool, tasks: Vec<LocalTask>) -> Result<Vec<TaskOutcome>> {
        let n = tasks.len();
        if n == 0 {
            return Err(anyhow!("cannot dispatch an empty cohort"));
        }
        let workers = self.workers.min(n);
        if workers <= 1 {
            let engine = pool.primary();
            return tasks.into_iter().map(|t| exec_task(engine, t)).collect();
        }

        let queue = TaskQueue::new();
        let (tx, rx) = channel::<Completion>();
        std::thread::scope(|s| {
            for w in 0..workers {
                let tx = tx.clone();
                let queue = &queue;
                let engine = pool.engine(w);
                s.spawn(move || worker_loop(engine, queue, tx));
            }
            drop(tx);
            let _close = CloseOnDrop(&queue);
            queue.push_round(0, tasks);
            // close immediately: this is the whole dispatch, so workers
            // drain and exit while we collect
            queue.close();
            collect_completions(&rx, n, 0)
        })
    }

    /// Drive `rounds` consecutive rounds of `strategy` over one
    /// persistent worker pool, overlapping round *h+1*'s plan-ahead phase
    /// with round *h*'s stragglers (module docs, "Overlapped execution").
    ///
    /// Byte-identical to calling `strategy.run_round(env)` `rounds` times
    /// — the phase contract sequences every state mutation in the serial
    /// order — so this is purely a wall-clock optimization.
    pub fn run_overlapped(
        &self,
        pool: &EnginePool,
        env: &mut FlEnv,
        strategy: &mut dyn Strategy,
        rounds: usize,
    ) -> Result<Vec<RoundReport>> {
        if rounds == 0 {
            return Ok(Vec::new());
        }
        if self.workers <= 1 {
            // one worker: nothing drains in the background, so the plain
            // serial loop is both simpler and identical
            return (0..rounds).map(|_| strategy.run_round(env)).collect();
        }

        let queue = TaskQueue::new();
        let (tx, rx) = channel::<Completion>();
        let mut reports = Vec::with_capacity(rounds);
        let result = std::thread::scope(|s| {
            for w in 0..self.workers {
                let tx = tx.clone();
                let queue = &queue;
                let engine = pool.engine(w);
                s.spawn(move || worker_loop(engine, queue, tx));
            }
            drop(tx);

            // guard, not a trailing call: a panic inside a scheme phase
            // must still close the queue or the parked workers would
            // never join and the scope would hang forever
            let _close = CloseOnDrop(&queue);
            drive_rounds(&queue, &rx, env, strategy, rounds, &mut reports)
        });
        result.map(|()| reports)
    }
}

/// Shared collect phase: fold a round's outcomes into the environment's
/// traffic meter and virtual clock and assemble the `RoundReport` (the
/// bookkeeping formerly copy-pasted across Heroes, dense and Flanc).
pub fn collect_round(
    env: &mut FlEnv,
    round: usize,
    outcomes: &[TaskOutcome],
    block_variance: f64,
) -> RoundReport {
    let mut down = 0usize;
    let mut up = 0usize;
    let mut completion = Vec::with_capacity(outcomes.len());
    let mut losses = Vec::with_capacity(outcomes.len());
    for o in outcomes {
        down += o.bytes;
        up += o.bytes;
        completion.push(o.completion);
        losses.push(o.result.mean_loss);
    }
    env.traffic.record_down(down);
    env.traffic.record_up(up);
    let round_time = completion.iter().copied().fold(0.0, f64::max);
    env.clock.advance(round_time);

    RoundReport {
        round,
        round_time,
        avg_wait: average_wait(&completion),
        mean_loss: losses.iter().sum::<f64>() / losses.len().max(1) as f64,
        taus: outcomes.iter().map(|o| o.tau).collect(),
        widths: outcomes.iter().map(|o| o.p).collect(),
        down_bytes: down,
        up_bytes: up,
        completion_times: completion,
        block_variance,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_workers_clamps_to_serial() {
        assert_eq!(RoundDriver::new(0).workers(), 1);
        assert_eq!(RoundDriver::new(1).workers(), 1);
        assert_eq!(RoundDriver::new(4).workers(), 4);
    }

    #[test]
    fn task_types_are_send() {
        // the queue moves tasks/outcomes across threads
        fn assert_send<T: Send>() {}
        assert_send::<LocalTask>();
        assert_send::<TaskOutcome>();
        assert_send::<Dispatch>();
        assert_send::<Completion>();
    }

    #[test]
    fn queue_delivers_in_order_and_drains_on_close() {
        use crate::data::loader::ImageLoader;
        use crate::data::synth_image::ImageGen;
        use crate::util::rng::Rng;
        use std::sync::Arc;

        // tasks are sequencing metadata here — they are never executed
        let set = Arc::new(ImageGen::cifar_twin().generate(4, 1, &mut Rng::new(1)));
        let mk = |client: usize| LocalTask {
            client,
            p: 1,
            tau: 1,
            lr: 0.1,
            train_exec: "unused".into(),
            probe_exec: None,
            payload: Vec::new(),
            stream: BatchStream::Image(ImageLoader::new(set.clone(), vec![0, 1], 2, Rng::new(2))),
            bytes: 0,
            completion: 0.0,
        };
        let queue = TaskQueue::new();
        queue.push_round(7, vec![mk(10), mk(11), mk(12)]);
        queue.close();
        for expect in 0..3usize {
            let d = queue.pop().expect("queue must drain pushed tasks");
            assert_eq!((d.seq, d.index), (7, expect), "FIFO assignment order");
            assert_eq!(d.task.client, 10 + expect);
        }
        assert!(queue.pop().is_none(), "closed drained queue must yield None");
    }

    #[test]
    fn ordered_collect_returns_earliest_error() {
        let slots: Vec<Option<Result<TaskOutcome>>> = vec![
            Some(Err(anyhow!("first"))),
            Some(Err(anyhow!("second"))),
        ];
        let err = into_ordered(slots).unwrap_err();
        assert_eq!(err.to_string(), "first");
    }
}
