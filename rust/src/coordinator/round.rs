//! Shared parallel round pipeline — every scheme (Heroes, the dense
//! baselines, Flanc) plans a round into [`LocalTask`]s and hands them to
//! the [`RoundDriver`], which executes the simulated clients (possibly on
//! several worker threads) and performs the round bookkeeping the schemes
//! used to reimplement one by one.
//!
//! # Pipeline
//!
//! One synchronous round flows through four phases:
//!
//! 1. **plan** — the scheme samples participants and decides width / τ /
//!    payload / executable per client (Alg. 1 for Heroes, the simpler
//!    width×τ policies for the baselines), producing an ordered
//!    `Vec<LocalTask>`. Planning runs on the coordinator thread and may
//!    freely mutate scheme state (ledger, tracker).
//! 2. **dispatch** — [`RoundDriver::run`] executes each task's local
//!    training (Alg. 2, `client::run_local`) through the `Sync` PJRT
//!    [`Engine`]. With `workers == 1` tasks run inline on the caller's
//!    thread; with `workers == N` a `std::thread::scope` pool of N
//!    threads pulls task indices off a shared atomic counter.
//! 3. **collect** — each outcome lands in the slot of its task index, so
//!    `run` returns outcomes in **assignment order** no matter which
//!    worker finished first; if tasks failed, the error of the earliest
//!    failed task is returned (again independent of scheduling).
//! 4. **aggregate** — the scheme folds the ordered outcomes into its
//!    global model (block-wise, overlap-aware or grouped averaging), then
//!    [`collect_round`] converts the shared bookkeeping — traffic bytes,
//!    completion times, losses, the virtual-clock advance by the
//!    synchronous-round maximum (Eq. 19) — into the final [`RoundReport`].
//!
//! # Determinism contract
//!
//! A dispatched task touches no shared mutable state: its batch stream is
//! owned and seeded by `(seed, client, round)` ([`FlEnv::batch_stream`]),
//! its payload is owned, and PJRT CPU executions are deterministic
//! functions of their inputs. Combined with assignment-order collection,
//! a seeded run therefore produces **byte-identical `RoundReport`
//! sequences for any `--workers N`**, and `workers == 1` reproduces the
//! serial loop exactly (`rust/tests/integration_parallel.rs` pins this).

use crate::coordinator::assignment::average_wait;
use crate::coordinator::client::{run_local, LocalResult};
use crate::coordinator::env::{BatchStream, FlEnv};
use crate::coordinator::RoundReport;
use crate::runtime::Engine;
use crate::tensor::Tensor;
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// One client's planned local round, fully self-contained: a worker
/// thread needs nothing beyond the task and a `&Engine` to execute it.
///
/// Self-containment means the plan phase materializes all K payloads
/// before dispatch (peak memory K reduced payloads instead of the old
/// serial loop's one). Payloads are factorized sub-models and K is tens
/// of clients, so this is cheap; revisit (build payloads on-worker from
/// the read-only global) if cohorts grow orders of magnitude.
pub struct LocalTask {
    pub client: usize,
    /// assigned width
    pub p: usize,
    /// local update frequency τ
    pub tau: usize,
    /// effective learning rate for this round
    pub lr: f32,
    pub train_exec: String,
    /// estimation-probe executable (Heroes probing rounds only)
    pub probe_exec: Option<String>,
    /// parameter payload `[...]` in the executable's input layout
    pub payload: Vec<Tensor>,
    /// owned batch source (seeded by `(seed, client, round)`)
    pub stream: BatchStream,
    /// payload transfer size, counted once per direction (broadcast down,
    /// upload up)
    pub bytes: usize,
    /// projected completion time τ·μ + ν (Eq. 17-18)
    pub completion: f64,
}

/// A completed task: the plan metadata plus the local-training result.
pub struct TaskOutcome {
    pub client: usize,
    pub p: usize,
    pub tau: usize,
    pub bytes: usize,
    pub completion: f64,
    pub result: LocalResult,
}

fn exec_task(engine: &Engine, task: LocalTask) -> Result<TaskOutcome> {
    let LocalTask {
        client, p, tau, lr, train_exec, probe_exec, payload, mut stream, bytes, completion,
    } = task;
    let result = run_local(
        engine,
        &train_exec,
        probe_exec.as_deref(),
        payload,
        tau,
        lr,
        || stream.next_batch(),
    )?;
    Ok(TaskOutcome { client, p, tau, bytes, completion, result })
}

/// Dispatches a round's tasks over up to `workers` threads.
#[derive(Debug, Clone, Copy)]
pub struct RoundDriver {
    workers: usize,
}

impl RoundDriver {
    /// `workers == 0` is treated as 1 (the serial coordinator loop).
    pub fn new(workers: usize) -> RoundDriver {
        RoundDriver { workers: workers.max(1) }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Execute all tasks, returning outcomes in assignment order.
    ///
    /// Never spawns more threads than tasks; with one worker (or one
    /// task) everything runs inline on the caller's thread.
    pub fn run(&self, engine: &Engine, tasks: Vec<LocalTask>) -> Result<Vec<TaskOutcome>> {
        let n = tasks.len();
        let workers = self.workers.min(n.max(1));
        if workers <= 1 {
            return tasks.into_iter().map(|t| exec_task(engine, t)).collect();
        }

        // Work queue: a shared index + take-once task slots; outcomes land
        // in the slot of their task index so order is scheduling-free.
        let queue: Vec<Mutex<Option<LocalTask>>> =
            tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<Result<TaskOutcome>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();

        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let task = queue[i]
                        .lock()
                        .expect("task slot poisoned")
                        .take()
                        .expect("task dispatched twice");
                    let outcome = exec_task(engine, task);
                    *slots[i].lock().expect("outcome slot poisoned") = Some(outcome);
                });
            }
        });

        slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .expect("outcome slot poisoned")
                    .expect("worker exited without filling its slot")
            })
            .collect()
    }
}

/// Shared collect phase: fold a round's outcomes into the environment's
/// traffic meter and virtual clock and assemble the `RoundReport` (the
/// bookkeeping formerly copy-pasted across Heroes, dense and Flanc).
pub fn collect_round(
    env: &mut FlEnv,
    round: usize,
    outcomes: &[TaskOutcome],
    block_variance: f64,
) -> RoundReport {
    let mut down = 0usize;
    let mut up = 0usize;
    let mut completion = Vec::with_capacity(outcomes.len());
    let mut losses = Vec::with_capacity(outcomes.len());
    for o in outcomes {
        down += o.bytes;
        up += o.bytes;
        completion.push(o.completion);
        losses.push(o.result.mean_loss);
    }
    env.traffic.record_down(down);
    env.traffic.record_up(up);
    let round_time = completion.iter().copied().fold(0.0, f64::max);
    env.clock.advance(round_time);

    RoundReport {
        round,
        round_time,
        avg_wait: average_wait(&completion),
        mean_loss: losses.iter().sum::<f64>() / losses.len().max(1) as f64,
        taus: outcomes.iter().map(|o| o.tau).collect(),
        widths: outcomes.iter().map(|o| o.p).collect(),
        down_bytes: down,
        up_bytes: up,
        completion_times: completion,
        block_variance,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_workers_clamps_to_serial() {
        assert_eq!(RoundDriver::new(0).workers(), 1);
        assert_eq!(RoundDriver::new(1).workers(), 1);
        assert_eq!(RoundDriver::new(4).workers(), 4);
    }

    #[test]
    fn task_types_are_send() {
        // the scoped workers move tasks/outcomes across threads
        fn assert_send<T: Send>() {}
        assert_send::<LocalTask>();
        assert_send::<TaskOutcome>();
    }
}
