//! Shared parallel round pipeline — every scheme (Heroes, the dense
//! baselines, Flanc) plans a round into [`LocalTask`]s and hands them to
//! the [`RoundDriver`], which executes the simulated clients (possibly on
//! several worker threads over a per-worker [`EnginePool`]) and performs
//! the round bookkeeping the schemes used to reimplement one by one.
//!
//! # Pipeline
//!
//! A scheme's round is decomposed into the three [`Strategy`] hook phases
//! (see `baselines::Strategy`):
//!
//! * **A · plan-ahead** (`plan_ahead`) — sample participants, collect
//!   statuses and run any outcome-independent width/τ planning. Phase A
//!   is the only phase that consumes the environment's RNG, and it must
//!   not read state that phase C mutates — that contract is what lets the
//!   coordinator run it for round *h+1* while round *h* is still
//!   executing.
//! * **B · materialize** (`take_tasks`) — turn the pending plan into
//!   ordered, fully self-contained [`LocalTask`]s against the scheme's
//!   *current* global model (payloads, batch streams, executables).
//! * **C · finish** (`finish_round`) — fold the assignment-ordered
//!   [`TaskOutcome`]s into the global model and the environment's traffic
//!   meter / virtual clock (Eq. 19), emitting the [`RoundReport`].
//!
//! Between B and C the driver **dispatches**: a task queue feeds worker
//! threads, worker *i* pinned to engine *i* of the pool so executions
//! never contend on one PJRT client's intra-op lock, and a completion
//! channel carries `(task index, outcome)` pairs back to the coordinator,
//! which files them in assignment order.
//!
//! # Overlapped execution
//!
//! [`RoundDriver::run`] drives one round (B-phase output in, ordered
//! outcomes out). [`RoundDriver::run_overlapped`] drives a *sequence* of
//! rounds over one persistent worker pool: while round *h*'s stragglers
//! drain, the coordinator already runs phase A of round *h+1* (sampling,
//! statuses, outcome-independent width/τ planning), and round *h+1*'s
//! tasks hit the still-warm workers the moment phase C of round *h*
//! lands — no per-round fork/join barrier, no thread respawn. Payload
//! materialization (phase B) stays sequenced after phase C of the
//! previous round because a synchronous-FL payload is a function of the
//! aggregated global.
//!
//! # Semi-async quorum rounds
//!
//! [`RoundDriver::run_quorum`] goes one step further, in the style of
//! FedBuff-like buffered aggregation: phase C of round *h* no longer
//! waits for the whole cohort — it fires once the **first K of N**
//! cohort members (by *projected* completion time, Eq. 17-18) have
//! landed, so round *h+1*'s payloads materialize and its tasks hit the
//! workers while *h*'s stragglers are still executing. Stragglers are
//! not discarded: a round-*h* straggler whose (virtual) upload lands
//! before round *h'* aggregates is folded into *h'* with staleness
//! weight `w = 1/(1+s)^α`, `s = h' − h` ([`staleness_weight`];
//! `--staleness-alpha` configures α), via the schemes'
//! [`Strategy::finish_round_quorum`] hook and the weighted accumulators
//! in `coordinator::aggregate`.
//!
//! ```text
//!            round h                round h+1             round h+2
//!  A ───► B ───► dispatch ───────────────────────────────────────────►
//!                │ c₁ ▌▌▌▌┆                  the K fastest (by
//!                │ c₂ ▌▌▌▌▌▌┆◄─ t_q          projected completion)
//!                │ c₃ ▌▌▌▌▌▌▌▌▌▌▌▌▌▌┆        form the quorum; C(h)
//!                ▼        │                  fires at t_agg = t₀ + t_q
//!                       C(h) ─► B(h+1) ─► dispatch(h+1) ...
//!                         │                    │
//!                         │     c₃ lands ──────┴─► merged into the
//!                         │     (virtually) here   first C(h+s) with
//!                         ▼                        t_agg ≥ its finish,
//!                     late buffer ───────────────► weight 1/(1+s)^α
//! ```
//!
//! Devices are serialized on the virtual clock: a cohort member still
//! busy with an earlier round's straggling task starts its next task
//! when that one lands (`delay_busy_clients`), so a slow client's
//! re-sampled rounds queue up on its one device instead of running
//! concurrently — the quorum speedup measures real straggler hiding,
//! not impossible parallelism.
//!
//! # Adaptive quorum control (closing the Alg. 1 loop over K and α)
//!
//! With `--quorum auto` the per-round K and α are **controller outputs**
//! instead of CLI constants ([`crate::coordinator::quorum_ctl`]): before
//! each aggregation the driver feeds the round's *projected* completion
//! times (plan facts) and the scheme's observed signals
//! ([`Strategy::quorum_signals`]: staleness index, β² proxy, smoothness
//! estimate, count spread) to the policy, which returns this round's
//! `(K_h, α_h)`:
//!
//! ```text
//!     plan facts (virtual)                observed (virtual)
//!   completions τ·μ + ν ──┐       ┌── staleness_index, β², L, spread
//!                         ▼       ▼
//!              ┌─────────────────────────────┐
//!              │   QuorumController::decide  │  K ∈ [K_min, N]: smallest
//!              │  projected staleness loss   │  K whose projected loss
//!              │  vs the Eq. 23 ε-margin     │  fits the ε-margin slice;
//!              │  budget (--quorum-margin)   │  α annealed vs observed
//!              └─────────────┬───────────────┘  per-block losses
//!                            │ (K_h, α_h)
//!     quorum_members(·, K_h) ▼
//!   C(h) fires at t_q(K_h); late merges of this round weigh 1/(1+s)^α_h;
//!   the resulting staleness lands in the ledger → next round's signals
//! ```
//!
//! **Adaptive determinism contract.** Every controller input is
//! virtual-clock state: projected completions are plan facts, the
//! staleness/β²/spread signals are deterministic ledger state, and the
//! annealed α is a pure function of that history. No wall-clock, worker
//! or pool state ever reaches a decision, so `--quorum auto` runs are
//! **seed-deterministic for any `--workers`/`--pool`**, exactly like the
//! static mode. A cohort with no straggler tail (projected-completion
//! spread under the controller's threshold) provably decides `K = N`
//! every round, which routes through the synchronous phase-C hook — a
//! homogeneous-cohort `--quorum auto` run is **byte-identical to the
//! full-barrier run** (both pinned in `tests/integration_parallel.rs`).
//!
//! **Quorum determinism contract.** Quorum membership and the merge
//! round of every straggler are decided by the *virtual* clock — the
//! projected completion times the plan already carries — never by which
//! worker thread happens to finish first. Completions that race ahead
//! of their consumption point park in a pending-completion buffer keyed
//! by `(round, task)`; the coordinator blocks for exactly the outcomes
//! the virtual schedule says round *h* aggregates. Hence, for a fixed
//! seed, `--quorum K < N` is **deterministic for any worker count and
//! pool size**, and `--quorum N` (full cohort, no stragglers, unit
//! weights) routes through the plain phase-C hook and reproduces the
//! serial loop **byte-identically**. Stragglers still outstanding when
//! the run ends are drained and their *updates* dropped (their merge
//! round never happens; their upload traffic is not billed) — but a
//! straggler that *failed* still fails the run, exactly like the
//! synchronous paths.
//!
//! # Scenario churn & mid-round dropouts
//!
//! Under a churn scenario (`--scenario`, `simulation::scenario`) a
//! dispatched client may **vanish mid-round**: the coordinator stamps the
//! scenario's dropout draws onto the round's tasks at dispatch
//! ([`FlEnv::stamp_dropouts`] — decided on the virtual clock, never by
//! worker racing), and a stamped task travels the completion channel as
//! [`TaskFate::Dropped`] instead of [`TaskFate::Done`]: its broadcast is
//! billed (the payload went out), its PJRT work is skipped (nobody can
//! receive the result), its upload never arrives.
//!
//! * **Quorum path** — a dropped client is a *never-arriving straggler*:
//!   excluded from quorum membership ([`quorum_members_surviving`]),
//!   never admitted to the pending-straggler buffer, never merged; its
//!   broadcast bytes bill with the round's stragglers and its client id
//!   rides [`QuorumBatch::dropped`] so schemes can retire plan state. A
//!   round whose every member dropped is a typed
//!   [`ScenarioError::EmptySurvivors`]; churn that leaves fewer survivors
//!   than a static `--quorum K` demands is a typed
//!   [`ScenarioError::QuorumInfeasible`] — never a silent degrade. The
//!   observed dropout rate feeds the adaptive controller as a
//!   [`QuorumSignals::dropout_rate`] signal (lost updates consume the
//!   staleness budget like realized losses, growing K).
//! * **Full-barrier paths** (serial and overlapped) — governed by
//!   `--dropout-policy` ([`finish_dispatched_round`]): `survivors`
//!   re-plans phase C over the surviving outcomes (through the quorum
//!   phase-C hook, which already aggregates cohort subsets; the barrier
//!   waits for survivors only — a vanished client is detected, not
//!   awaited), `error` fails the run with a typed
//!   [`ScenarioError::MidRoundDropout`]. An all-dropped round errs under
//!   either policy.
//!
//! Dropout decisions, like everything else here, are pure functions of
//! `(scenario, seed, round, client)` — churn runs stay byte-identical
//! for any `--workers`/`--pool`, and `--scenario stable` stamps nothing:
//! every fate is `Done` and the pipeline reproduces the pre-scenario
//! paths byte for byte.
//!
//! [`QuorumSignals::dropout_rate`]: crate::coordinator::quorum_ctl::QuorumSignals
//! [`ScenarioError::EmptySurvivors`]: crate::simulation::ScenarioError
//! [`ScenarioError::QuorumInfeasible`]: crate::simulation::ScenarioError
//! [`ScenarioError::MidRoundDropout`]: crate::simulation::ScenarioError
//! [`FlEnv::stamp_dropouts`]: crate::coordinator::env::FlEnv::stamp_dropouts
//!
//! # Engine-level fault injection
//!
//! On top of scheduled churn, `--faults` injects **engine-level**
//! failures (`simulation::faults`: `exec` execute errors, `corrupt`
//! bit-flipped upload frames, `partition` delivery stalls) and
//! `--fault-policy` (`coordinator::resilience`) decides per class
//! whether the run retries, re-plans or fails. Like dropouts, **faults
//! are seeded schedule facts**: [`FlEnv::stamp_faults`] draws and
//! *resolves* each fault at dispatch — retry delays and backoffs land
//! on the task's virtual completion, abandoned tasks carry an
//! unrecovered [`FaultStamp`] and travel the channel as
//! [`TaskFate::Faulted`] (PJRT work skipped, like a dropout), and the
//! `fail` action aborts at the stamp with a typed
//! [`ResilienceError::FaultAbort`] — so no worker timing ever enters a
//! fault decision and faulted runs stay byte-identical across
//! `--workers`/`--pool`/`--overlap`.
//!
//! * **Quorum path** — an unrecovered fault marks its task in
//!   [`RoundMeta`] exactly like a dropout: excluded from membership,
//!   never merged, retired via [`QuorumBatch::dropped`]; its fate is a
//!   scheduled fact the drain ignores. The observed fault rate feeds
//!   the adaptive controller as [`QuorumSignals::fault_rate`], growing
//!   K under fault pressure the same way churn does.
//! * **Full-barrier paths** — [`finish_dispatched_round`] re-plans
//!   phase C over the survivor set: faulted tasks always take the
//!   survivors route (their policy already spoke at stamp time;
//!   `--dropout-policy error` governs scenario dropouts only).
//! * **Recovered faults** complete as plain [`TaskFate::Done`] — their
//!   cost is the stamped completion delay. A recovered `corrupt` fault
//!   in a wire mode additionally flips the drawn bit in the encoded
//!   `HWU1` frame ([`crate::codec::corrupt_frame`]), *verifies* the
//!   decode surfaces a typed `CodecError`, and recovers by decoding the
//!   clean frame (the retransmission the retry paid for).
//!
//! The per-class injected/observed/retried/recovered/abandoned counts
//! fold into the env's [`ResilienceLedger`], which the runner attaches
//! to the recorder output. `--faults off` (the default) stamps nothing,
//! consumes no RNG and leaves every path byte-identical.
//!
//! [`QuorumSignals::fault_rate`]: crate::coordinator::quorum_ctl::QuorumSignals
//! [`FaultStamp`]: crate::coordinator::resilience::FaultStamp
//! [`ResilienceError::FaultAbort`]: crate::coordinator::resilience::ResilienceError
//! [`ResilienceLedger`]: crate::coordinator::resilience::ResilienceLedger
//! [`FlEnv::stamp_faults`]: crate::coordinator::env::FlEnv::stamp_faults
//!
//! # Hierarchical aggregation
//!
//! With `--hierarchy E` (≥ 2; quorum mode only) the quorum decision runs
//! **twice**, once per tier ([`crate::coordinator::hierarchy`]): the
//! round's survivors are split round-robin across E edge aggregators,
//! each edge runs a *clone* of the quorum policy over its sub-cohort and
//! forwards **one composed update** (its largest member payload) upward
//! over a backhaul link, and the real policy then decides a root quorum
//! over the E edge arrivals. The round aggregates the union of the
//! root-quorum edges' member sets at `t_q` = the slowest root-quorum
//! edge's *arrival* (backhaul included — [`QuorumBatch::round_time`]),
//! bills the WAN exactly Σ forwarded-update bytes
//! ([`QuorumBatch::wan_up_bytes`]) instead of per-member uploads, and
//! treats everyone else as a straggler: a late *edge* lands as a unit at
//! its arrival instant, an edge-local straggler is forwarded
//! individually at completion + backhaul. The plan is a pure function of
//! `(completions, bytes, cfg, policy state)` — no RNG, no wall clock —
//! so hierarchical runs inherit the full `--workers`/`--pool`
//! determinism contract, and `--hierarchy 1` (the default) leaves every
//! flat path byte-identical.
//!
//! # Determinism contract
//!
//! A dispatched task touches no shared mutable state: its batch stream is
//! owned and seeded by `(seed, client, round)` ([`FlEnv::batch_stream`]),
//! its payload is owned, and PJRT CPU executions are deterministic
//! functions of their inputs — on *every* engine of the pool, since all
//! engines compile the same HLO through the same pipeline. Combined with
//! assignment-order collection and the phase contract above (A commutes
//! with C, B and C are sequenced), a seeded run produces **byte-identical
//! `RoundReport` sequences for any `--workers N`, any pool size, and for
//! overlapped vs. non-overlapped dispatch**
//! (`rust/tests/integration_parallel.rs` pins all three axes, plus the
//! quorum contract above).
//!
//! The wire codec preserves this contract: under `--codec wire*` a
//! worker frames each trained update (`crate::codec`), and the encoded
//! bytes are a pure function of `(plan, update, cfg)` — no RNG, clock
//! or thread state — while the frame *length* is a pure function of the
//! payload shapes and the encoding alone, which is how the plan can
//! bill ν and `up_bytes` before training and the worker can verify the
//! realized frame against them ([`CodecError::PlannedSizeDrift`]).
//! `--codec analytic` (the default) never constructs a frame and leaves
//! every path byte-identical to the pre-codec repo.

use crate::baselines::Strategy;
use crate::codec::{self, CodecError, Encoding, FrameMeta};
use crate::config::DropoutPolicy;
use crate::coordinator::assignment::average_wait;
use crate::coordinator::client::{run_local, LocalResult};
use crate::coordinator::env::{BatchStream, FlEnv};
use crate::coordinator::hierarchy::{plan_hierarchy, HierarchyCfg};
use crate::coordinator::quorum_ctl::QuorumPolicy;
use crate::coordinator::resilience::FaultStamp;
use crate::coordinator::RoundReport;
use crate::runtime::{Engine, EnginePanic, EnginePool};
use crate::simulation::{FaultClass, ScenarioError};
use crate::tensor::Tensor;
use crate::transport::{SimTransport, Transport};
use anyhow::{anyhow, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Condvar, Mutex};

/// One client's planned local round, fully self-contained: a worker
/// thread needs nothing beyond the task and a `&Engine` to execute it.
///
/// Self-containment means the plan phase materializes all K payloads
/// before dispatch (peak memory K reduced payloads instead of the old
/// serial loop's one). Payloads are factorized sub-models and K is tens
/// of clients, so this is cheap; revisit (build payloads on-worker from
/// the read-only global) if cohorts grow orders of magnitude.
pub struct LocalTask {
    pub client: usize,
    /// assigned width
    pub p: usize,
    /// local update frequency τ
    pub tau: usize,
    /// effective learning rate for this round
    pub lr: f32,
    pub train_exec: String,
    /// estimation-probe executable (Heroes probing rounds only)
    pub probe_exec: Option<String>,
    /// parameter payload `[...]` in the executable's input layout
    pub payload: Vec<Tensor>,
    /// owned batch source (seeded by `(seed, client, round)`)
    pub stream: BatchStream,
    /// broadcast (downlink) transfer size — the analytic payload float
    /// count in every codec mode (the server sends the model out as-is;
    /// only the *update upload* is wire-framed). Billed bytes are u64
    /// end to end (hlint rule C1).
    pub bytes: u64,
    /// upload (uplink) transfer size: equal to `bytes` under
    /// `--codec analytic`, the measured `HWU1` frame length
    /// ([`crate::codec::upload_bytes`]) under the wire modes — the same
    /// number the planner priced ν from
    pub up_bytes: u64,
    /// extra upload bytes billed for fault-recovery retransmissions:
    /// a recovered `corrupt` fault re-sends the frame once per retry, and
    /// each retransmission is real uplink traffic (PR 8 follow-up).
    /// Stamped by [`FlEnv::stamp_faults`] (`retries × up_bytes` for a
    /// recovered corrupt stamp, 0 otherwise) — schemes always construct
    /// tasks with 0. Kept separate from [`LocalTask::up_bytes`] so the
    /// planned-frame-length check ([`CodecError::PlannedSizeDrift`])
    /// still compares single-frame sizes.
    pub rebill_bytes: u64,
    /// wire-mode frame identity; `None` under `--codec analytic`, where
    /// the update never touches the codec and the run stays
    /// byte-identical to the pre-codec repo
    pub wire: Option<WireTask>,
    /// projected completion time τ·μ + ν (Eq. 17-18)
    pub completion: f64,
    /// scenario mid-round dropout: the virtual instant (relative to the
    /// round start) at which this client vanishes. Stamped by
    /// [`FlEnv::stamp_dropouts`] at dispatch — schemes always construct
    /// tasks with `None`. A stamped task is executed as a no-op and
    /// completes as [`TaskFate::Dropped`].
    ///
    /// [`FlEnv::stamp_dropouts`]: crate::coordinator::env::FlEnv::stamp_dropouts
    pub drop_at: Option<f64>,
    /// injected engine-level fault, resolved under the fault policy at
    /// dispatch (module docs, "Engine-level fault injection"). Stamped
    /// by [`FlEnv::stamp_faults`] — schemes always construct tasks with
    /// `None`. A recovered stamp already adjusted `completion`; an
    /// unrecovered one makes the task complete as [`TaskFate::Faulted`]
    /// with its PJRT work skipped.
    ///
    /// [`FlEnv::stamp_faults`]: crate::coordinator::env::FlEnv::stamp_faults
    pub fault: Option<FaultStamp>,
}

/// Wire-mode metadata a task carries to its encode point: the frame
/// header identity plus the encoding. Stamped by the scheme's
/// `take_tasks` whenever the codec is a wire mode; the worker encodes
/// the trained update into an `HWU1` frame, verifies the frame length
/// against the planned [`LocalTask::up_bytes`], and decodes it back —
/// so quantization/sparsification error honestly enters aggregation.
#[derive(Debug, Clone, Copy)]
pub struct WireTask {
    /// `codec::scheme_id::*` of the producing scheme
    pub scheme: u8,
    pub round: u32,
    pub enc: Encoding,
}

/// A completed task: the plan metadata plus the local-training result.
#[derive(Debug)]
pub struct TaskOutcome {
    pub client: usize,
    pub p: usize,
    pub tau: usize,
    /// broadcast (downlink) bytes — see [`LocalTask::bytes`]
    pub bytes: u64,
    /// upload (uplink) bytes actually billed: the planned frame
    /// ([`LocalTask::up_bytes`]) plus any fault-recovery retransmissions
    /// ([`LocalTask::rebill_bytes`])
    pub up_bytes: u64,
    pub completion: f64,
    pub result: LocalResult,
}

/// A dispatched client that vanished mid-round (module docs, "Scenario
/// churn"): broadcast billed, PJRT work skipped, upload never arrives.
#[derive(Debug)]
pub struct DroppedTask {
    pub client: usize,
    /// broadcast bytes (billed down at aggregation, never up)
    pub bytes: u64,
    /// virtual instant of the vanish, relative to the round start
    pub drop_time: f64,
}

/// A dispatched client lost to an unrecovered engine-level fault
/// (module docs, "Engine-level fault injection"): broadcast billed,
/// PJRT work skipped, upload never arrives — the fault analogue of
/// [`DroppedTask`], with the class/retry provenance attached.
#[derive(Debug)]
pub struct FaultedTask {
    pub client: usize,
    /// broadcast bytes (billed down at aggregation, never up)
    pub bytes: u64,
    pub class: FaultClass,
    /// retry attempts paid before the coordinator gave up
    pub retries: u32,
    /// virtual instant the task was declared lost, relative to the
    /// round start
    pub fault_time: f64,
}

/// What became of a dispatched task — the completion channel's payload.
#[derive(Debug)]
pub enum TaskFate {
    /// the client trained and (virtually) uploaded
    Done(TaskOutcome),
    /// the client vanished mid-round; its update never merges
    Dropped(DroppedTask),
    /// an unrecovered engine-level fault; its update never merges
    Faulted(FaultedTask),
}

/// The fate a stamp already decided at dispatch time, if any: a
/// `drop_at` stamp completes as [`TaskFate::Dropped`], an unrecovered
/// fault stamp as [`TaskFate::Faulted`] — both without touching an
/// engine. The single source of truth shared by [`exec_task`] (the
/// in-process path) and the networked transport, which resolves stamped
/// fates coordinator-side so stamps never travel the wire.
pub(crate) fn stamped_fate(task: &LocalTask) -> Option<TaskFate> {
    if let Some(drop_time) = task.drop_at {
        // the client vanished: its broadcast is already out, its result
        // could never be uploaded — skip the PJRT work entirely
        return Some(TaskFate::Dropped(DroppedTask {
            client: task.client,
            bytes: task.bytes,
            drop_time,
        }));
    }
    if let Some(stamp) = task.fault {
        if !stamp.recovered {
            // the fault policy gave this task up at stamp time (retry
            // budget exhausted, or `replan`): like a dropout, nobody
            // can receive the result — skip the PJRT work
            return Some(TaskFate::Faulted(FaultedTask {
                client: task.client,
                bytes: task.bytes,
                class: stamp.event.class,
                retries: stamp.retries,
                fault_time: stamp.fault_time,
            }));
        }
    }
    None
}

pub(crate) fn exec_task(engine: &Engine, task: LocalTask) -> Result<TaskFate> {
    if let Some(fate) = stamped_fate(&task) {
        return Ok(fate);
    }
    let LocalTask {
        client, p, tau, lr, train_exec, probe_exec, payload, mut stream, bytes, up_bytes,
        rebill_bytes, wire, completion, drop_at: _, fault,
    } = task;
    let mut result = run_local(
        engine,
        &train_exec,
        probe_exec.as_deref(),
        payload,
        tau,
        lr,
        || stream.next_batch(),
    )?;
    if let Some(w) = wire {
        // the client's (virtual) upload actually travels the wire: frame
        // the update, verify the realized length against what the plan
        // billed, and aggregate from the *decoded* tensors so q8/top-k
        // error honestly reaches the accumulators
        let meta = FrameMeta { scheme: w.scheme, round: w.round, client: client as u64 };
        let mut buf = Vec::with_capacity(crate::util::cast::bytes_to_usize(up_bytes));
        let n = codec::encode_update(&mut buf, &meta, w.enc, &result.params)?;
        if n as u64 != up_bytes {
            return Err(CodecError::PlannedSizeDrift { planned: up_bytes, actual: n as u64 }.into());
        }
        if let Some(stamp) = fault {
            if stamp.recovered && stamp.event.class == FaultClass::Corrupt {
                // the recovered corrupt fault's first transmission: flip
                // the drawn bit and verify the reader rejects the frame
                // with a typed CodecError — then recover by decoding the
                // clean frame (the retransmission the retry paid for)
                let mut poisoned = buf.clone();
                codec::corrupt_frame(&mut poisoned, stamp.event.bit);
                if codec::decode_update(&poisoned).is_ok() {
                    return Err(anyhow!(
                        "client {client}: corrupted frame (bit {}) decoded cleanly — \
                         the corrupt-fault injection must surface a typed CodecError",
                        stamp.event.bit
                    ));
                }
            }
        }
        result.params = codec::decode_update(&buf)?.tensors;
    }
    // billed upload = the planned frame plus any fault-recovery
    // retransmissions stamped onto the task (PR 8 follow-up)
    let up_bytes = up_bytes + rebill_bytes;
    Ok(TaskFate::Done(TaskOutcome { client, p, tau, bytes, up_bytes, completion, result }))
}

/// Partition ordered fates into (survivors, dropped, faulted), each in
/// assignment order.
pub fn split_fates(fates: Vec<TaskFate>) -> (Vec<TaskOutcome>, Vec<DroppedTask>, Vec<FaultedTask>) {
    let mut done = Vec::with_capacity(fates.len());
    let mut dropped = Vec::new();
    let mut faulted = Vec::new();
    for fate in fates {
        match fate {
            TaskFate::Done(o) => done.push(o),
            TaskFate::Dropped(d) => dropped.push(d),
            TaskFate::Faulted(f) => faulted.push(f),
        }
    }
    (done, dropped, faulted)
}

/// A task tagged with its round sequence number and assignment index.
struct Dispatch {
    seq: usize,
    index: usize,
    task: LocalTask,
}

/// A finished task travelling back to the coordinator — the unit every
/// [`Transport`] backend delivers, whatever the medium (the in-process
/// completion channel, or a socket). `seq`/`index` echo the dispatch
/// coordinates; `outcome` carries the fate or the task's typed error
/// (which fails the run through the earliest-failed-task path).
pub struct Completion {
    pub seq: usize,
    pub index: usize,
    pub outcome: Result<TaskFate>,
}

/// The shared work queue: coordinator pushes, workers pop (blocking until
/// work arrives or the queue is closed).
pub(crate) struct TaskQueue {
    state: Mutex<QueueState>,
    ready: Condvar,
}

struct QueueState {
    tasks: VecDeque<Dispatch>,
    closed: bool,
}

impl TaskQueue {
    fn new() -> TaskQueue {
        TaskQueue {
            state: Mutex::new(QueueState { tasks: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
        }
    }

    /// Enqueue one round's tasks (assignment order) under sequence `seq`.
    ///
    /// Lock poisoning is recovered, not propagated: a worker panicking
    /// with the queue lock held leaves `QueueState` (a plain deque +
    /// flag) fully valid, and the panic itself already travels the
    /// completion channel as a typed [`EnginePanic`].
    pub(crate) fn push_round(&self, seq: usize, tasks: Vec<LocalTask>) {
        let mut st = self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        for (index, task) in tasks.into_iter().enumerate() {
            st.tasks.push_back(Dispatch { seq, index, task });
        }
        drop(st);
        self.ready.notify_all();
    }

    /// No more work will ever arrive; blocked workers drain and exit.
    fn close(&self) {
        self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner).closed = true;
        self.ready.notify_all();
    }

    /// Next task, blocking while the queue is open but empty; `None` once
    /// it is closed and drained.
    fn pop(&self) -> Option<Dispatch> {
        let mut st = self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        loop {
            if let Some(d) = st.tasks.pop_front() {
                return Some(d);
            }
            if st.closed {
                return None;
            }
            st = self.ready.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }
}

/// Worker body: pull tasks, execute on the pinned engine, report on the
/// completion channel. Exits when the queue closes or the coordinator
/// hangs up the channel.
///
/// A panicking task must still produce a completion: the coordinator
/// blocks on exactly one completion per dispatched task, and sibling
/// workers keep their channel ends alive while parked in `pop()`, so an
/// unwound worker would deadlock the whole scope (the overlapped queue
/// stays open between rounds). The panic is converted into a typed
/// [`EnginePanic`] carrying the worker's pool index and surfaced through
/// the ordinary earliest-failed-task path.
fn worker_loop(worker: usize, engine: &Engine, queue: &TaskQueue, tx: Sender<Completion>) {
    while let Some(Dispatch { seq, index, task }) = queue.pop() {
        let outcome =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| exec_task(engine, task)))
                .unwrap_or_else(|payload| Err(EnginePanic::from_payload(worker, payload).into()));
        if tx.send(Completion { seq, index, outcome }).is_err() {
            break;
        }
    }
}

/// Closes the queue when dropped — **including on unwind**. Workers park
/// in `TaskQueue::pop` while the queue is open; if the coordinator side
/// panics without closing, `std::thread::scope` would wait forever to
/// join them, turning a crash into a silent hang. Every dispatch path
/// holds one of these for the lifetime of its worker scope.
struct CloseOnDrop<'q>(&'q TaskQueue);

impl Drop for CloseOnDrop<'_> {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// Ordered collect: slot completions by assignment index, then surface
/// the earliest failed task's error (independent of scheduling) or the
/// fates in assignment order.
fn into_ordered(slots: Vec<Option<Result<TaskFate>>>) -> Result<Vec<TaskFate>> {
    let mut out = Vec::with_capacity(slots.len());
    for (index, slot) in slots.into_iter().enumerate() {
        out.push(slot.ok_or_else(|| anyhow!("completion missing for dispatched task {index}"))??);
    }
    Ok(out)
}

/// Collect exactly `expected` completions of round `seq`, filing each by
/// its assignment index (shared by the single-round and overlapped
/// dispatch paths — their collection protocol must never diverge).
///
/// A stray completion — wrong round, out-of-range index, or a duplicate
/// of an already-filed slot — is a proper `Err`, not a coordinator
/// abort: on these synchronous paths at most one round is ever in
/// flight, so anything else on the channel means the queue protocol was
/// violated and the run must fail cleanly (workers are drained by the
/// caller's `CloseOnDrop`). The quorum path instead *routes* cross-round
/// completions into its pending buffer (see `QuorumState`).
fn collect_completions(
    tp: &mut dyn Transport,
    expected: usize,
    seq: usize,
) -> Result<Vec<TaskFate>> {
    let mut slots: Vec<Option<Result<TaskFate>>> = (0..expected).map(|_| None).collect();
    for _ in 0..expected {
        let c = tp.recv().map_err(|_| anyhow!("worker pool died mid-round"))?;
        if c.seq != seq {
            return Err(anyhow!(
                "stray completion from round {} while round {seq} is in flight",
                c.seq
            ));
        }
        let Some(slot) = slots.get_mut(c.index) else {
            return Err(anyhow!(
                "completion index {} out of range for a {expected}-task round",
                c.index
            ));
        };
        if slot.is_some() {
            return Err(anyhow!("duplicate completion for round {seq} task {}", c.index));
        }
        *slot = Some(c.outcome);
    }
    into_ordered(slots)
}

/// Shared full-barrier phase C under scenario churn and fault injection
/// (module docs, "Scenario churn" / "Engine-level fault injection"): no
/// losses take the plain synchronous hook (byte-identical to the
/// pre-scenario path); with losses, the aggregation re-plans over the
/// survivors through the quorum phase-C hook (which already handles
/// cohort subsets), billing the lost clients' broadcasts and handing
/// their ids to the scheme for plan retirement.
///
/// `--dropout-policy error` governs **scenario dropouts only**: it fails
/// the run with a typed [`ScenarioError::MidRoundDropout`] carrying the
/// full dropped-client list. Faulted tasks always take the survivors
/// route — their per-class policy already spoke at stamp time (a `fail`
/// action aborted there; an unrecovered retry/re-plan is a planned
/// loss). Generic over `?Sized` so both `Strategy::run_round` (on
/// `Self`) and the overlapped coordinator (on `dyn Strategy`) share one
/// definition.
pub fn finish_dispatched_round<S: Strategy + ?Sized>(
    env: &mut FlEnv,
    strategy: &mut S,
    round: usize,
    survivors: Vec<TaskOutcome>,
    dropped: Vec<DroppedTask>,
    faulted: Vec<FaultedTask>,
) -> Result<RoundReport> {
    if dropped.is_empty() && faulted.is_empty() {
        return strategy.finish_round(env, survivors);
    }
    for d in &dropped {
        log::debug!(
            "round {round}: client {} dropped {:.1}s into the round (virtual)",
            d.client,
            d.drop_time
        );
    }
    for f in &faulted {
        log::debug!(
            "round {round}: client {} lost to an unrecovered {} fault {:.1}s into the \
             round (virtual, {} retries)",
            f.client,
            f.class.name(),
            f.fault_time,
            f.retries
        );
    }
    if !dropped.is_empty() && env.cfg.dropout_policy == DropoutPolicy::Error {
        return Err(ScenarioError::MidRoundDropout {
            round,
            dropped: dropped.iter().map(|d| d.client).collect(),
        }
        .into());
    }
    if survivors.is_empty() {
        return Err(ScenarioError::EmptySurvivors { round }.into());
    }
    let straggler_down_bytes =
        dropped.iter().map(|d| d.bytes).sum::<u64>() + faulted.iter().map(|f| f.bytes).sum::<u64>();
    let mut lost: Vec<usize> = dropped.iter().map(|d| d.client).collect();
    lost.extend(faulted.iter().map(|f| f.client));
    strategy.finish_round_quorum(
        env,
        QuorumBatch {
            round,
            quorum: survivors,
            late: Vec::new(),
            straggler_down_bytes,
            dropped: lost,
            wan_up_bytes: None,
            round_time: None,
        },
    )
}

/// Coordinator body of [`RoundDriver::run_overlapped`] (and of
/// [`RoundDriver::run_overlapped_on`] for a caller-supplied backend):
/// plan, dispatch and collect `rounds` rounds against an
/// already-running [`Transport`].
fn drive_rounds(
    tp: &mut dyn Transport,
    env: &mut FlEnv,
    strategy: &mut dyn Strategy,
    rounds: usize,
    reports: &mut Vec<RoundReport>,
) -> Result<()> {
    // phases A + B for round 0, then dispatch immediately
    strategy.plan_ahead(env)?;
    let mut tasks = strategy.take_tasks(env)?;
    let mut expected = tasks.len();
    if expected == 0 {
        return Err(anyhow!("cannot dispatch an empty cohort"));
    }
    // the dispatch-round id (scenario cursor) the dropout policy reports;
    // distinct from the chunk-local sequence number `h`
    let mut round_id = env.stamp_dropouts(&mut tasks);
    env.stamp_faults(&mut tasks, round_id)?;
    validate_completions(&tasks)?;
    tp.dispatch(0, tasks)?;

    for h in 0..rounds {
        if h + 1 < rounds {
            // overlap: round h+1's phase A runs while round h's
            // stragglers are still on the workers
            strategy.plan_ahead(env)?;
        }
        let fates = collect_completions(tp, expected, h)?;
        let (survivors, dropped, faulted) = split_fates(fates);
        reports.push(finish_dispatched_round(
            env, strategy, round_id, survivors, dropped, faulted,
        )?);
        if h + 1 < rounds {
            // phase B for h+1 (payloads need the freshly aggregated
            // global); workers pick tasks up as they free — no join
            // barrier in between
            let mut tasks = strategy.take_tasks(env)?;
            expected = tasks.len();
            if expected == 0 {
                return Err(anyhow!("cannot dispatch an empty cohort"));
            }
            round_id = env.stamp_dropouts(&mut tasks);
            env.stamp_faults(&mut tasks, round_id)?;
            validate_completions(&tasks)?;
            tp.dispatch(h + 1, tasks)?;
        }
    }
    Ok(())
}

/// Staleness weight of a late merge: `w = (1/(1+s))^α` for a round-`h`
/// update folded at round `h+s` (FedBuff-style polynomial discounting).
/// Positive and monotone non-increasing in `s` for any `α ≥ 0`;
/// `w(0) = 1` and `α = 0` disables discounting entirely. Floored at
/// `f32::MIN_POSITIVE`: an extreme α (or staleness) must degrade the
/// merge to "negligible", never to a zero weight the accumulators would
/// reject as invalid.
pub fn staleness_weight(staleness: usize, alpha: f64) -> f32 {
    ((1.0 / (1.0 + staleness as f64)).powf(alpha) as f32).max(f32::MIN_POSITIVE)
}

/// Static semi-async knobs (`--quorum K`, `--staleness-alpha`) — the
/// payload of `QuorumPolicy::Static`. `--quorum auto` replaces them with
/// the per-round `quorum_ctl::QuorumController` decisions.
#[derive(Debug, Clone, Copy)]
pub struct QuorumCfg {
    /// aggregate once this many cohort members have (virtually) landed;
    /// 0 or ≥ cohort size ⇒ full barrier
    pub quorum: usize,
    /// α in the staleness weight `1/(1+s)^α`
    pub alpha: f64,
}

/// A straggler's update folded into a later round.
pub struct LateArrival {
    /// the round whose plan produced this task
    pub origin_round: usize,
    /// rounds elapsed between origin and merge
    pub staleness: usize,
    /// `staleness_weight(staleness, α)`
    pub weight: f32,
    pub outcome: TaskOutcome,
}

/// One quorum round's phase-C input: the quorum members' outcomes
/// (assignment order) plus the late arrivals due at this aggregation
/// point ((origin round, assignment index) order).
pub struct QuorumBatch {
    pub round: usize,
    pub quorum: Vec<TaskOutcome>,
    pub late: Vec<LateArrival>,
    /// broadcast bytes of this round's non-quorum cohort members —
    /// surviving stragglers *and* dropped clients (their payloads went
    /// out at dispatch; a survivor's upload is billed at merge, a
    /// dropped client's never)
    pub straggler_down_bytes: u64,
    /// clients of this round that vanished mid-round (assignment order):
    /// their updates never merge — schemes retaining per-round plan
    /// state must retire them here or leak it
    pub dropped: Vec<usize>,
    /// hierarchical rounds only (`--hierarchy`): the WAN uplink actually
    /// billed at this aggregation — Σ composed-update bytes over the
    /// root-quorum edges, which replaces the flat path's per-member sum
    /// (each edge forwards ONE composed update). `None` on every flat
    /// path, which bills member uploads individually as before.
    pub wan_up_bytes: Option<u64>,
    /// hierarchical rounds only: the root aggregation instant relative
    /// to the round start — the slowest root-quorum edge's *arrival*,
    /// backhaul included. `None` ⇒ the quorum members' max completion
    /// (the flat rule).
    pub round_time: Option<f64>,
}

/// Per-round observer for [`RoundDriver::run_quorum`]: called after every
/// aggregation with the freshly-emitted report; return `Ok(false)` to
/// stop the run early (the experiment runner uses this for evaluation
/// cadence and early-stop budgets — quorum runs cannot be chunked from
/// outside without dropping cross-chunk stragglers).
pub type RoundObserver<'a> =
    &'a mut dyn FnMut(&FlEnv, &dyn Strategy, &RoundReport) -> Result<bool>;

/// A dispatched-but-unmerged straggler, waiting for the aggregation
/// point its virtual upload time lands in.
struct PendingStraggler {
    seq: usize,
    index: usize,
    client: usize,
    /// virtual absolute time at which its upload lands
    abs_finish: f64,
}

/// Plan facts about one dispatched round the quorum scheduler needs
/// after the tasks themselves have moved to the workers.
struct RoundMeta {
    /// virtual absolute dispatch time (round start)
    t_start: f64,
    /// per assignment index: projected completion time (τ·μ + ν, plus
    /// any busy-device delay — see `delay_busy_clients`)
    completions: Vec<f64>,
    /// per assignment index: broadcast (downlink) transfer size
    bytes: Vec<u64>,
    /// per assignment index: upload (uplink) transfer size — analytic or
    /// measured wire-frame length, whatever the plan billed ν from
    up_bytes: Vec<u64>,
    /// per assignment index: the simulated client
    clients: Vec<usize>,
    /// per assignment index: stamped as a scenario mid-round dropout OR
    /// an unrecovered engine-level fault — either way the upload never
    /// arrives (never a quorum member, never a pending straggler)
    dropped: Vec<bool>,
}

impl RoundMeta {
    fn capture(tasks: &[LocalTask], t_start: f64) -> RoundMeta {
        RoundMeta {
            t_start,
            completions: tasks.iter().map(|t| t.completion).collect(),
            bytes: tasks.iter().map(|t| t.bytes).collect(),
            up_bytes: tasks.iter().map(|t| t.up_bytes).collect(),
            clients: tasks.iter().map(|t| t.client).collect(),
            dropped: tasks
                .iter()
                .map(|t| t.drop_at.is_some() || t.fault.map_or(false, |s| !s.recovered))
                .collect(),
        }
    }
}

/// A simulated device trains one task at a time: a cohort member still
/// (virtually) busy with an earlier round's straggling task starts its
/// new task when that one lands, not at the round start — without this
/// serialization a perpetual straggler re-sampled every round would
/// train several rounds *concurrently* on one device, overstating the
/// quorum speedup. No-op for clients with nothing pending, so
/// full-quorum runs are untouched.
///
/// One `busy_until` map is built up front (max `abs_finish` per pending
/// client), so the cost is O(tasks + pending) instead of the old
/// per-task rescan's O(tasks × pending) — same results bit for bit
/// (reference-equivalence pinned in the tests below).
fn delay_busy_clients(tasks: &mut [LocalTask], pending: &[PendingStraggler], t_start: f64) {
    if pending.is_empty() {
        return;
    }
    let mut busy_until: HashMap<usize, f64> = HashMap::with_capacity(pending.len());
    for p in pending {
        let e = busy_until.entry(p.client).or_insert(f64::NEG_INFINITY);
        *e = e.max(p.abs_finish);
    }
    for task in tasks.iter_mut() {
        if let Some(&until) = busy_until.get(&task.client) {
            // the old loop folded from t_start, so a straggler landing
            // before the round start contributes exactly 0.0
            task.completion += until.max(t_start) - t_start;
        }
    }
}

/// Plan/task-construction-time validation: a non-finite projected
/// completion time would make the quorum ranking meaningless (and used
/// to panic the coordinator inside `quorum_members`'s comparator), so it
/// is rejected as a proper `Err` before the round is ever dispatched.
fn validate_completions(tasks: &[LocalTask]) -> Result<()> {
    for t in tasks {
        if !t.completion.is_finite() {
            return Err(anyhow!(
                "client {}: non-finite projected completion time {}",
                t.client,
                t.completion
            ));
        }
    }
    Ok(())
}

/// The quorum members of a cohort: indices of the `k` smallest projected
/// completion times (index tie-break), returned in assignment order.
/// Completions are validated finite at dispatch (`validate_completions`),
/// and the comparator is total either way — no panic path. Crate-visible
/// so the hierarchical planner ranks edge sub-cohorts (and edge
/// arrivals) with exactly this rule.
#[allow(clippy::indexing_slicing)]
// hlint::allow(panic_path, item): the sort comparator only sees indices drawn from `0..completions.len()`
pub(crate) fn quorum_members(completions: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..completions.len()).collect();
    idx.sort_by(|&a, &b| completions[a].total_cmp(&completions[b]).then(a.cmp(&b)));
    idx.truncate(k);
    idx.sort_unstable();
    idx
}

/// [`quorum_members`] over the round's *survivors*: a scenario-dropped
/// client can never be a quorum member (its upload never arrives), so
/// the ranking runs on the non-dropped indices only. With nothing
/// dropped this is exactly `quorum_members` — and dropping any client
/// *outside* the chosen quorum leaves the member set (hence the merged
/// bytes) unchanged, the invariance `tests/prop_coordinator.rs` pins.
/// `drive_quorum` inlines the same filter-rank-map composition over its
/// single prebuilt survivor list (so the K decision and the ranking can
/// never desynchronize); this standalone form is the property-test
/// surface.
#[allow(clippy::indexing_slicing)]
// hlint::allow(panic_path, item): `survivors` holds indices into `completions`, and `quorum_members` returns indices into its own input
pub fn quorum_members_surviving(completions: &[f64], dropped: &[bool], k: usize) -> Vec<usize> {
    debug_assert_eq!(completions.len(), dropped.len());
    let survivors: Vec<usize> =
        (0..completions.len()).filter(|&i| !dropped.get(i).copied().unwrap_or(false)).collect();
    let surv_completions: Vec<f64> = survivors.iter().map(|&i| completions[i]).collect();
    quorum_members(&surv_completions, k).into_iter().map(|j| survivors[j]).collect()
}

/// Completion routing for the quorum path: completions arrive in
/// worker-race order, but the coordinator consumes them in the virtual
/// schedule's order — anything not yet needed parks here, keyed by
/// `(round, assignment index)`. Stray and duplicate completions are
/// proper errors (the cross-round analogue of `collect_completions`'s
/// validation).
#[derive(Default)]
struct QuorumState {
    /// `BTreeMap`, not `HashMap`: `drain` walks this map to surface the
    /// earliest-(round, index) straggler failure, and an ordered map
    /// makes that walk deterministic by construction (hlint rule D3) —
    /// no collect-and-sort step whose omission could silently reintroduce
    /// hash-order dependence. Arrival-order independence is pinned by
    /// `quorum_state_drain_order_is_arrival_independent`.
    arrived: std::collections::BTreeMap<(usize, usize), Result<TaskFate>>,
    /// received-or-consumed flag per [seq][index], for duplicate detection
    received: Vec<Vec<bool>>,
    /// dispatched completions not yet received
    outstanding: usize,
}

impl QuorumState {
    fn register_round(&mut self, n: usize) {
        self.received.push(vec![false; n]);
        self.outstanding += n;
    }

    fn file(&mut self, c: Completion) -> Result<()> {
        let Some(round) = self.received.get_mut(c.seq) else {
            return Err(anyhow!("completion for round {} which was never dispatched", c.seq));
        };
        let Some(flag) = round.get_mut(c.index) else {
            return Err(anyhow!(
                "completion index {} out of range for round {} ({} tasks)",
                c.index,
                c.seq,
                round.len()
            ));
        };
        if *flag {
            return Err(anyhow!("duplicate completion for round {} task {}", c.seq, c.index));
        }
        *flag = true;
        self.outstanding -= 1;
        self.arrived.insert((c.seq, c.index), c.outcome);
        Ok(())
    }

    /// Shutdown barrier: wait for every dispatched task's completion and
    /// surface the earliest-(round, index) failure among the updates that
    /// will never merge. Their *results* are discarded by design, but a
    /// panic or engine error in a straggler is a real fault and must fail
    /// the run exactly as it would on the synchronous paths. Dropped and
    /// Faulted fates drain silently — scheduled churn and policy-resolved
    /// fault losses are facts of the plan, not failures. Costs no extra
    /// wall-clock: the worker scope joins on these tasks anyway.
    fn drain(&mut self, tp: &mut dyn Transport) -> Result<()> {
        while self.outstanding > 0 {
            let c = tp.recv().map_err(|_| anyhow!("worker pool died during drain"))?;
            self.file(c)?;
        }
        // ordered iteration replaces the old collect-and-sort: same
        // earliest-(round, index) failure, by map invariant
        for (key, outcome) in std::mem::take(&mut self.arrived) {
            outcome.map_err(|e| {
                anyhow!("straggler of round {} (task {}) failed: {e}", key.0, key.1)
            })?;
        }
        Ok(())
    }

    /// Block until the fate of `(seq, index)` is available, parking
    /// everything else that drains off the channel in the meantime.
    fn demand(
        &mut self,
        tp: &mut dyn Transport,
        seq: usize,
        index: usize,
    ) -> Result<TaskFate> {
        loop {
            if let Some(outcome) = self.arrived.remove(&(seq, index)) {
                return outcome;
            }
            let c = tp.recv().map_err(|_| anyhow!("worker pool died mid-round"))?;
            self.file(c)?;
        }
    }

    /// [`QuorumState::demand`] for a merge input — quorum members and
    /// due late arrivals are chosen among survivors, so a `Dropped` or
    /// `Faulted` fate here means the scheduler violated its own churn
    /// invariant: a typed [`ScenarioError::PhantomMerge`], matching the
    /// rest of the dropout machinery.
    fn demand_done(
        &mut self,
        tp: &mut dyn Transport,
        seq: usize,
        index: usize,
    ) -> Result<TaskOutcome> {
        match self.demand(tp, seq, index)? {
            TaskFate::Done(o) => Ok(o),
            TaskFate::Dropped(d) => Err(ScenarioError::PhantomMerge {
                round: seq,
                index,
                client: d.client,
                fate: "dropped mid-round",
            }
            .into()),
            TaskFate::Faulted(f) => Err(ScenarioError::PhantomMerge {
                round: seq,
                index,
                client: f.client,
                fate: "lost to an unrecovered fault",
            }
            .into()),
        }
    }
}

/// Coordinator body of [`RoundDriver::run_quorum`] (module docs,
/// "Semi-async quorum rounds" and "Adaptive quorum control").
// hlint::allow(panic_path, item): every index below is either `i < n = meta.*.len()` (RoundMeta's parallel vectors) or drawn from `survivors_idx`, whose entries are `0..n` by construction
#[allow(clippy::too_many_arguments, clippy::indexing_slicing)]
fn drive_quorum(
    tp: &mut dyn Transport,
    env: &mut FlEnv,
    strategy: &mut dyn Strategy,
    rounds: usize,
    policy: &mut QuorumPolicy,
    hierarchy: Option<HierarchyCfg>,
    mut observer: Option<RoundObserver<'_>>,
    reports: &mut Vec<RoundReport>,
) -> Result<()> {
    let mut state = QuorumState::default();
    let mut pending: Vec<PendingStraggler> = Vec::new();

    // phases A + B for round 0, then dispatch immediately
    strategy.plan_ahead(env)?;
    let mut tasks = strategy.take_tasks(env)?;
    if tasks.is_empty() {
        return Err(anyhow!("cannot dispatch an empty cohort"));
    }
    let round_id = env.stamp_dropouts(&mut tasks);
    env.stamp_faults(&mut tasks, round_id)?;
    validate_completions(&tasks)?;
    let mut meta = RoundMeta::capture(&tasks, env.clock.now());
    state.register_round(tasks.len());
    tp.dispatch(0, tasks)?;

    for h in 0..rounds {
        if h + 1 < rounds {
            // overlap: round h+1's phase A runs under round h's cohort
            strategy.plan_ahead(env)?;
        }

        // scenario churn: a dropped client can never satisfy the quorum —
        // membership ranks survivors only, and churn that empties the
        // round or starves a static K is a typed error (module docs,
        // "Scenario churn"). The survivor filter is built exactly once;
        // the K decision and the membership ranking both read it, so the
        // two can never desynchronize.
        let n = meta.completions.len();
        let survivors_idx: Vec<usize> = (0..n).filter(|&i| !meta.dropped[i]).collect();
        let n_survivors = survivors_idx.len();
        if n_survivors == 0 {
            return Err(ScenarioError::EmptySurvivors { round: h }.into());
        }
        if let Some(required) = policy.required_quorum() {
            // the documented oversized-K clamp is against the *configured*
            // cohort size — a round that churn (availability windows or
            // mid-round dropouts) thinned below the demanded K is a typed
            // error, never a silent degrade
            let required = required.min(env.cfg.k_per_round.max(1));
            if required > n_survivors {
                return Err(ScenarioError::QuorumInfeasible {
                    round: h,
                    required,
                    survivors: n_survivors,
                }
                .into());
            }
        }
        let surv_completions: Vec<f64> =
            survivors_idx.iter().map(|&i| meta.completions[i]).collect();

        // this round's (K, α): plan facts + observed virtual-clock
        // signals in, deterministic decision out (module docs,
        // "Adaptive quorum control"); signals are fetched lazily so the
        // static-K path never walks the ledger. The driver injects the
        // observed dropout rate — a dispatch-time fact of the virtual
        // schedule, not a scheme signal. With `--hierarchy` the same
        // policy drives the edge tier instead (module docs,
        // "Hierarchical aggregation"): `members` becomes the union of
        // the root-quorum edges' quorums, `t_q` the slowest root-quorum
        // edge's arrival, and non-members get plan-deferred landing
        // instants (whole late edges and individually-forwarded edge
        // stragglers) instead of their raw completions.
        let churn = env.observed_dropout_rate();
        let faults = env.observed_fault_rate();
        let signals = || {
            let mut sig = strategy.quorum_signals();
            sig.dropout_rate = churn;
            sig.fault_rate = faults;
            sig
        };
        let (members, t_q, wan_up_bytes, alpha, deferred): (
            Vec<usize>,
            f64,
            Option<u64>,
            f64,
            HashMap<usize, f64>,
        ) = if let Some(hcfg) = &hierarchy {
            // the hierarchy plans WAN forwards from *upload* sizes — in a
            // wire mode an edge's composed forward is a measured frame
            let surv_bytes: Vec<u64> =
                survivors_idx.iter().map(|&i| meta.up_bytes[i]).collect();
            let plan = plan_hierarchy(&surv_completions, &surv_bytes, hcfg, policy, signals);
            let members: Vec<usize> =
                plan.members.iter().map(|&j| survivors_idx[j]).collect();
            let deferred: HashMap<usize, f64> =
                plan.deferred.iter().map(|&(j, t)| (survivors_idx[j], t)).collect();
            (members, plan.t_q, Some(plan.wan_up_bytes), plan.alpha, deferred)
        } else {
            let decision = policy.decide_with(&surv_completions, signals);
            let k = decision.k.clamp(1, n_survivors);
            let members: Vec<usize> = quorum_members(&surv_completions, k)
                .into_iter()
                .map(|j| survivors_idx[j])
                .collect();
            let t_q = members.iter().map(|&i| meta.completions[i]).fold(0.0f64, f64::max);
            (members, t_q, None, decision.alpha, HashMap::new())
        };
        let t_agg = meta.t_start + t_q;

        // stragglers from earlier rounds whose virtual uploads have
        // landed by this aggregation point, oldest first
        let (due, still): (Vec<_>, Vec<_>) =
            pending.drain(..).partition(|p: &PendingStraggler| p.abs_finish <= t_agg);
        pending = still;
        let mut due = due;
        due.sort_by(|a, b| (a.seq, a.index).cmp(&(b.seq, b.index)));

        // pull exactly the outcomes the virtual schedule aggregates now;
        // anything else racing off the channel parks in the buffer
        let mut quorum_outcomes = Vec::with_capacity(members.len());
        for &i in &members {
            quorum_outcomes.push(state.demand_done(tp, h, i)?);
        }
        let mut late = Vec::with_capacity(due.len());
        for p in &due {
            let outcome = state.demand_done(tp, p.seq, p.index)?;
            let staleness = h - p.seq;
            late.push(LateArrival {
                origin_round: p.seq,
                staleness,
                weight: staleness_weight(staleness, alpha),
                outcome,
            });
        }

        // register this round's stragglers (their virtual finish times
        // are plan facts, known before their results exist); a dropped
        // client's broadcast bills like a straggler's but it never enters
        // the pending buffer — its upload never arrives. A hierarchical
        // round overrides the landing instant with the plan's deferred
        // arrival (late edge as a unit, or individual backhaul forward).
        let mut straggler_down = 0u64;
        let mut dropped_clients = Vec::new();
        {
            let mut m = members.iter().peekable();
            for i in 0..n {
                if m.peek() == Some(&&i) {
                    m.next();
                } else if meta.dropped[i] {
                    straggler_down += meta.bytes[i];
                    dropped_clients.push(meta.clients[i]);
                    log::debug!(
                        "round {h}: client {} lost mid-round (dropout or unrecovered \
                         fault) — released, never merged",
                        meta.clients[i]
                    );
                } else {
                    straggler_down += meta.bytes[i];
                    let rel_finish = deferred.get(&i).copied().unwrap_or(meta.completions[i]);
                    pending.push(PendingStraggler {
                        seq: h,
                        index: i,
                        client: meta.clients[i],
                        abs_finish: meta.t_start + rel_finish,
                    });
                }
            }
        }

        // full quorum with nothing due late is exactly the synchronous
        // phase C — route through it so `--quorum N` stays byte-identical
        // to the serial loop (a churned round has k < n, so it always
        // takes the quorum hook, which books the dropped broadcasts).
        // Hierarchical rounds always take the quorum hook: their WAN
        // uplink is the composed-update sum, never the member sum.
        let report = if wan_up_bytes.is_none() && members.len() == n && late.is_empty() {
            strategy.finish_round(env, quorum_outcomes)?
        } else {
            strategy.finish_round_quorum(
                env,
                QuorumBatch {
                    round: h,
                    quorum: quorum_outcomes,
                    late,
                    straggler_down_bytes: straggler_down,
                    dropped: dropped_clients,
                    wan_up_bytes,
                    round_time: wan_up_bytes.is_some().then_some(t_q),
                },
            )?
        };
        reports.push(report);
        if let (Some(cb), Some(report)) = (observer.as_mut(), reports.last()) {
            if !cb(&*env, &*strategy, report)? {
                return state.drain(tp);
            }
        }

        if h + 1 < rounds {
            // phase B for h+1 (payloads need the quorum aggregate);
            // round h's stragglers are still executing on the workers
            let mut tasks = strategy.take_tasks(env)?;
            if tasks.is_empty() {
                return Err(anyhow!("cannot dispatch an empty cohort"));
            }
            let t_start = env.clock.now();
            delay_busy_clients(&mut tasks, &pending, t_start);
            let round_id = env.stamp_dropouts(&mut tasks);
            env.stamp_faults(&mut tasks, round_id)?;
            validate_completions(&tasks)?;
            meta = RoundMeta::capture(&tasks, t_start);
            state.register_round(tasks.len());
            tp.dispatch(h + 1, tasks)?;
        }
    }
    // outstanding stragglers never merge, but their failures must still
    // surface (see QuorumState::drain)
    state.drain(tp)
}

/// Dispatches rounds' tasks over up to `workers` threads, worker *i*
/// pinned to engine *i* of the pool.
#[derive(Debug, Clone, Copy)]
pub struct RoundDriver {
    workers: usize,
    /// `--hierarchy`: edge-aggregator tier for quorum rounds (see
    /// `coordinator::hierarchy`); `None` is the flat path, byte-identical
    /// to its historical self
    hierarchy: Option<HierarchyCfg>,
}

impl RoundDriver {
    /// `workers == 0` is treated as 1 (the serial coordinator loop).
    pub fn new(workers: usize) -> RoundDriver {
        RoundDriver { workers: workers.max(1), hierarchy: None }
    }

    /// Attach (or detach) the edge-aggregator tier. Only `run_quorum`
    /// reads it — the hierarchy is a quorum-round feature and config
    /// validation rejects `--hierarchy` without an active quorum mode.
    pub fn with_hierarchy(mut self, hierarchy: Option<HierarchyCfg>) -> RoundDriver {
        self.hierarchy = hierarchy;
        self
    }

    pub fn hierarchy(&self) -> Option<HierarchyCfg> {
        self.hierarchy
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Execute one round's tasks, returning fates in assignment order
    /// (scenario-stamped tasks complete as [`TaskFate::Dropped`] without
    /// touching an engine; see `split_fates` / `finish_dispatched_round`
    /// for the policy layer).
    ///
    /// Errs on an empty cohort (an empty round has no reference client
    /// and would poison every downstream average). Never spawns more
    /// threads than tasks; with one worker (or one task) everything runs
    /// inline on the caller's thread against the pool's primary engine.
    pub fn run(&self, pool: &EnginePool, tasks: Vec<LocalTask>) -> Result<Vec<TaskFate>> {
        let n = tasks.len();
        if n == 0 {
            return Err(anyhow!("cannot dispatch an empty cohort"));
        }
        validate_completions(&tasks)?;
        let workers = self.workers.min(n);
        if workers <= 1 {
            let engine = pool.primary();
            return tasks.into_iter().map(|t| exec_task(engine, t)).collect();
        }

        let queue = TaskQueue::new();
        let (tx, rx) = channel::<Completion>();
        std::thread::scope(|s| {
            for w in 0..workers {
                let tx = tx.clone();
                let queue = &queue;
                let engine = pool.engine(w);
                s.spawn(move || worker_loop(w, engine, queue, tx));
            }
            drop(tx);
            let _close = CloseOnDrop(&queue);
            let mut tp = SimTransport::new(&queue, rx);
            tp.dispatch(0, tasks)?;
            // close immediately: this is the whole dispatch, so workers
            // drain and exit while we collect
            queue.close();
            collect_completions(&mut tp, n, 0)
        })
    }

    /// Drive `rounds` consecutive rounds of `strategy` over one
    /// persistent worker pool, overlapping round *h+1*'s plan-ahead phase
    /// with round *h*'s stragglers (module docs, "Overlapped execution").
    ///
    /// Byte-identical to calling `strategy.run_round(env)` `rounds` times
    /// — the phase contract sequences every state mutation in the serial
    /// order — so this is purely a wall-clock optimization.
    pub fn run_overlapped(
        &self,
        pool: &EnginePool,
        env: &mut FlEnv,
        strategy: &mut dyn Strategy,
        rounds: usize,
    ) -> Result<Vec<RoundReport>> {
        if rounds == 0 {
            return Ok(Vec::new());
        }
        if self.workers <= 1 {
            // one worker: nothing drains in the background, so the plain
            // serial loop is both simpler and identical
            return (0..rounds).map(|_| strategy.run_round(env)).collect();
        }

        let queue = TaskQueue::new();
        let (tx, rx) = channel::<Completion>();
        let mut reports = Vec::with_capacity(rounds);
        let result = std::thread::scope(|s| {
            for w in 0..self.workers {
                let tx = tx.clone();
                let queue = &queue;
                let engine = pool.engine(w);
                s.spawn(move || worker_loop(w, engine, queue, tx));
            }
            drop(tx);

            // guard, not a trailing call: a panic inside a scheme phase
            // must still close the queue or the parked workers would
            // never join and the scope would hang forever
            let _close = CloseOnDrop(&queue);
            let mut tp = SimTransport::new(&queue, rx);
            drive_rounds(&mut tp, env, strategy, rounds, &mut reports)
        });
        result.map(|()| reports)
    }

    /// [`RoundDriver::run_overlapped`] against a caller-supplied
    /// [`Transport`] backend instead of the in-process worker pool.
    ///
    /// The transport owns its executors (`self.workers` is a sim-pool
    /// concept and is ignored here), but the coordinator loop — and with
    /// it every plan, stamp, aggregation and billing decision — is the
    /// same code path, so a backend that executes tasks faithfully
    /// reproduces the simulation byte for byte. The simulation is the
    /// oracle: `transport::tcp`'s parity suite pins exactly this.
    pub fn run_overlapped_on(
        &self,
        tp: &mut dyn Transport,
        env: &mut FlEnv,
        strategy: &mut dyn Strategy,
        rounds: usize,
    ) -> Result<Vec<RoundReport>> {
        if rounds == 0 {
            return Ok(Vec::new());
        }
        let mut reports = Vec::with_capacity(rounds);
        drive_rounds(tp, env, strategy, rounds, &mut reports)?;
        Ok(reports)
    }

    /// Drive `rounds` semi-async K-of-N quorum rounds of `strategy`
    /// (module docs, "Semi-async quorum rounds"): round *h* aggregates
    /// once its K virtually-fastest cohort members land, round *h+1*
    /// dispatches immediately, and *h*'s stragglers fold into later
    /// rounds staleness-weighted. The per-round (K, α) come from
    /// `policy` — PR 3's static knobs (`QuorumPolicy::fixed`) or the
    /// adaptive controller (`--quorum auto`; module docs, "Adaptive
    /// quorum control"). The policy is borrowed mutably so callers can
    /// inspect controller state (e.g. the annealed α) after the run.
    ///
    /// Deterministic for a fixed seed regardless of worker count or pool
    /// size; whenever a round's decided K covers the whole cohort (the
    /// static knob ≥ N or 0, or an adaptive no-straggler round) it takes
    /// the synchronous phase-C hook and reproduces the serial loop
    /// byte-identically. The observer, when present, runs after each
    /// round's aggregation; returning `Ok(false)` ends the run early. On
    /// any exit, outstanding stragglers are drained — their updates
    /// dropped, their failures surfaced.
    pub fn run_quorum(
        &self,
        pool: &EnginePool,
        env: &mut FlEnv,
        strategy: &mut dyn Strategy,
        rounds: usize,
        policy: &mut QuorumPolicy,
        observer: Option<RoundObserver<'_>>,
    ) -> Result<Vec<RoundReport>> {
        if rounds == 0 {
            return Ok(Vec::new());
        }
        // No serial special case: quorum semantics live on the virtual
        // clock, so even one worker runs the full pipeline (it just
        // executes the queue sequentially) and produces the same bytes.
        let queue = TaskQueue::new();
        let (tx, rx) = channel::<Completion>();
        let mut reports = Vec::with_capacity(rounds);
        let result = std::thread::scope(|s| {
            for w in 0..self.workers {
                let tx = tx.clone();
                let queue = &queue;
                let engine = pool.engine(w);
                s.spawn(move || worker_loop(w, engine, queue, tx));
            }
            drop(tx);

            let _close = CloseOnDrop(&queue);
            let mut tp = SimTransport::new(&queue, rx);
            drive_quorum(
                &mut tp,
                env,
                strategy,
                rounds,
                policy,
                self.hierarchy,
                observer,
                &mut reports,
            )
        });
        result.map(|()| reports)
    }

    /// [`RoundDriver::run_quorum`] against a caller-supplied
    /// [`Transport`] backend — the quorum analogue of
    /// [`RoundDriver::run_overlapped_on`]. Quorum semantics live on the
    /// virtual clock, so the decided (K, α), membership ranking and
    /// staleness weights are identical whatever the medium; only wall
    /// clocks differ.
    pub fn run_quorum_on(
        &self,
        tp: &mut dyn Transport,
        env: &mut FlEnv,
        strategy: &mut dyn Strategy,
        rounds: usize,
        policy: &mut QuorumPolicy,
        observer: Option<RoundObserver<'_>>,
    ) -> Result<Vec<RoundReport>> {
        if rounds == 0 {
            return Ok(Vec::new());
        }
        let mut reports = Vec::with_capacity(rounds);
        drive_quorum(
            tp,
            env,
            strategy,
            rounds,
            policy,
            self.hierarchy,
            observer,
            &mut reports,
        )?;
        Ok(reports)
    }
}

/// Shared phase-C bookkeeping for quorum rounds, the semi-async analogue
/// of [`collect_round`]: the round's clock advance is the **quorum**
/// completion time (the K-th smallest projection — the whole point of
/// the mode), waiting time is measured within the quorum, downlink
/// traffic covers the full cohort broadcast (stragglers received their
/// payloads too) while uplink bills quorum members now and each
/// straggler at its merge round, and the training-loss mean covers
/// everything folded into this aggregate (quorum and late alike).
pub fn collect_quorum_round(
    env: &mut FlEnv,
    batch: &QuorumBatch,
    block_variance: f64,
) -> RoundReport {
    let mut down = batch.straggler_down_bytes;
    let mut member_up = 0u64;
    let mut completion = Vec::with_capacity(batch.quorum.len());
    let mut losses = Vec::with_capacity(batch.quorum.len() + batch.late.len());
    for o in &batch.quorum {
        down += o.bytes;
        member_up += o.up_bytes;
        completion.push(o.completion);
        losses.push(o.result.mean_loss);
    }
    // hierarchical rounds bill the edges' composed updates on the WAN
    // instead of the member sum (each edge forwards one update); late
    // merges still bill individually at their merge round either way
    let mut up = batch.wan_up_bytes.unwrap_or(member_up);
    for l in &batch.late {
        up += l.outcome.up_bytes;
        losses.push(l.outcome.result.mean_loss);
    }
    env.traffic.record_down(down);
    env.traffic.record_up(up);
    let round_time = batch
        .round_time
        .unwrap_or_else(|| completion.iter().copied().fold(0.0, f64::max));
    env.clock.advance(round_time);

    RoundReport {
        round: batch.round,
        round_time,
        avg_wait: average_wait(&completion),
        mean_loss: losses.iter().sum::<f64>() / losses.len().max(1) as f64,
        taus: batch.quorum.iter().map(|o| o.tau).collect(),
        widths: batch.quorum.iter().map(|o| o.p).collect(),
        down_bytes: down,
        up_bytes: up,
        completion_times: completion,
        block_variance,
    }
}

/// Shared collect phase: fold a round's outcomes into the environment's
/// traffic meter and virtual clock and assemble the `RoundReport` (the
/// bookkeeping formerly copy-pasted across Heroes, dense and Flanc).
pub fn collect_round(
    env: &mut FlEnv,
    round: usize,
    outcomes: &[TaskOutcome],
    block_variance: f64,
) -> RoundReport {
    let mut down = 0u64;
    let mut up = 0u64;
    let mut completion = Vec::with_capacity(outcomes.len());
    let mut losses = Vec::with_capacity(outcomes.len());
    for o in outcomes {
        down += o.bytes;
        up += o.up_bytes;
        completion.push(o.completion);
        losses.push(o.result.mean_loss);
    }
    env.traffic.record_down(down);
    env.traffic.record_up(up);
    let round_time = completion.iter().copied().fold(0.0, f64::max);
    env.clock.advance(round_time);

    RoundReport {
        round,
        round_time,
        avg_wait: average_wait(&completion),
        mean_loss: losses.iter().sum::<f64>() / losses.len().max(1) as f64,
        taus: outcomes.iter().map(|o| o.tau).collect(),
        widths: outcomes.iter().map(|o| o.p).collect(),
        down_bytes: down,
        up_bytes: up,
        completion_times: completion,
        block_variance,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_workers_clamps_to_serial() {
        assert_eq!(RoundDriver::new(0).workers(), 1);
        assert_eq!(RoundDriver::new(1).workers(), 1);
        assert_eq!(RoundDriver::new(4).workers(), 4);
    }

    #[test]
    fn task_types_are_send() {
        // the queue moves tasks/outcomes across threads
        fn assert_send<T: Send>() {}
        assert_send::<LocalTask>();
        assert_send::<TaskOutcome>();
        assert_send::<TaskFate>();
        assert_send::<DroppedTask>();
        assert_send::<FaultedTask>();
        assert_send::<Dispatch>();
        assert_send::<Completion>();
    }

    #[test]
    fn queue_delivers_in_order_and_drains_on_close() {
        use crate::data::loader::ImageLoader;
        use crate::data::synth_image::ImageGen;
        use crate::util::rng::Rng;
        use std::sync::Arc;

        // tasks are sequencing metadata here — they are never executed
        let set = Arc::new(ImageGen::cifar_twin().generate(4, 1, &mut Rng::new(1)));
        let mk = |client: usize| LocalTask {
            client,
            p: 1,
            tau: 1,
            lr: 0.1,
            train_exec: "unused".into(),
            probe_exec: None,
            payload: Vec::new(),
            stream: BatchStream::Image(ImageLoader::new(set.clone(), vec![0, 1], 2, Rng::new(2))),
            bytes: 0,
            up_bytes: 0,
            rebill_bytes: 0,
            wire: None,
            completion: 0.0,
            drop_at: None,
            fault: None,
        };
        let queue = TaskQueue::new();
        queue.push_round(7, vec![mk(10), mk(11), mk(12)]);
        queue.close();
        for expect in 0..3usize {
            let d = queue.pop().expect("queue must drain pushed tasks");
            assert_eq!((d.seq, d.index), (7, expect), "FIFO assignment order");
            assert_eq!(d.task.client, 10 + expect);
        }
        assert!(queue.pop().is_none(), "closed drained queue must yield None");
    }

    #[test]
    fn ordered_collect_returns_earliest_error() {
        let slots: Vec<Option<Result<TaskFate>>> = vec![
            Some(Err(anyhow!("first"))),
            Some(Err(anyhow!("second"))),
        ];
        let err = into_ordered(slots).unwrap_err();
        assert_eq!(err.to_string(), "first");
    }

    fn dummy_outcome(client: usize) -> TaskOutcome {
        TaskOutcome {
            client,
            p: 1,
            tau: 1,
            bytes: 0,
            up_bytes: 0,
            completion: 0.0,
            result: crate::coordinator::client::LocalResult {
                params: Vec::new(),
                mean_loss: 0.0,
                final_loss: 0.0,
                mean_grad_sq: 0.0,
                estimates: None,
            },
        }
    }

    /// A `Done` fate for channel/tests plumbing.
    fn done(client: usize) -> Result<TaskFate> {
        Ok(TaskFate::Done(dummy_outcome(client)))
    }

    #[test]
    fn split_fates_partitions_in_assignment_order() {
        let fates = vec![
            TaskFate::Done(dummy_outcome(10)),
            TaskFate::Dropped(DroppedTask { client: 11, bytes: 7, drop_time: 0.5 }),
            TaskFate::Done(dummy_outcome(12)),
            TaskFate::Faulted(FaultedTask {
                client: 14,
                bytes: 3,
                class: FaultClass::Exec,
                retries: 2,
                fault_time: 2.0,
            }),
            TaskFate::Dropped(DroppedTask { client: 13, bytes: 9, drop_time: 1.5 }),
        ];
        let (survivors, dropped, faulted) = split_fates(fates);
        assert_eq!(survivors.iter().map(|o| o.client).collect::<Vec<_>>(), vec![10, 12]);
        assert_eq!(dropped.iter().map(|d| d.client).collect::<Vec<_>>(), vec![11, 13]);
        assert_eq!(dropped.iter().map(|d| d.bytes).sum::<u64>(), 16);
        assert_eq!(faulted.iter().map(|f| f.client).collect::<Vec<_>>(), vec![14]);
        assert_eq!(faulted[0].class, FaultClass::Exec);
        assert_eq!(faulted[0].retries, 2);
    }

    #[test]
    fn quorum_members_exclude_dropped_clients() {
        // the fastest projection is dropped: membership skips it and
        // takes the next-fastest survivors instead
        let completions = [5.0, 1.0, 3.0, 2.0, 9.0];
        let no_drop = [false; 5];
        assert_eq!(
            quorum_members_surviving(&completions, &no_drop, 2),
            quorum_members(&completions, 2),
            "no churn ⇒ exactly the plain ranking"
        );
        let mut dropped = [false; 5];
        dropped[1] = true;
        assert_eq!(quorum_members_surviving(&completions, &dropped, 2), vec![2, 3]);
        // dropping outside the chosen quorum leaves the member set alone
        let mut outside = [false; 5];
        outside[4] = true;
        assert_eq!(
            quorum_members_surviving(&completions, &outside, 2),
            quorum_members_surviving(&completions, &no_drop, 2),
            "a non-quorum dropout must not change the member set"
        );
    }

    #[test]
    fn stray_completion_is_an_error_not_a_panic() {
        // regression: a completion from a round not in flight used to hit
        // `assert_eq!` and abort the coordinator
        let queue = TaskQueue::new();
        let (tx, rx) = channel::<Completion>();
        let mut tp = SimTransport::new(&queue, rx);
        tx.send(Completion { seq: 3, index: 0, outcome: done(0) }).unwrap();
        let err = collect_completions(&mut tp, 1, 0).unwrap_err();
        assert!(err.to_string().contains("stray completion"), "unexpected error: {err}");

        // duplicate slot
        tx.send(Completion { seq: 0, index: 0, outcome: done(0) }).unwrap();
        tx.send(Completion { seq: 0, index: 0, outcome: done(0) }).unwrap();
        let err = collect_completions(&mut tp, 2, 0).unwrap_err();
        assert!(err.to_string().contains("duplicate completion"), "unexpected error: {err}");

        // out-of-range index
        tx.send(Completion { seq: 0, index: 9, outcome: done(0) }).unwrap();
        let err = collect_completions(&mut tp, 1, 0).unwrap_err();
        assert!(err.to_string().contains("out of range"), "unexpected error: {err}");
    }

    #[test]
    fn busy_clients_are_serialized_on_the_virtual_clock() {
        use crate::data::loader::ImageLoader;
        use crate::data::synth_image::ImageGen;
        use crate::util::rng::Rng;
        use std::sync::Arc;

        let set = Arc::new(ImageGen::cifar_twin().generate(4, 1, &mut Rng::new(1)));
        let mk = |client: usize, completion: f64| LocalTask {
            client,
            p: 1,
            tau: 1,
            lr: 0.1,
            train_exec: "unused".into(),
            probe_exec: None,
            payload: Vec::new(),
            stream: BatchStream::Image(ImageLoader::new(set.clone(), vec![0, 1], 2, Rng::new(2))),
            bytes: 0,
            up_bytes: 0,
            rebill_bytes: 0,
            wire: None,
            completion,
            drop_at: None,
            fault: None,
        };
        // round starts at t=10; client 3 is still busy until t=25 with a
        // round-0 straggler, client 4 is idle
        let pending = vec![
            PendingStraggler { seq: 0, index: 2, client: 3, abs_finish: 25.0 },
            PendingStraggler { seq: 0, index: 1, client: 3, abs_finish: 19.0 },
        ];
        let mut tasks = vec![mk(3, 5.0), mk(4, 5.0)];
        delay_busy_clients(&mut tasks, &pending, 10.0);
        // busy client: starts at 25, finishes 15 after round start + 5
        assert_eq!(tasks[0].completion, 20.0);
        // idle client: untouched (exactly +0.0)
        assert_eq!(tasks[1].completion, 5.0);
    }

    #[test]
    fn delay_busy_clients_matches_reference_loop() {
        use crate::data::loader::ImageLoader;
        use crate::data::synth_image::ImageGen;
        use crate::util::rng::Rng;
        use std::sync::Arc;

        // the old O(tasks × pending) per-task rescan, kept verbatim as
        // the reference the busy_until-map rewrite must match bit for bit
        fn reference(tasks: &mut [LocalTask], pending: &[PendingStraggler], t_start: f64) {
            for task in tasks.iter_mut() {
                let busy_until = pending
                    .iter()
                    .filter(|p| p.client == task.client)
                    .map(|p| p.abs_finish)
                    .fold(t_start, f64::max);
                task.completion += busy_until - t_start;
            }
        }

        let set = Arc::new(ImageGen::cifar_twin().generate(4, 1, &mut Rng::new(1)));
        let mk = |client: usize, completion: f64| LocalTask {
            client,
            p: 1,
            tau: 1,
            lr: 0.1,
            train_exec: "unused".into(),
            probe_exec: None,
            payload: Vec::new(),
            stream: BatchStream::Image(ImageLoader::new(set.clone(), vec![0, 1], 2, Rng::new(2))),
            bytes: 0,
            up_bytes: 0,
            rebill_bytes: 0,
            wire: None,
            completion,
            drop_at: None,
            fault: None,
        };
        let mut rng = Rng::new(17);
        for case in 0..50 {
            let t_start = rng.uniform_in(0.0, 50.0);
            let n_tasks = 1 + rng.below(8);
            let n_pending = rng.below(10);
            let mut a: Vec<LocalTask> = (0..n_tasks)
                .map(|_| mk(rng.below(6), rng.uniform_in(0.1, 20.0)))
                .collect();
            let mut b: Vec<LocalTask> =
                a.iter().map(|t| mk(t.client, t.completion)).collect();
            let pending: Vec<PendingStraggler> = (0..n_pending)
                .map(|i| PendingStraggler {
                    seq: 0,
                    index: i,
                    client: rng.below(6),
                    // including finishes *before* the round start, which
                    // must contribute exactly nothing
                    abs_finish: rng.uniform_in(-10.0, 80.0) + t_start,
                })
                .collect();
            delay_busy_clients(&mut a, &pending, t_start);
            reference(&mut b, &pending, t_start);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(
                    x.completion.to_bits(),
                    y.completion.to_bits(),
                    "case {case}: client {} diverged ({} vs {})",
                    x.client,
                    x.completion,
                    y.completion
                );
            }
        }
    }

    #[test]
    fn non_finite_completions_are_rejected_at_dispatch() {
        // regression: a NaN projected completion used to survive until
        // quorum_members' comparator `.expect` aborted the coordinator;
        // it is now a proper Err at plan/task-construction time
        use crate::data::loader::ImageLoader;
        use crate::data::synth_image::ImageGen;
        use crate::util::rng::Rng;
        use std::sync::Arc;

        let set = Arc::new(ImageGen::cifar_twin().generate(4, 1, &mut Rng::new(1)));
        let mk = |completion: f64| LocalTask {
            client: 0,
            p: 1,
            tau: 1,
            lr: 0.1,
            train_exec: "unused".into(),
            probe_exec: None,
            payload: Vec::new(),
            stream: BatchStream::Image(ImageLoader::new(set.clone(), vec![0, 1], 2, Rng::new(2))),
            bytes: 0,
            up_bytes: 0,
            rebill_bytes: 0,
            wire: None,
            completion,
            drop_at: None,
            fault: None,
        };
        validate_completions(&[mk(1.0), mk(0.0)]).unwrap();
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let err = validate_completions(&[mk(1.0), mk(bad)]).unwrap_err();
            assert!(
                err.to_string().contains("non-finite projected completion"),
                "unexpected error: {err}"
            );
        }
    }

    #[test]
    fn quorum_members_are_the_virtually_fastest() {
        // ranked by projected completion, index tie-break, returned in
        // assignment order
        let completions = [5.0, 1.0, 3.0, 1.0, 9.0];
        assert_eq!(quorum_members(&completions, 2), vec![1, 3]);
        assert_eq!(quorum_members(&completions, 3), vec![1, 2, 3]);
        assert_eq!(quorum_members(&completions, 5), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn staleness_weight_properties() {
        assert_eq!(staleness_weight(0, 1.0), 1.0);
        assert!((staleness_weight(1, 1.0) - 0.5).abs() < 1e-7);
        assert!((staleness_weight(3, 1.0) - 0.25).abs() < 1e-7);
        assert_eq!(staleness_weight(7, 0.0), 1.0, "α = 0 disables discounting");
        // α sharpens the discount
        assert!(staleness_weight(2, 2.0) < staleness_weight(2, 1.0));
        // extreme α underflows f64→f32 — the floor keeps the weight a
        // valid (positive) accumulator input instead of aborting the run
        let w = staleness_weight(2, 100.0);
        assert!(w > 0.0, "underflowed weight must stay positive, got {w}");
    }

    #[test]
    fn quorum_state_routes_cross_round_completions() {
        let queue = TaskQueue::new();
        let (tx, rx) = channel::<Completion>();
        let mut tp = SimTransport::new(&queue, rx);
        let mut state = QuorumState::default();
        state.register_round(2); // round 0
        state.register_round(1); // round 1

        // round 1's completion races ahead of round 0's — demand(0, ..)
        // must park it, then demand(1, ..) must find it buffered
        tx.send(Completion { seq: 1, index: 0, outcome: done(10) }).unwrap();
        tx.send(Completion { seq: 0, index: 1, outcome: done(11) }).unwrap();
        tx.send(Completion { seq: 0, index: 0, outcome: done(12) }).unwrap();
        assert_eq!(state.demand_done(&mut tp, 0, 0).unwrap().client, 12);
        assert_eq!(state.demand_done(&mut tp, 0, 1).unwrap().client, 11);
        assert_eq!(state.demand_done(&mut tp, 1, 0).unwrap().client, 10);

        // never-dispatched round and duplicates are errors
        let c = Completion { seq: 5, index: 0, outcome: done(0) };
        assert!(state.file(c).is_err());
        let dup = Completion { seq: 1, index: 0, outcome: done(0) };
        assert!(state.file(dup).unwrap_err().to_string().contains("duplicate"));
    }

    #[test]
    fn demanding_a_dropped_fate_as_merge_input_is_a_scheduler_bug() {
        let queue = TaskQueue::new();
        let (tx, rx) = channel::<Completion>();
        let mut tp = SimTransport::new(&queue, rx);
        let mut state = QuorumState::default();
        state.register_round(2);
        let fate = TaskFate::Dropped(DroppedTask { client: 4, bytes: 0, drop_time: 1.0 });
        tx.send(Completion { seq: 0, index: 0, outcome: Ok(fate) }).unwrap();
        let err = state.demand_done(&mut tp, 0, 0).unwrap_err();
        match err.downcast_ref::<ScenarioError>() {
            Some(&ScenarioError::PhantomMerge { round: 0, index: 0, client: 4, .. }) => {}
            other => panic!("expected a typed PhantomMerge, got {other:?} ({err})"),
        }
        assert!(err.to_string().contains("scheduler bug"), "unexpected error: {err}");

        // an unrecovered fault demanded for merge is the same class of bug
        let fate = TaskFate::Faulted(FaultedTask {
            client: 7,
            bytes: 0,
            class: FaultClass::Partition,
            retries: 1,
            fault_time: 3.0,
        });
        tx.send(Completion { seq: 0, index: 1, outcome: Ok(fate) }).unwrap();
        let err = state.demand_done(&mut tp, 0, 1).unwrap_err();
        match err.downcast_ref::<ScenarioError>() {
            Some(&ScenarioError::PhantomMerge { round: 0, index: 1, client: 7, fate }) => {
                assert!(fate.contains("fault"), "fate string should name the fault: {fate}");
            }
            other => panic!("expected a typed PhantomMerge, got {other:?} ({err})"),
        }
    }

    #[test]
    fn drain_surfaces_failed_never_merged_stragglers() {
        // a straggler whose update would be discarded at run end must
        // still fail the run if its task errored
        let queue = TaskQueue::new();
        let (tx, rx) = channel::<Completion>();
        let mut tp = SimTransport::new(&queue, rx);
        let mut state = QuorumState::default();
        state.register_round(2);
        tx.send(Completion { seq: 0, index: 0, outcome: done(1) }).unwrap();
        tx.send(Completion { seq: 0, index: 1, outcome: Err(anyhow!("engine died")) }).unwrap();
        let err = state.drain(&mut tp).unwrap_err();
        assert!(err.to_string().contains("straggler of round 0"), "unexpected error: {err}");
        assert!(err.to_string().contains("engine died"), "unexpected error: {err}");

        // all-Ok leftovers drain cleanly — including dropped fates, which
        // are scheduled churn, not faults
        let queue = TaskQueue::new();
        let (tx, rx) = channel::<Completion>();
        let mut tp = SimTransport::new(&queue, rx);
        let mut state = QuorumState::default();
        state.register_round(2);
        tx.send(Completion { seq: 0, index: 0, outcome: done(2) }).unwrap();
        let fate = TaskFate::Dropped(DroppedTask { client: 3, bytes: 0, drop_time: 0.2 });
        tx.send(Completion { seq: 0, index: 1, outcome: Ok(fate) }).unwrap();
        state.drain(&mut tp).unwrap();
    }

    #[test]
    fn quorum_state_drain_order_is_arrival_independent() {
        // bit-exactness pin for the HashMap → BTreeMap conversion of
        // `QuorumState::arrived`: with two failed stragglers, drain must
        // surface the earliest-(round, index) failure no matter which
        // arrival order filed them. Under the old HashMap this held only
        // because of an explicit collect-and-sort; the BTreeMap makes it
        // structural — this test keeps anyone from regressing it back to
        // an unordered map.
        let run = |arrivals: &[(usize, usize)]| -> String {
            let queue = TaskQueue::new();
            let (tx, rx) = channel::<Completion>();
            let mut tp = SimTransport::new(&queue, rx);
            let mut state = QuorumState::default();
            state.register_round(2); // round 0
            state.register_round(2); // round 1
            for &(seq, index) in arrivals {
                let outcome = if seq == 0 && index == 0 {
                    done(9)
                } else {
                    Err(anyhow!("straggler {seq}/{index} died"))
                };
                tx.send(Completion { seq, index, outcome }).unwrap();
            }
            state.drain(&mut tp).unwrap_err().to_string()
        };
        let forward = run(&[(0, 0), (0, 1), (1, 0), (1, 1)]);
        let reversed = run(&[(1, 1), (1, 0), (0, 1), (0, 0)]);
        let shuffled = run(&[(1, 0), (0, 0), (1, 1), (0, 1)]);
        assert!(forward.contains("straggler of round 0 (task 1)"), "got: {forward}");
        assert_eq!(forward, reversed);
        assert_eq!(forward, shuffled);
    }
}
