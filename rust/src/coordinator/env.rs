//! Shared federated environment: datasets + partitions + device fleet +
//! WAN model + virtual clock + traffic meter + global evaluation.
//!
//! Every scheme (Heroes and the four baselines) runs against the same
//! `FlEnv`, so comparisons in the experiment figures differ only by the
//! scheme logic, exactly like the paper's testbed (§VI-C).
//!
//! Training data is handed out as **owned** [`BatchStream`]s — one per
//! `(client, round)`, seeded deterministically from
//! `(cfg.seed, client, round)` — so worker threads of the parallel round
//! driver (`coordinator::round`) pull batches without aliasing the env.
//! Evaluation, the virtual clock and the traffic meter stay on the
//! coordinator thread.

use crate::config::{ExperimentConfig, Partition, PopulationMode};
use crate::coordinator::assignment::ClientStatus;
use crate::coordinator::resilience::{rebill_for, FaultsCtl, ResilienceLedger};
use crate::coordinator::XData;
use crate::data::loader::{EvalBatches, ImageLoader, TextEvalBatches, TextLoader};
use crate::data::partition::{gamma_partition, phi_partition, PartitionPlan};
use crate::data::synth_image::ImageGen;
use crate::data::synth_text::{LazyTextGen, TextGen};
use crate::data::{ImageSet, TextSet};
use crate::model::{ComposedGlobal, DenseGlobal};
use crate::runtime::{Engine, EnginePool, InputInfo, Manifest, ModelInfo, Value};
use crate::simulation::{
    CacheStats, DeviceFleet, LazyCache, NetworkModel, Population, PopulationSpec, ScenarioCtl,
    TrafficMeter, VirtualClock,
};
use crate::tensor::{IntTensor, Tensor};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::sync::{Arc, Mutex};

/// Shared training data + per-client partitions; `batch_stream` stamps
/// out owned loaders over it on demand. The `Lazy*` variants hold no
/// per-client state at all — a sampled client's shard is synthesized
/// from its `Population::shard_spec` on first touch and memoized in a
/// bounded [`LazyCache`] (O(cohort) resident, counters observable via
/// [`FlEnv::shard_cache_stats`]).
enum TrainData {
    Image {
        set: Arc<ImageSet>,
        /// per-client shard descriptors into `set` (`client_indices`
        /// materializes a cohort member's index list on demand; each
        /// stream shuffles its own copy)
        plan: PartitionPlan,
    },
    Text {
        /// per-client token streams
        shards: Vec<Arc<Vec<i32>>>,
        seq_len: usize,
    },
    /// `--population lazy`, image families: shards are pure functions of
    /// `(partition prior, shard seed)`
    LazyImage {
        gen: ImageGen,
        seed_protos: u64,
        partition: Partition,
        classes: usize,
        cache: Mutex<LazyCache<Arc<ImageSet>>>,
    },
    /// `--population lazy`, text family: style chain + stream per client
    /// from the frozen global chain
    LazyText {
        gen: Arc<LazyTextGen>,
        seq_len: usize,
        cache: Mutex<LazyCache<Arc<Vec<i32>>>>,
    },
}

enum TestData {
    Image(Arc<ImageSet>),
    Text(Arc<TextSet>),
}

/// An owned, self-contained batch source for one client's local round.
///
/// The stream holds `Arc`s of the shared dataset plus its own cursor and
/// RNG, so a worker thread can draw batches with no access to `FlEnv`.
/// Streams for the same `(seed, client, round)` yield identical batch
/// sequences — the determinism contract of `coordinator::round` rests on
/// this.
pub enum BatchStream {
    Image(ImageLoader),
    Text(TextLoader),
    /// A pre-drawn batch schedule (the networked dispatch path): the
    /// coordinator draws a task's worst-case consumption from the live
    /// stream at dispatch and ships it, so a remote executor replays
    /// exactly the sequence the simulation would have drawn.
    Fixed(FixedBatches),
}

impl BatchStream {
    /// Next training batch (paper: ξ ~ D_n).
    pub fn next_batch(&mut self) -> (XData, IntTensor) {
        match self {
            BatchStream::Image(l) => {
                let b = l.next_batch();
                (XData::Image(b.x), b.y)
            }
            BatchStream::Text(l) => {
                let b = l.next_batch();
                (XData::Tokens(b.x), b.y)
            }
            BatchStream::Fixed(f) => f.next(),
        }
    }
}

/// The payload of [`BatchStream::Fixed`]: an owned, pre-drawn batch
/// sequence, nonempty by construction.
///
/// `run_local` consumes at most `2τ + 2` batches (two probe batches plus
/// up to two attempts of τ batches on the divergence-retry path), so a
/// schedule of that length replays bit-identically to the live stream it
/// was drawn from in every execution path. Polling past the end cycles
/// back to the first batch rather than panicking — a correctly sized
/// schedule never reaches that.
pub struct FixedBatches {
    first: (XData, IntTensor),
    rest: Vec<(XData, IntTensor)>,
    cursor: usize,
}

impl FixedBatches {
    /// `None` on an empty schedule — a batch source must produce, and
    /// holding the first batch out of band keeps `next` panic-free.
    pub fn new(mut batches: Vec<(XData, IntTensor)>) -> Option<FixedBatches> {
        if batches.is_empty() {
            return None;
        }
        let first = batches.remove(0);
        Some(FixedBatches { first, rest: batches, cursor: 0 })
    }

    fn next(&mut self) -> (XData, IntTensor) {
        let i = self.cursor;
        self.cursor += 1;
        if i == 0 {
            return self.first.clone();
        }
        self.rest.get(i - 1).cloned().unwrap_or_else(|| self.first.clone())
    }
}

/// The common federated world for one experiment run.
///
/// Holds the per-worker [`EnginePool`]: the round driver pins worker *i*
/// to engine *i*, while coordinator-side evaluation runs on the pool's
/// primary engine ([`FlEnv::engine`]).
pub struct FlEnv<'e> {
    pub pool: &'e EnginePool,
    pub info: ModelInfo,
    pub cfg: ExperimentConfig,
    pub fleet: DeviceFleet,
    pub clock: VirtualClock,
    pub traffic: TrafficMeter,
    network: NetworkModel,
    /// churn schedule state (`--scenario`): plan/dispatch cursors,
    /// bandwidth trace, observed dropout totals
    scenario: ScenarioCtl,
    /// fault schedule + policy state (`--faults`/`--fault-policy`):
    /// per-class draws, stamp-time resolutions and the resilience ledger
    faults: FaultsCtl,
    train: TrainData,
    test: TestData,
    // hlint::allow(unkeyed_rng): the eager path's historical shared cursor — coordinator-thread-only by construction (worker threads receive owned streams), kept for byte-identity with pre-lazy runs
    rng: Rng,
    /// `--population lazy`: the parametric client world (None on the
    /// eager path — which then behaves byte-identically to its
    /// historical self)
    population: Option<Population>,
    /// the round index `sample_clients` most recently planned — the key
    /// for the lazy mode's per-round status draws
    plan_round: usize,
}

impl<'e> FlEnv<'e> {
    /// Build the world: synthesize data, partition it per the config,
    /// draw the device fleet. Deterministic in `cfg.seed` (and
    /// independent of the pool size — engines only execute).
    ///
    /// `--population lazy` routes to [`Self::build_lazy`] instead: no
    /// per-client state is enumerated, so build cost is O(test split)
    /// and round cost is O(cohort) at any `n_clients`.
    pub fn build(pool: &'e EnginePool, cfg: ExperimentConfig) -> Result<FlEnv<'e>> {
        cfg.validate()?;
        if cfg.population == PopulationMode::Lazy {
            return Self::build_lazy(pool, cfg);
        }
        let info = pool.manifest().model(&cfg.family)?.clone();
        let mut rng = Rng::new(cfg.seed);
        let mut data_rng = rng.fork(1);
        let mut fleet_rng = rng.fork(2);

        let (train, test) = match &info.input {
            InputInfo::Image { .. } => {
                let gen = if cfg.family == "resnet" {
                    ImageGen::imagenet_twin()
                } else {
                    ImageGen::cifar_twin()
                };
                let n_train = cfg.n_clients * cfg.samples_per_client;
                // test size must tile the eval batch exactly (exact metrics)
                let n_test = (cfg.test_samples / info.eval_batch).max(1) * info.eval_batch;
                let train = Arc::new(gen.generate(n_train, cfg.seed ^ 0xDA7A, &mut data_rng));
                let test = Arc::new(gen.generate(n_test, cfg.seed ^ 0xDA7A, &mut data_rng));
                let labels = &train.labels;
                let plan = match cfg.partition {
                    Partition::Gamma(g) => gamma_partition(
                        labels, info.classes, cfg.n_clients, cfg.samples_per_client, g, &mut data_rng,
                    ),
                    Partition::Phi(frac) => {
                        let missing = ((info.classes as f64) * frac).round() as usize;
                        phi_partition(
                            labels, info.classes, cfg.n_clients, cfg.samples_per_client,
                            missing.min(info.classes - 1), &mut data_rng,
                        )
                    }
                    Partition::Natural => {
                        return Err(anyhow!("natural partition is text-only"));
                    }
                };
                (TrainData::Image { set: train, plan }, TestData::Image(test))
            }
            InputInfo::Text { seq_len, .. } => {
                let gen = TextGen::shakespeare_twin();
                let test_tokens = 4_000.max(cfg.test_samples * (seq_len + 1));
                let set = Arc::new(gen.generate(
                    cfg.n_clients, cfg.shard_tokens, test_tokens, cfg.seed ^ 0x7E47,
                ));
                let shards = set.shards.iter().cloned().map(Arc::new).collect();
                (TrainData::Text { shards, seq_len: *seq_len }, TestData::Text(set))
            }
        };

        let fleet = DeviceFleet::default_fleet(cfg.n_clients, &mut fleet_rng);
        let network = NetworkModel {
            up_lo_mbps: cfg.up_mbps.0,
            up_hi_mbps: cfg.up_mbps.1,
            down_lo_mbps: cfg.down_mbps.0,
            down_hi_mbps: cfg.down_mbps.1,
        };
        let scenario = ScenarioCtl::new(cfg.scenario, cfg.seed);
        let faults = FaultsCtl::new(cfg.faults, cfg.fault_policy, cfg.seed);
        Ok(FlEnv {
            pool,
            info,
            cfg,
            fleet,
            clock: VirtualClock::new(),
            traffic: TrafficMeter::new(),
            network,
            scenario,
            faults,
            train,
            test,
            rng: rng.fork(3),
            population: None,
            plan_round: 0,
        })
    }

    /// Build the `--population lazy` world: a [`Population`] of priors
    /// instead of an enumerated fleet/dataset. Only the test split is
    /// synthesized eagerly (from its own keyed RNG — O(test), not
    /// O(population)); every per-client quantity is derived from
    /// `(seed, client[, round])` on first touch and shard state is
    /// memoized in a bounded cache, so resident memory and per-round
    /// cost are O(cohort) at any `n_clients`.
    fn build_lazy(pool: &'e EnginePool, cfg: ExperimentConfig) -> Result<FlEnv<'e>> {
        let info = pool.manifest().model(&cfg.family)?.clone();
        let population = Population::new(PopulationSpec::default_mix(cfg.n_clients, cfg.seed))?;
        // a few cohorts' worth of shards stay resident so overlap/quorum
        // stragglers re-hit their shard while it is still warm
        let cache_cap = (4 * cfg.k_per_round).max(32);
        let (train, test) = match &info.input {
            InputInfo::Image { .. } => {
                if matches!(cfg.partition, Partition::Natural) {
                    return Err(anyhow!("natural partition is text-only"));
                }
                let gen = if cfg.family == "resnet" {
                    ImageGen::imagenet_twin()
                } else {
                    ImageGen::cifar_twin()
                };
                let n_test = (cfg.test_samples / info.eval_batch).max(1) * info.eval_batch;
                // same prototype seed as every client shard, so the test
                // split shares the class structure
                let mut trng = Rng::new(cfg.seed ^ 0x7E57_DA7A);
                let test = Arc::new(gen.generate(n_test, cfg.seed ^ 0xDA7A, &mut trng));
                (
                    TrainData::LazyImage {
                        gen,
                        seed_protos: cfg.seed ^ 0xDA7A,
                        partition: cfg.partition,
                        classes: info.classes,
                        cache: Mutex::new(LazyCache::new(cache_cap)?),
                    },
                    TestData::Image(test),
                )
            }
            InputInfo::Text { seq_len, .. } => {
                let gen = Arc::new(TextGen::shakespeare_twin().lazy(cfg.seed ^ 0x7E47));
                let test_tokens = 4_000.max(cfg.test_samples * (seq_len + 1));
                let test = Arc::new(TextSet {
                    vocab: gen.vocab(),
                    shards: Vec::new(),
                    test: gen.global_stream(test_tokens, cfg.seed ^ 0x7E57_EEEE),
                });
                (
                    TrainData::LazyText {
                        gen,
                        seq_len: *seq_len,
                        cache: Mutex::new(LazyCache::new(cache_cap)?),
                    },
                    TestData::Text(test),
                )
            }
        };
        let network = NetworkModel {
            up_lo_mbps: cfg.up_mbps.0,
            up_hi_mbps: cfg.up_mbps.1,
            down_lo_mbps: cfg.down_mbps.0,
            down_hi_mbps: cfg.down_mbps.1,
        };
        let scenario = ScenarioCtl::new(cfg.scenario, cfg.seed);
        let faults = FaultsCtl::new(cfg.faults, cfg.fault_policy, cfg.seed);
        Ok(FlEnv {
            pool,
            info,
            cfg,
            // no enumerated fleet exists in lazy mode: device draws come
            // from the population's keyed RNGs
            fleet: DeviceFleet { devices: Vec::new() },
            clock: VirtualClock::new(),
            traffic: TrafficMeter::new(),
            network,
            scenario,
            faults,
            train,
            test,
            rng: Rng::new(cfg.seed ^ 0x909D),
            population: Some(population),
            plan_round: 0,
        })
    }

    /// The coordinator's engine (evaluation, serial dispatch).
    pub fn engine(&self) -> &'e Engine {
        self.pool.primary()
    }

    /// Randomly sample K participants (paper Alg. 1 line 5), restricted
    /// to the clients the scenario says are attending this round. Full
    /// availability (every scenario but churned windows) takes the exact
    /// historical code path — same RNG consumption, byte-identical
    /// sampling — which is what keeps `--scenario stable` equal to the
    /// pre-scenario default.
    /// In `--population lazy` mode the cohort comes from the population's
    /// sparse sampler instead: O(K) work and memory regardless of
    /// `n_clients`, keyed by `(seed, round)` so the draw is independent
    /// of the shared cursor RNG and of materialization history.
    #[allow(clippy::indexing_slicing)] // `sample_distinct` indices are `< available.len()` (hlint reason at the site)
    pub fn sample_clients(&mut self) -> Vec<usize> {
        let round = self.scenario.begin_plan_round();
        self.plan_round = round;
        if let Some(pop) = &self.population {
            let scenario = &self.scenario;
            return pop.sample_cohort(round, self.cfg.k_per_round, |c| scenario.available_now(c));
        }
        let n = self.cfg.n_clients;
        let available: Vec<usize> =
            (0..n).filter(|&c| self.scenario.available_now(c)).collect();
        if available.len() == n {
            return self.rng.sample_distinct(n, self.cfg.k_per_round);
        }
        // a thinned round samples what it can; an empty availability set
        // yields an empty cohort, which the planner rejects as a proper
        // error downstream
        let k = self.cfg.k_per_round.min(available.len());
        // hlint::allow(panic_path): `sample_distinct(available.len(), k)` yields indices strictly below `available.len()`
        self.rng.sample_distinct(available.len(), k).into_iter().map(|i| available[i]).collect()
    }

    /// Collect a client's round status (Alg. 1 line 4). Under a
    /// bandwidth-drifting scenario the WAN band is scaled by the trace
    /// multiplier of the round being planned (RNG consumption identical
    /// to the unscaled path).
    /// In `--population lazy` mode both draws are keyed by
    /// `(seed, client, plan round)` — no fleet entry or shared RNG cursor
    /// is touched, so status collection is O(1) per cohort member.
    #[allow(clippy::indexing_slicing)] // eager fleet enumerates all clients (hlint reason at the site)
    pub fn status(&mut self, client: usize) -> ClientStatus {
        if let Some(pop) = &self.population {
            let q = pop.flops(client, self.plan_round);
            let mut lrng = pop.link_rng(client, self.plan_round);
            let link = match self.scenario.bandwidth_scale() {
                None => self.network.sample(&mut lrng),
                Some(s) => self.network.sample_scaled(&mut lrng, s),
            };
            return ClientStatus { client, q_flops: q, link };
        }
        // hlint::allow(panic_path): the eager fleet enumerates all `n_clients` devices and cohorts are sampled from `0..n_clients`
        let q = self.fleet.devices[client].sample_flops();
        let link = match self.scenario.bandwidth_scale() {
            None => self.network.sample(&mut self.rng),
            Some(s) => self.network.sample_scaled(&mut self.rng, s),
        };
        ClientStatus { client, q_flops: q, link }
    }

    /// Stamp this dispatch's scenario dropouts onto the round's tasks
    /// (called exactly once per dispatched round by every driver path):
    /// a dropped task's `drop_at` is set to the virtual instant the
    /// client vanishes. Returns the dispatch-round index — the round
    /// number the full-barrier dropout policy reports. Dropout draws are
    /// pure functions of `(seed, round, client)`, so any worker/pool
    /// count sees the same churn.
    pub fn stamp_dropouts(&mut self, tasks: &mut [crate::coordinator::round::LocalTask]) -> usize {
        let round = self.scenario.begin_dispatch_round();
        let mut dropped = 0usize;
        for t in tasks.iter_mut() {
            t.drop_at = self.scenario.dropout(round, t.client).map(|frac| frac * t.completion);
            dropped += t.drop_at.is_some() as usize;
        }
        self.scenario.note_dispatched(tasks.len(), dropped);
        round
    }

    /// Stamp this dispatch's engine-level faults onto the round's tasks
    /// (called once per dispatched round by every driver path, right
    /// after [`Self::stamp_dropouts`] with the round index it returned).
    /// Every fault is drawn *and resolved* here, at stamp time
    /// (`coordinator::resilience`): a recovered fault delays the task's
    /// projected completion by its retry/stall cost, an unrecovered one
    /// attaches the stamp that makes the task complete as
    /// `TaskFate::Faulted`, and a `fail`-policy fault aborts with a typed
    /// `ResilienceError::FaultAbort`. A scenario-dropped task masks its
    /// fault draw (the client is gone before the engine ever runs).
    /// Draws are pure functions of `(seed, round, client)` and the
    /// ledger is an order-independent sum, so any worker/pool count sees
    /// the same faults; `--faults off` stamps nothing and consumes no
    /// RNG.
    pub fn stamp_faults(
        &mut self,
        tasks: &mut [crate::coordinator::round::LocalTask],
        round: usize,
    ) -> Result<()> {
        if self.faults.is_off() {
            return Ok(());
        }
        self.faults.note_dispatched(tasks.len());
        for t in tasks.iter_mut() {
            if let Some((stamp, completion)) =
                self.faults.stamp_one(round, t.client, t.completion, t.drop_at.is_some())?
            {
                // a recovered corrupt fault re-sent the upload frame on
                // every retry: bill the retransmitted bytes onto the task
                // (exec_task folds them into `TaskOutcome::up_bytes`) and
                // into the resilience ledger
                let rebill = rebill_for(&stamp, t.up_bytes);
                if rebill > 0 {
                    t.rebill_bytes = rebill;
                    self.faults.note_rebilled(rebill);
                }
                t.fault = Some(stamp);
                t.completion = completion;
            }
        }
        Ok(())
    }

    /// Observed mid-round dropout rate over everything dispatched so far
    /// (the adaptive quorum controller's churn signal).
    pub fn observed_dropout_rate(&self) -> f64 {
        self.scenario.observed_dropout_rate()
    }

    /// Observed engine-fault rate over everything dispatched so far (the
    /// adaptive quorum controller's fault-pressure signal; 0 while
    /// `--faults off`).
    pub fn observed_fault_rate(&self) -> f64 {
        self.faults.observed_fault_rate()
    }

    /// The run's resilience ledger (read-only; the recorder attaches it
    /// to the run output, tests pin its counts).
    pub fn resilience(&self) -> &ResilienceLedger {
        self.faults.ledger()
    }

    /// The run's scenario state (read-only; tests and logs).
    pub fn scenario(&self) -> &ScenarioCtl {
        &self.scenario
    }

    /// Owned batch stream for one client's local round. Deterministic in
    /// `(cfg.seed, client, round)` and independent of every other stream,
    /// so the round driver may run it on any worker thread. Errs on a
    /// client outside the partition (a planner bug surfaced as a typed
    /// error, not an index panic); a poisoned shard-cache lock is
    /// recovered, since every cached value is pure in its key.
    pub fn batch_stream(&self, client: usize, round: usize) -> Result<BatchStream> {
        // mix (seed, client, round) injectively enough for SplitMix64's
        // whitening; the +1s keep client 0 / round 0 off the raw seed
        let seed = self
            .cfg
            .seed
            .wrapping_add((client as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add((round as u64 + 1).wrapping_mul(0xD1B5_4A32_D192_ED03));
        let rng = Rng::new(seed);
        let stream = match &self.train {
            TrainData::Image { set, plan } => BatchStream::Image(ImageLoader::new(
                set.clone(),
                plan.client_indices(client),
                self.info.batch,
                rng,
            )),
            TrainData::Text { shards, seq_len } => {
                let shard = shards
                    .get(client)
                    .ok_or_else(|| anyhow!("client {client} outside the text partition"))?;
                BatchStream::Text(TextLoader::new(shard.clone(), self.info.batch, *seq_len, rng))
            }
            TrainData::LazyImage { gen, seed_protos, partition, classes, cache } => {
                let pop = self
                    .population
                    .as_ref()
                    .ok_or_else(|| anyhow!("lazy train data without a population"))?;
                let spec = pop.shard_spec(client, self.cfg.samples_per_client);
                let set = cache
                    .lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner)
                    .get_or_insert_with(client, || {
                        let mut srng = Rng::new(spec.seed);
                        let labels =
                            lazy_shard_labels(*partition, *classes, client, spec.quota, &mut srng);
                        Arc::new(gen.generate_labeled(labels, *seed_protos, &mut srng))
                    });
                let indices: Vec<usize> = (0..set.len()).collect();
                BatchStream::Image(ImageLoader::new(set, indices, self.info.batch, rng))
            }
            TrainData::LazyText { gen, seq_len, cache } => {
                let pop = self
                    .population
                    .as_ref()
                    .ok_or_else(|| anyhow!("lazy train data without a population"))?;
                let spec = pop.shard_spec(client, self.cfg.shard_tokens);
                // a loader needs strictly more than seq_len+1 tokens; pad
                // tiny jittered quotas up to two full windows
                let tokens = spec.quota.max(2 * (*seq_len + 1) + 2);
                let stream = cache
                    .lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner)
                    .get_or_insert_with(client, || Arc::new(gen.shard(tokens, spec.seed)));
                BatchStream::Text(TextLoader::new(stream, self.info.batch, *seq_len, rng))
            }
        };
        Ok(stream)
    }

    /// The lazy population, if this env was built with `--population
    /// lazy` (tests and benches inspect priors and cohort draws).
    pub fn population(&self) -> Option<&Population> {
        self.population.as_ref()
    }

    /// Shard-cache counters for the lazy data path (`None` on the eager
    /// path). The O(cohort) property tests assert on `materializations`
    /// and `peak_resident` here.
    pub fn shard_cache_stats(&self) -> Option<CacheStats> {
        // a poisoned lock is recovered: the stats are plain counters and
        // every cached value is pure in its key
        use std::sync::PoisonError;
        match &self.train {
            TrainData::LazyImage { cache, .. } => {
                Some(cache.lock().unwrap_or_else(PoisonError::into_inner).stats().clone())
            }
            TrainData::LazyText { cache, .. } => {
                Some(cache.lock().unwrap_or_else(PoisonError::into_inner).stats().clone())
            }
            _ => None,
        }
    }

    /// Evaluate a parameter list with the given eval executable over the
    /// full test split; returns (mean loss, accuracy). The eval
    /// executables return `[loss_sum, correct]` scalars; their arity and
    /// shapes come from the compiled artifact — external input — so a
    /// missing output is a typed error, not an index panic.
    pub fn evaluate_param_list(&self, exec: &str, params: &[Tensor]) -> Result<(f64, f64)> {
        fn scalar(out: &[Tensor], idx: usize, exec: &str) -> Result<f64> {
            out.get(idx)
                .and_then(|t| t.data().first())
                .map(|&v| f64::from(v))
                .ok_or_else(|| anyhow!("{exec}: eval executable returned no scalar output {idx}"))
        }
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut total = 0.0f64;
        match &self.test {
            TestData::Image(set) => {
                for (batch, real) in EvalBatches::new(set, self.info.eval_batch) {
                    if real < self.info.eval_batch {
                        // The eval executable reduces over the whole
                        // (wrap-padded) batch, so a ragged tail would
                        // mis-scale loss/accuracy — drop it, exactly like
                        // the text branch. (This was only a debug_assert
                        // before: release builds silently mis-scaled.)
                        break;
                    }
                    let mut inputs: Vec<Value> = params.iter().map(Value::F32).collect();
                    inputs.push(Value::F32(&batch.x));
                    inputs.push(Value::I32(&batch.y));
                    let out = self.engine().execute(exec, &inputs)?;
                    loss_sum += scalar(&out, 0, exec)?;
                    correct += scalar(&out, 1, exec)?;
                    total += real as f64;
                }
            }
            TestData::Text(set) => {
                let InputInfo::Text { seq_len, .. } = self.info.input else {
                    return Err(anyhow!("text eval on non-text family"));
                };
                for (batch, real) in TextEvalBatches::new(set, self.info.eval_batch, seq_len) {
                    if real < self.info.eval_batch {
                        break; // drop the ragged tail: exact full batches only
                    }
                    let mut inputs: Vec<Value> = params.iter().map(Value::F32).collect();
                    inputs.push(Value::I32(&batch.x));
                    inputs.push(Value::I32(&batch.y));
                    let out = self.engine().execute(exec, &inputs)?;
                    loss_sum += scalar(&out, 0, exec)?;
                    correct += scalar(&out, 1, exec)?;
                    total += (real * seq_len) as f64;
                }
            }
        }
        if total == 0.0 {
            // distinguish "no data" from "data but no full batch" — only
            // exactly-full batches enter the sums (ragged tails skip)
            return Err(anyhow!(
                "test set has no full evaluation batches (eval batch = {})",
                self.info.eval_batch
            ));
        }
        Ok((loss_sum / total, correct / total))
    }

    /// Test the composed global model at full width (paper metric ①).
    pub fn evaluate_composed(&self, global: &ComposedGlobal) -> Result<(f64, f64)> {
        let params = global.full_inputs(&self.info);
        self.evaluate_param_list(&Manifest::eval_name(&self.cfg.family, true), &params)
    }

    /// Test the dense global model at full width.
    pub fn evaluate_dense(&self, global: &DenseGlobal) -> Result<(f64, f64)> {
        let mut params = global.weights.clone();
        params.push(global.bias.clone());
        self.evaluate_param_list(&Manifest::eval_name(&self.cfg.family, false), &params)
    }
}

/// Label vector for one lazily synthesized image shard, drawn from the
/// partition *prior* instead of an eager global pool: Γ keeps `gamma_pct`
/// of the quota on the client's dominant class (`client % classes`, the
/// eager scheme's assignment) and spreads the rest evenly; Φ removes
/// `missing_frac` of the classes (a shard-keyed draw) and balances the
/// quota over the kept ones. Pure in `(partition, classes, client, quota)`
/// plus the RNG's seed, so a shard is identical no matter when — or how
/// often — it is materialized.
// hlint::allow(unkeyed_rng, item): callers construct a fresh `Rng::new(spec.seed)` per shard — the parameter is the per-shard keyed RNG, not a shared cursor
fn lazy_shard_labels(
    partition: Partition,
    classes: usize,
    client: usize,
    quota: usize,
    rng: &mut Rng,
) -> Vec<i32> {
    let mut labels: Vec<i32> = Vec::with_capacity(quota);
    match partition {
        Partition::Gamma(gamma_pct) => {
            let frac = (gamma_pct / 100.0).clamp(0.0, 1.0);
            let dom = client % classes;
            let n_dom = ((quota as f64 * frac).round() as usize).min(quota);
            labels.extend(std::iter::repeat(dom as i32).take(n_dom));
            let others: Vec<usize> = (0..classes).filter(|&c| c != dom).collect();
            if others.is_empty() {
                labels.extend(std::iter::repeat(dom as i32).take(quota - n_dom));
            } else {
                let rest = quota - n_dom;
                for (j, &c) in others.iter().enumerate() {
                    let share = rest / others.len() + usize::from(j < rest % others.len());
                    labels.extend(std::iter::repeat(c as i32).take(share));
                }
            }
        }
        Partition::Phi(missing_frac) => {
            let missing = ((classes as f64 * missing_frac).round() as usize).min(classes - 1);
            let keep = classes - missing;
            let kept = rng.sample_distinct(classes, keep);
            for (j, &c) in kept.iter().enumerate() {
                let share = quota / keep + usize::from(j < quota % keep);
                labels.extend(std::iter::repeat(c as i32).take(share));
            }
        }
        // hlint::allow(panic_path): provably dead — `build_lazy` rejects `Natural` for image families before any shard is materialized
        Partition::Natural => unreachable!("natural partition is text-only"),
    }
    rng.shuffle(&mut labels);
    labels
}

#[cfg(test)]
mod tests {
    // In-module so the tests can graft ragged test sets onto the private
    // `test` field; PJRT execution still needs artifacts, so each test
    // skips gracefully without them.
    use super::*;
    use crate::config::Scale;

    fn pool_or_skip() -> Option<EnginePool> {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(EnginePool::single(Manifest::load(&dir).unwrap()).unwrap())
    }

    #[test]
    fn image_eval_skips_ragged_tail_batches() {
        // regression: the image branch only debug_assert!ed exact tiling;
        // in release builds a wrap-padded partial batch entered the sums
        // and silently mis-scaled loss/accuracy
        let Some(pool) = pool_or_skip() else { return };
        let mut cfg = ExperimentConfig::preset("cnn", Scale::Smoke);
        cfg.n_clients = 4;
        cfg.k_per_round = 2;
        cfg.samples_per_client = 16;
        cfg.test_samples = 64;
        let mut env = FlEnv::build(&pool, cfg).unwrap();
        let global = ComposedGlobal::init(&env.info, &mut Rng::new(7)).unwrap();
        let baseline = env.evaluate_composed(&global).unwrap();

        // graft half an eval batch of duplicated samples onto the set
        let TestData::Image(set) = &env.test else { panic!("cnn env must hold image test data") };
        let mut bigger = (**set).clone();
        let extra = env.info.eval_batch / 2;
        assert!(extra > 0, "eval batch too small to form a ragged tail");
        let ss = bigger.sample_size();
        for i in 0..extra {
            let row = bigger.pixels[i * ss..(i + 1) * ss].to_vec();
            bigger.pixels.extend_from_slice(&row);
            let label = bigger.labels[i];
            bigger.labels.push(label);
        }
        env.test = TestData::Image(Arc::new(bigger));
        let ragged = env.evaluate_composed(&global).unwrap();
        assert_eq!(ragged, baseline, "a partial eval batch must not change image metrics");
    }

    #[test]
    fn text_eval_skips_ragged_tail_batches() {
        // the text branch's skip, pinned the same way: dropping the
        // partial tail batch means a stream truncated to exactly the full
        // batches evaluates identically
        let Some(pool) = pool_or_skip() else { return };
        let mut cfg = ExperimentConfig::preset("rnn", Scale::Smoke);
        cfg.n_clients = 4;
        cfg.k_per_round = 2;
        cfg.samples_per_client = 16;
        cfg.shard_tokens = 800;
        cfg.test_samples = 50;
        let mut env = FlEnv::build(&pool, cfg).unwrap();
        let global = ComposedGlobal::init(&env.info, &mut Rng::new(7)).unwrap();
        let InputInfo::Text { seq_len, .. } = env.info.input else {
            panic!("rnn env must hold text data")
        };
        let stride = seq_len + 1;
        let batch = env.info.eval_batch;

        let TestData::Text(set) = &env.test else { panic!("rnn env must hold text test data") };
        let windows = set.test.len() / stride;
        let full = (windows / batch) * batch;
        assert!(windows > full, "need a partial tail batch: {windows} windows, batch {batch}");
        let mut exact = (**set).clone();
        exact.test.truncate(full * stride);

        let with_tail = env.evaluate_composed(&global).unwrap();
        env.test = TestData::Text(Arc::new(exact));
        let without_tail = env.evaluate_composed(&global).unwrap();
        assert_eq!(with_tail, without_tail, "a partial eval batch must not change text metrics");
    }
}
