//! Shared federated environment: datasets + partitions + device fleet +
//! WAN model + virtual clock + traffic meter + global evaluation.
//!
//! Every scheme (Heroes and the four baselines) runs against the same
//! `FlEnv`, so comparisons in the experiment figures differ only by the
//! scheme logic, exactly like the paper's testbed (§VI-C).
//!
//! Training data is handed out as **owned** [`BatchStream`]s — one per
//! `(client, round)`, seeded deterministically from
//! `(cfg.seed, client, round)` — so worker threads of the parallel round
//! driver (`coordinator::round`) pull batches without aliasing the env.
//! Evaluation, the virtual clock and the traffic meter stay on the
//! coordinator thread.

use crate::config::{ExperimentConfig, Partition};
use crate::coordinator::assignment::ClientStatus;
use crate::coordinator::XData;
use crate::data::loader::{EvalBatches, ImageLoader, TextEvalBatches, TextLoader};
use crate::data::partition::{gamma_partition, phi_partition};
use crate::data::synth_image::ImageGen;
use crate::data::synth_text::TextGen;
use crate::data::{ImageSet, TextSet};
use crate::model::{ComposedGlobal, DenseGlobal};
use crate::runtime::{Engine, EnginePool, InputInfo, Manifest, ModelInfo, Value};
use crate::simulation::{DeviceFleet, NetworkModel, ScenarioCtl, TrafficMeter, VirtualClock};
use crate::tensor::{IntTensor, Tensor};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Shared training data + per-client partitions; `batch_stream` stamps
/// out owned loaders over it on demand.
enum TrainData {
    Image {
        set: Arc<ImageSet>,
        /// per-client sample indices into `set` (cloned into each stream,
        /// which shuffles its own copy)
        parts: Vec<Vec<usize>>,
    },
    Text {
        /// per-client token streams
        shards: Vec<Arc<Vec<i32>>>,
        seq_len: usize,
    },
}

enum TestData {
    Image(Arc<ImageSet>),
    Text(Arc<TextSet>),
}

/// An owned, self-contained batch source for one client's local round.
///
/// The stream holds `Arc`s of the shared dataset plus its own cursor and
/// RNG, so a worker thread can draw batches with no access to `FlEnv`.
/// Streams for the same `(seed, client, round)` yield identical batch
/// sequences — the determinism contract of `coordinator::round` rests on
/// this.
pub enum BatchStream {
    Image(ImageLoader),
    Text(TextLoader),
}

impl BatchStream {
    /// Next training batch (paper: ξ ~ D_n).
    pub fn next_batch(&mut self) -> (XData, IntTensor) {
        match self {
            BatchStream::Image(l) => {
                let b = l.next_batch();
                (XData::Image(b.x), b.y)
            }
            BatchStream::Text(l) => {
                let b = l.next_batch();
                (XData::Tokens(b.x), b.y)
            }
        }
    }
}

/// The common federated world for one experiment run.
///
/// Holds the per-worker [`EnginePool`]: the round driver pins worker *i*
/// to engine *i*, while coordinator-side evaluation runs on the pool's
/// primary engine ([`FlEnv::engine`]).
pub struct FlEnv<'e> {
    pub pool: &'e EnginePool,
    pub info: ModelInfo,
    pub cfg: ExperimentConfig,
    pub fleet: DeviceFleet,
    pub clock: VirtualClock,
    pub traffic: TrafficMeter,
    network: NetworkModel,
    /// churn schedule state (`--scenario`): plan/dispatch cursors,
    /// bandwidth trace, observed dropout totals
    scenario: ScenarioCtl,
    train: TrainData,
    test: TestData,
    rng: Rng,
}

impl<'e> FlEnv<'e> {
    /// Build the world: synthesize data, partition it per the config,
    /// draw the device fleet. Deterministic in `cfg.seed` (and
    /// independent of the pool size — engines only execute).
    pub fn build(pool: &'e EnginePool, cfg: ExperimentConfig) -> Result<FlEnv<'e>> {
        cfg.validate()?;
        let info = pool.manifest().model(&cfg.family)?.clone();
        let mut rng = Rng::new(cfg.seed);
        let mut data_rng = rng.fork(1);
        let mut fleet_rng = rng.fork(2);

        let (train, test) = match &info.input {
            InputInfo::Image { .. } => {
                let gen = if cfg.family == "resnet" {
                    ImageGen::imagenet_twin()
                } else {
                    ImageGen::cifar_twin()
                };
                let n_train = cfg.n_clients * cfg.samples_per_client;
                // test size must tile the eval batch exactly (exact metrics)
                let n_test = (cfg.test_samples / info.eval_batch).max(1) * info.eval_batch;
                let train = Arc::new(gen.generate(n_train, cfg.seed ^ 0xDA7A, &mut data_rng));
                let test = Arc::new(gen.generate(n_test, cfg.seed ^ 0xDA7A, &mut data_rng));
                let labels = &train.labels;
                let parts = match cfg.partition {
                    Partition::Gamma(g) => gamma_partition(
                        labels, info.classes, cfg.n_clients, cfg.samples_per_client, g, &mut data_rng,
                    ),
                    Partition::Phi(frac) => {
                        let missing = ((info.classes as f64) * frac).round() as usize;
                        phi_partition(
                            labels, info.classes, cfg.n_clients, cfg.samples_per_client,
                            missing.min(info.classes - 1), &mut data_rng,
                        )
                    }
                    Partition::Natural => {
                        return Err(anyhow!("natural partition is text-only"));
                    }
                };
                (TrainData::Image { set: train, parts }, TestData::Image(test))
            }
            InputInfo::Text { seq_len, .. } => {
                let gen = TextGen::shakespeare_twin();
                let test_tokens = 4_000.max(cfg.test_samples * (seq_len + 1));
                let set = Arc::new(gen.generate(
                    cfg.n_clients, cfg.shard_tokens, test_tokens, cfg.seed ^ 0x7E47,
                ));
                let shards = set.shards.iter().cloned().map(Arc::new).collect();
                (TrainData::Text { shards, seq_len: *seq_len }, TestData::Text(set))
            }
        };

        let fleet = DeviceFleet::default_fleet(cfg.n_clients, &mut fleet_rng);
        let network = NetworkModel {
            up_lo_mbps: cfg.up_mbps.0,
            up_hi_mbps: cfg.up_mbps.1,
            down_lo_mbps: cfg.down_mbps.0,
            down_hi_mbps: cfg.down_mbps.1,
        };
        let scenario = ScenarioCtl::new(cfg.scenario, cfg.seed);
        Ok(FlEnv {
            pool,
            info,
            cfg,
            fleet,
            clock: VirtualClock::new(),
            traffic: TrafficMeter::new(),
            network,
            scenario,
            train,
            test,
            rng: rng.fork(3),
        })
    }

    /// The coordinator's engine (evaluation, serial dispatch).
    pub fn engine(&self) -> &'e Engine {
        self.pool.primary()
    }

    /// Randomly sample K participants (paper Alg. 1 line 5), restricted
    /// to the clients the scenario says are attending this round. Full
    /// availability (every scenario but churned windows) takes the exact
    /// historical code path — same RNG consumption, byte-identical
    /// sampling — which is what keeps `--scenario stable` equal to the
    /// pre-scenario default.
    pub fn sample_clients(&mut self) -> Vec<usize> {
        self.scenario.begin_plan_round();
        let n = self.cfg.n_clients;
        let available: Vec<usize> =
            (0..n).filter(|&c| self.scenario.available_now(c)).collect();
        if available.len() == n {
            return self.rng.sample_distinct(n, self.cfg.k_per_round);
        }
        // a thinned round samples what it can; an empty availability set
        // yields an empty cohort, which the planner rejects as a proper
        // error downstream
        let k = self.cfg.k_per_round.min(available.len());
        self.rng.sample_distinct(available.len(), k).into_iter().map(|i| available[i]).collect()
    }

    /// Collect a client's round status (Alg. 1 line 4). Under a
    /// bandwidth-drifting scenario the WAN band is scaled by the trace
    /// multiplier of the round being planned (RNG consumption identical
    /// to the unscaled path).
    pub fn status(&mut self, client: usize) -> ClientStatus {
        let q = self.fleet.devices[client].sample_flops();
        let link = match self.scenario.bandwidth_scale() {
            None => self.network.sample(&mut self.rng),
            Some(s) => self.network.sample_scaled(&mut self.rng, s),
        };
        ClientStatus { client, q_flops: q, link }
    }

    /// Stamp this dispatch's scenario dropouts onto the round's tasks
    /// (called exactly once per dispatched round by every driver path):
    /// a dropped task's `drop_at` is set to the virtual instant the
    /// client vanishes. Returns the dispatch-round index — the round
    /// number the full-barrier dropout policy reports. Dropout draws are
    /// pure functions of `(seed, round, client)`, so any worker/pool
    /// count sees the same churn.
    pub fn stamp_dropouts(&mut self, tasks: &mut [crate::coordinator::round::LocalTask]) -> usize {
        let round = self.scenario.begin_dispatch_round();
        let mut dropped = 0usize;
        for t in tasks.iter_mut() {
            t.drop_at = self.scenario.dropout(round, t.client).map(|frac| frac * t.completion);
            dropped += t.drop_at.is_some() as usize;
        }
        self.scenario.note_dispatched(tasks.len(), dropped);
        round
    }

    /// Observed mid-round dropout rate over everything dispatched so far
    /// (the adaptive quorum controller's churn signal).
    pub fn observed_dropout_rate(&self) -> f64 {
        self.scenario.observed_dropout_rate()
    }

    /// The run's scenario state (read-only; tests and logs).
    pub fn scenario(&self) -> &ScenarioCtl {
        &self.scenario
    }

    /// Owned batch stream for one client's local round. Deterministic in
    /// `(cfg.seed, client, round)` and independent of every other stream,
    /// so the round driver may run it on any worker thread.
    pub fn batch_stream(&self, client: usize, round: usize) -> BatchStream {
        // mix (seed, client, round) injectively enough for SplitMix64's
        // whitening; the +1s keep client 0 / round 0 off the raw seed
        let seed = self
            .cfg
            .seed
            .wrapping_add((client as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add((round as u64 + 1).wrapping_mul(0xD1B5_4A32_D192_ED03));
        let rng = Rng::new(seed);
        match &self.train {
            TrainData::Image { set, parts } => BatchStream::Image(ImageLoader::new(
                set.clone(),
                parts[client].clone(),
                self.info.batch,
                rng,
            )),
            TrainData::Text { shards, seq_len } => BatchStream::Text(TextLoader::new(
                shards[client].clone(),
                self.info.batch,
                *seq_len,
                rng,
            )),
        }
    }

    /// Evaluate a parameter list with the given eval executable over the
    /// full test split; returns (mean loss, accuracy).
    pub fn evaluate_param_list(&self, exec: &str, params: &[Tensor]) -> Result<(f64, f64)> {
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut total = 0.0f64;
        match &self.test {
            TestData::Image(set) => {
                for (batch, real) in EvalBatches::new(set, self.info.eval_batch) {
                    if real < self.info.eval_batch {
                        // The eval executable reduces over the whole
                        // (wrap-padded) batch, so a ragged tail would
                        // mis-scale loss/accuracy — drop it, exactly like
                        // the text branch. (This was only a debug_assert
                        // before: release builds silently mis-scaled.)
                        break;
                    }
                    let mut inputs: Vec<Value> = params.iter().map(Value::F32).collect();
                    inputs.push(Value::F32(&batch.x));
                    inputs.push(Value::I32(&batch.y));
                    let out = self.engine().execute(exec, &inputs)?;
                    loss_sum += out[0].data()[0] as f64;
                    correct += out[1].data()[0] as f64;
                    total += real as f64;
                }
            }
            TestData::Text(set) => {
                let InputInfo::Text { seq_len, .. } = self.info.input else {
                    return Err(anyhow!("text eval on non-text family"));
                };
                for (batch, real) in TextEvalBatches::new(set, self.info.eval_batch, seq_len) {
                    if real < self.info.eval_batch {
                        break; // drop the ragged tail: exact full batches only
                    }
                    let mut inputs: Vec<Value> = params.iter().map(Value::F32).collect();
                    inputs.push(Value::I32(&batch.x));
                    inputs.push(Value::I32(&batch.y));
                    let out = self.engine().execute(exec, &inputs)?;
                    loss_sum += out[0].data()[0] as f64;
                    correct += out[1].data()[0] as f64;
                    total += (real * seq_len) as f64;
                }
            }
        }
        if total == 0.0 {
            // distinguish "no data" from "data but no full batch" — only
            // exactly-full batches enter the sums (ragged tails skip)
            return Err(anyhow!(
                "test set has no full evaluation batches (eval batch = {})",
                self.info.eval_batch
            ));
        }
        Ok((loss_sum / total, correct / total))
    }

    /// Test the composed global model at full width (paper metric ①).
    pub fn evaluate_composed(&self, global: &ComposedGlobal) -> Result<(f64, f64)> {
        let params = global.full_inputs(&self.info);
        self.evaluate_param_list(&Manifest::eval_name(&self.cfg.family, true), &params)
    }

    /// Test the dense global model at full width.
    pub fn evaluate_dense(&self, global: &DenseGlobal) -> Result<(f64, f64)> {
        let mut params = global.weights.clone();
        params.push(global.bias.clone());
        self.evaluate_param_list(&Manifest::eval_name(&self.cfg.family, false), &params)
    }
}

#[cfg(test)]
mod tests {
    // In-module so the tests can graft ragged test sets onto the private
    // `test` field; PJRT execution still needs artifacts, so each test
    // skips gracefully without them.
    use super::*;
    use crate::config::Scale;

    fn pool_or_skip() -> Option<EnginePool> {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(EnginePool::single(Manifest::load(&dir).unwrap()).unwrap())
    }

    #[test]
    fn image_eval_skips_ragged_tail_batches() {
        // regression: the image branch only debug_assert!ed exact tiling;
        // in release builds a wrap-padded partial batch entered the sums
        // and silently mis-scaled loss/accuracy
        let Some(pool) = pool_or_skip() else { return };
        let mut cfg = ExperimentConfig::preset("cnn", Scale::Smoke);
        cfg.n_clients = 4;
        cfg.k_per_round = 2;
        cfg.samples_per_client = 16;
        cfg.test_samples = 64;
        let mut env = FlEnv::build(&pool, cfg).unwrap();
        let global = ComposedGlobal::init(&env.info, &mut Rng::new(7)).unwrap();
        let baseline = env.evaluate_composed(&global).unwrap();

        // graft half an eval batch of duplicated samples onto the set
        let TestData::Image(set) = &env.test else { panic!("cnn env must hold image test data") };
        let mut bigger = (**set).clone();
        let extra = env.info.eval_batch / 2;
        assert!(extra > 0, "eval batch too small to form a ragged tail");
        let ss = bigger.sample_size();
        for i in 0..extra {
            let row = bigger.pixels[i * ss..(i + 1) * ss].to_vec();
            bigger.pixels.extend_from_slice(&row);
            let label = bigger.labels[i];
            bigger.labels.push(label);
        }
        env.test = TestData::Image(Arc::new(bigger));
        let ragged = env.evaluate_composed(&global).unwrap();
        assert_eq!(ragged, baseline, "a partial eval batch must not change image metrics");
    }

    #[test]
    fn text_eval_skips_ragged_tail_batches() {
        // the text branch's skip, pinned the same way: dropping the
        // partial tail batch means a stream truncated to exactly the full
        // batches evaluates identically
        let Some(pool) = pool_or_skip() else { return };
        let mut cfg = ExperimentConfig::preset("rnn", Scale::Smoke);
        cfg.n_clients = 4;
        cfg.k_per_round = 2;
        cfg.samples_per_client = 16;
        cfg.shard_tokens = 800;
        cfg.test_samples = 50;
        let mut env = FlEnv::build(&pool, cfg).unwrap();
        let global = ComposedGlobal::init(&env.info, &mut Rng::new(7)).unwrap();
        let InputInfo::Text { seq_len, .. } = env.info.input else {
            panic!("rnn env must hold text data")
        };
        let stride = seq_len + 1;
        let batch = env.info.eval_batch;

        let TestData::Text(set) = &env.test else { panic!("rnn env must hold text test data") };
        let windows = set.test.len() / stride;
        let full = (windows / batch) * batch;
        assert!(windows > full, "need a partial tail batch: {windows} windows, batch {batch}");
        let mut exact = (**set).clone();
        exact.test.truncate(full * stride);

        let with_tail = env.evaluate_composed(&global).unwrap();
        env.test = TestData::Text(Arc::new(exact));
        let without_tail = env.evaluate_composed(&global).unwrap();
        assert_eq!(with_tail, without_tail, "a partial eval batch must not change text metrics");
    }
}
