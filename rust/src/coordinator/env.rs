//! Shared federated environment: datasets + partitions + device fleet +
//! WAN model + virtual clock + traffic meter + global evaluation.
//!
//! Every scheme (Heroes and the four baselines) runs against the same
//! `FlEnv`, so comparisons in the experiment figures differ only by the
//! scheme logic, exactly like the paper's testbed (§VI-C).

use crate::config::{ExperimentConfig, Partition};
use crate::coordinator::assignment::ClientStatus;
use crate::coordinator::XData;
use crate::data::loader::{EvalBatches, ImageLoader, TextEvalBatches, TextLoader};
use crate::data::partition::{gamma_partition, phi_partition};
use crate::data::synth_image::ImageGen;
use crate::data::synth_text::TextGen;
use crate::data::{ImageSet, TextSet};
use crate::model::{ComposedGlobal, DenseGlobal};
use crate::runtime::{Engine, InputInfo, Manifest, ModelInfo, Value};
use crate::simulation::{DeviceFleet, NetworkModel, TrafficMeter, VirtualClock};
use crate::tensor::{IntTensor, Tensor};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::sync::Arc;

enum ClientLoader {
    Image(ImageLoader),
    Text(TextLoader),
}

enum TestData {
    Image(Arc<ImageSet>),
    Text(Arc<TextSet>),
}

/// The common federated world for one experiment run.
pub struct FlEnv<'e> {
    pub engine: &'e Engine,
    pub info: ModelInfo,
    pub cfg: ExperimentConfig,
    pub fleet: DeviceFleet,
    pub clock: VirtualClock,
    pub traffic: TrafficMeter,
    network: NetworkModel,
    loaders: Vec<ClientLoader>,
    test: TestData,
    rng: Rng,
}

impl<'e> FlEnv<'e> {
    /// Build the world: synthesize data, partition it per the config,
    /// draw the device fleet. Deterministic in `cfg.seed`.
    pub fn build(engine: &'e Engine, cfg: ExperimentConfig) -> Result<FlEnv<'e>> {
        cfg.validate()?;
        let info = engine.manifest().model(&cfg.family)?.clone();
        let mut rng = Rng::new(cfg.seed);
        let mut data_rng = rng.fork(1);
        let mut fleet_rng = rng.fork(2);

        let (loaders, test) = match &info.input {
            InputInfo::Image { .. } => {
                let gen = if cfg.family == "resnet" {
                    ImageGen::imagenet_twin()
                } else {
                    ImageGen::cifar_twin()
                };
                let n_train = cfg.n_clients * cfg.samples_per_client;
                // test size must tile the eval batch exactly (exact metrics)
                let n_test = (cfg.test_samples / info.eval_batch).max(1) * info.eval_batch;
                let train = Arc::new(gen.generate(n_train, cfg.seed ^ 0xDA7A, &mut data_rng));
                let test = Arc::new(gen.generate(n_test, cfg.seed ^ 0xDA7A, &mut data_rng));
                let labels = &train.labels;
                let parts = match cfg.partition {
                    Partition::Gamma(g) => gamma_partition(
                        labels, info.classes, cfg.n_clients, cfg.samples_per_client, g, &mut data_rng,
                    ),
                    Partition::Phi(frac) => {
                        let missing = ((info.classes as f64) * frac).round() as usize;
                        phi_partition(
                            labels, info.classes, cfg.n_clients, cfg.samples_per_client,
                            missing.min(info.classes - 1), &mut data_rng,
                        )
                    }
                    Partition::Natural => {
                        return Err(anyhow!("natural partition is text-only"));
                    }
                };
                let loaders = parts
                    .into_iter()
                    .enumerate()
                    .map(|(i, idxs)| {
                        ClientLoader::Image(ImageLoader::new(
                            train.clone(), idxs, info.batch, data_rng.fork(100 + i as u64),
                        ))
                    })
                    .collect();
                (loaders, TestData::Image(test))
            }
            InputInfo::Text { seq_len, .. } => {
                let gen = TextGen::shakespeare_twin();
                let test_tokens = 4_000.max(cfg.test_samples * (seq_len + 1));
                let set = Arc::new(gen.generate(
                    cfg.n_clients, cfg.shard_tokens, test_tokens, cfg.seed ^ 0x7E47,
                ));
                let seq = *seq_len;
                let loaders = (0..cfg.n_clients)
                    .map(|i| {
                        ClientLoader::Text(TextLoader::new(
                            Arc::new(set.shards[i].clone()), info.batch, seq,
                            data_rng.fork(200 + i as u64),
                        ))
                    })
                    .collect();
                (loaders, TestData::Text(set))
            }
        };

        let fleet = DeviceFleet::default_fleet(cfg.n_clients, &mut fleet_rng);
        let network = NetworkModel {
            up_lo_mbps: cfg.up_mbps.0,
            up_hi_mbps: cfg.up_mbps.1,
            down_lo_mbps: cfg.down_mbps.0,
            down_hi_mbps: cfg.down_mbps.1,
        };
        Ok(FlEnv {
            engine,
            info,
            cfg,
            fleet,
            clock: VirtualClock::new(),
            traffic: TrafficMeter::new(),
            network,
            loaders,
            test,
            rng: rng.fork(3),
        })
    }

    /// Randomly sample K participants (paper Alg. 1 line 5).
    pub fn sample_clients(&mut self) -> Vec<usize> {
        self.rng.sample_distinct(self.cfg.n_clients, self.cfg.k_per_round)
    }

    /// Collect a client's round status (Alg. 1 line 4).
    pub fn status(&mut self, client: usize) -> ClientStatus {
        let q = self.fleet.devices[client].sample_flops();
        let link = self.network.sample(&mut self.rng);
        ClientStatus { client, q_flops: q, link }
    }

    /// Next training batch for a client.
    pub fn next_batch(&mut self, client: usize) -> (XData, IntTensor) {
        match &mut self.loaders[client] {
            ClientLoader::Image(l) => {
                let b = l.next_batch();
                (XData::Image(b.x), b.y)
            }
            ClientLoader::Text(l) => {
                let b = l.next_batch();
                (XData::Tokens(b.x), b.y)
            }
        }
    }

    /// Evaluate a parameter list with the given eval executable over the
    /// full test split; returns (mean loss, accuracy).
    pub fn evaluate_param_list(&self, exec: &str, params: &[Tensor]) -> Result<(f64, f64)> {
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut total = 0.0f64;
        match &self.test {
            TestData::Image(set) => {
                for (batch, real) in EvalBatches::new(set, self.info.eval_batch) {
                    debug_assert_eq!(real, self.info.eval_batch, "test set must tile eval batches");
                    let mut inputs: Vec<Value> = params.iter().map(Value::F32).collect();
                    inputs.push(Value::F32(&batch.x));
                    inputs.push(Value::I32(&batch.y));
                    let out = self.engine.execute(exec, &inputs)?;
                    loss_sum += out[0].data()[0] as f64;
                    correct += out[1].data()[0] as f64;
                    total += real as f64;
                }
            }
            TestData::Text(set) => {
                let InputInfo::Text { seq_len, .. } = self.info.input else {
                    return Err(anyhow!("text eval on non-text family"));
                };
                for (batch, real) in TextEvalBatches::new(set, self.info.eval_batch, seq_len) {
                    if real < self.info.eval_batch {
                        break; // drop the ragged tail: exact full batches only
                    }
                    let mut inputs: Vec<Value> = params.iter().map(Value::F32).collect();
                    inputs.push(Value::I32(&batch.x));
                    inputs.push(Value::I32(&batch.y));
                    let out = self.engine.execute(exec, &inputs)?;
                    loss_sum += out[0].data()[0] as f64;
                    correct += out[1].data()[0] as f64;
                    total += (real * seq_len) as f64;
                }
            }
        }
        if total == 0.0 {
            return Err(anyhow!("empty test set"));
        }
        Ok((loss_sum / total, correct / total))
    }

    /// Test the composed global model at full width (paper metric ①).
    pub fn evaluate_composed(&self, global: &ComposedGlobal) -> Result<(f64, f64)> {
        let params = global.full_inputs(&self.info);
        self.evaluate_param_list(&Manifest::eval_name(&self.cfg.family, true), &params)
    }

    /// Test the dense global model at full width.
    pub fn evaluate_dense(&self, global: &DenseGlobal) -> Result<(f64, f64)> {
        let mut params = global.weights.clone();
        params.push(global.bias.clone());
        self.evaluate_param_list(&Manifest::eval_name(&self.cfg.family, false), &params)
    }
}
