//! Shared federated environment: datasets + partitions + device fleet +
//! WAN model + virtual clock + traffic meter + global evaluation.
//!
//! Every scheme (Heroes and the four baselines) runs against the same
//! `FlEnv`, so comparisons in the experiment figures differ only by the
//! scheme logic, exactly like the paper's testbed (§VI-C).
//!
//! Training data is handed out as **owned** [`BatchStream`]s — one per
//! `(client, round)`, seeded deterministically from
//! `(cfg.seed, client, round)` — so worker threads of the parallel round
//! driver (`coordinator::round`) pull batches without aliasing the env.
//! Evaluation, the virtual clock and the traffic meter stay on the
//! coordinator thread.

use crate::config::{ExperimentConfig, Partition};
use crate::coordinator::assignment::ClientStatus;
use crate::coordinator::XData;
use crate::data::loader::{EvalBatches, ImageLoader, TextEvalBatches, TextLoader};
use crate::data::partition::{gamma_partition, phi_partition};
use crate::data::synth_image::ImageGen;
use crate::data::synth_text::TextGen;
use crate::data::{ImageSet, TextSet};
use crate::model::{ComposedGlobal, DenseGlobal};
use crate::runtime::{Engine, InputInfo, Manifest, ModelInfo, Value};
use crate::simulation::{DeviceFleet, NetworkModel, TrafficMeter, VirtualClock};
use crate::tensor::{IntTensor, Tensor};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Shared training data + per-client partitions; `batch_stream` stamps
/// out owned loaders over it on demand.
enum TrainData {
    Image {
        set: Arc<ImageSet>,
        /// per-client sample indices into `set` (cloned into each stream,
        /// which shuffles its own copy)
        parts: Vec<Vec<usize>>,
    },
    Text {
        /// per-client token streams
        shards: Vec<Arc<Vec<i32>>>,
        seq_len: usize,
    },
}

enum TestData {
    Image(Arc<ImageSet>),
    Text(Arc<TextSet>),
}

/// An owned, self-contained batch source for one client's local round.
///
/// The stream holds `Arc`s of the shared dataset plus its own cursor and
/// RNG, so a worker thread can draw batches with no access to `FlEnv`.
/// Streams for the same `(seed, client, round)` yield identical batch
/// sequences — the determinism contract of `coordinator::round` rests on
/// this.
pub enum BatchStream {
    Image(ImageLoader),
    Text(TextLoader),
}

impl BatchStream {
    /// Next training batch (paper: ξ ~ D_n).
    pub fn next_batch(&mut self) -> (XData, IntTensor) {
        match self {
            BatchStream::Image(l) => {
                let b = l.next_batch();
                (XData::Image(b.x), b.y)
            }
            BatchStream::Text(l) => {
                let b = l.next_batch();
                (XData::Tokens(b.x), b.y)
            }
        }
    }
}

/// The common federated world for one experiment run.
pub struct FlEnv<'e> {
    pub engine: &'e Engine,
    pub info: ModelInfo,
    pub cfg: ExperimentConfig,
    pub fleet: DeviceFleet,
    pub clock: VirtualClock,
    pub traffic: TrafficMeter,
    network: NetworkModel,
    train: TrainData,
    test: TestData,
    rng: Rng,
}

impl<'e> FlEnv<'e> {
    /// Build the world: synthesize data, partition it per the config,
    /// draw the device fleet. Deterministic in `cfg.seed`.
    pub fn build(engine: &'e Engine, cfg: ExperimentConfig) -> Result<FlEnv<'e>> {
        cfg.validate()?;
        let info = engine.manifest().model(&cfg.family)?.clone();
        let mut rng = Rng::new(cfg.seed);
        let mut data_rng = rng.fork(1);
        let mut fleet_rng = rng.fork(2);

        let (train, test) = match &info.input {
            InputInfo::Image { .. } => {
                let gen = if cfg.family == "resnet" {
                    ImageGen::imagenet_twin()
                } else {
                    ImageGen::cifar_twin()
                };
                let n_train = cfg.n_clients * cfg.samples_per_client;
                // test size must tile the eval batch exactly (exact metrics)
                let n_test = (cfg.test_samples / info.eval_batch).max(1) * info.eval_batch;
                let train = Arc::new(gen.generate(n_train, cfg.seed ^ 0xDA7A, &mut data_rng));
                let test = Arc::new(gen.generate(n_test, cfg.seed ^ 0xDA7A, &mut data_rng));
                let labels = &train.labels;
                let parts = match cfg.partition {
                    Partition::Gamma(g) => gamma_partition(
                        labels, info.classes, cfg.n_clients, cfg.samples_per_client, g, &mut data_rng,
                    ),
                    Partition::Phi(frac) => {
                        let missing = ((info.classes as f64) * frac).round() as usize;
                        phi_partition(
                            labels, info.classes, cfg.n_clients, cfg.samples_per_client,
                            missing.min(info.classes - 1), &mut data_rng,
                        )
                    }
                    Partition::Natural => {
                        return Err(anyhow!("natural partition is text-only"));
                    }
                };
                (TrainData::Image { set: train, parts }, TestData::Image(test))
            }
            InputInfo::Text { seq_len, .. } => {
                let gen = TextGen::shakespeare_twin();
                let test_tokens = 4_000.max(cfg.test_samples * (seq_len + 1));
                let set = Arc::new(gen.generate(
                    cfg.n_clients, cfg.shard_tokens, test_tokens, cfg.seed ^ 0x7E47,
                ));
                let shards = set.shards.iter().cloned().map(Arc::new).collect();
                (TrainData::Text { shards, seq_len: *seq_len }, TestData::Text(set))
            }
        };

        let fleet = DeviceFleet::default_fleet(cfg.n_clients, &mut fleet_rng);
        let network = NetworkModel {
            up_lo_mbps: cfg.up_mbps.0,
            up_hi_mbps: cfg.up_mbps.1,
            down_lo_mbps: cfg.down_mbps.0,
            down_hi_mbps: cfg.down_mbps.1,
        };
        Ok(FlEnv {
            engine,
            info,
            cfg,
            fleet,
            clock: VirtualClock::new(),
            traffic: TrafficMeter::new(),
            network,
            train,
            test,
            rng: rng.fork(3),
        })
    }

    /// Randomly sample K participants (paper Alg. 1 line 5).
    pub fn sample_clients(&mut self) -> Vec<usize> {
        self.rng.sample_distinct(self.cfg.n_clients, self.cfg.k_per_round)
    }

    /// Collect a client's round status (Alg. 1 line 4).
    pub fn status(&mut self, client: usize) -> ClientStatus {
        let q = self.fleet.devices[client].sample_flops();
        let link = self.network.sample(&mut self.rng);
        ClientStatus { client, q_flops: q, link }
    }

    /// Owned batch stream for one client's local round. Deterministic in
    /// `(cfg.seed, client, round)` and independent of every other stream,
    /// so the round driver may run it on any worker thread.
    pub fn batch_stream(&self, client: usize, round: usize) -> BatchStream {
        // mix (seed, client, round) injectively enough for SplitMix64's
        // whitening; the +1s keep client 0 / round 0 off the raw seed
        let seed = self
            .cfg
            .seed
            .wrapping_add((client as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add((round as u64 + 1).wrapping_mul(0xD1B5_4A32_D192_ED03));
        let rng = Rng::new(seed);
        match &self.train {
            TrainData::Image { set, parts } => BatchStream::Image(ImageLoader::new(
                set.clone(),
                parts[client].clone(),
                self.info.batch,
                rng,
            )),
            TrainData::Text { shards, seq_len } => BatchStream::Text(TextLoader::new(
                shards[client].clone(),
                self.info.batch,
                *seq_len,
                rng,
            )),
        }
    }

    /// Evaluate a parameter list with the given eval executable over the
    /// full test split; returns (mean loss, accuracy).
    pub fn evaluate_param_list(&self, exec: &str, params: &[Tensor]) -> Result<(f64, f64)> {
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut total = 0.0f64;
        match &self.test {
            TestData::Image(set) => {
                for (batch, real) in EvalBatches::new(set, self.info.eval_batch) {
                    debug_assert_eq!(real, self.info.eval_batch, "test set must tile eval batches");
                    let mut inputs: Vec<Value> = params.iter().map(Value::F32).collect();
                    inputs.push(Value::F32(&batch.x));
                    inputs.push(Value::I32(&batch.y));
                    let out = self.engine.execute(exec, &inputs)?;
                    loss_sum += out[0].data()[0] as f64;
                    correct += out[1].data()[0] as f64;
                    total += real as f64;
                }
            }
            TestData::Text(set) => {
                let InputInfo::Text { seq_len, .. } = self.info.input else {
                    return Err(anyhow!("text eval on non-text family"));
                };
                for (batch, real) in TextEvalBatches::new(set, self.info.eval_batch, seq_len) {
                    if real < self.info.eval_batch {
                        break; // drop the ragged tail: exact full batches only
                    }
                    let mut inputs: Vec<Value> = params.iter().map(Value::F32).collect();
                    inputs.push(Value::I32(&batch.x));
                    inputs.push(Value::I32(&batch.y));
                    let out = self.engine.execute(exec, &inputs)?;
                    loss_sum += out[0].data()[0] as f64;
                    correct += out[1].data()[0] as f64;
                    total += (real * seq_len) as f64;
                }
            }
        }
        if total == 0.0 {
            return Err(anyhow!("empty test set"));
        }
        Ok((loss_sum / total, correct / total))
    }

    /// Test the composed global model at full width (paper metric ①).
    pub fn evaluate_composed(&self, global: &ComposedGlobal) -> Result<(f64, f64)> {
        let params = global.full_inputs(&self.info);
        self.evaluate_param_list(&Manifest::eval_name(&self.cfg.family, true), &params)
    }

    /// Test the dense global model at full width.
    pub fn evaluate_dense(&self, global: &DenseGlobal) -> Result<(f64, f64)> {
        let mut params = global.weights.clone();
        params.push(global.bias.clone());
        self.evaluate_param_list(&Manifest::eval_name(&self.cfg.family, false), &params)
    }
}
