//! Client-side local training (paper Alg. 2), executed through the AOT
//! train/probe executables.
//!
//! The client receives `[v, û, ..., bias]` (already composed-ready), runs
//! `τ` SGD iterations via the width-specific `train` executable, and —
//! when probing is requested — estimates `L, σ², G²` from three probe
//! gradients (see `estimator`). The updated factors go back to the PS;
//! nothing here ever touches python.

use crate::coordinator::estimator::{estimate_from_probes, ClientEstimates};
use crate::coordinator::XData;
use crate::runtime::{Engine, Value};
use crate::tensor::{IntTensor, Tensor};
use anyhow::{anyhow, Result};

/// Outcome of one client's local round.
#[derive(Debug, Clone)]
pub struct LocalResult {
    /// updated parameter list, same layout as the payload
    pub params: Vec<Tensor>,
    /// mean training loss over the τ iterations
    pub mean_loss: f64,
    /// loss at the final iteration
    pub final_loss: f64,
    /// mean ||∇||² reported by the train executable
    pub mean_grad_sq: f64,
    /// probe-based estimates (None when probing was skipped)
    pub estimates: Option<ClientEstimates>,
}

fn push_batch<'a>(inputs: &mut Vec<Value<'a>>, x: &'a XData, y: &'a IntTensor) {
    match x {
        XData::Image(t) => inputs.push(Value::F32(t)),
        XData::Tokens(t) => inputs.push(Value::I32(t)),
    }
    inputs.push(Value::I32(y));
}

fn run_probe(
    engine: &Engine,
    probe_exec: &str,
    params: &[Tensor],
    x: &XData,
    y: &IntTensor,
) -> Result<Tensor> {
    let mut inputs: Vec<Value> = params.iter().map(Value::F32).collect();
    push_batch(&mut inputs, x, y);
    let mut out = engine.execute(probe_exec, &inputs)?;
    out.pop().ok_or_else(|| anyhow!("probe returned nothing"))
}

/// Run `τ` local iterations (+ optional estimation probes).
///
/// `next_batch` yields a fresh mini-batch per call (paper: ξ ~ D_n).
///
/// Divergence guard: if a step produces a non-finite loss the client
/// restarts from the received payload at lr/4; if that also diverges it
/// uploads the payload unchanged (a skipped update). Schemes whose
/// dynamics blow up (e.g. original-NC's cross-width basis/coefficient
/// drift at high lr) thus lose progress instead of crashing the run —
/// matching how a real deployment would clamp a bad client round.
pub fn run_local(
    engine: &Engine,
    train_exec: &str,
    probe_exec: Option<&str>,
    payload: Vec<Tensor>,
    tau: usize,
    lr: f32,
    mut next_batch: impl FnMut() -> (XData, IntTensor),
) -> Result<LocalResult> {
    if tau == 0 {
        // τ comes from the planner's frequency assignment — a zero here
        // is a controller bug, surfaced as a typed error (the loop below
        // would otherwise silently upload the payload unchanged)
        return Err(anyhow!("{train_exec}: tau must be at least 1"));
    }
    let n_params = payload.len();

    // Estimation probes need a fixed batch ξ₁ reused at start and end
    // (Alg. 2 l.7) plus an independent ξ₂ (l.8-9).
    let probe_ctx = if let Some(pe) = probe_exec {
        let (x1, y1) = next_batch();
        let (x2, y2) = next_batch();
        let g_start = run_probe(engine, pe, &payload, &x1, &y1)?;
        let g_alt = run_probe(engine, pe, &payload, &x2, &y2)?;
        Some((pe, x1, y1, g_start, g_alt, payload.clone()))
    } else {
        None
    };

    let mut attempt_lr = lr;
    let mut params = payload.clone();
    let mut loss_sum = 0.0f64;
    let mut gsq_sum = 0.0f64;
    let mut final_loss = f64::NAN;
    'attempts: for attempt in 0..2 {
        let lr_t = Tensor::from_vec(&[1], vec![attempt_lr]);
        params = payload.clone();
        loss_sum = 0.0;
        gsq_sum = 0.0;
        for _ in 0..tau {
            let (x, y) = next_batch();
            let mut inputs: Vec<Value> = params.iter().map(Value::F32).collect();
            push_batch(&mut inputs, &x, &y);
            inputs.push(Value::F32(&lr_t));
            let mut out = engine.execute(train_exec, &inputs)?;
            if out.len() != n_params + 2 {
                return Err(anyhow!(
                    "{train_exec}: expected {} outputs, got {}",
                    n_params + 2,
                    out.len()
                ));
            }
            // the arity check above guarantees the two scalar tails, but
            // their *shapes* come from the compiled artifact — typed Err
            let scalar = |t: Option<Tensor>, what: &str| -> Result<f64> {
                t.as_ref()
                    .and_then(|t| t.data().first())
                    .map(|&v| f64::from(v))
                    .ok_or_else(|| anyhow!("{train_exec}: {what} output is not a scalar"))
            };
            let gsq = scalar(out.pop(), "grad-norm")?;
            let loss = scalar(out.pop(), "loss")?;
            if !loss.is_finite() {
                if attempt == 0 {
                    log::debug!("{train_exec}: non-finite loss, retrying at lr/4");
                    attempt_lr = lr * 0.25;
                    continue 'attempts;
                }
                // second divergence: skip the update entirely
                log::debug!("{train_exec}: diverged twice, skipping update");
                params = payload.clone();
                loss_sum = f64::NAN;
                break;
            }
            loss_sum += loss;
            gsq_sum += gsq;
            final_loss = loss;
            params = out;
        }
        break;
    }
    let loss_sum = if loss_sum.is_finite() { loss_sum } else { final_loss.max(0.0) * tau as f64 };

    let estimates = if let Some((pe, x1, y1, g_start, g_alt, theta0)) = probe_ctx {
        let g_end = run_probe(engine, pe, &params, &x1, &y1)?;
        let dist_sq: f64 = params.iter().zip(&theta0).map(|(a, b)| a.sq_dist(b)).sum();
        Some(estimate_from_probes(&g_start, &g_alt, &g_end, dist_sq))
    } else {
        None
    };

    Ok(LocalResult {
        params,
        mean_loss: loss_sum / tau as f64,
        final_loss,
        mean_grad_sq: gsq_sum / tau as f64,
        estimates,
    })
}
