//! Adaptive quorum control — closing the Alg. 1 loop over K and α.
//!
//! PR 3's semi-async mode made `--quorum K` and `--staleness-alpha α`
//! static operator knobs. The paper's whole point (Alg. 1 / Eq. 23) is
//! that the coordinator *adapts* its per-round decisions to the observed
//! heterogeneity, so the [`QuorumController`] turns both knobs into
//! per-round controller outputs:
//!
//! * **K** — each round, pick the **smallest** quorum whose projected
//!   staleness penalty (`frequency::projected_staleness_loss`, derived
//!   from the plan's virtual completion times) fits inside the staleness
//!   budget — the slice of the Eq. 23 margin `ε − 6L²β²` the operator
//!   grants to semi-asynchrony (`frequency::staleness_budget`,
//!   `--quorum-margin`). The observed losses already on the books
//!   ([`BlockLedger::staleness_index`]) and the ledger's count-spread
//!   pressure consume the budget first, so **K grows as the staleness
//!   index rises**; a widening projected-completion spread (straggler
//!   tail) makes small K save more round time and admits it as soon as
//!   the budget allows, so **K shrinks as the tail widens**.
//! * **α** — annealed against the observed per-block staleness losses:
//!   while the staleness index sits below half the budget the discount
//!   sharpens toward `alpha_max` (late noise is cheap to suppress);
//!   once losses bite it relaxes toward `alpha_min`, recovering the
//!   stragglers' training signal instead of throwing it away.
//!
//! Every input is **virtual-clock state** — projected completion times
//! are plan facts, the staleness index and β² proxy are deterministic
//! ledger state — so adaptive runs stay seed-deterministic for any
//! `--workers`/`--pool` (pinned in `tests/integration_parallel.rs`).
//! A cohort with no straggler tail (relative completion spread below
//! [`QuorumCtlCfg::spread_min`]) provably collapses to `K = N`, which
//! `RoundDriver::run_quorum` routes through the synchronous phase-C
//! hook — byte-identical to the full-barrier run.
//!
//! [`BlockLedger::staleness_index`]: crate::coordinator::ledger::BlockLedger::staleness_index

use crate::coordinator::frequency::{projected_staleness_loss, staleness_budget};
use crate::coordinator::round::QuorumCfg;

/// Observed signals the controller reads each round, all deterministic
/// functions of virtual-clock state. Schemes without a ledger report the
/// default (no staleness, no imbalance, unit smoothness): for them the
/// controller budget is purely the ε-margin slice.
#[derive(Debug, Clone, Copy)]
pub struct QuorumSignals {
    /// fraction of recorded training lost to staleness discounts
    /// (`BlockLedger::staleness_index`)
    pub staleness_index: f64,
    /// observed β² proxy (`BlockLedger::relative_variance`)
    pub beta_sq: f64,
    /// current smoothness estimate L (Eq. 23)
    pub l: f64,
    /// dimensionless planned-count spread (`BlockLedger::spread_index`):
    /// the straggler tail's footprint in the training books
    pub spread_index: f64,
    /// observed mid-round dropout rate (scenario churn). Injected by the
    /// round driver from the virtual schedule's dispatch facts
    /// (`FlEnv::observed_dropout_rate`) — schemes always report 0 here.
    /// A dropped straggler's training is lost outright, so churn
    /// consumes the staleness budget like realized losses: **K grows
    /// toward the full barrier as the dropout rate rises** (monotone,
    /// property-tested), keeping more of the surviving cohort's signal
    /// in the synchronous merge instead of relegating it to straggler
    /// slots that may vanish.
    pub dropout_rate: f64,
    /// observed engine-fault rate (`--faults`; injected by the round
    /// driver from `FlEnv::observed_fault_rate` — schemes always report
    /// 0 here). An unrecovered fault loses its update exactly like a
    /// dropout, and a recovered one stretched the straggler tail, so
    /// fault pressure consumes the staleness budget the same way churn
    /// does: **K grows toward the full barrier as the fault rate rises**
    /// (monotone, property-tested in `tests/prop_faults.rs`).
    pub fault_rate: f64,
}

impl Default for QuorumSignals {
    fn default() -> QuorumSignals {
        QuorumSignals {
            staleness_index: 0.0,
            beta_sq: 0.0,
            l: 1.0,
            spread_index: 0.0,
            dropout_rate: 0.0,
            fault_rate: 0.0,
        }
    }
}

/// Controller knobs (`--quorum auto`, `--quorum-margin`, `--quorum-floor`).
#[derive(Debug, Clone, Copy)]
pub struct QuorumCtlCfg {
    /// hard floor for the chosen K (`--quorum-floor`); clamped to the
    /// cohort size per round
    pub k_min: usize,
    /// fraction of the Eq. 23 margin `ε − 6L²β²` the projected staleness
    /// penalty may consume (`--quorum-margin`)
    pub margin_frac: f64,
    /// minimum relative round-time saving `(t_N − t_K)/t_N` before going
    /// semi-async is worth anything: below it the controller returns
    /// K = N, which is what collapses homogeneous cohorts to the
    /// full-barrier path
    pub spread_min: f64,
    /// convergence target ε (Eq. 23)
    pub epsilon: f64,
    /// α annealing range and step; `alpha_gain = 0` freezes α
    pub alpha_min: f64,
    pub alpha_max: f64,
    pub alpha_gain: f64,
}

impl QuorumCtlCfg {
    /// Knobs from the experiment surface: ε and the two CLI knobs, with
    /// the annealing defaults (α starts at and is capped by the
    /// configured `--staleness-alpha`).
    pub fn new(epsilon: f64, k_min: usize, margin_frac: f64, alpha_max: f64) -> QuorumCtlCfg {
        QuorumCtlCfg {
            k_min: k_min.max(1),
            margin_frac,
            spread_min: 0.05,
            epsilon,
            alpha_min: 0.0,
            alpha_max: alpha_max.max(0.0),
            alpha_gain: 0.25,
        }
    }
}

/// One round's controller output.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuorumDecision {
    /// quorum size, in `[k_min.clamp(1, n), n]`
    pub k: usize,
    /// α for this round's late merges
    pub alpha: f64,
}

/// The per-run adaptive controller (module docs). One instance lives for
/// one `RoundDriver::run_quorum` pipeline; its only mutable state is the
/// annealed α.
#[derive(Debug, Clone)]
pub struct QuorumController {
    cfg: QuorumCtlCfg,
    alpha: f64,
}

impl QuorumController {
    pub fn new(cfg: QuorumCtlCfg) -> QuorumController {
        let alpha = cfg.alpha_max.max(cfg.alpha_min);
        QuorumController { cfg, alpha }
    }

    /// The current annealed α (for post-run inspection / logging).
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Decide this round's (K, α) from the plan's projected completion
    /// times and the observed signals. Pure virtual-clock state in,
    /// deterministic decision out.
    ///
    /// Invariants (property-tested in `tests/prop_coordinator.rs`):
    /// K ∈ `[k_min.clamp(1, n), n]`; at fixed α, K is monotone
    /// non-decreasing in the observed staleness index; a spread-free
    /// cohort (all completions within `spread_min` of the maximum)
    /// always yields K = n.
    #[allow(clippy::indexing_slicing)]
    // hlint::allow(panic_path, item): `sorted` has `n = completions.len()` entries (the empty case returns early) and every candidate index stays in `k_min.clamp(1, n)..n`
    pub fn decide(&mut self, completions: &[f64], sig: &QuorumSignals) -> QuorumDecision {
        let n = completions.len().max(1);
        let budget = staleness_budget(self.cfg.epsilon, sig.l, sig.beta_sq, self.cfg.margin_frac);

        // anneal α against the observed per-block staleness losses
        let target = 0.5 * budget;
        let toward =
            if sig.staleness_index <= target { self.cfg.alpha_max } else { self.cfg.alpha_min };
        self.alpha = (self.alpha + self.cfg.alpha_gain * (toward - self.alpha))
            .clamp(self.cfg.alpha_min, self.cfg.alpha_max.max(self.cfg.alpha_min));

        // observed losses, the count-spread pressure and the observed
        // churn/fault rates consume the budget before any *new*
        // staleness is admitted — this is what grows K back toward N
        // when the staleness index (or the dropout/fault rate: lost
        // updates are realized losses too) rises
        let budget_left = (budget / (1.0 + sig.spread_index.max(0.0))
            - sig.staleness_index.max(0.0)
            - sig.dropout_rate.max(0.0)
            - sig.fault_rate.max(0.0))
        .max(0.0);

        if completions.is_empty() {
            // empty cohorts are rejected upstream; stay total anyway
            return QuorumDecision { k: 1, alpha: self.alpha };
        }
        let mut sorted: Vec<f64> = completions.to_vec();
        sorted.sort_by(f64::total_cmp);
        let t_full = sorted[n - 1];
        let k_min = self.cfg.k_min.clamp(1, n);

        let mut k = n;
        if t_full > 0.0 {
            for cand in k_min..n {
                let saving = (t_full - sorted[cand - 1]) / t_full;
                if saving < self.cfg.spread_min {
                    // savings only shrink as cand grows (sorted): no
                    // larger candidate can pass either — full barrier
                    break;
                }
                if projected_staleness_loss(&sorted, cand, self.alpha) <= budget_left {
                    k = cand;
                    break;
                }
            }
        }
        QuorumDecision { k, alpha: self.alpha }
    }
}

/// Per-round quorum decision source for `RoundDriver::run_quorum`:
/// PR 3's static knobs or the adaptive controller (`--quorum auto`).
#[derive(Debug, Clone)]
pub enum QuorumPolicy {
    /// fixed K and α every round (`--quorum K`); K = 0 or ≥ the cohort
    /// size means full barrier, exactly as before
    Static(QuorumCfg),
    Auto(QuorumController),
}

impl QuorumPolicy {
    /// The static policy (`--quorum K --staleness-alpha α`).
    pub fn fixed(quorum: usize, alpha: f64) -> QuorumPolicy {
        QuorumPolicy::Static(QuorumCfg { quorum, alpha })
    }

    /// The quorum size a static policy *demands* (`None` for the
    /// adaptive controller and the full-barrier 0, which both scale to
    /// whatever survives). The round driver uses this to surface churn
    /// that makes an explicit `--quorum K` unsatisfiable as a typed
    /// `ScenarioError::QuorumInfeasible` instead of silently degrading.
    pub fn required_quorum(&self) -> Option<usize> {
        match self {
            QuorumPolicy::Static(cfg) if cfg.quorum > 0 => Some(cfg.quorum),
            _ => None,
        }
    }

    /// The policy an experiment config asks for, or `None` when quorum
    /// mode is off (synchronous rounds).
    pub fn from_config(cfg: &crate::config::ExperimentConfig) -> Option<QuorumPolicy> {
        match cfg.quorum {
            crate::config::QuorumKnob::Off => None,
            crate::config::QuorumKnob::Fixed(k) => {
                Some(QuorumPolicy::fixed(k, cfg.staleness_alpha))
            }
            crate::config::QuorumKnob::Auto => {
                Some(QuorumPolicy::Auto(QuorumController::new(QuorumCtlCfg::new(
                    cfg.epsilon,
                    cfg.quorum_floor,
                    cfg.quorum_margin,
                    cfg.staleness_alpha,
                ))))
            }
        }
    }

    /// This round's (K, α). `completions` are the round's projected
    /// completion times (plan facts); `sig` the scheme's observed
    /// signals. K is always clamped to `[1, completions.len()]`.
    pub fn decide(&mut self, completions: &[f64], sig: &QuorumSignals) -> QuorumDecision {
        self.decide_with(completions, || *sig)
    }

    /// [`QuorumPolicy::decide`] with the signals fetched lazily: a
    /// static policy never reads them, so the driver's per-round ledger
    /// walk is skipped entirely on the `--quorum K` path.
    pub fn decide_with(
        &mut self,
        completions: &[f64],
        sig: impl FnOnce() -> QuorumSignals,
    ) -> QuorumDecision {
        let n = completions.len().max(1);
        match self {
            QuorumPolicy::Static(cfg) => QuorumDecision {
                k: if cfg.quorum == 0 { n } else { cfg.quorum.clamp(1, n) },
                alpha: cfg.alpha,
            },
            QuorumPolicy::Auto(ctl) => {
                let mut d = ctl.decide(completions, &sig());
                d.k = d.k.clamp(1, n);
                d
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl() -> QuorumController {
        QuorumController::new(QuorumCtlCfg::new(0.8, 1, 0.5, 1.0))
    }

    /// A 16-member cohort: 15 fast clients within 7% of each other plus
    /// one ~4.5× straggler (the bench's Laptop-vs-AGX tail).
    fn tailed() -> Vec<f64> {
        let mut v: Vec<f64> = (0..15).map(|i| 1.0 + 0.005 * i as f64).collect();
        v.push(4.5);
        v
    }

    #[test]
    fn homogeneous_cohort_collapses_to_full_barrier() {
        let mut c = ctl();
        // identical completions: zero spread, K must be the cohort size
        let d = c.decide(&[2.0; 6], &QuorumSignals::default());
        assert_eq!(d.k, 6);
        // spread below spread_min (5%) likewise
        let d = c.decide(&[1.0, 1.01, 1.02, 1.03], &QuorumSignals::default());
        assert_eq!(d.k, 4);
        // degenerate inputs stay in range
        let d = c.decide(&[0.0, 0.0], &QuorumSignals::default());
        assert_eq!(d.k, 2);
        let d = c.decide(&[3.0], &QuorumSignals::default());
        assert_eq!(d.k, 1);
    }

    #[test]
    fn straggler_tail_shrinks_k_within_the_budget() {
        let mut c = ctl();
        let d = c.decide(&tailed(), &QuorumSignals::default());
        assert!(d.k < 16, "a 4.5x straggler must not force a full barrier (k = {})", d.k);
        assert!(d.k >= 1);
        // the chosen K's projected penalty fits the budget
        let mut sorted = tailed();
        sorted.sort_by(f64::total_cmp);
        let budget = staleness_budget(0.8, 1.0, 0.0, 0.5);
        assert!(projected_staleness_loss(&sorted, d.k, d.alpha) <= budget + 1e-12);
    }

    #[test]
    fn observed_staleness_grows_k() {
        // fixed α (gain 0) isolates the K rule: as the observed staleness
        // index eats the budget, the feasible K rises to N
        let mut cfg = QuorumCtlCfg::new(0.8, 1, 0.5, 1.0);
        cfg.alpha_gain = 0.0;
        let mut prev = 0;
        for idx in [0.0, 0.02, 0.05, 0.2] {
            let mut c = QuorumController::new(cfg);
            let sig = QuorumSignals { staleness_index: idx, ..QuorumSignals::default() };
            let d = c.decide(&tailed(), &sig);
            assert!(d.k >= prev, "K must not shrink as staleness rises: {} < {prev}", d.k);
            prev = d.k;
        }
        assert_eq!(prev, 16, "a saturated staleness index must force the full barrier");
    }

    #[test]
    fn observed_churn_grows_k() {
        // the scenario engine's dropout-rate signal consumes the budget
        // like realized staleness losses: heavier churn ⇒ more synchrony
        let mut cfg = QuorumCtlCfg::new(0.8, 1, 0.5, 1.0);
        cfg.alpha_gain = 0.0;
        let mut prev = 0;
        for rate in [0.0, 0.05, 0.15, 0.5] {
            let mut c = QuorumController::new(cfg);
            let sig = QuorumSignals { dropout_rate: rate, ..QuorumSignals::default() };
            let d = c.decide(&tailed(), &sig);
            assert!(d.k >= prev, "K must not shrink as churn rises: {} < {prev}", d.k);
            prev = d.k;
        }
        assert_eq!(prev, 16, "a saturated dropout rate must force the full barrier");
    }

    #[test]
    fn observed_faults_grow_k() {
        // the fault-injection ledger's observed rate consumes the budget
        // exactly like churn: heavier fault pressure ⇒ more synchrony
        let mut cfg = QuorumCtlCfg::new(0.8, 1, 0.5, 1.0);
        cfg.alpha_gain = 0.0;
        let mut prev = 0;
        for rate in [0.0, 0.05, 0.15, 0.5] {
            let mut c = QuorumController::new(cfg);
            let sig = QuorumSignals { fault_rate: rate, ..QuorumSignals::default() };
            let d = c.decide(&tailed(), &sig);
            assert!(d.k >= prev, "K must not shrink as faults rise: {} < {prev}", d.k);
            prev = d.k;
        }
        assert_eq!(prev, 16, "a saturated fault rate must force the full barrier");
    }

    #[test]
    fn required_quorum_reports_only_explicit_static_k() {
        assert_eq!(QuorumPolicy::fixed(12, 1.0).required_quorum(), Some(12));
        assert_eq!(QuorumPolicy::fixed(0, 1.0).required_quorum(), None, "0 = full barrier");
        let auto = QuorumPolicy::Auto(QuorumController::new(QuorumCtlCfg::new(0.8, 1, 0.5, 1.0)));
        assert_eq!(auto.required_quorum(), None, "auto scales to the survivors");
    }

    #[test]
    fn k_floor_is_respected() {
        let mut cfg = QuorumCtlCfg::new(0.8, 3, 1.0, 0.1);
        cfg.alpha_gain = 0.0;
        let mut c = QuorumController::new(cfg);
        // near-free staleness (tiny α, generous margin): K would be 1
        // without the floor
        let d = c.decide(&tailed(), &QuorumSignals::default());
        assert!(d.k >= 3, "k = {} violates the floor", d.k);
        // floor above the cohort size clamps to it
        let mut c = QuorumController::new(QuorumCtlCfg::new(0.8, 99, 0.5, 1.0));
        assert_eq!(c.decide(&tailed(), &QuorumSignals::default()).k, 16);
    }

    #[test]
    fn alpha_anneals_within_bounds() {
        let mut c = ctl();
        // losses far over budget: α relaxes toward alpha_min
        let hot = QuorumSignals { staleness_index: 0.5, ..QuorumSignals::default() };
        let mut last = c.alpha();
        for _ in 0..20 {
            let d = c.decide(&tailed(), &hot);
            assert!(d.alpha <= last + 1e-12, "α must relax under loss pressure");
            assert!((0.0..=1.0).contains(&d.alpha));
            last = d.alpha;
        }
        assert!(last < 0.05, "α must approach alpha_min, got {last}");
        // loss-free rounds sharpen it back toward alpha_max
        for _ in 0..20 {
            last = c.decide(&tailed(), &QuorumSignals::default()).alpha;
        }
        assert!(last > 0.95, "α must recover toward alpha_max, got {last}");
    }

    #[test]
    fn static_policy_reproduces_pr3_clamps() {
        let mut p = QuorumPolicy::fixed(0, 1.0);
        assert_eq!(p.decide(&[1.0, 2.0, 3.0], &QuorumSignals::default()).k, 3);
        let mut p = QuorumPolicy::fixed(99, 0.5);
        let d = p.decide(&[1.0, 2.0, 3.0], &QuorumSignals::default());
        assert_eq!((d.k, d.alpha), (3, 0.5));
        let mut p = QuorumPolicy::fixed(2, 2.0);
        assert_eq!(p.decide(&[1.0, 2.0, 3.0], &QuorumSignals::default()).k, 2);
    }
}
