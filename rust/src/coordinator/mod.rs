//! Layer-3 coordinator — the paper's system contribution.
//!
//! * `ledger`     — block training-adequacy bookkeeping (§II-B)
//! * `frequency`  — convergence-bound mathematics (Eq. 23-27)
//! * `estimator`  — L/σ²/G² estimation from probe gradients (Alg. 2 l.7-9)
//! * `assignment` — the greedy round planner (Alg. 1 l.4-23)
//! * `aggregate`  — basis averaging + block-wise coefficient aggregation (Eq. 5)
//! * `client`     — simulated client executing Alg. 2 through PJRT
//! * `env`        — shared federated world (data, fleet, WAN, clock, eval)
//! * `round`      — the parallel round driver shared by every scheme
//! * `resilience` — fault policies (retry/re-plan/fail) + resilience ledger
//! * `quorum_ctl` — adaptive quorum control: per-round (K, α) decisions
//! * `hierarchy`  — edge-tier quorum aggregation (`--hierarchy E`)
//! * `server`     — the Heroes PS round loop (Alg. 1)
//!
//! # Population scale
//!
//! The coordinator itself is population-agnostic: every phase operates
//! on the *sampled cohort* only. Under `--population lazy` the env hands
//! out per-client state derived on demand from `(seed, client_id)`
//! (`simulation::population`), so a round costs O(cohort) regardless of
//! the nominal population size; under `--hierarchy E` the round driver
//! additionally splits the cohort across E edge aggregators, each
//! running the same quorum machinery over its sub-cohort and forwarding
//! one composed update upward (`hierarchy`), keeping the root's
//! aggregation fan-in at O(E) instead of O(cohort).

// The determinism layers promise typed errors, never panics: promote
// slice-index panics to clippy warnings here (CI denies warnings);
// hlint rule P1 enforces the same contract with per-line reasons.
#![warn(clippy::indexing_slicing)]


pub mod aggregate;
pub mod assignment;
pub mod client;
pub mod env;
pub mod estimator;
pub mod frequency;
pub mod hierarchy;
pub mod ledger;
pub mod quorum_ctl;
pub mod resilience;
pub mod round;
pub mod server;

use crate::tensor::{IntTensor, Tensor};

/// 1/t learning-rate schedule shared by every scheme: lr_h = lr0 / (1 + h/D).
pub fn scheduled_lr(lr0: f32, round: usize, decay_rounds: usize) -> f32 {
    lr0 / (1.0 + round as f32 / decay_rounds.max(1) as f32)
}

/// A model input batch: image families feed f32 pixels, the text family
/// feeds i32 tokens.
#[derive(Debug, Clone)]
pub enum XData {
    Image(Tensor),
    Tokens(IntTensor),
}

/// Per-round metrics emitted by every scheme (Heroes and baselines) —
/// the raw series behind all paper figures. `PartialEq` so tests can pin
/// the round driver's workers=1 ≡ workers=N determinism contract.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundReport {
    pub round: usize,
    /// T^h (Eq. 19): synchronous round completion time, simulated seconds
    pub round_time: f64,
    /// W^h (Eq. 20): average waiting time
    pub avg_wait: f64,
    /// mean local training loss over participants
    pub mean_loss: f64,
    pub taus: Vec<usize>,
    pub widths: Vec<usize>,
    pub down_bytes: u64,
    pub up_bytes: u64,
    pub completion_times: Vec<f64>,
    /// V^h (Eq. 21): block update-count variance after the round
    pub block_variance: f64,
}
