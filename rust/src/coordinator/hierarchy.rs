//! Hierarchical quorum aggregation — an edge-aggregator tier between the
//! cohort and the cloud (`--hierarchy E`).
//!
//! At million-client populations a single coordinator ingesting every
//! member upload is the WAN bottleneck, so the cohort is split
//! round-robin over `E` edge aggregators. Each edge runs the *same*
//! K-of-N quorum rule as the flat driver over its sub-cohort (a clone of
//! the run's [`QuorumPolicy`], so edge decisions share the rule without
//! advancing the adaptive controller's annealed α), composes its quorum
//! members' low-rank updates into **one** update, and forwards that over
//! the edge→cloud backhaul. The root then runs the quorum rule once more
//! — with the *real* policy, so α anneals exactly once per round — over
//! the edge **arrival** times, and aggregates the edges that land in its
//! quorum.
//!
//! ```text
//!   clients ──┬─ edge 0 ─ K₀-of-N₀ ─┐ one composed update each,
//!             ├─ edge 1 ─ K₁-of-N₁ ─┤ max-member bytes over the
//!             └─ edge 2 ─ K₂-of-N₂ ─┘ backhaul (not the member sum)
//!                                   ▼
//!                        root: K-of-E over arrivals
//! ```
//!
//! Everything here is a pure function of plan facts — projected
//! completion times, payload sizes, the deterministic policy — so
//! hierarchical rounds keep the driver's determinism contract: no
//! worker/pool state ever reaches a decision. Two latencies fall out of
//! the plan instead of being simulated per member:
//!
//! * a client that missed its **edge** quorum is forwarded individually,
//!   landing at `completion + bytes/backhaul`;
//! * an **edge** that missed the root quorum lands *as a unit* at its
//!   own arrival instant — its quorum members become stragglers of the
//!   round together.
//!
//! Both re-enter the flat driver's pending-straggler machinery and merge
//! staleness-weighted like any late arrival, so the hierarchy composes
//! with the semi-async pipeline instead of replacing it.

use crate::config::ExperimentConfig;
use crate::coordinator::quorum_ctl::{QuorumPolicy, QuorumSignals};
use crate::coordinator::round::quorum_members;
use crate::simulation::network::MBIT;

/// Edge-tier shape, carried by `RoundDriver` (off when `None`).
#[derive(Debug, Clone, Copy)]
pub struct HierarchyCfg {
    /// number of edge aggregators (≥ 2; `--hierarchy`)
    pub edges: usize,
    /// edge→cloud backhaul throughput, in `LinkSample::up_bps` units
    /// (bytes per second)
    pub backhaul_bps: f64,
}

/// Wired edges are provisioned links, not client WAN: the backhaul runs
/// at this multiple of the top of the client uplink band.
const BACKHAUL_UPLINK_MULT: f64 = 8.0;

impl HierarchyCfg {
    /// The tier an experiment config asks for: `Some` when
    /// `--hierarchy E` with `E > 1` (validation has already required an
    /// active quorum mode alongside it). The backhaul is a deterministic
    /// plan constant — no RNG — so enabling the tier never perturbs the
    /// flat path's draw sequence.
    pub fn from_config(cfg: &ExperimentConfig) -> Option<HierarchyCfg> {
        (cfg.hierarchy > 1).then(|| HierarchyCfg {
            edges: cfg.hierarchy,
            backhaul_bps: BACKHAUL_UPLINK_MULT * cfg.up_mbps.1 * MBIT,
        })
    }
}

/// One edge aggregator's round: its quorum over its sub-cohort and the
/// single composed update it forwards.
#[derive(Debug)]
pub struct EdgePlan {
    /// edge id (round-robin residue)
    pub edge: usize,
    /// caller-index space (survivor positions), ascending
    pub members: Vec<usize>,
    /// when the edge quorum is complete (relative to round start)
    pub t_edge: f64,
    /// when the composed update lands at the root
    pub arrival: f64,
    /// WAN bytes of the composed update: the *widest member's* payload,
    /// not the member sum — neural composition merges the sub-cohort's
    /// low-rank factors into one update of the largest assigned width.
    /// `u64` per the traffic contract: billed bytes never truncate, even
    /// on 32-bit targets.
    pub up_bytes: u64,
}

/// The whole round's hierarchical schedule.
#[derive(Debug)]
pub struct HierarchyPlan {
    /// non-empty edges, in edge-id order
    pub edges: Vec<EdgePlan>,
    /// positions into `edges` the root aggregates now, ascending
    pub root_quorum: Vec<usize>,
    /// union of the root-quorum edges' members (caller-index space,
    /// ascending) — the round's effective quorum
    pub members: Vec<usize>,
    /// root aggregation instant relative to round start: the slowest
    /// root-quorum edge's arrival
    pub t_q: f64,
    /// WAN uplink billed at aggregation: Σ composed-update bytes over
    /// the root quorum (replaces the flat path's per-member sum).
    /// `u64` like every billed byte counter.
    pub wan_up_bytes: u64,
    /// α of the root decision (late merges of this round)
    pub alpha: f64,
    /// every non-member's landing instant relative to round start,
    /// `(caller index, relative finish)` in index order
    pub deferred: Vec<(usize, f64)>,
}

/// Plan one hierarchical round over the survivors' plan facts.
///
/// `completions`/`bytes` are indexed by survivor position; `policy` is
/// the run's quorum policy (mutated only by the root decision);
/// `signals` is fetched lazily — a static policy never reads it, exactly
/// like the flat path.
#[allow(clippy::indexing_slicing)]
// hlint::allow(panic_path, item): every index is a survivor position `< n = completions.len()` or an edge position `< edges.len()` produced by the round-robin split / quorum selection right above its use
pub fn plan_hierarchy(
    completions: &[f64],
    bytes: &[u64],
    cfg: &HierarchyCfg,
    policy: &mut QuorumPolicy,
    signals: impl Fn() -> QuorumSignals,
) -> HierarchyPlan {
    let n = completions.len();
    debug_assert_eq!(n, bytes.len());
    debug_assert!(cfg.backhaul_bps > 0.0, "backhaul must carry traffic");
    let e_cnt = cfg.edges.max(2).min(n.max(1));

    // round-robin sub-cohorts: survivor i reports to edge i % E — a pure
    // function of the index, so membership never depends on RNG state
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); e_cnt];
    for i in 0..n {
        groups[i % e_cnt].push(i);
    }

    let mut edges: Vec<EdgePlan> = Vec::with_capacity(e_cnt);
    for (e, group) in groups.into_iter().enumerate() {
        if group.is_empty() {
            continue;
        }
        let gc: Vec<f64> = group.iter().map(|&i| completions[i]).collect();
        // a clone decides so an edge-tier decision can't advance the
        // root controller's annealed α E times per round
        let mut edge_policy = policy.clone();
        let d = edge_policy.decide_with(&gc, &signals);
        let k = d.k.clamp(1, group.len());
        let members: Vec<usize> = quorum_members(&gc, k).into_iter().map(|j| group[j]).collect();
        let t_edge = members.iter().map(|&i| completions[i]).fold(0.0f64, f64::max);
        let up_bytes = members.iter().map(|&i| bytes[i]).max().unwrap_or(0);
        let arrival = t_edge + crate::util::cast::bytes_to_f64(up_bytes) / cfg.backhaul_bps;
        edges.push(EdgePlan { edge: e, members, t_edge, arrival, up_bytes });
    }

    // the REAL policy decides the root quorum over edge arrivals — one α
    // anneal step per round, same as the flat driver
    let arrivals: Vec<f64> = edges.iter().map(|ep| ep.arrival).collect();
    let d = policy.decide_with(&arrivals, &signals);
    let k_root = d.k.clamp(1, edges.len().max(1));
    let root_quorum = quorum_members(&arrivals, k_root);

    let mut members: Vec<usize> =
        root_quorum.iter().flat_map(|&e| edges[e].members.iter().copied()).collect();
    members.sort_unstable();
    let t_q = root_quorum.iter().map(|&e| edges[e].arrival).fold(0.0f64, f64::max);
    let wan_up_bytes = root_quorum.iter().map(|&e| edges[e].up_bytes).sum();

    // non-members: a root-deferred edge lands as a unit at its arrival;
    // an edge straggler is forwarded individually over the backhaul
    let mut edge_member = vec![false; n];
    let mut deferred: Vec<(usize, f64)> = Vec::new();
    for (pos, ep) in edges.iter().enumerate() {
        for &i in &ep.members {
            edge_member[i] = true;
        }
        if root_quorum.binary_search(&pos).is_err() {
            deferred.extend(ep.members.iter().map(|&i| (i, ep.arrival)));
        }
    }
    for (i, member) in edge_member.iter().enumerate() {
        if !member {
            let fwd = crate::util::cast::bytes_to_f64(bytes[i]) / cfg.backhaul_bps;
            deferred.push((i, completions[i] + fwd));
        }
    }
    deferred.sort_by(|a, b| a.0.cmp(&b.0));

    HierarchyPlan { edges, root_quorum, members, t_q, wan_up_bytes, alpha: d.alpha, deferred }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(edges: usize) -> HierarchyCfg {
        // 1000 bytes/s keeps transfer arithmetic easy to eyeball
        HierarchyCfg { edges, backhaul_bps: 1000.0 }
    }

    #[test]
    fn full_barrier_policy_keeps_every_member_and_compresses_wan() {
        let completions = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let bytes = [100, 200, 300, 400, 500, 600];
        let mut policy = QuorumPolicy::fixed(0, 1.0); // full barrier everywhere
        let plan = plan_hierarchy(&completions, &bytes, &cfg(2), &mut policy, QuorumSignals::default);
        assert_eq!(plan.edges.len(), 2);
        // round-robin: edge 0 = {0,2,4}, edge 1 = {1,3,5}
        assert_eq!(plan.edges[0].members, vec![0, 2, 4]);
        assert_eq!(plan.edges[1].members, vec![1, 3, 5]);
        assert_eq!(plan.members, vec![0, 1, 2, 3, 4, 5], "full barrier keeps everyone");
        assert!(plan.deferred.is_empty());
        // WAN forwards one composed update per edge: max member bytes,
        // far below the flat path's 2100-byte member sum
        assert_eq!(plan.wan_up_bytes, 500 + 600);
        // edge 1 completes at 6.0 and lands 600/1000 s later
        assert_eq!(plan.edges[1].t_edge, 6.0);
        assert!((plan.edges[1].arrival - 6.6).abs() < 1e-12);
        assert!((plan.t_q - 6.6).abs() < 1e-12);
    }

    #[test]
    fn per_edge_quorum_defers_edge_stragglers_individually() {
        let completions = [1.0, 2.0, 10.0, 20.0];
        let bytes = [100, 100, 500, 500];
        let mut policy = QuorumPolicy::fixed(1, 1.0); // fastest-of-each
        let plan = plan_hierarchy(&completions, &bytes, &cfg(2), &mut policy, QuorumSignals::default);
        // edge 0 = {0, 2} keeps 0; edge 1 = {1, 3} keeps 1
        assert_eq!(plan.edges[0].members, vec![0]);
        assert_eq!(plan.edges[1].members, vec![1]);
        // root: static K=1 keeps only the earliest-arriving edge (edge 0,
        // arrival 1.1 vs 2.1) — edge 1's quorum defers as a unit
        assert_eq!(plan.root_quorum, vec![0]);
        assert_eq!(plan.members, vec![0]);
        assert_eq!(plan.wan_up_bytes, 100);
        // deferred: client 1 at edge 1's arrival, clients 2 and 3
        // forwarded individually at completion + bytes/backhaul
        let expect = vec![(1usize, 2.0 + 0.1), (2, 10.0 + 0.5), (3, 20.0 + 0.5)];
        assert_eq!(plan.deferred.len(), expect.len());
        for ((i, t), (ei, et)) in plan.deferred.iter().zip(&expect) {
            assert_eq!(i, ei);
            assert!((t - et).abs() < 1e-12, "client {i}: {t} vs {et}");
        }
    }

    #[test]
    fn cohort_smaller_than_edges_still_plans() {
        let completions = [3.0];
        let bytes = [64];
        let mut policy = QuorumPolicy::fixed(0, 1.0);
        let plan = plan_hierarchy(&completions, &bytes, &cfg(8), &mut policy, QuorumSignals::default);
        assert_eq!(plan.edges.len(), 1);
        assert_eq!(plan.members, vec![0]);
        assert_eq!(plan.wan_up_bytes, 64);
        assert!((plan.t_q - (3.0 + 0.064)).abs() < 1e-12);
        assert!(plan.deferred.is_empty());
    }

    #[test]
    fn plans_are_pure_in_their_inputs() {
        let completions: Vec<f64> = (0..13).map(|i| 1.0 + 0.7 * i as f64).collect();
        let bytes: Vec<u64> = (0..13u64).map(|i| 100 + 37 * i).collect();
        let mk = || QuorumPolicy::fixed(2, 0.5);
        let (mut p1, mut p2) = (mk(), mk());
        let a = plan_hierarchy(&completions, &bytes, &cfg(3), &mut p1, QuorumSignals::default);
        let b = plan_hierarchy(&completions, &bytes, &cfg(3), &mut p2, QuorumSignals::default);
        assert_eq!(a.members, b.members);
        assert_eq!(a.root_quorum, b.root_quorum);
        assert_eq!(a.wan_up_bytes, b.wan_up_bytes);
        assert_eq!(a.t_q.to_bits(), b.t_q.to_bits());
        let da: Vec<(usize, u64)> = a.deferred.iter().map(|&(i, t)| (i, t.to_bits())).collect();
        let db: Vec<(usize, u64)> = b.deferred.iter().map(|&(i, t)| (i, t.to_bits())).collect();
        assert_eq!(da, db);
    }

    #[test]
    fn edge_clones_do_not_advance_the_root_alpha() {
        use crate::coordinator::quorum_ctl::{QuorumController, QuorumCtlCfg};
        // a hot staleness signal relaxes α on every adaptive decision; the
        // hierarchy must take exactly ONE anneal step per round (the root
        // decision), no matter how many edges decided with clones
        let hot = QuorumSignals { staleness_index: 0.5, ..QuorumSignals::default() };
        let completions: Vec<f64> = (0..12).map(|i| 1.0 + 0.5 * i as f64).collect();
        let bytes = vec![100u64; 12];

        let mut hier = QuorumPolicy::Auto(QuorumController::new(QuorumCtlCfg::new(0.8, 1, 0.5, 1.0)));
        let _ = plan_hierarchy(&completions, &bytes, &cfg(4), &mut hier, || hot);

        let mut flat = QuorumPolicy::Auto(QuorumController::new(QuorumCtlCfg::new(0.8, 1, 0.5, 1.0)));
        let _ = flat.decide_with(&completions, || hot);

        let alpha = |p: &QuorumPolicy| match p {
            QuorumPolicy::Auto(c) => c.alpha(),
            QuorumPolicy::Static(_) => unreachable!(),
        };
        assert_eq!(
            alpha(&hier).to_bits(),
            alpha(&flat).to_bits(),
            "hierarchy advanced α a different number of times than one flat decision"
        );
    }

    #[test]
    fn from_config_gates_on_the_knob() {
        use crate::config::{ExperimentConfig, Scale};
        let mut c = ExperimentConfig::preset("cnn", Scale::Smoke);
        assert!(HierarchyCfg::from_config(&c).is_none(), "default is flat");
        c.hierarchy = 1;
        assert!(HierarchyCfg::from_config(&c).is_none(), "a 1-edge tier is the flat path");
        c.hierarchy = 4;
        let h = HierarchyCfg::from_config(&c).expect("explicit tier");
        assert_eq!(h.edges, 4);
        assert!((h.backhaul_bps - BACKHAUL_UPLINK_MULT * c.up_mbps.1 * MBIT).abs() < 1e-9);
    }
}
