//! The Heroes parameter server — paper Alg. 1 end to end.
//!
//! Owns the composed global model, the block ledger and the estimate
//! tracker; each `run_round` samples clients, plans widths / τ / blocks
//! (`assignment::plan_round`), dispatches the simulated clients through
//! the PJRT train executables, performs basis + block-wise aggregation
//! and advances the virtual clock by the synchronous-round maximum.

use crate::config::ExperimentConfig;
use crate::coordinator::aggregate::ComposedAccumulator;
use crate::coordinator::assignment::{self, average_wait, ControllerCfg, RoundPlan};
use crate::coordinator::client::run_local;
use crate::coordinator::env::FlEnv;
use crate::coordinator::estimator::EstimateTracker;
use crate::coordinator::ledger::BlockLedger;
use crate::coordinator::RoundReport;
use crate::model::ComposedGlobal;
use crate::runtime::{Manifest, ModelInfo};
use crate::util::rng::Rng;
use anyhow::Result;

/// The Heroes PS state.
pub struct HeroesServer {
    pub global: ComposedGlobal,
    pub ledger: BlockLedger,
    pub tracker: EstimateTracker,
    ctrl: ControllerCfg,
    family: String,
    lr: f32,
    lr_decay_rounds: usize,
    tau_default: usize,
    round: usize,
    /// probe every round (paper); can be thinned for speed
    pub probe_every: usize,
}

impl HeroesServer {
    pub fn new(info: &ModelInfo, cfg: &ExperimentConfig, rng: &mut Rng) -> Result<HeroesServer> {
        Ok(HeroesServer {
            global: ComposedGlobal::init(info, rng)?,
            ledger: BlockLedger::new(info),
            tracker: EstimateTracker::new(0.3),
            ctrl: ControllerCfg {
                mu_max: cfg.mu_max,
                rho: cfg.rho,
                eta: cfg.lr as f64,
                epsilon: cfg.epsilon,
                tau_min: cfg.tau_min,
                tau_max: cfg.tau_max,
                tau_floor: cfg.tau_default,
                h_max: 1_000_000,
            },
            family: cfg.family.clone(),
            lr: cfg.lr,
            lr_decay_rounds: cfg.lr_decay_rounds,
            tau_default: cfg.tau_default,
            round: 0,
            probe_every: 1,
        })
    }

    /// Plan the round: Alg. 1 proper once estimates exist, otherwise the
    /// predefined identical τ (h = 0 bootstrap).
    fn plan(&mut self, env: &mut FlEnv, clients: &[usize]) -> RoundPlan {
        let statuses: Vec<_> = clients.iter().map(|&c| env.status(c)).collect();
        if self.tracker.ready() {
            let est = self.tracker.current();
            assignment::plan_round(&env.info, &self.ctrl, &est, &statuses, &mut self.ledger)
        } else {
            // bootstrap: widths still greedy, τ identical
            let mut assignments = Vec::with_capacity(statuses.len());
            for s in &statuses {
                let (p, mu) = assignment::assign_width(&env.info, s.q_flops, self.ctrl.mu_max);
                let nu = s.link.upload_time(env.info.bytes_composed[&p]);
                let sel = self.ledger.select_for_width(&env.info, p);
                self.ledger.record(&sel, self.tau_default as u64);
                assignments.push(assignment::Assignment {
                    client: s.client,
                    p,
                    mu,
                    nu,
                    tau: self.tau_default,
                    selection: sel,
                    projected_t: crate::coordinator::frequency::completion_time(
                        self.tau_default, mu, nu,
                    ),
                });
            }
            let (fastest, t_l) = assignments
                .iter()
                .enumerate()
                .map(|(i, a)| (i, a.projected_t))
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap_or((0, 0.0));
            RoundPlan { assignments, fastest, t_l, h_star: 1 }
        }
    }

    /// Execute one synchronous round (paper Alg. 1 lines 4-27).
    pub fn run_round(&mut self, env: &mut FlEnv) -> Result<RoundReport> {
        let clients = env.sample_clients();
        let plan = self.plan(env, &clients);
        let engine = env.engine;
        let info = env.info.clone();
        let probing = self.probe_every > 0 && self.round % self.probe_every.max(1) == 0;

        let mut acc = ComposedAccumulator::new(&info, &self.global);
        let mut completion = Vec::with_capacity(plan.assignments.len());
        let mut losses = Vec::with_capacity(plan.assignments.len());
        let mut estimates = Vec::new();
        let mut down = 0usize;
        let mut up = 0usize;
        let lr_h = crate::coordinator::scheduled_lr(self.lr, self.round, self.lr_decay_rounds);

        for a in &plan.assignments {
            let payload = self.global.reduced_inputs(&info, a.p, &a.selection.blocks)?;
            let bytes = info.bytes_composed[&a.p];
            down += bytes;
            let train_exec = Manifest::train_name(&self.family, a.p, true);
            let probe_exec = probing.then(|| Manifest::probe_name(&self.family, a.p));
            let client = a.client;
            let result = run_local(
                engine,
                &train_exec,
                probe_exec.as_deref(),
                payload,
                a.tau,
                lr_h,
                || env.next_batch(client),
            )?;
            up += bytes;
            acc.push(&a.selection.blocks, &result.params)?;
            completion.push(a.projected_t);
            losses.push(result.mean_loss);
            if let Some(e) = result.estimates {
                estimates.push(e);
            }
        }

        self.global = acc.finalize()?;
        let mean_loss = losses.iter().sum::<f64>() / losses.len().max(1) as f64;
        self.tracker.update(&estimates, mean_loss);

        env.traffic.record_down(down);
        env.traffic.record_up(up);
        let round_time = completion.iter().copied().fold(0.0, f64::max);
        env.clock.advance(round_time);

        let report = RoundReport {
            round: self.round,
            round_time,
            avg_wait: average_wait(&completion),
            mean_loss,
            taus: plan.assignments.iter().map(|a| a.tau).collect(),
            widths: plan.assignments.iter().map(|a| a.p).collect(),
            down_bytes: down,
            up_bytes: up,
            completion_times: completion,
            block_variance: self.ledger.variance(),
        };
        self.round += 1;
        Ok(report)
    }
}
