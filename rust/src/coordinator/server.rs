//! The Heroes parameter server — paper Alg. 1 end to end.
//!
//! Owns the composed global model, the block ledger and the estimate
//! tracker; each `run_round` samples clients, plans widths / τ / blocks
//! (`assignment::plan_round`), dispatches the simulated clients through
//! the shared parallel `RoundDriver` (`coordinator::round`), performs
//! basis + block-wise aggregation in assignment order and advances the
//! virtual clock by the synchronous-round maximum.

use crate::config::ExperimentConfig;
use crate::coordinator::aggregate::ComposedAccumulator;
use crate::coordinator::assignment::{self, fastest_reference, ControllerCfg, RoundPlan};
use crate::coordinator::env::FlEnv;
use crate::coordinator::estimator::EstimateTracker;
use crate::coordinator::ledger::BlockLedger;
use crate::coordinator::round::{collect_round, LocalTask, RoundDriver};
use crate::coordinator::RoundReport;
use crate::model::ComposedGlobal;
use crate::runtime::{Manifest, ModelInfo};
use crate::util::rng::Rng;
use anyhow::Result;

/// The Heroes PS state.
pub struct HeroesServer {
    pub global: ComposedGlobal,
    pub ledger: BlockLedger,
    pub tracker: EstimateTracker,
    ctrl: ControllerCfg,
    driver: RoundDriver,
    family: String,
    lr: f32,
    lr_decay_rounds: usize,
    tau_default: usize,
    round: usize,
    /// probe every round (paper); can be thinned for speed
    pub probe_every: usize,
}

impl HeroesServer {
    pub fn new(info: &ModelInfo, cfg: &ExperimentConfig, rng: &mut Rng) -> Result<HeroesServer> {
        Ok(HeroesServer {
            global: ComposedGlobal::init(info, rng)?,
            ledger: BlockLedger::new(info),
            tracker: EstimateTracker::new(0.3),
            ctrl: ControllerCfg {
                mu_max: cfg.mu_max,
                rho: cfg.rho,
                eta: cfg.lr as f64,
                epsilon: cfg.epsilon,
                tau_min: cfg.tau_min,
                tau_max: cfg.tau_max,
                tau_floor: cfg.tau_default,
                h_max: 1_000_000,
            },
            driver: RoundDriver::new(cfg.workers),
            family: cfg.family.clone(),
            lr: cfg.lr,
            lr_decay_rounds: cfg.lr_decay_rounds,
            tau_default: cfg.tau_default,
            round: 0,
            probe_every: 1,
        })
    }

    /// Plan the round: Alg. 1 proper once estimates exist, otherwise the
    /// predefined identical τ (h = 0 bootstrap).
    fn plan(&mut self, env: &mut FlEnv, clients: &[usize]) -> RoundPlan {
        let statuses: Vec<_> = clients.iter().map(|&c| env.status(c)).collect();
        if self.tracker.ready() {
            let est = self.tracker.current();
            assignment::plan_round(&env.info, &self.ctrl, &est, &statuses, &mut self.ledger)
        } else {
            // bootstrap: widths still greedy, τ identical
            let mut assignments = Vec::with_capacity(statuses.len());
            for s in &statuses {
                let (p, mu) = assignment::assign_width(&env.info, s.q_flops, self.ctrl.mu_max);
                let nu = s.link.upload_time(env.info.bytes_composed[&p]);
                let sel = self.ledger.select_for_width(&env.info, p);
                self.ledger.record(&sel, self.tau_default as u64);
                assignments.push(assignment::Assignment {
                    client: s.client,
                    p,
                    mu,
                    nu,
                    tau: self.tau_default,
                    selection: sel,
                    projected_t: crate::coordinator::frequency::completion_time(
                        self.tau_default, mu, nu,
                    ),
                });
            }
            let (fastest, t_l) = fastest_reference(&assignments);
            RoundPlan { assignments, fastest, t_l, h_star: 1 }
        }
    }

    /// Execute one synchronous round (paper Alg. 1 lines 4-27) through
    /// the shared plan → dispatch → collect → aggregate pipeline.
    pub fn run_round(&mut self, env: &mut FlEnv) -> Result<RoundReport> {
        let clients = env.sample_clients();
        let plan = self.plan(env, &clients);
        let info = env.info.clone();
        let probing = self.probe_every > 0 && self.round % self.probe_every.max(1) == 0;
        let lr_h = crate::coordinator::scheduled_lr(self.lr, self.round, self.lr_decay_rounds);

        // plan → tasks (assignment order)
        let mut tasks = Vec::with_capacity(plan.assignments.len());
        for a in &plan.assignments {
            tasks.push(LocalTask {
                client: a.client,
                p: a.p,
                tau: a.tau,
                lr: lr_h,
                train_exec: Manifest::train_name(&self.family, a.p, true),
                probe_exec: probing.then(|| Manifest::probe_name(&self.family, a.p)),
                payload: self.global.reduced_inputs(&info, a.p, &a.selection.blocks)?,
                stream: env.batch_stream(a.client, self.round),
                bytes: info.bytes_composed[&a.p],
                completion: a.projected_t,
            });
        }

        // dispatch + ordered collect
        let outcomes = self.driver.run(env.engine, tasks)?;

        // aggregate (Eq. 5) in assignment order
        let mut acc = ComposedAccumulator::new(&info, &self.global);
        let mut estimates = Vec::new();
        for (a, o) in plan.assignments.iter().zip(&outcomes) {
            acc.push(&a.selection.blocks, &o.result.params)?;
            if let Some(e) = o.result.estimates {
                estimates.push(e);
            }
        }
        self.global = acc.finalize()?;
        let mean_loss =
            outcomes.iter().map(|o| o.result.mean_loss).sum::<f64>() / outcomes.len().max(1) as f64;
        self.tracker.update(&estimates, mean_loss);

        let report = collect_round(env, self.round, &outcomes, self.ledger.variance());
        self.round += 1;
        Ok(report)
    }
}
