//! The Heroes parameter server — paper Alg. 1 end to end.
//!
//! Owns the composed global model, the block ledger and the estimate
//! tracker; a round is decomposed into the `Strategy` hook phases so the
//! round driver can pipeline rounds:
//!
//! * `plan_ahead` — sample clients, collect statuses (the only phase
//!   touching the env's RNG; safe to run while the previous round's
//!   stragglers drain).
//! * `take_tasks` — Alg. 1 planning (`assignment::plan_round` once the
//!   estimator is live, the predefined-τ bootstrap before) + payload /
//!   stream materialization. β² for the H* solver (Eq. 23's 6L²β² floor)
//!   is fed from the ledger's observed block-training imbalance here.
//! * `finish_round` — basis + block-wise aggregation in assignment
//!   order, estimator update, clock/traffic bookkeeping.
//! * `finish_round_quorum` — the semi-async variant: quorum members fold
//!   at weight 1, late arrivals at their staleness weight, each against
//!   the block selections of the *plan that produced them* — so the
//!   low-rank tensor updates of a slow client still reach exactly the
//!   blocks only it trained, rounds later. Plans are retained in a small
//!   deque until every cohort member has merged; the ledger records the
//!   staleness discount per block so the controller's β² proxy sees the
//!   true training imbalance.
//!
//! `run_round` composes the three phases around the shared parallel
//! `RoundDriver` (`coordinator::round`).

use crate::config::ExperimentConfig;
use crate::coordinator::aggregate::ComposedAccumulator;
use crate::coordinator::assignment::{
    self, cohort_statuses, fastest_reference, Assignment, ClientStatus, ControllerCfg, RoundPlan,
};
use crate::coordinator::env::FlEnv;
use crate::coordinator::estimator::EstimateTracker;
use crate::coordinator::hierarchy::HierarchyCfg;
use crate::coordinator::ledger::BlockLedger;
use crate::codec::scheme_id;
use crate::coordinator::round::{
    collect_quorum_round, collect_round, LocalTask, QuorumBatch, RoundDriver, TaskOutcome,
    WireTask,
};
use crate::coordinator::RoundReport;
use crate::model::ComposedGlobal;
use crate::runtime::{Manifest, ModelInfo};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::collections::VecDeque;

/// A dispatched round's plan, retained until every cohort member's
/// update has been folded (quorum mode merges stragglers rounds later,
/// and aggregation needs their block selections).
struct PlanSlot {
    round: usize,
    plan: RoundPlan,
    /// cohort members not yet folded into any aggregate
    remaining: usize,
}

/// The Heroes PS state.
pub struct HeroesServer {
    pub global: ComposedGlobal,
    pub ledger: BlockLedger,
    pub tracker: EstimateTracker,
    ctrl: ControllerCfg,
    driver: RoundDriver,
    family: String,
    lr: f32,
    lr_decay_rounds: usize,
    tau_default: usize,
    round: usize,
    /// probe every round (paper); can be thinned for speed
    pub probe_every: usize,
    /// phase-A output (statuses) awaiting `take_tasks`
    pending: Option<Vec<ClientStatus>>,
    /// phase-B plans whose outcomes are still (partly) outstanding,
    /// oldest first; the synchronous paths hold at most one
    in_flight: VecDeque<PlanSlot>,
}

impl HeroesServer {
    // hlint::allow(unkeyed_rng): construction-time model init draws from the run-seed cursor once — per-round draws go through the env's keyed RNGs
    pub fn new(info: &ModelInfo, cfg: &ExperimentConfig, rng: &mut Rng) -> Result<HeroesServer> {
        Ok(HeroesServer {
            global: ComposedGlobal::init(info, rng)?,
            ledger: BlockLedger::new(info)?,
            tracker: EstimateTracker::new(0.3),
            ctrl: ControllerCfg {
                mu_max: cfg.mu_max,
                rho: cfg.rho,
                eta: cfg.lr as f64,
                epsilon: cfg.epsilon,
                tau_min: cfg.tau_min,
                tau_max: cfg.tau_max,
                tau_floor: cfg.tau_default,
                h_max: 1_000_000,
                beta_sq: 0.0,
                codec: cfg.codec,
            },
            driver: RoundDriver::new(cfg.workers).with_hierarchy(HierarchyCfg::from_config(cfg)),
            family: cfg.family.clone(),
            lr: cfg.lr,
            lr_decay_rounds: cfg.lr_decay_rounds,
            tau_default: cfg.tau_default,
            round: 0,
            probe_every: 1,
            pending: None,
            in_flight: VecDeque::new(),
        })
    }

    /// Plan the round: Alg. 1 proper once estimates exist, otherwise the
    /// predefined identical τ (h = 0 bootstrap).
    fn plan(&mut self, info: &ModelInfo, statuses: &[ClientStatus]) -> Result<RoundPlan> {
        if self.tracker.ready() {
            let est = self.tracker.current();
            // Feed the observed coefficient-reduction error into the H*
            // solver: evenly-trained blocks compose with little error, so
            // the ledger's relative count variance is the live β² proxy
            // (previously hardcoded 0.0, erasing Eq. 23's 6L²β² floor).
            // Capped so an early-training imbalance spike cannot pin H*
            // at h_max and collapse τ (see `capped_beta_sq`).
            self.ctrl.beta_sq = crate::coordinator::frequency::capped_beta_sq(
                self.ledger.relative_variance(),
                self.ctrl.epsilon,
                est.l,
            );
            assignment::plan_round(info, &self.ctrl, &est, statuses, &mut self.ledger)
        } else {
            // bootstrap: widths still greedy, τ identical
            let mut assignments = Vec::with_capacity(statuses.len());
            for s in statuses {
                let (p, mu) = assignment::assign_width(info, s.q_flops, self.ctrl.mu_max);
                let up = crate::codec::upload_bytes(
                    info.composed_params_of(p)?,
                    info.bytes_composed_of(p)?,
                    self.ctrl.codec,
                );
                let nu = s.link.upload_time(up);
                let sel = self.ledger.select_for_width(info, p)?;
                self.ledger.record(&sel, self.tau_default as u64)?;
                assignments.push(assignment::Assignment {
                    client: s.client,
                    p,
                    mu,
                    nu,
                    tau: self.tau_default,
                    selection: sel,
                    projected_t: crate::coordinator::frequency::completion_time(
                        self.tau_default, mu, nu,
                    ),
                });
            }
            let (fastest, t_l) = fastest_reference(&assignments)
                .ok_or_else(|| anyhow!("cannot plan a round with an empty cohort"))?;
            Ok(RoundPlan { assignments, fastest, t_l, h_star: 1 })
        }
    }

    /// Phase A: sample this round's participants and collect statuses.
    /// Touches only the env's RNG, so the driver may run it while the
    /// previous round is still executing.
    pub fn plan_ahead(&mut self, env: &mut FlEnv) -> Result<()> {
        if self.pending.is_some() {
            return Err(anyhow!("plan_ahead called twice without take_tasks"));
        }
        let clients = env.sample_clients();
        self.pending = Some(cohort_statuses(env, &clients));
        Ok(())
    }

    /// Phase B: Alg. 1 planning + payload materialization against the
    /// current global (so it is sequenced after the previous round's
    /// aggregation).
    pub fn take_tasks(&mut self, env: &FlEnv) -> Result<Vec<LocalTask>> {
        let statuses = self
            .pending
            .take()
            .ok_or_else(|| anyhow!("take_tasks without a preceding plan_ahead"))?;
        let plan = self.plan(&env.info, &statuses)?;
        let probing = self.probe_every > 0 && self.round % self.probe_every.max(1) == 0;
        let lr_h = crate::coordinator::scheduled_lr(self.lr, self.round, self.lr_decay_rounds);

        let mut tasks = Vec::with_capacity(plan.assignments.len());
        for a in &plan.assignments {
            tasks.push(LocalTask {
                client: a.client,
                p: a.p,
                tau: a.tau,
                lr: lr_h,
                train_exec: Manifest::train_name(&self.family, a.p, true),
                probe_exec: probing.then(|| Manifest::probe_name(&self.family, a.p)),
                payload: self.global.reduced_inputs(&env.info, a.p, &a.selection.blocks)?,
                stream: env.batch_stream(a.client, self.round)?,
                bytes: env.info.bytes_composed_of(a.p)? as u64,
                up_bytes: crate::codec::upload_bytes(
                    env.info.composed_params_of(a.p)?,
                    env.info.bytes_composed_of(a.p)?,
                    self.ctrl.codec,
                ),
                rebill_bytes: 0,
                wire: self.ctrl.codec.encoding().map(|enc| WireTask {
                    scheme: scheme_id::HEROES,
                    round: self.round as u32,
                    enc,
                }),
                completion: a.projected_t,
                drop_at: None,
                fault: None,
            });
        }
        let remaining = plan.assignments.len();
        self.in_flight.push_back(PlanSlot { round: self.round, plan, remaining });
        Ok(tasks)
    }

    /// The retained plan's assignment for `client` of `round`.
    fn assignment_of(
        in_flight: &VecDeque<PlanSlot>,
        round: usize,
        client: usize,
    ) -> Result<&Assignment> {
        let slot = in_flight
            .iter()
            .find(|s| s.round == round)
            .ok_or_else(|| anyhow!("no retained plan for round {round}"))?;
        slot.plan
            .assignments
            .iter()
            .find(|a| a.client == client)
            .ok_or_else(|| anyhow!("client {client} was not in round {round}'s plan"))
    }

    /// Phase C: aggregate (Eq. 5) in assignment order, update the
    /// estimator, fold the round into the env's meters.
    pub fn finish_round(
        &mut self,
        env: &mut FlEnv,
        outcomes: Vec<TaskOutcome>,
    ) -> Result<RoundReport> {
        let pos = self
            .in_flight
            .iter()
            .position(|s| s.round == self.round)
            .ok_or_else(|| anyhow!("finish_round without a dispatched round"))?;
        let slot = self
            .in_flight
            .remove(pos)
            .ok_or_else(|| anyhow!("finish_round without a dispatched round"))?;
        let plan = slot.plan;
        let info = env.info.clone();
        let mut acc = ComposedAccumulator::new(&info, &self.global);
        let mut estimates = Vec::new();
        for (a, o) in plan.assignments.iter().zip(&outcomes) {
            acc.push(&a.selection.blocks, &o.result.params)?;
            if let Some(e) = o.result.estimates {
                estimates.push(e);
            }
        }
        self.global = acc.finalize()?;
        let mean_loss =
            outcomes.iter().map(|o| o.result.mean_loss).sum::<f64>() / outcomes.len().max(1) as f64;
        self.tracker.update(&estimates, mean_loss);

        let report = collect_round(env, self.round, &outcomes, self.ledger.variance());
        self.round += 1;
        Ok(report)
    }

    /// Phase C, semi-async: quorum members fold at weight 1 against this
    /// round's plan, late arrivals at their staleness weight against the
    /// plan of their **origin** round — so a slow client's low-rank
    /// block updates still land on exactly the blocks it trained. The
    /// ledger books each late merge's staleness discount per block
    /// (`BlockLedger::record_staleness`), which feeds the controller's
    /// β² proxy next round.
    pub fn finish_round_quorum(
        &mut self,
        env: &mut FlEnv,
        batch: QuorumBatch,
    ) -> Result<RoundReport> {
        if batch.round != self.round {
            return Err(anyhow!(
                "quorum batch for round {} but server is at round {}",
                batch.round,
                self.round
            ));
        }
        let info = env.info.clone();
        let mut acc = ComposedAccumulator::new(&info, &self.global);
        let mut estimates = Vec::new();
        let mut losses = Vec::with_capacity(batch.quorum.len() + batch.late.len());
        for o in &batch.quorum {
            let a = Self::assignment_of(&self.in_flight, batch.round, o.client)?;
            acc.push_weighted(&a.selection.blocks, &o.result.params, 1.0)?;
            if let Some(e) = o.result.estimates {
                estimates.push(e);
            }
            losses.push(o.result.mean_loss);
        }
        for late in &batch.late {
            let a = Self::assignment_of(&self.in_flight, late.origin_round, late.outcome.client)?;
            acc.push_weighted(&a.selection.blocks, &late.outcome.result.params, late.weight)?;
            self.ledger.record_staleness(&a.selection, a.tau as u64, late.weight)?;
            if let Some(e) = late.outcome.result.estimates {
                estimates.push(e);
            }
            losses.push(late.outcome.result.mean_loss);
        }
        self.global = acc.finalize()?;

        // retire fully-merged plans; a scenario-dropped client's update
        // never arrives, so its plan slot retires here or leaks forever
        for o in &batch.quorum {
            Self::retire(&mut self.in_flight, batch.round, o.client)?;
        }
        for late in &batch.late {
            Self::retire(&mut self.in_flight, late.origin_round, late.outcome.client)?;
        }
        for &client in &batch.dropped {
            Self::retire(&mut self.in_flight, batch.round, client)?;
        }
        self.in_flight.retain(|s| s.remaining > 0);

        let mean_loss = losses.iter().sum::<f64>() / losses.len().max(1) as f64;
        self.tracker.update(&estimates, mean_loss);

        let report = collect_quorum_round(env, &batch, self.ledger.variance());
        self.round += 1;
        Ok(report)
    }

    /// Count one folded cohort member of `round` towards its plan's
    /// retirement.
    fn retire(in_flight: &mut VecDeque<PlanSlot>, round: usize, client: usize) -> Result<()> {
        let slot = in_flight
            .iter_mut()
            .find(|s| s.round == round)
            .ok_or_else(|| anyhow!("no retained plan for round {round} (client {client})"))?;
        slot.remaining = slot
            .remaining
            .checked_sub(1)
            .ok_or_else(|| anyhow!("round {round} over-merged (client {client})"))?;
        Ok(())
    }

    /// The dispatch configuration (for the `Strategy` trait's shared
    /// `run_round` composition).
    pub fn driver(&self) -> RoundDriver {
        self.driver
    }

    /// Observed signals for the adaptive quorum controller
    /// (`coordinator::quorum_ctl`): the ledger's staleness index, the β²
    /// proxy the H* solver already consumes, the tracker's smoothness
    /// estimate and the planned-count spread. All deterministic
    /// virtual-clock state — reading them never perturbs a run.
    pub fn quorum_signals(&self) -> crate::coordinator::quorum_ctl::QuorumSignals {
        crate::coordinator::quorum_ctl::QuorumSignals {
            staleness_index: self.ledger.staleness_index(),
            beta_sq: self.ledger.relative_variance(),
            l: if self.tracker.ready() { self.tracker.current().l } else { 1.0 },
            spread_index: self.ledger.spread_index(),
            // the observed churn is a dispatch fact the round driver
            // injects (`FlEnv::observed_dropout_rate`), not scheme state
            ..Default::default()
        }
    }
}
