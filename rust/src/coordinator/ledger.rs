//! Block ledger — training-adequacy bookkeeping for the enhanced neural
//! composition (paper §II-B), at **channel-group granularity**.
//!
//! The paper selects the least-trained coefficient *blocks* freely. Free
//! selection breaks channel alignment between consecutive layers (a block
//! trained at slot (0,0) of a width-1 model lands at tile (a,g) of the
//! full model), which at reproducible training budgets prevents the full
//! model from cohering (DESIGN.md §Deviations). We therefore rotate at
//! the granularity the composition actually exposes: every *group class*
//! (a set of layers whose activations meet, e.g. through residual adds)
//! selects the `p` least-trained channel groups; a layer's trained blocks
//! are the cross product of its input-class and output-class selections,
//! `id = a·P + g`. Width-p sub-models are then exactly channel-aligned
//! sub-networks of the width-P model, while rotation still guarantees the
//! paper's core property: every block of every coefficient is trained
//! evenly (total-update-times balance, Eq. 21).

use crate::runtime::ModelInfo;
use crate::util::stats;
use anyhow::{anyhow, Result};

/// One round's selection for one client: per-class group choices plus the
/// per-layer block ids they induce (both ascending).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Selection {
    /// groups[class_idx] = selected group ids, len = p
    pub groups: Vec<Vec<usize>>,
    /// blocks[layer_idx] = coefficient block ids (ascending)
    pub blocks: Vec<Vec<usize>>,
}

/// Group-class update counters.
#[derive(Debug, Clone)]
pub struct BlockLedger {
    cap_p: usize,
    /// group-class names, in first-appearance order over the layer list
    classes: Vec<String>,
    /// counts[class_idx][group] — total local iterations (c_i analogue)
    counts: Vec<Vec<u64>>,
    /// stale[class_idx][group] — iterations *lost* to staleness-weighted
    /// late merges (semi-async quorum mode): a round-`h` update merged at
    /// round `h+s` with weight `w = 1/(1+s)^α` only delivered `w·τ`
    /// effective iterations, so `(1−w)·τ` is recorded here. `counts`
    /// keeps driving the least-trained rotation on *planned* iterations
    /// (plan-time behaviour is untouched, preserving the `--quorum N`
    /// byte-identity); the stale tally discounts them after the fact so
    /// `relative_variance` — the controller's β² proxy — sees the true
    /// imbalance: blocks trained mostly by stragglers are systematically
    /// under-trained even when the planned counts look balanced.
    stale: Vec<Vec<f64>>,
    /// per layer: (in_class idx, out_class idx)
    layer_classes: Vec<(Option<usize>, Option<usize>)>,
}

impl BlockLedger {
    /// Build the ledger for a model family. Errs on a malformed layer
    /// spec (a scale flag without its class name — manifest input, so a
    /// typed error, not an assert).
    pub fn new(info: &ModelInfo) -> Result<BlockLedger> {
        let mut classes: Vec<String> = Vec::new();
        let mut idx_of = |name: &Option<String>| -> Option<usize> {
            name.as_ref().map(|n| {
                if let Some(i) = classes.iter().position(|c| c == n) {
                    i
                } else {
                    classes.push(n.clone());
                    classes.len() - 1
                }
            })
        };
        let mut layer_classes: Vec<(Option<usize>, Option<usize>)> =
            Vec::with_capacity(info.layers.len());
        for l in &info.layers {
            if l.s_in != l.in_class.is_some() {
                return Err(anyhow!("layer {}: s_in must come with an in_class", l.name));
            }
            if l.s_out != l.out_class.is_some() {
                return Err(anyhow!("layer {}: s_out must come with an out_class", l.name));
            }
            layer_classes.push((idx_of(&l.in_class), idx_of(&l.out_class)));
        }
        Ok(BlockLedger {
            cap_p: info.cap_p,
            counts: vec![vec![0; info.cap_p]; classes.len()],
            stale: vec![vec![0.0; info.cap_p]; classes.len()],
            classes,
            layer_classes,
        })
    }

    pub fn classes(&self) -> &[String] {
        &self.classes
    }

    /// Group counters of one class (empty for an unknown class index).
    pub fn class_counts(&self, class_idx: usize) -> &[u64] {
        self.counts.get(class_idx).map_or(&[], Vec::as_slice)
    }

    /// The `want` least-trained groups of a class, ascending id order
    /// (count-sorted, id tie-break — the paper's least-trained rule).
    #[allow(clippy::indexing_slicing)]
    // hlint::allow(panic_path, item): private — `class_idx` enumerates `self.classes` and `want = p ≤ cap_p` is validated by `select_for_width`, the only caller
    fn select_groups(&self, class_idx: usize, want: usize) -> Vec<usize> {
        let c = &self.counts[class_idx];
        assert!(want <= c.len(), "want {want} of {} groups", c.len());
        let mut ids: Vec<usize> = (0..c.len()).collect();
        ids.sort_by_key(|&i| c[i]);
        ids.truncate(want);
        ids.sort_unstable();
        ids
    }

    /// Blocks of one layer induced by per-class group selections.
    #[allow(clippy::indexing_slicing)]
    // hlint::allow(panic_path, item): private — `layer_idx` enumerates `info.layers` and the class indices were derived from the same layer list at construction
    fn layer_blocks(&self, layer_idx: usize, groups: &[Vec<usize>]) -> Vec<usize> {
        let (ic, oc) = self.layer_classes[layer_idx];
        match (ic, oc) {
            (None, None) => vec![0],
            (None, Some(o)) => groups[o].clone(),
            (Some(i), None) => groups[i].clone(),
            (Some(i), Some(o)) => {
                let mut out = Vec::with_capacity(groups[i].len() * groups[o].len());
                for &a in &groups[i] {
                    for &g in &groups[o] {
                        out.push(a * self.cap_p + g);
                    }
                }
                out // ascending because both selections are sorted
            }
        }
    }

    /// Full selection for a width-p client. Errs on a width outside
    /// `1..=cap_p` — a planner bug surfaced as a typed error.
    pub fn select_for_width(&self, info: &ModelInfo, p: usize) -> Result<Selection> {
        if p < 1 || p > self.cap_p {
            return Err(anyhow!("width {p} outside 1..={} for this ledger", self.cap_p));
        }
        let groups: Vec<Vec<usize>> =
            (0..self.classes.len()).map(|c| self.select_groups(c, p)).collect();
        let blocks = (0..info.layers.len()).map(|l| self.layer_blocks(l, &groups)).collect();
        Ok(Selection { groups, blocks })
    }

    /// The all-groups selection (width P) — identity block layout.
    pub fn full_selection(&self, info: &ModelInfo) -> Result<Selection> {
        self.select_for_width(info, self.cap_p)
    }

    /// Shape-check a selection against the ledger before recording: a
    /// mismatched class count or an out-of-range group id is a proper
    /// `Err` (it means the selection came from a different model's
    /// ledger), never a coordinator abort.
    fn check_selection(&self, sel: &Selection) -> Result<()> {
        if sel.groups.len() != self.counts.len() {
            return Err(anyhow!(
                "selection has {} group classes but the ledger tracks {}",
                sel.groups.len(),
                self.counts.len()
            ));
        }
        for (class_idx, groups) in sel.groups.iter().enumerate() {
            if let Some(&g) = groups.iter().find(|&&g| g >= self.cap_p) {
                let class = self.classes.get(class_idx).map_or("?", String::as_str);
                return Err(anyhow!(
                    "selection group id {g} out of range for class {class} ({} groups)",
                    self.cap_p
                ));
            }
        }
        Ok(())
    }

    /// Record `tau` local iterations on a selection (Alg. 1 l.21-22).
    /// Errs (without partial mutation) on a selection whose shape does
    /// not match this ledger.
    #[allow(clippy::indexing_slicing)]
    // hlint::allow(panic_path, item): `check_selection` has validated the class count and every group id
    pub fn record(&mut self, sel: &Selection, tau: u64) -> Result<()> {
        self.check_selection(sel)?;
        for (class_idx, groups) in sel.groups.iter().enumerate() {
            for &g in groups {
                self.counts[class_idx][g] += tau;
            }
        }
        Ok(())
    }

    /// Record the staleness discount of a late merge (quorum mode): a
    /// selection trained for `tau` iterations but folded at weight `w`
    /// only delivered `w·τ` effective iterations; the lost `(1−w)·τ` is
    /// tallied per group so `relative_variance` sees it. Errs (without
    /// partial mutation) on a shape-mismatched selection.
    #[allow(clippy::indexing_slicing)]
    // hlint::allow(panic_path, item): `check_selection` has validated the class count and every group id
    pub fn record_staleness(&mut self, sel: &Selection, tau: u64, weight: f32) -> Result<()> {
        self.check_selection(sel)?;
        let lost = tau as f64 * (1.0 - (weight as f64).clamp(0.0, 1.0));
        for (class_idx, groups) in sel.groups.iter().enumerate() {
            for &g in groups {
                self.stale[class_idx][g] += lost;
            }
        }
        Ok(())
    }

    /// Fraction of all recorded iterations lost to staleness discounts
    /// (0 in synchronous / full-quorum runs).
    pub fn staleness_index(&self) -> f64 {
        let total: f64 = self.counts.iter().flatten().map(|&x| x as f64).sum();
        let lost: f64 = self.stale.iter().flatten().sum();
        if total > 0.0 {
            lost / total
        } else {
            0.0
        }
    }

    /// Mean over classes of a per-class statistic of the group counts
    /// (shared traversal of `variance` / `relative_variance`);
    /// `effective` discounts each group's stale tally first.
    fn mean_class_stat(&self, effective: bool, stat: impl Fn(&[f64]) -> f64) -> f64 {
        let per_class: Vec<f64> = self
            .counts
            .iter()
            .zip(&self.stale)
            .map(|(c, st)| {
                let xs: Vec<f64> = c
                    .iter()
                    .zip(st)
                    .map(|(&x, &s)| if effective { (x as f64 - s).max(0.0) } else { x as f64 })
                    .collect();
                stat(&xs)
            })
            .collect();
        stats::mean(&per_class)
    }

    /// V^h: mean over classes of the per-class group-count variance
    /// (Eq. 21 at group granularity), on *planned* counts — the rotation
    /// diagnostic the round reports carry.
    pub fn variance(&self) -> f64 {
        self.mean_class_stat(false, stats::variance)
    }

    /// V^h normalized per class by the squared mean count (mean squared
    /// coefficient of variation) — a dimensionless imbalance measure.
    /// The controller feeds this to the H* solver as its observed β²
    /// (Eq. 23's coefficient-reduction error bound): evenly-trained
    /// blocks compose with little error, badly skewed training budgets
    /// inflate it. Computed over **effective** counts (planned minus the
    /// staleness losses recorded by `record_staleness`) so semi-async
    /// runs expose the true per-block imbalance. 0 while the ledger is
    /// empty; identical to the raw statistic while no staleness has been
    /// recorded.
    pub fn relative_variance(&self) -> f64 {
        self.mean_class_stat(true, |xs| {
            let m = stats::mean(xs);
            if m > 0.0 {
                stats::variance(xs) / (m * m)
            } else {
                0.0
            }
        })
    }

    /// Hypothetical V^h if `sel` received `tau` more iterations — the
    /// controller's τ search (Alg. 1 line 19) uses this without mutating.
    /// A selection with fewer classes than the ledger (foreign ledger)
    /// contributes no hypothetical additions for the missing classes.
    pub fn variance_if(&self, sel: &Selection, tau: u64) -> f64 {
        const NO_GROUPS: &[usize] = &[];
        let per_class: Vec<f64> = self
            .counts
            .iter()
            .enumerate()
            .map(|(class_idx, c)| {
                let groups = sel.groups.get(class_idx).map_or(NO_GROUPS, Vec::as_slice);
                let xs: Vec<f64> = c
                    .iter()
                    .enumerate()
                    .map(|(g, &x)| {
                        let add = if groups.binary_search(&g).is_ok() { tau } else { 0 };
                        (x + add) as f64
                    })
                    .collect();
                stats::variance(&xs)
            })
            .collect();
        stats::mean(&per_class)
    }

    /// Spread diagnostics: (min, max) group count over all classes.
    pub fn count_range(&self) -> (u64, u64) {
        let mut lo = u64::MAX;
        let mut hi = 0;
        for c in &self.counts {
            for &x in c {
                lo = lo.min(x);
                hi = hi.max(x);
            }
        }
        if lo == u64::MAX {
            (0, 0)
        } else {
            (lo, hi)
        }
    }

    /// Dimensionless planned-count spread `(hi − lo)/hi` over all groups
    /// — the straggler tail's footprint in the training books (a wide
    /// spread means rotation is being starved by clients that keep
    /// missing their merge rounds). One of the adaptive quorum
    /// controller's observed signals (`quorum_ctl::QuorumSignals`); 0 on
    /// an empty or perfectly balanced ledger.
    pub fn spread_index(&self) -> f64 {
        let (lo, hi) = self.count_range();
        if hi == 0 {
            0.0
        } else {
            (hi - lo) as f64 / hi as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests_support::toy_info;

    // toy_info: conv1 (out class "g1"), head (in class "g1"); cap_p = 2.

    #[test]
    fn classes_derived_from_layers() {
        let info = toy_info();
        let ledger = BlockLedger::new(&info).unwrap();
        assert_eq!(ledger.classes(), &["g1".to_string()]);
        assert_eq!(ledger.class_counts(0), &[0, 0]);
    }

    #[test]
    fn selection_is_shared_across_tied_layers() {
        let info = toy_info();
        let mut ledger = BlockLedger::new(&info).unwrap();
        let sel = ledger.select_for_width(&info, 1).unwrap();
        // one class, one group picked; conv1 blocks == head blocks == group
        assert_eq!(sel.groups, vec![vec![0]]);
        assert_eq!(sel.blocks, vec![vec![0], vec![0]]);
        ledger.record(&sel, 5).unwrap();
        // next narrow selection must rotate to the other group
        let sel2 = ledger.select_for_width(&info, 1).unwrap();
        assert_eq!(sel2.groups, vec![vec![1]]);
        assert_eq!(sel2.blocks, vec![vec![1], vec![1]]);
    }

    #[test]
    fn full_selection_is_identity_layout() {
        let info = toy_info();
        let ledger = BlockLedger::new(&info).unwrap();
        let sel = ledger.full_selection(&info).unwrap();
        assert_eq!(sel.groups, vec![vec![0, 1]]);
        assert_eq!(sel.blocks, vec![vec![0, 1], vec![0, 1]]);
    }

    #[test]
    fn cross_product_blocks_for_dual_scaled_layers() {
        // synthesize a dual-scaled layer by hand
        let mut info = toy_info();
        info.layers[1].s_in = true;
        info.layers[1].s_out = true;
        info.layers[1].in_class = Some("g1".into());
        info.layers[1].out_class = Some("g2".into());
        info.layers[1].blocks_total = 4;
        let mut ledger = BlockLedger::new(&info).unwrap();
        assert_eq!(ledger.classes(), &["g1".to_string(), "g2".to_string()]);
        let sel = ledger.select_for_width(&info, 1).unwrap();
        assert_eq!(sel.blocks[1], vec![0]); // a=0,g=0 -> 0*2+0
        ledger.record(&sel, 3).unwrap();
        let sel2 = ledger.select_for_width(&info, 1).unwrap();
        // both classes rotate -> a=1,g=1 -> 1*2+1 = 3
        assert_eq!(sel2.blocks[1], vec![3]);
        let full = ledger.select_for_width(&info, 2).unwrap();
        assert_eq!(full.blocks[1], vec![0, 1, 2, 3]);
    }

    #[test]
    fn variance_and_variance_if_agree() {
        let info = toy_info();
        let mut ledger = BlockLedger::new(&info).unwrap();
        let sel = ledger.select_for_width(&info, 1).unwrap();
        ledger.record(&sel, 4).unwrap();
        assert!(ledger.variance() > 0.0);
        let sel2 = ledger.select_for_width(&info, 1).unwrap();
        let hyp = ledger.variance_if(&sel2, 4);
        ledger.record(&sel2, 4).unwrap();
        assert!((hyp - ledger.variance()).abs() < 1e-12);
        assert_eq!(ledger.variance(), 0.0); // balanced again
    }

    #[test]
    fn relative_variance_is_dimensionless_imbalance() {
        let info = toy_info();
        let mut ledger = BlockLedger::new(&info).unwrap();
        // empty ledger: no imbalance signal
        assert_eq!(ledger.relative_variance(), 0.0);
        // counts [6, 0]: mean 3, var 9 -> CV² = 1
        let sel = ledger.select_for_width(&info, 1).unwrap();
        ledger.record(&sel, 6).unwrap();
        assert!((ledger.relative_variance() - 1.0).abs() < 1e-12);
        // balanced [6, 6]: imbalance vanishes even though counts grew
        let sel2 = ledger.select_for_width(&info, 1).unwrap();
        ledger.record(&sel2, 6).unwrap();
        assert_eq!(ledger.relative_variance(), 0.0);
    }

    #[test]
    fn staleness_discounts_effective_counts() {
        let info = toy_info();
        let mut ledger = BlockLedger::new(&info).unwrap();
        // two balanced selections: planned counts [6, 6] -> no imbalance
        let sel_a = ledger.select_for_width(&info, 1).unwrap();
        ledger.record(&sel_a, 6).unwrap();
        let sel_b = ledger.select_for_width(&info, 1).unwrap();
        ledger.record(&sel_b, 6).unwrap();
        assert_eq!(ledger.relative_variance(), 0.0);
        assert_eq!(ledger.staleness_index(), 0.0);

        // group B's 6 iterations merged late at weight 1/2: effective
        // counts become [6, 3] — the planned balance was an illusion
        ledger.record_staleness(&sel_b, 6, 0.5).unwrap();
        assert!((ledger.staleness_index() - 0.25).abs() < 1e-12, "3 of 12 iterations lost");
        // effective [6, 3]: mean 4.5, var 2.25 -> CV² = 1/9
        assert!((ledger.relative_variance() - 1.0 / 9.0).abs() < 1e-12);
        // the raw rotation diagnostic stays on planned counts
        assert_eq!(ledger.variance(), 0.0);
    }

    #[test]
    fn full_weight_merge_records_no_staleness() {
        let info = toy_info();
        let mut ledger = BlockLedger::new(&info).unwrap();
        let sel = ledger.select_for_width(&info, 1).unwrap();
        ledger.record(&sel, 5).unwrap();
        let before = ledger.relative_variance();
        ledger.record_staleness(&sel, 5, 1.0).unwrap();
        assert_eq!(ledger.relative_variance(), before);
        assert_eq!(ledger.staleness_index(), 0.0);
    }

    #[test]
    fn shape_mismatched_record_is_an_error_not_an_abort() {
        // regression: record/record_staleness used to assert_eq! on the
        // class count and panic-index on out-of-range groups, aborting
        // the coordinator mid-run
        let info = toy_info();
        let mut ledger = BlockLedger::new(&info).unwrap();
        let wrong_classes = Selection { groups: vec![vec![0], vec![1]], blocks: vec![vec![0]] };
        let err = ledger.record(&wrong_classes, 3).unwrap_err();
        assert!(err.to_string().contains("group classes"), "unexpected error: {err}");
        let oob = Selection { groups: vec![vec![7]], blocks: vec![vec![7]] };
        let err = ledger.record(&oob, 3).unwrap_err();
        assert!(err.to_string().contains("out of range"), "unexpected error: {err}");
        let err = ledger.record_staleness(&oob, 3, 0.5).unwrap_err();
        assert!(err.to_string().contains("out of range"), "unexpected error: {err}");
        // nothing was partially recorded
        assert_eq!(ledger.count_range(), (0, 0));
        assert_eq!(ledger.staleness_index(), 0.0);
    }

    #[test]
    fn spread_index_is_dimensionless_count_spread() {
        let info = toy_info();
        let mut ledger = BlockLedger::new(&info).unwrap();
        assert_eq!(ledger.spread_index(), 0.0, "empty ledger has no spread");
        let sel = ledger.select_for_width(&info, 1).unwrap();
        ledger.record(&sel, 8).unwrap();
        // counts [8, 0] -> spread (8-0)/8 = 1
        assert_eq!(ledger.spread_index(), 1.0);
        let sel2 = ledger.select_for_width(&info, 1).unwrap();
        ledger.record(&sel2, 8).unwrap();
        // balanced [8, 8] -> 0
        assert_eq!(ledger.spread_index(), 0.0);
    }

    #[test]
    fn count_range_tracks_extremes() {
        let info = toy_info();
        let mut ledger = BlockLedger::new(&info).unwrap();
        assert_eq!(ledger.count_range(), (0, 0));
        let sel = ledger.select_for_width(&info, 1).unwrap();
        ledger.record(&sel, 9).unwrap();
        assert_eq!(ledger.count_range(), (0, 9));
    }
}
