//! Fault policies and the resilience ledger (`--fault-policy`).
//!
//! `simulation::faults` draws *what* goes wrong — this layer decides
//! *what the coordinator does about it*, per fault class:
//!
//! * [`FaultAction::Retry`] — pay for the failed attempts on the
//!   virtual clock: each of the event's `severity` attempts burns
//!   `frac · completion` of wasted work plus an exponential backoff
//!   (`backoff · 2^i`), all added to the task's completion. At most
//!   `budget` retries are paid per client per round; a severity beyond
//!   the budget abandons the task (it re-plans like a dropout). A
//!   transient partition under `Retry` simply waits the stall out.
//! * [`FaultAction::Replan`] — don't wait: the task is abandoned the
//!   moment the fault manifests and the round re-plans over the
//!   survivor set through the existing dropout machinery
//!   (`finish_dispatched_round` / the quorum never-arriving-straggler
//!   path).
//! * [`FaultAction::Fail`] — any observed fault of the class aborts the
//!   run with a typed [`ResilienceError::FaultAbort`].
//!
//! Every decision here is resolved **at stamp time**, before any worker
//! touches the task: retry counts, backoff delays and abandon instants
//! are plan facts derived from `(fault schedule, policy)`, never from
//! worker timing — so faulted runs stay byte-identical across
//! `--workers`/`--pool`/`--overlap` and the whole subsystem inherits
//! the scenario engine's determinism contract. A task that the dropout
//! schedule already kills *masks* its fault draw (the dropout wins; the
//! ledger books the event as injected-but-unobserved).
//!
//! The [`ResilienceLedger`] counts injected / observed / retried /
//! recovered / abandoned per class; it feeds the recorder's run output
//! and the observed fault rate the adaptive quorum controller consumes
//! ([`QuorumSignals::fault_rate`](crate::coordinator::quorum_ctl::QuorumSignals)).

use crate::codec::json::Json;
use crate::simulation::{FaultClass, FaultEvent, FaultsCfg, FAULT_CLASSES};
use anyhow::{anyhow, Result};

/// Typed resilience errors.
#[derive(Debug, Clone, Copy, PartialEq, Eq, thiserror::Error)]
pub enum ResilienceError {
    #[error(
        "round {round}: client {client} hit a `{}` fault under the `fail` policy",
        .class.name()
    )]
    FaultAbort { round: usize, client: usize, class: FaultClass },
}

/// Per-class reaction to an observed fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// bounded retries with virtual-clock exponential backoff
    Retry,
    /// abandon the task and re-plan over the survivor set
    Replan,
    /// abort the run with a typed error
    Fail,
}

impl FaultAction {
    pub fn parse(s: &str) -> Result<FaultAction> {
        match s {
            "retry" => Ok(FaultAction::Retry),
            "replan" => Ok(FaultAction::Replan),
            "fail" => Ok(FaultAction::Fail),
            other => Err(anyhow!("unknown fault action `{other}` (retry|replan|fail)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FaultAction::Retry => "retry",
            FaultAction::Replan => "replan",
            FaultAction::Fail => "fail",
        }
    }
}

/// Largest accepted `--fault-policy budget=N`. The backoff arithmetic is
/// finite for any u32 ([`resolve_fault`]'s exp2 formulation), but a
/// budget past this bound only buys astronomically long virtual delays
/// (2^1024 seconds dwarfs any horizon) and usually signals a typo — so
/// parsing rejects it with a typed error instead of quietly honoring it.
pub const MAX_RETRY_BUDGET: u32 = 1024;

/// The `--fault-policy` knob: per-class actions plus the retry knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPolicyCfg {
    pub exec: FaultAction,
    pub corrupt: FaultAction,
    pub partition: FaultAction,
    /// retries paid per client per round before a `Retry`-class fault
    /// is abandoned
    pub budget: u32,
    /// base backoff (virtual seconds); attempt i waits `backoff · 2^i`
    pub backoff: f64,
}

impl Default for FaultPolicyCfg {
    fn default() -> FaultPolicyCfg {
        FaultPolicyCfg {
            exec: FaultAction::Retry,
            corrupt: FaultAction::Retry,
            partition: FaultAction::Retry,
            budget: 2,
            backoff: 5.0,
        }
    }
}

impl FaultPolicyCfg {
    /// Parse a single action applied to every class (`retry` | `replan`
    /// | `fail`), or comma-separated `<class>=<action>` /
    /// `budget=<N>` / `backoff=<secs>` items, e.g.
    /// `exec=retry,corrupt=replan,budget=3,backoff=2.5`. Unlisted
    /// classes keep their defaults; malformed items are typed errors.
    pub fn parse(s: &str) -> Result<FaultPolicyCfg> {
        let mut cfg = FaultPolicyCfg::default();
        if let Ok(action) = FaultAction::parse(s) {
            cfg.exec = action;
            cfg.corrupt = action;
            cfg.partition = action;
            return Ok(cfg);
        }
        if s.is_empty() {
            return Err(anyhow!(
                "empty --fault-policy (expect retry|replan|fail or <class>=<action>,...)"
            ));
        }
        for item in s.split(',') {
            let Some((key, val)) = item.split_once('=') else {
                return Err(anyhow!(
                    "bad --fault-policy item `{item}` in `{s}` (expect <class>=<action>, \
                     budget=<N> or backoff=<secs>)"
                ));
            };
            match key {
                "exec" => cfg.exec = FaultAction::parse(val)?,
                "corrupt" => cfg.corrupt = FaultAction::parse(val)?,
                "partition" => cfg.partition = FaultAction::parse(val)?,
                "budget" => {
                    cfg.budget = val
                        .parse()
                        .map_err(|_| anyhow!("bad retry budget `{val}` in `{s}`"))?;
                    if cfg.budget > MAX_RETRY_BUDGET {
                        return Err(anyhow!(
                            "retry budget {} exceeds the maximum {MAX_RETRY_BUDGET} \
                             (backoff 2^N virtual seconds is astronomical past it)",
                            cfg.budget
                        ));
                    }
                }
                "backoff" => {
                    let b: f64 = val
                        .parse()
                        .map_err(|_| anyhow!("bad backoff `{val}` in `{s}`"))?;
                    if !(b.is_finite() && b >= 0.0) {
                        return Err(anyhow!("backoff must be a finite non-negative number"));
                    }
                    cfg.backoff = b;
                }
                other => {
                    return Err(anyhow!(
                        "unknown --fault-policy key `{other}` in `{s}` \
                         (exec|corrupt|partition|budget|backoff)"
                    ))
                }
            }
        }
        Ok(cfg)
    }

    pub fn action(&self, class: FaultClass) -> FaultAction {
        match class {
            FaultClass::Exec => self.exec,
            FaultClass::Corrupt => self.corrupt,
            FaultClass::Partition => self.partition,
        }
    }
}

/// A fault resolved onto a dispatched task — the policy decision plus
/// its virtual-clock consequences, fixed at stamp time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultStamp {
    pub event: FaultEvent,
    pub action: FaultAction,
    /// retry attempts actually paid for (≤ the policy budget)
    pub retries: u32,
    /// true: the task completes anyway (its completion already carries
    /// the retry/stall delay); false: the task is lost at `fault_time`
    pub recovered: bool,
    /// virtual seconds into the round at which an unrecovered task is
    /// declared lost (0 when recovered)
    pub fault_time: f64,
}

/// How one stamped task resolves.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultResolution {
    /// the dropout schedule already killed the task; the fault never
    /// manifests
    Masked,
    /// the task completes at `new_completion` (retry/stall paid)
    Recovered { stamp: FaultStamp, new_completion: f64 },
    /// the task is lost at `stamp.fault_time`
    Abandoned { stamp: FaultStamp },
}

/// Resolve one drawn event under a policy — pure in `(event, policy,
/// completion, dropped)`. `completion` is the task's unfaulted virtual
/// completion; `dropped` is whether the dropout schedule already
/// stamped the task. `Err` only under the `fail` action.
pub fn resolve_fault(
    event: FaultEvent,
    policy: &FaultPolicyCfg,
    round: usize,
    client: usize,
    completion: f64,
    dropped: bool,
) -> Result<FaultResolution> {
    if dropped {
        return Ok(FaultResolution::Masked);
    }
    let action = policy.action(event.class);
    // time one failed attempt wastes before the fault manifests
    let attempt = event.frac * completion;
    // cumulative exponential backoff over n retries: backoff · (2^n − 1).
    // exp2 instead of `(1u64 << n) - 1`: the shift is UB-shaped for
    // n ≥ 64 (debug panic, release wrap), while exp2 is finite for every
    // u32 — and bit-identical to the integer formulation wherever both
    // are defined (2^n − 1 is exactly representable for n ≤ 53, and for
    // 53 < n < 64 both round to 2^n under the same nearest-even rule).
    let backoff_sum = |n: u32| policy.backoff * (f64::from(n).exp2() - 1.0);
    let resolution = match action {
        FaultAction::Fail => {
            return Err(ResilienceError::FaultAbort { round, client, class: event.class }.into())
        }
        FaultAction::Replan => FaultResolution::Abandoned {
            stamp: FaultStamp {
                event,
                action,
                retries: 0,
                recovered: false,
                fault_time: attempt,
            },
        },
        FaultAction::Retry => match event.class {
            // a transient partition delays delivery; retrying means
            // waiting the stall out
            FaultClass::Partition => FaultResolution::Recovered {
                stamp: FaultStamp { event, action, retries: 0, recovered: true, fault_time: 0.0 },
                new_completion: completion + event.stall,
            },
            FaultClass::Exec | FaultClass::Corrupt => {
                if event.severity <= policy.budget {
                    // severity failed attempts, then a clean run: pay
                    // severity wasted attempts + backoffs on top of the
                    // full completion
                    let delay = event.severity as f64 * attempt + backoff_sum(event.severity);
                    FaultResolution::Recovered {
                        stamp: FaultStamp {
                            event,
                            action,
                            retries: event.severity,
                            recovered: true,
                            fault_time: 0.0,
                        },
                        new_completion: completion + delay,
                    }
                } else {
                    // budget exhausted: budget+1 failed attempts and
                    // budget backoffs, then give up
                    let spent =
                        (policy.budget + 1) as f64 * attempt + backoff_sum(policy.budget);
                    FaultResolution::Abandoned {
                        stamp: FaultStamp {
                            event,
                            action,
                            retries: policy.budget,
                            recovered: false,
                            fault_time: spent,
                        },
                    }
                }
            }
        },
    };
    Ok(resolution)
}

/// Upload bytes a stamped fault re-bills on top of the planned frame: a
/// *recovered* `corrupt` fault means the client's upload frame failed its
/// integrity check and every retry re-sent the full frame, so the task's
/// measured traffic grows by `retries × up_bytes`. Exec retries re-run
/// compute without re-uploading, partitions stall delivery of the one
/// frame already in flight, and an unrecovered fault never completes its
/// upload — all of those re-bill nothing.
pub fn rebill_for(stamp: &FaultStamp, up_bytes: u64) -> u64 {
    if stamp.recovered && stamp.event.class == FaultClass::Corrupt {
        up_bytes.saturating_mul(u64::from(stamp.retries))
    } else {
        0
    }
}

/// Per-class fault counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ClassCounts {
    /// events the schedule drew for dispatched tasks
    pub injected: u64,
    /// injected minus dropout-masked: faults that actually perturbed
    /// the round
    pub observed: u64,
    /// retry attempts paid on the virtual clock
    pub retried: u64,
    /// observed faults whose task still completed
    pub recovered: u64,
    /// observed faults whose task was lost (retry budget exhausted or
    /// re-planned away)
    pub abandoned: u64,
}

/// Run-level fault accounting, folded at stamp time (plan facts — the
/// totals are order-independent sums over tasks, so any dispatch
/// interleaving books the same ledger).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ResilienceLedger {
    pub exec: ClassCounts,
    pub corrupt: ClassCounts,
    pub partition: ClassCounts,
    /// tasks dispatched while fault injection was on (rate denominator)
    pub dispatched: u64,
    /// upload bytes re-billed for corrupt-frame retransmissions
    /// ([`rebill_for`]) — the traffic accounts' share of fault recovery
    pub rebilled_bytes: u64,
}

impl ResilienceLedger {
    pub fn counts(&self, class: FaultClass) -> &ClassCounts {
        match class {
            FaultClass::Exec => &self.exec,
            FaultClass::Corrupt => &self.corrupt,
            FaultClass::Partition => &self.partition,
        }
    }

    fn counts_mut(&mut self, class: FaultClass) -> &mut ClassCounts {
        match class {
            FaultClass::Exec => &mut self.exec,
            FaultClass::Corrupt => &mut self.corrupt,
            FaultClass::Partition => &mut self.partition,
        }
    }

    /// Observed faults per dispatched task, cumulative — the pressure
    /// signal the adaptive quorum controller consumes.
    pub fn observed_rate(&self) -> f64 {
        if self.dispatched == 0 {
            return 0.0;
        }
        let observed: u64 = FAULT_CLASSES.iter().map(|c| self.counts(*c).observed).sum();
        observed as f64 / self.dispatched as f64
    }

    pub fn is_empty(&self) -> bool {
        *self == ResilienceLedger::default()
    }

    pub fn to_json(&self) -> Json {
        let class_obj = |c: &ClassCounts| {
            Json::obj(vec![
                ("injected", Json::from(c.injected)),
                ("observed", Json::from(c.observed)),
                ("retried", Json::from(c.retried)),
                ("recovered", Json::from(c.recovered)),
                ("abandoned", Json::from(c.abandoned)),
            ])
        };
        Json::obj(vec![
            ("exec", class_obj(&self.exec)),
            ("corrupt", class_obj(&self.corrupt)),
            ("partition", class_obj(&self.partition)),
            ("dispatched", Json::from(self.dispatched)),
            ("rebilled_bytes", Json::from(self.rebilled_bytes)),
            ("observed_fault_rate", Json::from(self.observed_rate())),
        ])
    }
}

/// The per-run fault controller `FlEnv` holds: the schedule, the
/// policy, and the ledger they fold into.
#[derive(Debug, Clone)]
pub struct FaultsCtl {
    cfg: FaultsCfg,
    policy: FaultPolicyCfg,
    seed: u64,
    ledger: ResilienceLedger,
}

impl FaultsCtl {
    pub fn new(cfg: FaultsCfg, policy: FaultPolicyCfg, seed: u64) -> FaultsCtl {
        FaultsCtl { cfg, policy, seed, ledger: ResilienceLedger::default() }
    }

    pub fn is_off(&self) -> bool {
        self.cfg.is_off()
    }

    pub fn ledger(&self) -> &ResilienceLedger {
        &self.ledger
    }

    pub fn observed_fault_rate(&self) -> f64 {
        self.ledger.observed_rate()
    }

    /// Count one round's dispatch into the rate denominator (no-op
    /// while faults are off, preserving the byte-identical ledger).
    pub fn note_dispatched(&mut self, tasks: usize) {
        if !self.is_off() {
            self.ledger.dispatched += tasks as u64;
        }
    }

    /// Book corrupt-retransmission traffic ([`rebill_for`]) into the
    /// ledger. An order-independent sum like every other counter, so any
    /// dispatch interleaving books the same total.
    pub fn note_rebilled(&mut self, bytes: u64) {
        self.ledger.rebilled_bytes += bytes;
    }

    /// Draw and resolve the fault (if any) for one dispatched task,
    /// folding the ledger and returning the stamp plus the possibly
    /// delayed completion. The decision is a pure function of
    /// `(cfg, policy, seed, round, client, completion, dropped)`; the
    /// ledger is an order-independent sum of those decisions.
    pub fn stamp_one(
        &mut self,
        round: usize,
        client: usize,
        completion: f64,
        dropped: bool,
    ) -> Result<Option<(FaultStamp, f64)>> {
        let Some(event) = self.cfg.draw(self.seed, round, client) else {
            return Ok(None);
        };
        let counts = self.ledger.counts_mut(event.class);
        counts.injected += 1;
        let resolution = resolve_fault(event, &self.policy, round, client, completion, dropped)?;
        match resolution {
            FaultResolution::Masked => Ok(None),
            FaultResolution::Recovered { stamp, new_completion } => {
                counts.observed += 1;
                counts.retried += stamp.retries as u64;
                counts.recovered += 1;
                Ok(Some((stamp, new_completion)))
            }
            FaultResolution::Abandoned { stamp } => {
                counts.observed += 1;
                counts.retried += stamp.retries as u64;
                counts.abandoned += 1;
                Ok(Some((stamp, completion)))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn event(class: FaultClass, severity: u32) -> FaultEvent {
        FaultEvent { class, severity, frac: 0.5, stall: 10.0, bit: 7 }
    }

    #[test]
    fn policy_parses_the_documented_grammar() {
        let d = FaultPolicyCfg::default();
        assert_eq!(d.exec, FaultAction::Retry);
        assert_eq!(FaultPolicyCfg::parse("replan").unwrap().corrupt, FaultAction::Replan);
        let c = FaultPolicyCfg::parse("exec=retry,corrupt=replan,budget=3,backoff=2.5").unwrap();
        assert_eq!(c.exec, FaultAction::Retry);
        assert_eq!(c.corrupt, FaultAction::Replan);
        assert_eq!(c.partition, FaultAction::Retry, "unlisted classes keep their default");
        assert_eq!(c.budget, 3);
        assert!((c.backoff - 2.5).abs() < 1e-12);
        for bad in ["", "panic", "exec=panic", "budget=x", "backoff=-1", "fuse=retry"] {
            assert!(FaultPolicyCfg::parse(bad).is_err(), "`{bad}` must be rejected");
        }
        // budgets at/above the shift width are legal inputs (the backoff
        // arithmetic is finite for any accepted N); only past the bound
        // does parsing reject
        assert_eq!(FaultPolicyCfg::parse("budget=64").unwrap().budget, 64);
        assert_eq!(FaultPolicyCfg::parse("budget=200").unwrap().budget, 200);
        assert_eq!(FaultPolicyCfg::parse("budget=1024").unwrap().budget, MAX_RETRY_BUDGET);
        for bad in ["budget=1025", "budget=4000000000"] {
            assert!(FaultPolicyCfg::parse(bad).is_err(), "`{bad}` must be rejected");
        }
    }

    #[test]
    fn retry_recovers_within_budget_and_abandons_beyond_it() {
        let policy = FaultPolicyCfg { budget: 2, backoff: 4.0, ..FaultPolicyCfg::default() };
        // severity 2 ≤ budget 2: recovered, completion carries 2 wasted
        // attempts (2 · 0.5 · 100) plus backoff 4·(2²−1) = 12
        let r = resolve_fault(event(FaultClass::Exec, 2), &policy, 0, 3, 100.0, false).unwrap();
        match r {
            FaultResolution::Recovered { stamp, new_completion } => {
                assert!(stamp.recovered);
                assert_eq!(stamp.retries, 2);
                assert!((new_completion - 212.0).abs() < 1e-9, "got {new_completion}");
            }
            other => panic!("expected recovery, got {other:?}"),
        }
        // severity 3 > budget 2: abandoned after budget+1 attempts and
        // budget backoffs: 3 · 50 + 4·(2²−1) = 162
        let r = resolve_fault(event(FaultClass::Corrupt, 3), &policy, 0, 3, 100.0, false).unwrap();
        match r {
            FaultResolution::Abandoned { stamp } => {
                assert!(!stamp.recovered);
                assert_eq!(stamp.retries, policy.budget, "retries never exceed the budget");
                assert!((stamp.fault_time - 162.0).abs() < 1e-9, "got {}", stamp.fault_time);
            }
            other => panic!("expected abandonment, got {other:?}"),
        }
    }

    #[test]
    fn partition_retry_waits_the_stall_out() {
        let policy = FaultPolicyCfg::default();
        let r =
            resolve_fault(event(FaultClass::Partition, 1), &policy, 0, 0, 100.0, false).unwrap();
        assert_eq!(
            r,
            FaultResolution::Recovered {
                stamp: FaultStamp {
                    event: event(FaultClass::Partition, 1),
                    action: FaultAction::Retry,
                    retries: 0,
                    recovered: true,
                    fault_time: 0.0,
                },
                new_completion: 110.0,
            }
        );
    }

    #[test]
    fn replan_abandons_at_the_manifest_instant() {
        let policy = FaultPolicyCfg::parse("replan").unwrap();
        let r = resolve_fault(event(FaultClass::Exec, 4), &policy, 0, 0, 100.0, false).unwrap();
        match r {
            FaultResolution::Abandoned { stamp } => {
                assert_eq!(stamp.retries, 0);
                assert!((stamp.fault_time - 50.0).abs() < 1e-12);
            }
            other => panic!("expected abandonment, got {other:?}"),
        }
    }

    #[test]
    fn fail_surfaces_a_typed_abort_and_dropouts_mask_faults() {
        let policy = FaultPolicyCfg::parse("fail").unwrap();
        let err =
            resolve_fault(event(FaultClass::Exec, 1), &policy, 4, 9, 100.0, false).unwrap_err();
        assert_eq!(
            err.downcast_ref::<ResilienceError>(),
            Some(&ResilienceError::FaultAbort { round: 4, client: 9, class: FaultClass::Exec })
        );
        // a dropout-stamped task masks its fault — even under `fail`
        let r = resolve_fault(event(FaultClass::Exec, 1), &policy, 4, 9, 100.0, true).unwrap();
        assert_eq!(r, FaultResolution::Masked);
    }

    #[test]
    fn ledger_books_stamp_decisions_order_independently() {
        let cfg = FaultsCfg::parse("exec=0.4,corrupt=0.3,partition=0.4").unwrap();
        let run = |order: &[usize]| {
            let mut ctl = FaultsCtl::new(cfg, FaultPolicyCfg::default(), 11);
            ctl.note_dispatched(order.len());
            for &client in order {
                ctl.stamp_one(0, client, 50.0 + client as f64, false).unwrap();
            }
            *ctl.ledger()
        };
        let fwd: Vec<usize> = (0..64).collect();
        let rev: Vec<usize> = (0..64).rev().collect();
        let a = run(&fwd);
        assert_eq!(a, run(&rev), "ledger must be evaluation-order independent");
        assert!(a.dispatched == 64 && !a.is_empty());
        for class in FAULT_CLASSES {
            let c = a.counts(class);
            assert_eq!(c.observed, c.recovered + c.abandoned, "{class:?}: {c:?}");
            assert!(c.observed <= c.injected);
        }
        assert!(a.observed_rate() > 0.0 && a.observed_rate() <= 1.0);
        // off-ledger: stays default-empty and free of RNG draws
        let mut off = FaultsCtl::new(FaultsCfg::default(), FaultPolicyCfg::default(), 11);
        off.note_dispatched(64);
        for client in 0..64 {
            assert!(off.stamp_one(0, client, 50.0, false).unwrap().is_none());
        }
        assert!(off.ledger().is_empty(), "off must book nothing");
    }

    #[test]
    fn ledger_json_carries_every_counter() {
        let mut ctl = FaultsCtl::new(
            FaultsCfg::parse("exec=1").unwrap(),
            FaultPolicyCfg::default(),
            3,
        );
        ctl.note_dispatched(4);
        for client in 0..4 {
            ctl.stamp_one(0, client, 10.0, client == 0).unwrap();
        }
        let j = ctl.ledger().to_json();
        let exec = j.get("exec").unwrap();
        assert_eq!(exec.get("injected").unwrap().as_u64(), Some(4));
        assert_eq!(exec.get("observed").unwrap().as_u64(), Some(3), "client 0 is masked");
        assert_eq!(j.get("dispatched").unwrap().as_u64(), Some(4));
        assert!(j.get("observed_fault_rate").unwrap().as_f64().unwrap() > 0.0);
    }
}
