//! Client-side variable estimation (paper Alg. 2 lines 7-9) and PS-side
//! aggregation (Alg. 1 line 25).
//!
//! The AOT `probe` executables return the flat gradient of the local loss
//! at given parameters/batch. From three probes a client estimates:
//!
//!   L  = ||∇F(x̄) − ∇F(x̂)|| / ||x̄ − x̂||      (smoothness, line 7)
//!   σ² = ||∇F(x̂;ξ₁) − ∇F(x̂;ξ₂)||² / 2        (gradient variance, line 8)
//!   G² = (||∇F(x̂;ξ₁)||² + ||∇F(x̂;ξ₂)||²)/2   (gradient bound, line 9)
//!
//! (The σ² estimator is the standard unbiased two-sample form of
//! E||∇F(x;ξ) − ∇F(x)||² under independent batches.) The PS averages the
//! per-client values and smooths across rounds with an EMA — edge
//! conditions drift, so fresh rounds should dominate (§V-C).

use crate::coordinator::frequency::Estimates;
use crate::tensor::Tensor;
use crate::util::stats::Ema;

/// One client's probe-derived estimates.
#[derive(Debug, Clone, Copy)]
pub struct ClientEstimates {
    pub l: f64,
    pub sigma_sq: f64,
    pub g_sq: f64,
}

/// Compute the Alg. 2 estimates from three probe gradients.
///
/// * `g_start` — ∇F(x̂; ξ₁) at the received parameters
/// * `g_alt`   — ∇F(x̂; ξ₂) at the received parameters, independent batch
/// * `g_end`   — ∇F(x̄; ξ₁) at the locally-updated parameters
/// * `param_sq_dist` — ||x̄ − x̂||²
pub fn estimate_from_probes(
    g_start: &Tensor,
    g_alt: &Tensor,
    g_end: &Tensor,
    param_sq_dist: f64,
) -> ClientEstimates {
    let g1 = g_start.sq_norm();
    let g2 = g_alt.sq_norm();
    let sigma_sq = g_start.sq_dist(g_alt) / 2.0;
    let g_sq = 0.5 * (g1 + g2);
    let l = if param_sq_dist > 1e-12 {
        (g_end.sq_dist(g_start)).sqrt() / param_sq_dist.sqrt()
    } else {
        0.0
    };
    ClientEstimates { l, sigma_sq, g_sq }
}

/// PS-side aggregator: means over the round's participants, EMA-smoothed
/// across rounds.
#[derive(Debug)]
pub struct EstimateTracker {
    l: Ema,
    sigma_sq: Ema,
    g_sq: Ema,
    loss: Ema,
    seen_any: bool,
}

impl EstimateTracker {
    pub fn new(alpha: f64) -> EstimateTracker {
        EstimateTracker {
            l: Ema::new(alpha),
            sigma_sq: Ema::new(alpha),
            g_sq: Ema::new(alpha),
            loss: Ema::new(alpha),
            seen_any: false,
        }
    }

    /// Fold in one round's client estimates + observed mean training loss.
    pub fn update(&mut self, clients: &[ClientEstimates], mean_loss: f64) {
        if !clients.is_empty() {
            let n = clients.len() as f64;
            let ml = clients.iter().map(|c| c.l).sum::<f64>() / n;
            let ms = clients.iter().map(|c| c.sigma_sq).sum::<f64>() / n;
            let mg = clients.iter().map(|c| c.g_sq).sum::<f64>() / n;
            // discard degenerate L (all-zero probes) rather than poison the EMA
            if ml.is_finite() && ml > 0.0 {
                self.l.push(ml);
            }
            if ms.is_finite() {
                self.sigma_sq.push(ms);
            }
            if mg.is_finite() {
                self.g_sq.push(mg);
            }
            self.seen_any = true;
        }
        if mean_loss.is_finite() && mean_loss > 0.0 {
            self.loss.push(mean_loss);
        }
    }

    /// True once at least one probe round has been folded in — before
    /// that the controller must use the predefined τ (Alg. 1: h = 0 case).
    pub fn ready(&self) -> bool {
        self.seen_any && self.loss.get().is_some()
    }

    /// Current estimates (bootstrap defaults if not ready).
    pub fn current(&self) -> Estimates {
        let loss = self.loss.get().unwrap_or(1.0);
        if !self.seen_any {
            return Estimates::bootstrap(loss);
        }
        Estimates {
            l: self.l.get().unwrap_or(1.0),
            sigma_sq: self.sigma_sq.get().unwrap_or(1.0),
            g_sq: self.g_sq.get().unwrap_or(1.0),
            loss,
        }
        .sanitized()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_math_matches_formulas() {
        let g1 = Tensor::from_vec(&[3], vec![1.0, 0.0, 0.0]);
        let g2 = Tensor::from_vec(&[3], vec![0.0, 1.0, 0.0]);
        let ge = Tensor::from_vec(&[3], vec![3.0, 0.0, 0.0]);
        let e = estimate_from_probes(&g1, &g2, &ge, 4.0);
        assert!((e.sigma_sq - 1.0).abs() < 1e-9); // ||g1-g2||²/2 = 2/2
        assert!((e.g_sq - 1.0).abs() < 1e-9);
        assert!((e.l - 1.0).abs() < 1e-9); // ||ge-g1||/||dx|| = 2/2
    }

    #[test]
    fn zero_distance_gives_zero_l() {
        let g = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let e = estimate_from_probes(&g, &g, &g, 0.0);
        assert_eq!(e.l, 0.0);
        assert_eq!(e.sigma_sq, 0.0);
    }

    #[test]
    fn tracker_bootstraps_then_tracks() {
        let mut t = EstimateTracker::new(0.5);
        assert!(!t.ready());
        let boot = t.current();
        assert_eq!(boot.l, 1.0);
        t.update(&[ClientEstimates { l: 2.0, sigma_sq: 0.3, g_sq: 5.0 }], 2.5);
        assert!(t.ready());
        let cur = t.current();
        assert!((cur.l - 2.0).abs() < 1e-9);
        assert!((cur.loss - 2.5).abs() < 1e-9);
        // EMA moves toward the new value
        t.update(&[ClientEstimates { l: 4.0, sigma_sq: 0.3, g_sq: 5.0 }], 2.0);
        let cur = t.current();
        assert!(cur.l > 2.0 && cur.l < 4.0);
    }

    #[test]
    fn tracker_ignores_degenerate_probes() {
        let mut t = EstimateTracker::new(0.5);
        t.update(&[ClientEstimates { l: 3.0, sigma_sq: 0.1, g_sq: 1.0 }], 2.0);
        let before = t.current().l;
        t.update(&[ClientEstimates { l: 0.0, sigma_sq: 0.1, g_sq: 1.0 }], 2.0);
        assert_eq!(t.current().l, before, "zero L must not poison the EMA");
    }
}
