//! The one text layer: every JSON touchpoint — experiment configs,
//! metrics emission, golden traces, bench snapshots, the artifact
//! manifest — goes through this facade. The backing value type and
//! parser live in `util::json` (now `pub(crate)`); nothing outside
//! `codec/` constructs or walks those internals directly.
//!
//! Serialization streams into any `io::Write` sink (lil-json idiom —
//! an edge client writes frames and text the same way); the
//! `Json::to_string_*` conveniences remain for in-memory use.
//!
//! Fidelity contract (pinned in `util::json` tests): every emitted
//! `f64` reparses to identical bits, and `u64` counters take the
//! lossless `Json::Uint` path — see the backend docs.

pub use crate::util::json::{parse, parse_file, Json, JsonError};

use std::io::{self, Write};
use std::path::Path;

/// Stream a compact document into `w`.
pub fn to_writer<W: Write>(w: &mut W, v: &Json) -> io::Result<()> {
    v.write_to(w, 0, 0)
}

/// Stream a pretty document (2-space indent) into `w`.
pub fn to_writer_pretty<W: Write>(w: &mut W, v: &Json) -> io::Result<()> {
    v.write_to(w, 2, 0)
}

/// Write a pretty document to `path` (the snapshot/golden writer).
pub fn write_file(path: &Path, v: &Json) -> io::Result<()> {
    let mut f = io::BufWriter::new(std::fs::File::create(path)?);
    to_writer_pretty(&mut f, v)?;
    f.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_sinks_match_the_string_serializers() {
        let v = parse(r#"{"a":[1,2.5,null],"big":18446744073709551615,"s":"x"}"#).unwrap();
        let mut compact = Vec::new();
        to_writer(&mut compact, &v).unwrap();
        assert_eq!(String::from_utf8(compact).unwrap(), v.to_string_compact());
        let mut pretty = Vec::new();
        to_writer_pretty(&mut pretty, &v).unwrap();
        assert_eq!(String::from_utf8(pretty).unwrap(), v.to_string_pretty());
    }

    #[test]
    fn write_file_round_trips_through_parse_file() {
        let v = Json::obj(vec![
            ("counter", Json::from(5_000_000_000u64)),
            ("pi", Json::from(std::f64::consts::PI)),
        ]);
        let dir = std::env::temp_dir().join("heroes_codec_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.json");
        write_file(&path, &v).unwrap();
        assert_eq!(parse_file(&path).unwrap(), v);
        let _ = std::fs::remove_file(&path);
    }
}
