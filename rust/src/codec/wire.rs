//! The `HWU1` framed update payload: streaming writer into any
//! `io::Write` sink, exact-round-trip reader with typed [`CodecError`]s.
//! Byte layout and determinism contract: see the module docs in
//! [`crate::codec`].

use super::{quant, CodecError, Encoding};
use crate::tensor::Tensor;
use std::io::{Read, Write};

pub const MAGIC: [u8; 4] = *b"HWU1";
pub const VERSION: u8 = 1;
/// Fixed frame header length in bytes.
pub const HEADER_LEN: usize = 32;

/// Plan-side identity stamped into a frame header.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FrameMeta {
    pub scheme: u8,
    pub round: u32,
    pub client: u64,
}

/// A parsed frame header.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FrameHeader {
    pub scheme: u8,
    pub flags: u8,
    pub round: u32,
    pub client: u64,
    pub tensors: u32,
    pub body_len: u64,
}

/// Shape/encoding facts of one decoded section (`stored` = entries
/// physically carried: `len` for raw/q8, `k` for top-k).
#[derive(Debug, Clone, PartialEq)]
pub struct SectionInfo {
    pub tag: u8,
    pub dims: Vec<usize>,
    pub stored: usize,
}

/// A fully decoded frame: header, per-section facts, and the
/// reconstructed (dequantized, densified) tensors ready for the
/// aggregation accumulators.
#[derive(Debug)]
pub struct DecodedUpdate {
    pub header: FrameHeader,
    pub sections: Vec<SectionInfo>,
    pub tensors: Vec<Tensor>,
}

/// Body length of one tensor section (everything after tag/rank/dims).
fn body_len(len: usize, enc: Encoding) -> usize {
    match (enc.topk, enc.q8) {
        (None, false) => 4 * len,
        (None, true) => 8 + len,
        (Some(r), false) => {
            let k = quant::k_of(len, r);
            4 + 4 * k + 4 * k
        }
        (Some(r), true) => {
            let k = quant::k_of(len, r);
            4 + 8 + 4 * k + k
        }
    }
}

/// Encoded length of one tensor section — a pure function of shape and
/// encoding (top-k's k depends only on `len`), never of the data.
pub fn section_len(shape: &[usize], enc: Encoding) -> usize {
    4 + 4 * shape.len() + body_len(shape.iter().product(), enc)
}

/// Total frame length for an update whose tensors have these shapes.
/// This is what the planner bills ν and the traffic meter from *before*
/// training; [`encode_update`] is guaranteed to produce exactly this
/// many bytes.
pub fn frame_len_for_shapes<'a, I>(shapes: I, enc: Encoding) -> usize
where
    I: IntoIterator<Item = &'a [usize]>,
{
    HEADER_LEN + shapes.into_iter().map(|s| section_len(s, enc)).sum::<usize>()
}

/// Stream one update frame into `w`; returns the frame length written.
pub fn encode_update<W: Write>(
    w: &mut W,
    meta: &FrameMeta,
    enc: Encoding,
    tensors: &[Tensor],
) -> Result<usize, CodecError> {
    let body: u64 = tensors.iter().map(|t| section_len(t.shape(), enc) as u64).sum();
    w.write_all(&MAGIC)?;
    w.write_all(&[VERSION, meta.scheme, enc.flags(), 0])?;
    w.write_all(&meta.round.to_le_bytes())?;
    w.write_all(&meta.client.to_le_bytes())?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    w.write_all(&body.to_le_bytes())?;
    for t in tensors {
        write_section(w, t, enc)?;
    }
    Ok(HEADER_LEN + body as usize)
}

#[allow(clippy::indexing_slicing)]
// hlint::allow(panic_path, item): every `data[i]` draws i from `top_k_indices`, which returns indices < data.len() by contract (pinned in quant's tests)
fn write_section<W: Write>(w: &mut W, t: &Tensor, enc: Encoding) -> Result<(), CodecError> {
    let shape = t.shape();
    let data = t.data();
    // tag mirrors the header flag bits: bit0 q8, bit1 topk
    w.write_all(&[enc.flags(), shape.len() as u8, 0, 0])?;
    for &d in shape {
        w.write_all(&(d as u32).to_le_bytes())?;
    }
    match (enc.topk, enc.q8) {
        (None, false) => {
            for &v in data {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        (None, true) => {
            let (lo, scale, q) = quant::quantize_q8(data);
            w.write_all(&lo.to_le_bytes())?;
            w.write_all(&scale.to_le_bytes())?;
            w.write_all(&q)?;
        }
        (Some(r), false) => {
            let idx = quant::top_k_indices(data, quant::k_of(data.len(), r));
            w.write_all(&(idx.len() as u32).to_le_bytes())?;
            for &i in &idx {
                w.write_all(&(i as u32).to_le_bytes())?;
            }
            for &i in &idx {
                w.write_all(&data[i].to_le_bytes())?;
            }
        }
        (Some(r), true) => {
            let idx = quant::top_k_indices(data, quant::k_of(data.len(), r));
            let kept: Vec<f32> = idx.iter().map(|&i| data[i]).collect();
            let (lo, scale, q) = quant::quantize_q8(&kept);
            w.write_all(&(idx.len() as u32).to_le_bytes())?;
            w.write_all(&lo.to_le_bytes())?;
            w.write_all(&scale.to_le_bytes())?;
            for &i in &idx {
                w.write_all(&(i as u32).to_le_bytes())?;
            }
            w.write_all(&q)?;
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// reading

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    #[allow(clippy::indexing_slicing)]
    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if self.pos + n > self.b.len() {
            return Err(CodecError::Truncated {
                offset: self.pos,
                needed: n,
                have: self.b.len(),
            });
        }
        // hlint::allow(panic_path): range is in bounds by the check above — the only Truncated exit for the whole reader
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Fixed-width take: the typed-error twin of `take` for integer
    /// fields — `take(N)` returns exactly N bytes, so the array copy is
    /// total and no `try_into().unwrap()` is needed.
    fn take_n<const N: usize>(&mut self) -> Result<[u8; N], CodecError> {
        let mut out = [0u8; N];
        out.copy_from_slice(self.take(N)?);
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take_n::<1>()?[0])
    }

    fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take_n()?))
    }

    fn u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take_n()?))
    }

    fn f32(&mut self) -> Result<f32, CodecError> {
        Ok(f32::from_le_bytes(self.take_n()?))
    }
}

/// Parse and validate just the 32-byte header.
pub fn read_header(bytes: &[u8]) -> Result<FrameHeader, CodecError> {
    let mut r = Reader { b: bytes, pos: 0 };
    let magic: [u8; 4] = r.take_n()?;
    if magic != MAGIC {
        return Err(CodecError::BadMagic { found: magic });
    }
    let version = r.u8()?;
    if version != VERSION {
        return Err(CodecError::BadVersion(version));
    }
    let scheme = r.u8()?;
    let flags = r.u8()?;
    let _reserved = r.u8()?;
    let round = r.u32()?;
    let client = r.u64()?;
    let tensors = r.u32()?;
    let body_len = r.u64()?;
    Ok(FrameHeader { scheme, flags, round, client, tensors, body_len })
}

/// Decode one frame back into dense f32 tensors (dequantizing q8,
/// densifying top-k with zeros at the dropped positions). Exact
/// round-trip for raw sections.
#[allow(clippy::indexing_slicing)]
pub fn decode_update(bytes: &[u8]) -> Result<DecodedUpdate, CodecError> {
    let header = read_header(bytes)?;
    let actual = (bytes.len() - HEADER_LEN.min(bytes.len())) as u64;
    if header.body_len != actual {
        return Err(CodecError::LengthMismatch { declared: header.body_len, actual });
    }
    let mut r = Reader { b: bytes, pos: HEADER_LEN };
    let mut sections = Vec::with_capacity(header.tensors as usize);
    let mut tensors = Vec::with_capacity(header.tensors as usize);
    for _ in 0..header.tensors {
        let tag = r.u8()?;
        let rank = r.u8()? as usize;
        let _reserved = r.take(2)?;
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(r.u32()? as usize);
        }
        let len: usize = dims.iter().product();
        let (data, stored) = match tag {
            0 => {
                let mut v = Vec::with_capacity(len);
                for _ in 0..len {
                    v.push(r.f32()?);
                }
                (v, len)
            }
            1 => {
                let lo = r.f32()?;
                let scale = r.f32()?;
                let codes = r.take(len)?;
                (codes.iter().map(|&q| quant::dequantize_q8(lo, scale, q)).collect(), len)
            }
            2 | 3 => {
                let k = r.u32()? as usize;
                if k > len {
                    return Err(CodecError::BadTopK { k, len });
                }
                let mut v = vec![0.0f32; len];
                if tag == 3 {
                    let lo = r.f32()?;
                    let scale = r.f32()?;
                    let mut idx = Vec::with_capacity(k);
                    for _ in 0..k {
                        let i = r.u32()? as usize;
                        if i >= len {
                            return Err(CodecError::BadTopK { k: i, len });
                        }
                        idx.push(i);
                    }
                    let codes = r.take(k)?;
                    for (&i, &q) in idx.iter().zip(codes) {
                        // hlint::allow(panic_path): i < len validated above (BadTopK otherwise)
                        v[i] = quant::dequantize_q8(lo, scale, q);
                    }
                } else {
                    let mut idx = Vec::with_capacity(k);
                    for _ in 0..k {
                        let i = r.u32()? as usize;
                        if i >= len {
                            return Err(CodecError::BadTopK { k: i, len });
                        }
                        idx.push(i);
                    }
                    for &i in &idx {
                        // hlint::allow(panic_path): i < len validated above (BadTopK otherwise)
                        v[i] = r.f32()?;
                    }
                }
                (v, k)
            }
            t => return Err(CodecError::BadSectionTag(t)),
        };
        sections.push(SectionInfo { tag, dims: dims.clone(), stored });
        tensors.push(Tensor::from_vec(&dims, data));
    }
    if r.pos != bytes.len() {
        // sections ended before the declared body did — the header lied
        return Err(CodecError::LengthMismatch {
            declared: header.body_len,
            actual: (r.pos - HEADER_LEN) as u64,
        });
    }
    Ok(DecodedUpdate { header, sections, tensors })
}

// ---------------------------------------------------------------------
// streaming sources
//
// A TCP segment, a pipe buffer or a throttled socket hands the reader
// the frame in arbitrary chunks — possibly split mid-header or
// mid-section. The functions below accumulate exactly one frame and
// then delegate to the slice path above, so every typed error a
// one-shot `decode_update` of the same bytes would produce is produced
// here too (parity pinned in `tests/prop_codec.rs`). The single
// deliberate difference: trailing bytes after the declared body belong
// to the *next* frame on a stream and are left unread, where a one-shot
// slice treats them as `LengthMismatch`.

/// Read up to `buf.len()` bytes from `r`, tolerating short reads;
/// returns the count actually read (short only on clean EOF).
fn read_full<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<usize, CodecError> {
    let mut got = 0usize;
    loop {
        let Some(rest) = buf.get_mut(got..) else { break };
        if rest.is_empty() {
            break;
        }
        match r.read(rest) {
            Ok(0) => break,
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(CodecError::Io(e)),
        }
    }
    Ok(got)
}

/// Read exactly one `HWU1` frame (header + body) from a byte stream.
///
/// `cap` bounds the total frame this reader will buffer
/// ([`CodecError::FrameTooLarge`] otherwise) — the network transport's
/// per-connection backpressure bound. A stream that ends early yields
/// the same typed error a one-shot [`decode_update`] of the bytes
/// received so far would.
pub fn read_frame_from<R: Read>(r: &mut R, cap: u64) -> Result<Vec<u8>, CodecError> {
    let mut head = [0u8; HEADER_LEN];
    let got = read_full(r, &mut head)?;
    if got < HEADER_LEN {
        // delegate the typed error to the slice path: a 32-byte header
        // cannot parse from fewer bytes, so this always errors — but
        // with the same BadMagic/BadVersion/Truncated a one-shot gives
        return match read_header(head.get(..got).unwrap_or(&[])) {
            Err(e) => Err(e),
            Ok(_) => Err(CodecError::Truncated { offset: got, needed: HEADER_LEN, have: got }),
        };
    }
    let header = read_header(&head)?;
    let total = (HEADER_LEN as u64).saturating_add(header.body_len);
    if total > cap {
        return Err(CodecError::FrameTooLarge { declared: total, cap });
    }
    let total = usize::try_from(total)
        .map_err(|_| CodecError::FrameTooLarge { declared: total, cap })?;
    let mut frame = vec![0u8; total];
    let (head_buf, body_buf) = frame.split_at_mut(HEADER_LEN);
    head_buf.copy_from_slice(&head);
    let body_got = read_full(r, body_buf)?;
    if (body_got as u64) < header.body_len {
        // early EOF mid-body: same LengthMismatch as a one-shot decode
        // of the received prefix
        frame.truncate(HEADER_LEN + body_got);
        return match decode_update(&frame) {
            Err(e) => Err(e),
            Ok(_) => Err(CodecError::LengthMismatch {
                declared: header.body_len,
                actual: body_got as u64,
            }),
        };
    }
    Ok(frame)
}

/// Streaming decode: [`read_frame_from`] + [`decode_update`]. Consumes
/// exactly one frame; bytes after it stay on the stream for the next
/// call.
pub fn decode_update_from<R: Read>(r: &mut R, cap: u64) -> Result<DecodedUpdate, CodecError> {
    let frame = read_frame_from(r, cap)?;
    decode_update(&frame)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn meta() -> FrameMeta {
        FrameMeta { scheme: 1, round: 7, client: 42 }
    }

    fn payload(rng: &mut Rng) -> Vec<Tensor> {
        vec![
            Tensor::randn(&[9, 2, 3], 0.5, rng),
            Tensor::randn(&[3, 8], 0.5, rng),
            Tensor::randn(&[5], 0.5, rng),
        ]
    }

    #[test]
    fn raw_round_trip_is_bit_exact_and_lengths_agree() {
        let mut rng = Rng::new(3);
        let ts = payload(&mut rng);
        let enc = Encoding::default();
        let mut buf = Vec::new();
        let n = encode_update(&mut buf, &meta(), enc, &ts).unwrap();
        assert_eq!(n, buf.len());
        assert_eq!(n, frame_len_for_shapes(ts.iter().map(|t| t.shape()), enc));
        let d = decode_update(&buf).unwrap();
        assert_eq!(d.header.scheme, 1);
        assert_eq!(d.header.round, 7);
        assert_eq!(d.header.client, 42);
        assert_eq!(d.header.body_len as usize, buf.len() - HEADER_LEN);
        for (a, b) in ts.iter().zip(&d.tensors) {
            assert_eq!(a.shape(), b.shape());
            assert_eq!(a.data(), b.data(), "raw sections must round-trip bit-exactly");
        }
    }

    #[test]
    fn encoding_is_deterministic_for_identical_inputs() {
        let mut rng = Rng::new(9);
        let ts = payload(&mut rng);
        for enc in [
            Encoding::default(),
            Encoding { q8: true, topk: None },
            Encoding { q8: true, topk: Some(0.2) },
        ] {
            let mut a = Vec::new();
            let mut b = Vec::new();
            encode_update(&mut a, &meta(), enc, &ts).unwrap();
            encode_update(&mut b, &meta(), enc, &ts).unwrap();
            assert_eq!(a, b, "{enc:?}: same (plan, update, cfg) must give same bytes");
        }
    }

    #[test]
    fn q8_and_topk_sections_report_their_stored_counts() {
        let mut rng = Rng::new(5);
        let ts = payload(&mut rng);
        let enc = Encoding { q8: true, topk: Some(0.25) };
        let mut buf = Vec::new();
        encode_update(&mut buf, &meta(), enc, &ts).unwrap();
        let d = decode_update(&buf).unwrap();
        for (t, s) in ts.iter().zip(&d.sections) {
            assert_eq!(s.tag, 3);
            assert_eq!(s.stored, quant::k_of(t.len(), 0.25));
        }
    }

    #[test]
    fn typed_errors_for_malformed_frames() {
        let mut rng = Rng::new(11);
        let ts = payload(&mut rng);
        let mut buf = Vec::new();
        encode_update(&mut buf, &meta(), Encoding::default(), &ts).unwrap();

        let mut bad = buf.clone();
        bad[0] = b'X';
        assert!(matches!(decode_update(&bad), Err(CodecError::BadMagic { .. })));

        let mut bad = buf.clone();
        bad[4] = 9;
        assert!(matches!(decode_update(&bad), Err(CodecError::BadVersion(9))));

        assert!(matches!(
            decode_update(&buf[..HEADER_LEN - 3]),
            Err(CodecError::Truncated { .. })
        ));

        // chop the body: header still declares the full body_len
        assert!(matches!(
            decode_update(&buf[..buf.len() - 5]),
            Err(CodecError::LengthMismatch { .. })
        ));

        // corrupt a section tag
        let mut bad = buf.clone();
        bad[HEADER_LEN] = 200;
        assert!(matches!(decode_update(&bad), Err(CodecError::BadSectionTag(200))));
    }

    /// A reader that hands out its bytes `chunk` at a time — the worst
    /// case a TCP stream can legally present.
    struct Chunked<'a> {
        data: &'a [u8],
        pos: usize,
        chunk: usize,
    }

    impl Read for Chunked<'_> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            let n = self.chunk.min(buf.len()).min(self.data.len() - self.pos);
            buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    #[test]
    fn chunked_reads_match_one_shot_decoding() {
        let mut rng = Rng::new(21);
        let ts = payload(&mut rng);
        for enc in [
            Encoding::default(),
            Encoding { q8: true, topk: None },
            Encoding { q8: true, topk: Some(0.25) },
        ] {
            let mut buf = Vec::new();
            encode_update(&mut buf, &meta(), enc, &ts).unwrap();
            let one = decode_update(&buf).unwrap();
            // chunk sizes spanning "split mid-header" through "one read"
            for chunk in [1, 3, 7, 31, HEADER_LEN, 1024, buf.len()] {
                let mut r = Chunked { data: &buf, pos: 0, chunk };
                let strm = decode_update_from(&mut r, u64::MAX).unwrap();
                assert_eq!(strm.header, one.header, "{enc:?} chunk {chunk}");
                assert_eq!(strm.sections, one.sections, "{enc:?} chunk {chunk}");
                for (a, b) in one.tensors.iter().zip(&strm.tensors) {
                    assert_eq!(a.shape(), b.shape());
                    assert_eq!(a.data(), b.data(), "{enc:?} chunk {chunk}");
                }
            }
        }
    }

    #[test]
    fn chunked_truncation_yields_the_one_shot_typed_errors() {
        let mut rng = Rng::new(23);
        let ts = payload(&mut rng);
        let mut buf = Vec::new();
        encode_update(&mut buf, &meta(), Encoding::default(), &ts).unwrap();
        // cut mid-magic, mid-header, at the body boundary and mid-body:
        // the streaming reader must surface exactly the one-shot error
        for cut in [0, 1, HEADER_LEN - 3, HEADER_LEN, HEADER_LEN + 9, buf.len() - 5] {
            let one = decode_update(&buf[..cut]).unwrap_err();
            let mut r = Chunked { data: &buf[..cut], pos: 0, chunk: 2 };
            let strm = decode_update_from(&mut r, u64::MAX).unwrap_err();
            assert_eq!(format!("{one:?}"), format!("{strm:?}"), "cut {cut}");
        }
        // malformed-but-complete frames error identically through a stream
        let mut bad = buf.clone();
        bad[0] = b'X';
        let mut r = Chunked { data: &bad, pos: 0, chunk: 5 };
        assert!(matches!(
            decode_update_from(&mut r, u64::MAX),
            Err(CodecError::BadMagic { .. })
        ));
    }

    #[test]
    fn frame_cap_bounds_the_stream_buffer() {
        let mut rng = Rng::new(27);
        let ts = payload(&mut rng);
        let mut buf = Vec::new();
        let n = encode_update(&mut buf, &meta(), Encoding::default(), &ts).unwrap();
        let mut r = Chunked { data: &buf, pos: 0, chunk: 64 };
        assert!(matches!(
            read_frame_from(&mut r, (n - 1) as u64),
            Err(CodecError::FrameTooLarge { .. })
        ));
        // an exact cap is enough
        let mut r = Chunked { data: &buf, pos: 0, chunk: 64 };
        assert_eq!(read_frame_from(&mut r, n as u64).unwrap(), buf);
    }

    #[test]
    fn back_to_back_frames_read_one_at_a_time() {
        // trailing bytes belong to the next frame on a stream: two frames
        // concatenated decode sequentially, where the one-shot slice path
        // would (correctly) reject the pair as a LengthMismatch
        let mut rng = Rng::new(29);
        let ts = payload(&mut rng);
        let mut buf = Vec::new();
        encode_update(&mut buf, &meta(), Encoding::default(), &ts).unwrap();
        let first_len = buf.len();
        let meta2 = FrameMeta { scheme: 2, round: 8, client: 43 };
        encode_update(&mut buf, &meta2, Encoding::default(), &ts).unwrap();
        assert!(matches!(decode_update(&buf), Err(CodecError::LengthMismatch { .. })));
        let mut r = Chunked { data: &buf, pos: 0, chunk: 13 };
        let a = decode_update_from(&mut r, u64::MAX).unwrap();
        let b = decode_update_from(&mut r, u64::MAX).unwrap();
        assert_eq!(r.pos, buf.len(), "both frames fully consumed");
        assert_eq!(a.header.client, 42);
        assert_eq!(b.header.client, 43);
        assert_eq!(a.header.body_len as usize, first_len - HEADER_LEN);
    }
}
