//! One codec layer: the update-payload **wire format** (with AnycostFL-
//! style quantization/sparsification) and the **text facade** every JSON
//! touchpoint goes through ([`json`]).
//!
//! Until this layer existed, the repo never serialized a byte: traffic
//! was billed analytically from tensor shapes (`ModelInfo::bytes_*`).
//! The `--codec` knob ([`CodecCfg`]) switches uploads onto real encoded
//! frames, making compression visible to the `TrafficMeter`, to
//! `LinkSample::upload_time` (shorter tails), and therefore to the
//! adaptive `QuorumController` (fewer bytes ⇒ smaller K).
//!
//! # Wire format (`HWU1`, version 1)
//!
//! All integers little-endian. One frame carries one client's full
//! update (the composed low-rank tensor list a scheme uploads).
//!
//! ```text
//! header — 32 bytes
//!   0   magic        4  b"HWU1"
//!   4   version      1  = 1
//!   5   scheme       1  1 = heroes (composed), 2 = dense, 3 = flanc
//!   6   flags        1  bit0 = q8, bit1 = topk
//!   7   reserved     1  = 0
//!   8   round        4  u32, dispatch round of the plan
//!   12  client       8  u64, client id
//!   20  tensors      4  u32, number of per-tensor sections
//!   24  body_len     8  u64, total bytes of all sections (frame length
//!                       minus the 32-byte header — the reader checks it)
//!
//! per-tensor section
//!   +0  tag          1  0 raw | 1 q8 | 2 topk | 3 topk+q8
//!   +1  rank         1
//!   +2  reserved     2  = 0
//!   +4  dims         4·rank  u32 each
//!   +…  body
//!       raw:      len·f32
//!       q8:       lo f32, scale f32, len·u8
//!       topk:     k u32, k·u32 ascending indices, k·f32 values
//!       topk+q8:  k u32, lo f32, scale f32, k·u32 indices, k·u8 values
//! ```
//!
//! # Determinism contract
//!
//! The encoded byte string is a **pure function of `(plan, update,
//! cfg)`**: header fields come from the plan (scheme, round, client),
//! the per-tensor encoding decisions (q8 `lo`/`scale`, the top-k index
//! set with its |value|-desc/index-asc tie-break) are pure functions of
//! the tensor data, and no timestamps, worker ids or iteration order
//! over shared state enter the frame. Hence encoded *sizes* — and with
//! them every virtual-clock and traffic decision — are identical across
//! `--workers`/`--pool`/`--overlap`/`--hierarchy` counts.
//!
//! Moreover the frame **length** depends only on the tensor *shapes*
//! and the encoding (top-k keeps `k = clamp(ceil(R·len), 1, len)`
//! entries regardless of the data), so the planner can bill ν from
//! [`upload_bytes`] before any training happens and the round driver
//! verifies the realized frame matches ([`CodecError::PlannedSizeDrift`]
//! would flag a non-deterministic encoder).
//!
//! `--codec analytic` (the default) bypasses this module entirely on
//! the upload path and is byte-identical to the pre-codec repo — the
//! PR 5/6 goldens keep pinning it.

// The determinism layers promise typed errors, never panics: promote
// slice-index panics to clippy warnings here (CI denies warnings);
// hlint rule P1 enforces the same contract with per-line reasons.
#![warn(clippy::indexing_slicing)]


pub mod json;
pub mod quant;
pub mod wire;

pub use wire::{
    decode_update, encode_update, frame_len_for_shapes, DecodedUpdate, FrameHeader, FrameMeta,
    SectionInfo,
};

use crate::runtime::ParamSpec;
use anyhow::{anyhow, Result};

/// Typed wire-format errors — a malformed frame is a proper `Err`, never
/// a panic.
#[derive(Debug, thiserror::Error)]
pub enum CodecError {
    #[error("bad magic {found:02x?} (want HWU1)")]
    BadMagic { found: [u8; 4] },
    #[error("unsupported wire version {0} (this reader speaks version 1)")]
    BadVersion(u8),
    #[error("truncated frame: offset {offset} + {needed} needed bytes > {have} available")]
    Truncated { offset: usize, needed: usize, have: usize },
    #[error("length mismatch: header declares {declared} body bytes, frame carries {actual}")]
    LengthMismatch { declared: u64, actual: u64 },
    #[error("unknown section encoding tag {0}")]
    BadSectionTag(u8),
    #[error("top-k section declares k={k} over a {len}-element tensor")]
    BadTopK { k: usize, len: usize },
    #[error("encoded frame is {actual} bytes but the plan billed {planned} — the encoder broke the size-is-a-pure-shape-function contract")]
    PlannedSizeDrift { planned: u64, actual: u64 },
    #[error("frame declares {declared} bytes but the stream reader's buffer cap is {cap}")]
    FrameTooLarge { declared: u64, cap: u64 },
    #[error("wire i/o: {0}")]
    Io(#[from] std::io::Error),
}

/// Upload encoding options inside `wire` mode.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Encoding {
    /// per-tensor uint8 affine quantization (lo + scale·q)
    pub q8: bool,
    /// magnitude top-k sparsification: keep `ceil(rate·len)` entries
    /// per tensor (clamped to `[1, len]`), rate ∈ (0, 1]
    pub topk: Option<f64>,
}

impl Encoding {
    /// Header flag byte (bit0 q8, bit1 topk).
    pub fn flags(&self) -> u8 {
        u8::from(self.q8) | (u8::from(self.topk.is_some()) << 1)
    }
}

/// The `--codec` knob: how update uploads are represented and billed.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum CodecCfg {
    /// Bill uploads analytically from tensor shapes (`ModelInfo::bytes_*`)
    /// — byte-identical to the pre-codec repo; nothing is serialized.
    #[default]
    Analytic,
    /// Encode each upload into an `HWU1` frame and bill the meter, the
    /// link ν and the hierarchy backhaul from the real encoded length.
    Wire(Encoding),
}

impl CodecCfg {
    /// Parse `analytic` | `wire` | `wire:q8` | `wire:q8,topk=R` (options
    /// comma-separated, order-free; `topk` alone is allowed too).
    pub fn parse(s: &str) -> Result<CodecCfg> {
        match s {
            "analytic" => return Ok(CodecCfg::Analytic),
            "wire" => return Ok(CodecCfg::Wire(Encoding::default())),
            _ => {}
        }
        let Some(opts) = s.strip_prefix("wire:") else {
            return Err(anyhow!(
                "unknown codec `{s}` (expect analytic | wire | wire:q8 | wire:q8,topk=R)"
            ));
        };
        let mut enc = Encoding::default();
        for opt in opts.split(',') {
            match opt {
                "q8" => enc.q8 = true,
                _ => {
                    let Some(r) = opt.strip_prefix("topk=") else {
                        return Err(anyhow!(
                            "unknown codec option `{opt}` in `{s}` (expect q8 | topk=R)"
                        ));
                    };
                    let rate: f64 = r
                        .parse()
                        .map_err(|_| anyhow!("bad top-k rate `{r}` in `{s}`"))?;
                    if !(rate > 0.0 && rate <= 1.0) {
                        return Err(anyhow!("top-k rate must be in (0, 1], got {rate}"));
                    }
                    enc.topk = Some(rate);
                }
            }
        }
        Ok(CodecCfg::Wire(enc))
    }

    /// Canonical knob string (inverse of [`CodecCfg::parse`]).
    pub fn name(&self) -> String {
        match self {
            CodecCfg::Analytic => "analytic".into(),
            CodecCfg::Wire(e) => match (e.q8, e.topk) {
                (false, None) => "wire".into(),
                (true, None) => "wire:q8".into(),
                (true, Some(r)) => format!("wire:q8,topk={r}"),
                (false, Some(r)) => format!("wire:topk={r}"),
            },
        }
    }

    /// The wire encoding, if this config serializes uploads.
    pub fn encoding(&self) -> Option<Encoding> {
        match self {
            CodecCfg::Analytic => None,
            CodecCfg::Wire(e) => Some(*e),
        }
    }
}

/// Scheme tag for the frame header.
pub mod scheme_id {
    pub const HEROES: u8 = 1;
    pub const DENSE: u8 = 2;
    pub const FLANC: u8 = 3;
}

/// Upload bytes one width-p update is billed at: the analytic shape
/// count in `analytic` mode, the exact `HWU1` frame length in `wire`
/// modes. Pure in `(specs, codec)` — the same function prices the plan's
/// ν, the dispatched task and the traffic meter, so they can never
/// disagree. Returns `u64`: this is the boundary where in-memory shape
/// counts become *billed* bytes, and billed bytes never truncate.
// hlint::allow(truncating_cast): the `usize` param is the *entry* to the billed-byte domain — an in-memory analytic shape count, widened to u64 on every return path below
pub fn upload_bytes(specs: &[ParamSpec], analytic_bytes: usize, codec: CodecCfg) -> u64 {
    match codec {
        CodecCfg::Analytic => analytic_bytes as u64,
        CodecCfg::Wire(enc) => {
            wire::frame_len_for_shapes(specs.iter().map(|s| s.shape.as_slice()), enc) as u64
        }
    }
}

/// Number of frame-prefix bits [`corrupt_frame`] targets: the 4-byte
/// magic plus the version byte.
pub const CORRUPTIBLE_PREFIX_BITS: u64 = 40;

/// Flip one bit of an encoded frame's magic/version prefix — the
/// fault-injection layer's `corrupt` class (`simulation::faults`). The
/// drawn `bit` is reduced `mod` [`CORRUPTIBLE_PREFIX_BITS`], so *any*
/// u64 draw lands inside the 5 prefix bytes and the subsequent
/// [`decode_update`] is guaranteed to fail with a typed
/// [`CodecError::BadMagic`] or [`CodecError::BadVersion`] — never a
/// silent mis-decode. No-op on a frame shorter than the prefix (the
/// reader already rejects those as truncated).
pub fn corrupt_frame(frame: &mut [u8], bit: u64) {
    let bit = (bit % CORRUPTIBLE_PREFIX_BITS) as usize;
    let (byte, shift) = (bit / 8, bit % 8);
    if let Some(b) = frame.get_mut(byte) {
        *b ^= 1 << shift;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_knob_parses_the_documented_grammar() {
        assert_eq!(CodecCfg::parse("analytic").unwrap(), CodecCfg::Analytic);
        assert_eq!(
            CodecCfg::parse("wire").unwrap(),
            CodecCfg::Wire(Encoding { q8: false, topk: None })
        );
        assert_eq!(
            CodecCfg::parse("wire:q8").unwrap(),
            CodecCfg::Wire(Encoding { q8: true, topk: None })
        );
        assert_eq!(
            CodecCfg::parse("wire:q8,topk=0.25").unwrap(),
            CodecCfg::Wire(Encoding { q8: true, topk: Some(0.25) })
        );
        assert_eq!(
            CodecCfg::parse("wire:topk=0.5").unwrap(),
            CodecCfg::Wire(Encoding { q8: false, topk: Some(0.5) })
        );
        for bad in ["", "wired", "wire:", "wire:q9", "wire:topk=0", "wire:topk=1.5", "wire:topk=x"]
        {
            assert!(CodecCfg::parse(bad).is_err(), "`{bad}` must be rejected");
        }
    }

    #[test]
    fn knob_name_is_parse_inverse() {
        for s in ["analytic", "wire", "wire:q8", "wire:q8,topk=0.25", "wire:topk=0.5"] {
            let c = CodecCfg::parse(s).unwrap();
            assert_eq!(CodecCfg::parse(&c.name()).unwrap(), c, "{s}");
            assert_eq!(c.name(), s);
        }
    }

    #[test]
    fn every_corruptible_bit_surfaces_a_typed_decode_error() {
        // the corrupt fault class must *demonstrably* exercise the typed
        // decode-error path: whatever u64 the fault schedule draws, the
        // flipped prefix bit makes the reader reject the frame
        use crate::util::rng::Rng;
        let mut rng = Rng::new(11);
        let ts = vec![crate::tensor::Tensor::randn(&[4, 3], 0.5, &mut rng)];
        let meta = FrameMeta { scheme: scheme_id::HEROES, round: 2, client: 9 };
        let mut clean = Vec::new();
        encode_update(&mut clean, &meta, Encoding::default(), &ts).unwrap();
        for bit in 0..CORRUPTIBLE_PREFIX_BITS {
            // offset by a multiple of the modulus: reduction must land on
            // the same prefix bit for any draw
            for draw in [bit, bit + 5 * CORRUPTIBLE_PREFIX_BITS] {
                let mut poisoned = clean.clone();
                corrupt_frame(&mut poisoned, draw);
                assert_ne!(poisoned, clean, "bit {draw} must change the frame");
                let err = decode_update(&poisoned).expect_err("corrupted frame must not decode");
                assert!(
                    matches!(err, CodecError::BadMagic { .. } | CodecError::BadVersion(_)),
                    "bit {draw}: want BadMagic/BadVersion, got {err}"
                );
            }
        }
    }

    #[test]
    fn upload_bytes_analytic_passthrough_and_wire_measured() {
        let specs = vec![
            ParamSpec { name: "v".into(), shape: vec![9, 2, 3], init_std: 0.1 },
            ParamSpec { name: "b".into(), shape: vec![5], init_std: 0.0 },
        ];
        assert_eq!(upload_bytes(&specs, 777, CodecCfg::Analytic), 777);
        let raw = upload_bytes(&specs, 777, CodecCfg::parse("wire").unwrap());
        // 32-byte frame header + per-tensor (4 + 4·rank) + 4 bytes/elem
        assert_eq!(raw, 32 + (4 + 12 + 54 * 4) + (4 + 4 + 5 * 4));
        let q8 = upload_bytes(&specs, 777, CodecCfg::parse("wire:q8").unwrap());
        assert!(q8 < raw, "q8 ({q8}) must shrink the raw frame ({raw})");
    }
}
