//! AnycostFL-style compression primitives: per-tensor uint8 affine
//! quantization and magnitude top-k sparsification (PAPERS.md).
//!
//! Both are **pure functions of the tensor data** — no RNG, no
//! wall-clock — which is what lets the wire layer promise that encoded
//! bytes are a pure function of `(plan, update, cfg)`.

/// Per-tensor affine q8: `v ≈ lo + scale·q`, `q ∈ 0..=255`, with
/// `lo = min(v)` and `scale = (max − min)/255`. A constant tensor
/// (`max == min`, including the empty one) encodes with `scale = 0` and
/// all-zero codes, reconstructing exactly.
pub fn quantize_q8(data: &[f32]) -> (f32, f32, Vec<u8>) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in data {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if data.is_empty() || lo >= hi {
        return (if data.is_empty() { 0.0 } else { lo }, 0.0, vec![0; data.len()]);
    }
    let scale = ((hi as f64 - lo as f64) / 255.0) as f32;
    let q = data
        .iter()
        .map(|&v| ((v as f64 - lo as f64) / scale as f64).round().clamp(0.0, 255.0) as u8)
        .collect();
    (lo, scale, q)
}

/// Inverse of [`quantize_q8`] for one code.
pub fn dequantize_q8(lo: f32, scale: f32, q: u8) -> f32 {
    lo + scale * q as f32
}

/// The k kept by top-k at `rate` over a `len`-element tensor:
/// `clamp(ceil(rate·len), 1, len)` (0 only for the empty tensor).
pub fn k_of(len: usize, rate: f64) -> usize {
    if len == 0 {
        return 0;
    }
    ((rate * len as f64).ceil() as usize).clamp(1, len)
}

/// Indices of the k largest-|v| entries, returned **ascending** (the
/// wire order). Ties break toward the lower index; `total_cmp` keeps
/// the order total (and thus deterministic) even for NaN payloads.
#[allow(clippy::indexing_slicing)]
// hlint::allow(panic_path, item): the sort comparator only sees indices drawn from `0..data.len()`
pub fn top_k_indices(data: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..data.len()).collect();
    idx.sort_by(|&a, &b| data[b].abs().total_cmp(&data[a].abs()).then(a.cmp(&b)));
    idx.truncate(k);
    idx.sort_unstable();
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q8_error_is_bounded_by_half_a_step() {
        let data: Vec<f32> = (0..1000).map(|i| ((i * 2654435761u64 as usize) % 997) as f32 / 99.7 - 5.0).collect();
        let (lo, scale, q) = quantize_q8(&data);
        for (&v, &code) in data.iter().zip(&q) {
            let err = (v - dequantize_q8(lo, scale, code)).abs();
            assert!(
                err <= 0.5001 * scale + 1e-6,
                "q8 error {err} exceeds scale/2 = {}",
                scale / 2.0
            );
        }
    }

    #[test]
    fn q8_constant_and_empty_tensors_reconstruct_exactly() {
        let (lo, scale, q) = quantize_q8(&[2.5; 7]);
        assert_eq!(scale, 0.0);
        assert!(q.iter().all(|&c| dequantize_q8(lo, scale, c) == 2.5));
        assert_eq!(quantize_q8(&[]), (0.0, 0.0, vec![]));
    }

    #[test]
    fn top_k_picks_magnitudes_with_stable_ties() {
        let data = [0.1f32, -3.0, 0.5, 3.0, -0.5, 2.0];
        // |−3| ties |3| → lower index 1 wins first, both still kept at k=3
        assert_eq!(top_k_indices(&data, 3), vec![1, 3, 5]);
        assert_eq!(top_k_indices(&data, 1), vec![1]);
        // |0.5| ties |−0.5| → index 2 beats 4
        assert_eq!(top_k_indices(&data, 5), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn k_of_clamps_to_at_least_one_and_at_most_len() {
        assert_eq!(k_of(0, 0.5), 0);
        assert_eq!(k_of(10, 0.001), 1);
        assert_eq!(k_of(10, 0.25), 3); // ceil(2.5)
        assert_eq!(k_of(10, 1.0), 10);
    }
}
