//! Flanc — original neural composition [Mei et al., NeurIPS'22].
//!
//! Shared neural basis across all widths, but each width `p` owns a
//! *private* coefficient `u_p ∈ (R, b(p)·O)` per layer: "the coefficients
//! in different shapes do not share any parameter" (paper §VI-B1 ④).
//! Consequently a width's coefficient is only ever trained by clients
//! fast enough to run that width — the very training-starvation problem
//! Heroes' enhanced composition fixes (paper §I). Aggregation: basis
//! averaged over *all* K participants; coefficients averaged within the
//! same-width group only; the global model evaluated at width P.

use crate::baselines::Strategy;
use crate::codec::{scheme_id, CodecCfg};
use crate::config::ExperimentConfig;
use crate::coordinator::assignment::{assign_width, cohort_statuses};
use crate::coordinator::env::FlEnv;
use crate::coordinator::frequency::completion_time;
use crate::coordinator::hierarchy::HierarchyCfg;
use crate::coordinator::round::{
    collect_quorum_round, collect_round, LocalTask, QuorumBatch, RoundDriver, TaskOutcome,
    WireTask,
};
use crate::coordinator::RoundReport;
use crate::model::init_params;
use crate::runtime::{Manifest, ModelInfo};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};

/// Flanc PS state: shared basis + per-width private coefficients.
pub struct FlancServer {
    /// per layer
    bases: Vec<Tensor>,
    /// coeffs[p-1][layer]: width-p coefficient (R, b(p)·O)
    coeffs: Vec<Vec<Tensor>>,
    bias: Tensor,
    driver: RoundDriver,
    family: String,
    lr: f32,
    lr_decay_rounds: usize,
    mu_max: f64,
    tau: usize,
    codec: CodecCfg,
    round: usize,
    /// phase-A output (client, p, μ, ν) awaiting `take_tasks`
    pending: Option<Vec<(usize, usize, f64, f64)>>,
}

impl FlancServer {
    pub fn new(info: &ModelInfo, cfg: &ExperimentConfig, rng: &mut Rng) -> Result<FlancServer> {
        // Basis + bias from the full-width spec; per-width coefficients
        // drawn independently (they share no parameters by construction).
        let full = init_params(
            info.composed_params
                .get(&info.cap_p)
                .ok_or_else(|| anyhow!("no composed params at P"))?,
            rng,
        );
        let l = info.layers.len();
        let bases: Vec<Tensor> = (0..l).map(|i| full[2 * i].clone()).collect();
        let bias = full[2 * l].clone();
        let mut coeffs = Vec::with_capacity(info.cap_p);
        for p in 1..=info.cap_p {
            let specs = info
                .composed_params
                .get(&p)
                .ok_or_else(|| anyhow!("no composed params at p={p}"))?;
            let params = init_params(specs, rng);
            coeffs.push((0..l).map(|i| params[2 * i + 1].clone()).collect());
        }
        Ok(FlancServer {
            bases,
            coeffs,
            bias,
            driver: RoundDriver::new(cfg.workers).with_hierarchy(HierarchyCfg::from_config(cfg)),
            family: cfg.family.clone(),
            lr: cfg.lr,
            lr_decay_rounds: cfg.lr_decay_rounds,
            mu_max: cfg.mu_max,
            tau: cfg.tau_default,
            codec: cfg.codec,
            round: 0,
            pending: None,
        })
    }

    /// Payload for a width-p client: `[v_0, u_p_0, ..., bias]`.
    fn payload(&self, p: usize) -> Vec<Tensor> {
        let mut out = Vec::with_capacity(2 * self.bases.len() + 1);
        for (i, v) in self.bases.iter().enumerate() {
            out.push(v.clone());
            out.push(self.coeffs[p - 1][i].clone());
        }
        out.push(self.bias.clone());
        out
    }

    /// Weighted neural-composition aggregation, shared by the
    /// synchronous (all weights 1 — bit-identical to the old integer-
    /// count arithmetic) and quorum phase-C paths: basis + bias averaged
    /// `Σw·x/Σw` over every folded update, coefficients within
    /// same-width groups only; widths nobody contributed to keep state.
    fn aggregate_weighted<'a>(&mut self, folds: impl Iterator<Item = (&'a TaskOutcome, f32)>) {
        let l = self.bases.len();
        let mut basis_sum: Vec<Tensor> =
            self.bases.iter().map(|v| Tensor::zeros(v.shape())).collect();
        let mut bias_sum = Tensor::zeros(self.bias.shape());
        let mut coeff_sum: Vec<Vec<Tensor>> = self
            .coeffs
            .iter()
            .map(|per| per.iter().map(|u| Tensor::zeros(u.shape())).collect())
            .collect();
        let mut coeff_w = vec![0.0f32; self.coeffs.len()];
        let mut total_w = 0.0f32;
        for (o, w) in folds {
            for i in 0..l {
                basis_sum[i].axpy(w, &o.result.params[2 * i]);
                coeff_sum[o.p - 1][i].axpy(w, &o.result.params[2 * i + 1]);
            }
            bias_sum.axpy(w, &o.result.params[2 * l]);
            coeff_w[o.p - 1] += w;
            total_w += w;
        }

        if total_w > 0.0 {
            let inv = 1.0 / total_w;
            for (i, mut v) in basis_sum.into_iter().enumerate() {
                v.scale(inv);
                self.bases[i] = v;
            }
            bias_sum.scale(inv);
            self.bias = bias_sum;
        }
        for (pi, (per, &wsum)) in coeff_sum.into_iter().zip(&coeff_w).enumerate() {
            if wsum > 0.0 {
                let inv = 1.0 / wsum;
                self.coeffs[pi] = per
                    .into_iter()
                    .map(|mut u| {
                        u.scale(inv);
                        u
                    })
                    .collect();
            }
        }
    }
}

impl Strategy for FlancServer {
    fn name(&self) -> &'static str {
        "flanc"
    }

    fn driver(&self) -> RoundDriver {
        self.driver
    }

    /// Phase A: sampling, statuses and widths (fixed τ, so the entire
    /// plan is outcome-independent).
    fn plan_ahead(&mut self, env: &mut FlEnv) -> Result<()> {
        if self.pending.is_some() {
            return Err(anyhow!("plan_ahead called twice without take_tasks"));
        }
        let clients = env.sample_clients();
        let statuses = cohort_statuses(env, &clients);
        let work = statuses
            .iter()
            .map(|s| {
                let (p, mu) = assign_width(&env.info, s.q_flops, self.mu_max);
                let up = crate::codec::upload_bytes(
                    &env.info.composed_params[&p],
                    env.info.bytes_composed[&p],
                    self.codec,
                );
                let nu = s.link.upload_time(up);
                (s.client, p, mu, nu)
            })
            .collect();
        self.pending = Some(work);
        Ok(())
    }

    /// Phase B: payloads (basis + per-width coefficient) + streams.
    fn take_tasks(&mut self, env: &FlEnv) -> Result<Vec<LocalTask>> {
        let work = self
            .pending
            .take()
            .ok_or_else(|| anyhow!("take_tasks without a preceding plan_ahead"))?;
        let lr_h = crate::coordinator::scheduled_lr(self.lr, self.round, self.lr_decay_rounds);
        let mut tasks = Vec::with_capacity(work.len());
        for &(client, p, mu, nu) in &work {
            tasks.push(LocalTask {
                client,
                p,
                tau: self.tau,
                lr: lr_h,
                train_exec: Manifest::train_name(&self.family, p, true),
                probe_exec: None,
                payload: self.payload(p),
                stream: env.batch_stream(client, self.round)?,
                bytes: env.info.bytes_composed[&p] as u64,
                up_bytes: crate::codec::upload_bytes(
                    &env.info.composed_params[&p],
                    env.info.bytes_composed[&p],
                    self.codec,
                ),
                rebill_bytes: 0,
                wire: self.codec.encoding().map(|enc| WireTask {
                    scheme: scheme_id::FLANC,
                    round: self.round as u32,
                    enc,
                }),
                completion: completion_time(self.tau, mu, nu),
                drop_at: None,
                fault: None,
            });
        }
        Ok(tasks)
    }

    /// Phase C: basis averaged over all K, coefficients within
    /// same-width groups only.
    fn finish_round(&mut self, env: &mut FlEnv, outcomes: Vec<TaskOutcome>) -> Result<RoundReport> {
        self.aggregate_weighted(outcomes.iter().map(|o| (o, 1.0)));
        let report = collect_round(env, self.round, &outcomes, 0.0);
        self.round += 1;
        Ok(report)
    }

    /// Phase C, semi-async: the same aggregation with quorum members at
    /// weight 1 and late arrivals at their staleness weight — a slow
    /// width-group's private coefficient still receives its trainers'
    /// updates rounds later instead of starving.
    fn finish_round_quorum(&mut self, env: &mut FlEnv, batch: QuorumBatch) -> Result<RoundReport> {
        self.aggregate_weighted(
            batch
                .quorum
                .iter()
                .map(|o| (o, 1.0))
                .chain(batch.late.iter().map(|l| (&l.outcome, l.weight))),
        );
        let report = collect_quorum_round(env, &batch, 0.0);
        self.round += 1;
        Ok(report)
    }

    fn evaluate(&self, env: &FlEnv) -> Result<(f64, f64)> {
        let p = env.info.cap_p;
        let params = self.payload(p);
        let mut inputs = params;
        // evaluate_composed expects a ComposedGlobal; reuse the generic
        // param-list evaluation path instead.
        let exec = Manifest::eval_name(&self.family, true);
        env_eval(env, &exec, &mut inputs)
    }
}

/// Evaluate an arbitrary composed param list (helper shared with tests).
fn env_eval(env: &FlEnv, exec: &str, params: &mut [Tensor]) -> Result<(f64, f64)> {
    env.evaluate_param_list(exec, params)
}
