//! Baseline FL schemes (paper §VI-B1) behind a common `Strategy` trait.
//!
//! * `FedAvg`   — full dense model, fixed identical τ.
//! * `ADP`      — full dense model, per-round *identical* τ adapted to a
//!                resource budget (Wang et al., INFOCOM'18).
//! * `HeteroFL` — dense width-pruned sub-models by computation power,
//!                fixed τ, overlap-aware aggregation.
//! * `Flanc`    — original neural composition: shared basis, but each
//!                width owns a private coefficient (no cross-shape
//!                aggregation), fixed τ.
//!
//! Heroes itself (`coordinator::server::HeroesServer`) implements the same
//! trait, so experiment drivers iterate schemes uniformly.

pub mod dense;
pub mod flanc;

pub use dense::{DenseServer, TauPolicy, WidthPolicy};
pub use flanc::FlancServer;

use crate::coordinator::env::FlEnv;
use crate::coordinator::RoundReport;
use anyhow::Result;

/// A federated scheme driving rounds against a shared environment.
pub trait Strategy {
    fn name(&self) -> &'static str;
    /// Execute one synchronous round.
    fn run_round(&mut self, env: &mut FlEnv) -> Result<RoundReport>;
    /// Evaluate the current global model: (test loss, test accuracy).
    fn evaluate(&self, env: &FlEnv) -> Result<(f64, f64)>;
    /// Current block-variance diagnostic (0 for schemes without a ledger).
    fn block_variance(&self) -> f64 {
        0.0
    }
}

impl Strategy for crate::coordinator::server::HeroesServer {
    fn name(&self) -> &'static str {
        "heroes"
    }

    fn run_round(&mut self, env: &mut FlEnv) -> Result<RoundReport> {
        HeroesServer::run_round(self, env)
    }

    fn evaluate(&self, env: &FlEnv) -> Result<(f64, f64)> {
        env.evaluate_composed(&self.global)
    }

    fn block_variance(&self) -> f64 {
        self.ledger.variance()
    }
}

use crate::coordinator::server::HeroesServer;

/// Instantiate a scheme by name ("heroes", "fedavg", "adp", "heterofl",
/// "flanc").
pub fn make_strategy(
    name: &str,
    info: &crate::runtime::ModelInfo,
    cfg: &crate::config::ExperimentConfig,
    rng: &mut crate::util::rng::Rng,
) -> Result<Box<dyn Strategy>> {
    Ok(match name {
        "heroes" => Box::new(HeroesServer::new(info, cfg, rng)?),
        "fedavg" => Box::new(dense::DenseServer::fedavg(info, cfg, rng)?),
        "adp" => Box::new(dense::DenseServer::adp(info, cfg, rng)?),
        "heterofl" => Box::new(dense::DenseServer::heterofl(info, cfg, rng)?),
        "flanc" => Box::new(flanc::FlancServer::new(info, cfg, rng)?),
        other => anyhow::bail!("unknown scheme `{other}`"),
    })
}

/// The five schemes of the paper's evaluation, in figure order.
pub const ALL_SCHEMES: [&str; 5] = ["fedavg", "adp", "heterofl", "flanc", "heroes"];
