//! Baseline FL schemes (paper §VI-B1) behind a common `Strategy` trait.
//!
//! * `FedAvg`   — full dense model, fixed identical τ.
//! * `ADP`      — full dense model, per-round *identical* τ adapted to a
//!                resource budget (Wang et al., INFOCOM'18).
//! * `HeteroFL` — dense width-pruned sub-models by computation power,
//!                fixed τ, overlap-aware aggregation.
//! * `Flanc`    — original neural composition: shared basis, but each
//!                width owns a private coefficient (no cross-shape
//!                aggregation), fixed τ.
//!
//! Heroes itself (`coordinator::server::HeroesServer`) implements the same
//! trait, so experiment drivers iterate schemes uniformly.

pub mod dense;
pub mod flanc;

pub use dense::{DenseServer, TauPolicy, WidthPolicy};
pub use flanc::FlancServer;

use crate::coordinator::env::FlEnv;
use crate::coordinator::quorum_ctl::QuorumSignals;
use crate::coordinator::round::{LocalTask, QuorumBatch, RoundDriver, TaskOutcome};
use crate::coordinator::RoundReport;
use anyhow::Result;

/// A federated scheme driving rounds against a shared environment.
///
/// A round decomposes into three hook phases so the round driver can
/// pipeline consecutive rounds (`coordinator::round`, "Overlapped
/// execution"):
///
/// * [`Strategy::plan_ahead`] (phase A) samples participants, collects
///   statuses and runs any outcome-independent width/τ planning, stashing
///   the pending plan inside the scheme. **Contract:** phase A is the
///   only phase that consumes the env's RNG, and it must not read state
///   that [`Strategy::finish_round`] mutates (global model, estimate
///   trackers, the round counter) — that is what makes `plan_ahead` for
///   round *h+1* commute with `finish_round` for round *h*, keeping
///   overlapped and serial execution byte-identical.
/// * [`Strategy::take_tasks`] (phase B) materializes the pending plan
///   into ordered dispatchable tasks against the scheme's *current*
///   global state (payloads, batch streams, this round's lr).
/// * [`Strategy::finish_round`] (phase C) folds the assignment-ordered
///   outcomes into the global model, the env's meters and the scheme's
///   trackers, emitting the round report.
///
/// [`Strategy::run_round`] is the serial composition A→B→dispatch→C.
pub trait Strategy {
    fn name(&self) -> &'static str;
    /// The scheme's dispatch configuration (worker count).
    fn driver(&self) -> RoundDriver;
    /// Phase A — overlappable planning for the scheme's next round.
    fn plan_ahead(&mut self, env: &mut FlEnv) -> Result<()>;
    /// Phase B — materialize the pending plan into dispatchable tasks.
    fn take_tasks(&mut self, env: &FlEnv) -> Result<Vec<LocalTask>>;
    /// Phase C — aggregate assignment-ordered outcomes, emit the report.
    fn finish_round(&mut self, env: &mut FlEnv, outcomes: Vec<TaskOutcome>) -> Result<RoundReport>;
    /// Phase C, semi-async variant (`RoundDriver::run_quorum`): fold the
    /// quorum members' outcomes at weight 1 plus the due late arrivals at
    /// their staleness weights into the global model. Late outcomes may
    /// stem from *earlier* rounds' plans (`LateArrival::origin_round`) —
    /// schemes whose aggregation needs plan state (Heroes' block
    /// selections) must retain it until every cohort member has merged.
    fn finish_round_quorum(&mut self, env: &mut FlEnv, batch: QuorumBatch) -> Result<RoundReport>;
    /// Execute one synchronous round (A→B→dispatch→C). One definition
    /// for every scheme — the phases are the per-scheme parts. Scenario
    /// churn and fault injection ride the shared policy layer: dropouts
    /// and fault stamps land at dispatch and are resolved by
    /// `round::finish_dispatched_round` (survivors re-plan vs typed
    /// error, per `--dropout-policy`; faulted tasks were already
    /// resolved by `--fault-policy` at stamp time).
    fn run_round(&mut self, env: &mut FlEnv) -> Result<RoundReport> {
        self.plan_ahead(env)?;
        let mut tasks = self.take_tasks(env)?;
        let round = env.stamp_dropouts(&mut tasks);
        env.stamp_faults(&mut tasks, round)?;
        let fates = self.driver().run(env.pool, tasks)?;
        let (survivors, dropped, faulted) = crate::coordinator::round::split_fates(fates);
        crate::coordinator::round::finish_dispatched_round(
            env, self, round, survivors, dropped, faulted,
        )
    }
    /// Evaluate the current global model: (test loss, test accuracy).
    fn evaluate(&self, env: &FlEnv) -> Result<(f64, f64)>;
    /// Current block-variance diagnostic (0 for schemes without a ledger).
    fn block_variance(&self) -> f64 {
        0.0
    }
    /// Fraction of recorded training lost to staleness discounts under
    /// semi-async quorum merges (0 for schemes without a ledger, and in
    /// synchronous / full-quorum runs).
    fn staleness_index(&self) -> f64 {
        0.0
    }
    /// Observed signals for the adaptive quorum controller
    /// (`--quorum auto`): staleness index, β² proxy, smoothness estimate
    /// and planned-count spread — all deterministic virtual-clock state.
    /// Schemes without a ledger report the neutral default, leaving the
    /// controller with the pure ε-margin budget.
    fn quorum_signals(&self) -> QuorumSignals {
        QuorumSignals::default()
    }
}

impl Strategy for crate::coordinator::server::HeroesServer {
    fn name(&self) -> &'static str {
        "heroes"
    }

    fn driver(&self) -> RoundDriver {
        HeroesServer::driver(self)
    }

    fn plan_ahead(&mut self, env: &mut FlEnv) -> Result<()> {
        HeroesServer::plan_ahead(self, env)
    }

    fn take_tasks(&mut self, env: &FlEnv) -> Result<Vec<LocalTask>> {
        HeroesServer::take_tasks(self, env)
    }

    fn finish_round(&mut self, env: &mut FlEnv, outcomes: Vec<TaskOutcome>) -> Result<RoundReport> {
        HeroesServer::finish_round(self, env, outcomes)
    }

    fn finish_round_quorum(&mut self, env: &mut FlEnv, batch: QuorumBatch) -> Result<RoundReport> {
        HeroesServer::finish_round_quorum(self, env, batch)
    }

    fn evaluate(&self, env: &FlEnv) -> Result<(f64, f64)> {
        env.evaluate_composed(&self.global)
    }

    fn block_variance(&self) -> f64 {
        self.ledger.variance()
    }

    fn staleness_index(&self) -> f64 {
        self.ledger.staleness_index()
    }

    fn quorum_signals(&self) -> QuorumSignals {
        HeroesServer::quorum_signals(self)
    }
}

use crate::coordinator::server::HeroesServer;

/// Instantiate a scheme by name ("heroes", "fedavg", "adp", "heterofl",
/// "flanc").
pub fn make_strategy(
    name: &str,
    info: &crate::runtime::ModelInfo,
    cfg: &crate::config::ExperimentConfig,
    rng: &mut crate::util::rng::Rng,
) -> Result<Box<dyn Strategy>> {
    Ok(match name {
        "heroes" => Box::new(HeroesServer::new(info, cfg, rng)?),
        "fedavg" => Box::new(dense::DenseServer::fedavg(info, cfg, rng)?),
        "adp" => Box::new(dense::DenseServer::adp(info, cfg, rng)?),
        "heterofl" => Box::new(dense::DenseServer::heterofl(info, cfg, rng)?),
        "flanc" => Box::new(flanc::FlancServer::new(info, cfg, rng)?),
        other => anyhow::bail!("unknown scheme `{other}`"),
    })
}

/// The five schemes of the paper's evaluation, in figure order.
pub const ALL_SCHEMES: [&str; 5] = ["fedavg", "adp", "heterofl", "flanc", "heroes"];
