//! Dense-model baselines: FedAvg, ADP and HeteroFL as one parameterized
//! server (width policy × τ policy).
//!
//! * FedAvg  (width = Full, τ = Fixed): the reference scheme [McMahan'17].
//! * ADP     (width = Full, τ = Adaptive): per-round identical τ chosen so
//!   the projected slowest participant fits a per-round time budget —
//!   the resource-constrained adaptive control of [Wang'18] reduced to
//!   its time dimension (DESIGN.md §Substitutions).
//! * HeteroFL (width = Greedy, τ = Fixed): width-pruned dense sub-models
//!   by computation power with overlap-aware aggregation [Diao'20].
//!
//! Round execution is delegated to the shared parallel pipeline
//! (`coordinator::round`): this file only plans widths/τ and aggregates.

use crate::baselines::Strategy;
use crate::codec::{scheme_id, CodecCfg};
use crate::config::ExperimentConfig;
use crate::coordinator::aggregate::DenseAccumulator;
use crate::coordinator::assignment::cohort_statuses;
use crate::coordinator::env::FlEnv;
use crate::coordinator::frequency::completion_time;
use crate::coordinator::hierarchy::HierarchyCfg;
use crate::coordinator::round::{
    collect_quorum_round, collect_round, LocalTask, QuorumBatch, RoundDriver, TaskOutcome,
    WireTask,
};
use crate::coordinator::RoundReport;
use crate::model::DenseGlobal;
use crate::runtime::{Manifest, ModelInfo};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};

/// Width assignment policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WidthPolicy {
    /// everyone trains the full width-P model
    Full,
    /// greedy μ ≤ μ^max width by computation power (HeteroFL)
    Greedy,
}

/// Local-update-frequency policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TauPolicy {
    /// fixed identical τ every round (FedAvg, HeteroFL)
    Fixed(usize),
    /// identical τ per round fitted to a round-time budget (ADP)
    Adaptive { round_budget: f64 },
}

/// A dense scheme's pending round: widths + the per-round identical τ,
/// both functions of the sampled statuses only (never of the previous
/// round's outcomes), so phase A computes them in full.
struct PendingDense {
    /// (client, p, μ, ν) per participant, sampling order
    work: Vec<(usize, usize, f64, f64)>,
    tau: usize,
}

/// Parameterized dense-model PS.
pub struct DenseServer {
    pub global: DenseGlobal,
    scheme: &'static str,
    width: WidthPolicy,
    tau: TauPolicy,
    driver: RoundDriver,
    family: String,
    lr: f32,
    lr_decay_rounds: usize,
    mu_max: f64,
    tau_bounds: (usize, usize),
    codec: CodecCfg,
    round: usize,
    /// phase-A output awaiting `take_tasks`
    pending: Option<PendingDense>,
}

impl DenseServer {
    fn new(
        scheme: &'static str,
        width: WidthPolicy,
        tau: TauPolicy,
        info: &ModelInfo,
        cfg: &ExperimentConfig,
        rng: &mut Rng,
    ) -> Result<DenseServer> {
        Ok(DenseServer {
            global: DenseGlobal::init(info, rng)?,
            scheme,
            width,
            tau,
            driver: RoundDriver::new(cfg.workers).with_hierarchy(HierarchyCfg::from_config(cfg)),
            family: cfg.family.clone(),
            lr: cfg.lr,
            lr_decay_rounds: cfg.lr_decay_rounds,
            mu_max: cfg.mu_max,
            tau_bounds: (cfg.tau_min, cfg.tau_max),
            codec: cfg.codec,
            round: 0,
            pending: None,
        })
    }

    pub fn fedavg(info: &ModelInfo, cfg: &ExperimentConfig, rng: &mut Rng) -> Result<DenseServer> {
        Self::new("fedavg", WidthPolicy::Full, TauPolicy::Fixed(cfg.tau_default), info, cfg, rng)
    }

    pub fn adp(info: &ModelInfo, cfg: &ExperimentConfig, rng: &mut Rng) -> Result<DenseServer> {
        // Budget: what the default τ costs a mid-fleet client on the full
        // model — ADP then squeezes τ whenever the round would overshoot.
        let q_mid = crate::simulation::DeviceClass::JetsonTx2.mean_flops();
        let mu_mid = info.flops_dense[&info.cap_p] / q_mid;
        let up_mid = 0.5 * (cfg.up_mbps.0 + cfg.up_mbps.1) * 125_000.0;
        let nu_mid = info.bytes_dense[&info.cap_p] as f64 / up_mid;
        let budget = cfg.tau_default as f64 * mu_mid + nu_mid;
        Self::new(
            "adp", WidthPolicy::Full, TauPolicy::Adaptive { round_budget: budget }, info, cfg, rng,
        )
    }

    pub fn heterofl(info: &ModelInfo, cfg: &ExperimentConfig, rng: &mut Rng) -> Result<DenseServer> {
        Self::new("heterofl", WidthPolicy::Greedy, TauPolicy::Fixed(cfg.tau_default), info, cfg, rng)
    }

    /// Greedy dense width under μ^max (HeteroFL analogue of Alg. 1 l.6-11).
    fn assign_width(&self, info: &ModelInfo, q: f64) -> (usize, f64) {
        match self.width {
            WidthPolicy::Full => (info.cap_p, info.flops_dense[&info.cap_p] / q),
            WidthPolicy::Greedy => {
                let mut p = 1;
                while p < info.cap_p && info.flops_dense[&(p + 1)] / q <= self.mu_max {
                    p += 1;
                }
                (p, info.flops_dense[&p] / q)
            }
        }
    }
}

impl Strategy for DenseServer {
    fn name(&self) -> &'static str {
        self.scheme
    }

    fn driver(&self) -> RoundDriver {
        self.driver
    }

    /// Phase A: sampling, statuses, widths and the per-round identical τ
    /// — nothing here depends on previous outcomes, so the driver may run
    /// it while the previous round drains.
    fn plan_ahead(&mut self, env: &mut FlEnv) -> Result<()> {
        if self.pending.is_some() {
            return Err(anyhow!("plan_ahead called twice without take_tasks"));
        }
        let clients = env.sample_clients();
        let statuses = cohort_statuses(env, &clients);

        // widths + cost components
        let work: Vec<(usize, usize, f64, f64)> = statuses
            .iter()
            .map(|s| {
                let (p, mu) = self.assign_width(&env.info, s.q_flops);
                let up = crate::codec::upload_bytes(
                    &env.info.dense_params[&p],
                    env.info.bytes_dense[&p],
                    self.codec,
                );
                let nu = s.link.upload_time(up);
                (s.client, p, mu, nu)
            })
            .collect();

        // identical τ for everyone
        let tau = match self.tau {
            TauPolicy::Fixed(t) => t,
            TauPolicy::Adaptive { round_budget } => {
                let mu_max = work.iter().map(|w| w.2).fold(0.0, f64::max);
                let nu_max = work.iter().map(|w| w.3).fold(0.0, f64::max);
                let t = ((round_budget - nu_max) / mu_max).floor();
                (t.max(1.0) as usize).clamp(self.tau_bounds.0, self.tau_bounds.1)
            }
        };
        self.pending = Some(PendingDense { work, tau });
        Ok(())
    }

    /// Phase B: payloads + batch streams against the current global.
    fn take_tasks(&mut self, env: &FlEnv) -> Result<Vec<LocalTask>> {
        let PendingDense { work, tau } = self
            .pending
            .take()
            .ok_or_else(|| anyhow!("take_tasks without a preceding plan_ahead"))?;
        let lr_h = crate::coordinator::scheduled_lr(self.lr, self.round, self.lr_decay_rounds);
        let mut tasks = Vec::with_capacity(work.len());
        for &(client, p, mu, nu) in &work {
            tasks.push(LocalTask {
                client,
                p,
                tau,
                lr: lr_h,
                train_exec: Manifest::train_name(&self.family, p, false),
                probe_exec: None,
                payload: self.global.reduced_inputs(&env.info, p)?,
                stream: env.batch_stream(client, self.round)?,
                bytes: env.info.bytes_dense[&p] as u64,
                up_bytes: crate::codec::upload_bytes(
                    &env.info.dense_params[&p],
                    env.info.bytes_dense[&p],
                    self.codec,
                ),
                rebill_bytes: 0,
                wire: self.codec.encoding().map(|enc| WireTask {
                    scheme: scheme_id::DENSE,
                    round: self.round as u32,
                    enc,
                }),
                completion: completion_time(tau, mu, nu),
                drop_at: None,
                fault: None,
            });
        }
        Ok(tasks)
    }

    /// Phase C: overlap-aware aggregation in assignment order.
    fn finish_round(&mut self, env: &mut FlEnv, outcomes: Vec<TaskOutcome>) -> Result<RoundReport> {
        let info = env.info.clone();
        let mut acc = DenseAccumulator::new(&info, &self.global);
        for o in &outcomes {
            acc.push(o.p, &o.result.params)?;
        }
        self.global = acc.finalize()?;

        let report = collect_round(env, self.round, &outcomes, 0.0);
        self.round += 1;
        Ok(report)
    }

    /// Phase C, semi-async: the overlap-aware weighted average — quorum
    /// members at weight 1, late arrivals at their staleness weight.
    /// Dense aggregation needs only each outcome's width, so no plan
    /// retention is required.
    fn finish_round_quorum(&mut self, env: &mut FlEnv, batch: QuorumBatch) -> Result<RoundReport> {
        let info = env.info.clone();
        let mut acc = DenseAccumulator::new(&info, &self.global);
        for o in &batch.quorum {
            acc.push_weighted(o.p, &o.result.params, 1.0)?;
        }
        for late in &batch.late {
            acc.push_weighted(late.outcome.p, &late.outcome.result.params, late.weight)?;
        }
        self.global = acc.finalize()?;

        let report = collect_quorum_round(env, &batch, 0.0);
        self.round += 1;
        Ok(report)
    }

    fn evaluate(&self, env: &FlEnv) -> Result<(f64, f64)> {
        env.evaluate_dense(&self.global)
    }
}
