//! Experiment harnesses: one per paper table/figure (see DESIGN.md's
//! experiment index) plus the generic scheme runner.

pub mod figures;
pub mod runner;

pub use figures::{run_experiment, ExpCtx, ALL_EXPERIMENTS};
pub use runner::{run_scheme, run_schemes, StopCondition};
