//! Harnesses regenerating every table and figure of the paper's
//! evaluation (§VI). Each harness runs the relevant schemes under one
//! shared configuration, prints the same rows/series the paper reports,
//! and persists raw series + a summary JSON under the results directory.
//!
//! Absolute numbers live on this testbed's scale (synthetic data, scaled
//! bandwidth — DESIGN.md §Substitutions); the *shape* — who wins, by
//! roughly what factor, where the crossovers fall — is the reproduction
//! target (EXPERIMENTS.md records paper-vs-measured per experiment).

// Outside the determinism layers (CONTRIBUTING.md): CLI surface,
// report generation and dev tooling may panic on programmer error.
#![allow(clippy::unwrap_used, clippy::expect_used)]


use crate::baselines::ALL_SCHEMES;
use crate::config::{ExperimentConfig, Partition, Scale};
use crate::coordinator::env::FlEnv;
use crate::experiments::runner::{run_scheme, run_schemes, StopCondition};
use crate::metrics::Recorder;
use crate::runtime::EnginePool;
use crate::codec::json::Json;
use crate::util::cli::Args;
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Experiment context shared by all harnesses.
pub struct ExpCtx<'e> {
    pub pool: &'e EnginePool,
    pub scale: Scale,
    pub args: Args,
    pub out_dir: PathBuf,
}

impl<'e> ExpCtx<'e> {
    /// Config resolution order: preset(family, scale) <- --config file
    /// (JSON, same keys) <- CLI flags.
    pub fn cfg(&self, family: &str) -> Result<ExperimentConfig> {
        let base = if let Some(path) = self.args.get("config") {
            let doc = crate::codec::json::parse_file(std::path::Path::new(path))?;
            ExperimentConfig::from_json(family, self.scale, &doc)?
        } else {
            ExperimentConfig::preset(family, self.scale)
        };
        base.apply_args(&self.args)
    }

    fn write_summary(&self, name: &str, summary: Json) -> Result<()> {
        std::fs::create_dir_all(&self.out_dir)?;
        let path = self.out_dir.join(format!("{name}_summary.json"));
        std::fs::write(&path, summary.to_string_pretty())?;
        println!("  -> {}", path.display());
        Ok(())
    }
}

/// All experiment ids, in paper order.
pub const ALL_EXPERIMENTS: [&str; 12] = [
    "table1", "fig2", "fig4a", "fig4b", "fig5a", "fig5b", "fig6", "fig7a", "fig7b", "fig8",
    "fig9", "e2e",
];

/// Dispatch by experiment id.
pub fn run_experiment(name: &str, ctx: &ExpCtx) -> Result<()> {
    match name {
        "table1" => table1(ctx),
        "fig2" => fig2(ctx),
        "fig4a" => fig4(ctx, "cnn", "fig4a"),
        "fig4b" => fig4(ctx, "resnet", "fig4b"),
        "fig5a" => fig5(ctx, "cnn", "fig5a"),
        "fig5b" => fig5(ctx, "resnet", "fig5b"),
        "fig6" => fig_resource(ctx, "cnn", "fig6"),
        "fig7a" => fig7(ctx, "cnn", "fig7a"),
        "fig7b" => fig7(ctx, "resnet", "fig7b"),
        "fig8" => fig_resource(ctx, "resnet", "fig8"),
        "fig9" => fig9(ctx),
        "e2e" => e2e(ctx),
        other => Err(anyhow!("unknown experiment `{other}` (one of {ALL_EXPERIMENTS:?})")),
    }
}

fn scheme_json(recs: &[Recorder], f: impl Fn(&Recorder) -> Json) -> Json {
    Json::Obj(recs.iter().map(|r| (r.scheme.clone(), f(r))).collect::<BTreeMap<_, _>>())
}

// ---------------------------------------------------------------------
// Table I — enhanced NC vs original NC vs model pruning under equal
// traffic / time budgets (paper §II-B, ResNet/ImageNet).

fn table1(ctx: &ExpCtx) -> Result<()> {
    println!("== Table I: accuracy within given resource constraints (ResNet twin) ==");
    let cfg = ctx.cfg("resnet")?;
    let schemes = ["heterofl", "flanc", "heroes"]; // MP, original NC, enhanced NC
    let recs = run_schemes(ctx.pool, &cfg, &schemes, StopCondition::default(),
        Some((&ctx.out_dir, "table1")))?;

    // Budgets: 50% / 100% of the *smallest* total consumption across
    // schemes (so every scheme has data at both budgets) — the paper's
    // 30/60 GB and 20k/40k s pairs scaled to this testbed.
    let min_traffic = recs.iter().map(|r| r.samples.last().unwrap().traffic_gb)
        .fold(f64::INFINITY, f64::min);
    let min_time = recs.iter().map(|r| r.samples.last().unwrap().sim_time)
        .fold(f64::INFINITY, f64::min);
    let budgets_gb = [0.5 * min_traffic, min_traffic];
    let budgets_t = [0.5 * min_time, min_time];

    println!("{:<12} | acc@{:.3}GB  acc@{:.3}GB | acc@{:.0}s  acc@{:.0}s",
        "scheme", budgets_gb[0], budgets_gb[1], budgets_t[0], budgets_t[1]);
    let label = |s: &str| match s {
        "heterofl" => "MP",
        "flanc" => "Original NC",
        _ => "Enhanced NC",
    };
    let mut rows = BTreeMap::new();
    for r in &recs {
        let row = [
            r.accuracy_at_traffic(budgets_gb[0]),
            r.accuracy_at_traffic(budgets_gb[1]),
            r.accuracy_at_time(budgets_t[0]),
            r.accuracy_at_time(budgets_t[1]),
        ];
        println!("{:<12} | {:>10.2}% {:>10.2}% | {:>8.2}% {:>8.2}%",
            label(&r.scheme), row[0] * 100.0, row[1] * 100.0, row[2] * 100.0, row[3] * 100.0);
        rows.insert(r.scheme.clone(), Json::from_f64_slice(&row));
    }
    ctx.write_summary("table1", Json::obj(vec![
        ("budgets_gb", Json::from_f64_slice(&budgets_gb)),
        ("budgets_s", Json::from_f64_slice(&budgets_t)),
        ("accuracy", Json::Obj(rows)),
    ]))
}

// ---------------------------------------------------------------------
// Fig. 2 — ranked per-client completion times for one full-participation
// round: (a) identical fixed τ, (b) Heroes' adaptive τ.

fn fig2(ctx: &ExpCtx) -> Result<()> {
    println!("== Fig 2: ranked completion time in one round (fixed vs adaptive τ) ==");
    let mut cfg = ctx.cfg("cnn")?;
    // full participation for the ranking round
    cfg.k_per_round = cfg.n_clients;
    let collect = |scheme: &str| -> Result<Vec<f64>> {
        let mut env = FlEnv::build(ctx.pool, cfg.clone())?;
        let mut rng = Rng::new(cfg.seed ^ 0x5EED);
        let mut s = crate::baselines::make_strategy(scheme, &env.info, &cfg, &mut rng)?;
        // warmup rounds so heroes' estimator is live, then the measured round
        let mut last = None;
        for _ in 0..4 {
            last = Some(s.run_round(&mut env)?);
        }
        let mut times = last.unwrap().completion_times;
        times.sort_by(|a, b| b.partial_cmp(a).unwrap());
        Ok(times)
    };
    let fixed = collect("fedavg")?;
    let adaptive = collect("heroes")?;
    let idle = |ts: &[f64]| {
        let t_max = ts.iter().copied().fold(0.0, f64::max);
        ts.iter().map(|t| (t_max - t) / t_max).sum::<f64>() / ts.len() as f64
    };
    println!("(a) fixed τ   : max {:>7.1}s min {:>7.1}s  mean idle {:.1}%",
        fixed.first().unwrap(), fixed.last().unwrap(), idle(&fixed) * 100.0);
    println!("(b) adaptive τ: max {:>7.1}s min {:>7.1}s  mean idle {:.1}%",
        adaptive.first().unwrap(), adaptive.last().unwrap(), idle(&adaptive) * 100.0);
    ctx.write_summary("fig2", Json::obj(vec![
        ("fixed_sorted_s", Json::from_f64_slice(&fixed)),
        ("adaptive_sorted_s", Json::from_f64_slice(&adaptive)),
        ("fixed_idle_frac", Json::from(idle(&fixed))),
        ("adaptive_idle_frac", Json::from(idle(&adaptive))),
    ]))
}

// ---------------------------------------------------------------------
// Fig. 4 — accuracy-vs-time curves for the five schemes.

fn fig4(ctx: &ExpCtx, family: &str, name: &str) -> Result<()> {
    println!("== {name}: training performance ({family}) ==");
    let cfg = ctx.cfg(family)?;
    let recs = run_schemes(ctx.pool, &cfg, &ALL_SCHEMES, StopCondition::default(),
        Some((&ctx.out_dir, name)))?;
    // print accuracy at quartiles of the shortest total time
    let t_end = recs.iter().map(|r| r.samples.last().unwrap().sim_time).fold(f64::INFINITY, f64::min);
    println!("{:<10} {:>9} {:>9} {:>9} {:>9}", "scheme",
        format!("@{:.0}s", 0.25 * t_end), format!("@{:.0}s", 0.5 * t_end),
        format!("@{:.0}s", 0.75 * t_end), format!("@{:.0}s", t_end));
    for r in &recs {
        println!("{:<10} {:>8.2}% {:>8.2}% {:>8.2}% {:>8.2}%", r.scheme,
            r.accuracy_at_time(0.25 * t_end) * 100.0, r.accuracy_at_time(0.5 * t_end) * 100.0,
            r.accuracy_at_time(0.75 * t_end) * 100.0, r.accuracy_at_time(t_end) * 100.0);
    }
    ctx.write_summary(name, Json::obj(vec![
        ("time_budget_s", Json::from(t_end)),
        ("final_accuracy", scheme_json(&recs, |r| Json::from(r.accuracy_at_time(t_end)))),
        ("curves", scheme_json(&recs, |r| Json::Arr(
            r.samples.iter().map(|s| Json::from_f64_slice(&[s.sim_time, s.test_acc])).collect()))),
    ]))
}

// ---------------------------------------------------------------------
// Fig. 5 — average waiting time per scheme.

fn fig5(ctx: &ExpCtx, family: &str, name: &str) -> Result<()> {
    println!("== {name}: average waiting time ({family}) ==");
    let cfg = ctx.cfg(family)?;
    let recs = run_schemes(ctx.pool, &cfg, &ALL_SCHEMES, StopCondition::default(),
        Some((&ctx.out_dir, name)))?;
    for r in &recs {
        println!("{:<10} mean wait {:>8.2}s", r.scheme, r.mean_wait());
    }
    ctx.write_summary(name, Json::obj(vec![
        ("mean_wait_s", scheme_json(&recs, |r| Json::from(r.mean_wait()))),
    ]))
}

// ---------------------------------------------------------------------
// Fig. 6 / Fig. 8 — traffic and completion time to a target accuracy.

fn fig_resource(ctx: &ExpCtx, family: &str, name: &str) -> Result<()> {
    let cfg = ctx.cfg(family)?;
    let default_target = if ctx.scale == Scale::Smoke { 0.55 } else { 0.65 };
    let target = ctx.args.get_f64("target", default_target)?;
    println!("== {name}: resource consumption to reach {:.0}% ({family}) ==", target * 100.0);
    let stop = StopCondition { accuracy: Some(target), ..Default::default() };
    let recs = run_schemes(ctx.pool, &cfg, &ALL_SCHEMES, stop, Some((&ctx.out_dir, name)))?;
    println!("{:<10} {:>12} {:>12}", "scheme", "traffic(GB)", "time(s)");
    let mut rows = BTreeMap::new();
    for r in &recs {
        let gb = r.traffic_to_accuracy(target);
        let t = r.time_to_accuracy(target);
        println!("{:<10} {:>12} {:>12}", r.scheme,
            gb.map(|x| format!("{x:.4}")).unwrap_or_else(|| "n/r".into()),
            t.map(|x| format!("{x:.0}")).unwrap_or_else(|| "n/r".into()));
        rows.insert(r.scheme.clone(), Json::obj(vec![
            ("traffic_gb", gb.map(Json::from).unwrap_or(Json::Null)),
            ("time_s", t.map(Json::from).unwrap_or(Json::Null)),
            ("final_acc", Json::from(r.final_accuracy())),
        ]));
    }
    ctx.write_summary(name, Json::obj(vec![
        ("target_accuracy", Json::from(target)),
        ("consumption", Json::Obj(rows)),
    ]))
}

// ---------------------------------------------------------------------
// Fig. 7 — accuracy under different Non-IID levels within a time budget.

fn fig7(ctx: &ExpCtx, family: &str, name: &str) -> Result<()> {
    println!("== {name}: Non-IID sweep ({family}) ==");
    let levels = [20.0, 40.0, 60.0, 80.0];
    let mut per_level: BTreeMap<String, Json> = BTreeMap::new();
    let mut rows: BTreeMap<String, Vec<f64>> =
        ALL_SCHEMES.iter().map(|s| (s.to_string(), Vec::new())).collect();
    for &level in &levels {
        let mut cfg = ctx.cfg(family)?;
        cfg.partition = if family == "cnn" {
            Partition::Gamma(level)
        } else {
            Partition::Phi(level / 100.0)
        };
        let recs = run_schemes(ctx.pool, &cfg, &ALL_SCHEMES, StopCondition::default(), None)?;
        let t_budget = recs.iter().map(|r| r.samples.last().unwrap().sim_time)
            .fold(f64::INFINITY, f64::min);
        for r in &recs {
            rows.get_mut(&r.scheme).unwrap().push(r.accuracy_at_time(t_budget));
        }
        per_level.insert(format!("{level}"), Json::from(t_budget));
    }
    println!("{:<10} {:>8} {:>8} {:>8} {:>8}", "scheme", "20", "40", "60", "80");
    for (scheme, accs) in &rows {
        println!("{:<10} {:>7.2}% {:>7.2}% {:>7.2}% {:>7.2}%", scheme,
            accs[0] * 100.0, accs[1] * 100.0, accs[2] * 100.0, accs[3] * 100.0);
    }
    ctx.write_summary(name, Json::obj(vec![
        ("levels", Json::from_f64_slice(&levels)),
        ("time_budgets", Json::Obj(per_level)),
        ("accuracy", Json::Obj(rows.into_iter().map(|(k, v)| (k, Json::from_f64_slice(&v))).collect())),
    ]))
}

// ---------------------------------------------------------------------
// Fig. 9 — RNN / text: time-to-accuracy and traffic.

fn fig9(ctx: &ExpCtx) -> Result<()> {
    let cfg = ctx.cfg("rnn")?;
    let default_target = if ctx.scale == Scale::Smoke { 0.25 } else { 0.35 };
    let target = ctx.args.get_f64("target", default_target)?;
    println!("== fig9: RNN over text, target accuracy {:.0}% ==", target * 100.0);
    let stop = StopCondition { accuracy: Some(target), ..Default::default() };
    let recs = run_schemes(ctx.pool, &cfg, &ALL_SCHEMES, stop, Some((&ctx.out_dir, "fig9")))?;
    println!("{:<10} {:>12} {:>12} {:>10}", "scheme", "time(s)", "traffic(GB)", "final acc");
    let mut rows = BTreeMap::new();
    for r in &recs {
        let t = r.time_to_accuracy(target);
        let gb = r.traffic_to_accuracy(target);
        println!("{:<10} {:>12} {:>12} {:>9.2}%", r.scheme,
            t.map(|x| format!("{x:.0}")).unwrap_or_else(|| "n/r".into()),
            gb.map(|x| format!("{x:.4}")).unwrap_or_else(|| "n/r".into()),
            r.final_accuracy() * 100.0);
        rows.insert(r.scheme.clone(), Json::obj(vec![
            ("time_s", t.map(Json::from).unwrap_or(Json::Null)),
            ("traffic_gb", gb.map(Json::from).unwrap_or(Json::Null)),
            ("final_acc", Json::from(r.final_accuracy())),
        ]));
    }
    ctx.write_summary("fig9", Json::obj(vec![
        ("target_accuracy", Json::from(target)),
        ("results", Json::Obj(rows)),
    ]))
}

// ---------------------------------------------------------------------
// e2e — the end-to-end validation run (EXPERIMENTS.md): Heroes on the
// CNN family for a few hundred rounds, logging the full loss curve.

fn e2e(ctx: &ExpCtx) -> Result<()> {
    println!("== e2e: Heroes end-to-end training run ==");
    let mut cfg = ctx.cfg("cnn")?;
    if ctx.args.get("rounds").is_none() {
        cfg.rounds = if ctx.scale == Scale::Smoke { 150 } else { 400 };
    }
    let rec = run_scheme(ctx.pool, &cfg, "heroes", StopCondition::default())?;
    rec.write_files(&ctx.out_dir, "e2e")?;
    println!("{:>6} {:>10} {:>11} {:>10} {:>9}", "round", "time(s)", "traffic(GB)", "test loss", "acc");
    for s in &rec.samples {
        println!("{:>6} {:>10.1} {:>11.4} {:>10.4} {:>8.2}%",
            s.round, s.sim_time, s.traffic_gb, s.test_loss, s.test_acc * 100.0);
    }
    ctx.write_summary("e2e", Json::obj(vec![
        ("final_accuracy", Json::from(rec.final_accuracy())),
        ("rounds", Json::from(rec.samples.last().map(|s| s.round).unwrap_or(0))),
    ]))
}
