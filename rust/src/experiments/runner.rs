//! Generic experiment runner: drive one scheme against a fresh `FlEnv`
//! for a budgeted number of rounds, evaluating periodically into a
//! `Recorder`. All table/figure harnesses build on this.
//!
//! With `cfg.overlap` the rounds between two evaluation points run
//! through `RoundDriver::run_overlapped` (straggler-overlapped planning
//! over a persistent worker pool); reports are byte-identical either way.
//! With quorum mode active (`--quorum K` or `--quorum auto`) the whole
//! budget runs as **one** semi-async `RoundDriver::run_quorum` pipeline —
//! chunking at evaluation points would discard cross-chunk stragglers —
//! with the evaluation cadence and early-stop budgets riding the
//! driver's per-round observer, which also logs the (possibly adaptive)
//! chosen K at every evaluation point.
//!
//! Churn scenarios (`cfg.scenario`, `simulation::scenario`) need no
//! special handling here: the env applies availability windows and
//! bandwidth traces while planning, the drivers stamp and police
//! mid-round dropouts per `cfg.dropout_policy` — the runner just logs
//! the active scenario so a churned series is never mistaken for a
//! stable one.

use crate::baselines::{make_strategy, Strategy};
use crate::config::ExperimentConfig;
use crate::coordinator::env::FlEnv;
use crate::coordinator::quorum_ctl::QuorumPolicy;
use crate::coordinator::RoundReport;
use crate::metrics::Recorder;
use crate::runtime::EnginePool;
use crate::simulation::Scenario;
use crate::util::rng::Rng;
use anyhow::Result;
use std::path::Path;

/// Early-stop conditions checked at every evaluation point.
#[derive(Debug, Clone, Copy, Default)]
pub struct StopCondition {
    /// stop once simulated time exceeds this (seconds)
    pub sim_time: Option<f64>,
    /// stop once total traffic exceeds this (GB)
    pub traffic_gb: Option<f64>,
    /// stop once test accuracy reaches this
    pub accuracy: Option<f64>,
}

impl StopCondition {
    fn met(&self, sim_time: f64, traffic_gb: f64, acc: f64) -> bool {
        self.sim_time.map(|t| sim_time >= t).unwrap_or(false)
            || self.traffic_gb.map(|t| traffic_gb >= t).unwrap_or(false)
            || self.accuracy.map(|a| acc >= a).unwrap_or(false)
    }
}

/// One evaluation point, shared by the synchronous loop and the quorum
/// observer so the two modes can never record diverging series:
/// evaluate the global model, push the sample, log, and check the stop
/// budgets. Returns `false` once a budget is met.
#[allow(clippy::too_many_arguments)]
fn eval_point(
    env: &FlEnv,
    strategy: &dyn Strategy,
    rec: &mut Recorder,
    scheme: &str,
    round: usize,
    last_train_loss: f64,
    stop: StopCondition,
    quorum_k: Option<usize>,
) -> Result<bool> {
    let (loss, acc) = strategy.evaluate(env)?;
    let t = env.clock.now();
    let gb = env.traffic.total_gb();
    rec.push_eval(round, t, &env.traffic, loss, acc, last_train_loss, strategy.block_variance());
    let stale = strategy.staleness_index();
    // quorum modes log the K the round actually aggregated (the
    // adaptive controller's per-round output; the static knob's clamp)
    let k = quorum_k.map(|k| format!(" K={k}")).unwrap_or_default();
    log::info!(
        "[{scheme}] round {round:>4}: t={t:9.1}s traffic={gb:.4}GB loss={loss:.4} \
         acc={acc:.4} stale={stale:.3}{k}"
    );
    Ok(!stop.met(t, gb, acc))
}

/// Run `scheme` on a fresh environment derived from `cfg`.
///
/// Evaluates at round 0 and then every `cfg.eval_every` rounds (plus a
/// final evaluation), recording the simulated clock and traffic meter at
/// each point. Returns the full series.
pub fn run_scheme(
    pool: &EnginePool,
    cfg: &ExperimentConfig,
    scheme: &str,
    stop: StopCondition,
) -> Result<Recorder> {
    let mut env = FlEnv::build(pool, cfg.clone())?;
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut strategy = make_strategy(scheme, &env.info, cfg, &mut rng)?;
    let mut rec = Recorder::new(scheme);
    if cfg.scenario != Scenario::Stable {
        log::info!(
            "[{scheme}] scenario {} (dropout policy {:?})",
            cfg.scenario.name(),
            cfg.dropout_policy
        );
    }
    if !cfg.faults.is_off() {
        log::info!("[{scheme}] fault injection {} (policy {:?})", cfg.faults.name(), cfg.fault_policy);
    }

    let (loss0, acc0) = strategy.evaluate(&env)?;
    rec.push_eval(0, 0.0, &env.traffic, loss0, acc0, loss0, strategy.block_variance());

    // With overlap, rounds between two evaluation points form one
    // pipelined chunk; otherwise they run one by one. Reports (and thus
    // every evaluation) are byte-identical across both paths. The
    // strategy's own driver is the single source of the worker count.
    let driver = strategy.driver();
    let mut last_train_loss = loss0;

    if let Some(mut policy) = QuorumPolicy::from_config(cfg) {
        // semi-async: one continuous pipeline, evaluation + stop budgets
        // in the observer (module docs)
        let total = cfg.rounds;
        let eval_every = cfg.eval_every;
        let mut observer = |env: &FlEnv, strategy: &dyn Strategy, report: &RoundReport| {
            last_train_loss = report.mean_loss;
            rec.push_round(report);
            let done = report.round + 1;
            if done % eval_every == 0 || done == total {
                // the round's actual quorum size: its reported
                // completion set is exactly the K aggregated members
                let k = report.completion_times.len();
                return eval_point(
                    env, strategy, &mut rec, scheme, done, last_train_loss, stop, Some(k),
                );
            }
            Ok(true)
        };
        driver.run_quorum(
            pool,
            &mut env,
            strategy.as_mut(),
            total,
            &mut policy,
            Some(&mut observer),
        )?;
        if !cfg.faults.is_off() {
            rec.set_resilience(*env.resilience());
        }
        return Ok(rec);
    }

    let mut round = 0usize;
    while round < cfg.rounds {
        let until_eval = cfg.eval_every - round % cfg.eval_every;
        let chunk = until_eval.min(cfg.rounds - round).max(1);
        let reports = if cfg.overlap {
            driver.run_overlapped(pool, &mut env, strategy.as_mut(), chunk)?
        } else {
            let mut out = Vec::with_capacity(chunk);
            for _ in 0..chunk {
                out.push(strategy.run_round(&mut env)?);
            }
            out
        };
        for report in &reports {
            last_train_loss = report.mean_loss;
            rec.push_round(report);
        }
        round += chunk;
        if round % cfg.eval_every == 0 || round == cfg.rounds {
            let go = eval_point(
                &env, strategy.as_ref(), &mut rec, scheme, round, last_train_loss, stop, None,
            )?;
            if !go {
                break;
            }
        }
    }
    if !cfg.faults.is_off() {
        // attach the run's fault accounting; fault-free runs keep the
        // pre-fault output schema byte for byte
        rec.set_resilience(*env.resilience());
    }
    Ok(rec)
}

/// Run several schemes under identical configs; optionally persist each
/// series under `out_dir` with the given file prefix.
pub fn run_schemes(
    pool: &EnginePool,
    cfg: &ExperimentConfig,
    schemes: &[&str],
    stop: StopCondition,
    out: Option<(&Path, &str)>,
) -> Result<Vec<Recorder>> {
    let mut all = Vec::with_capacity(schemes.len());
    for scheme in schemes {
        let rec = run_scheme(pool, cfg, scheme, stop)?;
        if let Some((dir, prefix)) = out {
            rec.write_files(dir, prefix)?;
        }
        all.push(rec);
    }
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stop_condition_logic() {
        let s = StopCondition { sim_time: Some(10.0), traffic_gb: None, accuracy: Some(0.9) };
        assert!(!s.met(5.0, 1.0, 0.5));
        assert!(s.met(11.0, 1.0, 0.5));
        assert!(s.met(5.0, 1.0, 0.95));
        assert!(!StopCondition::default().met(1e9, 1e9, 1.0));
    }
}
