//! Generic experiment runner: drive one scheme against a fresh `FlEnv`
//! for a budgeted number of rounds, evaluating periodically into a
//! `Recorder`. All table/figure harnesses build on this.
//!
//! With `cfg.overlap` the rounds between two evaluation points run
//! through `RoundDriver::run_overlapped` (straggler-overlapped planning
//! over a persistent worker pool); reports are byte-identical either way.
//! With quorum mode active (`--quorum K` or `--quorum auto`) the whole
//! budget runs as **one** semi-async `RoundDriver::run_quorum` pipeline —
//! chunking at evaluation points would discard cross-chunk stragglers —
//! with the evaluation cadence and early-stop budgets riding the
//! driver's per-round observer, which also logs the (possibly adaptive)
//! chosen K at every evaluation point.
//!
//! Churn scenarios (`cfg.scenario`, `simulation::scenario`) need no
//! special handling here: the env applies availability windows and
//! bandwidth traces while planning, the drivers stamp and police
//! mid-round dropouts per `cfg.dropout_policy` — the runner just logs
//! the active scenario so a churned series is never mistaken for a
//! stable one.

use crate::baselines::{make_strategy, Strategy};
use crate::config::ExperimentConfig;
use crate::coordinator::env::FlEnv;
use crate::coordinator::quorum_ctl::QuorumPolicy;
use crate::coordinator::RoundReport;
use crate::metrics::Recorder;
use crate::runtime::EnginePool;
use crate::simulation::Scenario;
use crate::transport::{Transport, TransportCfg};
use crate::util::rng::Rng;
use anyhow::Result;
use std::path::Path;

/// Early-stop conditions checked at every evaluation point.
#[derive(Debug, Clone, Copy, Default)]
pub struct StopCondition {
    /// stop once simulated time exceeds this (seconds)
    pub sim_time: Option<f64>,
    /// stop once total traffic exceeds this (GB)
    pub traffic_gb: Option<f64>,
    /// stop once test accuracy reaches this
    pub accuracy: Option<f64>,
}

impl StopCondition {
    fn met(&self, sim_time: f64, traffic_gb: f64, acc: f64) -> bool {
        self.sim_time.map(|t| sim_time >= t).unwrap_or(false)
            || self.traffic_gb.map(|t| traffic_gb >= t).unwrap_or(false)
            || self.accuracy.map(|a| acc >= a).unwrap_or(false)
    }
}

/// One evaluation point, shared by the synchronous loop and the quorum
/// observer so the two modes can never record diverging series:
/// evaluate the global model, push the sample, log, and check the stop
/// budgets. Returns `false` once a budget is met.
#[allow(clippy::too_many_arguments)]
fn eval_point(
    env: &FlEnv,
    strategy: &dyn Strategy,
    rec: &mut Recorder,
    scheme: &str,
    round: usize,
    last_train_loss: f64,
    stop: StopCondition,
    quorum_k: Option<usize>,
) -> Result<bool> {
    let (loss, acc) = strategy.evaluate(env)?;
    let t = env.clock.now();
    let gb = env.traffic.total_gb();
    rec.push_eval(round, t, &env.traffic, loss, acc, last_train_loss, strategy.block_variance());
    let stale = strategy.staleness_index();
    // quorum modes log the K the round actually aggregated (the
    // adaptive controller's per-round output; the static knob's clamp)
    let k = quorum_k.map(|k| format!(" K={k}")).unwrap_or_default();
    log::info!(
        "[{scheme}] round {round:>4}: t={t:9.1}s traffic={gb:.4}GB loss={loss:.4} \
         acc={acc:.4} stale={stale:.3}{k}"
    );
    Ok(!stop.met(t, gb, acc))
}

/// Run `scheme` on a fresh environment derived from `cfg`.
///
/// Evaluates at round 0 and then every `cfg.eval_every` rounds (plus a
/// final evaluation), recording the simulated clock and traffic meter at
/// each point. Returns the full series.
pub fn run_scheme(
    pool: &EnginePool,
    cfg: &ExperimentConfig,
    scheme: &str,
    stop: StopCondition,
) -> Result<Recorder> {
    let mut env = FlEnv::build(pool, cfg.clone())?;
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut strategy = make_strategy(scheme, &env.info, cfg, &mut rng)?;
    let mut rec = Recorder::new(scheme);
    if cfg.scenario != Scenario::Stable {
        log::info!(
            "[{scheme}] scenario {} (dropout policy {:?})",
            cfg.scenario.name(),
            cfg.dropout_policy
        );
    }
    if !cfg.faults.is_off() {
        log::info!("[{scheme}] fault injection {} (policy {:?})", cfg.faults.name(), cfg.fault_policy);
    }

    let (loss0, acc0) = strategy.evaluate(&env)?;
    rec.push_eval(0, 0.0, &env.traffic, loss0, acc0, loss0, strategy.block_variance());

    // Route the rounds through the configured transport. `sim` keeps
    // the historical entry points (each chunk spawns its in-process
    // worker pool) byte for byte; `tcp` binds a localhost server, runs
    // `workers` loopback executor threads over real sockets, and drives
    // every chunk through one persistent transport. Decisions are
    // transport-independent (see `transport` module docs), so both
    // routes must record identical series.
    match &cfg.transport {
        TransportCfg::Sim => {
            drive_recorded(pool, None, cfg, scheme, stop, &mut env, strategy.as_mut(), &mut rec, loss0)?;
        }
        TransportCfg::Tcp(addr) => {
            #[cfg(feature = "net")]
            {
                log::info!("[{scheme}] transport tcp:{addr} ({} loopback executors)", cfg.workers);
                let tcp = crate::transport::tcp::TcpCfg::new(addr.as_str());
                crate::transport::tcp::with_loopback(pool, cfg.workers, tcp, |tp| {
                    drive_recorded(
                        pool, Some(tp), cfg, scheme, stop, &mut env, strategy.as_mut(), &mut rec,
                        loss0,
                    )
                })?;
            }
            #[cfg(not(feature = "net"))]
            return Err(anyhow::anyhow!(
                "--transport tcp:{addr} needs the `net` cargo feature \
                 (rebuild with `cargo build --features net`)"
            ));
        }
    }
    if !cfg.faults.is_off() {
        // attach the run's fault accounting; fault-free runs keep the
        // pre-fault output schema byte for byte
        rec.set_resilience(*env.resilience());
    }
    Ok(rec)
}

/// The transport-generic round loop behind [`run_scheme`]: quorum mode
/// runs the whole budget as one semi-async pipeline, otherwise rounds
/// between evaluation points form chunks. `net: None` is the historical
/// in-process path (serial, `--overlap`, or quorum worker pools, byte
/// for byte); `net: Some(tp)` drives the same loops over the given
/// transport, which owns the executors for the entire run.
#[allow(clippy::too_many_arguments)]
fn drive_recorded(
    pool: &EnginePool,
    mut net: Option<&mut dyn Transport>,
    cfg: &ExperimentConfig,
    scheme: &str,
    stop: StopCondition,
    env: &mut FlEnv,
    strategy: &mut dyn Strategy,
    rec: &mut Recorder,
    loss0: f64,
) -> Result<()> {
    // With overlap, rounds between two evaluation points form one
    // pipelined chunk; otherwise they run one by one. Reports (and thus
    // every evaluation) are byte-identical across both paths. The
    // strategy's own driver is the single source of the worker count.
    let driver = strategy.driver();
    let mut last_train_loss = loss0;

    if let Some(mut policy) = QuorumPolicy::from_config(cfg) {
        // semi-async: one continuous pipeline, evaluation + stop budgets
        // in the observer (module docs)
        let total = cfg.rounds;
        let eval_every = cfg.eval_every;
        let mut observer = |env: &FlEnv, strategy: &dyn Strategy, report: &RoundReport| {
            last_train_loss = report.mean_loss;
            rec.push_round(report);
            let done = report.round + 1;
            if done % eval_every == 0 || done == total {
                // the round's actual quorum size: its reported
                // completion set is exactly the K aggregated members
                let k = report.completion_times.len();
                return eval_point(
                    env, strategy, rec, scheme, done, last_train_loss, stop, Some(k),
                );
            }
            Ok(true)
        };
        match net.as_deref_mut() {
            Some(tp) => {
                driver.run_quorum_on(tp, env, strategy, total, &mut policy, Some(&mut observer))?;
            }
            None => {
                driver.run_quorum(pool, env, strategy, total, &mut policy, Some(&mut observer))?;
            }
        }
        return Ok(());
    }

    let mut round = 0usize;
    while round < cfg.rounds {
        let until_eval = cfg.eval_every - round % cfg.eval_every;
        let chunk = until_eval.min(cfg.rounds - round).max(1);
        let reports = match net.as_deref_mut() {
            // the networked transport owns the executors; every chunk
            // (overlapped or not — they are byte-identical) rides the
            // transport-generic drive loop
            Some(tp) => driver.run_overlapped_on(tp, env, strategy, chunk)?,
            None if cfg.overlap => driver.run_overlapped(pool, env, strategy, chunk)?,
            None => {
                let mut out = Vec::with_capacity(chunk);
                for _ in 0..chunk {
                    out.push(strategy.run_round(env)?);
                }
                out
            }
        };
        for report in &reports {
            last_train_loss = report.mean_loss;
            rec.push_round(report);
        }
        round += chunk;
        if round % cfg.eval_every == 0 || round == cfg.rounds {
            let go = eval_point(
                env, &*strategy, rec, scheme, round, last_train_loss, stop, None,
            )?;
            if !go {
                break;
            }
        }
    }
    Ok(())
}

/// Run several schemes under identical configs; optionally persist each
/// series under `out_dir` with the given file prefix.
pub fn run_schemes(
    pool: &EnginePool,
    cfg: &ExperimentConfig,
    schemes: &[&str],
    stop: StopCondition,
    out: Option<(&Path, &str)>,
) -> Result<Vec<Recorder>> {
    let mut all = Vec::with_capacity(schemes.len());
    for scheme in schemes {
        let rec = run_scheme(pool, cfg, scheme, stop)?;
        if let Some((dir, prefix)) = out {
            rec.write_files(dir, prefix)?;
        }
        all.push(rec);
    }
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stop_condition_logic() {
        let s = StopCondition { sim_time: Some(10.0), traffic_gb: None, accuracy: Some(0.9) };
        assert!(!s.met(5.0, 1.0, 0.5));
        assert!(s.met(11.0, 1.0, 0.5));
        assert!(s.met(5.0, 1.0, 0.95));
        assert!(!StopCondition::default().met(1e9, 1e9, 1.0));
    }
}
