//! Synthetic federated datasets (substrate — CIFAR-10 / ImageNet-100 /
//! Shakespeare are not available offline; DESIGN.md §Substitutions).
//!
//! * `synth_image` — class-conditioned image generator (CIFAR / ImageNet
//!   twins) with controllable difficulty.
//! * `synth_text` — order-2 Markov character streams (Shakespeare twin)
//!   with per-client chain perturbation for natural Non-IID.
//! * `partition` — the paper's Γ (dominant-class) and φ (missing-class)
//!   Non-IID partition schemes (§VI-A2).
//! * `loader` — per-client shuffled batch iterators feeding PJRT literals.

pub mod loader;
pub mod partition;
pub mod synth_image;
pub mod synth_text;

/// A supervised image dataset in NHWC f32 with int labels.
#[derive(Debug, Clone)]
pub struct ImageSet {
    pub hw: usize,
    pub channels: usize,
    pub classes: usize,
    /// (n, hw, hw, c) row-major
    pub pixels: Vec<f32>,
    pub labels: Vec<i32>,
}

impl ImageSet {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn sample_size(&self) -> usize {
        self.hw * self.hw * self.channels
    }

    pub fn sample(&self, i: usize) -> &[f32] {
        let s = self.sample_size();
        &self.pixels[i * s..(i + 1) * s]
    }
}

/// A character-stream dataset: one token stream per logical shard plus a
/// global test stream.
#[derive(Debug, Clone)]
pub struct TextSet {
    pub vocab: usize,
    /// per-shard token streams (shard = paper's "speaking role")
    pub shards: Vec<Vec<i32>>,
    pub test: Vec<i32>,
}
