//! Order-2 Markov character streams — the Shakespeare twin.
//!
//! A global transition structure maps each character bigram to a small
//! set of plausible next characters (like English orthography does); each
//! shard ("speaking role" in LEAF's Shakespeare split) perturbs the chain
//! with its own style component, which reproduces the natural Non-IID of
//! the original dataset. The entropy of the chain bounds achievable
//! next-char accuracy well above chance, so accuracy curves behave like
//! the paper's Fig. 9.

use super::TextSet;
use crate::util::rng::Rng;

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct TextGen {
    pub vocab: usize,
    /// candidate next-chars per bigram state
    pub branching: usize,
    /// weight of the shard-specific chain vs the global one, in [0,1]
    pub style_weight: f64,
}

impl TextGen {
    /// Shakespeare twin defaults (vocab matches the rnn model spec).
    pub fn shakespeare_twin() -> TextGen {
        TextGen { vocab: 64, branching: 3, style_weight: 0.3 }
    }

    /// Draw a chain: for every bigram state, `branching` candidate next
    /// chars with geometric-ish weights.
    fn chain(&self, rng: &mut Rng) -> Vec<Vec<(i32, f64)>> {
        let states = self.vocab * self.vocab;
        (0..states)
            .map(|_| {
                let mut cands = Vec::with_capacity(self.branching);
                let mut w = 1.0;
                for _ in 0..self.branching {
                    cands.push((rng.below(self.vocab) as i32, w));
                    w *= 0.45;
                }
                cands
            })
            .collect()
    }

    fn sample_stream(
        &self,
        global: &[Vec<(i32, f64)>],
        style: Option<&[Vec<(i32, f64)>]>,
        len: usize,
        rng: &mut Rng,
    ) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        let mut a = rng.below(self.vocab) as i32;
        let mut b = rng.below(self.vocab) as i32;
        out.push(a);
        out.push(b);
        let mut weights: Vec<f64> = Vec::with_capacity(self.vocab);
        while out.len() < len {
            let state = (a as usize) * self.vocab + b as usize;
            weights.clear();
            weights.resize(self.vocab, 1e-4); // smoothing mass
            for &(c, w) in &global[state] {
                weights[c as usize] += (1.0 - self.style_weight) * w;
            }
            if let Some(st) = style {
                for &(c, w) in &st[state] {
                    weights[c as usize] += self.style_weight * w;
                }
            }
            let next = rng.weighted(&weights) as i32;
            out.push(next);
            a = b;
            b = next;
        }
        out
    }

    /// Build `shards` per-client streams of `shard_len` tokens plus a
    /// global test stream of `test_len` tokens.
    pub fn generate(&self, shards: usize, shard_len: usize, test_len: usize, seed: u64) -> TextSet {
        let mut rng = Rng::new(seed);
        let global = self.chain(&mut rng);
        let mut out_shards = Vec::with_capacity(shards);
        for s in 0..shards {
            let mut srng = rng.fork(s as u64 + 1);
            let style = self.chain(&mut srng);
            out_shards.push(self.sample_stream(&global, Some(&style), shard_len, &mut srng));
        }
        let mut trng = rng.fork(0xEEEE);
        // test stream mixes styles the way the paper evaluates on the full
        // test split: global chain only.
        let test = self.sample_stream(&global, None, test_len, &mut trng);
        TextSet { vocab: self.vocab, shards: out_shards, test }
    }

    /// Freeze the global transition structure once (pure in `chain_seed`)
    /// so per-client shards can be synthesized lazily, one at a time.
    pub fn lazy(self, chain_seed: u64) -> LazyTextGen {
        let mut rng = Rng::new(chain_seed);
        let global = self.chain(&mut rng);
        LazyTextGen { gen: self, global }
    }
}

/// Lazy per-client text synthesis: the global chain is built once, each
/// client's style chain + stream come from an independent keyed RNG. No
/// per-population shard vector ever exists — a shard is a pure function
/// of `(chain_seed, client_seed)`, synthesized on first touch.
#[derive(Debug, Clone)]
pub struct LazyTextGen {
    gen: TextGen,
    global: Vec<Vec<(i32, f64)>>,
}

impl LazyTextGen {
    pub fn vocab(&self) -> usize {
        self.gen.vocab
    }

    /// One client's shard: style chain + token stream from `client_seed`.
    pub fn shard(&self, shard_len: usize, client_seed: u64) -> Vec<i32> {
        let mut srng = Rng::new(client_seed);
        let style = self.gen.chain(&mut srng);
        self.gen.sample_stream(&self.global, Some(&style), shard_len, &mut srng)
    }

    /// A global-chain-only stream (the test split's distribution).
    pub fn global_stream(&self, len: usize, seed: u64) -> Vec<i32> {
        self.gen.sample_stream(&self.global, None, len, &mut Rng::new(seed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let gen = TextGen::shakespeare_twin();
        let ts = gen.generate(5, 500, 1000, 9);
        assert_eq!(ts.shards.len(), 5);
        assert!(ts.shards.iter().all(|s| s.len() == 500));
        assert_eq!(ts.test.len(), 1000);
        let ok = |s: &[i32]| s.iter().all(|&t| (0..64).contains(&t));
        assert!(ts.shards.iter().all(|s| ok(s)));
        assert!(ok(&ts.test));
    }

    #[test]
    fn deterministic() {
        let gen = TextGen::shakespeare_twin();
        let a = gen.generate(3, 100, 100, 5);
        let b = gen.generate(3, 100, 100, 5);
        assert_eq!(a.shards, b.shards);
        assert_eq!(a.test, b.test);
    }

    #[test]
    fn lazy_shards_are_pure_and_styled() {
        let lazy = TextGen::shakespeare_twin().lazy(21);
        let a = lazy.shard(400, 77);
        assert_eq!(a, lazy.shard(400, 77), "shard must be pure in its seed");
        assert_eq!(a.len(), 400);
        assert!(a.iter().all(|&t| (0..64).contains(&t)));
        assert_ne!(a, lazy.shard(400, 78), "different clients get different styles");
        // materialization order is unobservable
        let other = TextGen::shakespeare_twin().lazy(21);
        let _ = other.shard(400, 78);
        assert_eq!(a, other.shard(400, 77));
    }

    #[test]
    fn lazy_global_stream_is_pure() {
        let lazy = TextGen::shakespeare_twin().lazy(21);
        let t = lazy.global_stream(600, 5);
        assert_eq!(t, lazy.global_stream(600, 5));
        assert_eq!(t.len(), 600);
        assert_eq!(lazy.vocab(), 64);
    }

    #[test]
    fn chain_is_predictable_above_chance() {
        // An order-2 bigram counter trained on the test stream should
        // predict continuations far better than 1/64.
        let gen = TextGen::shakespeare_twin();
        let ts = gen.generate(1, 10, 20_000, 11);
        let v = gen.vocab;
        let split = ts.test.len() / 2;
        let mut counts = vec![0u32; v * v * v];
        for w in ts.test[..split].windows(3) {
            counts[(w[0] as usize * v + w[1] as usize) * v + w[2] as usize] += 1;
        }
        let mut correct = 0usize;
        let mut total = 0usize;
        for w in ts.test[split..].windows(3) {
            let state = w[0] as usize * v + w[1] as usize;
            let row = &counts[state * v..(state + 1) * v];
            let pred = row.iter().enumerate().max_by_key(|(_, &c)| c).unwrap().0;
            if pred == w[2] as usize {
                correct += 1;
            }
            total += 1;
        }
        let acc = correct as f64 / total as f64;
        assert!(acc > 0.25, "bigram predictability too low: {acc}");
    }

    #[test]
    fn shards_differ_in_style() {
        let gen = TextGen::shakespeare_twin();
        let ts = gen.generate(2, 5000, 10, 13);
        // bigram distributions of two shards should differ measurably
        let hist = |s: &[i32]| {
            let mut h = vec![0f64; 64 * 64];
            for w in s.windows(2) {
                h[w[0] as usize * 64 + w[1] as usize] += 1.0;
            }
            let n: f64 = h.iter().sum();
            for x in &mut h {
                *x /= n;
            }
            h
        };
        let h0 = hist(&ts.shards[0]);
        let h1 = hist(&ts.shards[1]);
        let l1: f64 = h0.iter().zip(&h1).map(|(a, b)| (a - b).abs()).sum();
        assert!(l1 > 0.1, "shard styles indistinguishable: l1={l1}");
    }
}
