//! Class-conditioned synthetic image generator.
//!
//! Each class owns a smooth random prototype (a sum of random 2-D
//! sinusoids per channel — low-frequency structure a conv net picks up);
//! a sample is `mix·prototype + (1-mix)·noise` with a random per-sample
//! gain and offset. `mix` controls difficulty: the defaults land the
//! composed CNN in the paper's accuracy regime (70-85%) after a few
//! hundred federated rounds rather than instantly, so accuracy-vs-time
//! curves have the shape the figures need.

use super::ImageSet;
use crate::util::rng::Rng;

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct ImageGen {
    pub hw: usize,
    pub channels: usize,
    pub classes: usize,
    /// prototype weight in [0,1]; higher = easier
    pub mix: f64,
    /// number of sinusoid components per class prototype
    pub components: usize,
}

impl ImageGen {
    /// CIFAR-10 twin (paper §VI-A1): 10 classes, 16×16×3.
    pub fn cifar_twin() -> ImageGen {
        ImageGen { hw: 16, channels: 3, classes: 10, mix: 0.45, components: 4 }
    }

    /// ImageNet-100 twin: 20 classes, 16×16×3, slightly harder.
    pub fn imagenet_twin() -> ImageGen {
        ImageGen { hw: 16, channels: 3, classes: 20, mix: 0.40, components: 5 }
    }

    fn prototypes(&self, rng: &mut Rng) -> Vec<Vec<f32>> {
        let size = self.hw * self.hw * self.channels;
        (0..self.classes)
            .map(|_| {
                let mut proto = vec![0.0f32; size];
                for _ in 0..self.components {
                    // random 2-D sinusoid with per-channel phase
                    let fx = rng.uniform_in(0.5, 3.0);
                    let fy = rng.uniform_in(0.5, 3.0);
                    let ph = rng.uniform_in(0.0, std::f64::consts::TAU);
                    let chw: Vec<f64> = (0..self.channels).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
                    for y in 0..self.hw {
                        for x in 0..self.hw {
                            let v = (std::f64::consts::TAU
                                * (fx * x as f64 / self.hw as f64 + fy * y as f64 / self.hw as f64)
                                + ph)
                                .sin();
                            for c in 0..self.channels {
                                proto[(y * self.hw + x) * self.channels + c] += (v * chw[c]) as f32;
                            }
                        }
                    }
                }
                // normalize prototype to unit std
                let n = proto.len() as f64;
                let mean = proto.iter().map(|&x| x as f64).sum::<f64>() / n;
                let var = proto.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
                let inv = 1.0 / var.sqrt().max(1e-6);
                for p in &mut proto {
                    *p = ((*p as f64 - mean) * inv) as f32;
                }
                proto
            })
            .collect()
    }

    /// Generate `n` samples with labels cycling uniformly over classes
    /// (shuffled), from the class prototypes seeded by `seed_protos`.
    /// The same `seed_protos` must be used for train and test so they
    /// share the class structure.
    pub fn generate(&self, n: usize, seed_protos: u64, rng: &mut Rng) -> ImageSet {
        let mut labels: Vec<i32> = (0..n).map(|i| (i % self.classes) as i32).collect();
        rng.shuffle(&mut labels);
        self.generate_labeled(labels, seed_protos, rng)
    }

    /// Generate samples for a caller-provided label sequence — the lazy
    /// population path synthesizes a client's non-IID shard by building
    /// its label vector from the partition prior (dominant-class share,
    /// missing classes) and a shard-keyed RNG, then calling this with
    /// the same `seed_protos` as every other client and the test split
    /// (prototypes are pure in `seed_protos`, so all shards share the
    /// class structure without any global dataset existing).
    pub fn generate_labeled(&self, labels: Vec<i32>, seed_protos: u64, rng: &mut Rng) -> ImageSet {
        let mut prng = Rng::new(seed_protos);
        let protos = self.prototypes(&mut prng);
        let size = self.hw * self.hw * self.channels;
        let mut pixels = vec![0.0f32; labels.len() * size];
        let mix = self.mix as f32;
        for (i, &lab) in labels.iter().enumerate() {
            let gain = rng.uniform_in(0.8, 1.2) as f32;
            let offset = rng.uniform_in(-0.1, 0.1) as f32;
            let proto = &protos[lab as usize];
            let out = &mut pixels[i * size..(i + 1) * size];
            for (o, &p) in out.iter_mut().zip(proto.iter()) {
                let noise = rng.normal() as f32;
                *o = gain * (mix * p + (1.0 - mix) * noise) + offset;
            }
        }
        ImageSet { hw: self.hw, channels: self.channels, classes: self.classes, pixels, labels }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_label_range() {
        let gen = ImageGen::cifar_twin();
        let mut rng = Rng::new(1);
        let ds = gen.generate(100, 42, &mut rng);
        assert_eq!(ds.len(), 100);
        assert_eq!(ds.pixels.len(), 100 * 16 * 16 * 3);
        assert!(ds.labels.iter().all(|&l| (0..10).contains(&l)));
        // roughly balanced
        let mut counts = [0usize; 10];
        for &l in &ds.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10));
    }

    #[test]
    fn deterministic_given_seeds() {
        let gen = ImageGen::cifar_twin();
        let a = gen.generate(20, 42, &mut Rng::new(7));
        let b = gen.generate(20, 42, &mut Rng::new(7));
        assert_eq!(a.pixels, b.pixels);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn generate_labeled_composes_to_generate() {
        // the eager entry point is exactly shuffle + generate_labeled, so
        // the lazy shard path shares every downstream byte
        let gen = ImageGen::cifar_twin();
        let a = gen.generate(30, 42, &mut Rng::new(7));
        let mut rng = Rng::new(7);
        let mut labels: Vec<i32> = (0..30).map(|i| (i % gen.classes) as i32).collect();
        rng.shuffle(&mut labels);
        let b = gen.generate_labeled(labels, 42, &mut rng);
        assert_eq!(a.pixels, b.pixels);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn generate_labeled_respects_labels_and_shares_protos() {
        let gen = ImageGen::cifar_twin();
        let skewed: Vec<i32> = (0..40).map(|i| if i < 32 { 3 } else { (i % 10) as i32 }).collect();
        let ds = gen.generate_labeled(skewed.clone(), 42, &mut Rng::new(11));
        assert_eq!(ds.labels, skewed);
        assert_eq!(ds.pixels.len(), 40 * gen.hw * gen.hw * gen.channels);
    }

    #[test]
    fn different_proto_seeds_differ() {
        let gen = ImageGen::cifar_twin();
        let a = gen.generate(20, 1, &mut Rng::new(7));
        let b = gen.generate(20, 2, &mut Rng::new(7));
        assert_ne!(a.pixels, b.pixels);
    }

    #[test]
    fn class_structure_is_detectable() {
        // nearest-prototype classification on clean prototypes must beat
        // chance by a wide margin — otherwise the task is unlearnable.
        let gen = ImageGen::cifar_twin();
        let mut rng = Rng::new(3);
        let train = gen.generate(400, 42, &mut rng);
        let test = gen.generate(200, 42, &mut rng);
        let size = train.sample_size();
        // class means from train
        let mut means = vec![vec![0.0f64; size]; gen.classes];
        let mut counts = vec![0usize; gen.classes];
        for i in 0..train.len() {
            let c = train.labels[i] as usize;
            counts[c] += 1;
            for (m, &p) in means[c].iter_mut().zip(train.sample(i)) {
                *m += p as f64;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c as f64;
            }
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let s = test.sample(i);
            let best = (0..gen.classes)
                .min_by(|&a, &b| {
                    let da: f64 = s.iter().zip(&means[a]).map(|(&x, &m)| (x as f64 - m).powi(2)).sum();
                    let db: f64 = s.iter().zip(&means[b]).map(|(&x, &m)| (x as f64 - m).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == test.labels[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.5, "nearest-mean accuracy too low: {acc}");
    }
}
