//! Per-client batch iterators feeding PJRT literals.
//!
//! A `ImageLoader` owns a client's index list into the shared `ImageSet`
//! and yields fixed-size `(x, y)` batches (the AOT executables have static
//! shapes), reshuffling each epoch. `TextLoader` slides fixed-length
//! windows over the client's token stream: `x = s[i..i+T]`,
//! `y = s[i+1..i+T+1]` (next-char prediction).

use super::{ImageSet, TextSet};
use crate::tensor::{IntTensor, Tensor};
use crate::util::rng::Rng;
use std::sync::Arc;

/// One (x, y) training batch as host tensors.
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: Tensor,
    pub y: IntTensor,
}

/// One tokenized (x, y) batch for the RNN family.
#[derive(Debug, Clone)]
pub struct TokenBatch {
    pub x: IntTensor,
    pub y: IntTensor,
}

/// Shuffled, epoch-cycling image batch loader.
#[derive(Debug, Clone)]
pub struct ImageLoader {
    data: Arc<ImageSet>,
    indices: Vec<usize>,
    cursor: usize,
    batch: usize,
    rng: Rng,
}

impl ImageLoader {
    pub fn new(data: Arc<ImageSet>, indices: Vec<usize>, batch: usize, rng: Rng) -> ImageLoader {
        assert!(!indices.is_empty(), "empty client partition");
        let mut l = ImageLoader { data, indices, cursor: 0, batch, rng };
        l.rng.shuffle(&mut l.indices);
        l
    }

    /// Number of samples this client holds.
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Next fixed-size batch; wraps (with reshuffle) at epoch end.
    pub fn next_batch(&mut self) -> Batch {
        let ss = self.data.sample_size();
        let hw = self.data.hw;
        let c = self.data.channels;
        let mut x = vec![0.0f32; self.batch * ss];
        let mut y = vec![0i32; self.batch];
        for b in 0..self.batch {
            if self.cursor >= self.indices.len() {
                self.cursor = 0;
                self.rng.shuffle(&mut self.indices);
            }
            let i = self.indices[self.cursor];
            self.cursor += 1;
            x[b * ss..(b + 1) * ss].copy_from_slice(self.data.sample(i));
            y[b] = self.data.labels[i];
        }
        Batch {
            x: Tensor::from_vec(&[self.batch, hw, hw, c], x),
            y: IntTensor::from_vec(&[self.batch], y),
        }
    }
}

/// Sequential full-set evaluator batches (padding the tail by wrapping).
pub struct EvalBatches<'a> {
    data: &'a ImageSet,
    cursor: usize,
    batch: usize,
}

impl<'a> EvalBatches<'a> {
    pub fn new(data: &'a ImageSet, batch: usize) -> Self {
        EvalBatches { data, cursor: 0, batch }
    }

    /// Number of batches covering the set once.
    pub fn num_batches(&self) -> usize {
        self.data.len().div_ceil(self.batch)
    }
}

impl<'a> Iterator for EvalBatches<'a> {
    /// (batch, number of *real* samples in it)
    type Item = (Batch, usize);

    fn next(&mut self) -> Option<(Batch, usize)> {
        if self.cursor >= self.data.len() {
            return None;
        }
        let ss = self.data.sample_size();
        let real = (self.data.len() - self.cursor).min(self.batch);
        let mut x = vec![0.0f32; self.batch * ss];
        let mut y = vec![0i32; self.batch];
        for b in 0..self.batch {
            let i = if b < real { self.cursor + b } else { b % self.data.len() };
            x[b * ss..(b + 1) * ss].copy_from_slice(self.data.sample(i));
            y[b] = self.data.labels[i];
        }
        self.cursor += real;
        Some((
            Batch {
                x: Tensor::from_vec(&[self.batch, self.data.hw, self.data.hw, self.data.channels], x),
                y: IntTensor::from_vec(&[self.batch], y),
            },
            real,
        ))
    }
}

/// Random-window token batch loader over one shard.
#[derive(Debug, Clone)]
pub struct TextLoader {
    stream: Arc<Vec<i32>>,
    batch: usize,
    seq: usize,
    rng: Rng,
}

impl TextLoader {
    pub fn new(stream: Arc<Vec<i32>>, batch: usize, seq: usize, rng: Rng) -> TextLoader {
        assert!(stream.len() > seq + 1, "stream shorter than sequence length");
        TextLoader { stream, batch, seq, rng }
    }

    pub fn next_batch(&mut self) -> TokenBatch {
        let mut x = vec![0i32; self.batch * self.seq];
        let mut y = vec![0i32; self.batch * self.seq];
        let limit = self.stream.len() - self.seq - 1;
        for b in 0..self.batch {
            let start = self.rng.below(limit);
            x[b * self.seq..(b + 1) * self.seq].copy_from_slice(&self.stream[start..start + self.seq]);
            y[b * self.seq..(b + 1) * self.seq]
                .copy_from_slice(&self.stream[start + 1..start + self.seq + 1]);
        }
        TokenBatch {
            x: IntTensor::from_vec(&[self.batch, self.seq], x),
            y: IntTensor::from_vec(&[self.batch, self.seq], y),
        }
    }
}

/// Deterministic eval windows over the test stream.
pub struct TextEvalBatches<'a> {
    set: &'a TextSet,
    cursor: usize,
    batch: usize,
    seq: usize,
}

impl<'a> TextEvalBatches<'a> {
    pub fn new(set: &'a TextSet, batch: usize, seq: usize) -> Self {
        TextEvalBatches { set, cursor: 0, batch, seq }
    }
}

impl<'a> Iterator for TextEvalBatches<'a> {
    /// (batch, real sequences)
    type Item = (TokenBatch, usize);

    fn next(&mut self) -> Option<(TokenBatch, usize)> {
        let stride = self.seq + 1;
        let avail = self.set.test.len().saturating_sub(self.cursor);
        if avail < stride {
            return None;
        }
        let real = (avail / stride).min(self.batch);
        let mut x = vec![0i32; self.batch * self.seq];
        let mut y = vec![0i32; self.batch * self.seq];
        for b in 0..self.batch {
            let start = if b < real {
                self.cursor + b * stride
            } else {
                // pad by repeating the first window
                self.cursor
            };
            x[b * self.seq..(b + 1) * self.seq].copy_from_slice(&self.set.test[start..start + self.seq]);
            y[b * self.seq..(b + 1) * self.seq]
                .copy_from_slice(&self.set.test[start + 1..start + self.seq + 1]);
        }
        self.cursor += real * stride;
        Some((
            TokenBatch {
                x: IntTensor::from_vec(&[self.batch, self.seq], x),
                y: IntTensor::from_vec(&[self.batch, self.seq], y),
            },
            real,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_image::ImageGen;
    use crate::data::synth_text::TextGen;

    #[test]
    fn image_loader_batches_and_wraps() {
        let ds = Arc::new(ImageGen::cifar_twin().generate(25, 42, &mut Rng::new(1)));
        let mut l = ImageLoader::new(ds.clone(), (0..25).collect(), 16, Rng::new(2));
        let b1 = l.next_batch();
        assert_eq!(b1.x.shape(), &[16, 16, 16, 3]);
        assert_eq!(b1.y.shape(), &[16]);
        let _b2 = l.next_batch(); // forces an epoch wrap
        assert_eq!(l.len(), 25);
    }

    #[test]
    fn eval_batches_cover_all_samples_once() {
        let ds = ImageGen::cifar_twin().generate(100, 42, &mut Rng::new(1));
        let it = EvalBatches::new(&ds, 64);
        let total: usize = it.map(|(_, real)| real).sum();
        assert_eq!(total, 100);
        assert_eq!(EvalBatches::new(&ds, 64).num_batches(), 2);
    }

    #[test]
    fn text_loader_targets_are_shifted_inputs() {
        let ts = TextGen::shakespeare_twin().generate(1, 500, 10, 3);
        let stream = Arc::new(ts.shards[0].clone());
        let mut l = TextLoader::new(stream.clone(), 4, 20, Rng::new(5));
        let b = l.next_batch();
        assert_eq!(b.x.shape(), &[4, 20]);
        // y row must equal x row shifted by one within the source stream
        for row in 0..4 {
            let xs = &b.x.data()[row * 20..(row + 1) * 20];
            let ys = &b.y.data()[row * 20..(row + 1) * 20];
            // find xs in stream and verify ys follows it
            let pos = stream
                .windows(20)
                .position(|w| w == xs)
                .expect("window must come from the stream");
            assert_eq!(ys, &stream[pos + 1..pos + 21]);
        }
    }

    #[test]
    fn text_eval_is_deterministic_and_covers() {
        let ts = TextGen::shakespeare_twin().generate(1, 10, 2_000, 3);
        let n1: usize = TextEvalBatches::new(&ts, 32, 20).map(|(_, r)| r).sum();
        let n2: usize = TextEvalBatches::new(&ts, 32, 20).map(|(_, r)| r).sum();
        assert_eq!(n1, n2);
        assert!(n1 > 50, "too few eval windows: {n1}");
    }
}
