//! Non-IID partition schemes from the paper (§VI-A2).
//!
//! * `gamma_partition` — CIFAR-10 scheme: Γ% of each client's samples come
//!   from one dominant class, the rest spread evenly over the other
//!   classes. Γ = 100/classes (10 for CIFAR-10) degenerates to IID.
//! * `phi_partition` — ImageNet-100 scheme: each client *lacks* φ% of the
//!   classes; volume is equal across the classes it does hold. φ = 0 is IID.
//!
//! Both return per-client index lists into the dataset, never duplicate an
//! index, and use every sample at most once (invariants property-tested in
//! rust/tests/prop_coordinator.rs).

use crate::util::rng::Rng;

/// Group sample indices by label. `classes` must exceed every label.
fn by_class(labels: &[i32], classes: usize) -> Vec<Vec<usize>> {
    let mut pools: Vec<Vec<usize>> = vec![Vec::new(); classes];
    for (i, &l) in labels.iter().enumerate() {
        pools[l as usize].push(i);
    }
    pools
}

/// Γ-scheme (dominant-class). `gamma_pct` in [0,100]; each client draws
/// ~`gamma_pct`% of its quota from a dominant class assigned round-robin
/// and the rest evenly from the remaining classes. Pools are consumed
/// without replacement; when a pool dries up the sampler falls back to
/// whatever classes still have samples, so all quotas are met whenever
/// `n_clients * quota <= labels.len()`.
pub fn gamma_partition(
    labels: &[i32],
    classes: usize,
    n_clients: usize,
    quota: usize,
    gamma_pct: f64,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    assert!(n_clients * quota <= labels.len(), "not enough samples: need {} have {}", n_clients * quota, labels.len());
    let mut pools = by_class(labels, classes);
    for p in pools.iter_mut() {
        rng.shuffle(p);
    }
    let frac = (gamma_pct / 100.0).clamp(0.0, 1.0);
    let mut out = Vec::with_capacity(n_clients);
    for client in 0..n_clients {
        let dom = client % classes;
        let n_dom = ((quota as f64) * frac).round() as usize;
        let mut idxs = Vec::with_capacity(quota);
        take_from(&mut pools, dom, n_dom.min(quota), &mut idxs, rng);
        // even spread over the other classes
        let rest = quota - idxs.len();
        let others: Vec<usize> = (0..classes).filter(|&c| c != dom).collect();
        for (j, &c) in others.iter().enumerate() {
            // distribute remainder as evenly as integer division allows
            let share = rest / others.len() + usize::from(j < rest % others.len());
            take_from(&mut pools, c, share, &mut idxs, rng);
        }
        // top up from any non-empty pool if some pools dried out
        while idxs.len() < quota {
            let Some(c) = (0..classes).find(|&c| !pools[c].is_empty()) else { break };
            take_from(&mut pools, c, quota - idxs.len(), &mut idxs, rng);
        }
        assert_eq!(idxs.len(), quota, "client {client} quota unmet");
        out.push(idxs);
    }
    out
}

/// φ-scheme (missing-class). Each client holds `classes - missing` classes
/// (chosen per client) with equal per-class volume.
pub fn phi_partition(
    labels: &[i32],
    classes: usize,
    n_clients: usize,
    quota: usize,
    missing: usize,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    assert!(missing < classes, "cannot miss all classes");
    assert!(n_clients * quota <= labels.len(), "not enough samples");
    let mut pools = by_class(labels, classes);
    for p in pools.iter_mut() {
        rng.shuffle(p);
    }
    let keep = classes - missing;
    let mut out = Vec::with_capacity(n_clients);
    for client in 0..n_clients {
        let kept = rng.sample_distinct(classes, keep);
        let mut idxs = Vec::with_capacity(quota);
        for (j, &c) in kept.iter().enumerate() {
            let share = quota / keep + usize::from(j < quota % keep);
            take_from(&mut pools, c, share, &mut idxs, rng);
        }
        while idxs.len() < quota {
            let Some(c) = (0..classes).find(|&c| !pools[c].is_empty()) else { break };
            take_from(&mut pools, c, quota - idxs.len(), &mut idxs, rng);
        }
        assert_eq!(idxs.len(), quota, "client {client} quota unmet");
        out.push(idxs);
    }
    out
}

fn take_from(pools: &mut [Vec<usize>], class: usize, want: usize, out: &mut Vec<usize>, _rng: &mut Rng) {
    let pool = &mut pools[class];
    let take = want.min(pool.len());
    out.extend(pool.drain(pool.len() - take..));
}

/// Measure the dominant-class fraction of a partition (diagnostics + tests).
pub fn dominant_fraction(part: &[usize], labels: &[i32], classes: usize) -> f64 {
    let mut counts = vec![0usize; classes];
    for &i in part {
        counts[labels[i] as usize] += 1;
    }
    let max = counts.iter().max().copied().unwrap_or(0);
    if part.is_empty() {
        0.0
    } else {
        max as f64 / part.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn labels(n: usize, classes: usize) -> Vec<i32> {
        (0..n).map(|i| (i % classes) as i32).collect()
    }

    #[test]
    fn gamma_no_duplicates_and_quota() {
        let l = labels(2000, 10);
        let mut rng = Rng::new(1);
        let parts = gamma_partition(&l, 10, 20, 50, 40.0, &mut rng);
        assert_eq!(parts.len(), 20);
        let mut seen = std::collections::HashSet::new();
        for p in &parts {
            assert_eq!(p.len(), 50);
            for &i in p {
                assert!(seen.insert(i), "duplicate index {i}");
            }
        }
    }

    #[test]
    fn gamma_skew_increases_with_gamma() {
        let l = labels(5000, 10);
        let f = |g: f64| {
            let mut rng = Rng::new(2);
            let parts = gamma_partition(&l, 10, 10, 100, g, &mut rng);
            let avg: f64 = parts
                .iter()
                .map(|p| dominant_fraction(p, &l, 10))
                .sum::<f64>()
                / parts.len() as f64;
            avg
        };
        let iid = f(10.0);
        let mid = f(40.0);
        let hi = f(80.0);
        assert!(iid < mid && mid < hi, "skew not monotone: {iid} {mid} {hi}");
        assert!((hi - 0.8).abs() < 0.05, "Γ=80 should give ~80% dominant, got {hi}");
    }

    #[test]
    fn phi_missing_classes() {
        let l = labels(4000, 20);
        let mut rng = Rng::new(3);
        let missing = 8; // 40%
        let parts = phi_partition(&l, 20, 10, 100, missing, &mut rng);
        for p in &parts {
            let mut present = vec![false; 20];
            for &i in p {
                present[l[i] as usize] = true;
            }
            let held = present.iter().filter(|&&x| x).count();
            assert!(held <= 20 - missing, "client holds {held} classes, expected <= {}", 20 - missing);
        }
    }

    #[test]
    fn phi_zero_is_iid_like() {
        let l = labels(4000, 20);
        let mut rng = Rng::new(4);
        let parts = phi_partition(&l, 20, 10, 200, 0, &mut rng);
        for p in &parts {
            let dom = dominant_fraction(p, &l, 20);
            assert!(dom < 0.10, "IID partition too skewed: {dom}");
        }
    }

    #[test]
    fn exhausts_gracefully_at_capacity() {
        let l = labels(500, 10);
        let mut rng = Rng::new(5);
        let parts = gamma_partition(&l, 10, 10, 50, 80.0, &mut rng);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 500);
    }
}
