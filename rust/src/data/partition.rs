//! Non-IID partition schemes from the paper (§VI-A2).
//!
//! * `gamma_partition` — CIFAR-10 scheme: Γ% of each client's samples come
//!   from one dominant class, the rest spread evenly over the other
//!   classes. Γ = 100/classes (10 for CIFAR-10) degenerates to IID.
//! * `phi_partition` — ImageNet-100 scheme: each client *lacks* φ% of the
//!   classes; volume is equal across the classes it does hold. φ = 0 is IID.
//!
//! Both return a [`PartitionPlan`]: per-client **shard descriptors**
//! (class + slice into a shared shuffled pool) instead of eagerly
//! allocated `Vec<Vec<usize>>` index lists for every client. The plan
//! holds one flat copy of the shuffled per-class pools (O(samples) total,
//! shared by all clients) plus O(classes) slice records per client;
//! actual index lists are materialized per *cohort* client on demand via
//! [`PartitionPlan::client_indices`], reproducing byte for byte the index
//! order the historical eager partitioner emitted (pools were drained
//! from the tail, so a descriptor `(class, start, len)` names exactly the
//! elements a drain of the same count-state would have yielded, in the
//! same order — pinned by the reference-equivalence test below).
//!
//! Plans never duplicate an index and use every sample at most once
//! (invariants property-tested in rust/tests/prop_coordinator.rs).

use crate::util::rng::Rng;

/// Group sample indices by label. `classes` must exceed every label.
fn by_class(labels: &[i32], classes: usize) -> Vec<Vec<usize>> {
    let mut pools: Vec<Vec<usize>> = vec![Vec::new(); classes];
    for (i, &l) in labels.iter().enumerate() {
        pools[l as usize].push(i);
    }
    pools
}

/// One contiguous run of a client's shard: `len` samples of class
/// `class`, living at `pool[class][start..start + len]` of the plan's
/// shuffled pools.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSlice {
    pub class: usize,
    pub start: usize,
    pub len: usize,
}

/// A partition as per-client descriptors over shared shuffled pools.
///
/// Memory is O(samples + n_clients · classes) — no per-client index
/// vectors exist until [`Self::client_indices`] materializes one
/// (O(quota)) for a sampled cohort member.
#[derive(Debug, Clone)]
pub struct PartitionPlan {
    /// shuffled per-class index pools (immutable after planning)
    pools: Vec<Vec<usize>>,
    /// per-client slice descriptors, in the order the eager partitioner
    /// appended them
    shards: Vec<Vec<ShardSlice>>,
}

impl PartitionPlan {
    pub fn n_clients(&self) -> usize {
        self.shards.len()
    }

    /// Number of samples assigned to `client`.
    pub fn shard_len(&self, client: usize) -> usize {
        self.shards[client].iter().map(|s| s.len).sum()
    }

    /// The client's raw slice descriptors (sizes + pool offsets).
    pub fn slices(&self, client: usize) -> &[ShardSlice] {
        &self.shards[client]
    }

    /// Materialize the client's sample indices (O(quota)); identical
    /// values and order to the historical eager partition.
    pub fn client_indices(&self, client: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.shard_len(client));
        for s in &self.shards[client] {
            out.extend_from_slice(&self.pools[s.class][s.start..s.start + s.len]);
        }
        out
    }

    /// Materialize every client (tests/diagnostics only — this is the
    /// O(population) allocation the plan exists to avoid).
    pub fn materialize_all(&self) -> Vec<Vec<usize>> {
        (0..self.n_clients()).map(|c| self.client_indices(c)).collect()
    }

    /// Total samples assigned across all clients.
    pub fn total_assigned(&self) -> usize {
        (0..self.n_clients()).map(|c| self.shard_len(c)).sum()
    }
}

/// Record a take of up to `want` samples of `class` in count space:
/// the eager code drained from the pool tail, so the taken elements are
/// `pool[class][remaining - take..remaining]` (drain yields them in
/// ascending position order).
fn take_slice(
    remaining: &mut [usize],
    class: usize,
    want: usize,
    slices: &mut Vec<ShardSlice>,
    have: &mut usize,
) {
    let take = want.min(remaining[class]);
    if take > 0 {
        remaining[class] -= take;
        slices.push(ShardSlice { class, start: remaining[class], len: take });
        *have += take;
    }
}

/// Γ-scheme (dominant-class). `gamma_pct` in [0,100]; each client draws
/// ~`gamma_pct`% of its quota from a dominant class assigned round-robin
/// and the rest evenly from the remaining classes. Pools are consumed
/// without replacement; when a pool dries up the sampler falls back to
/// whatever classes still have samples, so all quotas are met whenever
/// `n_clients * quota <= labels.len()`.
pub fn gamma_partition(
    labels: &[i32],
    classes: usize,
    n_clients: usize,
    quota: usize,
    gamma_pct: f64,
    rng: &mut Rng,
) -> PartitionPlan {
    assert!(n_clients * quota <= labels.len(), "not enough samples: need {} have {}", n_clients * quota, labels.len());
    let mut pools = by_class(labels, classes);
    for p in pools.iter_mut() {
        rng.shuffle(p);
    }
    let mut remaining: Vec<usize> = pools.iter().map(Vec::len).collect();
    let frac = (gamma_pct / 100.0).clamp(0.0, 1.0);
    let mut shards = Vec::with_capacity(n_clients);
    for client in 0..n_clients {
        let dom = client % classes;
        let n_dom = ((quota as f64) * frac).round() as usize;
        let mut slices = Vec::new();
        let mut have = 0usize;
        take_slice(&mut remaining, dom, n_dom.min(quota), &mut slices, &mut have);
        // even spread over the other classes
        let rest = quota - have;
        let others: Vec<usize> = (0..classes).filter(|&c| c != dom).collect();
        for (j, &c) in others.iter().enumerate() {
            // distribute remainder as evenly as integer division allows
            let share = rest / others.len() + usize::from(j < rest % others.len());
            take_slice(&mut remaining, c, share, &mut slices, &mut have);
        }
        // top up from any non-empty pool if some pools dried out
        while have < quota {
            let Some(c) = (0..classes).find(|&c| remaining[c] > 0) else { break };
            take_slice(&mut remaining, c, quota - have, &mut slices, &mut have);
        }
        assert_eq!(have, quota, "client {client} quota unmet");
        shards.push(slices);
    }
    PartitionPlan { pools, shards }
}

/// φ-scheme (missing-class). Each client holds `classes - missing` classes
/// (chosen per client) with equal per-class volume.
pub fn phi_partition(
    labels: &[i32],
    classes: usize,
    n_clients: usize,
    quota: usize,
    missing: usize,
    rng: &mut Rng,
) -> PartitionPlan {
    assert!(missing < classes, "cannot miss all classes");
    assert!(n_clients * quota <= labels.len(), "not enough samples");
    let mut pools = by_class(labels, classes);
    for p in pools.iter_mut() {
        rng.shuffle(p);
    }
    let mut remaining: Vec<usize> = pools.iter().map(Vec::len).collect();
    let keep = classes - missing;
    let mut shards = Vec::with_capacity(n_clients);
    for client in 0..n_clients {
        let kept = rng.sample_distinct(classes, keep);
        let mut slices = Vec::new();
        let mut have = 0usize;
        for (j, &c) in kept.iter().enumerate() {
            let share = quota / keep + usize::from(j < quota % keep);
            take_slice(&mut remaining, c, share, &mut slices, &mut have);
        }
        while have < quota {
            let Some(c) = (0..classes).find(|&c| remaining[c] > 0) else { break };
            take_slice(&mut remaining, c, quota - have, &mut slices, &mut have);
        }
        assert_eq!(have, quota, "client {client} quota unmet");
        shards.push(slices);
    }
    PartitionPlan { pools, shards }
}

/// Measure the dominant-class fraction of a partition (diagnostics + tests).
pub fn dominant_fraction(part: &[usize], labels: &[i32], classes: usize) -> f64 {
    let mut counts = vec![0usize; classes];
    for &i in part {
        counts[labels[i] as usize] += 1;
    }
    let max = counts.iter().max().copied().unwrap_or(0);
    if part.is_empty() {
        0.0
    } else {
        max as f64 / part.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn labels(n: usize, classes: usize) -> Vec<i32> {
        (0..n).map(|i| (i % classes) as i32).collect()
    }

    /// The pre-plan eager Γ partitioner, verbatim semantics (actual pool
    /// drains): the oracle `client_indices` must reproduce byte for byte.
    fn eager_gamma_reference(
        labels: &[i32],
        classes: usize,
        n_clients: usize,
        quota: usize,
        gamma_pct: f64,
        rng: &mut Rng,
    ) -> Vec<Vec<usize>> {
        let mut pools = by_class(labels, classes);
        for p in pools.iter_mut() {
            rng.shuffle(p);
        }
        let drain = |pools: &mut [Vec<usize>], class: usize, want: usize, out: &mut Vec<usize>| {
            let pool = &mut pools[class];
            let take = want.min(pool.len());
            out.extend(pool.drain(pool.len() - take..));
        };
        let frac = (gamma_pct / 100.0).clamp(0.0, 1.0);
        let mut out = Vec::with_capacity(n_clients);
        for client in 0..n_clients {
            let dom = client % classes;
            let n_dom = ((quota as f64) * frac).round() as usize;
            let mut idxs = Vec::with_capacity(quota);
            drain(&mut pools, dom, n_dom.min(quota), &mut idxs);
            let rest = quota - idxs.len();
            let others: Vec<usize> = (0..classes).filter(|&c| c != dom).collect();
            for (j, &c) in others.iter().enumerate() {
                let share = rest / others.len() + usize::from(j < rest % others.len());
                drain(&mut pools, c, share, &mut idxs);
            }
            while idxs.len() < quota {
                let Some(c) = (0..classes).find(|&c| !pools[c].is_empty()) else { break };
                drain(&mut pools, c, quota - idxs.len(), &mut idxs);
            }
            out.push(idxs);
        }
        out
    }

    #[test]
    fn plan_matches_eager_reference_bit_for_bit() {
        // satellite contract: descriptors + on-demand materialization must
        // be indistinguishable from the historical eager index lists —
        // same RNG consumption (same seed in, same state out), same
        // indices, same order
        let l = labels(2000, 10);
        for seed in [1u64, 9, 77] {
            let mut plan_rng = Rng::new(seed);
            let plan = gamma_partition(&l, 10, 20, 50, 40.0, &mut plan_rng);
            let mut ref_rng = Rng::new(seed);
            let reference = eager_gamma_reference(&l, 10, 20, 50, 40.0, &mut ref_rng);
            assert_eq!(plan.materialize_all(), reference);
            // identical downstream RNG state: the plan consumed exactly
            // the draws the eager code did
            assert_eq!(plan_rng.next_u64(), ref_rng.next_u64());
        }
    }

    #[test]
    fn gamma_no_duplicates_and_quota() {
        let l = labels(2000, 10);
        let mut rng = Rng::new(1);
        let plan = gamma_partition(&l, 10, 20, 50, 40.0, &mut rng);
        assert_eq!(plan.n_clients(), 20);
        let mut seen = std::collections::HashSet::new();
        for c in 0..plan.n_clients() {
            let p = plan.client_indices(c);
            assert_eq!(p.len(), 50);
            assert_eq!(plan.shard_len(c), 50);
            for &i in &p {
                assert!(seen.insert(i), "duplicate index {i}");
            }
        }
    }

    #[test]
    fn gamma_skew_increases_with_gamma() {
        let l = labels(5000, 10);
        let f = |g: f64| {
            let mut rng = Rng::new(2);
            let plan = gamma_partition(&l, 10, 10, 100, g, &mut rng);
            let avg: f64 = (0..plan.n_clients())
                .map(|c| dominant_fraction(&plan.client_indices(c), &l, 10))
                .sum::<f64>()
                / plan.n_clients() as f64;
            avg
        };
        let iid = f(10.0);
        let mid = f(40.0);
        let hi = f(80.0);
        assert!(iid < mid && mid < hi, "skew not monotone: {iid} {mid} {hi}");
        assert!((hi - 0.8).abs() < 0.05, "Γ=80 should give ~80% dominant, got {hi}");
    }

    #[test]
    fn phi_missing_classes() {
        let l = labels(4000, 20);
        let mut rng = Rng::new(3);
        let missing = 8; // 40%
        let plan = phi_partition(&l, 20, 10, 100, missing, &mut rng);
        for c in 0..plan.n_clients() {
            let p = plan.client_indices(c);
            let mut present = vec![false; 20];
            for &i in &p {
                present[l[i] as usize] = true;
            }
            let held = present.iter().filter(|&&x| x).count();
            assert!(held <= 20 - missing, "client holds {held} classes, expected <= {}", 20 - missing);
        }
    }

    #[test]
    fn phi_zero_is_iid_like() {
        let l = labels(4000, 20);
        let mut rng = Rng::new(4);
        let plan = phi_partition(&l, 20, 10, 200, 0, &mut rng);
        for c in 0..plan.n_clients() {
            let dom = dominant_fraction(&plan.client_indices(c), &l, 20);
            assert!(dom < 0.10, "IID partition too skewed: {dom}");
        }
    }

    #[test]
    fn exhausts_gracefully_at_capacity() {
        let l = labels(500, 10);
        let mut rng = Rng::new(5);
        let plan = gamma_partition(&l, 10, 10, 50, 80.0, &mut rng);
        assert_eq!(plan.total_assigned(), 500);
    }
}
