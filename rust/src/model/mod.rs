//! Global model state held by the PS.
//!
//! * `ComposedGlobal` — Heroes / Flanc state: per layer a neural basis
//!   `v` and the *complete* coefficient `u` (R, B·O), plus the head bias.
//!   Width-p client payloads are `[v_0, û_0, v_1, û_1, ..., bias]` where
//!   `û_l` gathers that layer's selected blocks (paper Fig. 1).
//! * `DenseGlobal` — baseline state (FedAvg / ADP / HeteroFL): one dense
//!   weight per layer at full width; width-p sub-models are per-axis
//!   prefix slices (HeteroFL §3).
//!
//! Both initialize from the manifest's parameter specs (shape + init std)
//! so rust and the AOT graphs agree exactly on geometry.

// Outside the determinism layers (CONTRIBUTING.md): CLI surface,
// report generation and dev tooling may panic on programmer error.
#![allow(clippy::unwrap_used, clippy::expect_used)]


use crate::runtime::{ModelInfo, ParamSpec};
use crate::tensor::blocks::gather_blocks;
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};

/// Initialize a parameter list from manifest specs.
pub fn init_params(specs: &[ParamSpec], rng: &mut Rng) -> Vec<Tensor> {
    specs
        .iter()
        .map(|s| Tensor::randn(&s.shape, s.init_std, rng))
        .collect()
}

/// PS state for the composed (neural-composition) model family.
#[derive(Debug, Clone)]
pub struct ComposedGlobal {
    /// aligned with `ModelInfo::layers`
    pub bases: Vec<Tensor>,
    /// complete coefficients, shape (R, B·O) per layer
    pub coeffs: Vec<Tensor>,
    pub bias: Tensor,
}

impl ComposedGlobal {
    /// Random init (paper Alg. 1 line 1) using the full-width param specs.
    pub fn init(info: &ModelInfo, rng: &mut Rng) -> Result<ComposedGlobal> {
        let specs = info
            .composed_params
            .get(&info.cap_p)
            .ok_or_else(|| anyhow!("no composed params at P={}", info.cap_p))?;
        let params = init_params(specs, rng);
        Self::from_params(info, params)
    }

    /// Reassemble from a flat `[v_0, u_0, ..., bias]` list (full width).
    pub fn from_params(info: &ModelInfo, params: Vec<Tensor>) -> Result<ComposedGlobal> {
        let l = info.layers.len();
        if params.len() != 2 * l + 1 {
            return Err(anyhow!("expected {} params, got {}", 2 * l + 1, params.len()));
        }
        let mut it = params.into_iter();
        let mut bases = Vec::with_capacity(l);
        let mut coeffs = Vec::with_capacity(l);
        for layer in &info.layers {
            let v = it.next().unwrap();
            let u = it.next().unwrap();
            if v.shape() != layer.basis_shape.as_slice() {
                return Err(anyhow!("basis shape mismatch on {}", layer.name));
            }
            if u.shape() != layer.full_coeff_shape() {
                return Err(anyhow!("coefficient shape mismatch on {}", layer.name));
            }
            bases.push(v);
            coeffs.push(u);
        }
        Ok(ComposedGlobal { bases, coeffs, bias: it.next().unwrap() })
    }

    /// Client payload for width `p` given per-layer block selections
    /// (ascending ids, `len == layer.blocks_at(p)`).
    pub fn reduced_inputs(
        &self,
        info: &ModelInfo,
        p: usize,
        selections: &[Vec<usize>],
    ) -> Result<Vec<Tensor>> {
        if selections.len() != info.layers.len() {
            return Err(anyhow!("need one selection per layer"));
        }
        let mut out = Vec::with_capacity(2 * info.layers.len() + 1);
        for (idx, layer) in info.layers.iter().enumerate() {
            let sel = &selections[idx];
            if sel.len() != layer.blocks_at(p) {
                return Err(anyhow!(
                    "layer {} expects {} blocks at p={p}, got {}",
                    layer.name,
                    layer.blocks_at(p),
                    sel.len()
                ));
            }
            out.push(self.bases[idx].clone());
            out.push(gather_blocks(&self.coeffs[idx], sel, layer.o));
        }
        out.push(self.bias.clone());
        Ok(out)
    }

    /// Full-width payload (all blocks, ascending) — used by eval and by
    /// full-width clients.
    pub fn full_inputs(&self, info: &ModelInfo) -> Vec<Tensor> {
        let selections = full_selections(info);
        self.reduced_inputs(info, info.cap_p, &selections)
            .expect("full selection is always valid")
    }

    /// Squared reduction error α_n = ||u - û||² over the blocks NOT sent
    /// (paper Lemma 1: the model error induced by reducing the coefficient).
    pub fn reduction_error(&self, info: &ModelInfo, selections: &[Vec<usize>]) -> f64 {
        let mut err = 0.0;
        for (idx, layer) in info.layers.iter().enumerate() {
            let u = &self.coeffs[idx];
            let sel = &selections[idx];
            let o = layer.o;
            let data = u.data();
            let cols = layer.blocks_total * o;
            for b in 0..layer.blocks_total {
                if sel.binary_search(&b).is_err() {
                    for row in 0..layer.r {
                        let off = row * cols + b * o;
                        for c in 0..o {
                            let x = data[off + c] as f64;
                            err += x * x;
                        }
                    }
                }
            }
        }
        err
    }

    /// Total parameter element count (basis + coefficients + bias).
    pub fn num_elements(&self) -> usize {
        self.bases.iter().map(Tensor::len).sum::<usize>()
            + self.coeffs.iter().map(Tensor::len).sum::<usize>()
            + self.bias.len()
    }
}

/// All-blocks selections (ascending ids per layer).
pub fn full_selections(info: &ModelInfo) -> Vec<Vec<usize>> {
    info.layers
        .iter()
        .map(|l| (0..l.blocks_total).collect())
        .collect()
}

/// PS state for the dense baselines.
#[derive(Debug, Clone)]
pub struct DenseGlobal {
    /// aligned with `ModelInfo::layers`
    pub weights: Vec<Tensor>,
    pub bias: Tensor,
}

impl DenseGlobal {
    pub fn init(info: &ModelInfo, rng: &mut Rng) -> Result<DenseGlobal> {
        let specs = info
            .dense_params
            .get(&info.cap_p)
            .ok_or_else(|| anyhow!("no dense params at P={}", info.cap_p))?;
        let params = init_params(specs, rng);
        Self::from_params(info, params)
    }

    pub fn from_params(info: &ModelInfo, params: Vec<Tensor>) -> Result<DenseGlobal> {
        let l = info.layers.len();
        if params.len() != l + 1 {
            return Err(anyhow!("expected {} params, got {}", l + 1, params.len()));
        }
        let mut it = params.into_iter();
        let weights: Vec<Tensor> = (0..l).map(|_| it.next().unwrap()).collect();
        Ok(DenseGlobal { weights, bias: it.next().unwrap() })
    }

    /// Width-p sub-model: per-axis prefix slices matching the manifest's
    /// dense param shapes at p (HeteroFL extraction).
    pub fn reduced_inputs(&self, info: &ModelInfo, p: usize) -> Result<Vec<Tensor>> {
        let specs = info
            .dense_params
            .get(&p)
            .ok_or_else(|| anyhow!("no dense params at p={p}"))?;
        let mut out = Vec::with_capacity(specs.len());
        for (idx, spec) in specs.iter().enumerate() {
            if idx < self.weights.len() {
                out.push(self.weights[idx].slice_prefix(&spec.shape));
            } else {
                out.push(self.bias.clone()); // bias is width-independent
            }
        }
        Ok(out)
    }

    pub fn num_elements(&self) -> usize {
        self.weights.iter().map(Tensor::len).sum::<usize>() + self.bias.len()
    }
}

/// Test-support fixtures shared by unit tests across modules and the
/// integration/property tests (which, as external crates, cannot see
/// `#[cfg(test)]` items). Not part of the public API.
#[doc(hidden)]
pub mod tests_support {
    use super::*;
    use crate::runtime::{InputInfo, LayerInfo};
    use std::collections::BTreeMap;

    /// Hand-built two-layer ModelInfo (no manifest file needed).
    pub fn toy_info() -> ModelInfo {
        let layers = vec![
            LayerInfo {
                name: "conv1".into(), kind: "conv".into(), k: 3, stride: 1,
                i: 2, o: 4, r: 3, s_in: false, s_out: true,
                in_class: None, out_class: Some("g1".into()),
                basis_shape: vec![9, 2, 3], block_shape: vec![3, 4], blocks_total: 2,
            },
            LayerInfo {
                name: "head".into(), kind: "dense".into(), k: 1, stride: 1,
                i: 4, o: 5, r: 3, s_in: true, s_out: false,
                in_class: Some("g1".into()), out_class: None,
                basis_shape: vec![1, 4, 3], block_shape: vec![3, 5], blocks_total: 2,
            },
        ];
        let mk_composed = |p: usize| {
            vec![
                ParamSpec { name: "v_conv1".into(), shape: vec![9, 2, 3], init_std: 0.1 },
                ParamSpec { name: "u_conv1".into(), shape: vec![3, p * 4], init_std: 0.1 },
                ParamSpec { name: "v_head".into(), shape: vec![1, 4, 3], init_std: 0.1 },
                ParamSpec { name: "u_head".into(), shape: vec![3, p * 5], init_std: 0.1 },
                ParamSpec { name: "bias".into(), shape: vec![5], init_std: 0.0 },
            ]
        };
        let mk_dense = |p: usize| {
            vec![
                ParamSpec { name: "w_conv1".into(), shape: vec![3, 3, 2, 4 * p], init_std: 0.1 },
                ParamSpec { name: "w_head".into(), shape: vec![4 * p, 5], init_std: 0.1 },
                ParamSpec { name: "bias".into(), shape: vec![5], init_std: 0.0 },
            ]
        };
        ModelInfo {
            family: "toy".into(),
            cap_p: 2,
            classes: 5,
            batch: 4,
            eval_batch: 8,
            input: InputInfo::Image { hw: 8, channels: 2 },
            layers,
            composed_params: (1..=2).map(|p| (p, mk_composed(p))).collect(),
            dense_params: (1..=2).map(|p| (p, mk_dense(p))).collect(),
            flops_composed: BTreeMap::from([(1, 1e6), (2, 2e6)]),
            flops_dense: BTreeMap::from([(1, 0.9e6), (2, 1.8e6)]),
            bytes_composed: BTreeMap::from([(1, 500), (2, 800)]),
            bytes_dense: BTreeMap::from([(1, 700), (2, 2000)]),
            probe_dim: BTreeMap::from([(1, 10), (2, 20)]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::toy_info;
    use super::*;

    #[test]
    fn composed_init_shapes() {
        let info = toy_info();
        let g = ComposedGlobal::init(&info, &mut Rng::new(1)).unwrap();
        assert_eq!(g.bases[0].shape(), &[9, 2, 3]);
        assert_eq!(g.coeffs[0].shape(), &[3, 8]); // B=2 blocks of 4 cols
        assert_eq!(g.coeffs[1].shape(), &[3, 10]);
        assert_eq!(g.bias.shape(), &[5]);
        assert!(g.num_elements() > 0);
    }

    #[test]
    fn reduced_inputs_select_blocks() {
        let info = toy_info();
        let g = ComposedGlobal::init(&info, &mut Rng::new(2)).unwrap();
        let sels = vec![vec![1], vec![0]];
        let inputs = g.reduced_inputs(&info, 1, &sels).unwrap();
        assert_eq!(inputs.len(), 5);
        assert_eq!(inputs[1].shape(), &[3, 4]); // û_conv1: 1 block
        assert_eq!(inputs[3].shape(), &[3, 5]); // û_head: 1 block
        // û_conv1 equals block 1 of the full coefficient
        let full = &g.coeffs[0];
        for row in 0..3 {
            assert_eq!(&inputs[1].data()[row * 4..(row + 1) * 4], &full.data()[row * 8 + 4..row * 8 + 8]);
        }
    }

    #[test]
    fn full_inputs_match_cap_width() {
        let info = toy_info();
        let g = ComposedGlobal::init(&info, &mut Rng::new(3)).unwrap();
        let inputs = g.full_inputs(&info);
        assert_eq!(inputs[1].shape(), &[3, 8]);
        assert_eq!(inputs[3].shape(), &[3, 10]);
        // gathering all blocks in order is the identity
        assert_eq!(inputs[1].data(), g.coeffs[0].data());
    }

    #[test]
    fn reduction_error_counts_unsent_blocks() {
        let info = toy_info();
        let mut g = ComposedGlobal::init(&info, &mut Rng::new(4)).unwrap();
        // zero out everything, then set block 0 of layer 0 to ones
        for c in g.coeffs.iter_mut() {
            c.scale(0.0);
        }
        for row in 0..3 {
            for col in 0..4 {
                g.coeffs[0].data_mut()[row * 8 + col] = 1.0;
            }
        }
        // selecting block 0 ⇒ no error; selecting block 1 ⇒ error = 12
        let full_sel_head = vec![0, 1];
        let e0 = g.reduction_error(&info, &[vec![0], full_sel_head.clone()]);
        assert_eq!(e0, 0.0);
        let e1 = g.reduction_error(&info, &[vec![1], full_sel_head]);
        assert!((e1 - 12.0).abs() < 1e-9);
    }

    #[test]
    fn dense_reduce_slices_prefixes() {
        let info = toy_info();
        let g = DenseGlobal::init(&info, &mut Rng::new(5)).unwrap();
        assert_eq!(g.weights[0].shape(), &[3, 3, 2, 8]);
        let reduced = g.reduced_inputs(&info, 1).unwrap();
        assert_eq!(reduced[0].shape(), &[3, 3, 2, 4]);
        assert_eq!(reduced[1].shape(), &[4, 5]);
        assert_eq!(reduced[2].shape(), &[5]); // bias full
        // prefix slice of the first weight matches manual indexing
        let w = &g.weights[0];
        let r = &reduced[0];
        assert_eq!(r.data()[0], w.data()[0]);
        assert_eq!(r.data()[3], w.data()[3]);
        assert_eq!(r.data()[4], w.data()[8]);
    }

    #[test]
    fn from_params_validates() {
        let info = toy_info();
        assert!(ComposedGlobal::from_params(&info, vec![Tensor::zeros(&[1])]).is_err());
        let bad = vec![
            Tensor::zeros(&[9, 2, 3]),
            Tensor::zeros(&[3, 7]), // wrong coeff width
            Tensor::zeros(&[1, 4, 3]),
            Tensor::zeros(&[3, 10]),
            Tensor::zeros(&[5]),
        ];
        assert!(ComposedGlobal::from_params(&info, bad).is_err());
    }
}
