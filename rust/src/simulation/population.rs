//! Parametric population model: O(cohort) rounds at O(million) clients.
//!
//! The eager `FlEnv` world enumerates every client up front — device
//! fleet, data shards, partitions — so building it and planning a round
//! both cost O(population) even when only a K-client cohort participates.
//! This module replaces enumeration with a **distribution**: a
//! [`Population`] holds only the priors (capability-tier mix, data-size
//! prior + jitter, the seed) and derives any individual client's state as
//! a *pure function of `(seed, client_id)`* on first touch.
//!
//! # Lazy-materialization keys (determinism contract)
//!
//! Every per-client quantity gets its own salted RNG, exactly like the
//! scenario engine's per-event RNGs — one fresh generator per
//! `(salt, round, client)` key, never a shared cursor — so derivations
//! are independent of materialization *order* and *count*: touching
//! client 7 first or last, once or twice, caching it or not, yields the
//! same bytes. That is what makes a bounded cache a pure optimization.
//!
//! | quantity            | key                              |
//! |---------------------|----------------------------------|
//! | device class        | `(CLASS, 0, client)`             |
//! | per-round FLOP/s    | `(FLOPS, round, client)`         |
//! | per-round WAN link  | `(LINK, round, client)`          |
//! | cohort draw         | `(COHORT, round, 0)`             |
//! | shard quota + seed  | `(SHARD, 0, client)`             |
//!
//! # Cohort sampling contract
//!
//! [`Population::sample_cohort`] consumes exactly the `below(n - i)`
//! draw sequence of [`Rng::sample_distinct`], but runs the partial
//! Fisher–Yates over a sparse displacement map instead of a
//! `(0..population)` vector — O(k) time and memory, bit-identical output
//! ([`sparse_sample_distinct`]; equivalence property-tested in
//! rust/tests/prop_coordinator.rs). Unavailable picks (scenario windows)
//! are then replaced by bounded keyed rejection draws, so a windowed
//! round still fills its cohort without an O(population) availability
//! scan.
//!
//! Per-client *state* (synthesized shards, loaders) is memoized in a
//! bounded [`LazyCache`] whose [`CacheStats`] counters let tests assert
//! the O(cohort) bound: materializations ≤ rounds·K and resident entries
//! ≤ capacity, independent of population size.

use crate::simulation::device::{DeviceClass, DeviceFleet};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, HashMap, HashSet};

/// Key-mix salts for per-client/per-round derivations (same idiom as the
/// scenario engine's event salts — distinct constants per quantity).
const POP_SALT_CLASS: u64 = 0x9E6B_5533_D00D_0010;
const POP_SALT_FLOPS: u64 = 0x9E6B_5533_D00D_0011;
const POP_SALT_LINK: u64 = 0x9E6B_5533_D00D_0012;
const POP_SALT_COHORT: u64 = 0x9E6B_5533_D00D_0013;
const POP_SALT_SHARD: u64 = 0x9E6B_5533_D00D_0014;

/// One fresh generator per `(salt, a, b)` key: mixes the key injectively
/// enough for SplitMix64's whitening (the +1s keep index 0 off the raw
/// salt).
fn keyed_rng(seed: u64, salt: u64, a: u64, b: u64) -> Rng {
    let mix = salt
        .wrapping_add((a.wrapping_add(1)).wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_add((b.wrapping_add(1)).wrapping_mul(0xD1B5_4A32_D192_ED03));
    Rng::new(seed ^ mix)
}

/// The priors a population is drawn from.
#[derive(Debug, Clone)]
pub struct PopulationSpec {
    pub n_clients: usize,
    pub seed: u64,
    /// capability-tier mix (device class, weight)
    pub mix: Vec<(DeviceClass, f64)>,
    /// ± fractional jitter on per-client shard size around the base quota
    pub size_jitter: f64,
}

impl PopulationSpec {
    /// The paper-like default mix at a given scale.
    pub fn default_mix(n_clients: usize, seed: u64) -> PopulationSpec {
        PopulationSpec {
            n_clients,
            seed,
            mix: DeviceFleet::DEFAULT_MIX.to_vec(),
            size_jitter: 0.25,
        }
    }
}

/// A sampled client's data shard, as a descriptor: synthesize `quota`
/// samples from `seed` on first touch — never an index list into a
/// population-sized dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub client: usize,
    pub quota: usize,
    pub seed: u64,
}

/// A parametric client population. Holds O(1) state; every query is a
/// pure function of `(spec.seed, client, round)`.
#[derive(Debug, Clone)]
pub struct Population {
    spec: PopulationSpec,
    weights: Vec<f64>,
}

impl Population {
    pub fn new(spec: PopulationSpec) -> Result<Population> {
        if spec.n_clients == 0 {
            return Err(anyhow!("population must be non-empty"));
        }
        if spec.mix.is_empty() {
            return Err(anyhow!("population mix must be non-empty"));
        }
        let weights = spec.mix.iter().map(|(_, w)| *w).collect();
        Ok(Population { spec, weights })
    }

    pub fn len(&self) -> usize {
        self.spec.n_clients
    }

    pub fn is_empty(&self) -> bool {
        self.spec.n_clients == 0
    }

    pub fn spec(&self) -> &PopulationSpec {
        &self.spec
    }

    /// The client's capability tier — same weighted draw the eager
    /// `DeviceFleet` makes, keyed instead of sequential.
    #[allow(clippy::indexing_slicing)]
    pub fn device_class(&self, client: usize) -> DeviceClass {
        let mut rng = keyed_rng(self.spec.seed, POP_SALT_CLASS, 0, client as u64);
        // hlint::allow(panic_path): `Rng::weighted` returns an index < weights.len() == mix.len() by contract
        self.spec.mix[rng.weighted(&self.weights)].0
    }

    /// Per-round sustained throughput draw — the `ClientDevice` Gaussian
    /// (mean/cv per class, clamped to [0.4, 1.8]·mean), keyed by
    /// `(round, client)`.
    pub fn flops(&self, client: usize, round: usize) -> f64 {
        let class = self.device_class(client);
        let mean = class.mean_flops();
        let std = mean * class.cv();
        let mut rng = keyed_rng(self.spec.seed, POP_SALT_FLOPS, round as u64, client as u64);
        rng.normal_ms(mean, std).clamp(mean * 0.4, mean * 1.8)
    }

    /// Fresh generator for the client's WAN link draw this round (the
    /// caller feeds it to `NetworkModel::sample[_scaled]`).
    pub fn link_rng(&self, client: usize, round: usize) -> Rng {
        keyed_rng(self.spec.seed, POP_SALT_LINK, round as u64, client as u64)
    }

    /// The client's data-size prior draw: base quota jittered by
    /// ±`size_jitter`, plus the seed its shard is synthesized from.
    pub fn shard_spec(&self, client: usize, base_quota: usize) -> ShardSpec {
        let mut rng = keyed_rng(self.spec.seed, POP_SALT_SHARD, 0, client as u64);
        let j = self.spec.size_jitter.clamp(0.0, 0.9);
        let scale = rng.uniform_in(1.0 - j, 1.0 + j);
        let quota = ((base_quota as f64 * scale).round() as usize).max(1);
        ShardSpec { client, quota, seed: rng.next_u64() }
    }

    /// This round's cohort generator (exposed so tests can replay the
    /// exact draw stream against the dense reference sampler).
    pub fn cohort_rng(&self, round: usize) -> Rng {
        keyed_rng(self.spec.seed, POP_SALT_COHORT, round as u64, 0)
    }

    /// Sample a K-client cohort for `round`, O(k) in time and memory.
    ///
    /// With full availability this is exactly
    /// `cohort_rng(round).sample_distinct(n, k)` (bit-identical, see
    /// [`sparse_sample_distinct`]). Unavailable picks are replaced by
    /// bounded rejection draws from the same generator; if availability
    /// is too thin the cohort comes back short (downstream planners
    /// already treat a thin or empty cohort as a typed condition).
    pub fn sample_cohort(
        &self,
        round: usize,
        k: usize,
        available: impl Fn(usize) -> bool,
    ) -> Vec<usize> {
        let n = self.spec.n_clients;
        let k = k.min(n);
        let mut rng = self.cohort_rng(round);
        let mut picked = sparse_sample_distinct(n, k, &mut rng);
        picked.retain(|&c| available(c));
        if picked.len() == k {
            return picked;
        }
        // top up around unavailable picks: keyed rejection, bounded so a
        // near-empty availability window terminates with a short cohort
        let mut chosen: HashSet<usize> = picked.iter().copied().collect();
        let budget = 64 * k + 256;
        for _ in 0..budget {
            if picked.len() == k {
                break;
            }
            let c = rng.below(n);
            if chosen.insert(c) && available(c) {
                picked.push(c);
            }
        }
        picked
    }
}

/// Partial Fisher–Yates over a sparse displacement map: bit-identical to
/// [`Rng::sample_distinct`] (same `below(n - i)` draw per step, same
/// output prefix) without ever allocating the `(0..n)` vector — O(k)
/// instead of O(population).
// hlint::allow(unkeyed_rng): callers pass the per-round keyed cohort RNG — this fn mirrors `Rng::sample_distinct`'s draw-stream contract and owns no cursor
pub fn sparse_sample_distinct(n: usize, k: usize, rng: &mut Rng) -> Vec<usize> {
    // hlint::allow(panic_path): mirrors `Rng::sample_distinct`'s own contract — callers clamp k ≤ n, so a violation is a caller bug, not input
    assert!(k <= n, "cannot sample {k} from {n}");
    // disp[i] = value currently at virtual position i (identity if absent)
    let mut disp: HashMap<usize, usize> = HashMap::with_capacity(2 * k);
    let at = |disp: &HashMap<usize, usize>, i: usize| disp.get(&i).copied().unwrap_or(i);
    let mut out = Vec::with_capacity(k);
    for i in 0..k {
        let j = i + rng.below(n - i);
        let vi = at(&disp, i);
        let vj = at(&disp, j);
        out.push(vj);
        disp.insert(j, vi);
        disp.insert(i, vj);
    }
    out
}

/// Materialization counters for a [`LazyCache`] — the observable the
/// O(cohort) acceptance tests pin.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// values built from scratch (cache misses)
    pub materializations: usize,
    /// lookups served from the cache
    pub hits: usize,
    /// values evicted to respect the capacity bound
    pub evictions: usize,
    /// high-water mark of resident entries
    pub peak_resident: usize,
}

/// A bounded, counting memo for lazily materialized per-client state
/// (synthesized shards, device profiles). Eviction is least-recently-used
/// with a linear scan — capacity is O(cohort), so the scan is too.
///
/// Values are handed out by clone; callers store `Arc`s so an evicted
/// shard stays alive for any in-flight stream that still holds it.
///
/// Keyed by `BTreeMap` (hlint D3): access ticks are unique so the LRU
/// victim is unique either way (pinned by the reference-model test
/// below), but the ordered map keeps the eviction scan — and any future
/// iteration — deterministic by construction rather than by accident.
#[derive(Debug)]
pub struct LazyCache<T> {
    capacity: usize,
    tick: u64,
    map: BTreeMap<usize, (u64, T)>,
    stats: CacheStats,
}

impl<T: Clone> LazyCache<T> {
    pub fn new(capacity: usize) -> Result<LazyCache<T>> {
        if capacity == 0 {
            return Err(anyhow!("cache capacity must be positive"));
        }
        Ok(LazyCache { capacity, tick: 0, map: BTreeMap::new(), stats: CacheStats::default() })
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn resident(&self) -> usize {
        self.map.len()
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    /// Fetch `key`, materializing it with `build` on a miss. Because
    /// every cached quantity is a pure function of its key, eviction and
    /// rebuild are invisible to callers (bit-identical values).
    pub fn get_or_insert_with(&mut self, key: usize, build: impl FnOnce() -> T) -> T {
        self.tick += 1;
        if let Some((used, v)) = self.map.get_mut(&key) {
            *used = self.tick;
            self.stats.hits += 1;
            return v.clone();
        }
        if self.map.len() >= self.capacity {
            // evict the least-recently-used entry
            if let Some((&old, _)) = self.map.iter().min_by_key(|(_, (used, _))| *used) {
                self.map.remove(&old);
                self.stats.evictions += 1;
            }
        }
        let v = build();
        self.map.insert(key, (self.tick, v.clone()));
        self.stats.materializations += 1;
        self.stats.peak_resident = self.stats.peak_resident.max(self.map.len());
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_matches_dense_sample_distinct() {
        for seed in 0..20u64 {
            let n = 10 + (seed as usize * 37) % 400;
            let k = 1 + (seed as usize * 13) % n.min(40);
            let mut a = Rng::new(seed ^ 0xC0FFEE);
            let mut b = a.clone();
            let dense = a.sample_distinct(n, k);
            let sparse = sparse_sample_distinct(n, k, &mut b);
            assert_eq!(sparse, dense, "n={n} k={k}");
            // identical residual RNG state too
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn sparse_full_permutation() {
        let mut a = Rng::new(3);
        let mut b = a.clone();
        assert_eq!(sparse_sample_distinct(64, 64, &mut b), a.sample_distinct(64, 64));
    }

    #[test]
    fn derivations_are_order_independent() {
        let pop = Population::new(PopulationSpec::default_mix(1000, 42)).unwrap();
        // touch in one order...
        let fwd: Vec<_> = (0..100).map(|c| (pop.device_class(c), pop.flops(c, 3))).collect();
        // ...and the reverse; same bytes
        let mut rev: Vec<_> =
            (0..100).rev().map(|c| (pop.device_class(c), pop.flops(c, 3))).collect();
        rev.reverse();
        assert_eq!(
            fwd.iter().map(|(c, f)| (c.name(), f.to_bits())).collect::<Vec<_>>(),
            rev.iter().map(|(c, f)| (c.name(), f.to_bits())).collect::<Vec<_>>()
        );
    }

    #[test]
    fn class_mix_matches_priors() {
        let pop = Population::new(PopulationSpec::default_mix(4000, 9)).unwrap();
        let frac = |want: DeviceClass| {
            (0..4000).filter(|&c| pop.device_class(c) == want).count() as f64 / 4000.0
        };
        assert!((frac(DeviceClass::Laptop) - 0.4).abs() < 0.05);
        assert!((frac(DeviceClass::AgxXavier) - 0.1).abs() < 0.03);
    }

    #[test]
    fn flops_stay_in_class_band() {
        let pop = Population::new(PopulationSpec::default_mix(100, 7)).unwrap();
        for c in 0..100 {
            let mean = pop.device_class(c).mean_flops();
            for r in 0..5 {
                let q = pop.flops(c, r);
                assert!(q >= mean * 0.4 && q <= mean * 1.8, "q={q} mean={mean}");
            }
        }
    }

    #[test]
    fn shard_spec_jitters_around_base() {
        let pop = Population::new(PopulationSpec::default_mix(500, 11)).unwrap();
        let mut sum = 0.0;
        for c in 0..500 {
            let s = pop.shard_spec(c, 60);
            assert!(s.quota >= 45 && s.quota <= 75, "quota {} outside ±25%", s.quota);
            assert_eq!(s, pop.shard_spec(c, 60), "shard spec must be pure");
            sum += s.quota as f64;
        }
        let mean = sum / 500.0;
        assert!((mean - 60.0).abs() < 2.0, "jitter not centered: {mean}");
    }

    #[test]
    fn cohort_is_distinct_in_range_and_deterministic() {
        let pop = Population::new(PopulationSpec::default_mix(100_000, 5)).unwrap();
        for round in 0..4 {
            let a = pop.sample_cohort(round, 16, |_| true);
            let b = pop.sample_cohort(round, 16, |_| true);
            assert_eq!(a, b);
            assert_eq!(a.len(), 16);
            let mut s = a.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 16);
            assert!(s.iter().all(|&c| c < 100_000));
        }
        // different rounds draw different cohorts (overwhelmingly)
        assert_ne!(pop.sample_cohort(0, 16, |_| true), pop.sample_cohort(1, 16, |_| true));
    }

    #[test]
    fn cohort_respects_availability() {
        let pop = Population::new(PopulationSpec::default_mix(10_000, 6)).unwrap();
        let avail = |c: usize| c % 3 == 0;
        let cohort = pop.sample_cohort(2, 32, avail);
        assert_eq!(cohort.len(), 32);
        assert!(cohort.iter().all(|&c| avail(c)));
        let mut s = cohort.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 32);
    }

    #[test]
    fn cohort_thin_availability_comes_back_short_not_hung() {
        let pop = Population::new(PopulationSpec::default_mix(1000, 8)).unwrap();
        let cohort = pop.sample_cohort(0, 16, |c| c == 17);
        assert!(cohort.len() <= 1);
        assert!(cohort.iter().all(|&c| c == 17));
    }

    #[test]
    fn cache_counts_and_bounds() {
        let mut cache: LazyCache<usize> = LazyCache::new(4).unwrap();
        for round in 0..10 {
            for key in [round, round + 1, round + 2] {
                let v = cache.get_or_insert_with(key, || key * 10);
                assert_eq!(v, key * 10);
            }
            assert!(cache.resident() <= 4);
        }
        let st = cache.stats().clone();
        assert!(st.peak_resident <= 4);
        assert!(st.hits > 0);
        // keys 0..=11 each materialized at least once; two of each round's
        // three keys are re-hits from the previous round
        assert!(st.materializations >= 12);
        assert_eq!(st.materializations, st.evictions + cache.resident());
    }

    #[test]
    fn cache_rebuild_after_eviction_is_invisible() {
        let mut cache: LazyCache<u64> = LazyCache::new(2).unwrap();
        let build = |k: usize| Rng::new(k as u64).next_u64();
        let first = cache.get_or_insert_with(7, || build(7));
        // push 7 out...
        cache.get_or_insert_with(1, || build(1));
        cache.get_or_insert_with(2, || build(2));
        cache.get_or_insert_with(3, || build(3));
        // ...and rebuild: pure keys ⇒ identical value
        let again = cache.get_or_insert_with(7, || build(7));
        assert_eq!(first, again);
        assert!(cache.stats().evictions >= 2);
    }

    #[test]
    fn lru_eviction_matches_reference_model() {
        // bit-exactness pin for the HashMap → BTreeMap conversion: access
        // ticks are unique, so the LRU victim — and with it every hit,
        // miss and eviction downstream — must match a naive reference
        // implementation step for step, independent of map internals
        struct RefLru {
            cap: usize,
            tick: u64,
            entries: Vec<(usize, u64, usize)>, // (key, last_used, value)
        }
        impl RefLru {
            fn get(&mut self, key: usize, build: impl FnOnce() -> usize) -> (usize, bool) {
                self.tick += 1;
                if let Some(e) = self.entries.iter_mut().find(|e| e.0 == key) {
                    e.1 = self.tick;
                    return (e.2, true);
                }
                if self.entries.len() >= self.cap {
                    let (pos, _) =
                        self.entries.iter().enumerate().min_by_key(|(_, e)| e.1).unwrap();
                    self.entries.remove(pos);
                }
                let v = build();
                self.entries.push((key, self.tick, v));
                (v, false)
            }
        }
        let mut cache: LazyCache<usize> = LazyCache::new(3).unwrap();
        let mut reference = RefLru { cap: 3, tick: 0, entries: Vec::new() };
        let mut ref_hits = 0usize;
        let mut rng = Rng::new(0xE41C);
        for step in 0..500 {
            let key = rng.below(8);
            let (want, hit) = reference.get(key, || key * 1000 + 7);
            let got = cache.get_or_insert_with(key, || key * 1000 + 7);
            assert_eq!(got, want, "step {step} key {key}");
            ref_hits += usize::from(hit);
        }
        let st = cache.stats();
        assert_eq!(st.hits, ref_hits, "eviction victims diverged from the reference LRU");
        assert_eq!(st.materializations, 500 - ref_hits);
        assert_eq!(cache.resident(), reference.entries.len());
        assert_eq!(st.materializations, st.evictions + cache.resident());
    }
}
