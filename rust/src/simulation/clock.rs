//! Virtual clock + traffic meter.
//!
//! All completion-time metrics in the experiments are *simulated* time
//! (the paper's testbed also simulates devices/network on a workstation).
//! The clock advances by the synchronous-round maximum (Eq. 19); the
//! meter sums every PS↔client transfer (metric ④, §VI-B2).

/// Monotonic virtual clock (seconds).
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock { now: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance by `dt` seconds (panics on negative dt — a scheduling bug).
    pub fn advance(&mut self, dt: f64) {
        // hlint::allow(panic_path): a backwards clock is a scheduler bug, not a recoverable input — pinned by `clock_rejects_negative`
        assert!(dt >= 0.0, "clock moved backwards by {dt}");
        self.now += dt;
    }
}

/// Cumulative PS↔client traffic in bytes, split by direction.
#[derive(Debug, Clone, Default)]
pub struct TrafficMeter {
    pub down_bytes: u64,
    pub up_bytes: u64,
}

impl TrafficMeter {
    pub fn new() -> TrafficMeter {
        TrafficMeter::default()
    }

    pub fn record_down(&mut self, bytes: u64) {
        self.down_bytes += bytes;
    }

    pub fn record_up(&mut self, bytes: u64) {
        self.up_bytes += bytes;
    }

    pub fn total_bytes(&self) -> u64 {
        self.down_bytes + self.up_bytes
    }

    pub fn total_gb(&self) -> f64 {
        crate::util::cast::bytes_to_f64(self.total_bytes()) / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances_monotonically() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(1.5);
        c.advance(0.0);
        c.advance(2.5);
        assert!((c.now() - 4.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "backwards")]
    fn clock_rejects_negative() {
        VirtualClock::new().advance(-1.0);
    }

    #[test]
    fn traffic_accumulates() {
        let mut t = TrafficMeter::new();
        t.record_down(1000);
        t.record_up(500);
        t.record_up(250);
        assert_eq!(t.down_bytes, 1000);
        assert_eq!(t.up_bytes, 750);
        assert_eq!(t.total_bytes(), 1750);
        assert!((t.total_gb() - 1.75e-6).abs() < 1e-15);
    }
}
