//! Device compute model.
//!
//! Four device classes mirror the paper's physical testbed records
//! (§VI-C): laptop, Jetson TX2, Xavier NX, AGX Xavier. Effective training
//! throughput (FLOP/s actually sustained by f32 training, not peak specs)
//! is Gaussian per round: `q_n^h ~ N(mean, (cv·mean)²)`, giving the ~4×
//! strongest-to-weakest spread of the paper's Fig. 2. The fleet mix keeps
//! powerful devices rare ("high-performance clients only constitute a
//! small fraction" — §I).

use crate::util::rng::Rng;

/// Edge device classes from the paper's testbed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceClass {
    Laptop,
    JetsonTx2,
    XavierNx,
    AgxXavier,
}

impl DeviceClass {
    /// Mean sustained training throughput (FLOP/s). Values are scaled to
    /// this testbed but preserve the published inter-device ratios
    /// (TX2 : NX : AGX ≈ 1.3 : 21 : 32 TOPS peak → compressed in
    /// sustained f32 training to roughly 1 : 2 : 3, laptop ≈ 0.7×TX2).
    pub fn mean_flops(self) -> f64 {
        match self {
            DeviceClass::Laptop => 2.0e7,
            DeviceClass::JetsonTx2 => 3.0e7,
            DeviceClass::XavierNx => 6.0e7,
            DeviceClass::AgxXavier => 9.0e7,
        }
    }

    /// Coefficient of variation of the per-round throughput draw.
    pub fn cv(self) -> f64 {
        0.15
    }

    pub fn name(self) -> &'static str {
        match self {
            DeviceClass::Laptop => "laptop",
            DeviceClass::JetsonTx2 => "jetson-tx2",
            DeviceClass::XavierNx => "xavier-nx",
            DeviceClass::AgxXavier => "agx-xavier",
        }
    }
}

/// One client's device: samples a throughput per round.
#[derive(Debug, Clone)]
pub struct ClientDevice {
    pub class: DeviceClass,
    // hlint::allow(unkeyed_rng): the eager fleet's per-client cursor, forked once from the run seed at construction — byte-compat with the pre-population goldens; the lazy path derives keyed RNGs instead
    rng: Rng,
}

impl ClientDevice {
    // hlint::allow(unkeyed_rng): constructor takes ownership of the forked per-client cursor (see field note above)
    pub fn new(class: DeviceClass, rng: Rng) -> ClientDevice {
        ClientDevice { class, rng }
    }

    /// Throughput (FLOP/s) for this round; clamped to stay positive and
    /// within a sane band so a single draw cannot produce a degenerate
    /// round time.
    pub fn sample_flops(&mut self) -> f64 {
        let mean = self.class.mean_flops();
        let std = mean * self.class.cv();
        self.rng.normal_ms(mean, std).clamp(mean * 0.4, mean * 1.8)
    }

    /// Seconds for one local iteration of a model costing `flops`
    /// (paper Eq. 17: μ = G(v·û)/q).
    pub fn iteration_time(&mut self, flops: f64) -> f64 {
        flops / self.sample_flops()
    }
}

/// The fleet: device class per client, drawn from the configured mix.
#[derive(Debug, Clone)]
pub struct DeviceFleet {
    pub devices: Vec<ClientDevice>,
}

impl DeviceFleet {
    /// Paper-like mix: mostly weak devices, few powerful ones.
    pub const DEFAULT_MIX: [(DeviceClass, f64); 4] = [
        (DeviceClass::Laptop, 0.4),
        (DeviceClass::JetsonTx2, 0.3),
        (DeviceClass::XavierNx, 0.2),
        (DeviceClass::AgxXavier, 0.1),
    ];

    #[allow(clippy::indexing_slicing)]
    // hlint::allow(unkeyed_rng): eager-fleet construction draws the class mix from the run-seed cursor once, up front — byte-compat pinned by the pre-population goldens
    pub fn new(n_clients: usize, mix: &[(DeviceClass, f64)], rng: &mut Rng) -> DeviceFleet {
        let weights: Vec<f64> = mix.iter().map(|(_, w)| *w).collect();
        let devices = (0..n_clients)
            .map(|i| {
                // hlint::allow(panic_path): `Rng::weighted` returns an index < weights.len() == mix.len() by contract
                let class = mix[rng.weighted(&weights)].0;
                ClientDevice::new(class, rng.fork(i as u64))
            })
            .collect();
        DeviceFleet { devices }
    }

    // hlint::allow(unkeyed_rng): thin wrapper over `new` — same construction-time contract
    pub fn default_fleet(n_clients: usize, rng: &mut Rng) -> DeviceFleet {
        Self::new(n_clients, &Self::DEFAULT_MIX, rng)
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_order_matches_classes() {
        assert!(DeviceClass::Laptop.mean_flops() < DeviceClass::JetsonTx2.mean_flops());
        assert!(DeviceClass::JetsonTx2.mean_flops() < DeviceClass::XavierNx.mean_flops());
        assert!(DeviceClass::XavierNx.mean_flops() < DeviceClass::AgxXavier.mean_flops());
        // paper Fig. 2: ~4x spread strongest vs weakest
        let ratio = DeviceClass::AgxXavier.mean_flops() / DeviceClass::Laptop.mean_flops();
        assert!((3.0..6.0).contains(&ratio), "spread {ratio}");
    }

    #[test]
    fn samples_cluster_around_mean() {
        let mut d = ClientDevice::new(DeviceClass::XavierNx, Rng::new(1));
        let n = 5000;
        let mean_draw: f64 = (0..n).map(|_| d.sample_flops()).sum::<f64>() / n as f64;
        let mean = DeviceClass::XavierNx.mean_flops();
        assert!((mean_draw / mean - 1.0).abs() < 0.05, "mean drift {mean_draw}");
    }

    #[test]
    fn iteration_time_scales_with_flops() {
        let mut d = ClientDevice::new(DeviceClass::Laptop, Rng::new(2));
        let t1: f64 = (0..500).map(|_| d.iteration_time(1e7)).sum();
        let mut d2 = ClientDevice::new(DeviceClass::Laptop, Rng::new(2));
        let t2: f64 = (0..500).map(|_| d2.iteration_time(2e7)).sum();
        assert!((t2 / t1 - 2.0).abs() < 0.01, "not linear in flops: {}", t2 / t1);
    }

    #[test]
    fn fleet_mix_roughly_matches() {
        let mut rng = Rng::new(3);
        let fleet = DeviceFleet::default_fleet(2000, &mut rng);
        let frac = |c: DeviceClass| {
            fleet.devices.iter().filter(|d| d.class == c).count() as f64 / 2000.0
        };
        assert!((frac(DeviceClass::Laptop) - 0.4).abs() < 0.05);
        assert!((frac(DeviceClass::AgxXavier) - 0.1).abs() < 0.03);
    }
}
